#!/usr/bin/env bash
# CI entry point: tier-1 verify + formatting + fast bench JSON emission.
set -euo pipefail
cd "$(dirname "$0")"

echo "== guard: every rust/tests/ file is a [[test]] target in Cargo.toml =="
# Cargo.toml sets autotests = false (targets are explicit), so a new
# integration-test file that nobody registers would silently never run.
# Fail loudly instead.
missing=0
for f in rust/tests/*.rs; do
    name="$(basename "$f" .rs)"
    if ! grep -Eq "name[[:space:]]*=[[:space:]]*\"$name\"" rust/Cargo.toml; then
        echo "ERROR: $f has no [[test]] target named \"$name\" in rust/Cargo.toml"
        missing=1
    fi
done
if [ "$missing" -ne 0 ]; then
    echo "register the file(s) above as [[test]] targets (autotests = false)"
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== tier-1 again with the SIMD vector paths force-disabled =="
# The same suite with YODANN_FORCE_SCALAR=1: the functional-simd engine
# must fall back to its portable-scalar loop and stay bit-identical, so
# both sides of the runtime dispatch are pinned on every CI run.
YODANN_FORCE_SCALAR=1 cargo test -q

echo "== tier-1 a third time with fault injection armed from the environment =="
# YODANN_FAULT_SEED arms a session-default FaultPlan at SMOKE_BER through
# SessionBuilder::build's env fallback. The whole suite must still pass:
# tests that need determinism opt out with an explicit
# FaultPlan::disabled(), everything else must survive the occasional
# detected-and-retried flip.
YODANN_FAULT_SEED=7 cargo test -q

echo "== cargo build --examples (every non-golden example; quickstart needs --features golden) =="
cargo build --examples

echo "== cargo doc --no-deps with warnings denied (rustdoc is part of the serving API) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== example smoke: the serving-API examples must run end to end =="
cargo run --release --example serving_api
cargo run --release --example sharded_throughput
cargo run --release --example resnet_graph

echo "== cargo test --release -q (release-mode overflow/wrap behavior) =="
cargo test --release -q

# Note: src/fault, src/api, src/serve and src/coordinator additionally
# carry #![deny(clippy::unwrap_used, clippy::expect_used)] outside tests
# — the layers that own threads, locks and fault handling must not panic.
echo "== cargo clippy --all-targets -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy unavailable; skipping"
fi

echo "== cargo audit / cargo deny (advisory gates, skipped when not installed) =="
# The dependency tree is intentionally empty (std-only), so these are
# cheap; they exist to catch a future dependency slipping in with a
# known advisory. Both tools need a crate registry, so the growth
# container (offline) skips them and real CI runs them.
if command -v cargo-audit >/dev/null 2>&1; then
    cargo audit
else
    echo "cargo-audit unavailable; skipping"
fi
if command -v cargo-deny >/dev/null 2>&1; then
    cargo deny check
else
    echo "cargo-deny unavailable; skipping"
fi

echo "== cargo fmt --check (enforced) =="
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --check; then
        # Enforced: drift fails the run. As a courtesy the drift is
        # also fixed in the working tree, so a local run leaves only a
        # diff to commit (the one-time repo-wide format could not be
        # applied in the rustfmt-less growth container).
        echo "ERROR: cargo fmt --check found drift. The format has been applied"
        echo "to the working tree — commit the diff and re-run."
        cargo fmt
        exit 1
    fi
else
    echo "rustfmt unavailable; skipping"
fi

echo "== CLI smoke: static analyzer over every accepted network =="
# All four passes (ranges, liveness, contracts, locks) over every
# networks::ACCEPTED id at its native frame size, sharded-plan proofs
# included. The command exits non-zero on any error-severity finding,
# so this leg fails CI if a planner/compiler change breaks a proof.
# (Saturation *warnings* at full-range input are expected and pass.)
cargo run --release -- analyze --workers 2
# The row-band lowering proves through the same gate.
cargo run --release -- analyze --net bc-cifar10 --bands 3

echo "== CLI smoke: SIMD engine + row-band schedule through yodann throughput =="
cargo run --release -- throughput --engine simd --frames 2 --workers 2 --bands 2

echo "== CLI smoke: XNOR engine family + mixed-precision chain =="
# The binary-activation family end to end (bit-identity within the
# family), then the per-layer precision knob: a BWN stem with a binary
# trunk routed onto the XNOR companion engines.
cargo run --release -- throughput --engine xnor --frames 2 --workers 2
cargo run --release -- throughput --engine xnor-all --frames 2 --workers 2
cargo run --release -- throughput --engine both --frames 2 --workers 2 --precision multi-bit,binary,binary
# The derived accelerator-generation table renders.
cargo run --release -- table xnor

echo "== CLI smoke: near-threshold fault sweep through yodann faults =="
cargo run --release -- faults --net bc-cifar10 --corner 0.6 --frames 2

echo "== CLI smoke: power-aware serving daemon (DVFS governor) =="
# Burst traffic under a 1 mW core-power budget: the default chain's 7x7
# envelope on one chip prices under the budget at the 0.6 V rail, so
# the governor holds it and the daemon must exit 0.
cargo run --release -- serve --scenario burst --frames 64 --budget-mw 1.0 --seed 7
# Sustained saturation against a drain-latency SLO: the offered load
# oversubscribes the 0.6 V rail, so the governor has to leave the
# energy-optimal corner to keep the queue inside 0.1 ms (and earns its
# way back down once the input drains).
cargo run --release -- serve --scenario sustained --frames 64 --slo-ms 0.1 --tick-ms 0.05 --seed 7
# A budget below the idle floor cannot be held at any corner: the
# daemon must report the steady-state violation with a non-zero exit.
if cargo run --release -- serve --scenario burst --frames 16 --budget-mw 0.05 --seed 7; then
    echo "ERROR: an unholdable power budget must exit non-zero"
    exit 1
fi

echo "== fast engine A/B bench (writes BENCH_engines.json) =="
YODANN_BENCH_FAST=1 cargo bench --bench engines

echo "ci.sh: all checks done"
