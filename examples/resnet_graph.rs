//! Graph-IR serving: ResNet-18 (residual adds, projection shortcuts,
//! stride-2 subsampling) and AlexNet (the §IV-D 11×11 kernel split,
//! parallel partial convolutions summed off-chip) end-to-end through
//! the `Yodann` facade — the two topologies the chain-only API used to
//! reject with `NotASimpleChain`.
//!
//! Run: `cargo run --release --example resnet_graph`

use yodann::api::SessionBuilder;
use yodann::engine::EngineKind;
use yodann::model::networks;
use yodann::testkit::Gen;
use yodann::workload::synthetic_scene;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    for (id, graph, (h, w)) in [
        ("resnet18", networks::resnet18_graph(42), (48usize, 40usize)),
        ("alexnet", networks::alexnet_graph(42), (48, 40)),
    ] {
        // compile() validates the whole graph (channel typing, join
        // arity, reachability) into typed errors; walk_shapes carries
        // one frame's geometry through every conv segment and host-op
        // interlude without running it.
        let plan = graph.compile()?;
        let (oc, oh, ow) = plan.walk_shapes(3, h, w)?;
        println!(
            "{id}: {} conv layers, {} plan steps; 3x{h}x{w} -> {oc}x{oh}x{ow}",
            plan.convs.len(),
            plan.steps.len()
        );
        let mut sess = SessionBuilder::new()
            .graph(&graph)
            .engine(EngineKind::Functional)
            .workers(4)
            .build()?;
        let mut g = Gen::new(7);
        let frames: Vec<_> = (0..4).map(|_| synthetic_scene(&mut g, 3, h, w)).collect();
        let t0 = std::time::Instant::now();
        let results = sess.run_batch(frames)?;
        let dt = t0.elapsed().as_secs_f64();
        let ops: u64 = results.iter().map(|r| r.telemetry.ops).sum();
        println!(
            "  {} frames in {:.3} s ({:.2} frames/s, {:.2} GOp of Eq. 7 work)",
            results.len(),
            dt,
            results.len() as f64 / dt,
            ops as f64 / 1e9
        );
    }
    println!("graph networks serve end-to-end (no NotASimpleChain)");
    Ok(())
}
