//! Quickstart: run one binary-weight convolution block on the
//! cycle-accurate YodaNN simulator, check it bit-for-bit against the
//! AOT-compiled JAX/Pallas golden model (if `make artifacts` has run),
//! and report the paper's metrics at both operating corners.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use yodann::coordinator::{check_block, metrics::sim_metrics};
use yodann::hw::{BlockJob, Chip, ChipConfig};
use yodann::power::ArchId;
use yodann::runtime::Runtime;
use yodann::testkit::Gen;
use yodann::workload::{random_image, BinaryKernels, ScaleBias};

fn main() -> anyhow::Result<()> {
    // A 3×3 layer block: 32 input channels → 64 output channels (the
    // dual-filter mode), 16×16 pixels, zero-padded.
    let mut g = Gen::new(1);
    let image = random_image(&mut g, 32, 16, 16, 0.02);
    let kernels = BinaryKernels::random(&mut g, 64, 32, 3);
    let sb = ScaleBias::random(&mut g, 64);

    println!("== YodaNN quickstart ==");
    println!(
        "weights: {} binary weights = {} bytes on the wire (12-bit would be {} bytes)\n",
        kernels.bits.len(),
        kernels.storage_bits() / 8,
        kernels.storage_bits() * 12 / 8
    );

    // 1. Cycle-accurate simulation.
    let cfg = ChipConfig::yodann();
    let job = BlockJob {
        k: 3,
        zero_pad: true,
        image: image.clone(),
        kernels: kernels.clone(),
        scale_bias: sb.clone(),
    };
    let res = Chip::new(cfg).run_block(&job);
    let s = &res.stats;
    println!("simulated {} cycles:", s.cycles.total());
    println!(
        "  filter load {} | preload {} | compute {} | idle {} | flush {}",
        s.cycles.filter_load, s.cycles.preload, s.cycles.compute, s.cycles.idle, s.cycles.flush
    );
    println!(
        "  SCM {} reads / {} writes (max {} banks active per cycle — paper: ≤7)",
        s.scm_reads, s.scm_writes, s.scm_max_banks_per_cycle
    );

    // 2. Golden check against the JAX/Pallas model through PJRT.
    match Runtime::open_default() {
        Ok(mut rt) => {
            let report = check_block(&mut rt, &cfg, &image, &kernels, &sb, true)?;
            println!(
                "\ngolden check vs JAX/Pallas ({} samples): {}",
                report.samples,
                if report.ok() { "BIT-EXACT" } else { "MISMATCH!" }
            );
            assert!(report.ok());
        }
        Err(e) => println!("\n(golden check skipped: {e})"),
    }

    // 3. The paper's metrics at both corners.
    println!();
    for (label, v) in [("energy-optimal", 0.6), ("throughput-optimal", 1.2)] {
        let m = sim_metrics(s, ArchId::Bin32Multi, v, true);
        println!(
            "{label:>18} @{v:.1} V: {:>7.2} GOp/s  {:>6.1} TOp/s/W  {:>8.3} ms  {:>7.2} uJ",
            m.theta / 1e9,
            m.en_eff / 1e12,
            m.time * 1e3,
            m.core_energy * 1e6
        );
    }
    Ok(())
}
