//! Voltage sweep (Figs. 11 & 13): throughput, core energy efficiency and
//! area efficiency of YodaNN vs the fixed-point baseline across the
//! 0.6–1.2 V operating range, with the state-of-the-art pareto points.
//!
//! ```bash
//! cargo run --release --example voltage_sweep
//! ```

use yodann::power::{metric_area_mge, ArchId};
use yodann::report::figures;

fn main() {
    println!("== Fig. 11: throughput & core energy efficiency vs supply ==\n");
    for arch in [ArchId::Q29Fixed8, ArchId::Bin8, ArchId::Bin32Multi] {
        println!("{}:", arch.name());
        println!("  {:>5} {:>10} {:>12} {:>12} {:>14}", "V", "f (MHz)", "GOp/s", "TOp/s/W", "GOp/s/MGE");
        for p in figures::fig11_sweep(arch, 13) {
            println!(
                "  {:>5.2} {:>10.1} {:>12.1} {:>12.2} {:>14.1}",
                p.v,
                p.f_mhz,
                p.theta_gops,
                p.en_eff_tops_w,
                p.theta_gops / metric_area_mge(arch)
            );
        }
        println!();
    }

    println!("key comparisons (paper §IV-C):");
    let q29 = figures::fig11_sweep(ArchId::Q29Fixed8, 2);
    let bin8 = figures::fig11_sweep(ArchId::Bin8, 13);
    let q12 = q29.last().unwrap();
    let b12 = bin8.last().unwrap();
    let b06 = bin8.first().unwrap();
    println!(
        "  binary vs Q2.9 @1.2 V : {:.1}x core energy efficiency (paper: 5.1x), {:.2}x throughput (paper: 1.3x)",
        b12.en_eff_tops_w / q12.en_eff_tops_w,
        b12.theta_gops / q12.theta_gops
    );
    let q08 = &q29[0];
    println!(
        "  binary @0.6 V vs Q2.9 @0.8 V: {:.1}x energy efficiency (paper: 11.6x)",
        b06.en_eff_tops_w / q08.en_eff_tops_w
    );

    println!("\n== Fig. 13: pareto front vs state of the art ==\n");
    println!("{:<18} {:>12} {:>16}", "point", "TOp/s/W", "GOp/s/MGE");
    for p in figures::fig13(13) {
        println!(
            "{:<18} {:>12.2} {:>16.1}{}",
            p.name,
            p.en_eff,
            p.area_eff,
            if p.ours { "  *" } else { "" }
        );
    }
    println!("\n(* = YodaNN voltage-sweep points; every literature point is dominated)");
}
