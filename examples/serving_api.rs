//! The serving API end to end: build a session with [`SessionBuilder`],
//! pump frames through the non-blocking `submit` → [`FrameTicket`] path
//! with backpressure handling, and read the per-frame telemetry
//! (cycles, energy, Θ, power envelope) that rides on every result.
//!
//! The loop below is the intended shape of a serving frontend: admit
//! frames while the bounded in-flight queue has room, drain finished
//! tickets when it does not, and account every response.
//!
//! ```bash
//! cargo run --release --example serving_api
//! ```

use std::collections::VecDeque;

use yodann::api::{FrameTicket, SessionBuilder, YodannError};
use yodann::engine::EngineKind;
use yodann::model::networks;
use yodann::testkit::Gen;
use yodann::workload::{synthetic_scene, Image};

fn main() {
    let net = networks::scene_labeling();
    println!("== serving {} through the Yodann facade ==\n", net.name);

    // One validated configuration object; errors are typed and eager.
    let mut session = SessionBuilder::new()
        .network(&net, 42)
        .engine(EngineKind::CycleAccurate) // full per-frame ledger
        .workers(4)
        .supply(0.6) // the paper's energy-optimal corner
        .max_in_flight(3)
        .build()
        .expect("scene-labeling chains");
    println!(
        "session: {} layers, {} workers, policy {}, corner {:.1} V, in-flight bound {}\n",
        session.n_layers(),
        session.workers(),
        session.policy(),
        session.corner().v,
        session.max_in_flight()
    );

    // A malformed request is a typed error, not a panic.
    match session.submit(Image::zeros(5, 24, 32)) {
        Err(YodannError::FrameChannelMismatch { got, expected }) => {
            println!("rejected a {got}-channel frame (network takes {expected}) — typed error\n")
        }
        other => panic!("expected a typed channel mismatch, got {other:?}"),
    }

    // The serving loop: submit ahead, drain on backpressure.
    let mut g = Gen::new(0x5EE5);
    let traffic: Vec<Image> = (0..6).map(|_| synthetic_scene(&mut g, 3, 24, 32)).collect();
    let mut pending: VecDeque<FrameTicket> = VecDeque::new();
    println!("{:>5} {:>12} {:>12} {:>10} {:>12} {:>12}", "frame", "cycles", "energy uJ",
        "GOp/s", "host ms", "envelope mW");
    let drain = |t: FrameTicket| {
        let r = t.wait().expect("frame computes");
        let tel = &r.telemetry;
        println!(
            "{:>5} {:>12} {:>12.2} {:>10.2} {:>12.2} {:>12.2}",
            tel.frame_id,
            tel.cycles,
            tel.energy_j().unwrap_or(0.0) * 1e6,
            tel.chip_gops().unwrap_or(0.0),
            tel.host_seconds * 1e3,
            tel.envelope.total_w() * 1e3,
        );
    };
    for frame in traffic {
        loop {
            match session.submit(frame.clone()) {
                Ok(ticket) => {
                    pending.push_back(ticket);
                    break;
                }
                Err(YodannError::Backpressure { .. }) => {
                    // Queue full: retire the oldest in-flight frame.
                    drain(pending.pop_front().expect("backpressure implies pending work"));
                }
                Err(e) => panic!("unexpected submit failure: {e}"),
            }
        }
    }
    for t in pending {
        drain(t);
    }
    println!("\n(telemetry is per frame, priced at the session corner — no side channels)");
}
