//! End-to-end driver (EXPERIMENTS.md §E2E): the workload the paper's
//! power simulations used — the scene-labeling CNN of Cavigelli et al.
//! [13]/[50] on Stanford-backgrounds-like frames — run **through the full
//! stack**: synthetic frame generation → L3 coordinator block
//! decomposition → cycle-accurate chip simulation of every block →
//! off-chip accumulation → quantized ReLU/pooling between layers →
//! per-pixel 8-class argmax, with golden spot-checks against the
//! JAX/Pallas model and the paper's metrics at both corners.
//!
//! ```bash
//! cargo run --release --example scene_labeling           # 120×160 frame
//! cargo run --release --example scene_labeling -- --full # 240×320 frame
//! ```

use std::time::Instant;

use yodann::coordinator::{metrics::sim_metrics, run_layer, ExecOptions, LayerWorkload};
use yodann::fixedpoint::Q2_9;
use yodann::hw::{ChipConfig, ChipStats};
use yodann::model::{evaluate_network, networks, Corner};
use yodann::power::ArchId;
use yodann::testkit::Gen;
use yodann::workload::{synthetic_scene, BinaryKernels, Image, ScaleBias};

fn relu(img: &mut Image) {
    img.data.iter_mut().for_each(|v| *v = (*v).max(0));
}

fn maxpool2(img: &Image) -> Image {
    let mut out = Image::zeros(img.c, img.h / 2, img.w / 2);
    for c in 0..img.c {
        for y in 0..out.h {
            for x in 0..out.w {
                *out.at_mut(c, y, x) = img
                    .at(c, 2 * y, 2 * x)
                    .max(img.at(c, 2 * y, 2 * x + 1))
                    .max(img.at(c, 2 * y + 1, 2 * x))
                    .max(img.at(c, 2 * y + 1, 2 * x + 1));
            }
        }
    }
    out
}

const CLASSES: [&str; 8] =
    ["sky", "tree", "road", "grass", "water", "building", "mountain", "fg-object"];

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (h, w) = if full { (240, 320) } else { (120, 160) };
    println!("== scene labeling end-to-end ({h}x{w} synthetic frame, 8 classes) ==\n");

    let mut g = Gen::new(0x5CE11E);
    let mut x = synthetic_scene(&mut g, 3, h, w);
    // Scale into a regime where per-layer scaling keeps Q2.9 healthy.
    x.data.iter_mut().for_each(|v| *v /= 8);

    // Layer stack of [50]: 7×7 convs 3→16→64→256 with pooling, then an
    // 8-class 1×1 classifier (the FC runs as 1×1 conv here so the whole
    // pipeline stays on the accelerator).
    let specs: Vec<(usize, usize, usize, bool, f64)> = vec![
        // (k, n_in, n_out, pool, alpha)
        (7, 3, 16, true, 0.08),
        (7, 16, 64, true, 0.02),
        (7, 64, 256, false, 0.006),
        (1, 256, 8, false, 0.01),
    ];

    let cfg = ChipConfig::yodann();
    let mut total = ChipStats::default();
    let mut blocks = 0usize;
    let wall = Instant::now();
    for (li, &(k, n_in, n_out, pool, alpha)) in specs.iter().enumerate() {
        let kernels = BinaryKernels::random(&mut g, n_out, n_in, k);
        let sb = ScaleBias {
            alpha: vec![Q2_9.from_f64(alpha); n_out],
            beta: vec![0; n_out],
        };
        let wl = LayerWorkload { k, zero_pad: true, input: x.clone(), kernels, scale_bias: sb };
        let t0 = Instant::now();
        let run = run_layer(&wl, &cfg, ExecOptions::default());
        println!(
            "layer {}: k={k} {n_in:>3}->{n_out:>3} {}x{}  {:>4} blocks  {:>12} cycles  (sim {:?})",
            li + 1,
            x.h,
            x.w,
            run.blocks,
            run.stats.cycles.total(),
            t0.elapsed()
        );
        total.merge(&run.stats);
        blocks += run.blocks;
        x = run.output;
        if li + 1 < specs.len() {
            relu(&mut x);
        }
        if pool {
            x = maxpool2(&x);
        }
    }
    println!("\nsimulated {blocks} chip blocks in {:?} wall-clock", wall.elapsed());

    // Per-pixel argmax → class histogram (the application output).
    let mut hist = [0usize; 8];
    for y in 0..x.h {
        for xx in 0..x.w {
            let mut best = (i64::MIN, 0usize);
            for c in 0..x.c {
                let v = x.at(c, y, xx);
                if v > best.0 {
                    best = (v, c);
                }
            }
            hist[best.1] += 1;
        }
    }
    println!("\nlabel histogram over {} output pixels:", x.h * x.w);
    for (c, n) in hist.iter().enumerate() {
        println!("  {:<10} {:>6} ({:>5.1}%)", CLASSES[c], n, *n as f64 / (x.h * x.w) as f64 * 100.0);
    }

    // The paper's metrics for this frame at both corners.
    println!("\nchip metrics for this frame (simulated activity):");
    for (label, v) in [("energy-optimal 0.6 V", 0.6), ("throughput-optimal 1.2 V", 1.2)] {
        let m = sim_metrics(&total, ArchId::Bin32Multi, v, false);
        println!(
            "  {label:<26} {:>7.2} GOp/s  {:>6.1} TOp/s/W  {:>8.1} ms/frame ({:.2} FPS)  {:>8.1} uJ",
            m.theta / 1e9,
            m.en_eff / 1e12,
            m.time * 1e3,
            1.0 / m.time,
            m.core_energy * 1e6
        );
    }

    // Cross-check against the analytic model on the full-size network.
    let net = networks::scene_labeling();
    let e = evaluate_network(&net, Corner::energy_optimal());
    println!(
        "\nanalytic model, full 240x320 network @0.6 V: {:.1} GOp/s, {:.1} TOp/s/W, {:.2} FPS",
        e.avg_theta / 1e9,
        e.avg_en_eff / 1e12,
        e.fps
    );
    println!("(paper: state-of-the-art CNNs sustain ~11 FPS at 0.6 V / 895 uW)");
}
