//! AlexNet's 11×11 first layer on a 7×7-max accelerator (paper §IV-D):
//! the kernel is split into 2×(6×6) + 2×(5×5) sub-kernels with one
//! overlapping centre pixel. Choosing the overlap weights as the paper
//! prescribes — both +1 when w_centre = +1, else {+1, −1} — makes the
//! sum of the four sub-convolutions equal the 11×11 convolution **plus
//! the channel-identity sum**, which the host subtracts; no extra 1×1
//! convolution needed.
//!
//! This example builds a random binary 11×11 layer, performs the split,
//! runs the four sub-convolutions, applies the identity correction, and
//! verifies exact equality with the direct 11×11 convolution. It then
//! shows the chip-block schedule the coordinator would issue.
//!
//! ```bash
//! cargo run --release --example alexnet_blocking
//! ```

use yodann::coordinator::{decompose, LayerWorkload};
use yodann::hw::ChipConfig;
use yodann::testkit::Gen;
use yodann::workload::{random_image, BinaryKernels, Image, ScaleBias};

/// Wide-precision valid convolution of `img` with a signed weight matrix
/// placed at offset (oy, ox) inside an 11×11 field, zero-padded SAME.
fn conv_offset(img: &Image, w: &[i64], k: usize, oy: usize, ox: usize, out: &mut [i64]) {
    let half = 5isize; // 11×11 halo
    for y in 0..img.h {
        for x in 0..img.w {
            let mut acc = 0i64;
            for c in 0..img.c {
                for dy in 0..k {
                    for dx in 0..k {
                        let yy = y as isize + (oy + dy) as isize - half;
                        let xx = x as isize + (ox + dx) as isize - half;
                        acc += w[(c * k + dy) * k + dx] * img.at_padded(c, yy, xx);
                    }
                }
            }
            out[y * img.w + x] += acc;
        }
    }
}

fn main() {
    let mut g = Gen::new(0xA1EC);
    let (h, w) = (20usize, 20usize);
    let n_in = 3usize;
    let img = random_image(&mut g, n_in, h, w, 0.02);

    // One random binary 11×11 kernel per input channel.
    let k11: Vec<i64> = (0..n_in * 11 * 11).map(|_| if g.bool() { 1 } else { -1 }).collect();

    // Direct 11×11 convolution (the ground truth).
    let mut direct = vec![0i64; h * w];
    conv_offset(&img, &k11, 11, 0, 0, &mut direct);

    // ---- The paper's split -------------------------------------------------
    // top-left 6×6 at (0,0), bottom-right 6×6 at (5,5) — both contain the
    // centre (5,5); bottom-left 5×5 at (6,0), top-right 5×5 at (0,6).
    let at = |c: usize, dy: usize, dx: usize| k11[(c * 11 + dy) * 11 + dx];
    let sub = |oy: usize, ox: usize, k: usize, centre_override: &dyn Fn(usize) -> Option<i64>| {
        let mut v = vec![0i64; n_in * k * k];
        for c in 0..n_in {
            for dy in 0..k {
                for dx in 0..k {
                    let (gy, gx) = (oy + dy, ox + dx);
                    v[(c * k + dy) * k + dx] = if (gy, gx) == (5, 5) {
                        centre_override(c).unwrap_or_else(|| at(c, gy, gx))
                    } else {
                        at(c, gy, gx)
                    };
                }
            }
        }
        v
    };
    // Overlap rule: w_c = +1 → both 6×6 get +1 (sum 2, identity corrects to 1);
    //               w_c = −1 → one +1, one −1 (sum 0, identity corrects to −1).
    let tl = sub(0, 0, 6, &|c| Some(if at(c, 5, 5) > 0 { 1 } else { 1 }));
    let br = sub(5, 5, 6, &|c| Some(if at(c, 5, 5) > 0 { 1 } else { -1 }));
    let bl = sub(6, 0, 5, &|_| None);
    let tr = sub(0, 6, 5, &|_| None);

    let mut split = vec![0i64; h * w];
    conv_offset(&img, &tl, 6, 0, 0, &mut split);
    conv_offset(&img, &br, 6, 5, 5, &mut split);
    conv_offset(&img, &bl, 5, 6, 0, &mut split);
    conv_offset(&img, &tr, 5, 0, 6, &mut split);

    // Host-side identity correction: subtract Σ_c x_c(centre).
    for y in 0..h {
        for x in 0..w {
            let ident: i64 = (0..n_in).map(|c| img.at(c, y, x)).sum();
            split[y * w + x] -= ident;
        }
    }

    assert_eq!(split, direct, "split convolution must equal the 11x11 original");
    println!("11x11 -> 2x(6x6) + 2x(5x5) split: EXACT over {}x{} outputs", h, w);
    println!(
        "  ops per output pixel: direct 11x11 = {} vs split = {} (+1 identity subtract)",
        n_in * 121 * 2,
        n_in * (36 + 36 + 25 + 25) * 2 + 1
    );

    // ---- The chip-block schedule the coordinator issues --------------------
    println!("\ncoordinator schedule for AlexNet L1 on the 32x32 chip (224x224, 3->96):");
    let cfg = ChipConfig::yodann();
    for (label, k, n_out) in [("6x6 groups (x2)", 6usize, 48usize), ("5x5 groups (x2)", 5, 48)] {
        let mut g2 = Gen::new(9);
        let wl = LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g2, 3, 224, 224, 0.01),
            kernels: BinaryKernels::random(&mut g2, n_out, 3, k),
            scale_bias: ScaleBias::identity(n_out),
        };
        let jobs = decompose(&wl, &cfg);
        let tiles: std::collections::HashSet<_> = jobs.iter().map(|j| j.row_base).collect();
        println!(
            "  {label:<18} k={k}: {} blocks ({} row tiles x {} out-blocks), tile_h <= {}",
            jobs.len(),
            tiles.len(),
            jobs.len() / tiles.len(),
            jobs.iter().map(|j| j.job.image.h).max().unwrap()
        );
    }
    println!("\n(the paper's Table III rows 1ab/1cd follow this exact decomposition)");
}
