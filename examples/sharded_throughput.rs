//! Multi-chip sharded serving: the scene-labeling chain batched through
//! the [`Yodann`](yodann::api::Yodann) serving facade under every
//! [`ShardPolicy`], with the sharded
//! layer executor's per-chip activity rolled into the multi-chip power
//! and throughput models.
//!
//! Demonstrates the three scaling axes this repo now has:
//!
//! * per-frame parallelism (throughput traffic, deep batches),
//! * per-shard parallelism (latency traffic, one frame striped across a
//!   [`ShardGrid`] of chip instances),
//! * the hybrid `Auto` schedule picking between them per batch —
//!
//! all bit-identical, plus the analytic price of the grid: the aggregate
//! power envelope and the Eq. 9 halo rows that stripe borders
//! re-exchange every frame.
//!
//! ```bash
//! cargo run --release --example sharded_throughput
//! ```

use std::time::Instant;

use yodann::api::SessionBuilder;
use yodann::coordinator::{
    metrics::sharded_metrics, run_layer_sharded, ExecOptions, LayerWorkload, SessionLayerSpec,
    ShardGrid, ShardPolicy,
};
use yodann::engine::EngineKind;
use yodann::hw::ChipConfig;
use yodann::model::networks;
use yodann::power::{halo_exchange_words, ArchId, MultiChipPower};
use yodann::testkit::Gen;
use yodann::workload::{synthetic_scene, Image};

fn main() {
    let net = networks::scene_labeling();
    let specs = SessionLayerSpec::synthetic_network(&net, 42).expect("scene-labeling chains");
    let cfg = ChipConfig::yodann();
    let (h, w) = (24, 32); // reduced frames: the schedule, not the load
    let mut g = Gen::new(0x51AB);
    let frames: Vec<Image> = (0..4).map(|_| synthetic_scene(&mut g, 3, h, w)).collect();
    println!(
        "== sharded serving: {} ({} layers) on {}x{} frames, batch of {} ==\n",
        net.name,
        specs.len(),
        h,
        w,
        frames.len()
    );

    // The same batch under every schedule — bit-identical by contract.
    let mut reference: Option<Vec<Image>> = None;
    for policy in [
        ShardPolicy::PerFrame,
        ShardPolicy::PerShard(ShardGrid::striped(2)),
        ShardPolicy::PerShard(ShardGrid::striped(4)),
        ShardPolicy::Auto,
    ] {
        let mut sess = SessionBuilder::new()
            .chip(cfg)
            .layers(specs.clone())
            .engine(EngineKind::Functional)
            .workers(4)
            .shard_policy(policy)
            .max_in_flight(frames.len())
            .build()
            .expect("scene-labeling serves");
        let t0 = Instant::now();
        let out: Vec<Image> = sess
            .run_batch(frames.clone())
            .expect("batch runs")
            .into_iter()
            .map(|r| r.output)
            .collect();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {policy:<18} {dt:>8.3} s  ->  {:>7.2} frames/s",
            frames.len() as f64 / dt
        );
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_eq!(&out, r, "schedules must be bit-identical"),
        }
    }
    println!("  all schedules bit-identical\n");

    // The multi-chip story of one layer: per-shard cycle ledgers from
    // the cycle-accurate engine, rolled up at the energy-optimal corner.
    let l1 = net.conv_layers().next().unwrap();
    let mut g = Gen::new(0x10AD);
    let wl = LayerWorkload {
        k: l1.k,
        zero_pad: true,
        input: synthetic_scene(&mut g, 3, h, w),
        kernels: yodann::workload::BinaryKernels::random(&mut g, 16, 3, l1.k),
        scale_bias: yodann::workload::ScaleBias::random(&mut g, 16),
    };
    println!("layer 1 (k={}) striped across chip grids @0.6 V:", l1.k);
    println!(
        "  {:<6} {:>12} {:>12} {:>12} {:>14} {:>12}",
        "grid", "cycles(max)", "GOp/s", "TOp/s/W", "envelope mW", "halo words"
    );
    let mut base_theta = None;
    for stripes in [1usize, 2, 4] {
        let grid = ShardGrid::striped(stripes);
        let run = run_layer_sharded(
            &wl,
            &cfg,
            ExecOptions::default(),
            EngineKind::CycleAccurate,
            grid,
        );
        let per_shard: Vec<_> = run.per_shard.iter().map(|s| s.stats.clone()).collect();
        let m = sharded_metrics(&per_shard, ArchId::Bin32Multi, 0.6, false);
        let envelope = MultiChipPower::at(ArchId::Bin32Multi, 0.6, grid.chips(), l1.k);
        let halo = halo_exchange_words(stripes, l1.k, w, 3);
        let theta = m.theta / 1e9;
        let scaling = base_theta.map(|b: f64| theta / b).unwrap_or(1.0);
        if base_theta.is_none() {
            base_theta = Some(theta);
        }
        println!(
            "  {grid:<6} {:>12} {theta:>9.2} ({scaling:>4.2}x) {:>9.2} {:>14.1} {halo:>12}",
            m.cycles,
            m.en_eff / 1e12,
            envelope.total_w() * 1e3,
        );
    }
    println!(
        "\n(speedup is sub-linear by the Eq. 9 halo reloads each stripe border pays — \
         the per-shard ledgers price it honestly)"
    );
}
