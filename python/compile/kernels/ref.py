"""Pure-jnp oracle for the Pallas kernel - the CORE correctness signal.

Two references:

* `binary_conv_ref` - exact integer semantics (Q7.9 saturating channel
  accumulation, Q10.18 scale product, truncation) written with plain
  numpy ops and explicit loops: slow, obviously-correct, bit-true.
* `binary_conv_float` - float convolution via `lax.conv_general_dilated`
  used as a sanity cross-check in the non-saturating regime (where the
  integer pipeline is exact linear algebra).
"""

import jax.numpy as jnp
import numpy as np
from jax import lax

from ..quantize import Q29_MAX, Q29_MIN, Q79_MAX, Q79_MIN, Q1018_MAX, Q1018_MIN


def binary_conv_ref(x, w, alpha, beta, *, zero_pad=True):
    """Bit-true reference. Shapes as in `binary_conv_block`; numpy int64
    internally (no overflow anywhere)."""
    x = np.asarray(x, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    alpha = np.asarray(alpha, dtype=np.int64)
    beta = np.asarray(beta, dtype=np.int64)
    n_in, h, width = x.shape
    n_out, _, k, _ = w.shape
    if zero_pad:
        out_h, out_w = h, width
        off = (k - 1) // 2
        xp = np.zeros((n_in, h + k - 1, width + k - 1), dtype=np.int64)
        xp[:, off : off + h, off : off + width] = x
    else:
        out_h, out_w = h - k + 1, width - k + 1
        xp = x
    out = np.zeros((n_out, out_h, out_w), dtype=np.int64)
    for o in range(n_out):
        for y in range(out_h):
            for xx in range(out_w):
                acc = 0
                for i in range(n_in):  # chip channel order
                    sop = int((w[o, i] * xp[i, y : y + k, xx : xx + k]).sum())
                    acc = min(max(acc + sop, Q79_MIN), Q79_MAX)
                v = acc * int(alpha[o]) + (int(beta[o]) << 9)
                v = min(max(v, Q1018_MIN), Q1018_MAX)
                v >>= 9  # arithmetic shift: python ints floor-shift
                out[o, y, xx] = min(max(v, Q29_MIN), Q29_MAX)
    return out.astype(np.int32)


def binary_conv_float(x, w, alpha, beta, *, zero_pad=True):
    """Float reference (no saturation/truncation): valid when magnitudes
    stay inside Q7.9 and the scale product has no fractional truncation
    error beyond 1 LSB. Returns float values in Q2.9 *raw* units."""
    xf = jnp.asarray(x, dtype=jnp.float32)[None]  # NCHW
    wf = jnp.asarray(w, dtype=jnp.float32)  # OIHW
    pad = "SAME" if zero_pad else "VALID"
    conv = lax.conv_general_dilated(
        xf,
        wf,
        window_strides=(1, 1),
        padding=pad,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    alpha_f = jnp.asarray(alpha, dtype=jnp.float32)[:, None, None] / 2.0**9
    beta_f = jnp.asarray(beta, dtype=jnp.float32)[:, None, None]
    return conv * alpha_f + beta_f
