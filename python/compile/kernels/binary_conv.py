"""Layer 1 - the Pallas kernel for YodaNN's compute hot-spot: binary-weight
convolution with fused per-channel scale/bias, bit-true to the ASIC.

Hardware adaptation (DESIGN.md SHardware-Adaptation): the ASIC's SoP array
(49-50 complement-and-mux operators + adder tree per output channel)
becomes an **im2col matmul against +-1 weights** - the MXU-friendly
formulation: the k*k shifted views of the input block form a [k*k, h*w]
operand, the binary filters a [n_out, k*k] operand, and the reduction over
input channels runs as a `fori_loop` with **Q7.9 saturating accumulation
in exactly the chip's input-channel order** (saturation is
order-dependent, so the order is part of bit-exactness).

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute real Mosaic custom-calls; on a real TPU the same BlockSpec
structure tiles the halo'd input into VMEM (see `vmem_footprint_bytes`).

All tensors are **raw-integer** fixed point (int32): f32 would round the
29-bit Q10.18 scale product.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantize import Q29_MAX, Q29_MIN, Q79_MAX, Q79_MIN, Q1018_MAX, Q1018_MIN


def _conv_kernel(x_ref, w_ref, alpha_ref, beta_ref, o_ref, *, k, zero_pad):
    """Pallas kernel body.

    x_ref:     int32 [n_in, h, w]        raw Q2.9 activations
    w_ref:     int32 [n_out, n_in, k, k] weights in {-1, +1}
    alpha_ref: int32 [n_out]             raw Q2.9 per-channel scales
    beta_ref:  int32 [n_out]             raw Q2.9 per-channel biases
    o_ref:     int32 [n_out, out_h, out_w] raw Q2.9 outputs
    """
    x = x_ref[...]
    w = w_ref[...]
    n_in, h, width = x.shape
    n_out = w.shape[0]
    if zero_pad:
        out_h, out_w = h, width
        off = (k - 1) // 2
        x = jnp.pad(x, ((0, 0), (off, k - 1 - off), (off, k - 1 - off)))
    else:
        out_h, out_w = h - k + 1, width - k + 1

    w_flat = w.reshape(n_out, n_in, k * k)

    def per_channel(i, acc):
        xi = jax.lax.dynamic_index_in_dim(x, i, axis=0, keepdims=False)
        # im2col: the k*k shifted views of channel i (static slices).
        views = jnp.stack(
            [
                jax.lax.slice(xi, (dy, dx), (dy + out_h, dx + out_w)).reshape(-1)
                for dy in range(k)
                for dx in range(k)
            ]
        )  # [k*k, out_h*out_w]
        wi = jax.lax.dynamic_index_in_dim(w_flat, i, axis=1, keepdims=False)
        # The MXU-shaped contraction: +-1 weights x Q2.9 pixels.
        contrib = jax.lax.dot(wi, views, preferred_element_type=jnp.int32)
        # ChannelSummer: Q7.9 saturation after EVERY channel (chip order).
        return jnp.clip(acc + contrib, Q79_MIN, Q79_MAX)

    acc0 = jnp.zeros((n_out, out_h * out_w), dtype=jnp.int32)
    acc = jax.lax.fori_loop(0, n_in, per_channel, acc0)

    # Scale-Bias unit: Q7.9 x Q2.9 -> Q10.18, + beta, truncate+saturate.
    alpha = alpha_ref[...].astype(jnp.int32)[:, None]
    beta = beta_ref[...].astype(jnp.int32)[:, None]
    prod = jnp.clip(acc * alpha + (beta << 9), Q1018_MIN, Q1018_MAX)
    out = jnp.clip(prod >> 9, Q29_MIN, Q29_MAX)
    o_ref[...] = out.reshape(n_out, out_h, out_w)


def binary_conv_block(x, w, alpha, beta, *, k=None, zero_pad=True, interpret=True):
    """One YodaNN chip block: binary-weight conv + scale/bias.

    Args mirror `_conv_kernel`; `k` defaults to the kernel size of `w`.
    Returns int32 [n_out, out_h, out_w] raw Q2.9.
    """
    if k is None:
        k = w.shape[-1]
    n_out = w.shape[0]
    n_in, h, width = x.shape
    if zero_pad:
        out_h, out_w = h, width
    else:
        out_h, out_w = h - k + 1, width - k + 1
    kern = functools.partial(_conv_kernel, k=k, zero_pad=zero_pad)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n_out, out_h, out_w), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32), w.astype(jnp.int32), alpha.astype(jnp.int32), beta.astype(jnp.int32))


def vmem_footprint_bytes(n_in, n_out, k, h, w, zero_pad=True):
    """Estimated VMEM bytes a real-TPU lowering of this block needs: the
    halo'd input tile, the expanded +-1 weights (bf16 on the MXU path),
    the int32 accumulators and the output tile. Used by the L1 perf notes
    in EXPERIMENTS.md SPerf; must stay well under ~16 MiB/core."""
    halo = k - 1 if not zero_pad else (k - 1)
    x_bytes = n_in * (h + halo) * (w + halo) * 4
    w_bytes = n_out * n_in * k * k * 2  # +-1 expanded to bf16
    acc_bytes = n_out * h * w * 4
    out_bytes = n_out * h * w * 4
    return x_bytes + w_bytes + acc_bytes + out_bytes


def mxu_utilization_estimate(n_in, n_out, k):
    """Fraction of a 128x128 MXU tile the per-channel contraction fills:
    the [n_out, k*k] x [k*k, hw] matmul has a k*k-deep reduction, so the
    systolic array's depth utilization is k*k/128 per pass and its width
    utilization min(n_out,128)/128."""
    depth = min(k * k, 128) / 128.0
    width = min(n_out, 128) / 128.0
    del n_in
    return depth * width
