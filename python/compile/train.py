"""BinaryConnect training (paper SII-A / [22]) - the algorithm that
produces YodaNN's weights.

Full-precision *shadow* weights are kept for SGD; the forward (and
backward) pass sees binarized {-1,+1} weights, with the straight-through
estimator passing gradients to the shadow copy, which is clipped to
[-1, 1] after every update (the clipping is what makes the hard-sigmoid
stochastic binarization meaningful).

This module trains a small conv classifier on a synthetic two-class
"blob vs stripes" dataset, then exports chip-ready tensors:
binary weight planes (Eq. 5 bit encoding), per-channel scales
(batch-norm folding, SII-A: scaling by the mean absolute weight as in
the BWN approach [23]) and raw-Q2.9 biases - exactly the operands the
Rust coordinator feeds the simulated chip.

Run: ``python -m compile.train`` (from python/), or via the pytest in
tests/test_train.py.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .quantize import binarize_det, q29_from_float


def synthetic_dataset(key, n, hw=12):
    """Two classes: Gaussian blob (0) vs diagonal stripes (1), 1 channel."""
    k1, k2, k3 = jax.random.split(key, 3)
    half = n // 2
    yy, xx = jnp.mgrid[0:hw, 0:hw]
    # Blobs at random centres.
    cy = jax.random.uniform(k1, (half, 1, 1), minval=3, maxval=hw - 3)
    cx = jax.random.uniform(k2, (half, 1, 1), minval=3, maxval=hw - 3)
    blobs = jnp.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / 8.0)
    # Stripes at random phase.
    phase = jax.random.uniform(k3, (half, 1, 1), minval=0, maxval=6)
    stripes = 0.5 + 0.5 * jnp.sin((yy + xx) / 2.0 + phase)
    x = jnp.concatenate([blobs, stripes])[:, None]  # [n, 1, hw, hw]
    y = jnp.concatenate([jnp.zeros(half, jnp.int32), jnp.ones(half, jnp.int32)])
    noise = jax.random.normal(jax.random.fold_in(key, 7), x.shape) * 0.05
    return x + noise, y


def init_params(key, c_hidden=8, k=3, n_classes=2):
    k1, k2 = jax.random.split(key)
    scale = 0.3
    return {
        "w1": jax.random.uniform(k1, (c_hidden, 1, k, k), minval=-scale, maxval=scale),
        "b1": jnp.zeros((c_hidden,)),
        "w2": jax.random.uniform(k2, (n_classes, c_hidden, k, k), minval=-scale, maxval=scale),
        "b2": jnp.zeros((n_classes,)),
    }


def _binarize_ste(w):
    """Deterministic binarization with the straight-through estimator:
    forward sees sign(w), gradient flows as identity."""
    wb = jnp.where(w >= 0, 1.0, -1.0)
    return w + jax.lax.stop_gradient(wb - w)


def forward(params, x):
    """BinaryConnect forward: conv(sign(w)) with BWN per-channel scaling
    alpha = mean|w| [23], ReLU, global-avg-pool classifier head."""

    def conv(x, w, b):
        wb = _binarize_ste(w)
        alpha = jnp.mean(jnp.abs(w), axis=(1, 2, 3))  # BWN channel scale
        out = jax.lax.conv_general_dilated(
            x, wb, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
        )
        return out * alpha[None, :, None, None] + b[None, :, None, None]

    h = jax.nn.relu(conv(x, params["w1"], params["b1"]))
    h = conv(h, params["w2"], params["b2"])
    return jnp.mean(h, axis=(2, 3))  # [n, classes]


def loss_fn(params, x, y):
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@jax.jit
def train_step(params, x, y, lr):
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new = {}
    for name, p in params.items():
        g = grads[name]
        p = p - lr * g
        if name.startswith("w"):
            # BinaryConnect: clip the full-precision shadow weights.
            p = jnp.clip(p, -1.0, 1.0)
        new[name] = p
    return new, loss


def train(seed=0, steps=300, n=128, lr=0.2):
    """Train; returns (params, losses, accuracy)."""
    key = jax.random.PRNGKey(seed)
    x, y = synthetic_dataset(key, n)
    params = init_params(jax.random.fold_in(key, 1))
    losses = []
    for _ in range(steps):
        params, loss = train_step(params, x, y, lr)
        losses.append(float(loss))
    acc = float(jnp.mean(jnp.argmax(forward(params, x), axis=1) == y))
    return params, losses, acc


def export_chip_operands(params):
    """Convert trained parameters to chip operands: Eq. 5 weight bits,
    raw-Q2.9 alpha (BWN scale) and beta per layer."""
    out = []
    for wi, bi in (("w1", "b1"), ("w2", "b2")):
        w = np.asarray(params[wi])
        bits = np.asarray(binarize_det(w)) > 0  # Eq. 5: +1 -> bit 1
        alpha = q29_from_float(np.abs(w).mean(axis=(1, 2, 3)))
        beta = q29_from_float(np.asarray(params[bi]))
        out.append({"bits": bits, "alpha": alpha, "beta": beta})
    return out


def main():
    params, losses, acc = train()
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, train accuracy {acc:.2%}")
    ops = export_chip_operands(params)
    for i, layer in enumerate(ops):
        print(
            f"layer {i+1}: {layer['bits'].size} binary weights "
            f"({layer['bits'].size // 8} bytes), alpha[0]={layer['alpha'][0]} (raw Q2.9)"
        )


if __name__ == "__main__":
    main()
