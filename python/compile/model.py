"""Layer 2 - the JAX compute graph: YodaNN chip blocks composed into
binary-weight CNN forward passes, built on the L1 Pallas kernel.

Two exports matter to the AOT path (`aot.py`):

* `make_block_fn` - the *exact* computation one YodaNN chip block performs
  (binary conv + per-channel scale/bias on raw Q2.9 integers). The Rust
  coordinator loads its lowered HLO as the golden model and checks the
  cycle simulator's streamed outputs against it.
* `make_smallnet_fn` - a small scene-labeling-style CNN (3 conv blocks
  with quantized ReLU + 2x2 max-pool) used by the end-to-end example.

Python never runs at serving time: these functions exist to be lowered
once by `aot.py` into `artifacts/*.hlo.txt`.
"""

import jax
import jax.numpy as jnp

from .kernels.binary_conv import binary_conv_block
from .quantize import relu_q29


def make_block_fn(*, k, zero_pad=True):
    """The chip-block function with static kernel size; shapes are fixed
    at lowering time by the example arguments."""

    def block(x, w, alpha, beta):
        return (binary_conv_block(x, w, alpha, beta, k=k, zero_pad=zero_pad),)

    return block


def maxpool2x2_q(x):
    """2x2 max-pool on raw Q2.9 int32 [c, h, w] (h, w even)."""
    c, h, w = x.shape
    x = x.reshape(c, h // 2, 2, w // 2, 2)
    return jnp.max(jnp.max(x, axis=4), axis=2)


def make_smallnet_fn(layers):
    """A forward pass over `layers`, each a dict with keys
    ``k, zero_pad, pool`` - weights/scales/biases are passed as a flat
    argument list (w0, a0, b0, w1, a1, b1, ...) so the lowered HLO has a
    stable signature.

    ReLU runs after every block except the last; `pool` applies a 2x2
    max-pool. All arithmetic stays in raw Q2.9 int32.
    """

    def net(x, *params):
        assert len(params) == 3 * len(layers)
        for li, spec in enumerate(layers):
            w, alpha, beta = params[3 * li : 3 * li + 3]
            x = binary_conv_block(x, w, alpha, beta, k=spec["k"], zero_pad=spec["zero_pad"])
            if li + 1 < len(layers):
                x = relu_q29(x)
            if spec.get("pool"):
                x = maxpool2x2_q(x)
        return (x,)

    return net


def block_example_args(n_in, n_out, k, h, w):
    """ShapeDtypeStructs for lowering a block function."""
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((n_in, h, w), i32),
        jax.ShapeDtypeStruct((n_out, n_in, k, k), i32),
        jax.ShapeDtypeStruct((n_out,), i32),
        jax.ShapeDtypeStruct((n_out,), i32),
    )
