"""Build-time python: L1 Pallas kernels, L2 JAX model, AOT lowering.

Never imported at runtime - `make artifacts` runs `compile.aot` once and
the Rust binary is self-contained afterwards.
"""
