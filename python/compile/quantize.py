"""Fixed-point helpers mirroring the Rust `fixedpoint` module bit-for-bit.

All values are carried as **raw two's-complement integers** (int32/int64
jnp arrays); a raw value ``r`` in Qi.f represents ``r / 2**f``. The YodaNN
formats (paper SIII-E):

* Q2.9  - 12-bit activations / scales / biases,
* Q7.9  - 17-bit ChannelSummer accumulators (saturating),
* Q10.18 - 29-bit scale product, truncated+saturated back to Q2.9.

Truncation = arithmetic shift right (floor); saturation = clamp to the
representable range - exactly the hardware semantics, so results compare
``==`` against the Rust simulator.
"""

import jax.numpy as jnp
import numpy as np

# Q2.9
Q29_FRAC = 9
Q29_MAX = 2**11 - 1  # 2047
Q29_MIN = -(2**11)  # -2048
# Q7.9
Q79_MAX = 2**16 - 1  # 65535
Q79_MIN = -(2**16)  # -65536
# Q10.18
Q1018_MAX = 2**28 - 1
Q1018_MIN = -(2**28)


def q29_from_float(x):
    """Round-to-nearest-even quantization of real values to raw Q2.9."""
    scaled = np.asarray(x, dtype=np.float64) * 2.0**Q29_FRAC
    # numpy rounds half-to-even, matching the Rust `round_ties_even`.
    return np.clip(np.rint(scaled), Q29_MIN, Q29_MAX).astype(np.int32)


def q29_to_float(raw):
    """Real value of raw Q2.9."""
    return np.asarray(raw, dtype=np.float64) / 2.0**Q29_FRAC


def sat_q79(x):
    """Saturate raw values to the Q7.9 accumulator range (jnp)."""
    return jnp.clip(x, Q79_MIN, Q79_MAX)


def scale_bias_q(acc_q79, alpha_q29, beta_q29):
    """The Scale-Bias datapath: Q7.9 x Q2.9 -> Q10.18, + beta, truncate &
    saturate to Q2.9. `alpha`/`beta` broadcast over the trailing axes of
    `acc` (jnp int32 arithmetic; products stay under 2**28)."""
    prod = acc_q79.astype(jnp.int32) * alpha_q29.astype(jnp.int32)  # Q10.18
    summed = jnp.clip(prod + (beta_q29.astype(jnp.int32) << 9), Q1018_MIN, Q1018_MAX)
    # Arithmetic shift right truncates toward -inf (two's complement).
    out = summed >> 9  # Q10.18 -> Q1.. align to 9 fractional bits
    return jnp.clip(out, Q29_MIN, Q29_MAX)


def binarize_det(w_fp):
    """Deterministic BinaryConnect binarization: sign(w) in {-1,+1},
    with w >= 0 -> +1 (paper SII-A; the printed case split is a typo)."""
    return jnp.where(jnp.asarray(w_fp) >= 0, 1, -1).astype(jnp.int32)


def binarize_sto(w_fp, u):
    """Stochastic binarization with the hard sigmoid
    sigma(x) = clip((x+1)/2, 0, 1); `u` uniform in [0,1)."""
    sigma = jnp.clip((jnp.asarray(w_fp) + 1.0) / 2.0, 0.0, 1.0)
    return jnp.where(jnp.asarray(u) < sigma, 1, -1).astype(jnp.int32)


def relu_q29(x_q29):
    """Quantized ReLU on raw Q2.9 (max with 0 is exact in raw space)."""
    return jnp.maximum(x_q29, 0)
