"""AOT lowering: jax -> HLO **text** -> artifacts/*.hlo.txt.

HLO text (NOT ``lowered.compiler_ir("hlo").serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the `xla` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (consumed by `rust/src/runtime/`):

* ``block_k{K}_c{NIN}x{NOUT}_{H}x{W}.hlo.txt`` - golden chip blocks for
  k in {1,3,5,7}, used by the coordinator's golden checks.
* ``smallnet.hlo.txt`` - 3-layer scene-labeling-style CNN for the
  end-to-end example.
* ``manifest.txt`` - ``name k nin nout h w zero_pad`` per line (plain
  text; the Rust side has no JSON dependency).

Run once via ``make artifacts``; Python is never on the request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import block_example_args, make_block_fn, make_smallnet_fn


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Golden block configurations: one per native slot mode + the 1x1 edge
# case. Shapes chosen small enough for fast CI but large enough to
# exercise channel blocking (n_in = n_ch = 32, dual-mode n_out = 64).
BLOCKS = [
    # (k, n_in, n_out, h, w, zero_pad)
    (1, 32, 64, 16, 16, True),
    (3, 32, 64, 16, 16, True),
    (5, 32, 64, 12, 12, True),
    (7, 32, 32, 12, 12, True),
    (7, 32, 32, 12, 12, False),
]

# The end-to-end small network (scene-labeling shape: 3 RGB -> 8 classes).
SMALLNET_LAYERS = [
    dict(k=7, zero_pad=True, pool=True, n_out=16),
    dict(k=7, zero_pad=True, pool=True, n_out=32),
    dict(k=3, zero_pad=True, pool=False, n_out=8),
]
SMALLNET_IN = (3, 24, 32)  # c, h, w


def block_name(k, n_in, n_out, h, w, zero_pad):
    pad = "" if zero_pad else "_valid"
    return f"block_k{k}_c{n_in}x{n_out}_{h}x{w}{pad}"


def lower_blocks(outdir):
    entries = []
    for k, n_in, n_out, h, w, zero_pad in BLOCKS:
        fn = make_block_fn(k=k, zero_pad=zero_pad)
        lowered = jax.jit(fn).lower(*block_example_args(n_in, n_out, k, h, w))
        name = block_name(k, n_in, n_out, h, w, zero_pad)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        entries.append((name, k, n_in, n_out, h, w, int(zero_pad)))
        print(f"wrote {path}")
    return entries


def lower_smallnet(outdir):
    import jax.numpy as jnp

    fn = make_smallnet_fn(SMALLNET_LAYERS)
    c, h, w = SMALLNET_IN
    args = [jax.ShapeDtypeStruct((c, h, w), jnp.int32)]
    n_in = c
    hh, ww = h, w
    for spec in SMALLNET_LAYERS:
        n_out, k = spec["n_out"], spec["k"]
        args.append(jax.ShapeDtypeStruct((n_out, n_in, k, k), jnp.int32))
        args.append(jax.ShapeDtypeStruct((n_out,), jnp.int32))
        args.append(jax.ShapeDtypeStruct((n_out,), jnp.int32))
        n_in = n_out
        if spec["pool"]:
            hh, ww = hh // 2, ww // 2
    lowered = jax.jit(fn).lower(*args)
    path = os.path.join(outdir, "smallnet.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path} (output {SMALLNET_LAYERS[-1]['n_out']}x{hh}x{ww})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="primary artifact path (its directory receives all artifacts)")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    entries = lower_blocks(outdir)
    lower_smallnet(outdir)

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        for name, k, n_in, n_out, h, w, zp in entries:
            f.write(f"{name} {k} {n_in} {n_out} {h} {w} {zp}\n")

    # The Makefile's primary target: alias of the k7 block.
    import shutil

    k7 = block_name(7, 32, 32, 12, 12, True)
    shutil.copyfile(os.path.join(outdir, f"{k7}.hlo.txt"), os.path.abspath(args.out))
    print(f"wrote {args.out} (alias of {k7})")


if __name__ == "__main__":
    main()
