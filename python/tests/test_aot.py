"""AOT lowering: block functions must lower to parseable HLO text with
the expected parameter signature (int32 everywhere)."""

import jax

from compile.aot import BLOCKS, block_name, to_hlo_text
from compile.model import block_example_args, make_block_fn


def test_block_lowering_produces_hlo_text():
    k, n_in, n_out, h, w, zp = BLOCKS[1]  # the k3 dual-mode block
    fn = make_block_fn(k=k, zero_pad=zp)
    lowered = jax.jit(fn).lower(*block_example_args(n_in, n_out, k, h, w))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32" in text  # integer datapath end-to-end
    assert f"s32[{n_out},{h},{w}]" in text.replace(" ", "")


def test_block_names_are_unique():
    names = [block_name(*b) for b in BLOCKS]
    assert len(names) == len(set(names))


def test_all_blocks_lower():
    for k, n_in, n_out, h, w, zp in BLOCKS:
        fn = make_block_fn(k=k, zero_pad=zp)
        lowered = jax.jit(fn).lower(*block_example_args(n_in, n_out, k, h, w))
        assert "HloModule" in to_hlo_text(lowered)[:200]
