"""Quantization helpers vs the Rust fixedpoint semantics."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantize as qz


def test_q29_range():
    assert qz.q29_from_float(100.0) == 2047
    assert qz.q29_from_float(-100.0) == -2048
    assert qz.q29_from_float(1.0) == 512
    assert qz.q29_from_float(-1.0) == -512


def test_round_ties_even():
    # 1.5 LSB and 2.5 LSB both round to 2 (ties-to-even), matching Rust.
    assert qz.q29_from_float(1.5 / 512.0) == 2
    assert qz.q29_from_float(2.5 / 512.0) == 2


@settings(max_examples=50, deadline=None)
@given(st.floats(-4.2, 4.2, allow_nan=False))
def test_roundtrip_error_half_lsb(x):
    raw = qz.q29_from_float(x)
    back = qz.q29_to_float(raw)
    if -4.0 <= x <= 2047 / 512:
        assert abs(back - x) <= 0.5 / 512 + 1e-12


def test_scale_bias_identity():
    import jax.numpy as jnp

    acc = jnp.array([700, -1024, 0, 2047], dtype=jnp.int32)
    out = qz.scale_bias_q(acc, jnp.int32(512), jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(out), [700, -1024, 0, 2047])


def test_scale_bias_saturates():
    import jax.numpy as jnp

    out = qz.scale_bias_q(jnp.int32(40000), jnp.int32(512), jnp.int32(0))
    assert int(out) == 2047
    out = qz.scale_bias_q(jnp.int32(-40000), jnp.int32(512), jnp.int32(0))
    assert int(out) == -2048


def test_binarize_det_sign_convention():
    import numpy as np

    w = np.array([-0.5, -1e-9, 0.0, 0.7])
    out = np.asarray(qz.binarize_det(w))
    np.testing.assert_array_equal(out, [-1, -1, 1, 1])


@settings(max_examples=20, deadline=None)
@given(st.floats(-1, 1), st.floats(0, 0.999))
def test_binarize_sto_hard_sigmoid(w, u):
    out = int(qz.binarize_sto(w, u))
    sigma = min(max((w + 1) / 2, 0.0), 1.0)
    assert out == (1 if u < sigma else -1)


def test_relu_q29():
    import jax.numpy as jnp

    x = jnp.array([-5, 0, 7], dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(qz.relu_q29(x)), [0, 0, 7])
