"""BinaryConnect training: loss decreases, weights stay clipped, and the
exported operands are chip-ready (bit-planes + raw Q2.9 scales) and
consistent with the bit-true kernel."""

import numpy as np

from compile.kernels.binary_conv import binary_conv_block
from compile.train import export_chip_operands, forward, synthetic_dataset, train


def test_training_learns():
    params, losses, acc = train(seed=0, steps=300, n=96)
    assert losses[-1] < 0.3 * losses[0], f"{losses[0]} -> {losses[-1]}"
    assert acc > 0.85, f"accuracy {acc}"
    # Shadow weights stay in the BinaryConnect clip range.
    for name in ("w1", "w2"):
        w = np.asarray(params[name])
        assert np.all(w >= -1.0) and np.all(w <= 1.0)


def test_export_is_chip_ready():
    params, _, _ = train(seed=1, steps=60, n=64)
    ops = export_chip_operands(params)
    assert len(ops) == 2
    for layer in ops:
        assert layer["bits"].dtype == np.bool_
        assert layer["alpha"].dtype == np.int32
        assert np.all(np.abs(layer["alpha"]) <= 2047)
        assert np.all(np.abs(layer["beta"]) <= 2048)
    # Exported alpha follows the BWN rule: mean |w| per output channel.
    w1 = np.asarray(params["w1"])
    expect = np.clip(np.rint(np.abs(w1).mean(axis=(1, 2, 3)) * 512), -2048, 2047)
    np.testing.assert_array_equal(ops[0]["alpha"], expect.astype(np.int32))


def test_exported_weights_run_on_the_quantized_kernel():
    # The float training forward and the chip's integer pipeline must
    # agree on layer-1 activations up to quantization error.
    import jax.numpy as jnp

    params, _, _ = train(seed=2, steps=60, n=64)
    ops = export_chip_operands(params)
    x, _ = synthetic_dataset(__import__("jax").random.PRNGKey(3), 4, hw=10)
    x0 = np.asarray(x[0])  # [1, 10, 10]

    from compile.quantize import q29_from_float, q29_to_float

    xq = q29_from_float(x0)
    w = np.where(ops[0]["bits"], 1, -1).astype(np.int32)
    out_q = np.asarray(
        binary_conv_block(xq, w, ops[0]["alpha"], ops[0]["beta"], k=3)
    )
    # Float reference of the same computation.
    got = q29_to_float(out_q)
    wf = np.asarray(params["w1"])
    alpha = np.abs(wf).mean(axis=(1, 2, 3))
    import jax

    conv = jax.lax.conv_general_dilated(
        jnp.asarray(x0)[None],
        jnp.where(jnp.asarray(wf) >= 0, 1.0, -1.0),
        (1, 1),
        "SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    want = np.asarray(conv) * alpha[:, None, None] + np.asarray(params["b1"])[:, None, None]
    # Quantization of inputs/scales/outputs: allow a few LSB.
    err = np.max(np.abs(got - np.clip(want, -4, 2047 / 512)))
    assert err < 0.05, f"max err {err}"
