"""Pallas kernel vs pure reference - the core L1 correctness signal.

Hypothesis sweeps shapes, kernel sizes and value regimes (including the
Q7.9 saturating regime, where accumulation order matters) and asserts
bit-exact equality against the integer oracle, plus closeness to the
float reference in the non-saturating regime.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.binary_conv import (
    binary_conv_block,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import binary_conv_float, binary_conv_ref


def rand_case(rng, k, n_in, n_out, h, w, amp):
    x = rng.integers(-amp, amp + 1, size=(n_in, h, w), dtype=np.int32)
    wts = rng.choice(np.array([-1, 1], dtype=np.int32), size=(n_out, n_in, k, k))
    alpha = rng.integers(-512, 513, size=(n_out,), dtype=np.int32)
    beta = rng.integers(-256, 257, size=(n_out,), dtype=np.int32)
    return x, wts, alpha, beta


@settings(max_examples=25, deadline=None)
@given(
    k=st.sampled_from([1, 2, 3, 4, 5, 6, 7]),
    n_in=st.integers(1, 6),
    n_out=st.integers(1, 6),
    h=st.integers(7, 12),
    w=st.integers(7, 12),
    zero_pad=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_integer_oracle(k, n_in, n_out, h, w, zero_pad, seed):
    rng = np.random.default_rng(seed)
    x, wts, alpha, beta = rand_case(rng, k, n_in, n_out, h, w, amp=60)
    got = np.asarray(binary_conv_block(x, wts, alpha, beta, k=k, zero_pad=zero_pad))
    want = binary_conv_ref(x, wts, alpha, beta, zero_pad=zero_pad)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_oracle_in_saturating_regime(seed):
    # Large activations over many channels force Q7.9 saturation: the
    # kernel must saturate in the same channel order as the chip.
    rng = np.random.default_rng(seed)
    x, wts, alpha, beta = rand_case(rng, 3, 8, 3, 8, 8, amp=2000)
    got = np.asarray(binary_conv_block(x, wts, alpha, beta, k=3))
    want = binary_conv_ref(x, wts, alpha, beta)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(
    k=st.sampled_from([1, 3, 5, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_close_to_float_reference_when_linear(k, seed):
    # Small magnitudes: no saturation; the only nonlinearity is the final
    # >>9 truncation, bounded by 1 LSB.
    rng = np.random.default_rng(seed)
    x, wts, alpha, beta = rand_case(rng, k, 3, 4, 9, 9, amp=20)
    got = np.asarray(binary_conv_block(x, wts, alpha, beta, k=k), dtype=np.float64)
    want = np.asarray(binary_conv_float(x, wts, alpha, beta), dtype=np.float64)
    assert np.max(np.abs(got - want)) <= 1.0 + 1e-6


def test_identity_block():
    # +1 weights on a single pixel with alpha=1: window sum passthrough.
    x = np.zeros((1, 5, 5), dtype=np.int32)
    x[0, 2, 2] = 700
    w = np.ones((1, 1, 3, 3), dtype=np.int32)
    alpha = np.array([512], dtype=np.int32)
    beta = np.array([0], dtype=np.int32)
    out = np.asarray(binary_conv_block(x, w, alpha, beta, k=3))
    # Every window containing the pixel sums to 700.
    assert out[0, 2, 2] == 700
    assert out[0, 0, 0] == 0
    assert out[0, 1, 1] == 700


def test_bias_only():
    x = np.zeros((2, 4, 4), dtype=np.int32)
    w = np.ones((3, 2, 1, 1), dtype=np.int32)
    alpha = np.zeros((3,), dtype=np.int32)
    beta = np.array([-100, 0, 100], dtype=np.int32)
    out = np.asarray(binary_conv_block(x, w, alpha, beta, k=1))
    assert (out[0] == -100).all() and (out[1] == 0).all() and (out[2] == 100).all()


def test_truncation_floors_negative():
    # acc = -3 raw (tiny negative), alpha = 1.0: -3*512 >> 9 ... exact;
    # alpha = 0.5 (256): -3*256 = -768 >> 9 = -2 (floor of -1.5).
    x = np.full((1, 1, 1), -3, dtype=np.int32)
    w = np.ones((1, 1, 1, 1), dtype=np.int32)
    out = np.asarray(
        binary_conv_block(x, w, np.array([256], np.int32), np.array([0], np.int32), k=1)
    )
    assert out[0, 0, 0] == -2


def test_vmem_footprint_is_small_for_chip_blocks():
    # The largest golden block must sit far below a TPU core's ~16 MiB.
    assert vmem_footprint_bytes(32, 64, 3, 16, 16) < 2 * 2**20
    assert 0.0 < mxu_utilization_estimate(32, 64, 3) <= 1.0


@pytest.mark.parametrize("k", [1, 2, 3, 4, 5, 6, 7])
def test_all_kernel_sizes_smoke(k):
    rng = np.random.default_rng(k)
    x, wts, alpha, beta = rand_case(rng, k, 2, 2, 8, 8, amp=50)
    got = np.asarray(binary_conv_block(x, wts, alpha, beta, k=k))
    want = binary_conv_ref(x, wts, alpha, beta)
    np.testing.assert_array_equal(got, want)
