"""L2 model: block/network shapes and semantics."""

import numpy as np

from compile.model import (
    block_example_args,
    make_block_fn,
    make_smallnet_fn,
    maxpool2x2_q,
)


def test_block_fn_shapes():
    import jax

    fn = make_block_fn(k=3)
    args = block_example_args(4, 6, 3, 8, 8)
    out = jax.eval_shape(fn, *args)
    assert out[0].shape == (6, 8, 8)
    assert str(out[0].dtype) == "int32"


def test_block_fn_valid_padding_shrinks():
    import jax

    fn = make_block_fn(k=5, zero_pad=False)
    args = block_example_args(2, 3, 5, 10, 9)
    out = jax.eval_shape(fn, *args)
    assert out[0].shape == (3, 6, 5)


def test_maxpool():
    x = np.arange(16, dtype=np.int32).reshape(1, 4, 4)
    out = np.asarray(maxpool2x2_q(x))
    np.testing.assert_array_equal(out[0], [[5, 7], [13, 15]])


def test_smallnet_forward_shapes_and_relu():
    layers = [
        dict(k=3, zero_pad=True, pool=True, n_out=4),
        dict(k=3, zero_pad=True, pool=False, n_out=2),
    ]
    net = make_smallnet_fn(layers)
    rng = np.random.default_rng(0)
    x = rng.integers(-50, 50, size=(3, 8, 8), dtype=np.int32)
    params = []
    n_in = 3
    for spec in layers:
        params.append(
            rng.choice(np.array([-1, 1], np.int32), size=(spec["n_out"], n_in, 3, 3))
        )
        params.append(np.full((spec["n_out"],), 512, np.int32))
        params.append(np.zeros((spec["n_out"],), np.int32))
        n_in = spec["n_out"]
    (out,) = net(x, *params)
    assert out.shape == (2, 4, 4)
    # Intermediate ReLU means layer-2 inputs were non-negative; run layer 1
    # alone to confirm the clamp happened (spot property).
    from compile.kernels.binary_conv import binary_conv_block
    from compile.quantize import relu_q29

    l1 = relu_q29(binary_conv_block(x, params[0], params[1], params[2], k=3))
    assert int(np.asarray(l1).min()) >= 0
