//! The serving-grade inference API: one facade over the whole execution
//! stack.
//!
//! The pre-redesign surface grew bottom-up: `NetworkSession::new` /
//! `with_policy` / `set_policy` spread configuration over three calls,
//! `run_frame(Image) -> Image` blocked the caller and discarded the
//! per-frame activity ledger, and malformed geometry panicked somewhere
//! inside the planner. A request-queue serving system needs the
//! opposite: one validated configuration object, non-blocking
//! submission with backpressure, and observability on every response.
//! That is this module:
//!
//! * [`SessionBuilder`] — every knob (network, explicit layers, or a
//!   [`NetworkGraph`] via [`SessionBuilder::graph`] — the IR that runs
//!   AlexNet's 11×11 split and ResNet's residual shortcuts — plus
//!   engine kind, worker count, shard policy, operating corner,
//!   in-flight bound, caller-supplied [`Weights`]) in one place,
//!   validated **eagerly** at [`SessionBuilder::build`]
//!   into typed [`YodannError`]s;
//! * [`Yodann`] — the session facade: [`Yodann::submit`] enqueues a
//!   frame and returns a [`FrameTicket`] immediately (or
//!   [`YodannError::Backpressure`] when the bounded in-flight queue is
//!   full); [`FrameTicket::poll`]/[`FrameTicket::wait`] retrieve the
//!   [`FrameResult`];
//! * [`FrameTelemetry`] — cycles, energy, Θ and the multi-chip power
//!   envelope ride on every result, priced at the session's corner
//!   through the same roll-ups as the paper's tables.
//!
//! The engine behind the facade is the unchanged
//! [`NetworkSession`] worker pool — outputs are **bit-identical** to the
//! deprecated `run_batch` path for every engine kind and shard policy
//! (`rust/tests/conformance.rs` proves it differentially).
//!
//! Serving is **supervised**: a frame that panics a worker, trips an
//! injected fault ([`SessionBuilder::fault_plan`]) or loses its worker
//! thread fails *alone* — its ticket redeems the typed error
//! ([`YodannError::WorkerPanicked`], [`YodannError::FaultDetected`])
//! while the pool respawns and the session keeps admitting frames; and
//! [`FrameTicket::wait_timeout`] turns a missed frame deadline into
//! [`YodannError::DeadlineExceeded`] without forfeiting the result.

// The serving surface must never take down the caller: unwinding is
// reserved for the worker pool (where it is caught and typed), so the
// api modules ban unwrap/expect outright in non-test code.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

mod error;
mod ticket;

pub use error::YodannError;
pub use ticket::{FrameResult, FrameTelemetry, FrameTicket};

pub use crate::analysis::{AnalysisOptions, AnalysisReport, Preflight};

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::analysis::{self, Severity};
use crate::coordinator::blocks::plan_geometry_check;
use crate::coordinator::metrics::sim_metrics;
use crate::coordinator::session::{chain_compiled, panic_message, TracedFrame};
use crate::coordinator::{NetworkSession, SessionLayerSpec, ShardPolicy};
use crate::engine::EngineKind;
use crate::fault::FaultPlan;
use crate::hw::ChipConfig;
use crate::model::graph::{CompiledGraph, NetworkGraph, Precision, Weights};
use crate::model::{Corner, Network};
use crate::power::{calib, MultiChipPower};
use crate::workload::Image;
use ticket::SlotGuard;

/// One queued frame on its way to the dispatcher.
struct Job {
    id: u64,
    frame: Image,
    reply: Sender<Result<FrameResult, YodannError>>,
}

/// The corner-dependent half of frame pricing, shared between the
/// facade (which can swap the corner at runtime, [`Yodann::set_corner`])
/// and the dispatcher (which prices each finished frame). The session's
/// compute plan is corner-agnostic — only this state changes on a DVFS
/// step, which is why re-pricing never rebuilds the session.
#[derive(Debug)]
struct Pricing {
    corner: Corner,
    envelope: MultiChipPower,
    /// The kernel size the envelope is priced at — the most
    /// power-hungry mode across the network's conv layers (held fixed
    /// across corner swaps; the mode ratios are voltage-independent).
    envelope_k: usize,
    /// Concurrent chips the envelope prices (fixed by the shard policy).
    chips: usize,
}

/// Lock the shared pricing state, recovering from poisoning — pricing
/// is plain-old-data, so a panic mid-update cannot leave it torn.
fn lock_pricing(p: &Mutex<Pricing>) -> std::sync::MutexGuard<'_, Pricing> {
    p.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Everything the dispatcher needs to price a finished frame.
struct TelemetryCtx {
    engine: EngineKind,
    policy: ShardPolicy,
    dual_stream: bool,
    pricing: Arc<Mutex<Pricing>>,
}

impl TelemetryCtx {
    fn frame_result(&self, id: u64, traced: TracedFrame, host_seconds: f64) -> FrameResult {
        // Frames are priced at the corner in force when they complete —
        // a runtime corner swap re-prices everything after it.
        let (corner, envelope) = {
            let p = lock_pricing(&self.pricing);
            (p.corner, p.envelope)
        };
        let cycles = traced.stats.cycles.total();
        let ops = traced.stats.useful_ops;
        let metrics =
            (cycles > 0).then(|| sim_metrics(&traced.stats, corner.arch, corner.v, self.dual_stream));
        FrameResult {
            frame_id: id,
            output: traced.output,
            telemetry: FrameTelemetry {
                frame_id: id,
                engine: self.engine,
                policy: self.policy,
                corner,
                stats: traced.stats,
                ops,
                cycles,
                host_seconds,
                metrics,
                envelope,
                fault: traced.fault,
            },
        }
    }
}

/// Builder for a [`Yodann`] serving session: one place for every knob,
/// validated eagerly — [`SessionBuilder::build`] returns a typed
/// [`YodannError`] instead of panicking later inside the planner.
///
/// Defaults: the taped-out chip ([`ChipConfig::yodann`]), the functional
/// popcount engine, one worker per host core, the [`ShardPolicy::Auto`]
/// schedule, the paper's energy-optimal corner (0.6 V), and an in-flight
/// bound of `2 × workers`.
///
/// ```
/// use yodann::api::{SessionBuilder, YodannError};
///
/// // Validation is eager and typed: no layers, no session.
/// let err = SessionBuilder::new().build().unwrap_err();
/// assert!(matches!(err, YodannError::NoLayers));
/// ```
#[derive(Clone)]
pub struct SessionBuilder {
    cfg: ChipConfig,
    engine: EngineKind,
    workers: usize,
    policy: ShardPolicy,
    corner: Corner,
    dual_stream: Option<bool>,
    max_in_flight: Option<usize>,
    specs: Vec<SessionLayerSpec>,
    graph: Option<CompiledGraph>,
    weights: Option<Vec<Weights>>,
    precision: Option<Vec<Precision>>,
    fault: Option<FaultPlan>,
    preflight: Preflight,
    deferred_err: Option<YodannError>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

impl SessionBuilder {
    /// A builder with the defaults described on the type.
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            cfg: ChipConfig::yodann(),
            engine: EngineKind::Functional,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            policy: ShardPolicy::Auto,
            corner: Corner::energy_optimal(),
            dual_stream: None,
            max_in_flight: None,
            specs: Vec::new(),
            graph: None,
            weights: None,
            precision: None,
            fault: None,
            preflight: Preflight::Off,
            deferred_err: None,
        }
    }

    /// Run a Table-III network with seeded synthetic binary weights
    /// (see [`SessionLayerSpec::synthetic_network`]). A network that
    /// cannot chain defers its typed error to [`SessionBuilder::build`]
    /// — the non-chain networks (AlexNet's 11×11 split, ResNet's
    /// shortcuts) run through [`SessionBuilder::graph`] instead, using
    /// the graph encodings in [`crate::model::networks`]
    /// (`alexnet_graph`, `resnet18_graph`, `resnet34_graph`).
    pub fn network(mut self, net: &Network, seed: u64) -> SessionBuilder {
        match SessionLayerSpec::synthetic_network(net, seed) {
            Ok(specs) => {
                self.specs = specs;
                self.graph = None;
                self.deferred_err = None;
            }
            Err(e) => self.deferred_err = Some(e),
        }
        self
    }

    /// Run an explicit layer chain.
    pub fn layers(mut self, specs: Vec<SessionLayerSpec>) -> SessionBuilder {
        self.specs = specs;
        self.graph = None;
        self.deferred_err = None;
        self
    }

    /// Run a [`NetworkGraph`] — the graph IR that expresses what a
    /// chain cannot: parallel kernel-split branches recombined off-chip
    /// (AlexNet §IV-D), residual adds with projection shortcuts
    /// (ResNet), stride-2 subsampling, channel concat. The graph is
    /// compiled ([`NetworkGraph::compile`]) immediately; a graph that
    /// does not type-check defers its typed error to
    /// [`SessionBuilder::build`].
    pub fn graph(mut self, g: &NetworkGraph) -> SessionBuilder {
        match g.compile() {
            Ok(cg) => {
                self.graph = Some(cg);
                self.specs = Vec::new();
                self.deferred_err = None;
            }
            Err(e) => self.deferred_err = Some(e),
        }
        self
    }

    /// Override every conv layer's kernels and scale/bias with
    /// caller-supplied [`Weights`], in layer (step) order — how real
    /// trained BinaryConnect weights run over a network or graph whose
    /// topology was described with seeded placeholders. Arity and
    /// per-layer geometry (k, n_in, n_out) are validated at
    /// [`SessionBuilder::build`] into typed errors.
    pub fn weights(mut self, weights: Vec<Weights>) -> SessionBuilder {
        self.weights = Some(weights);
        self
    }

    /// Override every conv layer's [`Precision`] in layer (step) order:
    /// [`Precision::Binary`] layers run on the session engine's XNOR
    /// companion (binarized ±1 activations, 1 raster plane instead of
    /// 12), [`Precision::MultiBit`] layers on the engine as configured —
    /// the per-layer knob behind BWN-stem / BNN-trunk mixed-precision
    /// networks. Arity is validated at [`SessionBuilder::build`]
    /// ([`YodannError::PrecisionArity`]). Graphs built with
    /// [`NetworkBuilder::conv_with_precision`] carry their precision
    /// already; this override replaces it wholesale.
    ///
    /// [`NetworkBuilder::conv_with_precision`]:
    ///     crate::model::NetworkBuilder::conv_with_precision
    pub fn precision(mut self, precision: Vec<Precision>) -> SessionBuilder {
        self.precision = Some(precision);
        self
    }

    /// Simulated chip configuration (default: the taped-out YodaNN).
    pub fn chip(mut self, cfg: ChipConfig) -> SessionBuilder {
        self.cfg = cfg;
        self
    }

    /// Convolution engine kind (default: [`EngineKind::Functional`]).
    pub fn engine(mut self, kind: EngineKind) -> SessionBuilder {
        self.engine = kind;
        self
    }

    /// Worker threads in the session pool (default: host parallelism).
    pub fn workers(mut self, n: usize) -> SessionBuilder {
        self.workers = n;
        self
    }

    /// Batch schedule (default: [`ShardPolicy::Auto`]).
    pub fn shard_policy(mut self, policy: ShardPolicy) -> SessionBuilder {
        self.policy = policy;
        self
    }

    /// Operating corner the per-frame telemetry is priced at (default:
    /// the paper's energy-optimal 0.6 V corner).
    pub fn corner(mut self, corner: Corner) -> SessionBuilder {
        self.corner = corner;
        self
    }

    /// Shortcut: keep the corner's architecture, change its supply (V).
    pub fn supply(mut self, v: f64) -> SessionBuilder {
        self.corner.v = v;
        self
    }

    /// Force the dual-stream I/O pricing on or off (default: derived
    /// from layer 1 — dual when `k < 6` and more than 32 output
    /// channels, matching the chip's dual-filter modes).
    pub fn dual_stream(mut self, on: bool) -> SessionBuilder {
        self.dual_stream = Some(on);
        self
    }

    /// Bound on frames in flight — submitted tickets whose result has
    /// not been retrieved (default: `2 × workers`). When the queue is
    /// full, [`Yodann::submit`] reports [`YodannError::Backpressure`].
    pub fn max_in_flight(mut self, n: usize) -> SessionBuilder {
        self.max_in_flight = Some(n);
        self
    }

    /// Arm a [`FaultPlan`] on the session: seeded bit flips in image
    /// memory, packed weights and halo-exchange rows, checksum
    /// detection, and the panic/kill containment drills. Sessions that
    /// set no plan inherit the environment arm (`YODANN_FAULT_SEED`);
    /// pass [`FaultPlan::disabled`] to opt out of both.
    pub fn fault_plan(mut self, plan: FaultPlan) -> SessionBuilder {
        self.fault = Some(plan);
        self
    }

    /// What to do with static-analyzer findings at [`build`] time:
    /// nothing (default), print them to stderr, or refuse the build on
    /// any error-severity finding. The build-time run analyzes without
    /// a frame geometry (frame sizes are only known at submission), so
    /// it covers the range, liveness and lock passes; run
    /// [`analyze`](Self::analyze) with [`AnalysisOptions::shape`] for
    /// the geometry contracts too.
    ///
    /// [`build`]: Self::build
    pub fn preflight(mut self, mode: Preflight) -> SessionBuilder {
        self.preflight = mode;
        self
    }

    /// Run the static analyzer over this builder's configuration
    /// without building (or consuming) anything: the same model
    /// lowering as [`build`](Self::build) — graph passthrough or chain
    /// shim, `weights()` override applied — handed to
    /// [`analysis::analyze_graph`] together with the builder's chip,
    /// shard policy and worker count.
    pub fn analyze(&self, opts: &AnalysisOptions) -> Result<AnalysisReport, YodannError> {
        let plan = self.lowered_plan()?;
        Ok(analysis::analyze_graph(
            &plan,
            &self.cfg,
            Some((&self.policy, self.workers.max(1))),
            opts,
        ))
    }

    /// Lower the configured model to one compiled plan: a graph was
    /// compiled (and type-checked) by `graph()`; a chain gets the
    /// historical eager checks, then the shim lowering; `weights()`
    /// overrides every conv layer's parameters in plan order —
    /// caller-supplied (e.g. trained) weights over a seeded topology —
    /// with the layer geometry re-checked. Shared front half of
    /// [`build`](Self::build) and [`analyze`](Self::analyze).
    fn lowered_plan(&self) -> Result<CompiledGraph, YodannError> {
        if let Some(e) = &self.deferred_err {
            return Err(e.clone());
        }
        let mut plan: CompiledGraph = match &self.graph {
            Some(cg) => cg.clone(),
            None => {
                if self.specs.is_empty() {
                    return Err(YodannError::NoLayers);
                }
                for (li, s) in self.specs.iter().enumerate() {
                    if s.scale_bias.alpha.len() != s.kernels.n_out {
                        return Err(YodannError::ScaleBiasArity {
                            alphas: s.scale_bias.alpha.len(),
                            n_out: s.kernels.n_out,
                        }
                        .at_layer(li));
                    }
                    if li > 0 && self.specs[li - 1].kernels.n_out != s.kernels.n_in {
                        return Err(YodannError::ChannelChainMismatch {
                            prev_out: self.specs[li - 1].kernels.n_out,
                            n_in: s.kernels.n_in,
                        }
                        .at_layer(li));
                    }
                }
                chain_compiled(&self.specs)
            }
        };
        if let Some(ws) = &self.weights {
            if ws.len() != plan.convs.len() {
                return Err(YodannError::WeightsArity {
                    given: ws.len(),
                    layers: plan.convs.len(),
                });
            }
            for (li, (c, w)) in plan.convs.iter_mut().zip(ws).enumerate() {
                if w.kernels.k != c.k
                    || w.kernels.n_in != c.kernels.n_in
                    || w.kernels.n_out != c.kernels.n_out
                {
                    return Err(YodannError::InvalidConfig {
                        what: format!(
                            "weights() layer {li} is {}->{} k{}, but the network's '{}' layer \
                             is {}->{} k{}",
                            w.kernels.n_in,
                            w.kernels.n_out,
                            w.kernels.k,
                            c.label,
                            c.kernels.n_in,
                            c.kernels.n_out,
                            c.k
                        ),
                    }
                    .at_layer(li));
                }
                if w.scale_bias.alpha.len() != w.kernels.n_out {
                    return Err(YodannError::ScaleBiasArity {
                        alphas: w.scale_bias.alpha.len(),
                        n_out: w.kernels.n_out,
                    }
                    .at_layer(li));
                }
                c.kernels = Arc::clone(&w.kernels);
                c.scale_bias = Arc::clone(&w.scale_bias);
            }
        }
        if let Some(ps) = &self.precision {
            if ps.len() != plan.convs.len() {
                return Err(YodannError::PrecisionArity {
                    given: ps.len(),
                    layers: plan.convs.len(),
                });
            }
            for (c, p) in plan.convs.iter_mut().zip(ps) {
                c.precision = *p;
            }
        }
        Ok(plan)
    }

    /// Validate everything and spin up the session (worker pool +
    /// dispatcher thread). Every failure is a typed [`YodannError`];
    /// nothing is spawned unless the whole configuration is runnable.
    pub fn build(self) -> Result<Yodann, YodannError> {
        let plan = self.lowered_plan()?;
        if self.workers == 0 {
            return Err(YodannError::InvalidConfig {
                what: "workers must be >= 1 (0 requested)".into(),
            });
        }
        let max_in_flight = self.max_in_flight.unwrap_or(2 * self.workers);
        if max_in_flight == 0 {
            return Err(YodannError::InvalidConfig {
                what: "max_in_flight must be >= 1 (0 requested)".into(),
            });
        }
        let v = self.corner.v;
        let (v_lo, v_hi) = (self.corner.arch.v_min(), calib::V_NOM);
        if !(v_lo - 1e-9..=v_hi + 1e-9).contains(&v) {
            return Err(YodannError::InvalidConfig {
                what: format!(
                    "supply {v} V outside {}'s operating range [{v_lo}, {v_hi}] V",
                    self.corner.arch.name()
                ),
            });
        }
        for (li, c) in plan.convs.iter().enumerate() {
            // The frame-independent geometry preconditions (k in 1..=7,
            // image memory holds one window); zero_pad/h=1 here skips the
            // per-frame height check, which `validate_frame` walks with
            // the real frame at submission time.
            plan_geometry_check(&self.cfg, c.k, true, 1).map_err(|e| e.at_layer(li))?;
        }
        // Optional static-analysis preflight (range, liveness, locks —
        // geometry contracts need a frame shape and run per-submission).
        if self.preflight != Preflight::Off {
            let report = analysis::analyze_graph(
                &plan,
                &self.cfg,
                Some((&self.policy, self.workers)),
                &AnalysisOptions::default(),
            );
            match self.preflight {
                Preflight::Off => {}
                Preflight::Warn => {
                    for f in &report.findings {
                        eprintln!("yodann preflight [{}]: {f}", report.net);
                    }
                }
                Preflight::Refuse => {
                    if report.has_errors() {
                        let n = report.count_at(Severity::Error);
                        let first = report
                            .findings
                            .iter()
                            .find(|f| f.severity == Severity::Error)
                            .map(|f| f.to_string())
                            .unwrap_or_default();
                        return Err(YodannError::InvalidConfig {
                            what: format!(
                                "preflight analysis found {n} error finding(s); first: {first}"
                            ),
                        });
                    }
                }
            }
        }
        let first = &plan.convs[0];
        let dual = self
            .dual_stream
            .unwrap_or(first.k < 6 && first.kernels.n_out > 32);
        let chips = match self.policy {
            ShardPolicy::PerFrame => 1,
            ShardPolicy::PerShard(g) => g.chips(),
            // Auto stripes small batches across the whole pool: price
            // that worst case.
            ShardPolicy::Auto => self.workers,
            // Row-bands fans one frame across band workers (0 = the
            // whole pool), each modeling a chip against the shared
            // raster — but never more chips than the pool can actually
            // run concurrently, however many bands were requested.
            ShardPolicy::RowBands(n) => {
                if n == 0 {
                    self.workers
                } else {
                    n.min(self.workers)
                }
            }
        };
        // Price the whole-session envelope at the most power-hungry
        // kernel mode across the chain — not the first layer's. On the
        // multi-kernel architectures the 5×5 slot mode out-prices even
        // native 7×7 (MODE_RATIO_SLOT5 > 1), so "worst case" is decided
        // by the priced power, not by raw kernel size.
        let mut envelope_k = first.k;
        let mut envelope = MultiChipPower::at(self.corner.arch, v, chips, envelope_k);
        for c in plan.convs.iter().skip(1) {
            if c.k == envelope_k {
                continue;
            }
            let cand = MultiChipPower::at(self.corner.arch, v, chips, c.k);
            if cand.total_w() > envelope.total_w() {
                envelope = cand;
                envelope_k = c.k;
            }
        }
        let pricing = Arc::new(Mutex::new(Pricing {
            corner: self.corner,
            envelope,
            envelope_k,
            chips,
        }));
        let ctx = TelemetryCtx {
            engine: self.engine,
            policy: self.policy,
            dual_stream: dual,
            pricing: Arc::clone(&pricing),
        };
        // Weight-memory faults inject as the kernels are packed, so an
        // uncorrectable detection surfaces here as a typed build error.
        let fault = self.fault.or_else(FaultPlan::from_env);
        let session = NetworkSession::spawn_plan(
            self.cfg,
            self.engine,
            self.workers,
            self.policy,
            plan.clone(),
            fault,
        )?;
        let (tx, rx) = channel::<Job>();
        let dispatcher = std::thread::spawn(move || dispatcher_loop(session, rx, ctx));
        Ok(Yodann {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            in_flight: Arc::new(AtomicUsize::new(0)),
            next_id: 0,
            max_in_flight,
            plan: Arc::new(plan),
            engine: self.engine,
            policy: self.policy,
            workers: self.workers,
            pricing,
        })
    }
}

/// The serving facade: a persistent inference session with non-blocking
/// frame submission, bounded in-flight queueing, and per-frame
/// telemetry.
///
/// Built by [`SessionBuilder`]. Frames go in through [`Yodann::submit`]
/// (returning a [`FrameTicket`] immediately) or the blocking
/// [`Yodann::run_batch`] convenience; every completed frame comes back
/// as a [`FrameResult`] carrying the output image **and** its
/// [`FrameTelemetry`]. The dispatcher batches adaptively — bursts of
/// submissions fan across the whole worker pool under the session's
/// [`ShardPolicy`], exactly like the pre-redesign batch path. Outputs
/// are bit-identical to the deprecated [`NetworkSession`] paths for
/// every engine kind and shard policy.
///
/// Dropping the session drains every in-flight frame first, so
/// outstanding tickets stay redeemable.
///
/// ```
/// use std::sync::Arc;
/// use yodann::api::SessionBuilder;
/// use yodann::coordinator::SessionLayerSpec;
/// use yodann::engine::EngineKind;
/// use yodann::testkit::Gen;
/// use yodann::workload::{BinaryKernels, Image, ScaleBias};
///
/// let mut g = Gen::new(7);
/// let layer = SessionLayerSpec {
///     k: 3,
///     zero_pad: true,
///     kernels: Arc::new(BinaryKernels::random(&mut g, 4, 3, 3)),
///     scale_bias: Arc::new(ScaleBias::identity(4)),
///     relu: false,
///     maxpool2: false,
/// };
/// let mut session = SessionBuilder::new()
///     .layers(vec![layer])
///     .engine(EngineKind::Functional)
///     .workers(2)
///     .build()
///     .expect("a valid one-layer session");
///
/// let ticket = session.submit(Image::zeros(3, 8, 8)).expect("queue has room");
/// let result = ticket.wait().expect("frame computes");
/// assert_eq!((result.output.c, result.output.h, result.output.w), (4, 8, 8));
/// assert!(result.telemetry.ops > 0); // Eq. 7 accounting rides on every result
/// ```
#[derive(Debug)]
pub struct Yodann {
    tx: Option<Sender<Job>>,
    dispatcher: Option<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    next_id: u64,
    max_in_flight: usize,
    plan: Arc<CompiledGraph>,
    engine: EngineKind,
    policy: ShardPolicy,
    workers: usize,
    pricing: Arc<Mutex<Pricing>>,
}

impl Yodann {
    /// Start configuring a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// Engine kind the session runs.
    pub fn engine(&self) -> EngineKind {
        self.engine
    }

    /// Batch schedule in force.
    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    /// Worker threads in the session pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Conv layers in the network plan.
    pub fn n_layers(&self) -> usize {
        self.plan.convs.len()
    }

    /// Operating corner the telemetry is currently priced at.
    pub fn corner(&self) -> Corner {
        lock_pricing(&self.pricing).corner
    }

    /// Fraction of conv layers this session runs on the binary
    /// (XNOR) datapath — [`Precision::Binary`] layers plus everything
    /// when the main engine itself is an XNOR kind (binary engines run
    /// every layer binary). The serve governor blends its core-power
    /// pricing between the BWN and derived XNOR models by this
    /// fraction.
    pub fn binary_layer_fraction(&self) -> f64 {
        if self.plan.convs.is_empty() {
            return 0.0;
        }
        if self.engine.is_binary() {
            return 1.0;
        }
        let n = self.plan.convs.iter().filter(|c| c.precision == Precision::Binary).count();
        n as f64 / self.plan.convs.len() as f64
    }

    /// The whole-session power envelope frames are currently priced
    /// against — the most power-hungry kernel mode across the chain, at
    /// [`Yodann::corner`], over [`Yodann::envelope_chips`] chips.
    pub fn envelope(&self) -> MultiChipPower {
        lock_pricing(&self.pricing).envelope
    }

    /// The kernel size the envelope is priced at: the conv layer whose
    /// slot mode draws the most power (on multi-kernel architectures the
    /// 5×5 mode out-prices native 7×7, so this is not simply `max(k)`).
    pub fn envelope_kernel(&self) -> usize {
        lock_pricing(&self.pricing).envelope_k
    }

    /// Concurrent chips the envelope prices — the shard policy's chip
    /// count, clamped to the worker pool for row-band schedules.
    pub fn envelope_chips(&self) -> usize {
        lock_pricing(&self.pricing).chips
    }

    /// Move the session's operating corner at runtime — the DVFS hook.
    ///
    /// Re-prices telemetry (corner-tagged `SimMetrics`, the
    /// [`MultiChipPower`] envelope) for every frame completing after the
    /// swap **without rebuilding the session**: the compute plan, packed
    /// weights, worker pool and in-flight tickets are all
    /// corner-agnostic, so outputs are bit-identical across corners and
    /// only the pricing changes. Frames already in flight are priced at
    /// the corner in force when they complete.
    ///
    /// Errors with [`YodannError::SupplyOutOfRange`] when the supply is
    /// off the architecture's operating range — the same boundary
    /// [`SessionBuilder::build`] enforces, as a typed error instead of a
    /// deferred panic, so a governor stepping the corner cannot crash
    /// serving.
    pub fn set_corner(&self, corner: Corner) -> Result<(), YodannError> {
        let (v_lo, v_hi) = (corner.arch.v_min(), calib::V_NOM);
        if !(v_lo - 1e-9..=v_hi + 1e-9).contains(&corner.v) {
            return Err(YodannError::SupplyOutOfRange { v: corner.v, vmin: v_lo, vmax: v_hi });
        }
        let mut p = lock_pricing(&self.pricing);
        p.corner = corner;
        p.envelope = MultiChipPower::at(corner.arch, corner.v, p.chips, p.envelope_k);
        Ok(())
    }

    /// Frames currently in flight (submitted, result not yet retrieved).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// The in-flight bound; [`Yodann::submit`] backpressures at it.
    pub fn max_in_flight(&self) -> usize {
        self.max_in_flight
    }

    /// Validate a frame against the compiled network plan without
    /// running it: the checks [`Yodann::submit`] performs, available
    /// for admission control. The walk
    /// ([`CompiledGraph::walk_shapes`]) carries (c, h, w) through every
    /// conv segment and host-op interlude — valid-mode layers that run
    /// out of pixels mid-network come back as typed
    /// [`YodannError::NoOutputRows`] (per layer), graph joins whose
    /// branches disagree as [`YodannError::GraphShapeMismatch`];
    /// pre-redesign both were a worker panic (debug) or a usize wrap
    /// (release).
    pub fn validate_frame(&self, frame: &Image) -> Result<(), YodannError> {
        if frame.c == 0 || frame.h == 0 || frame.w == 0 {
            return Err(YodannError::EmptyFrame { c: frame.c, h: frame.h, w: frame.w });
        }
        self.plan.walk_shapes(frame.c, frame.h, frame.w).map(|_| ())
    }

    /// Submit one frame for inference, **without blocking**: the frame
    /// is validated eagerly, enqueued to the dispatcher, and a
    /// [`FrameTicket`] for its result is returned immediately.
    ///
    /// Errors: any [`Yodann::validate_frame`] failure;
    /// [`YodannError::Backpressure`] when [`Yodann::in_flight`] has
    /// reached the bound (wait on or drop an outstanding ticket, then
    /// resubmit); [`YodannError::SessionClosed`] if the dispatcher is
    /// gone.
    pub fn submit(&mut self, frame: Image) -> Result<FrameTicket, YodannError> {
        self.validate_frame(&frame)?;
        let occupied = self.in_flight.load(Ordering::SeqCst);
        if occupied >= self.max_in_flight {
            return Err(YodannError::Backpressure {
                in_flight: occupied,
                limit: self.max_in_flight,
            });
        }
        let tx = self.tx.as_ref().ok_or(YodannError::SessionClosed)?;
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let slot = SlotGuard(Arc::clone(&self.in_flight));
        let id = self.next_id;
        let (reply_tx, reply_rx) = channel();
        if tx.send(Job { id, frame, reply: reply_tx }).is_err() {
            // `slot` drops here, releasing the claimed capacity.
            return Err(YodannError::SessionClosed);
        }
        self.next_id += 1;
        Ok(FrameTicket { id, rx: reply_rx, done: None, slot: Some(slot) })
    }

    /// Blocking convenience over [`Yodann::submit`]: run a whole batch,
    /// pipelining submissions against the in-flight bound, and return
    /// the results in input order. An empty batch is `Ok(vec![])`.
    ///
    /// Fails with [`YodannError::Backpressure`] only when capacity is
    /// held by tickets *outside* this batch — drain those first.
    pub fn run_batch(&mut self, frames: Vec<Image>) -> Result<Vec<FrameResult>, YodannError> {
        let mut tickets: VecDeque<FrameTicket> = VecDeque::new();
        let mut results: Vec<FrameResult> = Vec::with_capacity(frames.len());
        for frame in frames {
            while self.in_flight() >= self.max_in_flight {
                match tickets.pop_front() {
                    Some(t) => results.push(t.wait()?),
                    None => {
                        return Err(YodannError::Backpressure {
                            in_flight: self.in_flight(),
                            limit: self.max_in_flight,
                        })
                    }
                }
            }
            tickets.push_back(self.submit(frame)?);
        }
        for t in tickets {
            results.push(t.wait()?);
        }
        Ok(results)
    }
}

impl Drop for Yodann {
    fn drop(&mut self) {
        // Close the job channel, then join: the dispatcher drains every
        // already-submitted frame first, so outstanding tickets resolve.
        self.tx.take();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// The dispatcher thread: owns the worker-pool session, serves queued
/// frames in submission order with **adaptive batching** — it drains
/// every job already queued and hands them to the session as one batch,
/// so a burst of submissions fans across the whole worker pool exactly
/// like the pre-redesign `run_batch` (a frame-at-a-time dispatcher
/// would serialize the pool under the per-frame schedule). Failures are
/// contained per frame: the session hands back a typed error in the
/// failed frame's slot (worker panic, injected loss, detected fault) and
/// only that ticket redeems the error, retagged with its ticket id. A
/// panic that escapes the session itself (a coordinator bug) is
/// converted to [`YodannError::Worker`] on each of the batch's tickets;
/// the dispatcher survives for later frames either way.
fn dispatcher_loop(mut session: NetworkSession, rx: Receiver<Job>, ctx: TelemetryCtx) {
    while let Ok(first) = rx.recv() {
        let mut jobs = vec![first];
        while let Ok(j) = rx.try_recv() {
            jobs.push(j);
        }
        let n = jobs.len();
        let mut ids = Vec::with_capacity(n);
        let mut frames = Vec::with_capacity(n);
        let mut replies = Vec::with_capacity(n);
        for Job { id, frame, reply } in jobs {
            ids.push(id);
            frames.push(frame);
            replies.push(reply);
        }
        let t0 = Instant::now();
        let out =
            std::panic::catch_unwind(AssertUnwindSafe(|| session.run_batch_traced(frames)));
        // Wall time amortized over the dispatch batch — the honest
        // per-frame figure when frames share the pool.
        let host_each = t0.elapsed().as_secs_f64() / n as f64;
        // A dropped ticket is fine — its result is simply discarded.
        match out {
            Ok(batch) => {
                for ((res, &id), reply) in batch.into_iter().zip(&ids).zip(&replies) {
                    let msg = match res {
                        Ok(traced) => Ok(ctx.frame_result(id, traced, host_each)),
                        // The session reports errors under its own batch
                        // index; the ticket speaks frame ids.
                        Err(e) => Err(e.with_frame_id(id)),
                    };
                    let _ = reply.send(msg);
                }
            }
            Err(p) => {
                let message = panic_message(p);
                for (&id, reply) in ids.iter().zip(&replies) {
                    let _ = reply
                        .send(Err(YodannError::Worker { frame: id, message: message.clone() }));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{BinaryKernels, ScaleBias};

    fn spec(k: usize, n_in: usize, n_out: usize, zero_pad: bool, seed: u64) -> SessionLayerSpec {
        let mut g = Gen::new(seed);
        SessionLayerSpec {
            k,
            zero_pad,
            kernels: Arc::new(BinaryKernels::random(&mut g, n_out, n_in, k)),
            scale_bias: Arc::new(ScaleBias::identity(n_out)),
            relu: false,
            maxpool2: false,
        }
    }

    #[test]
    fn builder_validates_eagerly_and_typed() {
        assert_eq!(SessionBuilder::new().build().unwrap_err(), YodannError::NoLayers);
        let e = SessionBuilder::new().layers(vec![spec(3, 3, 4, true, 1)]).workers(0).build();
        assert!(matches!(e.unwrap_err(), YodannError::InvalidConfig { .. }));
        let e = SessionBuilder::new()
            .layers(vec![spec(3, 3, 4, true, 1)])
            .max_in_flight(0)
            .build();
        assert!(matches!(e.unwrap_err(), YodannError::InvalidConfig { .. }));
        let e = SessionBuilder::new().layers(vec![spec(3, 3, 4, true, 1)]).supply(0.3).build();
        assert!(matches!(e.unwrap_err(), YodannError::InvalidConfig { .. }));
        // Broken channel chain, tagged with the offending layer.
        let e = SessionBuilder::new()
            .layers(vec![spec(3, 3, 4, true, 1), spec(3, 5, 2, true, 2)])
            .build()
            .unwrap_err();
        assert!(matches!(&e, YodannError::AtLayer { layer: 1, inner }
            if matches!(**inner, YodannError::ChannelChainMismatch { prev_out: 4, n_in: 5 })));
        // A valid network set after a failed one clears the deferred
        // error instead of reporting it stale.
        let ok = SessionBuilder::new()
            .network(&crate::model::networks::alexnet(), 1)
            .network(&crate::model::networks::scene_labeling(), 1)
            .workers(1)
            .build();
        assert!(ok.is_ok(), "{:?}", ok.err());
    }

    #[test]
    fn graph_sessions_build_and_validate_eagerly() {
        use crate::model::graph::NetworkBuilder;
        // A residual graph builds into a serving session.
        let mut g = Gen::new(21);
        let mut b = NetworkBuilder::new("res", 3);
        let x = b.input();
        let main = b.conv("c1", x, true, Weights::seeded(&mut g, 4, 3, 3));
        let proj = b.conv("p", x, true, Weights::seeded(&mut g, 4, 3, 1));
        let sum = b.add("add", &[main, proj]);
        let graph = b.build(sum);
        let mut sess =
            SessionBuilder::new().graph(&graph).workers(2).build().expect("graph builds");
        assert_eq!(sess.n_layers(), 2);
        let r = sess.submit(Image::zeros(3, 6, 6)).unwrap().wait().unwrap();
        assert_eq!((r.output.c, r.output.h, r.output.w), (4, 6, 6));
        // A graph that fails to compile defers its typed error to build.
        let mut g = Gen::new(22);
        let mut b = NetworkBuilder::new("bad", 3);
        let x = b.input();
        let c = b.conv("c1", x, true, Weights::seeded(&mut g, 4, 5, 3)); // wants 5 channels
        let bad = b.build(c);
        let e = SessionBuilder::new().graph(&bad).build().unwrap_err();
        assert!(matches!(&e, YodannError::AtNode { node, inner }
            if node == "c1"
                && matches!(**inner, YodannError::ChannelChainMismatch { prev_out: 3, n_in: 5 })));
    }

    #[test]
    fn weights_override_is_validated_and_applied() {
        // Supplying too few weight sets is a typed arity error.
        let e = SessionBuilder::new()
            .layers(vec![spec(3, 3, 4, true, 31), spec(3, 4, 2, true, 32)])
            .weights(vec![])
            .build()
            .unwrap_err();
        assert_eq!(e, YodannError::WeightsArity { given: 0, layers: 2 });
        // A geometry mismatch names the layer.
        let mut g = Gen::new(33);
        let wrong = Weights::seeded(&mut g, 4, 3, 5); // k5 where the layer is k3
        let e = SessionBuilder::new()
            .layers(vec![spec(3, 3, 4, true, 31)])
            .weights(vec![wrong])
            .build()
            .unwrap_err();
        assert!(matches!(&e, YodannError::AtLayer { layer: 0, inner }
            if matches!(**inner, YodannError::InvalidConfig { .. })));
        // Matching weights actually land: an all-+1 1×1 kernel with
        // identity scale makes the layer the per-pixel channel sum.
        let w = Weights::new(
            Arc::new(BinaryKernels::all_plus(1, 2, 1)),
            Arc::new(ScaleBias::identity(1)),
        );
        let mut sess = SessionBuilder::new()
            .layers(vec![spec(1, 2, 1, true, 34)])
            .weights(vec![w])
            .workers(1)
            .build()
            .unwrap();
        let mut frame = Image::zeros(2, 1, 1);
        *frame.at_mut(0, 0, 0) = 100;
        *frame.at_mut(1, 0, 0) = 23;
        let r = sess.submit(frame).unwrap().wait().unwrap();
        assert_eq!(r.output.at(0, 0, 0), 123);
    }

    #[test]
    fn frame_validation_walks_the_chain_geometry() {
        // Two valid-mode k=5 layers: an 11×11 frame leaves 7×7 after
        // layer 0 and 3×3 < k at layer 1 — the error names layer 1.
        let session = SessionBuilder::new()
            .layers(vec![spec(5, 2, 3, false, 3), spec(5, 3, 2, false, 4)])
            .workers(1)
            .build()
            .unwrap();
        assert!(session.validate_frame(&Image::zeros(2, 11, 11)).is_ok());
        let e = session.validate_frame(&Image::zeros(2, 7, 11)).unwrap_err();
        assert!(matches!(&e, YodannError::AtLayer { layer: 1, inner }
            if matches!(**inner, YodannError::NoOutputRows { k: 5, axis: "height", size: 3 })));
        let e = session.validate_frame(&Image::zeros(3, 11, 11)).unwrap_err();
        assert_eq!(e, YodannError::FrameChannelMismatch { got: 3, expected: 2 });
        let e = session.validate_frame(&Image::zeros(2, 0, 4)).unwrap_err();
        assert!(matches!(e, YodannError::EmptyFrame { .. }));
    }

    #[test]
    fn submit_backpressures_deterministically_and_recovers() {
        let mut session = SessionBuilder::new()
            .layers(vec![spec(3, 2, 2, true, 5)])
            .workers(1)
            .max_in_flight(2)
            .build()
            .unwrap();
        let g = |s: u64| {
            let mut g = Gen::new(s);
            crate::workload::random_image(&mut g, 2, 6, 6, 0.05)
        };
        let t0 = session.submit(g(1)).unwrap();
        let t1 = session.submit(g(2)).unwrap();
        // Slots are held until tickets deliver — the third submit is
        // refused no matter how fast the dispatcher is.
        let e = session.submit(g(3)).unwrap_err();
        assert_eq!(e, YodannError::Backpressure { in_flight: 2, limit: 2 });
        let r0 = t0.wait().unwrap();
        assert_eq!(r0.frame_id, 0);
        // Capacity came back.
        let t3 = session.submit(g(3)).unwrap();
        assert_eq!(t3.id(), 2);
        drop(t1);
        assert!(t3.wait().is_ok());
    }

    #[test]
    fn envelope_prices_the_worst_case_kernel_mode() {
        // Regression: the envelope used to be priced at `first.k`, so a
        // heterogeneous chain (AlexNet 11→5→3, ResNet 7→3) reported the
        // first layer's power for the whole session. A k3→k5 chain must
        // price at the 5×5 slot mode — identical to a homogeneous-k5
        // chain — not at the cheap leading 3×3 mode.
        let mixed = SessionBuilder::new()
            .layers(vec![spec(3, 3, 4, true, 41), spec(5, 4, 2, true, 42)])
            .workers(1)
            .build()
            .unwrap();
        let homo = SessionBuilder::new()
            .layers(vec![spec(5, 3, 4, true, 43), spec(5, 4, 2, true, 44)])
            .workers(1)
            .build()
            .unwrap();
        assert_eq!(mixed.envelope_kernel(), 5);
        assert_eq!(mixed.envelope().core_w_each, homo.envelope().core_w_each);
        // Pre-fix behavior: priced at first.k == 3 — strictly cheaper.
        let c = mixed.corner();
        let k3 = MultiChipPower::at(c.arch, c.v, 1, 3);
        assert!(
            mixed.envelope().core_w_each > k3.core_w_each,
            "worst-case mode must out-price the first layer's 3x3 mode"
        );
        // And "worst case" is decided by priced power, not raw k: on the
        // multi-kernel chip a k5 layer beats a k7 one.
        let with_k7 = SessionBuilder::new()
            .layers(vec![spec(7, 3, 4, true, 45), spec(5, 4, 2, true, 46)])
            .workers(1)
            .build()
            .unwrap();
        assert_eq!(with_k7.envelope_kernel(), 5);
    }

    #[test]
    fn row_band_pricing_clamps_to_the_worker_pool() {
        // Regression: RowBands(n) used to price `n` chips verbatim even
        // when n dwarfs the worker pool — an envelope claiming more
        // concurrent chips than can ever run.
        let s = SessionBuilder::new()
            .layers(vec![spec(3, 2, 2, true, 51)])
            .workers(2)
            .shard_policy(ShardPolicy::RowBands(64))
            .build()
            .unwrap();
        assert_eq!(s.envelope().chips, 2);
        assert_eq!(s.envelope_chips(), 2);
        // Fewer bands than workers still price the requested bands.
        let s = SessionBuilder::new()
            .layers(vec![spec(3, 2, 2, true, 52)])
            .workers(4)
            .shard_policy(ShardPolicy::RowBands(3))
            .build()
            .unwrap();
        assert_eq!(s.envelope().chips, 3);
        // RowBands(0) = one band per worker, as before.
        let s = SessionBuilder::new()
            .layers(vec![spec(3, 2, 2, true, 53)])
            .workers(2)
            .shard_policy(ShardPolicy::RowBands(0))
            .build()
            .unwrap();
        assert_eq!(s.envelope().chips, 2);
    }

    #[test]
    fn runtime_corner_swap_reprices_without_rebuilding() {
        let mut s = SessionBuilder::new()
            .layers(vec![spec(3, 2, 2, true, 61)])
            .workers(1)
            .build()
            .unwrap();
        let mut g = Gen::new(62);
        let frame = crate::workload::random_image(&mut g, 2, 6, 6, 0.1);
        let r0 = s.submit(frame.clone()).unwrap().wait().unwrap();
        assert!((r0.telemetry.corner.v - 0.6).abs() < 1e-12);
        let p0 = s.envelope().total_w();
        // Swap to the throughput-optimal corner: telemetry re-prices,
        // outputs stay bit-identical — no session rebuild.
        s.set_corner(Corner::throughput_optimal()).unwrap();
        assert!((s.corner().v - 1.2).abs() < 1e-12);
        assert!(s.envelope().total_w() > p0);
        let r1 = s.submit(frame).unwrap().wait().unwrap();
        assert!((r1.telemetry.corner.v - 1.2).abs() < 1e-12);
        assert!(r1.telemetry.envelope.total_w() > p0);
        assert_eq!(r0.output, r1.output);
        // An off-curve supply is a typed error and leaves pricing as-is.
        let e = s
            .set_corner(Corner { arch: crate::power::ArchId::Bin32Multi, v: 0.3 })
            .unwrap_err();
        assert!(matches!(e, YodannError::SupplyOutOfRange { .. }));
        assert!((s.corner().v - 1.2).abs() < 1e-12);
    }

    #[test]
    fn dropping_a_ticket_frees_its_slot() {
        let mut session = SessionBuilder::new()
            .layers(vec![spec(3, 2, 2, true, 6)])
            .workers(1)
            .max_in_flight(1)
            .build()
            .unwrap();
        let t = session.submit(Image::zeros(2, 5, 5)).unwrap();
        drop(t);
        // The dropped ticket released its claim even if the frame is
        // still computing.
        let t2 = session.submit(Image::zeros(2, 5, 5)).unwrap();
        assert!(t2.wait().is_ok());
    }
}
