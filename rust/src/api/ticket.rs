//! Frame tickets and per-frame results for the serving API.
//!
//! [`Yodann::submit`](super::Yodann::submit) is non-blocking: it hands
//! back a [`FrameTicket`] immediately and the frame computes on the
//! session's dispatcher in the background. The ticket is the only handle
//! to the result — [`FrameTicket::poll`] checks without blocking,
//! [`FrameTicket::wait`] blocks until the frame is done. Every completed
//! frame carries a [`FrameTelemetry`]: the merged activity ledger, the
//! paper's metrics at the session's operating corner, and the
//! multi-chip power-envelope snapshot — no side-channel accessors.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use super::YodannError;
use crate::coordinator::metrics::SimMetrics;
use crate::coordinator::ShardPolicy;
use crate::engine::EngineKind;
use crate::fault::FaultReport;
use crate::hw::ChipStats;
use crate::model::Corner;
use crate::power::MultiChipPower;
use crate::workload::Image;

/// What the serving session observed computing one frame.
///
/// The ledger (`stats`) is merged over every chip block of every layer
/// the frame executed. `metrics` prices that ledger at the session's
/// operating corner through the same [`sim_metrics`] roll-up the paper's
/// tables use — it is `Some` only for engines that keep a cycle ledger
/// (the cycle-accurate engine); the functional engines count
/// `useful_ops` but no cycles, so there is no chip time to price.
///
/// [`sim_metrics`]: crate::coordinator::metrics::sim_metrics
#[derive(Debug, Clone)]
pub struct FrameTelemetry {
    /// Ticket id of the frame this telemetry belongs to.
    pub frame_id: u64,
    /// Engine kind that computed the frame.
    pub engine: EngineKind,
    /// Schedule the frame ran under.
    pub policy: ShardPolicy,
    /// Operating corner the metrics are priced at.
    pub corner: Corner,
    /// Merged activity ledger (all-zero except `useful_ops` for engines
    /// without a cycle ledger).
    pub stats: ChipStats,
    /// Useful operations (Eq. 7 accounting), for every engine kind.
    pub ops: u64,
    /// Total simulated chip cycles (0 for ledger-free engines).
    pub cycles: u64,
    /// Host wall-clock seconds attributed to this frame: the dispatch
    /// batch's wall time divided by its size (frames submitted in a
    /// burst share the worker pool).
    pub host_seconds: f64,
    /// The paper's corner metrics (chip time, Θ, energy, Op/J) — `Some`
    /// when the engine kept a cycle ledger.
    pub metrics: Option<SimMetrics>,
    /// Aggregate power envelope of the chip grid the schedule implies
    /// (1 chip per-frame, `stripes × out_groups` per-shard).
    pub envelope: MultiChipPower,
    /// What fault injection did to this frame (all-zero when no plan is
    /// armed): surviving bit flips per site, checksum detections and
    /// repack retries, session-lifetime weight faults folded in.
    pub fault: FaultReport,
}

impl FrameTelemetry {
    /// Simulated core energy for this frame (J), when priced.
    pub fn energy_j(&self) -> Option<f64> {
        self.metrics.as_ref().map(|m| m.core_energy)
    }

    /// Simulated chip throughput Θ for this frame (GOp/s), when priced.
    pub fn chip_gops(&self) -> Option<f64> {
        self.metrics.as_ref().map(|m| m.theta / 1e9)
    }

    /// Host-side throughput of this frame (GOp/s of useful work).
    pub fn host_gops(&self) -> f64 {
        if self.host_seconds > 0.0 {
            self.ops as f64 / self.host_seconds / 1e9
        } else {
            0.0
        }
    }
}

/// One completed frame: the output image plus its telemetry.
#[derive(Debug, Clone)]
pub struct FrameResult {
    /// Ticket id (submission order).
    pub frame_id: u64,
    /// The network's output feature map.
    pub output: Image,
    /// Everything observed computing the frame.
    pub telemetry: FrameTelemetry,
}

/// RAII occupancy of one in-flight slot: decremented exactly once, when
/// the ticket delivers its result or is dropped unredeemed.
#[derive(Debug)]
pub(crate) struct SlotGuard(pub(crate) Arc<AtomicUsize>);

impl Drop for SlotGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A claim on one submitted frame's result.
///
/// Obtained from [`Yodann::submit`](super::Yodann::submit). The ticket
/// occupies one slot of the session's bounded in-flight queue until its
/// result is delivered (first `poll` that returns `true`, or `wait`) or
/// the ticket is dropped — holding finished tickets without polling them
/// therefore backpressures `submit`, which is the point: a serving loop
/// that stops draining results stops admitting frames.
///
/// Tickets outlive their session: dropping the [`Yodann`](super::Yodann)
/// first drains every in-flight frame, so a ticket polled afterwards
/// still yields its result.
///
/// ```
/// use yodann::api::SessionBuilder;
/// use yodann::engine::EngineKind;
/// use yodann::model::networks;
/// use yodann::workload::Image;
///
/// let mut session = SessionBuilder::new()
///     .network(&networks::scene_labeling(), 42)
///     .engine(EngineKind::Functional)
///     .workers(2)
///     .build()
///     .expect("scene-labeling chains");
/// let mut ticket = session.submit(Image::zeros(3, 8, 8)).expect("queue has room");
/// while !ticket.poll() {
///     std::thread::yield_now(); // non-blocking: do other work here
/// }
/// let result = ticket.wait().expect("frame computes");
/// assert_eq!(result.frame_id, 0);
/// ```
#[derive(Debug)]
pub struct FrameTicket {
    pub(crate) id: u64,
    pub(crate) rx: Receiver<Result<FrameResult, YodannError>>,
    pub(crate) done: Option<Result<FrameResult, YodannError>>,
    pub(crate) slot: Option<SlotGuard>,
}

impl FrameTicket {
    /// The frame's id (assigned in submission order, starting at 0).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Non-blocking readiness check. Returns `true` once the result (or
    /// the frame's error) is in; the value is cached for [`Self::wait`].
    /// Releases the in-flight slot the first time it returns `true`.
    pub fn poll(&mut self) -> bool {
        if self.done.is_some() {
            return true;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.finish(r);
                true
            }
            Err(TryRecvError::Empty) => false,
            Err(TryRecvError::Disconnected) => {
                self.finish(Err(YodannError::SessionClosed));
                true
            }
        }
    }

    /// Block until the frame is done and return its result. Consumes the
    /// ticket and releases its in-flight slot.
    pub fn wait(mut self) -> Result<FrameResult, YodannError> {
        if let Some(r) = self.done.take() {
            return r;
        }
        let r = self.rx.recv().unwrap_or_else(|_| Err(YodannError::SessionClosed));
        self.slot = None;
        r
    }

    /// Block for at most `timeout` — the serving loop's frame deadline.
    ///
    /// A deadline miss returns [`YodannError::DeadlineExceeded`] but
    /// does **not** consume the ticket: the frame is still in flight,
    /// its in-flight slot stays occupied, and a later
    /// `wait`/`wait_timeout`/`poll` still redeems the result. A dead
    /// dispatcher maps to [`YodannError::SessionClosed`]
    /// deterministically.
    pub fn wait_timeout(&mut self, timeout: Duration) -> Result<FrameResult, YodannError> {
        if let Some(r) = self.done.take() {
            self.slot = None;
            return r;
        }
        match self.rx.recv_timeout(timeout) {
            Ok(r) => {
                self.slot = None;
                r
            }
            Err(RecvTimeoutError::Timeout) => Err(YodannError::DeadlineExceeded {
                frame: self.id,
                timeout_ms: timeout.as_millis() as u64,
            }),
            Err(RecvTimeoutError::Disconnected) => {
                self.slot = None;
                Err(YodannError::SessionClosed)
            }
        }
    }

    fn finish(&mut self, r: Result<FrameResult, YodannError>) {
        self.done = Some(r);
        self.slot = None; // release the in-flight slot exactly once
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn ticket(rx: Receiver<Result<FrameResult, YodannError>>) -> FrameTicket {
        FrameTicket { id: 9, rx, done: None, slot: None }
    }

    #[test]
    fn wait_timeout_reports_deadline_then_still_delivers() {
        let (tx, rx) = channel();
        let mut t = ticket(rx);
        let e = t.wait_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(
            matches!(e, YodannError::DeadlineExceeded { frame: 9, timeout_ms: 10 }),
            "{e}"
        );
        assert!(e.to_string().contains("missed its 10 ms deadline"), "{e}");
        // The ticket stays redeemable: a late result still comes through.
        tx.send(Err(YodannError::Worker { frame: 9, message: "late".into() })).unwrap();
        let late = t.wait_timeout(Duration::from_millis(100)).unwrap_err();
        assert!(matches!(late, YodannError::Worker { frame: 9, .. }), "{late}");
    }

    #[test]
    fn dead_dispatcher_maps_to_session_closed_deterministically() {
        let (tx, rx) = channel();
        drop(tx);
        let mut t = ticket(rx);
        let e = t.wait_timeout(Duration::from_millis(5)).unwrap_err();
        assert!(matches!(e, YodannError::SessionClosed), "{e}");

        let (tx2, rx2) = channel::<Result<FrameResult, YodannError>>();
        drop(tx2);
        let mut t2 = ticket(rx2);
        assert!(t2.poll(), "disconnect is a terminal, immediately ready state");
        let e2 = t2.wait().unwrap_err();
        assert!(matches!(e2, YodannError::SessionClosed), "{e2}");
    }
}
