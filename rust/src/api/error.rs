//! Typed errors for the serving API.
//!
//! The pre-redesign execution surface failed in three inconsistent ways:
//! `Result<_, String>` from [`SessionLayerSpec::synthetic_network`],
//! panics from the plan-geometry guards
//! (`coordinator::blocks::check_plan_geometry`), and asserts inside
//! `NetworkSession` construction and batch submission. [`YodannError`]
//! replaces all three on the [`Yodann`](super::Yodann) facade: the
//! builder validates eagerly and every runtime failure a caller can
//! provoke (bad frame geometry, backpressure, a torn-down session) comes
//! back as a matchable variant instead of a panic or an opaque string.
//!
//! [`SessionLayerSpec::synthetic_network`]: crate::coordinator::SessionLayerSpec::synthetic_network

use crate::engine::EngineKind;
use crate::fault::FaultSite;

/// Everything the serving API can refuse to do, as data.
///
/// Variants carry the numbers a caller needs to react (resize the frame,
/// shed load, pick another engine) without parsing message text; the
/// [`std::fmt::Display`] form spells each one out for logs. Layer-scoped
/// failures are wrapped in [`YodannError::AtLayer`] so one geometry
/// variant serves every layer of a chain.
#[derive(Debug, Clone, PartialEq)]
pub enum YodannError {
    /// The builder was given no layers (and no network).
    NoLayers,
    /// The network has no convolution layers to accelerate.
    NoConvLayers {
        /// Network id.
        net: String,
    },
    /// The network's conv rows do not form a simple chain (e.g. AlexNet's
    /// parallel 11×11 split rows).
    NotASimpleChain {
        /// Network id.
        net: String,
        /// Label of the row where the chain breaks.
        layer: String,
        /// Channels the previous row produces.
        prev_out: usize,
        /// Channels this row declares as input.
        n_in: usize,
    },
    /// Kernel size outside the chip's supported 1..=7.
    UnsupportedKernel {
        /// Requested kernel size.
        k: usize,
    },
    /// The per-output-channel scale/bias arity does not match the kernel
    /// set.
    ScaleBiasArity {
        /// Scale/bias entries provided.
        alphas: usize,
        /// Output channels of the kernel set.
        n_out: usize,
    },
    /// A [`BatchNormThreshold`] node whose per-channel threshold arity
    /// does not match its source's channel count.
    ///
    /// [`BatchNormThreshold`]: crate::model::graph::GraphOp::BatchNormThreshold
    ThresholdArity {
        /// Threshold entries provided.
        thresholds: usize,
        /// Channels of the source feature map.
        channels: usize,
    },
    /// [`SessionBuilder::precision`](super::SessionBuilder::precision)
    /// supplied the wrong number of per-layer precision entries.
    PrecisionArity {
        /// Precision entries supplied.
        given: usize,
        /// Conv layers the network has.
        layers: usize,
    },
    /// Consecutive layers disagree on their channel count.
    ChannelChainMismatch {
        /// Channels the previous layer produces.
        prev_out: usize,
        /// Channels this layer declares as input.
        n_in: usize,
    },
    /// The chip's image memory cannot hold even one kernel window
    /// (`h_max < k`, the Eq. 9 capacity precondition).
    ChipCapacity {
        /// Kernel size.
        k: usize,
        /// Tile-height capacity of the configured image memory.
        h_max: usize,
        /// Configured image-memory rows.
        image_mem_rows: usize,
        /// Configured channel parallelism.
        n_ch: usize,
    },
    /// A valid-mode (non-padded) convolution over an image smaller than
    /// the kernel: there are no output pixels. Pre-redesign this was a
    /// debug panic / release `usize` wrap deep in the planner.
    NoOutputRows {
        /// Kernel size.
        k: usize,
        /// Which image axis is too small (`"height"` or `"width"`).
        axis: &'static str,
        /// Size of that axis when the offending layer runs.
        size: usize,
    },
    /// A frame with a zero dimension was submitted.
    EmptyFrame {
        /// Frame channels.
        c: usize,
        /// Frame height.
        h: usize,
        /// Frame width.
        w: usize,
    },
    /// The submitted frame's channel count does not match layer 1.
    FrameChannelMismatch {
        /// Channels the frame carries.
        got: usize,
        /// Channels the network's first layer expects.
        expected: usize,
    },
    /// An engine spelling [`EngineKind::parse`] does not accept.
    UnknownEngine {
        /// The rejected spelling.
        given: String,
    },
    /// A network id [`crate::model::networks::network`] does not know —
    /// the Display form echoes every accepted id (mirroring
    /// [`EngineKind::ACCEPTED`] for engines).
    UnknownNetwork {
        /// The rejected id.
        given: String,
    },
    /// A graph join node ([`Add`]/[`Concat`]) with fewer than two
    /// inputs — it joins nothing.
    ///
    /// [`Add`]: crate::model::graph::GraphOp::Add
    /// [`Concat`]: crate::model::graph::GraphOp::Concat
    GraphArity {
        /// Label of the offending node.
        node: String,
        /// The operation kind ("add" or "concat").
        op: &'static str,
        /// Inputs the node was given.
        inputs: usize,
    },
    /// A graph join node whose branches disagree on their channel count
    /// (residual [`Add`] needs identical channels on every input).
    ///
    /// [`Add`]: crate::model::graph::GraphOp::Add
    GraphChannelMismatch {
        /// Label of the offending node.
        node: String,
        /// Channels of the first branch.
        a: usize,
        /// Channels of the disagreeing branch.
        b: usize,
    },
    /// A graph join node whose branches disagree on their feature-map
    /// shape for the submitted frame (c, h, w).
    GraphShapeMismatch {
        /// Label of the offending node.
        node: String,
        /// Shape of the first branch.
        a: (usize, usize, usize),
        /// Shape of the disagreeing branch.
        b: (usize, usize, usize),
    },
    /// A graph node that is on no path to the output — built but never
    /// used, which is almost always a wiring mistake.
    GraphDisconnected {
        /// Label of the offending node.
        node: String,
    },
    /// [`SessionBuilder::weights`](super::SessionBuilder::weights)
    /// supplied the wrong number of per-layer weight sets.
    WeightsArity {
        /// Weight sets supplied.
        given: usize,
        /// Conv layers the network has.
        layers: usize,
    },
    /// A builder knob outside its valid range (zero workers, zero
    /// in-flight capacity, a supply voltage off the V–f curve, …).
    InvalidConfig {
        /// What was wrong, spelled out.
        what: String,
    },
    /// A supply voltage outside the fitted V–f curve's operating range —
    /// the hardware does not run there (SRAM fails below 0.8 V, standard
    /// cells below 0.6 V, §III-C). The typed sibling of the panicking
    /// [`VfCurve::freq`](crate::power::VfCurve::freq) boundary assert,
    /// returned by [`VfCurve::try_freq`](crate::power::VfCurve::try_freq)
    /// and by runtime corner swaps so a DVFS governor stepping the corner
    /// (or float accumulation at the boundary) cannot crash serving.
    SupplyOutOfRange {
        /// The requested supply (V).
        v: f64,
        /// Lowest valid supply (V).
        vmin: f64,
        /// Highest valid supply (V).
        vmax: f64,
    },
    /// Backpressure: the bounded in-flight queue is full. Wait on (or
    /// drop) an outstanding [`FrameTicket`](super::FrameTicket), then
    /// resubmit.
    Backpressure {
        /// Tickets currently in flight.
        in_flight: usize,
        /// The session's in-flight bound.
        limit: usize,
    },
    /// The session (or its dispatcher) is gone; the frame was not run.
    SessionClosed,
    /// A worker died computing this frame — an engine bug or a geometry
    /// hole the eager validation missed; the session survives and
    /// subsequent frames still run.
    Worker {
        /// The failed frame's ticket id.
        frame: u64,
        /// Best-effort panic payload.
        message: String,
    },
    /// A worker thread panicked (or was lost) computing this frame. The
    /// supervisor catches the unwind, fails *only this frame*, respawns
    /// the worker, and keeps serving subsequent frames.
    WorkerPanicked {
        /// The failed frame's ticket id (batch index on the deprecated
        /// session surface).
        frame: u64,
        /// The sharded conv layer that was running, if the loss happened
        /// mid-shard-reduction rather than in a whole-frame worker.
        layer: Option<usize>,
        /// Best-effort panic payload.
        message: String,
    },
    /// An injected (or real) memory fault was detected by checksum and
    /// persisted through the one repack retry the containment policy
    /// allows — the frame (or session build, for weight memory) is
    /// refused rather than silently corrupted.
    FaultDetected {
        /// The affected frame's ticket id; `None` for weight-memory
        /// faults caught at session build, before any frame exists.
        frame: Option<u64>,
        /// The 0-based conv layer whose memory failed verification.
        layer: usize,
        /// Which memory the fault lives in.
        site: FaultSite,
    },
    /// A [`FrameTicket::wait_timeout`](super::FrameTicket::wait_timeout)
    /// deadline elapsed before the frame finished. The frame is still in
    /// flight and the ticket stays redeemable.
    DeadlineExceeded {
        /// The ticket id of the frame that missed its deadline.
        frame: u64,
        /// The elapsed deadline, in milliseconds.
        timeout_ms: u64,
    },
    /// A layer-scoped error, tagged with the 0-based layer index.
    AtLayer {
        /// Layer index in the chain.
        layer: usize,
        /// The underlying error.
        inner: Box<YodannError>,
    },
    /// A graph-node-scoped error, tagged with the node's label (the
    /// graph analog of [`YodannError::AtLayer`]).
    AtNode {
        /// Label of the graph node.
        node: String,
        /// The underlying error.
        inner: Box<YodannError>,
    },
}

impl YodannError {
    /// Tag this error with the 0-based layer it occurred at.
    pub fn at_layer(self, layer: usize) -> YodannError {
        match self {
            // Re-tagging keeps the innermost error and the newest index.
            YodannError::AtLayer { inner, .. } => YodannError::AtLayer { layer, inner },
            other => YodannError::AtLayer { layer, inner: Box::new(other) },
        }
    }

    /// Tag this error with the graph node it occurred at.
    pub fn at_node(self, node: &str) -> YodannError {
        match self {
            // Re-tagging keeps the innermost error and the newest label.
            YodannError::AtNode { inner, .. } => {
                YodannError::AtNode { node: node.to_string(), inner }
            }
            other => YodannError::AtNode { node: node.to_string(), inner: Box::new(other) },
        }
    }

    /// Re-tag a per-frame error with the ticket id the caller knows it
    /// by (the session layer indexes frames by batch slot; the facade
    /// hands out monotonically increasing ticket ids).
    pub fn with_frame_id(self, id: u64) -> YodannError {
        match self {
            YodannError::Worker { message, .. } => YodannError::Worker { frame: id, message },
            YodannError::WorkerPanicked { layer, message, .. } => {
                YodannError::WorkerPanicked { frame: id, layer, message }
            }
            YodannError::FaultDetected { frame: Some(_), layer, site } => {
                YodannError::FaultDetected { frame: Some(id), layer, site }
            }
            YodannError::DeadlineExceeded { timeout_ms, .. } => {
                YodannError::DeadlineExceeded { frame: id, timeout_ms }
            }
            other => other,
        }
    }
}

impl std::fmt::Display for YodannError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            YodannError::NoLayers => {
                write!(f, "a session needs at least one layer (builder got none)")
            }
            YodannError::NoConvLayers { net } => {
                write!(f, "network '{net}' has no conv layers")
            }
            YodannError::NotASimpleChain { net, layer, prev_out, n_in } => write!(
                f,
                "network '{net}' is not a simple chain at layer '{layer}': previous output \
                 {prev_out} feeds declared input {n_in}"
            ),
            YodannError::UnsupportedKernel { k } => {
                write!(f, "kernel size {k} unsupported (1..=7)")
            }
            YodannError::ScaleBiasArity { alphas, n_out } => write!(
                f,
                "scale/bias arity mismatch: {alphas} entries for {n_out} output channels"
            ),
            YodannError::ThresholdArity { thresholds, channels } => write!(
                f,
                "threshold arity mismatch: {thresholds} entries for {channels} channels"
            ),
            YodannError::PrecisionArity { given, layers } => write!(
                f,
                "precision() supplied {given} per-layer entries for a network of {layers} conv \
                 layers"
            ),
            YodannError::ChannelChainMismatch { prev_out, n_in } => write!(
                f,
                "channel chain mismatch: previous layer outputs {prev_out} channels, this \
                 layer takes {n_in}"
            ),
            YodannError::ChipCapacity { k, h_max, image_mem_rows, n_ch } => write!(
                f,
                "h_max {h_max} cannot hold one {k}x{k} window (image memory of \
                 {image_mem_rows} rows / {n_ch} channels); Eq. 9 tiling requires h_max >= k"
            ),
            YodannError::NoOutputRows { k, axis, size } => write!(
                f,
                "valid-mode layer of {axis} {size} has no output rows for kernel {k}"
            ),
            YodannError::EmptyFrame { c, h, w } => {
                write!(f, "frame of {c}x{h}x{w} has no pixels")
            }
            YodannError::FrameChannelMismatch { got, expected } => write!(
                f,
                "frame has {got} channels, the network takes {expected}"
            ),
            YodannError::UnknownEngine { given } => write!(
                f,
                "unknown engine '{given}' (accepted: {})",
                EngineKind::ACCEPTED.join(", ")
            ),
            YodannError::UnknownNetwork { given } => write!(
                f,
                "unknown network '{given}' (accepted: {})",
                crate::model::networks::ACCEPTED.join(", ")
            ),
            YodannError::GraphArity { node, op, inputs } => write!(
                f,
                "graph node '{node}': {op} needs at least 2 inputs (got {inputs})"
            ),
            YodannError::GraphChannelMismatch { node, a, b } => write!(
                f,
                "graph node '{node}' joins branches of {a} and {b} channels"
            ),
            YodannError::GraphShapeMismatch { node, a, b } => write!(
                f,
                "graph node '{node}' joins branches of shape {}x{}x{} and {}x{}x{}",
                a.0, a.1, a.2, b.0, b.1, b.2
            ),
            YodannError::GraphDisconnected { node } => write!(
                f,
                "graph node '{node}' is on no path to the output"
            ),
            YodannError::WeightsArity { given, layers } => write!(
                f,
                "weights() supplied {given} layer weight sets for a network of {layers} conv \
                 layers"
            ),
            YodannError::InvalidConfig { what } => write!(f, "invalid configuration: {what}"),
            YodannError::SupplyOutOfRange { v, vmin, vmax } => write!(
                f,
                "supply {v} V outside operating range [{vmin}, {vmax}] V"
            ),
            YodannError::Backpressure { in_flight, limit } => write!(
                f,
                "in-flight queue full ({in_flight}/{limit}); wait on an outstanding ticket \
                 before resubmitting"
            ),
            YodannError::SessionClosed => write!(f, "session is shut down"),
            YodannError::Worker { frame, message } => {
                write!(f, "frame {frame} failed in a session worker: {message}")
            }
            // The two WorkerPanicked texts reproduce the pre-supervision
            // panic messages verbatim, so call sites that matched on the
            // panic text keep matching on the Display form.
            YodannError::WorkerPanicked { frame, layer: None, message } => {
                write!(f, "frame {frame} failed in a session worker: {message}")
            }
            YodannError::WorkerPanicked { frame, layer: Some(li), message } => {
                write!(f, "frame {frame}, sharded layer {li} failed in a session worker: {message}")
            }
            YodannError::FaultDetected { frame: Some(fr), layer, site } => write!(
                f,
                "frame {fr}: uncorrectable {site} fault at conv layer {layer} (detected by \
                 checksum, persisted through one repack retry)"
            ),
            YodannError::FaultDetected { frame: None, layer, site } => write!(
                f,
                "uncorrectable {site} fault in conv layer {layer}'s packed weights (detected \
                 at session build, persisted through one repack retry)"
            ),
            YodannError::DeadlineExceeded { frame, timeout_ms } => write!(
                f,
                "frame {frame} missed its {timeout_ms} ms deadline (still in flight; the \
                 ticket stays redeemable)"
            ),
            YodannError::AtLayer { layer, inner } => write!(f, "layer {layer}: {inner}"),
            YodannError::AtNode { node, inner } => write!(f, "node '{node}': {inner}"),
        }
    }
}

impl std::error::Error for YodannError {}

/// `?`-compatibility with the string-error call sites that remain (the
/// CLI's `Result<(), String>` commands).
impl From<YodannError> for String {
    fn from(e: YodannError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_the_historical_guard_phrases() {
        // The plan-geometry guards now panic with these Display texts;
        // the `should_panic(expected = ...)` pins in raster_props.rs
        // match on the same substrings.
        let e = YodannError::NoOutputRows { k: 5, axis: "height", size: 3 };
        assert!(e.to_string().contains("no output rows"), "{e}");
        let e = YodannError::ChipCapacity { k: 7, h_max: 4, image_mem_rows: 16, n_ch: 4 };
        assert!(e.to_string().contains("h_max"), "{e}");
        let e = YodannError::UnsupportedKernel { k: 9 };
        assert!(e.to_string().contains("unsupported (1..=7)"), "{e}");
    }

    #[test]
    fn at_layer_tags_and_retags() {
        let e = YodannError::UnsupportedKernel { k: 0 }.at_layer(3);
        assert_eq!(e.to_string(), "layer 3: kernel size 0 unsupported (1..=7)");
        // Re-tagging replaces the index instead of nesting.
        let e2 = e.at_layer(5);
        assert!(matches!(&e2, YodannError::AtLayer { layer: 5, inner }
            if matches!(**inner, YodannError::UnsupportedKernel { k: 0 })));
    }

    #[test]
    fn unknown_engine_lists_the_accepted_spellings() {
        let e = YodannError::UnknownEngine { given: "Quantum".into() };
        let msg = e.to_string();
        for &name in EngineKind::ACCEPTED {
            assert!(msg.contains(name), "'{name}' missing from: {msg}");
        }
    }

    #[test]
    fn unknown_network_lists_the_accepted_ids() {
        let e = YodannError::UnknownNetwork { given: "lenet".into() };
        let msg = e.to_string();
        for &id in crate::model::networks::ACCEPTED {
            assert!(msg.contains(id), "'{id}' missing from: {msg}");
        }
    }

    #[test]
    fn at_node_tags_and_retags() {
        let e = YodannError::GraphChannelMismatch { node: "add1".into(), a: 64, b: 128 };
        assert!(e.to_string().contains("64 and 128 channels"), "{e}");
        let e = YodannError::UnsupportedKernel { k: 9 }.at_node("conv1");
        assert_eq!(e.to_string(), "node 'conv1': kernel size 9 unsupported (1..=7)");
        let e2 = e.at_node("conv2");
        assert!(matches!(&e2, YodannError::AtNode { node, inner }
            if node == "conv2" && matches!(**inner, YodannError::UnsupportedKernel { k: 9 })));
    }

    #[test]
    fn string_conversion_matches_display() {
        let e = YodannError::SessionClosed;
        let s: String = e.clone().into();
        assert_eq!(s, e.to_string());
    }

    #[test]
    fn worker_panicked_keeps_the_historical_panic_texts() {
        // The deprecated run_frame/run_batch shims re-panic with these
        // Display forms, so pre-supervision panic-text matches survive.
        let e = YodannError::WorkerPanicked { frame: 2, layer: None, message: "boom".into() };
        assert_eq!(e.to_string(), "frame 2 failed in a session worker: boom");
        let e = YodannError::WorkerPanicked { frame: 2, layer: Some(1), message: "boom".into() };
        assert_eq!(e.to_string(), "frame 2, sharded layer 1 failed in a session worker: boom");
    }

    #[test]
    fn with_frame_id_retags_per_frame_variants_only() {
        let e = YodannError::WorkerPanicked { frame: 0, layer: Some(3), message: "x".into() }
            .with_frame_id(41);
        assert!(matches!(e, YodannError::WorkerPanicked { frame: 41, layer: Some(3), .. }));
        let e = YodannError::FaultDetected {
            frame: Some(0),
            layer: 1,
            site: FaultSite::ImageMemory,
        }
        .with_frame_id(41);
        assert!(matches!(e, YodannError::FaultDetected { frame: Some(41), .. }));
        // Build-time weight faults have no frame and stay that way.
        let e = YodannError::FaultDetected { frame: None, layer: 1, site: FaultSite::WeightMemory }
            .with_frame_id(41);
        assert!(matches!(e, YodannError::FaultDetected { frame: None, .. }));
        assert!(e.to_string().contains("weight-memory"), "{e}");
        let e = YodannError::SessionClosed.with_frame_id(41);
        assert!(matches!(e, YodannError::SessionClosed));
    }
}
