//! Minimal benchmarking harness (stand-in for `criterion`, unavailable in
//! this image's offline registry).
//!
//! Each `rust/benches/*.rs` target (built with `harness = false`) uses
//! [`Bencher`] to time closures with warm-up, fixed sample counts and
//! mean/median/σ reporting, and uses [`black_box`] to defeat
//! constant-folding. The bench binaries also *print the reproduced paper
//! tables/figures* — timing the generation and regenerating the artifact in
//! one target, as DESIGN.md §4 specifies.

use std::time::{Duration, Instant};

/// Re-export of the compiler fence that prevents the optimizer from
/// deleting benchmarked work.
pub use std::hint::black_box;

/// Result statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Number of measured samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Median time per iteration.
    pub median: Duration,
    /// Standard deviation across samples (per-iteration).
    pub stddev: Duration,
    /// Min / max per-iteration times.
    pub min: Duration,
    /// Max per-iteration time.
    pub max: Duration,
}

impl Stats {
    /// Throughput in "units per second" given the number of logical units
    /// (e.g. simulated cycles) performed per iteration.
    pub fn per_second(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>12?}  median {:>12?}  σ {:>10?}  (n={}, {} it/sample)",
            self.name, self.mean, self.median, self.stddev, self.samples, self.iters_per_sample
        )
    }
}

/// Benchmark runner with warm-up and automatic iteration calibration.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure_time: Duration,
    /// Warm-up time before sampling.
    pub warmup_time: Duration,
    /// Number of samples to split the measurement into.
    pub samples: usize,
    collected: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_secs(1),
            warmup_time: Duration::from_millis(300),
            samples: 20,
            collected: Vec::new(),
        }
    }
}

impl Bencher {
    /// A bencher honouring `YODANN_BENCH_FAST=1` (used by `make test` to
    /// smoke the bench targets quickly).
    pub fn from_env() -> Self {
        let mut b = Bencher::default();
        if std::env::var("YODANN_BENCH_FAST").is_ok_and(|v| v == "1") {
            b.measure_time = Duration::from_millis(100);
            b.warmup_time = Duration::from_millis(20);
            b.samples = 5;
        }
        b
    }

    /// Time `f`, returning per-iteration statistics and recording them.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warm-up and calibration: find iters such that one sample takes
        // roughly measure_time / samples.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_time || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let sample_budget = self.measure_time.as_secs_f64() / self.samples as f64;
        let iters = ((sample_budget / per_iter).ceil() as u64).max(1);

        let mut sample_means: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_means.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        sample_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sample_means.iter().sum::<f64>() / sample_means.len() as f64;
        let median = sample_means[sample_means.len() / 2];
        let var = sample_means.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / sample_means.len() as f64;
        let stats = Stats {
            name: name.to_string(),
            samples: self.samples,
            iters_per_sample: iters,
            mean: Duration::from_secs_f64(mean),
            median: Duration::from_secs_f64(median),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(sample_means[0]),
            max: Duration::from_secs_f64(*sample_means.last().unwrap()),
        };
        println!("{stats}");
        self.collected.push(stats.clone());
        stats
    }

    /// All statistics collected so far.
    pub fn results(&self) -> &[Stats] {
        &self.collected
    }
}

/// One machine-readable benchmark record: `name`, `ns_per_iter`, and an
/// optional throughput figure (`frames_per_s` — null when the benchmark
/// has no frame notion).
#[derive(Debug, Clone)]
pub struct JsonRecord {
    /// Benchmark name.
    pub name: String,
    /// Mean nanoseconds per iteration.
    pub ns_per_iter: f64,
    /// Frames per second, when the benchmark processes frames.
    pub frames_per_s: Option<f64>,
}

impl JsonRecord {
    /// Record from a [`Stats`] result.
    pub fn from_stats(s: &Stats) -> JsonRecord {
        JsonRecord { name: s.name.clone(), ns_per_iter: s.mean.as_secs_f64() * 1e9, frames_per_s: None }
    }

    /// Record from a [`Stats`] result that processes `frames` frames per
    /// iteration.
    pub fn with_frames(s: &Stats, frames: f64) -> JsonRecord {
        JsonRecord {
            name: s.name.clone(),
            ns_per_iter: s.mean.as_secs_f64() * 1e9,
            frames_per_s: Some(frames / s.mean.as_secs_f64()),
        }
    }

    /// A derived speedup/ratio record (`speedup/...` convention): no
    /// ns/iter of its own, the ratio rides in `frames_per_s`.
    pub fn ratio(name: &str, ratio: f64) -> JsonRecord {
        JsonRecord { name: name.to_string(), ns_per_iter: 0.0, frames_per_s: Some(ratio) }
    }
}

/// Integrity gate for bench JSON emission: a record set about to be
/// written must contain real measurements — no empty sets, no
/// non-finite or zero timings, no bogus throughput figures. Returns the
/// first problem found so the bench target can **fail loudly** instead
/// of silently committing a placeholder `BENCH_*.json`.
pub fn validate_records(records: &[JsonRecord]) -> Result<(), String> {
    if records.is_empty() {
        return Err("no benchmark records collected — refusing to write an empty file".into());
    }
    for r in records {
        if r.name.trim().is_empty() {
            return Err("a record has an empty name".into());
        }
        if r.ns_per_iter == 0.0 {
            // Ratio records (`speedup/...`, `shard-scaling/...`) carry
            // their value in frames_per_s and no timing of their own.
            match r.frames_per_s {
                Some(v) if v.is_finite() && v > 0.0 => {}
                _ => {
                    return Err(format!(
                        "record '{}' has neither a timing nor a finite ratio — placeholder?",
                        r.name
                    ))
                }
            }
        } else if !r.ns_per_iter.is_finite() || r.ns_per_iter < 0.0 {
            return Err(format!(
                "record '{}' has a bogus ns_per_iter of {}",
                r.name, r.ns_per_iter
            ));
        } else if let Some(f) = r.frames_per_s {
            if !f.is_finite() || f <= 0.0 {
                return Err(format!("record '{}' has a bogus frames_per_s of {f}", r.name));
            }
        }
    }
    Ok(())
}

/// [`emit_json`] behind the [`validate_records`] integrity gate: bench
/// targets that feed checked-in evidence files use this so a broken run
/// exits non-zero rather than overwriting good numbers with placeholder
/// records.
pub fn emit_json_strict(path: &str, suite: &str, records: &[JsonRecord]) -> std::io::Result<()> {
    validate_records(records)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
    emit_json(path, suite, records)
}

/// Extract the value of `"key": value` from one emitted record line.
fn json_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find([',', '}'])?;
    Some(rest[..end].trim())
}

/// Parse records back out of a file previously written by [`emit_json`]
/// (one record object per line — this reads our own format, not general
/// JSON; names containing commas or braces do not round-trip). Malformed
/// lines and placeholder files without records parse to nothing.
pub fn parse_records(text: &str) -> Vec<JsonRecord> {
    let mut out = Vec::new();
    for line in text.lines() {
        let t = line.trim();
        if !t.starts_with("{\"name\"") {
            continue;
        }
        let Some(name) = json_field(t, "name") else { continue };
        let name = name.trim_matches('"').to_string();
        let Some(ns) = json_field(t, "ns_per_iter").and_then(|v| v.parse::<f64>().ok()) else {
            continue;
        };
        let frames_per_s = match json_field(t, "frames_per_s") {
            Some("null") | None => None,
            Some(v) => v.parse::<f64>().ok(),
        };
        out.push(JsonRecord { name, ns_per_iter: ns, frames_per_s });
    }
    out
}

/// Merge `records` into the bench JSON at `path`: same-name records are
/// replaced, new ones appended, everything else preserved, and the file
/// rewritten in [`emit_json`]'s format. A missing or placeholder file
/// starts empty. Returns the total record count written. This is how
/// `yodann throughput --shards` lands its shard-scaling record in
/// `BENCH_engines.json` without clobbering the bench-emitted records.
pub fn merge_json(path: &str, suite: &str, records: &[JsonRecord]) -> std::io::Result<usize> {
    let mut all = match std::fs::read_to_string(path) {
        Ok(text) => parse_records(&text),
        Err(_) => Vec::new(),
    };
    for r in records {
        match all.iter_mut().find(|e| e.name == r.name) {
            Some(e) => *e = r.clone(),
            None => all.push(r.clone()),
        }
    }
    emit_json(path, suite, &all)?;
    Ok(all.len())
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Write benchmark records as a machine-readable JSON file (hand-rolled:
/// the offline registry has no serde), so the perf trajectory across PRs
/// is trackable — e.g. `BENCH_engines.json` from `cargo bench --bench
/// engines`.
pub fn emit_json(path: &str, suite: &str, records: &[JsonRecord]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(suite)));
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        let frames = match r.frames_per_s {
            Some(f) => format!("{f:.3}"),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"ns_per_iter\": {:.1}, \"frames_per_s\": {}}}{}\n",
            json_escape(&r.name),
            r.ns_per_iter,
            frames,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            samples: 4,
            collected: Vec::new(),
        };
        let s = b.bench("noop-ish", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(s.mean > Duration::ZERO);
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_emission_roundtrips_structure() {
        let records = vec![
            JsonRecord { name: "a/b".into(), ns_per_iter: 1234.5, frames_per_s: None },
            JsonRecord { name: "c\"d".into(), ns_per_iter: 7.0, frames_per_s: Some(62.5) },
        ];
        let path = std::env::temp_dir().join("yodann_bench_emit_test.json");
        emit_json(path.to_str().unwrap(), "unit-test", &records).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.contains("\"suite\": \"unit-test\""));
        assert!(text.contains("\"name\": \"a/b\""));
        assert!(text.contains("\"frames_per_s\": null"));
        assert!(text.contains("\"frames_per_s\": 62.500"));
        assert!(text.contains("c\\\"d"));
        // Exactly one trailing comma between the two records.
        assert_eq!(text.matches("}},").count() + text.matches("},\n").count(), 1);
    }

    #[test]
    fn validation_rejects_placeholders_and_accepts_real_records() {
        assert!(validate_records(&[]).is_err(), "empty sets must fail loudly");
        let good = vec![
            JsonRecord { name: "cycle/k7".into(), ns_per_iter: 120.0, frames_per_s: None },
            JsonRecord::ratio("speedup/x", 3.5),
            JsonRecord { name: "session/f".into(), ns_per_iter: 9.0, frames_per_s: Some(44.0) },
        ];
        assert!(validate_records(&good).is_ok());
        for bad in [
            JsonRecord { name: "".into(), ns_per_iter: 1.0, frames_per_s: None },
            JsonRecord { name: "nan".into(), ns_per_iter: f64::NAN, frames_per_s: None },
            JsonRecord { name: "zero".into(), ns_per_iter: 0.0, frames_per_s: None },
            JsonRecord::ratio("bad-ratio", 0.0),
            JsonRecord { name: "inf-fps".into(), ns_per_iter: 5.0, frames_per_s: Some(f64::INFINITY) },
        ] {
            let mut set = good.clone();
            let label = bad.name.clone();
            set.push(bad);
            assert!(validate_records(&set).is_err(), "{label} accepted");
        }
        let path = std::env::temp_dir().join("yodann_bench_strict_test.json");
        assert!(emit_json_strict(path.to_str().unwrap(), "unit-test", &[]).is_err());
        assert!(!path.exists(), "strict emission must not touch the file on failure");
        emit_json_strict(path.to_str().unwrap(), "unit-test", &good).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_json_roundtrips_and_replaces_by_name() {
        let path = std::env::temp_dir().join("yodann_bench_merge_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        let first = vec![
            JsonRecord { name: "a/b".into(), ns_per_iter: 100.0, frames_per_s: None },
            JsonRecord { name: "sess".into(), ns_per_iter: 50.0, frames_per_s: Some(20.0) },
        ];
        assert_eq!(merge_json(path, "engines", &first).unwrap(), 2);
        // Parse-back fidelity on our own format.
        let parsed = parse_records(&std::fs::read_to_string(path).unwrap());
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].name, "a/b");
        assert!((parsed[0].ns_per_iter - 100.0).abs() < 0.1);
        assert_eq!(parsed[0].frames_per_s, None);
        assert!((parsed[1].frames_per_s.unwrap() - 20.0).abs() < 0.01);
        // Merge: one replacement, one addition.
        let update = vec![
            JsonRecord { name: "sess".into(), ns_per_iter: 40.0, frames_per_s: Some(25.0) },
            JsonRecord::ratio("shard-scaling/2x1", 1.8),
        ];
        assert_eq!(merge_json(path, "engines", &update).unwrap(), 3);
        let merged = parse_records(&std::fs::read_to_string(path).unwrap());
        assert_eq!(merged.len(), 3);
        let sess = merged.iter().find(|r| r.name == "sess").unwrap();
        assert!((sess.frames_per_s.unwrap() - 25.0).abs() < 0.01);
        assert!(merged.iter().any(|r| r.name == "shard-scaling/2x1"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn parse_records_survives_the_checked_in_placeholder_shape() {
        // The pre-measurement placeholder has a note field and an empty
        // records array; merging into it must start from zero records.
        let placeholder = "{\n  \"suite\": \"engines\",\n  \"note\": \"placeholder\",\n  \
                           \"records\": []\n}\n";
        assert!(parse_records(placeholder).is_empty());
    }
}
