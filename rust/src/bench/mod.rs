//! Minimal benchmarking harness (stand-in for `criterion`, unavailable in
//! this image's offline registry).
//!
//! Each `rust/benches/*.rs` target (built with `harness = false`) uses
//! [`Bencher`] to time closures with warm-up, fixed sample counts and
//! mean/median/σ reporting, and uses [`black_box`] to defeat
//! constant-folding. The bench binaries also *print the reproduced paper
//! tables/figures* — timing the generation and regenerating the artifact in
//! one target, as DESIGN.md §4 specifies.

use std::time::{Duration, Instant};

/// Re-export of the compiler fence that prevents the optimizer from
/// deleting benchmarked work.
pub use std::hint::black_box;

/// Result statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Number of measured samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Median time per iteration.
    pub median: Duration,
    /// Standard deviation across samples (per-iteration).
    pub stddev: Duration,
    /// Min / max per-iteration times.
    pub min: Duration,
    /// Max per-iteration time.
    pub max: Duration,
}

impl Stats {
    /// Throughput in "units per second" given the number of logical units
    /// (e.g. simulated cycles) performed per iteration.
    pub fn per_second(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>12?}  median {:>12?}  σ {:>10?}  (n={}, {} it/sample)",
            self.name, self.mean, self.median, self.stddev, self.samples, self.iters_per_sample
        )
    }
}

/// Benchmark runner with warm-up and automatic iteration calibration.
pub struct Bencher {
    /// Target measurement time per benchmark.
    pub measure_time: Duration,
    /// Warm-up time before sampling.
    pub warmup_time: Duration,
    /// Number of samples to split the measurement into.
    pub samples: usize,
    collected: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_secs(1),
            warmup_time: Duration::from_millis(300),
            samples: 20,
            collected: Vec::new(),
        }
    }
}

impl Bencher {
    /// A bencher honouring `YODANN_BENCH_FAST=1` (used by `make test` to
    /// smoke the bench targets quickly).
    pub fn from_env() -> Self {
        let mut b = Bencher::default();
        if std::env::var("YODANN_BENCH_FAST").is_ok_and(|v| v == "1") {
            b.measure_time = Duration::from_millis(100);
            b.warmup_time = Duration::from_millis(20);
            b.samples = 5;
        }
        b
    }

    /// Time `f`, returning per-iteration statistics and recording them.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warm-up and calibration: find iters such that one sample takes
        // roughly measure_time / samples.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup_time || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let sample_budget = self.measure_time.as_secs_f64() / self.samples as f64;
        let iters = ((sample_budget / per_iter).ceil() as u64).max(1);

        let mut sample_means: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            sample_means.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        sample_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sample_means.iter().sum::<f64>() / sample_means.len() as f64;
        let median = sample_means[sample_means.len() / 2];
        let var = sample_means.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / sample_means.len() as f64;
        let stats = Stats {
            name: name.to_string(),
            samples: self.samples,
            iters_per_sample: iters,
            mean: Duration::from_secs_f64(mean),
            median: Duration::from_secs_f64(median),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(sample_means[0]),
            max: Duration::from_secs_f64(*sample_means.last().unwrap()),
        };
        println!("{stats}");
        self.collected.push(stats.clone());
        stats
    }

    /// All statistics collected so far.
    pub fn results(&self) -> &[Stats] {
        &self.collected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            samples: 4,
            collected: Vec::new(),
        };
        let s = b.bench("noop-ish", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(s.mean > Duration::ZERO);
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(b.results().len(), 1);
    }
}
