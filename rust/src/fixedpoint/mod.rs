//! Signed fixed-point arithmetic exactly as implemented by the YodaNN
//! datapath.
//!
//! The paper's number formats (§III-E):
//!
//! * **Q2.9** — 12-bit activations, weights-scale (α) and bias (β):
//!   1 sign + 2 integer + 9 fractional bits.
//! * **Q7.9** — 17-bit ChannelSummer accumulators: 1 + 7 + 9.
//! * **Q10.18** — 29-bit scale product (Q7.9 × Q2.9): 1 + 10 + 18, which is
//!   finally "resized with saturation and truncation to the initial Q2.9
//!   format".
//!
//! All values are carried as **raw two's-complement integers** (`i64`) next
//! to a [`QFormat`] descriptor; a raw value `r` in format Qi.f represents
//! the real number `r / 2^f`. Truncation is an arithmetic right shift
//! (floor), saturation clamps to the representable range — both exactly as
//! synthesized hardware behaves. This module is the single source of truth
//! for rounding/saturation semantics; the cycle simulator, the analytic
//! model and the JAX golden model (python/compile/kernels) all follow it.

/// A signed fixed-point format: 1 sign bit + `int_bits` + `frac_bits`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits (excluding the sign bit).
    pub int_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

/// 12-bit activation / scale / bias format (Q2.9).
pub const Q2_9: QFormat = QFormat { int_bits: 2, frac_bits: 9 };
/// 17-bit ChannelSummer accumulator format (Q7.9).
pub const Q7_9: QFormat = QFormat { int_bits: 7, frac_bits: 9 };
/// 29-bit scale-product format (Q10.18).
pub const Q10_18: QFormat = QFormat { int_bits: 10, frac_bits: 18 };

impl QFormat {
    /// Total storage width in bits, including the sign bit.
    pub const fn total_bits(self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Largest representable raw value: `2^(int+frac) − 1`.
    pub const fn max_raw(self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    /// Smallest representable raw value: `−2^(int+frac)`.
    pub const fn min_raw(self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// Value of one LSB.
    pub fn lsb(self) -> f64 {
        (self.frac_bits as f64).exp2().recip()
    }

    /// Clamp a raw value into this format's representable range.
    pub const fn saturate(self, raw: i64) -> i64 {
        let hi = self.max_raw();
        let lo = self.min_raw();
        if raw > hi {
            hi
        } else if raw < lo {
            lo
        } else {
            raw
        }
    }

    /// True if `raw` is representable without saturation.
    #[allow(clippy::manual_range_contains)] // RangeInclusive::contains is not const
    pub const fn contains(self, raw: i64) -> bool {
        raw >= self.min_raw() && raw <= self.max_raw()
    }

    /// Convert a real number to the nearest representable raw value
    /// (round-to-nearest, saturating). Used when *quantizing inputs*,
    /// e.g. images entering the accelerator.
    pub fn from_f64(self, x: f64) -> i64 {
        let scaled = x * (self.frac_bits as f64).exp2();
        self.saturate(scaled.round_ties_even() as i64)
    }

    /// Real value represented by `raw`.
    pub fn to_f64(self, raw: i64) -> f64 {
        raw as f64 / (self.frac_bits as f64).exp2()
    }

    /// Quantize a real number onto this format's grid (through
    /// [`Self::from_f64`] and back).
    pub fn quantize(self, x: f64) -> f64 {
        self.to_f64(self.from_f64(x))
    }
}

/// Saturating addition in format `fmt` (hardware accumulator register).
pub const fn sat_add(fmt: QFormat, a: i64, b: i64) -> i64 {
    fmt.saturate(a + b)
}

/// Exact product of two raw values. The result format is
/// `Q(ia+ib+1).(fa+fb)`: multiplying two two's-complement numbers of widths
/// `wa`, `wb` needs `wa+wb−1` bits except for `min×min`, hence the `+1`
/// guard integer bit — identical to the paper's Q7.9 × Q2.9 → Q10.18.
pub const fn mul(a_fmt: QFormat, a: i64, b_fmt: QFormat, b: i64) -> (QFormat, i64) {
    let fmt = QFormat {
        int_bits: a_fmt.int_bits + b_fmt.int_bits + 1,
        frac_bits: a_fmt.frac_bits + b_fmt.frac_bits,
    };
    (fmt, a * b)
}

/// Re-align a raw value from `from` to `to` fractional bits with hardware
/// semantics: left shifts are exact, right shifts **truncate** (arithmetic
/// shift, i.e. round toward −∞), and the result **saturates** to `to`.
///
/// This is the paper's "resized with saturation and truncation" step
/// (Q10.18 → Q2.9).
pub const fn resize(from: QFormat, raw: i64, to: QFormat) -> i64 {
    let aligned = if to.frac_bits >= from.frac_bits {
        raw << (to.frac_bits - from.frac_bits)
    } else {
        raw >> (from.frac_bits - to.frac_bits)
    };
    to.saturate(aligned)
}

/// The exact Scale-Bias datapath of §III-E:
/// `out = resize_Q2.9( acc_Q7.9 × α_Q2.9  +  β_Q2.9 aligned to .18 )`.
///
/// * `acc` — ChannelSummer output, raw Q7.9;
/// * `alpha` — per-channel scale, raw Q2.9;
/// * `beta` — per-channel bias, raw Q2.9.
///
/// Returns the streamed-out raw Q2.9 pixel.
pub const fn scale_bias(acc_q79: i64, alpha_q29: i64, beta_q29: i64) -> i64 {
    // Q7.9 × Q2.9 → Q10.18 (exact, 29 bits).
    let (prod_fmt, prod) = mul(Q7_9, acc_q79, Q2_9, alpha_q29);
    // Align the Q2.9 bias to 18 fractional bits and add. The sum still fits
    // the Q10.18 accumulator comfortably (|prod| < 2^28, |bias<<9| < 2^20),
    // but we saturate defensively, like the RTL adder would wrap-protect.
    let sum = Q10_18.saturate(prod + (beta_q29 << 9));
    debug_assert!(prod_fmt.frac_bits == 18);
    // Truncate + saturate to Q2.9.
    resize(Q10_18, sum, Q2_9)
}

/// A binary weight, the paper's w ∈ {−1, +1} remapped to one bit
/// (Eq. 5: −1 ↦ 0, +1 ↦ 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinWeight {
    /// w = −1 (stored as bit 0).
    Minus,
    /// w = +1 (stored as bit 1).
    Plus,
}

impl BinWeight {
    /// Decode from the stored bit.
    pub const fn from_bit(bit: bool) -> Self {
        if bit {
            BinWeight::Plus
        } else {
            BinWeight::Minus
        }
    }

    /// The stored bit (Eq. 5).
    pub const fn bit(self) -> bool {
        matches!(self, BinWeight::Plus)
    }

    /// The weight value as an integer (−1 or +1).
    pub const fn value(self) -> i64 {
        match self {
            BinWeight::Minus => -1,
            BinWeight::Plus => 1,
        }
    }

    /// The SoP "multiplier": a two's-complement-and-multiplex unit —
    /// passes `x` for +1, negates it for −1. No multiplier involved,
    /// which is the core trick of the paper.
    pub const fn apply(self, x: i64) -> i64 {
        match self {
            BinWeight::Minus => -x,
            BinWeight::Plus => x,
        }
    }
}

/// Deterministic BinaryConnect binarization (paper §II-A):
/// `w_b = +1 if w_fp ≥ 0 else −1`.
///
/// (The paper's printed formula has the cases swapped — an obvious typo;
/// BinaryConnect [22] defines sign binarization as implemented here.)
pub fn binarize_det(w_fp: f64) -> BinWeight {
    if w_fp >= 0.0 {
        BinWeight::Plus
    } else {
        BinWeight::Minus
    }
}

/// Stochastic BinaryConnect binarization: P(w=+1) = σ(w_fp) with the "hard
/// sigmoid" σ(x) = clip((x+1)/2, 0, 1). `u` must be uniform in [0, 1).
pub fn binarize_sto(w_fp: f64, u: f64) -> BinWeight {
    let sigma = ((w_fp + 1.0) / 2.0).clamp(0.0, 1.0);
    if u < sigma {
        BinWeight::Plus
    } else {
        BinWeight::Minus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q29_range() {
        assert_eq!(Q2_9.total_bits(), 12);
        assert_eq!(Q2_9.max_raw(), 2047);
        assert_eq!(Q2_9.min_raw(), -2048);
        assert!((Q2_9.to_f64(Q2_9.max_raw()) - 3.998_046_875).abs() < 1e-12);
        assert_eq!(Q2_9.to_f64(Q2_9.min_raw()), -4.0);
    }

    #[test]
    fn q79_and_q1018_widths() {
        assert_eq!(Q7_9.total_bits(), 17);
        assert_eq!(Q10_18.total_bits(), 29);
        // Q7.9 × Q2.9 must produce exactly Q10.18 per the paper.
        let (fmt, _) = mul(Q7_9, 1, Q2_9, 1);
        assert_eq!(fmt, Q10_18);
    }

    #[test]
    fn saturation_clamps_both_sides() {
        assert_eq!(Q2_9.saturate(5000), 2047);
        assert_eq!(Q2_9.saturate(-5000), -2048);
        assert_eq!(Q2_9.saturate(123), 123);
    }

    #[test]
    fn from_f64_rounds_and_saturates() {
        assert_eq!(Q2_9.from_f64(1.0), 512);
        assert_eq!(Q2_9.from_f64(-1.0), -512);
        assert_eq!(Q2_9.from_f64(100.0), 2047);
        assert_eq!(Q2_9.from_f64(-100.0), -2048);
        // round-to-nearest-even at the 0.5 LSB boundary
        assert_eq!(Q2_9.from_f64(1.5 / 512.0), 2);
        assert_eq!(Q2_9.from_f64(2.5 / 512.0), 2);
    }

    #[test]
    fn resize_truncates_toward_neg_inf() {
        // +2.75 LSB(Q2.9) expressed in Q10.18 → truncates to +2 LSB
        let raw_1018 = (2 << 9) + 384; // 2.75 * 2^9 ulp at .18
        assert_eq!(resize(Q10_18, raw_1018, Q2_9), 2);
        // −2.75 → −3 (arithmetic shift floors)
        assert_eq!(resize(Q10_18, -raw_1018, Q2_9), -3);
    }

    #[test]
    fn scale_bias_identity() {
        // α = 1.0 (raw 512), β = 0: acc Q7.9 value should pass through
        // unchanged when in Q2.9 range.
        for acc in [-1024i64, -3, 0, 5, 700, 2047] {
            assert_eq!(scale_bias(acc, 512, 0), acc);
        }
        // Out-of-range accumulator saturates to Q2.9.
        assert_eq!(scale_bias(40_000, 512, 0), 2047);
        assert_eq!(scale_bias(-40_000, 512, 0), -2048);
    }

    #[test]
    fn scale_bias_matches_reference_math() {
        // acc = 1.5 (raw 768), α = 0.5 (raw 256), β = −0.25 (raw −128)
        // → 1.5·0.5 − 0.25 = 0.5 → raw 256.
        assert_eq!(scale_bias(768, 256, -128), 256);
    }

    #[test]
    fn binweight_mapping() {
        assert_eq!(BinWeight::from_bit(true).value(), 1);
        assert_eq!(BinWeight::from_bit(false).value(), -1);
        assert_eq!(BinWeight::Plus.apply(-7), -7);
        assert_eq!(BinWeight::Minus.apply(-7), 7);
        assert!(binarize_det(0.0).bit());
        assert!(!binarize_det(-1e-9).bit());
    }

    #[test]
    fn stochastic_binarization_is_hard_sigmoid() {
        // w = 1 → σ = 1 → always +1; w = −1 → σ = 0 → always −1.
        for u in [0.0, 0.3, 0.999] {
            assert!(binarize_sto(1.0, u).bit());
            assert!(!binarize_sto(-1.0, u).bit());
        }
        // w = 0 → σ = 0.5.
        assert!(binarize_sto(0.0, 0.49).bit());
        assert!(!binarize_sto(0.0, 0.51).bit());
    }
}
