//! Data series regenerating the paper's figures (printed as tables /
//! CSV-like series — this repo has no plotting dependencies).

use super::paper;
use super::soa;
use crate::model::networks;
use crate::power::{area_breakdown, metric_area_mge, ArchId, CorePowerModel, PowerBreakdown};

/// Fig. 2 — execution-time share of convolution layers vs other layers for
/// the scene-labeling CNN of [13], CPU vs GPU.
#[derive(Debug, Clone)]
pub struct Fig2 {
    /// Convolution operations per frame (Eq. 7).
    pub conv_ops: u64,
    /// Non-convolution operations (activation, pooling, dense).
    pub other_ops: u64,
    /// Convolution share of *operations*.
    pub conv_op_share: f64,
    /// Measured convolution share of *time* on CPU ([13], the paper's bar).
    pub cpu_conv_time_share: f64,
    /// Measured convolution share of time on GPU.
    pub gpu_conv_time_share: f64,
    /// Implied per-op slowdown of non-conv layers on CPU (memory-bound).
    pub cpu_other_slowdown: f64,
    /// Implied per-op slowdown on GPU.
    pub gpu_other_slowdown: f64,
}

/// Compute Fig. 2 from the scene-labeling network's op counts plus the
/// measured time shares of [13]. The interesting quantitative content is
/// that convolutions are >99.9% of operations yet only ~80–90% of time —
/// i.e. non-conv layers are orders of magnitude less efficient, which is
/// why an accelerator may focus on convolution (§III).
pub fn fig2() -> Fig2 {
    let net = networks::scene_labeling();
    let conv_ops = net.conv_ops();
    // Non-conv ops: one ReLU per conv output pixel, 2×2 max-pool (3
    // compares per output) after each stage, dense classifier.
    let mut other_ops: u64 = 0;
    for c in net.conv_layers() {
        let outputs = (c.n_out * c.out_h() * c.out_w()) as u64;
        other_ops += outputs; // ReLU
        other_ops += (outputs / 4) * 3; // 2×2 max-pool compares
    }
    for l in &net.layers {
        if let crate::model::Layer::Dense(d) = l {
            other_ops += d.ops();
        }
    }
    let conv_op_share = conv_ops as f64 / (conv_ops + other_ops) as f64;
    let slowdown = |time_share: f64| {
        // t_conv/t_other = share/(1-share); ops ratio known ⇒ per-op ratio.
        let time_ratio = (1.0 - time_share) / time_share;
        time_ratio * conv_ops as f64 / other_ops as f64
    };
    Fig2 {
        conv_ops,
        other_ops,
        conv_op_share,
        cpu_conv_time_share: paper::fig2::CPU_CONV_SHARE,
        gpu_conv_time_share: paper::fig2::GPU_CONV_SHARE,
        cpu_other_slowdown: slowdown(paper::fig2::CPU_CONV_SHARE),
        gpu_other_slowdown: slowdown(paper::fig2::GPU_CONV_SHARE),
    }
}

/// Fig. 6 — area breakdown per architecture (kGE).
pub fn fig6() -> Vec<(ArchId, crate::power::AreaBreakdown)> {
    ArchId::all().iter().map(|&a| (a, area_breakdown(a))).collect()
}

/// One Fig. 11 sweep point.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    /// Core supply (V).
    pub v: f64,
    /// Clock (MHz).
    pub f_mhz: f64,
    /// Peak throughput (GOp/s).
    pub theta_gops: f64,
    /// Core energy efficiency (TOp/s/W).
    pub en_eff_tops_w: f64,
}

/// Fig. 11 — voltage sweep of throughput and core energy efficiency for
/// one architecture (the paper sweeps the Q2.9 baseline and YodaNN).
pub fn fig11_sweep(arch: ArchId, points: usize) -> Vec<SweepPoint> {
    let core = CorePowerModel::new(arch);
    let (v0, v1) = (arch.v_min(), 1.2);
    (0..points)
        .map(|i| {
            let v = v0 + (v1 - v0) * i as f64 / (points - 1) as f64;
            let theta = core.theta_peak(v, 7);
            let p = core.p_core_slot7(v);
            SweepPoint {
                v,
                f_mhz: core.freq(v) / 1e6,
                theta_gops: theta / 1e9,
                en_eff_tops_w: theta / p / 1e12,
            }
        })
        .collect()
}

/// Fig. 12 — core power breakdown per architecture at 1.2 V (the paper
/// plots 400 MHz; report both the model point at f(1.2 V) and rescaled).
pub fn fig12_at_400mhz() -> Vec<(ArchId, PowerBreakdown)> {
    ArchId::all()
        .iter()
        .map(|&a| {
            let m = CorePowerModel::new(a);
            let b = m.breakdown(1.2);
            let s = 400.0e6 / m.freq(1.2);
            (
                a,
                PowerBreakdown {
                    memory: b.memory * s,
                    sop: b.sop * s,
                    filter_bank: b.filter_bank * s,
                    scale_bias: b.scale_bias * s,
                    other: b.other * s,
                },
            )
        })
        .collect()
}

/// One Fig. 13 point (ours or state of the art).
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// Label.
    pub name: String,
    /// Core energy efficiency (TOp/s/W).
    pub en_eff: f64,
    /// Core area efficiency (GOp/s/MGE).
    pub area_eff: f64,
    /// True for YodaNN sweep points.
    pub ours: bool,
}

/// Fig. 13 — YodaNN's voltage sweep against the published SoA points.
pub fn fig13(points: usize) -> Vec<ParetoPoint> {
    let mut out: Vec<ParetoPoint> = fig11_sweep(ArchId::Bin32Multi, points)
        .into_iter()
        .map(|p| ParetoPoint {
            name: format!("YodaNN @{:.2}V", p.v),
            en_eff: p.en_eff_tops_w,
            area_eff: p.theta_gops / metric_area_mge(ArchId::Bin32Multi),
            ours: true,
        })
        .collect();
    out.extend(soa::POINTS.iter().map(|p| ParetoPoint {
        name: p.name.to_string(),
        en_eff: p.en_eff_tops_w,
        area_eff: p.area_eff_gops_mge,
        ours: false,
    }));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_conv_dominates_ops() {
        let f = fig2();
        assert!(f.conv_op_share > 0.999, "{}", f.conv_op_share);
        // Non-conv layers must be massively less efficient to explain the
        // measured time shares.
        assert!(f.cpu_other_slowdown > 50.0);
        assert!(f.gpu_other_slowdown > 100.0);
    }

    #[test]
    fn fig11_yodann_efficiency_rises_toward_low_voltage() {
        let sweep = fig11_sweep(ArchId::Bin32Multi, 13);
        assert!((sweep.first().unwrap().v - 0.6).abs() < 1e-9);
        assert!((sweep.last().unwrap().v - 1.2).abs() < 1e-9);
        // Energy efficiency is monotonically decreasing in V,
        // throughput increasing.
        for w in sweep.windows(2) {
            assert!(w[1].en_eff_tops_w < w[0].en_eff_tops_w);
            assert!(w[1].theta_gops > w[0].theta_gops);
        }
        // Headline endpoints.
        assert!((sweep[0].en_eff_tops_w - 61.2).abs() < 1.0);
        assert!((sweep.last().unwrap().theta_gops - 1505.0).abs() < 20.0);
    }

    #[test]
    fn fig11_baseline_stops_at_0v8() {
        let sweep = fig11_sweep(ArchId::Q29Fixed8, 5);
        assert!((sweep.first().unwrap().v - 0.8).abs() < 1e-9, "SRAM floor");
        // YodaNN dominates the baseline at every shared voltage.
        let yoda = fig11_sweep(ArchId::Bin32Multi, 5);
        let y12 = yoda.last().unwrap();
        let q12 = sweep.last().unwrap();
        assert!(y12.en_eff_tops_w > 4.0 * q12.en_eff_tops_w);
    }

    #[test]
    fn fig12_multi_kernel_sop_dominates() {
        let rows = fig12_at_400mhz();
        let (_, multi) =
            rows.iter().find(|(a, _)| *a == ArchId::Bin32Multi).unwrap();
        assert!(multi.sop > multi.memory && multi.sop > multi.filter_bank);
        // Totals at 400 MHz match the calibration (§ Table II back-solve).
        assert!((multi.total() - 127.1e-3).abs() / 127.1e-3 < 0.01);
    }

    #[test]
    fn fig13_yodann_forms_pareto_front() {
        let pts = fig13(13);
        let ours: Vec<&ParetoPoint> = pts.iter().filter(|p| p.ours).collect();
        let soa: Vec<&ParetoPoint> = pts.iter().filter(|p| !p.ours).collect();
        // Every SoA point is dominated by at least one YodaNN sweep point.
        for s in &soa {
            assert!(
                ours.iter().any(|o| o.en_eff >= s.en_eff && o.area_eff >= s.area_eff),
                "{} not dominated",
                s.name
            );
        }
    }
}
