//! State-of-the-art accelerator points for Fig. 13 (core area efficiency
//! vs core energy efficiency). Coordinates are the published numbers the
//! paper compares against; where the paper only states a ratio
//! ("outperforms X by N×"), the point is back-solved from YodaNN's own
//! peak numbers — each entry records its provenance.

/// One comparison point of Fig. 13.
#[derive(Debug, Clone, Copy)]
pub struct SoaPoint {
    /// Accelerator name.
    pub name: &'static str,
    /// Core energy efficiency (TOp/s/W).
    pub en_eff_tops_w: f64,
    /// Core area efficiency (GOp/s/MGE).
    pub area_eff_gops_mge: f64,
    /// Where the coordinates come from.
    pub source: &'static str,
}

/// The comparison set of Fig. 13 / §IV-E.
pub const POINTS: &[SoaPoint] = &[
    SoaPoint {
        name: "EIE",
        en_eff_tops_w: 5.0,
        area_eff_gops_mge: 40.5,
        source: "[47]: 5 TOp/s/W (97% sparsity); area from the paper's 28x claim",
    },
    SoaPoint {
        name: "k-Brain",
        en_eff_tops_w: 1.93,
        area_eff_gops_mge: 113.5,
        source: "[28]: 1.93 TOp/s/W; area from the paper's 10x claim",
    },
    SoaPoint {
        name: "NINEX",
        en_eff_tops_w: 1.8,
        area_eff_gops_mge: 120.0,
        source: "[27]: 2.7x lower peak throughput, '5x and more' lower core efficiency",
    },
    SoaPoint {
        name: "Sim (ISSCC'16)",
        en_eff_tops_w: 1.42,
        area_eff_gops_mge: 100.0,
        source: "[40]: 1.42 TOp/s/W DCNN processor (43x below YodaNN)",
    },
    SoaPoint {
        name: "Origami",
        en_eff_tops_w: 0.803,
        area_eff_gops_mge: 168.0,
        source: "[15]: 803 GOp/s/W @0.8 V core",
    },
    SoaPoint {
        name: "ShiDianNao",
        en_eff_tops_w: 0.4,
        area_eff_gops_mge: 80.0,
        source: "[18]: ~400 GOp/s/W class, 65 nm",
    },
    SoaPoint {
        name: "RedEye (analog)",
        en_eff_tops_w: 0.96,
        area_eff_gops_mge: 20.0,
        source: "[48]: 960 GOp/s/W (YodaNN 64x better, SIV-E)",
    },
    SoaPoint {
        name: "ISAAC (analog)",
        en_eff_tops_w: 0.38,
        area_eff_gops_mge: 15.0,
        source: "[49]: 380 GOp/s/W memristive crossbar",
    },
];

/// YodaNN must pareto-dominate every SoA point somewhere on its voltage
/// sweep — the claim of Fig. 13, checked in `report::figures::tests`.
pub fn dominated_by(en_eff: f64, area_eff: f64) -> Vec<&'static str> {
    POINTS
        .iter()
        .filter(|p| p.en_eff_tops_w <= en_eff && p.area_eff_gops_mge <= area_eff)
        .map(|p| p.name)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_match_paper_claims() {
        // 61.2 / 5 ≈ 12x vs EIE, /1.93 ≈ 32x vs k-Brain, /1.42 ≈ 43x vs [40].
        let yoda = 61.2;
        let by = |name: &str| {
            yoda / POINTS.iter().find(|p| p.name == name).unwrap().en_eff_tops_w
        };
        assert!((by("EIE") - 12.0).abs() < 0.5);
        assert!((by("k-Brain") - 32.0).abs() < 1.0);
        assert!((by("Sim (ISSCC'16)") - 43.0).abs() < 1.0);
    }

    #[test]
    fn yodann_peak_dominates_all_digital_points() {
        // At 1.2 V YodaNN reaches 1135 GOp/s/MGE and ~9.9 TOp/s/W; at
        // 0.6 V, 61.2 TOp/s/W. Every SoA point is dominated by one of the
        // sweep's endpoints in the efficiency dimension.
        for p in POINTS {
            assert!(
                p.en_eff_tops_w < 61.2 && p.area_eff_gops_mge < 1135.0,
                "{} not dominated",
                p.name
            );
        }
    }
}
