//! Paper-reported reference values, table renderers and figure series.
//!
//! * [`paper`] — every number the paper prints in Tables I–V and the
//!   headline claims, as constants, so benches/tests can report
//!   paper-vs-measured deltas.
//! * [`table`] — plain-text table renderer used by the CLI and benches.
//! * [`tables`] — generators that assemble each paper table from the
//!   models (the "measured" side).
//! * [`figures`] — data series for Figs. 2, 6, 11, 12 and 13.
//! * [`soa`] — the state-of-the-art accelerator points of Fig. 13.

pub mod figures;
pub mod paper;
pub mod soa;
pub mod table;
pub mod tables;

pub use table::Table;
