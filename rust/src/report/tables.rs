//! Generators assembling the paper's tables from the models — the
//! "measured" side of every paper-vs-measured comparison.

use super::paper;
use super::table::{delta_pct, fmt, Table};
use crate::model::{evaluate_network, networks, Corner, KernelMode};
use crate::power::{area_breakdown, ArchId, CorePowerModel, IoPowerModel};

fn arch_for(label: &str) -> ArchId {
    match label {
        "Q2.9" => ArchId::Q29Fixed8,
        "Bin" => ArchId::Bin8,
        other => panic!("unknown Table I arch {other}"),
    }
}

/// Measured values for one Table I column.
#[derive(Debug, Clone, Copy)]
pub struct Table1Measured {
    /// Architecture.
    pub arch: ArchId,
    /// Supply voltage (V).
    pub v: f64,
    /// Peak throughput (GOp/s).
    pub peak_gops: f64,
    /// Core power (mW).
    pub core_mw: f64,
    /// Device power (mW).
    pub device_mw: f64,
    /// Core area (MGE).
    pub area_mge: f64,
    /// Core energy efficiency (TOp/s/W).
    pub en_eff_core: f64,
    /// Device energy efficiency (TOp/s/W).
    pub en_eff_device: f64,
    /// Core area efficiency (GOp/s/MGE).
    pub area_eff_core: f64,
}

/// Compute one Table-I column from the models.
pub fn table1_column(arch: ArchId, v: f64) -> Table1Measured {
    let core = CorePowerModel::new(arch);
    let io = if arch.binary_weights() { IoPowerModel::binary() } else { IoPowerModel::q29() };
    let f = core.freq(v);
    let peak = core.theta_peak(v, 7);
    let p_core = core.p_core_slot7(v);
    let p_dev = p_core + io.power(f, KernelMode::Slot7);
    let area = area_breakdown(arch).total_mge();
    Table1Measured {
        arch,
        v,
        peak_gops: peak / 1e9,
        core_mw: p_core * 1e3,
        device_mw: p_dev * 1e3,
        area_mge: area,
        en_eff_core: peak / p_core / 1e12,
        en_eff_device: peak / p_dev / 1e12,
        area_eff_core: peak / 1e9 / area,
    }
}

/// Table I — fixed-point Q2.9 vs binary architecture, 8×8 channels.
/// Each cell prints `measured (paper Δ)`.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I: Fixed-point Q2.9 vs binary architecture 8x8 — measured (paper, delta)",
        &["metric", "Q2.9 1.2V", "Bin 1.2V", "Q2.9 0.8V", "Bin 0.8V", "Bin 0.6V"],
    );
    let cols: Vec<(Table1Measured, &paper::Table1Col)> = paper::TABLE1
        .iter()
        .map(|p| (table1_column(arch_for(p.arch), p.v), p))
        .collect();
    let mut push = |name: &str, f: &dyn Fn(&Table1Measured) -> f64, g: &dyn Fn(&paper::Table1Col) -> f64, d: usize| {
        let mut row = vec![name.to_string()];
        for (m, p) in &cols {
            row.push(format!("{} ({}, {})", fmt(f(m), d), fmt(g(p), d), delta_pct(f(m), g(p))));
        }
        t.row(row);
    };
    push("Peak Throughput (GOp/s)", &|m| m.peak_gops, &|p| p.peak_gops, 0);
    push("Avg. Power Core (mW)", &|m| m.core_mw, &|p| p.core_mw, 2);
    push("Avg. Power Device (mW)", &|m| m.device_mw, &|p| p.device_mw, 1);
    push("Area Core (MGE)", &|m| m.area_mge, &|p| p.area_mge, 2);
    push("Energy Core (TOp/s/W)", &|m| m.en_eff_core, &|p| p.en_eff_core, 2);
    push("Energy Device (TOp/s/W)", &|m| m.en_eff_device, &|p| p.en_eff_device, 2);
    push("Area Core (GOp/s/MGE)", &|m| m.area_eff_core, &|p| p.area_eff_core, 0);
    t.note("core power/throughput corners are calibration anchors (exact by construction);");
    t.note("device rows exercise the I/O pad model (fitted, see power::io).");
    t
}

/// Device energy efficiency (GOp/s/W) for a kernel size at 400 MHz, the
/// operating point of the paper's Table II.
pub fn table2_cell(arch: ArchId, k: usize) -> f64 {
    let core = CorePowerModel::new(arch);
    let io = if arch.binary_weights() { IoPowerModel::binary() } else { IoPowerModel::q29() };
    // Table II evaluates the *flexible* accelerator family: every binary
    // column except "32² (fixed)" supports the dual 5×5/3×3 modes (its 5×5
    // and 3×3 rows only make sense with two output streams); Table I's
    // binary 8×8, by contrast, is the fixed-7×7 variant.
    let multi = arch.binary_weights() && arch != ArchId::Bin32Fixed;
    let f400 = 400.0e6;
    let filters = if multi { KernelMode::for_kernel(k).filters_per_sop() } else { 1 };
    let theta = 2.0 * (k * k) as f64 * (arch.n_ch() * filters) as f64 * f400;
    // Core power rescaled linearly from f(1.2 V) to 400 MHz.
    let p_core = core.p_core_mode(1.2, k, multi) * f400 / core.freq(1.2);
    let p_io = io.power_for_kernel(f400, k, multi);
    theta / (p_core + p_io) / 1e9
}

/// Table II — device energy efficiency by filter size and architecture.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II: Device energy efficiency (GOp/s/W) @1.2V core, 400 MHz — measured (paper, delta)",
        &["kernel", "Q2.9", "8x8", "16x16", "32x32", "32^2 fixed"],
    );
    for row in &paper::TABLE2 {
        let mut cells = vec![format!("{0}x{0}", row.k)];
        let cell = |arch: ArchId, p: Option<f64>| match p {
            Some(pv) => {
                let m = table2_cell(arch, row.k);
                format!("{} ({}, {})", fmt(m, 0), fmt(pv, 0), delta_pct(m, pv))
            }
            None => {
                let m = table2_cell(arch, row.k);
                format!("{} (-)", fmt(m, 0))
            }
        };
        cells.push(cell(ArchId::Q29Fixed8, row.q29));
        cells.push(cell(ArchId::Bin8, Some(row.b8)));
        cells.push(cell(ArchId::Bin16, Some(row.b16)));
        cells.push(cell(ArchId::Bin32Multi, Some(row.b32)));
        cells.push(cell(ArchId::Bin32Fixed, row.b32_fixed));
        t.row(cells);
    }
    t.note("Q2.9 and fixed-kernel archs zero-pad small kernels into 7x7 (single stream);");
    t.note("multi-kernel archs run 5x5/3x3 in dual-filter mode (two output streams).");
    t
}

/// Table III — per-layer evaluation of one network at a corner.
pub fn table3(net_id: &str, corner: Corner) -> Table {
    let net = networks::network(net_id).unwrap_or_else(|| panic!("unknown network {net_id}"));
    let eval = evaluate_network(&net, corner);
    let mut t = Table::new(
        &format!(
            "Table III ({}): per-layer evaluation @{}V ({})",
            net.name,
            corner.v,
            corner.arch.name()
        ),
        &[
            "L", "hk", "w", "h", "n_in", "n_out", "x", "eta_tile", "eta_idle", "P~real",
            "Theta (GOp/s)", "EnEff (TOp/s/W)", "#MOp", "t (ms)", "E (uJ)",
        ],
    );
    for (layer, row) in net.conv_layers().zip(eval.rows.iter()) {
        t.row(vec![
            row.label.to_string(),
            layer.k.to_string(),
            layer.w.to_string(),
            layer.h.to_string(),
            layer.n_in.to_string(),
            layer.n_out.to_string(),
            row.repeat.to_string(),
            fmt(row.eta_tile, 2),
            fmt(row.eta_idle, 2),
            fmt(row.p_real, 2),
            fmt(row.theta_real / 1e9, 1),
            fmt(row.en_eff / 1e12, 1),
            fmt(row.ops as f64 / 1e6, 0),
            fmt(row.t * 1e3, 1),
            fmt(row.energy * 1e6, 1),
        ]);
    }
    t.note("E column in µJ: the paper's 'mJ' header is a unit typo (rows only sum as µJ).");
    t
}

/// Tables IV / V — all networks at a corner, with paper deltas.
pub fn table45(corner: Corner) -> Table {
    let (which, paper_rows): (&str, &[paper::NetworkRow]) = if corner.v < 1.0 {
        ("IV (energy-optimal, 0.6V)", &paper::TABLE4)
    } else {
        ("V (throughput-optimal, 1.2V)", &paper::TABLE5)
    };
    let mut t = Table::new(
        &format!("Table {which}: network-level results — measured (paper, delta)"),
        &["Network", "img", "EnEff TOp/s/W", "Theta GOp/s", "FPS", "Energy uJ"],
    );
    for p in paper_rows {
        let net = networks::network(p.id).unwrap();
        let e = evaluate_network(&net, corner);
        t.row(vec![
            net.name.to_string(),
            format!("{}x{}", e.img.0, e.img.1),
            format!("{} ({}, {})", fmt(e.avg_en_eff / 1e12, 1), p.en_eff, delta_pct(e.avg_en_eff / 1e12, p.en_eff)),
            format!("{} ({}, {})", fmt(e.avg_theta / 1e9, 1), p.theta, delta_pct(e.avg_theta / 1e9, p.theta)),
            format!("{} ({}, {})", fmt(e.fps, 1), p.fps, delta_pct(e.fps, p.fps)),
            format!("{} ({}, {})", fmt(e.frame_energy * 1e6, 1), p.energy, delta_pct(e.frame_energy * 1e6, p.energy)),
        ]);
    }
    t.note("AlexNet deltas are larger: the paper's AlexNet rows are not self-consistent");
    t.note("(printed eta x Theta_peak != printed Theta_real; see EXPERIMENTS.md).");
    t
}

/// One network's peak slot-store footprint, as measured by the static
/// analyzer's liveness pass (`yodann analyze` assembles these rows).
#[derive(Debug, Clone)]
pub struct ScmOccupancyRow {
    /// Network id.
    pub net: String,
    /// Frame geometry analyzed.
    pub img: (usize, usize),
    /// Peak number of simultaneously-live activation slots.
    pub peak_slots: usize,
    /// Peak live activation words across those slots.
    pub peak_words: usize,
}

/// Report section: per-network peak live activation memory (the host
/// slot store the coordinator holds between layers, proved by the
/// liveness pass) against the chip's SCM sizing. The on-chip image
/// memory holds one tile of one layer (`image_mem_rows × mem_columns`
/// words), so the ratio is the off-chip working set the Eq. 9 tiling
/// implies the host must carry.
pub fn scm_occupancy_table(cfg: &crate::hw::ChipConfig, rows: &[ScmOccupancyRow]) -> Table {
    let chip_words = cfg.image_mem_rows * cfg.mem_columns;
    // 12-bit Q2.9 words, decimal kB to match the paper's "9.2 kB".
    let kb = |words: usize| words as f64 * 12.0 / 8.0 / 1000.0;
    let mut t = Table::new(
        "SCM occupancy: peak live slot-store vs on-chip image memory (12-bit words)",
        &["Network", "img", "peak slots", "peak kWords", "peak kB", "x chip SCM", "x paper SCM"],
    );
    for r in rows {
        t.row(vec![
            r.net.clone(),
            format!("{}x{}", r.img.0, r.img.1),
            r.peak_slots.to_string(),
            fmt(r.peak_words as f64 / 1e3, 1),
            fmt(kb(r.peak_words), 1),
            fmt(r.peak_words as f64 / chip_words as f64, 1),
            fmt(r.peak_words as f64 / paper::headline::SCM_WORDS as f64, 1),
        ]);
    }
    t.note(&format!(
        "chip SCM: {} rows x {} column slots = {} words ({} kB modeled); paper floorplan: {} words (9.2 kB).",
        cfg.image_mem_rows,
        cfg.mem_columns,
        chip_words,
        fmt(kb(chip_words), 1),
        paper::headline::SCM_WORDS,
    ));
    t.note("x columns: peak host slot-store words over the named SCM capacity.");
    t
}

/// Report section: accelerator-generation comparison — YodaNN's
/// binary-weight mode against the derived XNOR (binary-activation)
/// operating point at both paper corners (0.6 V energy-optimal,
/// 1.2 V throughput-optimal). The XNOR rows come from
/// [`crate::power::XnorPowerModel`]: same silicon anchors, with the
/// structural reductions binarized activations buy (1 activation
/// plane instead of 12, XNOR+popcount SoP).
pub fn xnor_generation_table() -> Table {
    let m = crate::power::XnorPowerModel::new(ArchId::Bin32Multi);
    let mut t = Table::new(
        "Accelerator generations: YodaNN BWN vs derived XNOR mode (32x32 channels)",
        &["mode", "V", "act planes", "core mW", "Theta GOp/s", "core TOp/s/W", "pad mW", "pJ/Op"],
    );
    for corner in [Corner::energy_optimal(), Corner::throughput_optimal()] {
        for p in m.generation_points(corner) {
            let e_pj = p.core_w / p.theta_op_s * 1e12;
            t.row(vec![
                p.mode.to_string(),
                fmt(corner.v, 1),
                p.activation_planes.to_string(),
                fmt(p.core_w * 1e3, 2),
                fmt(p.theta_op_s / 1e9, 1),
                fmt(p.eff_op_s_w / 1e12, 1),
                fmt(p.io_w * 1e3, 1),
                fmt(e_pj, 4),
            ]);
        }
    }
    let ex = {
        use crate::power::xnor::{activation_words, ACTIVATION_PLANES_BWN, ACTIVATION_PLANES_XNOR};
        (
            activation_words(32, 32, 32, 3, true, ACTIVATION_PLANES_BWN),
            activation_words(32, 32, 32, 3, true, ACTIVATION_PLANES_XNOR),
        )
    };
    t.note("XNOR rows are derived, not taped out: memory /12 (1 sign plane), SoP /9.6");
    t.note("(paper's 4.8x weight-binarization gain x2 for dropping multi-bit adds),");
    t.note("throughput held at the BWN peak — both conservative for XNOR.");
    t.note(&format!(
        "activation residency, 32x32x32 k3 padded: {} -> {} words (12x) — the jump",
        ex.0, ex.1
    ));
    t.note("XNORBIN and ChewBaccaNN-class successors build on.");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_metrics() {
        let t = table1();
        assert_eq!(t.len(), 7);
        let s = t.render();
        assert!(s.contains("Peak Throughput"));
        assert!(s.contains("GOp/s/MGE"));
    }

    #[test]
    fn table1_core_anchors_have_zero_delta() {
        let m = table1_column(ArchId::Bin8, 0.6);
        assert!((m.peak_gops - 15.0).abs() < 0.2);
        assert!((m.core_mw - 0.26).abs() < 0.01);
    }

    #[test]
    fn table2_shape_holds() {
        // Who-wins shape: efficiency grows with n_ch and with kernel size.
        for &k in &[3usize, 5, 7] {
            let b8 = table2_cell(ArchId::Bin8, k);
            let b16 = table2_cell(ArchId::Bin16, k);
            let b32 = table2_cell(ArchId::Bin32Multi, k);
            assert!(b8 < b16 && b16 < b32, "k={k}: {b8} {b16} {b32}");
        }
        let t7 = table2_cell(ArchId::Bin32Multi, 7);
        let t5 = table2_cell(ArchId::Bin32Multi, 5);
        let t3 = table2_cell(ArchId::Bin32Multi, 3);
        assert!(t7 > t5 && t5 > t3);
        // Binary beats the Q2.9 baseline at 7×7.
        assert!(table2_cell(ArchId::Bin8, 7) > table2_cell(ArchId::Q29Fixed8, 7));
    }

    #[test]
    fn table2_numbers_within_10pct_of_paper() {
        for row in &paper::TABLE2 {
            let checks = [
                (ArchId::Bin8, Some(row.b8)),
                (ArchId::Bin16, Some(row.b16)),
                (ArchId::Bin32Multi, Some(row.b32)),
                (ArchId::Bin32Fixed, row.b32_fixed),
                (ArchId::Q29Fixed8, row.q29),
            ];
            for (arch, p) in checks {
                if let Some(pv) = p {
                    let m = table2_cell(arch, row.k);
                    assert!(
                        (m - pv).abs() / pv < 0.10,
                        "k={} {:?}: measured {m:.0} vs paper {pv}",
                        row.k,
                        arch
                    );
                }
            }
        }
    }

    #[test]
    fn table3_has_row_per_conv_layer() {
        let t = table3("resnet18", Corner::energy_optimal());
        assert_eq!(t.len(), 8);
    }

    #[test]
    fn table3_spot_rows_match_paper() {
        // The selected Table III rows the paper prints (excluding the
        // inconsistent AlexNet first-layer rows) reproduce within a few %.
        for &(net_id, label, e_tile, e_idle, p_real, theta, en_eff) in &paper::TABLE3_SPOT {
            let net = networks::network(net_id).unwrap();
            let eval = crate::model::evaluate_network(&net, Corner::energy_optimal());
            let row = eval
                .rows
                .iter()
                .find(|r| r.label == label)
                .unwrap_or_else(|| panic!("{net_id} row {label}"));
            assert!((row.eta_tile - e_tile).abs() < 0.011, "{net_id}/{label} eta_tile");
            assert!((row.eta_idle - e_idle).abs() < 0.011, "{net_id}/{label} eta_idle");
            assert!((row.p_real - p_real).abs() < 0.2, "{net_id}/{label} p_real");
            assert!(
                (row.theta_real / 1e9 - theta).abs() / theta < 0.03,
                "{net_id}/{label} theta {} vs {theta}",
                row.theta_real / 1e9
            );
            assert!(
                (row.en_eff / 1e12 - en_eff).abs() / en_eff < 0.07,
                "{net_id}/{label} en_eff {} vs {en_eff}",
                row.en_eff / 1e12
            );
        }
    }

    #[test]
    fn table45_renders_both_corners() {
        assert_eq!(table45(Corner::energy_optimal()).len(), 7);
        assert_eq!(table45(Corner::throughput_optimal()).len(), 7);
    }

    #[test]
    fn scm_occupancy_table_prices_the_ratio() {
        // One row at exactly the paper's SCM capacity: the paper ratio
        // column must print 1.0 and the kB column the floorplan's 9.2.
        let rows = vec![ScmOccupancyRow {
            net: "bc-cifar10".into(),
            img: (32, 32),
            peak_slots: 2,
            peak_words: paper::headline::SCM_WORDS,
        }];
        let t = scm_occupancy_table(&crate::hw::ChipConfig::yodann(), &rows);
        assert_eq!(t.len(), 1);
        let s = t.render();
        assert!(s.contains("bc-cifar10"));
        assert!(s.contains("9.2"), "{s}");
        assert!(s.contains("1.0"), "{s}");
    }

    #[test]
    fn xnor_generation_table_renders_both_corners() {
        let t = xnor_generation_table();
        // Two modes at two corners.
        assert_eq!(t.len(), 4);
        let s = t.render();
        assert!(s.contains("YodaNN BWN"), "{s}");
        assert!(s.contains("XNOR"), "{s}");
        assert!(s.contains("ChewBaccaNN"), "{s}");
        // The paper's 61.2 TOp/s/W headline appears as the BWN 0.6 V
        // efficiency cell; the derived XNOR cell must beat it.
        assert!(s.contains("61."), "{s}");
    }
}
