//! The paper's reported numbers, verbatim, used for paper-vs-measured
//! reporting in benches, tests and EXPERIMENTS.md.

/// Headline claims (abstract / §V).
pub mod headline {
    /// Peak throughput at 1.2 V (GOp/s).
    pub const PEAK_GOPS_1V2: f64 = 1510.0;
    /// Peak core energy efficiency at 0.6 V (TOp/s/W).
    pub const PEAK_TOPS_W_0V6: f64 = 61.2;
    /// Core power at 0.6 V (µW).
    pub const CORE_UW_0V6: f64 = 895.0;
    /// Peak throughput at 0.6 V (GOp/s).
    pub const PEAK_GOPS_0V6: f64 = 55.0;
    /// Peak area efficiency at 1.2 V (GOp/s/MGE).
    pub const AREA_EFF_1V2: f64 = 1135.0;
    /// Core area (MGE).
    pub const CORE_AREA_MGE: f64 = 1.33;
    /// Max clock at 1.2 V (MHz).
    pub const FMAX_1V2_MHZ: f64 = 480.0;
    /// Energy-efficiency gain of the binary core vs the 12-bit MAC
    /// baseline at 1.2 V (§I).
    pub const CORE_EFF_GAIN_VS_Q29: f64 = 5.1;
    /// Throughput gain vs the baseline at 1.2 V.
    pub const THROUGHPUT_GAIN_VS_Q29: f64 = 1.3;
    /// Efficiency gain at 0.6 V vs the SRAM fixed-point design at 0.8 V.
    pub const EFF_GAIN_VS_Q29_0V8: f64 = 11.6;
    /// SCM vs SRAM memory power reduction at 1.2 V.
    pub const SCM_VS_SRAM: f64 = 3.25;
    /// On-chip image-memory capacity per the floorplan (§V): 6 column
    /// slots × 8 row-groups × 128 rows = 6144 12-bit words — the
    /// "9.2 kB" SCM bank matrix. (§III's streaming argument needs a 7th
    /// resident column slot; see [`crate::hw::ChipConfig::mem_columns`].)
    pub const SCM_WORDS: usize = 6144;
}

/// A Table I column: fixed-point Q2.9 vs binary at 8×8 channels.
#[derive(Debug, Clone, Copy)]
pub struct Table1Col {
    /// Architecture label.
    pub arch: &'static str,
    /// Core supply (V).
    pub v: f64,
    /// Peak throughput (GOp/s).
    pub peak_gops: f64,
    /// Average core power (mW).
    pub core_mw: f64,
    /// Average device power (mW).
    pub device_mw: f64,
    /// Core area (MGE).
    pub area_mge: f64,
    /// Core energy efficiency (TOp/s/W).
    pub en_eff_core: f64,
    /// Device energy efficiency (TOp/s/W).
    pub en_eff_device: f64,
    /// Core area efficiency (GOp/s/MGE).
    pub area_eff_core: f64,
}

/// Table I as printed.
pub const TABLE1: [Table1Col; 5] = [
    Table1Col {
        arch: "Q2.9",
        v: 1.2,
        peak_gops: 348.0,
        core_mw: 185.0,
        device_mw: 580.0,
        area_mge: 0.72,
        en_eff_core: 1.88,
        en_eff_device: 0.60,
        area_eff_core: 487.0,
    },
    Table1Col {
        arch: "Bin",
        v: 1.2,
        peak_gops: 377.0,
        core_mw: 39.0,
        device_mw: 434.0,
        area_mge: 0.60,
        en_eff_core: 9.61,
        en_eff_device: 0.87,
        area_eff_core: 631.0,
    },
    Table1Col {
        arch: "Q2.9",
        v: 0.8,
        peak_gops: 131.0,
        core_mw: 31.0,
        device_mw: 143.0,
        area_mge: 0.72,
        en_eff_core: 4.26,
        en_eff_device: 0.89,
        area_eff_core: 183.0,
    },
    Table1Col {
        arch: "Bin",
        v: 0.8,
        peak_gops: 149.0,
        core_mw: 5.1,
        device_mw: 162.0,
        area_mge: 0.60,
        en_eff_core: 29.05,
        en_eff_device: 0.92,
        area_eff_core: 247.0,
    },
    Table1Col {
        arch: "Bin",
        v: 0.6,
        peak_gops: 15.0,
        core_mw: 0.26,
        device_mw: 15.54,
        area_mge: 0.60,
        en_eff_core: 58.56,
        en_eff_device: 0.98,
        area_eff_core: 25.0,
    },
];

/// Table II — device energy efficiency (GOp/s/W) at 1.2 V core / 1.8 V
/// pads, by kernel size × architecture. `None` where the paper leaves the
/// cell empty.
pub struct Table2Row {
    /// Kernel size (7, 5, 3).
    pub k: usize,
    /// Q2.9 baseline.
    pub q29: Option<f64>,
    /// Binary 8×8.
    pub b8: f64,
    /// Binary 16×16.
    pub b16: f64,
    /// Binary 32×32 multi-kernel.
    pub b32: f64,
    /// Binary 32×32 fixed-7×7.
    pub b32_fixed: Option<f64>,
}

/// Table II as printed.
pub const TABLE2: [Table2Row; 3] = [
    Table2Row { k: 7, q29: Some(600.0), b8: 856.0, b16: 1611.0, b32: 2756.0, b32_fixed: Some(3001.0) },
    Table2Row { k: 5, q29: None, b8: 611.0, b16: 1170.0, b32: 2107.0, b32_fixed: None },
    Table2Row { k: 3, q29: None, b8: 230.0, b16: 452.0, b32: 859.0, b32_fixed: None },
];

/// A Table IV / V row (per-network aggregate).
#[derive(Debug, Clone, Copy)]
pub struct NetworkRow {
    /// Network id (matches `model::networks`).
    pub id: &'static str,
    /// Average core energy efficiency (TOp/s/W).
    pub en_eff: f64,
    /// Average throughput (GOp/s).
    pub theta: f64,
    /// Frames per second.
    pub fps: f64,
    /// Energy per frame (the paper prints "mJ"; the rows are only
    /// self-consistent as µJ — see DESIGN.md §5).
    pub energy: f64,
}

/// Table IV — energy-optimal corner, 0.6 V.
pub const TABLE4: [NetworkRow; 7] = [
    NetworkRow { id: "bc-cifar10", en_eff: 56.7, theta: 19.1, fps: 15.8, energy: 20.8 },
    NetworkRow { id: "bc-svhn", en_eff: 50.6, theta: 16.5, fps: 53.2, energy: 5.5 },
    NetworkRow { id: "alexnet", en_eff: 14.1, theta: 3.3, fps: 0.5, energy: 352.2 },
    NetworkRow { id: "resnet18", en_eff: 48.1, theta: 16.2, fps: 1.1, energy: 311.0 },
    NetworkRow { id: "resnet34", en_eff: 52.5, theta: 17.8, fps: 0.6, energy: 548.4 },
    NetworkRow { id: "vgg13", en_eff: 54.3, theta: 18.2, fps: 0.8, energy: 398.1 },
    NetworkRow { id: "vgg19", en_eff: 55.9, theta: 18.9, fps: 0.5, energy: 683.7 },
];

/// Table V — throughput-optimal corner, 1.2 V.
pub const TABLE5: [NetworkRow; 7] = [
    NetworkRow { id: "bc-cifar10", en_eff: 8.6, theta: 525.4, fps: 434.8, energy: 136.6 },
    NetworkRow { id: "bc-svhn", en_eff: 7.7, theta: 454.4, fps: 1428.6, energy: 36.3 },
    NetworkRow { id: "alexnet", en_eff: 2.2, theta: 89.9, fps: 14.0, energy: 2244.4 },
    NetworkRow { id: "resnet18", en_eff: 7.3, theta: 446.4, fps: 29.2, energy: 2030.5 },
    NetworkRow { id: "resnet34", en_eff: 8.0, theta: 489.5, fps: 16.8, energy: 3587.2 },
    NetworkRow { id: "vgg13", en_eff: 8.3, theta: 501.8, fps: 22.4, energy: 2608.7 },
    NetworkRow { id: "vgg19", en_eff: 8.5, theta: 519.8, fps: 13.3, energy: 4481.8 },
];

/// Selected Table III rows used for spot checks: (network id, row label,
/// η_tile, η_idle, P̃_real, Θ_real GOp/s, EnEff TOp/s/W).
pub const TABLE3_SPOT: [(&str, &str, f64, f64, f64, f64, f64); 6] = [
    ("bc-cifar10", "1", 1.00, 0.09, 0.35, 1.9, 16.0),
    ("bc-cifar10", "2", 1.00, 1.00, 1.00, 20.1, 59.2),
    ("resnet18", "1", 0.86, 0.09, 0.35, 4.4, 15.1),
    ("resnet18", "2-5", 0.95, 1.00, 1.00, 19.1, 56.2),
    ("vgg13", "5", 0.97, 1.00, 1.00, 19.4, 57.2),
    ("alexnet", "2", 0.93, 0.75, 1.00, 39.1, 45.2),
];

/// Fig. 2 — share of execution time spent in convolution layers for the
/// scene-labeling CNN of [13], CPU vs GPU.
pub mod fig2 {
    /// Convolution share of total time on CPU (≈89%).
    pub const CPU_CONV_SHARE: f64 = 0.89;
    /// Convolution share on GPU (≈79%).
    pub const GPU_CONV_SHARE: f64 = 0.79;
}
