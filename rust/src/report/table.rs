//! Minimal plain-text table renderer (right-aligned numeric columns).

/// A simple text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Append a footnote printed under the table.
    pub fn note(&mut self, text: &str) -> &mut Self {
        self.notes.push(text.to_string());
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let sep: String = width
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:>w$} ", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&format!("{sep}\n"));
        out.push_str(&format!("{}\n", fmt_row(&self.header)));
        out.push_str(&format!("{sep}\n"));
        for row in &self.rows {
            out.push_str(&format!("{}\n", fmt_row(row)));
        }
        out.push_str(&format!("{sep}\n"));
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }
}

/// Format a float with `d` decimals.
pub fn fmt(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

/// Relative delta "measured vs paper" as a signed percentage string.
pub fn delta_pct(measured: f64, paper: f64) -> String {
    if paper == 0.0 {
        return "n/a".into();
    }
    format!("{:+.1}%", (measured - paper) / paper * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "metric"]);
        t.row(vec!["x".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "123.45".into()]);
        t.note("hello");
        let s = t.render();
        assert!(s.contains("metric"));
        assert!(s.contains("123.45"));
        assert!(s.contains("note: hello"));
        // All data lines have equal width.
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn delta_pct_signs() {
        assert_eq!(delta_pct(110.0, 100.0), "+10.0%");
        assert_eq!(delta_pct(95.0, 100.0), "-5.0%");
        assert_eq!(delta_pct(1.0, 0.0), "n/a");
    }
}
