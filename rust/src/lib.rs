//! # YodaNN — reproduction of *"YodaNN: An Architecture for Ultra-Low Power
//! Binary-Weight CNN Acceleration"* (Andri, Cavigelli, Rossi, Benini — 2016).
//!
//! YodaNN is a 65 nm UMC ASIC that accelerates convolution layers of CNNs
//! with **binary weights** (w ∈ {−1,+1}, BinaryConnect-style) and Q2.9
//! fixed-point activations. Since silicon cannot be re-fabricated here, this
//! crate substitutes every physical artifact with an executable model (see
//! DESIGN.md §1):
//!
//! * [`api`] — the serving-grade public surface: a [`api::SessionBuilder`]
//!   validating every knob eagerly into typed [`api::YodannError`]s, the
//!   [`api::Yodann`] facade with non-blocking `submit` → `FrameTicket`
//!   (`poll`/`wait`), a bounded in-flight queue with backpressure, and
//!   per-frame telemetry (cycles, energy, Θ, power envelope) on every
//!   result. This is the intended front door; the coordinator's session
//!   API beneath it is deprecated.
//! * [`analysis`] — the static plan verifier: abstract interpretation of
//!   a compiled graph's step program (Q2.9 interval/saturation analysis,
//!   slot-store lifetime proofs, block/shard geometry contracts, a
//!   lock-order registry) emitting typed findings before a frame runs —
//!   surfaced as `yodann analyze`, `SessionBuilder::analyze()` and a
//!   build-time preflight knob.
//! * [`hw`] — a cycle-accurate, bit-true simulator of the chip: filter bank,
//!   latch-based SCM image memory (6×8 banks), sliding-window image bank,
//!   SoP units with multi-kernel support, ChannelSummers, Scale-Bias unit,
//!   ready-valid I/O and the controller FSM of the paper's Algorithm 1.
//! * [`engine`] — pluggable convolution engines behind the `ConvEngine`
//!   trait: `CycleAccurate` (wraps [`hw::Chip`], full activity ledger) and
//!   `Functional` (popcount datapath over a layer-resident
//!   `BitplaneRaster` — activations packed once per layer, windows
//!   assembled by shifts — identical Q2.9/Q7.9/Q10.18 saturation order,
//!   no per-cycle ledger) — bit-identical outputs, selected per workload
//!   (accounting vs throughput).
//! * [`power`] — analytic voltage/frequency/power/area models calibrated to
//!   the paper's reported corners (Table I/II, Figs. 6, 11, 12).
//! * [`model`] — CNN layer/network descriptors (all networks of Table III),
//!   the paper's throughput-efficiency analytics (Eqs. 6–11), and the
//!   graph-based network IR ([`model::graph`]): a typed DAG of conv nodes
//!   and host ops (ReLU, pools, stride-2 subsample, residual add, concat)
//!   with a validating `compile()` lowering — how AlexNet's 11×11 split
//!   and ResNet's shortcut topologies actually run.
//! * [`coordinator`] — the L3 off-chip orchestration: channel blocking,
//!   vertical image tiling, streaming, off-chip partial-sum accumulation,
//!   multi-chip sharded execution (`ShardGrid` stripes × channel groups
//!   resolved against one shared layer raster, `ShardPolicy`-scheduled
//!   batched sessions), and metric roll-ups for Tables III–V.
//! * [`runtime`] — PJRT executor for the JAX/Pallas golden model that
//!   `make artifacts` AOT-lowers to `artifacts/*.hlo.txt`. Gated behind the
//!   `golden` cargo feature (it needs the offline `xla` crate closure); the
//!   default build is std-only so the tier-1 verify runs without any
//!   registry.
//! * [`fault`] — seeded fault injection + detection for near-threshold
//!   corners: a reproducible [`fault::FaultPlan`] flips bits in image
//!   memory, packed weights and halo-exchange rows at a
//!   voltage-dependent rate, checksums detect, and a per-frame
//!   [`fault::FaultReport`] lands on the telemetry.
//! * [`serve`] — power-aware serving on top of the facade: a DVFS
//!   governor stepping the simulated corner each control tick against a
//!   power budget or a latency SLO, priority-class admission control
//!   over the existing backpressure, and seeded load scenarios (burst /
//!   sustained saturation / thermal throttle) — every run bit-stable
//!   for a given seed, no wall clock anywhere in the control law.
//! * [`workload`] — deterministic synthetic workload generators (the
//!   Stanford-backgrounds stand-in, weight generators).
//! * [`report`] — paper-reported reference values and table/figure renderers
//!   used by the benches to regenerate every table and figure.
//!
//! The image's offline crate registry only carries the `xla` closure, so
//! [`bench`] (criterion stand-in), [`testkit`] (proptest stand-in) and
//! [`cli`] (clap stand-in) are small local substitutes.

// Geometry-index-heavy numeric code: `for y in 0..h`-style loops mirror
// the hardware's row/column/channel iteration and usually index several
// parallel buffers at computed offsets — iterator rewrites obscure that.
// ci.sh runs `cargo clippy --all-targets -- -D warnings` with this one
// style exemption.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod api;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod engine;
pub mod fault;
pub mod fixedpoint;
pub mod hw;
pub mod model;
pub mod power;
pub mod report;
#[cfg(feature = "golden")]
pub mod runtime;
pub mod serve;
pub mod testkit;
pub mod workload;

/// Crate-wide result type (anyhow-backed when the `golden` runtime and
/// its dependency closure are enabled; plain boxed-error otherwise).
#[cfg(feature = "golden")]
pub type Result<T> = anyhow::Result<T>;

/// Crate-wide result type (std-only default build).
#[cfg(not(feature = "golden"))]
pub type Result<T> = std::result::Result<T, Box<dyn std::error::Error + Send + Sync>>;
