//! Seeded load generators for the serving daemon's scenarios.
//!
//! A scenario is a deterministic offered-load schedule: given the same
//! seed and frame budget, [`LoadGen::next_tick`] emits the exact same
//! sequence of [`FrameRequest`]s — priorities, frame seeds, burst
//! phases — on every run. That determinism is what makes the whole
//! serve trace bit-stable: the governor only ever reacts to simulated
//! quantities derived from this schedule, never to wall-clock arrival
//! times.

use crate::testkit::Gen;

/// Admission priority class of one offered frame.
///
/// Admission control ([`super::admission::admit`]) submits `High`
/// requests before `Low` ones each tick, so when the session's bounded
/// in-flight queue fills, the typed
/// [`Backpressure`](crate::api::YodannError::Backpressure) refusals land
/// on the low class first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive traffic: admitted first, shed last.
    High,
    /// Best-effort traffic: first to be shed under backpressure.
    Low,
}

/// One frame the load generator offers to the serving loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameRequest {
    /// Admission class.
    pub priority: Priority,
    /// Seed the serving loop synthesizes the frame's pixels from — part
    /// of the schedule, so frame *contents* are reproducible too.
    pub seed: u64,
}

/// The serving daemon's built-in offered-load scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// A light base load with periodic bursts: one high-priority frame
    /// per tick, plus [`Scenario::BURST_SIZE`] mostly-low-priority
    /// extras on a seeded phase every [`Scenario::BURST_PERIOD`] ticks.
    /// Exercises SLO recovery and priority shedding.
    Burst,
    /// Steady oversubscription: [`Scenario::SUSTAINED_RATE`] frames per
    /// tick, mixed priority — more than the energy-optimal corner can
    /// serve, so the governor must hold a higher corner (or shed).
    Sustained,
    /// Moderate steady load whose *power budget* collapses mid-run
    /// (see [`Scenario::budget_scale`]): the governor is forced down
    /// toward the near-threshold rail, the bit-error rate climbs, and
    /// the measured fault rate pushes it back up — the
    /// reliability-versus-power tug-of-war.
    ThermalThrottle,
}

impl Scenario {
    /// Every scenario, in CLI/bench order.
    pub const ALL: [Scenario; 3] = [Scenario::Burst, Scenario::Sustained, Scenario::ThermalThrottle];

    /// Extra frames offered on a burst tick.
    pub const BURST_SIZE: usize = 8;
    /// Ticks between bursts.
    pub const BURST_PERIOD: u64 = 8;
    /// Frames offered per tick under sustained saturation.
    pub const SUSTAINED_RATE: usize = 6;
    /// Frames offered per tick under thermal throttling.
    pub const THERMAL_RATE: usize = 3;
    /// Tick at which the thermal scenario's budget collapses.
    pub const THROTTLE_AFTER_TICKS: u64 = 12;
    /// Budget multiplier after the collapse.
    pub const THROTTLE_SCALE: f64 = 0.35;

    /// CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            Scenario::Burst => "burst",
            Scenario::Sustained => "sustained",
            Scenario::ThermalThrottle => "thermal",
        }
    }

    /// Parse a CLI spelling ([`Scenario::name`]).
    pub fn parse(s: &str) -> Option<Scenario> {
        Scenario::ALL.into_iter().find(|sc| sc.name() == s)
    }

    /// Power-budget multiplier in force at `tick` — the thermal
    /// scenario's simulated enclosure throttling. `1.0` everywhere for
    /// the other scenarios, and for latency-SLO serving (which has no
    /// budget to scale).
    pub fn budget_scale(self, tick: u64) -> f64 {
        match self {
            Scenario::ThermalThrottle if tick >= Scenario::THROTTLE_AFTER_TICKS => {
                Scenario::THROTTLE_SCALE
            }
            _ => 1.0,
        }
    }

    /// Whether the scenario couples the live bit-error-rate dial to the
    /// governor's corner ([`crate::fault::LiveBer`]). Only the thermal
    /// scenario does — burst and sustained runs stay fault-free so
    /// their traces isolate the budget/SLO control laws.
    pub fn couples_faults(self) -> bool {
        matches!(self, Scenario::ThermalThrottle)
    }

    /// The governor's default starting supply (V) for this scenario:
    /// the energy-optimal rail for burst/sustained (the governor earns
    /// its way up), a mid-range corner for thermal throttling (so the
    /// collapse has somewhere to push down from).
    pub fn default_v_start(self) -> f64 {
        match self {
            Scenario::Burst | Scenario::Sustained => 0.6,
            Scenario::ThermalThrottle => 0.9,
        }
    }
}

/// Deterministic per-tick request emitter for one [`Scenario`].
///
/// Emits until `total_frames` requests have been offered, then returns
/// empty batches ([`LoadGen::exhausted`] turns true). All randomness
/// (burst phase, priority mix) comes from one seeded [`Gen`] advanced
/// in a fixed order, so the schedule is a pure function of
/// `(scenario, total_frames, seed)`.
#[derive(Debug)]
pub struct LoadGen {
    scenario: Scenario,
    total_frames: usize,
    emitted: usize,
    tick: u64,
    burst_phase: u64,
    seed: u64,
    gen: Gen,
}

impl LoadGen {
    /// A generator offering `total_frames` frames under `scenario`.
    pub fn new(scenario: Scenario, total_frames: usize, seed: u64) -> LoadGen {
        let mut gen = Gen::new(seed ^ 0x5E27_E0AD);
        let burst_phase = gen.below(Scenario::BURST_PERIOD);
        LoadGen { scenario, total_frames, emitted: 0, tick: 0, burst_phase, seed, gen }
    }

    /// Whether the whole frame budget has been offered.
    pub fn exhausted(&self) -> bool {
        self.emitted >= self.total_frames
    }

    /// Requests already offered across all ticks.
    pub fn offered(&self) -> usize {
        self.emitted
    }

    /// The requests offered on the next tick (empty once exhausted).
    pub fn next_tick(&mut self) -> Vec<FrameRequest> {
        let tick = self.tick;
        self.tick += 1;
        let mut out = Vec::new();
        match self.scenario {
            Scenario::Burst => {
                self.push(&mut out, Priority::High);
                if tick % Scenario::BURST_PERIOD == self.burst_phase {
                    for _ in 0..Scenario::BURST_SIZE {
                        // Bursts are mostly best-effort: 1-in-4 high.
                        let p = if self.gen.below(4) == 0 { Priority::High } else { Priority::Low };
                        self.push(&mut out, p);
                    }
                }
            }
            Scenario::Sustained => {
                for _ in 0..Scenario::SUSTAINED_RATE {
                    let p = if self.gen.below(3) == 0 { Priority::Low } else { Priority::High };
                    self.push(&mut out, p);
                }
            }
            Scenario::ThermalThrottle => {
                for _ in 0..Scenario::THERMAL_RATE {
                    let p = if self.gen.below(3) == 0 { Priority::Low } else { Priority::High };
                    self.push(&mut out, p);
                }
            }
        }
        out
    }

    fn push(&mut self, out: &mut Vec<FrameRequest>, priority: Priority) {
        if self.emitted >= self.total_frames {
            return;
        }
        // The same golden-ratio stride the CLI uses for per-frame seeds.
        let seed =
            self.seed.wrapping_add((self.emitted as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        out.push(FrameRequest { priority, seed });
        self.emitted += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(scenario: Scenario, frames: usize, seed: u64) -> Vec<Vec<FrameRequest>> {
        let mut lg = LoadGen::new(scenario, frames, seed);
        let mut ticks = Vec::new();
        while !lg.exhausted() {
            ticks.push(lg.next_tick());
        }
        ticks
    }

    #[test]
    fn schedules_are_reproducible_and_bounded() {
        for scenario in Scenario::ALL {
            let a = drain(scenario, 40, 7);
            let b = drain(scenario, 40, 7);
            assert_eq!(a, b, "{scenario:?} schedule must be seed-deterministic");
            let n: usize = a.iter().map(Vec::len).sum();
            assert_eq!(n, 40, "{scenario:?} offers exactly the frame budget");
            // Frame seeds are unique across the run.
            let mut seeds: Vec<u64> = a.iter().flatten().map(|r| r.seed).collect();
            seeds.sort_unstable();
            seeds.dedup();
            assert_eq!(seeds.len(), 40);
            // A different seed moves the schedule.
            assert_ne!(drain(scenario, 40, 8), a, "{scenario:?} must react to the seed");
        }
    }

    #[test]
    fn burst_ticks_carry_the_extra_frames() {
        let ticks = drain(Scenario::Burst, 64, 3);
        let burst_ticks = ticks.iter().filter(|t| t.len() > 1).count();
        assert!(burst_ticks >= 2, "64 frames must span several bursts");
        for t in &ticks {
            assert!(t.len() == 1 || t.len() == 1 + Scenario::BURST_SIZE || ticks.last() == Some(t));
        }
        // Bursts skew low-priority; the base load is all high.
        let low = ticks.iter().flatten().filter(|r| r.priority == Priority::Low).count();
        assert!(low > 0, "bursts must offer sheddable traffic");
    }

    #[test]
    fn thermal_budget_collapses_after_the_throttle_tick() {
        let s = Scenario::ThermalThrottle;
        assert_eq!(s.budget_scale(0), 1.0);
        assert_eq!(s.budget_scale(Scenario::THROTTLE_AFTER_TICKS - 1), 1.0);
        assert_eq!(s.budget_scale(Scenario::THROTTLE_AFTER_TICKS), Scenario::THROTTLE_SCALE);
        assert_eq!(Scenario::Burst.budget_scale(10_000), 1.0);
        assert!(s.couples_faults() && !Scenario::Burst.couples_faults());
    }

    #[test]
    fn names_round_trip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
        assert_eq!(Scenario::parse("quantum"), None);
    }
}
