//! Power-aware serving: a DVFS governor steering the simulated
//! operating corner against power, latency and fault budgets.
//!
//! YodaNN's whole value proposition is an *operating range* — 895 µW of
//! core power at 0.6 V up to 1.51 TOp/s at 1.2 V — but everything below
//! this module evaluates one fixed corner per session. `serve` closes
//! the loop: a long-running serving daemon ([`run`]) that moves the
//! corner **at runtime**, trading supply voltage against offered load,
//! a core-power budget or a latency SLO, and the measured fault rate of
//! the near-threshold corners.
//!
//! Structure:
//!
//! * [`load`] — seeded offered-load scenarios (burst, sustained
//!   saturation, thermal throttle) emitting per-tick [`FrameRequest`]s;
//! * [`admission`] — priority-class admission over the session's own
//!   bounded queue: high class submitted first, typed
//!   [`Backpressure`](crate::api::YodannError::Backpressure) refusals
//!   shed the low class first;
//! * [`governor`] — the per-tick control law, stepping the supply
//!   through [`VfCurve::step_supply`] and validating every corner with
//!   the typed [`VfCurve::try_freq`];
//! * this module — the tick loop: admit → run → observe → step →
//!   re-price, with a [`TickTrace`] row per tick and a [`ServeReport`]
//!   at the end.
//!
//! **Determinism.** Time in the control loop is *simulated*: each tick
//! spans [`ServeConfig::tick_s`] simulated seconds, frames cost
//! `ops / Θ(v)` at the governor's corner, the queue carries over in
//! operations, and deadline misses are computed from simulated
//! completion times. The host's wall clock never enters, so the same
//! seed produces the identical corner trace, shed counts and output
//! digest on any machine. The corner swap itself is
//! [`Yodann::set_corner`] — re-pricing without rebuilding the session —
//! and on fault-coupled scenarios the governor moves the session's
//! [`LiveBer`] dial only at tick boundaries, keeping injection
//! deterministic too.
//!
//! [`VfCurve::step_supply`]: crate::power::VfCurve::step_supply
//! [`VfCurve::try_freq`]: crate::power::VfCurve::try_freq

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod admission;
pub mod governor;
pub mod load;

pub use admission::{admit, Admitted, Refusal};
pub use governor::{Governor, GovernorAction, GovernorConfig, GovernorMode, Observation};
pub use load::{FrameRequest, LoadGen, Priority, Scenario};

use crate::api::{Yodann, YodannError};
use crate::engine::raster::mix64;
use crate::fault::LiveBer;
use crate::workload::Image;

/// Configuration of one serving run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeConfig {
    /// Offered-load scenario.
    pub scenario: Scenario,
    /// What the governor optimizes for.
    pub mode: GovernorMode,
    /// Control-law tunables.
    pub governor: GovernorConfig,
    /// Total frames the scenario offers before the run winds down.
    pub total_frames: usize,
    /// Seed for the load schedule and the synthesized frames.
    pub seed: u64,
    /// Simulated seconds per control tick.
    pub tick_s: f64,
    /// Leading ticks excluded from the steady-state budget check and
    /// the mean-power roll-up (the governor is still converging there).
    pub warmup_ticks: usize,
    /// Hard cap on control ticks (runaway-backlog backstop).
    pub max_ticks: u64,
}

impl ServeConfig {
    /// Defaults for `scenario` under `mode`: the scenario's own start
    /// corner, a 0.5 ms control tick, 64 frames, seed 7, 8 warmup
    /// ticks.
    pub fn new(scenario: Scenario, mode: GovernorMode) -> ServeConfig {
        ServeConfig {
            scenario,
            mode,
            governor: GovernorConfig {
                v_start: scenario.default_v_start(),
                ..GovernorConfig::default()
            },
            total_frames: 64,
            seed: 7,
            tick_s: 5e-4,
            warmup_ticks: 8,
            max_ticks: 10_000,
        }
    }
}

/// One control tick of the serve trace — every field simulated, so two
/// runs with the same seed produce `PartialEq`-identical rows.
#[derive(Debug, Clone, PartialEq)]
pub struct TickTrace {
    /// Tick index.
    pub tick: u64,
    /// Supply voltage (V) the tick ran at.
    pub v: f64,
    /// Clock frequency (Hz) at that corner.
    pub freq_hz: f64,
    /// Modeled core power (W) of the tick.
    pub power_w: f64,
    /// Effective power budget (W) in force — scenario-scaled;
    /// `f64::INFINITY` under latency-SLO serving.
    pub budget_w: f64,
    /// Utilization of the tick (busy fraction, 0..=1).
    pub util: f64,
    /// Simulated seconds of backlog carried into the next tick.
    pub queue_s: f64,
    /// Simulated seconds to drain everything pending this tick.
    pub drain_s: f64,
    /// Frames offered by the scenario.
    pub offered: u32,
    /// Frames admitted into the session.
    pub admitted: u32,
    /// Low-priority frames shed by backpressure.
    pub shed_low: u32,
    /// High-priority frames shed by backpressure.
    pub shed_high: u32,
    /// Frames refused with a detected, uncorrectable fault.
    pub faults: u32,
    /// Frames whose simulated completion missed the latency SLO.
    pub deadline_misses: u32,
    /// Fault rate over the tick's completed frames.
    pub fault_rate: f64,
    /// What the governor did at the end of the tick.
    pub action: GovernorAction,
}

/// What one serving run did, end to end.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Scenario that generated the load.
    pub scenario: Scenario,
    /// Governor mode the run served under.
    pub mode: GovernorMode,
    /// Per-tick trace, in order.
    pub trace: Vec<TickTrace>,
    /// Frames served to completion.
    pub frames_served: u64,
    /// Low-priority frames shed across the run.
    pub shed_low: u64,
    /// High-priority frames shed across the run.
    pub shed_high: u64,
    /// Frames refused with a detected fault across the run.
    pub faults_detected: u64,
    /// Deadline misses across the run.
    pub deadline_misses: u64,
    /// Simulated core energy of the run (J).
    pub energy_j: f64,
    /// Mean core power over the post-warmup ticks (W).
    pub mean_power_w: f64,
    /// Supply voltage when the run ended (V).
    pub final_v: f64,
    /// Lowest supply the governor visited (V).
    pub min_v: f64,
    /// Highest supply the governor visited (V).
    pub max_v: f64,
    /// Order-sensitive digest of every served frame's output pixels —
    /// bit-identical across runs of the same seed.
    pub output_digest: u64,
    /// Whether any post-warmup tick exceeded its effective power
    /// budget (always `false` under latency-SLO serving).
    pub budget_violated: bool,
}

/// Whether an error is a detected-fault refusal (at any nesting depth).
fn is_fault_detected(e: &YodannError) -> bool {
    match e {
        YodannError::FaultDetected { .. } => true,
        YodannError::AtLayer { inner, .. } | YodannError::AtNode { inner, .. } => {
            is_fault_detected(inner)
        }
        _ => false,
    }
}

/// Run one serving session to completion.
///
/// Each tick: offer the scenario's requests, admit them high-class
/// first against the session's bounded queue, run the admitted frames,
/// fold their outputs into the digest, derive the tick's simulated
/// observation (power, drain, fault and deadline rates), step the
/// governor, and re-price the session at the new corner
/// ([`Yodann::set_corner`] — no rebuild). `dial` is the fault hook: on
/// fault-coupled scenarios the loop moves it to the corner's bit-error
/// rate at every tick boundary. `make_frame` synthesizes a frame from a
/// request seed; `on_tick` observes each appended [`TickTrace`] (the
/// CLI's live readout).
///
/// Errors: an off-curve governor corner
/// ([`YodannError::SupplyOutOfRange`]), or any frame failure that is
/// *not* a detected fault or backpressure (those are counted, not
/// fatal).
pub fn run(
    session: &mut Yodann,
    dial: Option<&LiveBer>,
    cfg: &ServeConfig,
    make_frame: &mut dyn FnMut(u64) -> Image,
    on_tick: &mut dyn FnMut(&TickTrace),
) -> Result<ServeReport, YodannError> {
    let mut gov = Governor::new(session, cfg.mode, cfg.governor)?;
    session.set_corner(gov.corner())?;
    let mut load = LoadGen::new(cfg.scenario, cfg.total_frames, cfg.seed);
    let slo = match cfg.mode {
        GovernorMode::LatencySlo { seconds } => Some(seconds),
        GovernorMode::PowerBudget { .. } => None,
    };

    let mut trace: Vec<TickTrace> = Vec::new();
    let mut queue_ops = 0.0f64;
    let mut digest = mix64(cfg.seed ^ 0x5E4E_D16E_57A7_E0FF);
    let (mut frames_served, mut shed_low, mut shed_high) = (0u64, 0u64, 0u64);
    let (mut faults_total, mut misses_total) = (0u64, 0u64);
    let mut energy_j = 0.0f64;
    let mut tick = 0u64;

    loop {
        if tick >= cfg.max_ticks {
            break;
        }
        let requests = load.next_tick();
        if requests.is_empty() && load.exhausted() && queue_ops <= 1e-9 {
            break;
        }
        let v = gov.supply();
        let freq_hz = gov.freq_hz()?;
        // Fault coupling: the injection rate follows the corner, moved
        // only here, at the tick boundary, between drained batches.
        if let Some(d) = dial {
            d.set(gov.ber());
        }

        let offered = requests.len() as u32;
        let (admitted, refused) = admit(session, requests, make_frame);
        let n_admitted = admitted.len() as u32;
        let (mut t_shed_low, mut t_shed_high) = (0u32, 0u32);
        for r in refused {
            match r.error {
                YodannError::Backpressure { .. } => match r.priority {
                    Priority::Low => t_shed_low += 1,
                    Priority::High => t_shed_high += 1,
                },
                // Anything else is a configuration bug, not load.
                other => return Err(other),
            }
        }

        // Drain the tick's admitted frames; fold outputs and faults.
        let mut service_ops: Vec<u64> = Vec::with_capacity(admitted.len());
        let mut faults = 0u32;
        let mut completed = 0u32;
        for a in admitted {
            match a.ticket.wait() {
                Ok(res) => {
                    completed += 1;
                    frames_served += 1;
                    service_ops.push(res.telemetry.ops);
                    for &px in &res.output.data {
                        digest = mix64(digest ^ px as u64);
                    }
                }
                Err(e) if is_fault_detected(&e) => {
                    completed += 1;
                    faults += 1;
                }
                Err(e) => return Err(e),
            }
        }

        // The simulated queue: service times at the corner's aggregate
        // peak rate, deadline misses from simulated completion times.
        let theta = gov.theta(v);
        let mut misses = 0u32;
        let mut new_ops = 0.0f64;
        let mut backlog_ops = queue_ops;
        for &ops in &service_ops {
            backlog_ops += ops as f64;
            new_ops += ops as f64;
            if let Some(slo) = slo {
                if backlog_ops / theta > slo {
                    misses += 1;
                }
            }
        }
        let pending_ops = queue_ops + new_ops;
        let drain_s = pending_ops / theta;
        let util = (drain_s / cfg.tick_s).min(1.0);
        let power_w = gov.core_power_w(v, util);
        let budget_scale = cfg.scenario.budget_scale(tick);
        let budget_w = match cfg.mode {
            GovernorMode::PowerBudget { watts } => watts * budget_scale,
            GovernorMode::LatencySlo { .. } => f64::INFINITY,
        };
        let denom = completed.max(1) as f64;
        let fault_rate = f64::from(faults) / denom;
        let obs = Observation {
            power_w,
            drain_s,
            tick_s: cfg.tick_s,
            fault_rate,
            deadline_rate: f64::from(misses) / denom,
            backlog_growing: drain_s > cfg.tick_s,
            budget_scale,
        };
        let action = gov.tick(&obs)?;
        // The DVFS step itself: re-price, never rebuild.
        session.set_corner(gov.corner())?;

        queue_ops = (pending_ops - theta * cfg.tick_s).max(0.0);
        energy_j += power_w * cfg.tick_s;
        faults_total += u64::from(faults);
        misses_total += u64::from(misses);
        shed_low += u64::from(t_shed_low);
        shed_high += u64::from(t_shed_high);

        let row = TickTrace {
            tick,
            v,
            freq_hz,
            power_w,
            budget_w,
            util,
            queue_s: queue_ops / theta,
            drain_s,
            offered,
            admitted: n_admitted,
            shed_low: t_shed_low,
            shed_high: t_shed_high,
            faults,
            deadline_misses: misses,
            fault_rate,
            action,
        };
        on_tick(&row);
        trace.push(row);
        tick += 1;
    }

    let steady = trace.iter().skip(cfg.warmup_ticks.min(trace.len().saturating_sub(1)));
    let mut steady_n = 0usize;
    let mut steady_power = 0.0f64;
    let mut budget_violated = false;
    for row in steady {
        steady_n += 1;
        steady_power += row.power_w;
        if row.power_w > row.budget_w + 1e-12 {
            budget_violated = true;
        }
    }
    let (mut min_v, mut max_v) = (gov.supply(), gov.supply());
    for row in &trace {
        min_v = min_v.min(row.v);
        max_v = max_v.max(row.v);
    }
    Ok(ServeReport {
        scenario: cfg.scenario,
        mode: cfg.mode,
        trace,
        frames_served,
        shed_low,
        shed_high,
        faults_detected: faults_total,
        deadline_misses: misses_total,
        energy_j,
        mean_power_w: if steady_n > 0 { steady_power / steady_n as f64 } else { 0.0 },
        final_v: gov.supply(),
        min_v,
        max_v,
        output_digest: digest,
        budget_violated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::coordinator::SessionLayerSpec;
    use crate::fault::FaultPlan;
    use crate::testkit::Gen;
    use crate::workload::{random_image, BinaryKernels, ScaleBias};
    use std::sync::Arc;

    fn tiny_session() -> Yodann {
        let mut g = Gen::new(17);
        let l0 = SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 4, 2, 3)),
            scale_bias: Arc::new(ScaleBias::identity(4)),
            relu: false,
            maxpool2: false,
        };
        let l1 = SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 2, 4, 3)),
            scale_bias: Arc::new(ScaleBias::identity(2)),
            relu: false,
            maxpool2: false,
        };
        SessionBuilder::new()
            .layers(vec![l0, l1])
            .workers(2)
            .max_in_flight(8)
            // Beat the YODANN_FAULT_SEED environment arm: these tests
            // check load accounting, which injection would perturb.
            .fault_plan(FaultPlan::disabled())
            .build()
            .unwrap()
    }

    fn serve_once(cfg: &ServeConfig) -> ServeReport {
        let mut session = tiny_session();
        let mut make = |seed: u64| {
            let mut g = Gen::new(seed);
            random_image(&mut g, 2, 8, 8, 0.05)
        };
        run(&mut session, None, cfg, &mut make, &mut |_| {}).unwrap()
    }

    #[test]
    fn the_loop_terminates_and_serves_every_unshredded_frame() {
        let mut cfg =
            ServeConfig::new(Scenario::Burst, GovernorMode::PowerBudget { watts: 1e-3 });
        cfg.total_frames = 24;
        cfg.tick_s = 2e-6;
        let r = serve_once(&cfg);
        assert_eq!(r.frames_served + r.shed_low + r.shed_high, 24);
        assert!(r.frames_served > 0);
        assert!(!r.trace.is_empty());
        assert!(r.energy_j > 0.0);
        // Conservation per tick, too.
        for row in &r.trace {
            assert_eq!(row.offered, row.admitted + row.shed_low + row.shed_high);
        }
    }

    #[test]
    fn the_max_tick_backstop_caps_a_run_that_cannot_drain() {
        let mut cfg =
            ServeConfig::new(Scenario::Sustained, GovernorMode::PowerBudget { watts: 1e-9 });
        cfg.total_frames = 8;
        // A tick so short the backlog can never drain at any corner.
        cfg.tick_s = 1e-12;
        cfg.max_ticks = 5;
        let r = serve_once(&cfg);
        assert_eq!(r.trace.len(), 5);
    }
}
