//! The DVFS governor: one corner step per control tick, from simulated
//! observations only.
//!
//! The governor owns a supply voltage on the session architecture's
//! fitted V–f curve and moves it one [`GovernorConfig::v_step`] at a
//! time through [`VfCurve::step_supply`] — so it can never leave the
//! operating range — reading frequencies only through the typed
//! [`VfCurve::try_freq`] — so a bad corner surfaces as
//! [`YodannError::SupplyOutOfRange`] instead of a panic. The control
//! law sees a per-tick [`Observation`] of *simulated* quantities
//! (modeled power, queue drain time, measured fault and deadline-miss
//! rates); no wall clock enters anywhere, which is why a serve trace is
//! bit-stable across runs and hosts.
//!
//! [`VfCurve::step_supply`]: crate::power::VfCurve::step_supply
//! [`VfCurve::try_freq`]: crate::power::VfCurve::try_freq

use crate::api::{Yodann, YodannError};
use crate::model::Corner;
use crate::power::{CorePowerModel, XnorPowerModel};

/// What the governor optimizes for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GovernorMode {
    /// Hold steady-state core power at or under a budget (W), stepping
    /// up only when there is both backlog pressure and budget headroom
    /// at the next corner, and drifting down toward the energy-optimal
    /// rail when the load allows.
    PowerBudget {
        /// Core-power budget (W) — the paper's headline axis (the
        /// 895 µW figure is core power at 0.6 V), pads excluded.
        watts: f64,
    },
    /// Hold the queue-drain latency at or under a service-level
    /// objective (s), stepping up whenever the backlog would take
    /// longer than the SLO to drain and back down when the *predicted*
    /// drain at the lower corner leaves comfortable headroom.
    LatencySlo {
        /// Target drain latency (simulated seconds).
        seconds: f64,
    },
}

/// Fraction of the SLO the predicted drain must stay under before the
/// latency governor steps down — hysteresis against corner flapping.
const SLO_HEADROOM: f64 = 0.7;

/// Tunables of the control law.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GovernorConfig {
    /// Supply voltage (V) the governor starts at.
    pub v_start: f64,
    /// Corner step size (V) per control tick.
    pub v_step: f64,
    /// Fault-rate threshold (fraction of the tick's frames refused with
    /// a detected, uncorrectable fault) above which the governor steps
    /// the supply *up* regardless of mode — reliability buys margin
    /// before power or latency are consulted.
    pub fault_backoff: f64,
    /// Deadline-miss-rate threshold with the same override semantics.
    pub deadline_backoff: f64,
}

impl Default for GovernorConfig {
    fn default() -> GovernorConfig {
        GovernorConfig {
            v_start: 0.6,
            v_step: 0.025,
            fault_backoff: 0.05,
            deadline_backoff: 0.25,
        }
    }
}

/// One control tick's simulated inputs to [`Governor::tick`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    /// Modeled core power (W) the tick ran at.
    pub power_w: f64,
    /// Simulated time (s) to drain everything pending this tick.
    pub drain_s: f64,
    /// The control period (simulated seconds per tick).
    pub tick_s: f64,
    /// Fraction of this tick's frames refused with a detected fault.
    pub fault_rate: f64,
    /// Fraction of this tick's frames that missed the latency SLO.
    pub deadline_rate: f64,
    /// Whether pending work exceeds one tick of capacity.
    pub backlog_growing: bool,
    /// Scenario budget multiplier in force (thermal throttling).
    pub budget_scale: f64,
}

/// What the governor did on a tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GovernorAction {
    /// Kept the corner.
    Hold,
    /// Raised the supply one step.
    StepUp,
    /// Lowered the supply one step.
    StepDown,
}

impl GovernorAction {
    /// One-character trace glyph (`-` / `+` / `v`).
    pub fn glyph(self) -> char {
        match self {
            GovernorAction::Hold => '-',
            GovernorAction::StepUp => '+',
            GovernorAction::StepDown => 'v',
        }
    }
}

/// The per-session DVFS governor.
///
/// Built against a live [`Yodann`] session: the governor adopts the
/// session's architecture and prices power at the session's own
/// worst-case envelope (its [`Yodann::envelope_kernel`] mode over
/// [`Yodann::envelope_chips`] chips), so the corner it steers is priced
/// exactly like the telemetry the session reports.
#[derive(Debug)]
pub struct Governor {
    mode: GovernorMode,
    cfg: GovernorConfig,
    model: CorePowerModel,
    /// Derived XNOR pricing plus the session's binary-layer fraction;
    /// `None` when no layer runs the binary datapath (or the
    /// architecture has no binary-weight calibration to derive from).
    xnor: Option<(XnorPowerModel, f64)>,
    chips: usize,
    k: usize,
    v: f64,
}

impl Governor {
    /// A governor for `session`, starting at `cfg.v_start`. Errors with
    /// [`YodannError::SupplyOutOfRange`] when the start corner is off
    /// the architecture's curve.
    pub fn new(
        session: &Yodann,
        mode: GovernorMode,
        cfg: GovernorConfig,
    ) -> Result<Governor, YodannError> {
        let corner = session.corner();
        let model = CorePowerModel::new(corner.arch);
        model.vf.try_freq(cfg.v_start)?;
        let frac = session.binary_layer_fraction();
        let xnor = (frac > 0.0 && corner.arch.binary_weights())
            .then(|| (XnorPowerModel::new(corner.arch), frac));
        Ok(Governor {
            mode,
            cfg,
            model,
            xnor,
            chips: session.envelope_chips(),
            k: session.envelope_kernel(),
            v: cfg.v_start,
        })
    }

    /// The current supply voltage (V).
    pub fn supply(&self) -> f64 {
        self.v
    }

    /// The current operating corner, for [`Yodann::set_corner`].
    pub fn corner(&self) -> Corner {
        Corner { arch: self.model.arch, v: self.v }
    }

    /// What the governor optimizes for.
    pub fn mode(&self) -> GovernorMode {
        self.mode
    }

    /// Clock frequency (Hz) at the current corner, through the typed
    /// curve lookup.
    pub fn freq_hz(&self) -> Result<f64, YodannError> {
        self.model.vf.try_freq(self.v)
    }

    /// Memory bit-error rate at the current corner — what the serve
    /// loop feeds the [`LiveBer`](crate::fault::LiveBer) dial on
    /// fault-coupled scenarios.
    pub fn ber(&self) -> f64 {
        self.model.vf.bit_error_rate(self.v)
    }

    /// Modeled core power (W) of the session at supply `v` and
    /// utilization `util`: the envelope mode over the envelope chips,
    /// derated by the paper's workload activity factor
    /// ([`CorePowerModel::p_real`]). Sessions whose layers run the
    /// binary (XNOR) datapath blend toward the derived
    /// [`XnorPowerModel`] pricing by their binary-layer fraction, so
    /// the governor holds a power budget against what a mixed-precision
    /// chain actually burns. `v` is clamped to the curve.
    pub fn core_power_w(&self, v: f64, util: f64) -> f64 {
        let v = self.model.vf.step_supply(v, 0.0);
        let base = self.chips as f64
            * self.model.p_core(v, self.k)
            * CorePowerModel::p_real(util.clamp(0.0, 1.0));
        match &self.xnor {
            // First-order: the XNOR structural reductions (memory /12,
            // SoP /9.6) apply as the slot-7 power ratio at this corner,
            // weighted by how many layers run binary.
            Some((m, frac)) => {
                let ratio = m.p_core_slot7(v) / self.model.p_core_slot7(v);
                base * ((1.0 - frac) + frac * ratio)
            }
            None => base,
        }
    }

    /// Aggregate peak service rate (Op/s) at supply `v` — the queue
    /// model's drain rate. `v` is clamped to the curve.
    pub fn theta(&self, v: f64) -> f64 {
        let v = self.model.vf.step_supply(v, 0.0);
        self.chips as f64 * self.model.theta_peak(v, self.k)
    }

    /// Run one control step and return what was done. The supply only
    /// ever moves by `±v_step` through the curve's clamped stepper, and
    /// the new corner is re-validated through the typed frequency
    /// lookup before it is reported.
    pub fn tick(&mut self, obs: &Observation) -> Result<GovernorAction, YodannError> {
        let action = self.decide(obs);
        match action {
            GovernorAction::StepUp => self.v = self.model.vf.step_supply(self.v, self.cfg.v_step),
            GovernorAction::StepDown => {
                self.v = self.model.vf.step_supply(self.v, -self.cfg.v_step)
            }
            GovernorAction::Hold => {}
        }
        self.freq_hz()?;
        Ok(action)
    }

    fn decide(&self, obs: &Observation) -> GovernorAction {
        let vf = &self.model.vf;
        let up = vf.step_supply(self.v, self.cfg.v_step);
        let down = vf.step_supply(self.v, -self.cfg.v_step);
        // Reliability first: a breached fault or deadline rate buys
        // supply margin before power or latency are consulted — a
        // violated budget is reported, a corrupted stream is not served.
        if obs.fault_rate > self.cfg.fault_backoff || obs.deadline_rate > self.cfg.deadline_backoff
        {
            return if up > self.v { GovernorAction::StepUp } else { GovernorAction::Hold };
        }
        match self.mode {
            GovernorMode::PowerBudget { watts } => {
                let budget = watts * obs.budget_scale;
                if obs.power_w > budget && down < self.v {
                    GovernorAction::StepDown
                } else if obs.backlog_growing {
                    // Chase the backlog only while the next corner
                    // still fits the budget at full utilization.
                    if up > self.v && self.core_power_w(up, 1.0) <= budget {
                        GovernorAction::StepUp
                    } else {
                        GovernorAction::Hold
                    }
                } else if down < self.v && obs.drain_s <= obs.tick_s {
                    // Keeping up comfortably: drift toward the
                    // energy-optimal rail.
                    GovernorAction::StepDown
                } else {
                    GovernorAction::Hold
                }
            }
            GovernorMode::LatencySlo { seconds } => {
                if obs.drain_s > seconds {
                    if up > self.v {
                        GovernorAction::StepUp
                    } else {
                        GovernorAction::Hold
                    }
                } else if down < self.v {
                    // Predicted drain at the lower corner: pending work
                    // rescales by the throughput ratio.
                    let predicted = obs.drain_s * self.theta(self.v) / self.theta(down);
                    if predicted < seconds * SLO_HEADROOM {
                        GovernorAction::StepDown
                    } else {
                        GovernorAction::Hold
                    }
                } else {
                    GovernorAction::Hold
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::coordinator::SessionLayerSpec;
    use crate::testkit::Gen;
    use crate::workload::{BinaryKernels, ScaleBias};
    use std::sync::Arc;

    fn session() -> Yodann {
        let mut g = Gen::new(9);
        let layer = SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 2, 2, 3)),
            scale_bias: Arc::new(ScaleBias::identity(2)),
            relu: false,
            maxpool2: false,
        };
        SessionBuilder::new().layers(vec![layer]).workers(1).build().unwrap()
    }

    fn quiet(power_w: f64) -> Observation {
        Observation {
            power_w,
            drain_s: 0.0,
            tick_s: 1e-3,
            fault_rate: 0.0,
            deadline_rate: 0.0,
            backlog_growing: false,
            budget_scale: 1.0,
        }
    }

    #[test]
    fn power_governor_steps_down_when_over_budget_and_clamps_at_the_rail() {
        let s = session();
        let cfg = GovernorConfig { v_start: 0.7, ..GovernorConfig::default() };
        let mut g =
            Governor::new(&s, GovernorMode::PowerBudget { watts: 1e-4 }, cfg).unwrap();
        // Way over budget: must descend, one step per tick, to vmin.
        for _ in 0..10 {
            let p = g.core_power_w(g.supply(), 1.0);
            g.tick(&quiet(p)).unwrap();
        }
        assert!((g.supply() - 0.6).abs() < 1e-12, "v = {}", g.supply());
        // At the rail it holds rather than erroring.
        let p = g.core_power_w(0.6, 1.0);
        assert_eq!(g.tick(&quiet(p)).unwrap(), GovernorAction::Hold);
    }

    #[test]
    fn power_governor_chases_backlog_only_within_budget() {
        let s = session();
        let mut g = Governor::new(
            &s,
            GovernorMode::PowerBudget { watts: 1.0 }, // generous: full range fits
            GovernorConfig::default(),
        )
        .unwrap();
        let mut obs = quiet(g.core_power_w(0.6, 1.0));
        obs.backlog_growing = true;
        obs.drain_s = 10.0 * obs.tick_s;
        assert_eq!(g.tick(&obs).unwrap(), GovernorAction::StepUp);
        assert!(g.supply() > 0.6);
        // A tight budget pins the corner even under backlog.
        let mut tight = Governor::new(
            &s,
            GovernorMode::PowerBudget { watts: 1e-6 },
            GovernorConfig::default(),
        )
        .unwrap();
        assert_eq!(tight.tick(&obs).unwrap(), GovernorAction::Hold);
        assert!((tight.supply() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn xnor_sessions_price_under_the_bwn_envelope() {
        // The same one-layer chain on the binary datapath must report
        // strictly less core power at every corner — the governor's
        // budget headroom is what mixed-precision serving buys.
        let bwn = session();
        let mut g = Gen::new(9);
        let layer = SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 2, 2, 3)),
            scale_bias: Arc::new(ScaleBias::identity(2)),
            relu: false,
            maxpool2: false,
        };
        let xnor = SessionBuilder::new()
            .layers(vec![layer])
            .workers(1)
            .precision(vec![crate::model::Precision::Binary])
            .build()
            .unwrap();
        assert_eq!(bwn.binary_layer_fraction(), 0.0);
        assert_eq!(xnor.binary_layer_fraction(), 1.0);
        let mode = GovernorMode::PowerBudget { watts: 1.0 };
        let gb = Governor::new(&bwn, mode, GovernorConfig::default()).unwrap();
        let gx = Governor::new(&xnor, mode, GovernorConfig::default()).unwrap();
        for v in [0.6, 0.9, 1.2] {
            let (pb, px) = (gb.core_power_w(v, 1.0), gx.core_power_w(v, 1.0));
            assert!(px < pb, "xnor {px} vs bwn {pb} at {v} V");
        }
    }

    #[test]
    fn fault_pressure_overrides_the_budget() {
        let s = session();
        let mut g = Governor::new(
            &s,
            GovernorMode::PowerBudget { watts: 1e-6 }, // impossible budget
            GovernorConfig::default(),
        )
        .unwrap();
        let mut obs = quiet(1.0); // massively over budget...
        obs.fault_rate = 0.5; // ...but the output stream is corrupting
        assert_eq!(g.tick(&obs).unwrap(), GovernorAction::StepUp);
        assert!(g.supply() > 0.6, "reliability must out-rank the budget");
    }

    #[test]
    fn slo_governor_ramps_up_under_backlog_and_back_down_when_idle() {
        let s = session();
        let mode = GovernorMode::LatencySlo { seconds: 1e-3 };
        let mut g = Governor::new(&s, mode, GovernorConfig::default()).unwrap();
        let mut obs = quiet(0.0);
        obs.drain_s = 5e-3; // 5× the SLO
        for _ in 0..4 {
            assert_eq!(g.tick(&obs).unwrap(), GovernorAction::StepUp);
        }
        let peak = g.supply();
        assert!(peak > 0.69, "v = {peak}");
        // Idle again: predicted drain at the lower corner is ~0.
        obs.drain_s = 1e-6;
        while g.tick(&obs).unwrap() == GovernorAction::StepDown {}
        assert!((g.supply() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn governor_rejects_an_off_curve_start() {
        let s = session();
        let cfg = GovernorConfig { v_start: 0.4, ..GovernorConfig::default() };
        let e = Governor::new(&s, GovernorMode::PowerBudget { watts: 1.0 }, cfg).unwrap_err();
        assert!(matches!(e, YodannError::SupplyOutOfRange { .. }));
    }

    #[test]
    fn trace_glyphs_are_distinct() {
        let gl: Vec<char> =
            [GovernorAction::Hold, GovernorAction::StepUp, GovernorAction::StepDown]
                .iter()
                .map(|a| a.glyph())
                .collect();
        assert_eq!(gl.len(), 3);
        assert!(gl.iter().collect::<std::collections::HashSet<_>>().len() == 3);
    }
}
