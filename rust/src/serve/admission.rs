//! Priority-class admission control over the session's own
//! backpressure.
//!
//! The facade already bounds work: [`Yodann::submit`] refuses frames
//! with a typed [`YodannError::Backpressure`] once the in-flight queue
//! is full. Admission control adds exactly one policy on top — *order*:
//! each tick's offered requests are submitted high-priority first, so
//! whatever capacity the queue has goes to the latency-sensitive class
//! and the typed refusals land on best-effort traffic first. No second
//! queue, no counters of its own; the session's bound stays the single
//! source of truth.

use super::load::{FrameRequest, Priority};
use crate::api::{FrameTicket, Yodann, YodannError};
use crate::workload::Image;

/// One request that made it into the session this tick.
#[derive(Debug)]
pub struct Admitted {
    /// The request's admission class.
    pub priority: Priority,
    /// The request's frame seed.
    pub seed: u64,
    /// The live claim on the frame's result.
    pub ticket: FrameTicket,
}

/// One request the session refused this tick.
#[derive(Debug)]
pub struct Refusal {
    /// The request's admission class.
    pub priority: Priority,
    /// The request's frame seed.
    pub seed: u64,
    /// Why it was refused — [`YodannError::Backpressure`] when the
    /// in-flight queue was full, or any frame-validation error.
    pub error: YodannError,
}

/// Submit one tick's requests, high-priority class first.
///
/// `make_frame` synthesizes the frame for a request's seed (admission
/// owns ordering, not frame contents). Within a class, submission
/// order is the offered order, so the whole outcome is deterministic
/// for a deterministic schedule. Returns the admitted tickets and the
/// typed refusals; the caller decides whether a refusal is shedding
/// (backpressure) or a hard error.
pub fn admit(
    session: &mut Yodann,
    requests: Vec<FrameRequest>,
    make_frame: &mut dyn FnMut(u64) -> Image,
) -> (Vec<Admitted>, Vec<Refusal>) {
    let mut admitted = Vec::new();
    let mut refused = Vec::new();
    let (high, low): (Vec<FrameRequest>, Vec<FrameRequest>) =
        requests.into_iter().partition(|r| r.priority == Priority::High);
    for r in high.into_iter().chain(low) {
        match session.submit(make_frame(r.seed)) {
            Ok(ticket) => {
                admitted.push(Admitted { priority: r.priority, seed: r.seed, ticket })
            }
            Err(error) => refused.push(Refusal { priority: r.priority, seed: r.seed, error }),
        }
    }
    (admitted, refused)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::coordinator::SessionLayerSpec;
    use crate::testkit::Gen;
    use crate::workload::{random_image, BinaryKernels, ScaleBias};
    use std::sync::Arc;

    fn session(depth: usize) -> Yodann {
        let mut g = Gen::new(13);
        let layer = SessionLayerSpec {
            k: 3,
            zero_pad: true,
            kernels: Arc::new(BinaryKernels::random(&mut g, 2, 2, 3)),
            scale_bias: Arc::new(ScaleBias::identity(2)),
            relu: false,
            maxpool2: false,
        };
        SessionBuilder::new()
            .layers(vec![layer])
            .workers(1)
            .max_in_flight(depth)
            .fault_plan(crate::fault::FaultPlan::disabled())
            .build()
            .unwrap()
    }

    #[test]
    fn high_priority_is_admitted_before_low_is_shed() {
        let mut s = session(2);
        let req = |p, seed| FrameRequest { priority: p, seed };
        let offered = vec![
            req(Priority::Low, 1),
            req(Priority::High, 2),
            req(Priority::Low, 3),
            req(Priority::High, 4),
            req(Priority::High, 5),
        ];
        let mut make = |seed: u64| {
            let mut g = Gen::new(seed);
            random_image(&mut g, 2, 6, 6, 0.05)
        };
        let (admitted, refused) = admit(&mut s, offered, &mut make);
        // Two slots: both go to the high class, in offered order.
        assert_eq!(admitted.len(), 2);
        assert!(admitted.iter().all(|a| a.priority == Priority::High));
        assert_eq!(admitted[0].seed, 2);
        assert_eq!(admitted[1].seed, 4);
        // The shed set: the overflow high frame and both lows, every
        // refusal typed as backpressure.
        assert_eq!(refused.len(), 3);
        assert_eq!(refused.iter().filter(|r| r.priority == Priority::Low).count(), 2);
        for r in &refused {
            assert!(
                matches!(r.error, YodannError::Backpressure { limit: 2, .. }),
                "{:?}",
                r.error
            );
        }
        // Draining the admitted tickets restores capacity.
        for a in admitted {
            a.ticket.wait().unwrap();
        }
        let (adm2, ref2) = admit(&mut s, vec![req(Priority::Low, 9)], &mut make);
        assert_eq!((adm2.len(), ref2.len()), (1, 0));
    }

    #[test]
    fn validation_failures_are_refusals_not_panics() {
        let mut s = session(4);
        let offered = vec![FrameRequest { priority: Priority::High, seed: 1 }];
        // A frame with the wrong channel count: refused, typed.
        let (admitted, refused) =
            admit(&mut s, offered, &mut |_| Image::zeros(3, 6, 6));
        assert!(admitted.is_empty());
        assert!(matches!(
            refused[0].error,
            YodannError::FrameChannelMismatch { got: 3, expected: 2 }
        ));
    }
}
