//! Deterministic property-testing harness (stand-in for `proptest`, which is
//! unavailable in this image's offline crate registry).
//!
//! Usage mirrors the proptest workflow: a [`Gen`] (seeded SplitMix64) draws
//! random cases, [`property`] runs a closure over N cases and reports the
//! failing seed + case index on panic so the exact case can be replayed.
//! There is no shrinking; cases are kept small by construction instead.

/// SplitMix64 PRNG — tiny, fast, and with a guaranteed full 2^64 period.
/// Used for all randomness in the crate (workload generation included) so
/// every experiment is bit-reproducible from its seed.
#[derive(Debug, Clone)]
pub struct Gen {
    state: u64,
}

impl Gen {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Gen { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Gen::below(0)");
        // Multiply-shift rejection-free mapping (Lemire); bias is < 2^-64
        // per draw, irrelevant for testing purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit_f64() * (hi - lo)
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() - 1)]
    }

    /// A vector of `n` raw Q-format values spanning the full range of `fmt`.
    pub fn q_raws(&mut self, fmt: crate::fixedpoint::QFormat, n: usize) -> Vec<i64> {
        (0..n).map(|_| self.range_i64(fmt.min_raw(), fmt.max_raw())).collect()
    }
}

/// Run `cases` random property cases. On failure the panic message contains
/// the seed and case index, so the case replays with
/// `Gen::new(seed)` advanced to that index.
pub fn property<F: FnMut(&mut Gen)>(name: &str, seed: u64, cases: usize, mut f: F) {
    for i in 0..cases {
        // Derive a per-case generator so a failing case is replayable in
        // isolation: case i uses seed `seed ^ hash(i)`.
        let mut g = Gen::new(seed ^ (i as u64).wrapping_mul(0xA24B_AED4_963E_E407));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {i}/{cases} (seed={seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut g = Gen::new(1);
        for _ in 0..10_000 {
            assert!(g.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut g = Gen::new(2);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match g.range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn unit_f64_in_unit_interval() {
        let mut g = Gen::new(3);
        for _ in 0..10_000 {
            let x = g.unit_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            property("always-fails", 7, 3, |_| panic!("boom"));
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>().unwrap());
        assert!(msg.contains("always-fails") && msg.contains("boom"));
    }
}
