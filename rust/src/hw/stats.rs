//! Activity statistics and the activity→energy mapping.
//!
//! The cycle simulator counts *events* (SCM bank accesses, active SoP
//! operators, scale-bias ops, idle cycles). [`EnergyModel`] converts them
//! to joules using per-event energies derived from the calibrated unit
//! power breakdown ([`crate::power`]): at full 7×7 utilization the SoP
//! array evaluates `n_ch · 49` binary ops per cycle, the image memory
//! serves 6 reads + 1 write per cycle, etc., so
//! `e_event = P_unit(V) / (f(V) · events_per_cycle_at_full_rate)`.
//! This makes the simulator's energy estimate *independently* land on the
//! analytic model when activity is full — and diverge measurably when a
//! workload under-utilizes the chip, which is the cross-check
//! `rust/tests/efficiency_vs_sim.rs` exercises.

use crate::power::{ArchId, CorePowerModel};

/// Cycle counts per controller phase.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleBreakdown {
    /// Filter-bank load cycles (12-bit stream).
    pub filter_load: u64,
    /// Initial column preload cycles (Algorithm 1 lines 6–7).
    pub preload: u64,
    /// Main-loop compute cycles (one input channel each).
    pub compute: u64,
    /// Idle cycles while the output streams drain (n_out > n_in·streams).
    pub idle: u64,
    /// Tail flush cycles (last pixel streaming out).
    pub flush: u64,
}

impl CycleBreakdown {
    /// Total cycles.
    pub fn total(&self) -> u64 {
        self.filter_load + self.preload + self.compute + self.idle + self.flush
    }
}

/// Aggregated activity of one or more simulated blocks.
#[derive(Debug, Clone, Default)]
pub struct ChipStats {
    /// Cycle breakdown.
    pub cycles: CycleBreakdown,
    /// SCM bank reads.
    pub scm_reads: u64,
    /// SCM bank writes.
    pub scm_writes: u64,
    /// Max banks active in any cycle (≤ 7 per the paper's gating).
    pub scm_max_banks_per_cycle: usize,
    /// Active SoP binary-operator evaluations.
    pub sop_active_ops: u64,
    /// Silenced (clock-gated) operator-cycles.
    pub sop_silenced_ops: u64,
    /// Filter-bank column rotations.
    pub fb_rotations: u64,
    /// Filter-bank bits loaded.
    pub fb_bits_loaded: u64,
    /// Image-bank row fetches.
    pub bank_row_fetches: u64,
    /// ChannelSummer accumulate operations.
    pub summer_adds: u64,
    /// ChannelSummer saturation events (diagnostic).
    pub summer_saturations: u64,
    /// Scale-bias operations (streamed output pixels).
    pub sb_ops: u64,
    /// 12-bit words consumed on the input stream.
    pub input_words: u64,
    /// 12-bit words emitted on the output streams.
    pub output_words: u64,
    /// Useful arithmetic operations (Eq. 7 accounting: 2 per weight·pixel).
    pub useful_ops: u64,
}

impl ChipStats {
    /// Merge another block's stats into this aggregate.
    pub fn merge(&mut self, o: &ChipStats) {
        self.cycles.filter_load += o.cycles.filter_load;
        self.cycles.preload += o.cycles.preload;
        self.cycles.compute += o.cycles.compute;
        self.cycles.idle += o.cycles.idle;
        self.cycles.flush += o.cycles.flush;
        self.scm_reads += o.scm_reads;
        self.scm_writes += o.scm_writes;
        self.scm_max_banks_per_cycle = self.scm_max_banks_per_cycle.max(o.scm_max_banks_per_cycle);
        self.sop_active_ops += o.sop_active_ops;
        self.sop_silenced_ops += o.sop_silenced_ops;
        self.fb_rotations += o.fb_rotations;
        self.fb_bits_loaded += o.fb_bits_loaded;
        self.bank_row_fetches += o.bank_row_fetches;
        self.summer_adds += o.summer_adds;
        self.summer_saturations += o.summer_saturations;
        self.sb_ops += o.sb_ops;
        self.input_words += o.input_words;
        self.output_words += o.output_words;
        self.useful_ops += o.useful_ops;
    }

    /// Throughput (Op/s) at clock `f`.
    pub fn throughput(&self, f: f64) -> f64 {
        self.useful_ops as f64 / (self.cycles.total() as f64 / f)
    }
}

/// Per-event energies at one operating corner.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Corner voltage.
    pub v: f64,
    /// Clock frequency at the corner (Hz).
    pub f: f64,
    /// Energy per active SoP binary op (J).
    pub e_sop_op: f64,
    /// Energy per SCM bank access (J).
    pub e_scm_access: f64,
    /// Filter-bank energy per compute cycle (J) — shift-register hold +
    /// read; load/rotate events are folded into the same per-cycle figure.
    pub e_fb_cycle: f64,
    /// Scale-bias energy per output pixel (J).
    pub e_sb_op: f64,
    /// Controller/clock-tree/image-bank energy per cycle (J).
    pub e_other_cycle: f64,
    /// Energy per idle cycle (silenced datapath, §IV-A: "only a negligible
    /// amount of energy" — the calibrated idle fraction of a full cycle).
    pub e_idle_cycle: f64,
}

impl EnergyModel {
    /// Build the per-event energies for `arch` at supply `v`.
    pub fn new(arch: ArchId, v: f64) -> EnergyModel {
        let core = CorePowerModel::new(arch);
        let f = core.freq(v);
        let b = core.breakdown(v);
        let n_ch = arch.n_ch() as f64;
        let full_cycle_energy = core.p_core_slot7(v) / f;
        EnergyModel {
            v,
            f,
            e_sop_op: b.sop / (f * n_ch * 49.0),
            e_scm_access: b.memory / (f * 7.0),
            e_fb_cycle: b.filter_bank / f,
            // Architectures whose calibration split folds the scale-bias
            // unit into "other" simply get e_sb = 0 here.
            e_sb_op: b.scale_bias / f,
            e_other_cycle: b.other / f,
            e_idle_cycle: crate::power::calib::IDLE_FRACTION * full_cycle_energy,
        }
    }

    /// Total core energy (J) for a set of activity counters.
    pub fn energy(&self, s: &ChipStats) -> f64 {
        let active_cycles = s.cycles.compute + s.cycles.preload + s.cycles.filter_load;
        self.e_sop_op * s.sop_active_ops as f64
            + self.e_scm_access * (s.scm_reads + s.scm_writes) as f64
            + self.e_fb_cycle * active_cycles as f64
            + self.e_sb_op * s.sb_ops as f64
            + self.e_other_cycle * active_cycles as f64
            + self.e_idle_cycle * (s.cycles.idle + s.cycles.flush) as f64
    }

    /// Core energy efficiency (Op/J) implied by the simulated activity.
    pub fn en_eff(&self, s: &ChipStats) -> f64 {
        s.useful_ops as f64 / self.energy(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_activity_energy_matches_analytic_power() {
        // Construct stats for one second of fully-active 7×7 / 32×32
        // operation and check the implied power against the analytic core
        // power at the corner.
        let arch = ArchId::Bin32Multi;
        let m = EnergyModel::new(arch, 0.6);
        let cycles = m.f as u64;
        let s = ChipStats {
            cycles: CycleBreakdown { compute: cycles, ..Default::default() },
            sop_active_ops: cycles * 32 * 49,
            scm_reads: cycles * 6,
            scm_writes: cycles,
            sb_ops: cycles,
            useful_ops: cycles * 2 * 49 * 32,
            ..Default::default()
        };
        let p = m.energy(&s); // J over 1 s = W
        let analytic = CorePowerModel::new(arch).p_core_slot7(0.6);
        assert!(
            (p - analytic).abs() / analytic < 0.05,
            "sim {p} W vs analytic {analytic} W"
        );
    }

    #[test]
    fn idle_cycles_cost_the_idle_fraction() {
        let m = EnergyModel::new(ArchId::Bin32Multi, 0.6);
        let idle = ChipStats {
            cycles: CycleBreakdown { idle: 1000, ..Default::default() },
            ..Default::default()
        };
        let full = ChipStats {
            cycles: CycleBreakdown { compute: 1000, ..Default::default() },
            sop_active_ops: 1000 * 32 * 49,
            scm_reads: 1000 * 6,
            scm_writes: 1000,
            sb_ops: 1000,
            ..Default::default()
        };
        let ratio = m.energy(&idle) / m.energy(&full);
        assert!((ratio - crate::power::calib::IDLE_FRACTION).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn merge_accumulates() {
        let mut a = ChipStats { scm_reads: 5, ..Default::default() };
        let b = ChipStats { scm_reads: 7, scm_max_banks_per_cycle: 6, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.scm_reads, 12);
        assert_eq!(a.scm_max_banks_per_cycle, 6);
    }
}
