//! The controller FSM — the paper's Algorithm 1 "YodaNN chip block"
//! (lines 4–33): filter load, column preload, then the column-major main
//! loop with per-cycle input-channel interleaving, weight rotation on
//! column switches, and interleaved scale-bias streaming.
//!
//! Schedule recap (derivation in `hw::mod` docs and DESIGN.md):
//!
//! * window slot `p` at output column `x` holds logical image column
//!   `x − half + ((p − x) mod k)` (zero-padded layers; non-padded layers
//!   drop the `−half`), and the filter bank's rotation compensates;
//! * the live (streamed) column is the window's logical rightmost; its
//!   pixel for the current fetch row arrives just-in-time and is written
//!   to the slot the oldest column vacated (Fig. 5). Each column's pixels
//!   are therefore written exactly once;
//! * per-column window refills (the first k−1 rows) overlap the previous
//!   column's output drain, so they count bank events but no cycles —
//!   except for the very first column, whose live-pixel deliveries are
//!   the paper's "load m pixels of the (m+1)th column" preload cycles;
//! * each output pixel takes `max(n_in, ⌈n_out/streams⌉)` cycles: `n_in`
//!   compute cycles (one channel each) plus output-drain idle cycles when
//!   the block computes more output channels than it can stream — this is
//!   exactly what Eq. 10's η_chIdle measures.

use super::chip::Chip;
use super::config::BlockJob;
use super::io::OutputSink;
use super::scale_bias::ScaleBiasUnit;
use super::sop::SopArray;
use super::stats::ChipStats;
use super::summer::ChannelSummers;
use crate::workload::Image;

/// Geometry helper shared by the fetch logic.
struct Geometry {
    k: usize,
    /// Column/row offset of the window (half for zero-padded layers).
    offset: isize,
    w: usize,
    h: usize,
}

impl Geometry {
    /// Logical image column held by physical window slot `p` at output
    /// column `x`, and whether that slot is the live streaming column.
    fn slot_column(&self, x: usize, p: usize) -> (isize, bool) {
        let k = self.k;
        let j = (p + k - (x % k)) % k; // logical window offset 0..k−1
        let lcol = x as isize - self.offset + j as isize;
        (lcol, j == k - 1)
    }
}

/// Execute one block job on `chip`, returning the output tile, the output
/// sink (streamed order) and the block's activity statistics.
pub fn execute(chip: &mut Chip, job: &BlockJob) -> (Image, OutputSink, ChipStats) {
    job.validate(&chip.cfg).expect("invalid block job");
    let k = job.k;
    let n_in = job.image.c;
    let n_out = job.kernels.n_out;
    let h = job.image.h;
    let (out_h, out_w) = (job.out_h(), job.out_w());
    let streams = job.streams(&chip.cfg);
    let drain_cycles = n_out.div_ceil(streams) as u64;
    let geo = Geometry { k, offset: job.offset() as isize, w: job.image.w, h };
    let n_sop_slots = chip.cfg.n_ch * super::sop::OPS_PER_SOP;

    // Per-block unit state: fresh windows, fresh counters. (Cross-block
    // aggregation is the coordinator's job via ChipStats::merge.)
    chip.sop = SopArray::new();
    chip.image_bank = super::image_bank::ImageBank::new(chip.cfg.n_ch, k);
    chip.memory.reset();

    let mut stats = ChipStats::default();
    let mut summers = ChannelSummers::new(n_out);
    let mut sb = ScaleBiasUnit::new(job.scale_bias.clone());
    let mut sink = OutputSink::new();
    let mut out = Image::zeros(n_out, out_h, out_w);
    let mut contributions = vec![0i64; n_out];

    // ---- Phase 1: filter load (Algorithm 1 line 5) -----------------------
    let fb_rot0 = chip.filter_bank.rotate_events;
    let fb_bits0 = chip.filter_bank.bits_loaded;
    stats.cycles.filter_load = chip.filter_bank.load(job.kernels.clone());
    stats.input_words += stats.cycles.filter_load;

    // ---- Phase 2: preload m columns (lines 6–7) --------------------------
    let m = job.preload_m();
    for col in 0..m {
        for y in 0..h {
            for c in 0..n_in {
                chip.memory.write(col, c * h + y, job.image.at(c, y, col));
                chip.memory.end_cycle();
                stats.cycles.preload += 1;
                stats.input_words += 1;
            }
        }
    }

    // ---- Main loop (lines 9–33) ------------------------------------------
    for x in 0..out_w {
        // Column switch: rotate the filter-bank columns instead of moving
        // image data (Fig. 5 / Eq. 4); reset the vertical window.
        if x > 0 {
            chip.filter_bank.rotate();
        }
        debug_assert_eq!(chip.filter_bank.shift(), x % k);
        chip.image_bank.reset();

        // Column refill: fetch the window's first k−1 rows. Column 0's
        // real-row fetches are counted preload cycles; later columns
        // overlap the previous column's drain (η_border = 1 when
        // zero-padded), so only the bank events are counted.
        for wrow in 0..(k - 1) {
            let img_row = wrow as isize - geo.offset;
            for c in 0..n_in {
                fetch_row(chip, &geo, job, &mut stats, x, img_row, c);
                if x == 0 && img_row >= 0 {
                    stats.cycles.preload += 1;
                }
            }
        }

        for y in 0..out_h {
            // Steady-state: fetch the window's bottom row, one channel per
            // cycle, and accumulate that channel's contribution.
            let img_row = y as isize + (k - 1) as isize - geo.offset;
            summers.clear();
            for i in 0..n_in {
                fetch_row(chip, &geo, job, &mut stats, x, img_row, i);
                chip.sop.accumulate(
                    &chip.image_bank,
                    &chip.filter_bank,
                    i,
                    n_out,
                    n_sop_slots,
                    &mut contributions,
                );
                for (o, &contrib) in contributions.iter().enumerate() {
                    summers.add(o, contrib);
                }
                stats.cycles.compute += 1;
            }
            // Output-drain idling (Eq. 10): the Scale-Bias unit streams
            // ⌈n_out/streams⌉ pixels while the SoPs sit silenced.
            let idle = drain_cycles.saturating_sub(n_in as u64);
            stats.cycles.idle += idle;
            // Interleaved scale-bias + stream-out (lines 27–33).
            for o in 0..n_out {
                let v = sb.apply(o, summers.value(o));
                sink.emit(o, y, x, v);
                *out.at_mut(o, y, x) = v;
            }
        }
    }
    // Tail flush: the last pixel's outputs stream with no overlapping
    // compute.
    stats.cycles.flush = drain_cycles;

    // ---- Gather unit counters --------------------------------------------
    stats.scm_reads = chip.memory.total_reads();
    stats.scm_writes = chip.memory.total_writes();
    stats.scm_max_banks_per_cycle = chip.memory.max_banks_per_cycle;
    stats.sop_active_ops = chip.sop.active_ops;
    stats.sop_silenced_ops = chip.sop.silenced_ops;
    stats.fb_rotations = chip.filter_bank.rotate_events - fb_rot0;
    stats.fb_bits_loaded = chip.filter_bank.bits_loaded - fb_bits0;
    stats.bank_row_fetches = chip.image_bank.row_fetches;
    stats.summer_adds = summers.adds;
    stats.summer_saturations = summers.saturations;
    stats.sb_ops = sb.ops;
    stats.output_words = sink.words;
    stats.useful_ops = 2 * (k * k) as u64 * (n_in * n_out) as u64 * (out_h * out_w) as u64;
    (out, sink, stats)
}

/// Fetch one window row for channel `c` at output column `x` — one memory
/// cycle: up to k−1 pixels from the stored SCM columns plus the live
/// column's pixel delivered just-in-time from the input stream (the one
/// bank write of Fig. 7). Rows/columns outside the image read the
/// zero-padding halo muxes.
fn fetch_row(
    chip: &mut Chip,
    geo: &Geometry,
    job: &BlockJob,
    stats: &mut ChipStats,
    x: usize,
    img_row: isize,
    c: usize,
) {
    let k = geo.k;
    let h = geo.h;
    // Stack buffer: this runs once per simulated cycle — no allocation
    // on the hot path (§Perf iteration 3).
    let mut bottom = [0i64; 7];
    let bottom = &mut bottom[..k];
    for (p, slot) in bottom.iter_mut().enumerate() {
        let (lcol, is_live) = geo.slot_column(x, p);
        if lcol < 0 || lcol >= geo.w as isize || img_row < 0 || img_row >= h as isize {
            *slot = 0; // zero-padding mux (§III-E)
            continue;
        }
        let (col, row) = (lcol as usize, img_row as usize);
        let px = job.image.at(c, row, col);
        if is_live {
            // Just-in-time delivery: write to the retired column's slot,
            // forward combinationally to the image bank.
            chip.memory.write(col, c * h + row, px);
            stats.input_words += 1;
            *slot = px;
        } else {
            *slot = chip.memory.read(col, c * h + row);
            debug_assert_eq!(*slot, px, "SCM content diverged from source image");
        }
    }
    chip.image_bank.push_row(c, bottom);
    chip.memory.end_cycle();
}
