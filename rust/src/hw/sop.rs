//! The Sum-of-Product units (§III-B/E, Fig. 9).
//!
//! Each of the `n_ch` SoP units holds 50 binary "multipliers" — a two's
//! complement stage and a multiplexer each, no actual multiplier — plus an
//! adder tree. Per cycle a SoP adds one input channel's k×k window,
//! weighted ±1, producing:
//!
//! * 7×7 mode: one partial sum (49 of 50 operators used) for one output
//!   channel, or
//! * dual mode: **two** partial sums for two output channels from two 5×5
//!   (or 3×3) filters packed into the 2×25 operator halves.
//!
//! Unused operators and adder-tree branches are silenced/clock-gated; the
//! simulator counts active vs silenced operator-cycles for the energy
//! model.

use super::filter_bank::FilterBank;
use super::image_bank::ImageBank;

/// Operators per SoP unit (49 for one 7×7, 50 for two 5×5).
pub const OPS_PER_SOP: usize = 50;

/// The SoP array activity counters.
#[derive(Debug, Clone, Default)]
pub struct SopArray {
    /// Active binary-operator evaluations (switching energy).
    pub active_ops: u64,
    /// Silenced operator-cycles (clock-gated, ~zero dynamic power).
    pub silenced_ops: u64,
}

impl SopArray {
    /// New array.
    pub fn new() -> SopArray {
        SopArray::default()
    }

    /// One cycle of the array: add input channel `i`'s window contribution
    /// for every output channel into `acc` (the raw, pre-saturation adder
    /// outputs; the ChannelSummers apply Q7.9 saturation).
    ///
    /// `n_sop_slots` is the total operator budget of the chip
    /// (`n_ch × OPS_PER_SOP`), used to account silenced operators.
    pub fn accumulate(
        &mut self,
        bank: &ImageBank,
        fb: &FilterBank,
        i: usize,
        n_out: usize,
        n_sop_slots: usize,
        acc: &mut [i64],
    ) {
        debug_assert_eq!(acc.len(), n_out);
        let k = bank.k();
        let win = bank.window(i);
        // Hot path (§Perf): branch-free dots of the window against the
        // filter bank's rotation-resolved ±1 view. Dispatching on the
        // compile-time window size gives LLVM fixed trip counts to unroll
        // and vectorize.
        let (weights, stride) = fb.resolved_raw();
        match k * k {
            49 => dot_all::<49>(win, weights, stride, i, acc),
            36 => dot_all::<36>(win, weights, stride, i, acc),
            25 => dot_all::<25>(win, weights, stride, i, acc),
            16 => dot_all::<16>(win, weights, stride, i, acc),
            9 => dot_all::<9>(win, weights, stride, i, acc),
            4 => dot_all::<4>(win, weights, stride, i, acc),
            1 => dot_all::<1>(win, weights, stride, i, acc),
            other => panic!("unsupported window size {other}"),
        }
        let used = (n_out * k * k) as u64;
        self.active_ops += used;
        self.silenced_ops += (n_sop_slots as u64).saturating_sub(used);
    }
}

/// Fixed-size dot of one window against every output channel's resolved
/// ±1 kernel (layout `[(o·stride + i)·KK ..]`). i32 lanes: |Σ ±px| ≤
/// 49·2048 ≪ 2^31, so the whole dot vectorizes in 32-bit lanes (needs
/// SSE4.1+ `pmulld`; `.cargo/config.toml` sets target-cpu=native).
#[inline]
fn dot_all<const KK: usize>(
    win: &[i32],
    weights: &[i32],
    stride: usize,
    i: usize,
    acc: &mut [i64],
) {
    let w: &[i32; KK] = win[..KK].try_into().unwrap();
    for (o, a) in acc.iter_mut().enumerate() {
        let base = (o * stride + i) * KK;
        let f: &[i32; KK] = weights[base..base + KK].try_into().unwrap();
        let mut sum = 0i32;
        for j in 0..KK {
            sum += w[j] * f[j];
        }
        *a = sum as i64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::BinaryKernels;

    fn setup(k: usize, n_out: usize, n_in: usize) -> (ImageBank, FilterBank) {
        let mut fb = FilterBank::new();
        fb.load(BinaryKernels::random(&mut Gen::new(7), n_out, n_in, k));
        (ImageBank::new(n_in, k), fb)
    }

    #[test]
    fn all_plus_weights_sum_window() {
        let mut fb = FilterBank::new();
        fb.load(BinaryKernels::all_plus(1, 1, 3));
        let mut bank = ImageBank::new(1, 3);
        bank.push_row(0, &[1, 2, 3]);
        bank.push_row(0, &[4, 5, 6]);
        bank.push_row(0, &[7, 8, 9]);
        let mut sop = SopArray::new();
        let mut acc = vec![0i64];
        sop.accumulate(&bank, &fb, 0, 1, 32 * OPS_PER_SOP, &mut acc);
        assert_eq!(acc[0], 45);
        assert_eq!(sop.active_ops, 9);
        assert_eq!(sop.silenced_ops, (32 * OPS_PER_SOP - 9) as u64);
    }

    #[test]
    fn sign_flip_negates() {
        let mut g = Gen::new(8);
        let ks = BinaryKernels::random(&mut g, 1, 1, 3);
        let mut inv = ks.clone();
        for b in inv.bits.iter_mut() {
            *b = !*b;
        }
        let (mut bank, _) = setup(3, 1, 1);
        bank.push_row(0, &[5, -3, 2]);
        bank.push_row(0, &[0, 7, -1]);
        bank.push_row(0, &[4, 4, 4]);
        let mut fb1 = FilterBank::new();
        fb1.load(ks);
        let mut fb2 = FilterBank::new();
        fb2.load(inv);
        let (mut s1, mut s2) = (SopArray::new(), SopArray::new());
        let (mut a1, mut a2) = (vec![0i64], vec![0i64]);
        s1.accumulate(&bank, &fb1, 0, 1, 100, &mut a1);
        s2.accumulate(&bank, &fb2, 0, 1, 100, &mut a2);
        assert_eq!(a1[0], -a2[0]);
    }

    #[test]
    fn multiple_outputs_per_cycle() {
        let (mut bank, fb) = setup(3, 4, 2);
        bank.push_row(1, &[1, 1, 1]);
        let mut sop = SopArray::new();
        let mut acc = vec![0i64; 4];
        sop.accumulate(&bank, &fb, 1, 4, 100, &mut acc);
        // Contributions are bounded by the window magnitude: |Σ ±x| ≤ 3.
        for a in acc {
            assert!(a.abs() <= 3);
        }
        assert_eq!(sop.active_ops, 4 * 9);
    }
}
