//! The fixed-point Q2.9 baseline architecture of Table I: identical
//! dataflow, but 12-bit weights, 12×12-bit multipliers in the SoP units
//! and an SRAM image memory (which floors the supply at 0.8 V).
//!
//! The baseline shares the binary chip's schedule, so its cycle counts
//! differ only in the filter-load phase (12× the weight bits). Its
//! datapath semantics: Q2.9 × Q2.9 products (Q5.18, 24-bit) are summed in
//! a full-precision adder tree, truncated to Q7.9 at the tree root, then
//! accumulated in the saturating ChannelSummers and scale-biased exactly
//! like the binary design.

use crate::fixedpoint::{resize, sat_add, scale_bias, QFormat, Q7_9};
use crate::workload::{Image, ScaleBias};

/// Q5.18 adder-tree root format (Q2.9 × Q2.9 products, 24 bit).
pub const Q5_18: QFormat = QFormat { int_bits: 5, frac_bits: 18 };

/// A fixed-point kernel set: 12-bit Q2.9 weights.
#[derive(Debug, Clone)]
pub struct Q29Kernels {
    /// Output channels.
    pub n_out: usize,
    /// Input channels.
    pub n_in: usize,
    /// Kernel size.
    pub k: usize,
    /// Raw Q2.9 weights, layout `[(o·n_in + i)·k² + dy·k + dx]`.
    pub weights: Vec<i64>,
}

impl Q29Kernels {
    /// Random kernel set with weights in (−1, 1).
    pub fn random(gen: &mut crate::testkit::Gen, n_out: usize, n_in: usize, k: usize) -> Self {
        let weights =
            (0..n_out * n_in * k * k).map(|_| gen.range_i64(-511, 511)).collect();
        Q29Kernels { n_out, n_in, k, weights }
    }

    /// Binarize to ±1 (raw ±512 is NOT used — binarization maps to exact
    /// ±1 weights in the binary datapath; this helper returns the sign
    /// pattern for baseline-vs-binary experiments).
    pub fn signs(&self) -> crate::workload::BinaryKernels {
        crate::workload::BinaryKernels {
            n_out: self.n_out,
            n_in: self.n_in,
            k: self.k,
            bits: self.weights.iter().map(|&w| w >= 0).collect(),
        }
    }

    /// Raw weight accessor.
    #[inline]
    pub fn weight(&self, o: usize, i: usize, dy: usize, dx: usize) -> i64 {
        self.weights[((o * self.n_in + i) * self.k + dy) * self.k + dx]
    }

    /// Storage bits: 12 per weight — the paper's 12× filter-bank cost.
    pub fn storage_bits(&self) -> usize {
        self.weights.len() * 12
    }
}

/// Bit-true functional model of the baseline's convolution (zero-padded or
/// valid), mirroring `workload::reference_conv` with multipliers.
pub fn q29_conv(img: &Image, kernels: &Q29Kernels, sb: &ScaleBias, zero_pad: bool) -> Image {
    assert_eq!(img.c, kernels.n_in);
    let k = kernels.k;
    let (out_h, out_w) = if zero_pad { (img.h, img.w) } else { (img.h - k + 1, img.w - k + 1) };
    let half = (k - 1) / 2;
    let mut out = Image::zeros(kernels.n_out, out_h, out_w);
    for o in 0..kernels.n_out {
        for y in 0..out_h {
            for x in 0..out_w {
                let mut acc: i64 = 0;
                for i in 0..img.c {
                    // Adder tree over Q5.18 products, truncated to Q7.9.
                    let mut tree: i64 = 0;
                    for dy in 0..k {
                        for dx in 0..k {
                            let (yy, xx) = if zero_pad {
                                (
                                    y as isize + dy as isize - half as isize,
                                    x as isize + dx as isize - half as isize,
                                )
                            } else {
                                ((y + dy) as isize, (x + dx) as isize)
                            };
                            let px = img.at_padded(i, yy, xx);
                            tree += px * kernels.weight(o, i, dy, dx); // Q5.18
                        }
                    }
                    acc = sat_add(Q7_9, acc, resize(Q5_18, tree, Q7_9));
                }
                *out.at_mut(o, y, x) = scale_bias(acc, sb.alpha[o], sb.beta[o]);
            }
        }
    }
    out
}

/// Cycle model of the baseline: identical to the binary schedule except
/// the filter load streams 12-bit weights (one per cycle on the 12-bit
/// bus).
pub fn q29_filter_load_cycles(n_out: usize, n_in: usize, k: usize) -> u64 {
    (n_out * n_in * k * k) as u64 // 12 bits each over a 12-bit bus
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::random_image;

    #[test]
    fn identity_kernel_passthrough() {
        // A 1×1 kernel with weight 1.0 (raw 512): Q5.18 product resized to
        // Q7.9 reproduces the pixel; identity scale passes it out.
        let mut img = Image::zeros(1, 2, 2);
        *img.at_mut(0, 0, 0) = 700;
        *img.at_mut(0, 1, 1) = -301;
        let kernels = Q29Kernels { n_out: 1, n_in: 1, k: 1, weights: vec![512] };
        let out = q29_conv(&img, &kernels, &ScaleBias::identity(1), true);
        assert_eq!(out.at(0, 0, 0), 700);
        assert_eq!(out.at(0, 1, 1), -301);
    }

    #[test]
    fn truncation_is_applied_at_tree_root() {
        // Weight 0.5 (raw 256) on pixel raw 3: product 768 in Q5.18 =
        // 1.5 LSB(Q7.9) → truncates to 1.
        let mut img = Image::zeros(1, 1, 1);
        *img.at_mut(0, 0, 0) = 3;
        let kernels = Q29Kernels { n_out: 1, n_in: 1, k: 1, weights: vec![256] };
        let out = q29_conv(&img, &kernels, &ScaleBias::identity(1), true);
        assert_eq!(out.at(0, 0, 0), 1);
    }

    #[test]
    fn binarized_baseline_matches_binary_reference() {
        // Binarizing the Q2.9 weights and running the binary reference
        // must equal the baseline run with weights forced to ±1.0.
        let mut g = Gen::new(42);
        let img = random_image(&mut g, 2, 6, 6, 0.02);
        let q = Q29Kernels::random(&mut g, 3, 2, 3);
        let bin = q.signs();
        let pm1 = Q29Kernels {
            n_out: q.n_out,
            n_in: q.n_in,
            k: q.k,
            weights: q.weights.iter().map(|&w| if w >= 0 { 512 } else { -512 }).collect(),
        };
        let sb = ScaleBias::identity(3);
        let a = q29_conv(&img, &pm1, &sb, true);
        let b = crate::workload::reference_conv(&img, &bin, &sb, true);
        assert_eq!(a, b);
    }

    #[test]
    fn weight_storage_is_12x_binary() {
        let mut g = Gen::new(1);
        let q = Q29Kernels::random(&mut g, 8, 8, 7);
        assert_eq!(q.storage_bits(), 12 * q.signs().storage_bits());
        assert_eq!(q29_filter_load_cycles(8, 8, 7), 8 * 8 * 49);
    }
}
