//! Chip configuration and per-block job descriptors.

use crate::model::KernelMode;
use crate::workload::{BinaryKernels, Image, ScaleBias};

/// Static configuration of a simulated chip instance.
#[derive(Debug, Clone, Copy)]
pub struct ChipConfig {
    /// Channel parallelism: the chip computes `n_ch × n_ch` channels
    /// (×2 output channels in dual-filter modes).
    pub n_ch: usize,
    /// Whether the dual 5×5 / 3×3 modes are implemented (§III-E).
    pub multi_kernel: bool,
    /// Total image-memory rows (1024 for the taped-out chip): stores
    /// `image_mem_rows / n_ch` image rows per input channel.
    pub image_mem_rows: usize,
    /// Column slots in the image memory. The stripe is `b_k = 7` columns
    /// wide (§III): per cycle 6 column slots are *read* and the live
    /// streaming column's slot is *written* (Fig. 7) — so 7 slots must be
    /// resident. (The paper itself is off by one column between §III's
    /// "10.8 kB" stripe and the floorplan's "9.2 KiB" 6×8 bank matrix; we
    /// model the 7 slots residency requires and note the discrepancy in
    /// EXPERIMENTS.md.)
    pub mem_columns: usize,
    /// SCM bank rows (128 ⇒ 8 row-groups × 6 columns = 48 banks).
    pub scm_bank_rows: usize,
}

impl ChipConfig {
    /// The taped-out YodaNN configuration (32×32 channels, multi-kernel).
    pub fn yodann() -> ChipConfig {
        ChipConfig {
            n_ch: 32,
            multi_kernel: true,
            image_mem_rows: 1024,
            mem_columns: 7,
            scm_bank_rows: 128,
        }
    }

    /// The 8×8-channel fixed-7×7 variant of Table I.
    pub fn bin8() -> ChipConfig {
        ChipConfig {
            n_ch: 8,
            multi_kernel: false,
            image_mem_rows: 1024,
            mem_columns: 7,
            scm_bank_rows: 128,
        }
    }

    /// A scaled-down configuration for fast exhaustive tests (identical
    /// control logic, smaller arrays).
    pub fn tiny(n_ch: usize) -> ChipConfig {
        ChipConfig {
            n_ch,
            multi_kernel: true,
            image_mem_rows: 64 * n_ch.max(1),
            mem_columns: 7,
            scm_bank_rows: 16,
        }
    }

    /// Maximum image-tile height per input channel (the `h_max` of Eq. 9).
    pub fn h_max(&self) -> usize {
        self.image_mem_rows / self.n_ch
    }

    /// Number of SCM banks (columns × row-groups).
    pub fn scm_banks(&self) -> usize {
        self.mem_columns * self.image_mem_rows.div_ceil(self.scm_bank_rows)
    }
}

/// One unit of chip work: a convolution of up to `n_ch` input channels
/// into up to `n_ch` (or `2·n_ch` in dual modes) output channels over one
/// image tile. Produced by the coordinator's block decomposition.
#[derive(Debug, Clone)]
pub struct BlockJob {
    /// Kernel size (1..=7).
    pub k: usize,
    /// Zero-pad the borders (halo synthesized on-chip).
    pub zero_pad: bool,
    /// Input image tile (c = n_in ≤ n_ch, h ≤ h_max).
    pub image: Image,
    /// Binary kernels: `n_out × n_in`.
    pub kernels: BinaryKernels,
    /// Per-output-channel scale/bias.
    pub scale_bias: ScaleBias,
}

impl BlockJob {
    /// Hardware slot mode for this job on `cfg`.
    pub fn mode(&self, cfg: &ChipConfig) -> KernelMode {
        if cfg.multi_kernel {
            KernelMode::for_kernel(self.k)
        } else {
            KernelMode::Slot7
        }
    }

    /// Output streams used (1 or 2).
    pub fn streams(&self, cfg: &ChipConfig) -> usize {
        if cfg.multi_kernel {
            self.mode(cfg).filters_per_sop()
        } else {
            1
        }
    }

    /// Output height of the tile.
    pub fn out_h(&self) -> usize {
        if self.zero_pad {
            self.image.h
        } else {
            self.image.h - self.k + 1
        }
    }

    /// Output width of the tile.
    pub fn out_w(&self) -> usize {
        if self.zero_pad {
            self.image.w
        } else {
            self.image.w - self.k + 1
        }
    }

    /// Window offset: how far the window extends left/above the output
    /// pixel (the zero-padding halo). Asymmetric for even kernels.
    pub fn offset(&self) -> usize {
        if self.zero_pad {
            (self.k - 1) / 2
        } else {
            0
        }
    }

    /// Columns preloaded before the first valid output — the paper's `m`
    /// (Algorithm 1 line 6): `⌊(h_k−1)/2⌋` zero-padded, `h_k − 1` not.
    /// Generalized as `k − 1 − offset` so even kernels (asymmetric halo)
    /// preload the correct count too.
    pub fn preload_m(&self) -> usize {
        self.k - 1 - self.offset()
    }

    /// Validate the job against a chip configuration; returns a
    /// description of the violation if any.
    pub fn validate(&self, cfg: &ChipConfig) -> Result<(), String> {
        if self.k == 0 || self.k > 7 {
            return Err(format!("kernel size {} unsupported (1..=7)", self.k));
        }
        if self.kernels.k != self.k {
            return Err("kernel descriptor size mismatch".into());
        }
        if self.image.c != self.kernels.n_in {
            return Err(format!(
                "image channels {} != kernel n_in {}",
                self.image.c, self.kernels.n_in
            ));
        }
        if self.image.c > cfg.n_ch {
            return Err(format!("n_in {} exceeds n_ch {}", self.image.c, cfg.n_ch));
        }
        let max_out = cfg.n_ch * self.streams(cfg);
        if self.kernels.n_out > max_out {
            return Err(format!("n_out {} exceeds {} for this mode", self.kernels.n_out, max_out));
        }
        if self.scale_bias.alpha.len() != self.kernels.n_out
            || self.scale_bias.beta.len() != self.kernels.n_out
        {
            return Err("scale/bias arity mismatch".into());
        }
        if self.image.h > cfg.h_max() {
            return Err(format!("tile height {} exceeds h_max {}", self.image.h, cfg.h_max()));
        }
        if !self.zero_pad && (self.image.h < self.k || self.image.w < self.k) {
            return Err("image smaller than kernel without zero-padding".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, BinaryKernels, ScaleBias};

    fn job(k: usize, c: usize, n_out: usize, h: usize, w: usize) -> BlockJob {
        let mut g = Gen::new(1);
        BlockJob {
            k,
            zero_pad: true,
            image: random_image(&mut g, c, h, w, 0.02),
            kernels: BinaryKernels::random(&mut g, n_out, c, k),
            scale_bias: ScaleBias::identity(n_out),
        }
    }

    #[test]
    fn yodann_geometry() {
        let cfg = ChipConfig::yodann();
        assert_eq!(cfg.h_max(), 32);
        assert_eq!(cfg.scm_banks(), 56); // 7 slots x 8 row-groups (6 read + 1 written per cycle)
    }

    #[test]
    fn validation_catches_violations() {
        let cfg = ChipConfig::yodann();
        assert!(job(3, 32, 64, 32, 16).validate(&cfg).is_ok());
        assert!(job(7, 32, 32, 32, 16).validate(&cfg).is_ok());
        // 7×7 mode only streams 32 output channels.
        assert!(job(7, 32, 64, 32, 16).validate(&cfg).is_err());
        // Too many input channels.
        assert!(job(3, 33, 32, 32, 16).validate(&cfg).is_err());
        // Tile too tall.
        assert!(job(3, 32, 32, 33, 16).validate(&cfg).is_err());
        // Non-multi chip cannot use dual mode.
        let cfg8 = ChipConfig::bin8();
        assert!(job(3, 8, 16, 128, 16).validate(&cfg8).is_err());
        assert!(job(3, 8, 8, 128, 16).validate(&cfg8).is_ok());
    }

    #[test]
    fn preload_m_matches_algorithm1() {
        let mut j = job(7, 4, 4, 16, 16);
        assert_eq!(j.preload_m(), 3); // zero-padded: ⌊(h_k−1)/2⌋
        j.zero_pad = false;
        assert_eq!(j.preload_m(), 6); // not padded: h_k−1
        let j1 = job(1, 4, 4, 16, 16);
        assert_eq!(j1.preload_m(), 0); // 1×1 needs no preload
    }

    #[test]
    fn streams_follow_mode() {
        let cfg = ChipConfig::yodann();
        assert_eq!(job(7, 4, 4, 16, 16).streams(&cfg), 1);
        assert_eq!(job(5, 4, 4, 16, 16).streams(&cfg), 2);
        assert_eq!(job(3, 4, 4, 16, 16).streams(&cfg), 2);
        let cfg8 = ChipConfig::bin8();
        assert_eq!(job(3, 4, 4, 16, 16).streams(&cfg8), 1);
    }
}
