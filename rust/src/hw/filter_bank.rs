//! The Filter Bank (§III): a shift-register array holding
//! `n_ch × n_ch` (× 2 in dual modes) binary kernels of up to 7×7 bits,
//! with **column-wise circular shift per kernel** so that the sliding
//! window never moves image data (Fig. 5, Eqs. 2–4).
//!
//! Hardware performs a physical rotate of every kernel's columns; the
//! simulator keeps a rotation offset and applies it on read — bit-identical
//! behaviour, and the rotate events are still counted for the energy model.

use crate::workload::BinaryKernels;

/// Simulated filter bank.
#[derive(Debug, Clone)]
pub struct FilterBank {
    /// Loaded kernels (already binary bits).
    kernels: Option<BinaryKernels>,
    /// Current circular column shift (0..k).
    shift: usize,
    /// Rotation-resolved ±1 weights in window coordinates, contiguous per
    /// (o, i) kernel — the simulator's hot-path view. All `k` rotation
    /// planes are precomputed at load time (plane r = the weights as seen
    /// after r column switches); `rotate()` just selects a plane, so the
    /// per-column cost is O(1) (§Perf iterations 1 & 5 in EXPERIMENTS.md).
    resolved: Vec<i32>,
    /// Elements per rotation plane (`n_out · n_in · k²`).
    plane: usize,
    /// Total rotate events (for the energy model).
    pub rotate_events: u64,
    /// Bits loaded so far (streaming load is 12 bits/cycle).
    pub bits_loaded: u64,
}

impl FilterBank {
    /// Empty bank.
    pub fn new() -> FilterBank {
        FilterBank {
            kernels: None,
            shift: 0,
            resolved: Vec::new(),
            plane: 0,
            rotate_events: 0,
            bits_loaded: 0,
        }
    }

    fn rebuild_resolved(&mut self) {
        let ks = self.kernels.as_ref().expect("rebuild before load");
        let k = ks.k;
        self.plane = ks.bits.len();
        self.resolved.clear();
        self.resolved.reserve(self.plane * k);
        for shift in 0..k {
            for o in 0..ks.n_out {
                for i in 0..ks.n_in {
                    for dy in 0..k {
                        for p in 0..k {
                            let logical_dx = (p + k - shift) % k;
                            self
                                .resolved
                                .push(if ks.bit(o, i, dy, logical_dx) { 1 } else { -1 });
                        }
                    }
                }
            }
        }
    }

    /// Load a full kernel set, returning the number of **cycles** the
    /// 12-bit input stream needs to deliver it (1 bit per binary weight).
    pub fn load(&mut self, kernels: BinaryKernels) -> u64 {
        let bits = kernels.storage_bits() as u64;
        self.bits_loaded += bits;
        self.kernels = Some(kernels);
        self.shift = 0;
        self.rebuild_resolved();
        bits.div_ceil(12)
    }

    /// Circular right-shift of all kernel columns (one column switch) —
    /// O(1): selects the precomputed rotation plane.
    pub fn rotate(&mut self) {
        let k = self.kernels.as_ref().expect("rotate before load").k;
        self.shift = (self.shift + 1) % k;
        self.rotate_events += 1;
    }

    /// Reset the rotation (new tile / block).
    pub fn reset_rotation(&mut self) {
        self.shift = 0;
    }

    /// The rotation-resolved ±1 weights of kernel (o, i), length k², in
    /// window coordinates (hot-path accessor).
    #[inline]
    pub fn resolved(&self, o: usize, i: usize) -> &[i32] {
        let ks = self.kernels.as_ref().expect("resolved before load");
        let kk = ks.k * ks.k;
        let base = self.shift * self.plane + (o * ks.n_in + i) * kk;
        &self.resolved[base..base + kk]
    }

    /// The current rotation plane plus the per-output stride (`n_in`),
    /// for the SoP array's batched hot loop.
    #[inline]
    pub fn resolved_raw(&self) -> (&[i32], usize) {
        let ks = self.kernels.as_ref().expect("resolved before load");
        let base = self.shift * self.plane;
        (&self.resolved[base..base + self.plane], ks.n_in)
    }

    /// Current rotation offset (test hook).
    pub fn shift(&self) -> usize {
        self.shift
    }

    /// Weight for output channel `o`, input channel `i` at kernel position
    /// `(dy, dx)` **in window coordinates**: `dx` indexes the window's
    /// physical column slot. After `s` column switches the new rightmost
    /// image column sits in the slot the oldest vacated, so physical slot
    /// `p` must read logical weight column `(p − s) mod k` — Eq. 3: after
    /// one switch the slots read `[w13 w11 w12]` for k = 3.
    #[inline]
    pub fn weight(&self, o: usize, i: usize, dy: usize, dx: usize) -> i64 {
        let ks = self.kernels.as_ref().expect("weight read before load");
        let logical_dx = (dx + ks.k - self.shift) % ks.k;
        ks.weight(o, i, dy, logical_dx)
    }

    /// Weight without rotation (logical kernel coordinates — used by the
    /// functional cross-check).
    #[inline]
    pub fn weight_logical(&self, o: usize, i: usize, dy: usize, dx: usize) -> i64 {
        self.kernels.as_ref().expect("weight read before load").weight(o, i, dy, dx)
    }

    /// Loaded kernel size.
    pub fn k(&self) -> usize {
        self.kernels.as_ref().map(|ks| ks.k).unwrap_or(0)
    }
}

impl Default for FilterBank {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    #[test]
    fn load_cycle_count_is_bits_over_12() {
        let mut fb = FilterBank::new();
        // The taped-out chip: 32×32 kernels × 49 bits = 50176 bits
        // → 4182 cycles on the 12-bit stream.
        let cycles = fb.load(BinaryKernels::random(&mut Gen::new(1), 32, 32, 7));
        assert_eq!(cycles, 50176_u64.div_ceil(12));
    }

    #[test]
    fn rotation_wraps_and_counts() {
        let mut fb = FilterBank::new();
        fb.load(BinaryKernels::random(&mut Gen::new(2), 2, 2, 3));
        assert_eq!(fb.shift(), 0);
        for _ in 0..3 {
            fb.rotate();
        }
        assert_eq!(fb.shift(), 0); // wrapped k=3
        assert_eq!(fb.rotate_events, 3);
    }

    #[test]
    fn rotated_read_matches_eq3_permutation() {
        // Eq. 3 (k = 3): after one column switch the physical slots apply
        // weight columns [w_3 w_1 w_2], i.e. slot p reads logical column
        // (p − 1) mod 3.
        let mut g = Gen::new(3);
        let ks = BinaryKernels::random(&mut g, 1, 1, 3);
        let mut fb = FilterBank::new();
        fb.load(ks.clone());
        fb.rotate();
        for dy in 0..3 {
            for dx in 0..3 {
                assert_eq!(fb.weight(0, 0, dy, dx), ks.weight(0, 0, dy, (dx + 2) % 3));
            }
        }
    }

    #[test]
    fn full_rotation_is_identity() {
        let mut g = Gen::new(4);
        let ks = BinaryKernels::random(&mut g, 2, 3, 5);
        let mut fb = FilterBank::new();
        fb.load(ks.clone());
        for _ in 0..5 {
            fb.rotate();
        }
        for o in 0..2 {
            for i in 0..3 {
                for dy in 0..5 {
                    for dx in 0..5 {
                        assert_eq!(fb.weight(o, i, dy, dx), ks.weight(o, i, dy, dx));
                    }
                }
            }
        }
    }
}
