//! The top-level chip: wires the units of Fig. 3 and runs block jobs.

use super::config::{BlockJob, ChipConfig};
use super::controller;
use super::filter_bank::FilterBank;
use super::image_bank::ImageBank;
use super::image_memory::ImageMemory;
use super::io::OutputSink;
use super::sop::SopArray;
use super::stats::ChipStats;
use crate::workload::Image;

/// Result of one block execution.
#[derive(Debug, Clone)]
pub struct BlockResult {
    /// Output tile (`n_out × out_h × out_w`, raw Q2.9).
    pub output: Image,
    /// Streamed output events in hardware order.
    pub sink: OutputSink,
    /// Activity statistics of the block.
    pub stats: ChipStats,
}

/// A simulated YodaNN chip instance.
pub struct Chip {
    /// Static configuration.
    pub cfg: ChipConfig,
    /// Binary-weight filter bank.
    pub filter_bank: FilterBank,
    /// Multi-banked SCM image memory.
    pub memory: ImageMemory,
    /// Sliding-window image bank.
    pub image_bank: ImageBank,
    /// SoP array activity.
    pub sop: SopArray,
}

impl Chip {
    /// Build a chip from a configuration.
    pub fn new(cfg: ChipConfig) -> Chip {
        Chip {
            cfg,
            filter_bank: FilterBank::new(),
            memory: ImageMemory::new(cfg.mem_columns, cfg.image_mem_rows, cfg.scm_bank_rows),
            image_bank: ImageBank::new(cfg.n_ch, 7),
            sop: SopArray::new(),
        }
    }

    /// The taped-out 32×32 multi-kernel configuration.
    pub fn yodann() -> Chip {
        Chip::new(ChipConfig::yodann())
    }

    /// Execute one block job (Algorithm 1's "YodaNN chip block").
    pub fn run_block(&mut self, job: &BlockJob) -> BlockResult {
        let (output, sink, stats) = controller::execute(self, job);
        BlockResult { output, sink, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::config::ChipConfig;
    use crate::testkit::Gen;
    use crate::workload::{random_image, reference_conv, BinaryKernels, ScaleBias};

    fn run(
        cfg: ChipConfig,
        k: usize,
        n_in: usize,
        n_out: usize,
        h: usize,
        w: usize,
        zero_pad: bool,
        seed: u64,
    ) -> (BlockResult, Image) {
        let mut g = Gen::new(seed);
        let image = random_image(&mut g, n_in, h, w, 0.03);
        let kernels = BinaryKernels::random(&mut g, n_out, n_in, k);
        let sb = ScaleBias::random(&mut g, n_out);
        let job = BlockJob { k, zero_pad, image: image.clone(), kernels: kernels.clone(), scale_bias: sb.clone() };
        let expect = reference_conv(&image, &kernels, &sb, zero_pad);
        let mut chip = Chip::new(cfg);
        (chip.run_block(&job), expect)
    }

    #[test]
    fn matches_reference_7x7_zero_padded() {
        let (res, expect) = run(ChipConfig::tiny(4), 7, 3, 4, 12, 11, true, 100);
        assert_eq!(res.output, expect);
    }

    #[test]
    fn matches_reference_7x7_non_padded() {
        let (res, expect) = run(ChipConfig::tiny(4), 7, 2, 3, 13, 12, false, 101);
        assert_eq!(res.output, expect);
    }

    #[test]
    fn matches_reference_all_kernel_sizes() {
        for k in 1..=7 {
            let (res, expect) = run(ChipConfig::tiny(4), k, 3, 4, 10, 9, true, 200 + k as u64);
            assert_eq!(res.output, expect, "k={k} zero-padded");
            if k > 1 {
                let (res, expect) =
                    run(ChipConfig::tiny(4), k, 2, 2, 10, 9, false, 300 + k as u64);
                assert_eq!(res.output, expect, "k={k} non-padded");
            }
        }
    }

    #[test]
    fn dual_mode_doubles_output_channels() {
        // 3×3 dual mode: n_out up to 2·n_ch.
        let (res, expect) = run(ChipConfig::tiny(4), 3, 4, 8, 8, 8, true, 400);
        assert_eq!(res.output, expect);
    }

    #[test]
    fn full_chip_small_block_matches_reference() {
        let (res, expect) = run(ChipConfig::yodann(), 3, 32, 64, 16, 8, true, 500);
        assert_eq!(res.output, expect);
        // Gating invariant: ≤ 7 banks active per cycle (§III-C).
        assert!(res.stats.scm_max_banks_per_cycle <= 7);
    }

    #[test]
    fn cycle_counts_match_analytic_model() {
        // Fully-utilized 7×7 block: compute cycles = out_pixels · n_in,
        // no idle.
        let cfg = ChipConfig::tiny(4);
        let (res, _) = run(cfg, 7, 4, 4, 12, 10, true, 600);
        let s = &res.stats;
        assert_eq!(s.cycles.compute, (12 * 10 * 4) as u64);
        assert_eq!(s.cycles.idle, 0);
        // Filter load: n_out·n_in·k² bits / 12 per cycle.
        assert_eq!(s.cycles.filter_load, ((4 * 4 * 49) as u64).div_ceil(12));
        // Preload: m columns × h × n_in + m live pixels × n_in.
        let m = 3;
        assert_eq!(s.cycles.preload, (m * 12 * 4 + m * 4) as u64);
    }

    #[test]
    fn channel_idling_cycles_match_eq10() {
        // n_in = 1, n_out = 4, single stream (7×7): each pixel takes
        // max(1, 4) cycles ⇒ 3 idle cycles per pixel.
        let (res, expect) = run(ChipConfig::tiny(4), 7, 1, 4, 9, 9, true, 700);
        assert_eq!(res.output, expect);
        let s = &res.stats;
        assert_eq!(s.cycles.idle, (9 * 9 * 3) as u64);
        // η_chIdle = useful compute fraction = 1/4.
        let eta = s.cycles.compute as f64 / (s.cycles.compute + s.cycles.idle) as f64;
        assert!((eta - 0.25).abs() < 1e-9);
    }

    #[test]
    fn input_stream_is_one_pixel_per_cycle() {
        // Aggregate input rate never exceeds one word per cycle: words ≤
        // filter-load + preload + compute cycles.
        let (res, _) = run(ChipConfig::tiny(4), 5, 3, 4, 14, 13, true, 800);
        let s = &res.stats;
        assert!(
            s.input_words <= s.cycles.filter_load + s.cycles.preload + s.cycles.compute,
            "{} vs {}",
            s.input_words,
            s.cycles.filter_load + s.cycles.preload + s.cycles.compute
        );
    }

    #[test]
    fn every_pixel_written_once() {
        // The sliding-window schedule writes each image pixel to SCM
        // exactly once (Fig. 5): writes = n_in·h·w when all columns fit.
        let (res, _) = run(ChipConfig::tiny(4), 7, 2, 2, 10, 10, true, 900);
        assert_eq!(res.stats.scm_writes, (2 * 10 * 10) as u64);
    }

    #[test]
    fn streamed_order_is_interleaved_by_channel() {
        let (res, _) = run(ChipConfig::tiny(2), 3, 2, 4, 4, 4, true, 1000);
        // For each (x, y), channels stream in order before the next pixel.
        let px = &res.sink.pixels;
        for chunk in px.chunks(4) {
            assert_eq!(chunk.len(), 4);
            for (o, p) in chunk.iter().enumerate() {
                assert_eq!(p.channel, o);
                assert_eq!((p.y, p.x), (chunk[0].y, chunk[0].x));
            }
        }
    }
}
