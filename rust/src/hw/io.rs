//! The I/O interface (§III): a 12-bit input stream and two 12-bit output
//! streams with a blocking ready/valid handshake.
//!
//! In the simulator the producer (coordinator) and consumer never starve
//! the chip on purpose, but the handshake is modelled so back-pressure
//! scenarios are testable: a stream with no data stalls the consumer and
//! the stall is counted (visible in the cycle breakdown).

use std::collections::VecDeque;

/// One direction of a 12-bit ready/valid stream.
#[derive(Debug, Clone, Default)]
pub struct Stream {
    fifo: VecDeque<i64>,
    /// Total words transferred.
    pub words: u64,
    /// Cycles the consumer stalled on an empty stream (or the producer on
    /// a full one, for bounded streams).
    pub stalls: u64,
}

impl Stream {
    /// New empty stream.
    pub fn new() -> Stream {
        Stream::default()
    }

    /// Producer side: offer one word (valid).
    pub fn push(&mut self, word: i64) {
        self.fifo.push_back(word);
    }

    /// Consumer side: take one word if valid, else record a stall.
    pub fn pop(&mut self) -> Option<i64> {
        match self.fifo.pop_front() {
            Some(w) => {
                self.words += 1;
                Some(w)
            }
            None => {
                self.stalls += 1;
                None
            }
        }
    }

    /// Words currently queued.
    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    /// True if no words are queued.
    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }
}

/// An output event on one of the chip's output streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutputPixel {
    /// Output channel.
    pub channel: usize,
    /// Output row.
    pub y: usize,
    /// Output column.
    pub x: usize,
    /// Raw Q2.9 value.
    pub value: i64,
}

/// Collects the chip's streamed output pixels (per stream).
#[derive(Debug, Clone, Default)]
pub struct OutputSink {
    /// Ordered output events.
    pub pixels: Vec<OutputPixel>,
    /// 12-bit words emitted.
    pub words: u64,
}

impl OutputSink {
    /// New empty sink.
    pub fn new() -> OutputSink {
        OutputSink::default()
    }

    /// Record one streamed pixel.
    pub fn emit(&mut self, channel: usize, y: usize, x: usize, value: i64) {
        self.pixels.push(OutputPixel { channel, y, x, value });
        self.words += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_counts() {
        let mut s = Stream::new();
        s.push(1);
        s.push(2);
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), Some(2));
        assert_eq!(s.pop(), None);
        assert_eq!(s.words, 2);
        assert_eq!(s.stalls, 1);
    }

    #[test]
    fn sink_records_events() {
        let mut sink = OutputSink::new();
        sink.emit(3, 1, 2, -77);
        assert_eq!(sink.words, 1);
        assert_eq!(sink.pixels[0], OutputPixel { channel: 3, y: 1, x: 2, value: -77 });
    }
}
