//! The Image Bank (§III): a 7×7-pixel window cache per input channel
//! (2.4 kB for 32 channels), fed one row per cycle. When processing moves
//! one row down, the upper rows shift up and only the new bottom row is
//! fetched (6 pixels from the SCM image memory + 1 from the live stream).
//!
//! Window columns are **physical slots**: a new image column replaces the
//! retired one in place (Fig. 5), and the weight columns rotate to match
//! (see [`super::filter_bank`]).

/// Simulated image bank: `n_ch` windows of `k × k` raw Q2.9 pixels.
#[derive(Debug, Clone)]
pub struct ImageBank {
    /// Kernel/window size.
    k: usize,
    /// Channels.
    n_ch: usize,
    /// Window storage `[c][dy][p]` flattened. Stored as i32 — pixels are
    /// 12-bit Q2.9, so the SoP dot stays in 32-bit SIMD lanes (§Perf
    /// iteration 4; an i16/pmaddwd variant measured slower and was
    /// reverted, §Perf iteration 6).
    window: Vec<i32>,
    /// Rows fetched (energy model: one fetch = one row of ≤7 pixel moves).
    pub row_fetches: u64,
}

impl ImageBank {
    /// New bank for `n_ch` channels and window size `k`.
    pub fn new(n_ch: usize, k: usize) -> ImageBank {
        ImageBank { k, n_ch, window: vec![0; n_ch * k * k], row_fetches: 0 }
    }

    /// Reset all windows to zero (column switch / new block).
    pub fn reset(&mut self) {
        self.window.iter_mut().for_each(|w| *w = 0);
    }

    /// Shift channel `c`'s window one row up and install `bottom` as the
    /// new last row (`bottom[p]` per physical column slot).
    pub fn push_row(&mut self, c: usize, bottom: &[i64]) {
        assert_eq!(bottom.len(), self.k);
        let base = c * self.k * self.k;
        let w = &mut self.window[base..base + self.k * self.k];
        w.copy_within(self.k.., 0);
        for (dst, &src) in w[self.k * (self.k - 1)..].iter_mut().zip(bottom) {
            debug_assert!(i32::try_from(src).is_ok());
            *dst = src as i32;
        }
        self.row_fetches += 1;
    }

    /// Pixel at window row `dy`, physical column slot `p` of channel `c`.
    #[inline]
    pub fn at(&self, c: usize, dy: usize, p: usize) -> i64 {
        self.window[(c * self.k + dy) * self.k + p] as i64
    }

    /// The full window of channel `c` (row-major `[dy][p]`, raw Q2.9
    /// in i32 lanes).
    #[inline]
    pub fn window(&self, c: usize) -> &[i32] {
        &self.window[c * self.k * self.k..(c + 1) * self.k * self.k]
    }

    /// Window size.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Channel count.
    pub fn n_ch(&self) -> usize {
        self.n_ch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_row_shifts_up() {
        let mut b = ImageBank::new(1, 3);
        b.push_row(0, &[1, 2, 3]);
        b.push_row(0, &[4, 5, 6]);
        b.push_row(0, &[7, 8, 9]);
        // Window rows: [1 2 3], [4 5 6], [7 8 9].
        assert_eq!(b.at(0, 0, 0), 1);
        assert_eq!(b.at(0, 2, 2), 9);
        b.push_row(0, &[10, 11, 12]);
        // Top row dropped.
        assert_eq!(b.at(0, 0, 0), 4);
        assert_eq!(b.at(0, 2, 1), 11);
        assert_eq!(b.row_fetches, 4);
    }

    #[test]
    fn channels_are_independent() {
        let mut b = ImageBank::new(2, 2);
        b.push_row(0, &[1, 1]);
        b.push_row(1, &[2, 2]);
        assert_eq!(b.at(0, 1, 0), 1);
        assert_eq!(b.at(1, 1, 0), 2);
        assert_eq!(b.at(0, 0, 0), 0); // untouched rows stay zero
    }

    #[test]
    fn storage_matches_paper() {
        // 32 channels × 7×7 × 12 bit = 2.35 kB ≈ the paper's 2.4 kB.
        let b = ImageBank::new(32, 7);
        let bits = b.window.len() * 12;
        assert_eq!(bits, 32 * 49 * 12);
        assert!((bits as f64 / 8.0 / 1024.0 - 2.3) < 0.1);
    }
}
