//! The ChannelSummers (§III): one Q7.9 saturating accumulator per output
//! channel, adding one SoP contribution per cycle until all input
//! channels of the block have been seen.

use crate::fixedpoint::{sat_add, Q7_9};

/// The bank of ChannelSummer accumulators.
#[derive(Debug, Clone)]
pub struct ChannelSummers {
    acc: Vec<i64>,
    /// Saturation events observed (diagnostics: saturating sums indicate
    /// the network needs smaller activations or per-layer scaling).
    pub saturations: u64,
    /// Accumulate operations performed.
    pub adds: u64,
}

impl ChannelSummers {
    /// New bank of `n` accumulators.
    pub fn new(n: usize) -> ChannelSummers {
        ChannelSummers { acc: vec![0; n], saturations: 0, adds: 0 }
    }

    /// Clear all accumulators (new output pixel).
    pub fn clear(&mut self) {
        self.acc.iter_mut().for_each(|a| *a = 0);
    }

    /// Add `contribution` to accumulator `o` with Q7.9 saturation — the
    /// hardware register is 17 bits wide, so saturation applies after
    /// every add (order-dependent, which is why the golden model must
    /// accumulate in the same input-channel order).
    pub fn add(&mut self, o: usize, contribution: i64) {
        let s = sat_add(Q7_9, self.acc[o], contribution);
        if s != self.acc[o] + contribution {
            self.saturations += 1;
        }
        self.acc[o] = s;
        self.adds += 1;
    }

    /// Current accumulator value (raw Q7.9).
    pub fn value(&self, o: usize) -> i64 {
        self.acc[o]
    }

    /// Number of accumulators.
    pub fn len(&self) -> usize {
        self.acc.len()
    }

    /// True if the bank is empty.
    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_clears() {
        let mut s = ChannelSummers::new(2);
        s.add(0, 100);
        s.add(0, -30);
        s.add(1, 7);
        assert_eq!(s.value(0), 70);
        assert_eq!(s.value(1), 7);
        s.clear();
        assert_eq!(s.value(0), 0);
        assert_eq!(s.adds, 3);
    }

    #[test]
    fn saturates_at_q79() {
        let mut s = ChannelSummers::new(1);
        s.add(0, 60_000);
        s.add(0, 60_000);
        assert_eq!(s.value(0), Q7_9.max_raw()); // 65535
        assert_eq!(s.saturations, 1);
        // Saturation is sticky only while contributions keep pushing out;
        // subtracting recovers (per real two's-complement+clamp register).
        s.add(0, -70_000);
        assert_eq!(s.value(0), 65535 - 70_000);
    }

    #[test]
    fn negative_saturation() {
        let mut s = ChannelSummers::new(1);
        s.add(0, -70_000);
        assert_eq!(s.value(0), Q7_9.min_raw()); // −65536
        assert_eq!(s.saturations, 1);
    }
}
