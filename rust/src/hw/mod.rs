//! Cycle-accurate, bit-true simulator of the YodaNN accelerator (§III).
//!
//! The simulator models every unit of Fig. 3 at the paper's per-cycle
//! granularity:
//!
//! * one 12-bit word enters per cycle (weights during filter load, pixels
//!   afterwards);
//! * each main-loop cycle processes **one input channel**: all `n_ch` SoP
//!   units add that channel's k×k contribution to their ChannelSummers,
//!   and that channel's next window row is fetched — 6 SCM bank reads plus
//!   one bank write, exactly the access pattern of Fig. 5/7;
//! * output pixels stream out interleaved through the Scale-Bias unit (one
//!   or two 12-bit streams);
//! * on a column switch the filter-bank columns circular-shift instead of
//!   moving image data (Eqs. 2–4).
//!
//! Cycle counts, bank-access counts and unit-activity counters are exact
//! with respect to this schedule; arithmetic is bit-true Q2.9/Q7.9/Q10.18
//! (see [`crate::fixedpoint`]). Energy is derived from the activity
//! counters via the calibrated per-event energies of
//! [`stats::EnergyModel`], giving a simulation-based estimate that
//! cross-checks the analytic model (`rust/tests/efficiency_vs_sim.rs`).
//!
//! [`baseline`] models the fixed-point Q2.9 comparison architecture of
//! Table I (12×12-bit MACs, 12-bit weights, SRAM).

pub mod baseline;
pub mod chip;
pub mod config;
pub mod controller;
pub mod filter_bank;
pub mod image_bank;
pub mod image_memory;
pub mod io;
pub mod scale_bias;
pub mod sop;
pub mod stats;
pub mod summer;

pub use chip::{BlockResult, Chip};
pub use config::{BlockJob, ChipConfig};
pub use stats::{ChipStats, CycleBreakdown, EnergyModel};
