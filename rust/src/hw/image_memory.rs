//! The latch-based SCM image memory (§III-C, Figs. 5 and 7).
//!
//! Physically: a 6×8 matrix of 12-bit × 128-row latch arrays (48 banks,
//! 6144 words for the taped-out chip). Logically: **6 stored window
//! columns** of `h · n_in` pixels each; the 7th window column is the live
//! streaming column, which is simultaneously **written** into the slot of
//! the retired (oldest) column — the Fig. 5 replacement policy that makes
//! the filter bank rotate instead of moving image data.
//!
//! Pre-decoding activates exactly one bank per read/write; the simulator
//! counts per-bank accesses so the clock-gating claim ("only up to 7 over
//! 48 banks consume dynamic power in every cycle") is checkable.

/// Simulated multi-banked SCM image memory.
#[derive(Debug, Clone)]
pub struct ImageMemory {
    /// Stored words, `slots × rows` (slot-major). A word is a raw Q2.9 px.
    data: Vec<i64>,
    /// Logical column index stored in each physical slot (None = empty).
    col_of_slot: Vec<Option<usize>>,
    /// Column slots (6).
    slots: usize,
    /// Rows per slot (`h · n_in` in use; capacity `image_mem_rows`).
    rows_capacity: usize,
    /// Rows per SCM bank (128).
    bank_rows: usize,
    /// Per-bank read counts (energy model / gating check).
    pub bank_reads: Vec<u64>,
    /// Per-bank write counts.
    pub bank_writes: Vec<u64>,
    /// Banks touched in the current cycle (gating invariant check).
    touched_this_cycle: Vec<usize>,
    /// Maximum banks active in any single cycle seen so far.
    pub max_banks_per_cycle: usize,
}

impl ImageMemory {
    /// New memory with `slots` column slots of `rows_capacity` words.
    pub fn new(slots: usize, rows_capacity: usize, bank_rows: usize) -> ImageMemory {
        let banks = slots * rows_capacity.div_ceil(bank_rows);
        ImageMemory {
            data: vec![0; slots * rows_capacity],
            col_of_slot: vec![None; slots],
            slots,
            rows_capacity,
            bank_rows,
            bank_reads: vec![0; banks],
            bank_writes: vec![0; banks],
            touched_this_cycle: Vec::with_capacity(8),
            max_banks_per_cycle: 0,
        }
    }

    /// Clear contents, slot map and per-block counters (new block — the
    /// coordinator aggregates per-block stats itself).
    pub fn reset(&mut self) {
        self.data.iter_mut().for_each(|w| *w = 0);
        self.col_of_slot.iter_mut().for_each(|s| *s = None);
        self.touched_this_cycle.clear();
        self.bank_reads.iter_mut().for_each(|c| *c = 0);
        self.bank_writes.iter_mut().for_each(|c| *c = 0);
        self.max_banks_per_cycle = 0;
    }

    fn bank_of(&self, slot: usize, row: usize) -> usize {
        slot * self.rows_capacity.div_ceil(self.bank_rows) + row / self.bank_rows
    }

    fn touch(&mut self, bank: usize) {
        if !self.touched_this_cycle.contains(&bank) {
            self.touched_this_cycle.push(bank);
        }
    }

    /// Advance to the next cycle: record and check the gating invariant
    /// (≤ stored-columns reads + 1 write = ≤ 7 banks active).
    pub fn end_cycle(&mut self) {
        let n = self.touched_this_cycle.len();
        self.max_banks_per_cycle = self.max_banks_per_cycle.max(n);
        debug_assert!(
            n <= self.slots + 1,
            "SCM gating violated: {n} banks active in one cycle"
        );
        self.touched_this_cycle.clear();
    }

    /// The physical slot currently holding logical column `col`, if stored.
    pub fn slot_of(&self, col: usize) -> Option<usize> {
        self.col_of_slot.iter().position(|c| *c == Some(col))
    }

    /// Allocate a slot for a new live column: reuse the slot of the oldest
    /// stored column (Fig. 5), or the first empty slot during preload.
    pub fn allocate(&mut self, col: usize) -> usize {
        if let Some(s) = self.slot_of(col) {
            return s; // already allocated (continuing a live column)
        }
        let slot = if let Some(empty) = self.col_of_slot.iter().position(|c| c.is_none()) {
            empty
        } else {
            // Evict the oldest logical column.
            let (oldest_slot, _) = self
                .col_of_slot
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| c.unwrap())
                .expect("non-empty");
            oldest_slot
        };
        self.col_of_slot[slot] = Some(col);
        slot
    }

    /// Write one pixel of logical column `col` at row `row` (the one bank
    /// write per cycle of Fig. 7).
    pub fn write(&mut self, col: usize, row: usize, word: i64) {
        assert!(row < self.rows_capacity, "image memory row {row} overflow");
        let slot = self.allocate(col);
        let bank = self.bank_of(slot, row);
        self.bank_writes[bank] += 1;
        self.touch(bank);
        self.data[slot * self.rows_capacity + row] = word;
    }

    /// Read one pixel of logical column `col` at row `row`. Panics if the
    /// column is not resident — the controller schedule must guarantee
    /// read-before-evict (this is the invariant the sliding-window design
    /// exists to maintain).
    pub fn read(&mut self, col: usize, row: usize) -> i64 {
        let slot = self
            .slot_of(col)
            .unwrap_or_else(|| panic!("read of non-resident column {col} (schedule bug)"));
        let bank = self.bank_of(slot, row);
        self.bank_reads[bank] += 1;
        self.touch(bank);
        self.data[slot * self.rows_capacity + row]
    }

    /// Total reads across banks.
    pub fn total_reads(&self) -> u64 {
        self.bank_reads.iter().sum()
    }

    /// Total writes across banks.
    pub fn total_writes(&self) -> u64 {
        self.bank_writes.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut m = ImageMemory::new(6, 64, 16);
        m.write(0, 5, 123);
        assert_eq!(m.read(0, 5), 123);
        assert_eq!(m.total_writes(), 1);
        assert_eq!(m.total_reads(), 1);
    }

    #[test]
    fn bank_geometry_matches_paper() {
        // 6 slots × 1024 rows / 128 per bank = 48 banks.
        let m = ImageMemory::new(6, 1024, 128);
        assert_eq!(m.bank_reads.len(), 48);
    }

    #[test]
    fn eviction_replaces_oldest() {
        let mut m = ImageMemory::new(3, 8, 4);
        for col in 0..3 {
            m.write(col, 0, col as i64);
        }
        // All slots full; column 3 must evict column 0.
        m.write(3, 0, 33);
        assert!(m.slot_of(0).is_none());
        assert_eq!(m.slot_of(3), Some(0)); // reused slot 0
        assert_eq!(m.read(3, 0), 33);
        assert_eq!(m.read(1, 0), 1); // others untouched
    }

    #[test]
    #[should_panic(expected = "non-resident")]
    fn reading_evicted_column_panics() {
        let mut m = ImageMemory::new(2, 4, 4);
        m.write(0, 0, 1);
        m.write(1, 0, 2);
        m.write(2, 0, 3); // evicts 0
        m.read(0, 0);
    }

    #[test]
    fn gating_invariant_tracks_max_banks() {
        // Real geometry: 7 column slots. A steady-state cycle reads the 6
        // stored columns and writes the live one — 7 banks active, the
        // paper's "only up to 7 over 48 banks consume dynamic power".
        let mut m = ImageMemory::new(7, 64, 16);
        for col in 0..7 {
            m.write(col, 0, col as i64);
            m.end_cycle();
        }
        for col in 0..6 {
            m.read(col, 0);
        }
        m.write(6, 1, 7);
        m.end_cycle();
        assert_eq!(m.max_banks_per_cycle, 7);
    }
}
