//! The Scale-Bias unit (§III-E): once all input-channel contributions are
//! summed, each output channel is scaled and biased in an interleaved
//! manner and streamed out:
//! `Q7.9 acc × Q2.9 α → Q10.18, + β, → saturate/truncate → Q2.9`.

use crate::fixedpoint;
use crate::workload::ScaleBias;

/// Simulated Scale-Bias unit with activity counters.
#[derive(Debug, Clone)]
pub struct ScaleBiasUnit {
    params: ScaleBias,
    /// Scale-bias operations performed (one per streamed output pixel).
    pub ops: u64,
}

impl ScaleBiasUnit {
    /// New unit with per-channel parameters.
    pub fn new(params: ScaleBias) -> ScaleBiasUnit {
        ScaleBiasUnit { params, ops: 0 }
    }

    /// Process one output-channel value (raw Q7.9 → raw Q2.9).
    pub fn apply(&mut self, o: usize, acc_q79: i64) -> i64 {
        self.ops += 1;
        fixedpoint::scale_bias(acc_q79, self.params.alpha[o], self.params.beta[o])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_passthrough() {
        let mut u = ScaleBiasUnit::new(ScaleBias::identity(2));
        assert_eq!(u.apply(0, 700), 700);
        assert_eq!(u.apply(1, -1024), -1024);
        assert_eq!(u.ops, 2);
    }

    #[test]
    fn per_channel_parameters() {
        let sb = ScaleBias { alpha: vec![256, 512], beta: vec![0, 512] };
        let mut u = ScaleBiasUnit::new(sb);
        // Channel 0: ×0.5 → 1.5·0.5 = 0.75 (raw 384).
        assert_eq!(u.apply(0, 768), 384);
        // Channel 1: ×1 + 1.0 → 1.5 + 1.0 = 2.5 (raw 1280).
        assert_eq!(u.apply(1, 768), 1280);
    }
}
