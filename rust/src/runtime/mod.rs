//! PJRT runtime: loads the AOT-compiled JAX/Pallas golden model
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! it on the XLA CPU client — the reproduction of the paper's Torch
//! golden-model testbench (§IV-B), with Python never on the request path.
//!
//! Artifacts are HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::workload::Image;
use crate::Result;
use anyhow::{anyhow, Context};

/// Metadata of one golden-block artifact (a `manifest.txt` line).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Artifact stem (`block_k3_c32x64_16x16`).
    pub name: String,
    /// Kernel size.
    pub k: usize,
    /// Input channels.
    pub n_in: usize,
    /// Output channels.
    pub n_out: usize,
    /// Tile height.
    pub h: usize,
    /// Tile width.
    pub w: usize,
    /// Zero-padded convolution.
    pub zero_pad: bool,
}

impl ArtifactMeta {
    /// Parse one manifest line: `name k n_in n_out h w zero_pad`.
    pub fn parse(line: &str) -> Result<ArtifactMeta> {
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 7 {
            return Err(anyhow!("bad manifest line: {line:?}"));
        }
        Ok(ArtifactMeta {
            name: f[0].to_string(),
            k: f[1].parse()?,
            n_in: f[2].parse()?,
            n_out: f[3].parse()?,
            h: f[4].parse()?,
            w: f[5].parse()?,
            zero_pad: f[6] == "1",
        })
    }
}

/// A compiled golden-block executable.
pub struct GoldenBlock {
    /// Artifact metadata.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl GoldenBlock {
    /// Execute the golden block: image `[n_in, h, w]`, weights
    /// `[n_out, n_in, k, k]` (±1), per-channel raw-Q2.9 scale/bias.
    /// Returns the raw-Q2.9 output image `[n_out, out_h, out_w]`.
    pub fn run(
        &self,
        image: &Image,
        weights: &crate::workload::BinaryKernels,
        sb: &crate::workload::ScaleBias,
    ) -> Result<Image> {
        let m = &self.meta;
        if (image.c, image.h, image.w) != (m.n_in, m.h, m.w) {
            return Err(anyhow!(
                "image {}x{}x{} does not match artifact {} ({}x{}x{})",
                image.c,
                image.h,
                image.w,
                m.name,
                m.n_in,
                m.h,
                m.w
            ));
        }
        if (weights.n_out, weights.n_in, weights.k) != (m.n_out, m.n_in, m.k) {
            return Err(anyhow!("weights do not match artifact {}", m.name));
        }
        let to_i32 = |v: &[i64]| -> Vec<i32> { v.iter().map(|&x| x as i32).collect() };
        let x = xla::Literal::vec1(&to_i32(&image.data)).reshape(&[
            m.n_in as i64,
            m.h as i64,
            m.w as i64,
        ])?;
        let wv: Vec<i32> = weights.bits.iter().map(|&b| if b { 1 } else { -1 }).collect();
        let w = xla::Literal::vec1(&wv).reshape(&[
            m.n_out as i64,
            m.n_in as i64,
            m.k as i64,
            m.k as i64,
        ])?;
        let alpha = xla::Literal::vec1(&to_i32(&sb.alpha));
        let beta = xla::Literal::vec1(&to_i32(&sb.beta));

        let result = self.exe.execute::<xla::Literal>(&[x, w, alpha, beta])?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<i32>()?;
        let (out_h, out_w) = if m.zero_pad {
            (m.h, m.w)
        } else {
            (m.h - m.k + 1, m.w - m.k + 1)
        };
        if values.len() != m.n_out * out_h * out_w {
            return Err(anyhow!("unexpected golden output size {}", values.len()));
        }
        Ok(Image {
            c: m.n_out,
            h: out_h,
            w: out_w,
            data: values.into_iter().map(|v| v as i64).collect(),
        })
    }
}

/// The artifact registry + PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ArtifactMeta>,
    cache: HashMap<String, GoldenBlock>,
    smallnet: Option<xla::PjRtLoadedExecutable>,
    smallnet_compiled: bool,
}

impl Runtime {
    /// Open the runtime over an artifacts directory (reads
    /// `manifest.txt`; artifacts themselves compile lazily).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(ArtifactMeta::parse)
            .collect::<Result<Vec<_>>>()?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
            smallnet: None,
            smallnet_compiled: false,
        })
    }

    /// Open `artifacts/` by walking from the current directory up through
    /// every ancestor (tests, benches and examples run from varying
    /// depths inside the repo; any of them finds the repo-root artifacts).
    pub fn open_default() -> Result<Runtime> {
        let mut dir =
            std::env::current_dir().context("cannot determine the current directory")?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.txt").exists() {
                return Runtime::open(cand);
            }
            if !dir.pop() {
                return Err(anyhow!(
                    "artifacts/manifest.txt not found in the current directory or any \
                     ancestor — run `make artifacts`"
                ));
            }
        }
    }

    /// All known artifacts.
    pub fn manifest(&self) -> &[ArtifactMeta] {
        &self.manifest
    }

    /// Find the artifact for a given block geometry.
    pub fn find(&self, k: usize, n_in: usize, n_out: usize, h: usize, w: usize, zero_pad: bool) -> Option<&ArtifactMeta> {
        self.manifest.iter().find(|m| {
            (m.k, m.n_in, m.n_out, m.h, m.w, m.zero_pad) == (k, n_in, n_out, h, w, zero_pad)
        })
    }

    /// Load (and cache) a golden block by artifact name.
    pub fn golden(&mut self, name: &str) -> Result<&GoldenBlock> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .iter()
                .find(|m| m.name == name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?
                .clone();
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), GoldenBlock { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile and run the end-to-end `smallnet` artifact (the 3-layer
    /// CNN of `python/compile/aot.py::SMALLNET_LAYERS`: 7×7 3→16 +pool,
    /// 7×7 16→32 +pool, 3×3 32→8; quantized ReLU between layers).
    ///
    /// `params` holds (weights, scale/bias) triples per layer in order.
    /// Returns the raw-Q2.9 output `[8, h/4, w/4]`.
    pub fn run_smallnet(
        &mut self,
        image: &Image,
        params: &[(crate::workload::BinaryKernels, crate::workload::ScaleBias)],
    ) -> Result<Image> {
        let path = self.dir.join("smallnet.hlo.txt");
        if !self.smallnet_compiled {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.smallnet = Some(self.client.compile(&comp)?);
            self.smallnet_compiled = true;
        }
        let exe = self.smallnet.as_ref().unwrap();
        let to_i32 = |v: &[i64]| -> Vec<i32> { v.iter().map(|&x| x as i32).collect() };
        let mut args: Vec<xla::Literal> = Vec::with_capacity(1 + 3 * params.len());
        args.push(xla::Literal::vec1(&to_i32(&image.data)).reshape(&[
            image.c as i64,
            image.h as i64,
            image.w as i64,
        ])?);
        for (w, sb) in params {
            let wv: Vec<i32> = w.bits.iter().map(|&b| if b { 1 } else { -1 }).collect();
            args.push(xla::Literal::vec1(&wv).reshape(&[
                w.n_out as i64,
                w.n_in as i64,
                w.k as i64,
                w.k as i64,
            ])?);
            args.push(xla::Literal::vec1(&to_i32(&sb.alpha)));
            args.push(xla::Literal::vec1(&to_i32(&sb.beta)));
        }
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<i32>()?;
        let (c, h, w) = (params.last().unwrap().0.n_out, image.h / 4, image.w / 4);
        if values.len() != c * h * w {
            return Err(anyhow!("unexpected smallnet output size {}", values.len()));
        }
        Ok(Image { c, h, w, data: values.into_iter().map(|v| v as i64).collect() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_roundtrip() {
        let m = ArtifactMeta::parse("block_k3_c32x64_16x16 3 32 64 16 16 1").unwrap();
        assert_eq!(m.k, 3);
        assert_eq!(m.n_out, 64);
        assert!(m.zero_pad);
        assert!(ArtifactMeta::parse("too few fields").is_err());
    }
}
