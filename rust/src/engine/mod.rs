//! Pluggable convolution engines — the bit-true datapath decoupled from
//! activity accounting.
//!
//! Everything that *computes* a chip block now goes through the
//! [`ConvEngine`] trait, with two implementations:
//!
//! * [`CycleAccurate`] — wraps [`crate::hw::Chip`]: the per-cycle
//!   simulator with the full activity ledger (SCM bank events, SoP
//!   operator counts, cycle breakdown). Unchanged bit-true + stats
//!   semantics; this is what the paper's tables and the energy model
//!   consume.
//! * [`Functional`] — outputs only, as fast as the host allows: kernels
//!   bit-packed into one `u64` word per (output, input) channel pair
//!   ([`PackedKernels`]), window dots evaluated as popcounts over the
//!   activations' offset-binary bitplanes, and the identical
//!   Q2.9/Q7.9/Q10.18 saturation order (per-input-channel `sat_add`,
//!   then the Scale-Bias datapath). No per-cycle ledger is kept, which
//!   is the point: serving throughput traffic does not need one.
//!
//! The two engines are **bit-identical** on every supported geometry
//! (k ∈ 1..=7, zero-padded and valid, channel-blocked and vertically
//! tiled) — `rust/tests/engine_equivalence.rs` sweeps this exhaustively.
//!
//! Engines consume work in two forms: a materialized [`BlockJob`]
//! (`run_block`, the historical interface), or a zero-copy
//! ([`LayerData`], [`BlockPlan`]) pair (`run_plan`) where the plan is
//! pure indices into the full layer's image/kernel/scale data — this is
//! what lets [`crate::coordinator::session::NetworkSession`] share one
//! `Arc`'d kernel set across a worker pool without per-job clones.
//!
//! ### The popcount identity, raster-resident
//!
//! Activations are 12-bit Q2.9 raw values `x ∈ [−2048, 2047]`. Encode
//! each window sample in offset binary `u = x + 2048 ∈ [0, 4096)` and
//! pack bit `b` of every window sample into a plane word `U_b` (window
//! position `j = dy·k + dx` = bit `j`). With `P` the kernel's packed
//! weight word (bit 1 ⇔ w = +1, Eq. 5) and `S = Σ_j w_j = 2·pc(P) − k²`:
//!
//! ```text
//! Σ_j w_j·x_j = 2·Σ_b 2^b·pc(U_b ∧ P) − Σ_j u_j − 2048·S
//! ```
//!
//! which is exact integer arithmetic — the sign-select-and-add of the
//! paper's SoP units.
//!
//! **Where the window words come from.** Each pixel's code `u` never
//! changes within a layer, so the activations are packed exactly once
//! into a layer-resident [`BitplaneRaster`]: per (channel, padded row),
//! 12 bitplane rows u64-packed along x with the zero-pad halo pre-baked
//! (halo code 2048 = plane 11), plus a per-row **prefix-sum of `u`** so
//! a window's `Σu` is k subtractions instead of k² adds. A window's
//! `U_b` then assembles as k shift+mask row extracts per plane — work
//! amortized over *all* output channels of the window, replacing PR 1's
//! per-(pixel × channel) bit-by-bit repack. The raster flows through
//! [`LayerData::raster`] exactly like [`PackedKernels`]: packed once per
//! layer by the executor, once per frame per layer by a session worker
//! (into reusable scratch — steady-state serving allocates nothing).
//!
//! **Grouped popcounts.** When `(2^m − 1)·k² ≤ 64`, m consecutive
//! planes share one AND+POPCNT: plane `t` of a group is replicated
//! `2^t` times into disjoint k²-bit fields of one word, the kernel word
//! is replicated into every field (precomputed in [`PackedKernels`]),
//! and a single popcount returns the weighted partial `Σ_t 2^t·pc_t`.
//! For k ≤ 3 that is 4 popcounts per (window, output channel) instead
//! of 12; k = 4 needs 6; k ≥ 5 falls back to one plane per popcount.
//! The arithmetic stays exact — fields are disjoint, each holds at most
//! k² bits — so outputs remain bit-identical to the chip.

pub mod binary;
pub mod cycle;
pub mod functional;
pub mod raster;
pub mod simd;
pub mod xnor;

pub use binary::{binarize_q29, BinaryRaster, BINARY_ONE};
pub use cycle::CycleAccurate;
pub use functional::{Functional, PackedKernels};
pub use raster::BitplaneRaster;
pub use simd::FunctionalSimd;
pub use xnor::{Xnor, XnorSimd};

use crate::hw::{BlockJob, ChipConfig, ChipStats};
use crate::workload::{BinaryKernels, Image, ScaleBias};

/// A planned chip block: pure indices into the parent layer's data —
/// no image tiles, no kernel slices. Produced by
/// [`crate::coordinator::blocks::plan_layer`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockPlan {
    /// First output channel this block computes.
    pub out_base: usize,
    /// Output channels in this block.
    pub out_len: usize,
    /// First input channel of this block.
    pub in_base: usize,
    /// Input channels in this block.
    pub in_len: usize,
    /// Input-channel block index (for the off-chip partial-sum reduction).
    pub in_block: usize,
    /// Total input-channel blocks for this output block.
    pub in_blocks: usize,
    /// First output row of this tile in the layer's output.
    pub row_base: usize,
    /// Rows of valid (non-halo) output this tile contributes.
    pub rows_valid: usize,
    /// First input row of the tile in the full image.
    pub clip0: usize,
    /// Input rows in the tile.
    pub tile_h: usize,
}

impl BlockPlan {
    /// A plan covering one whole (already materialized) block job —
    /// the `run_block` → `run_plan` adapter.
    pub fn whole(k: usize, zero_pad: bool, n_out: usize, n_in: usize, h: usize) -> BlockPlan {
        BlockPlan {
            out_base: 0,
            out_len: n_out,
            in_base: 0,
            in_len: n_in,
            in_block: 0,
            in_blocks: 1,
            row_base: 0,
            rows_valid: if zero_pad { h } else { (h + 1).saturating_sub(k) },
            clip0: 0,
            tile_h: h,
        }
    }
}

/// A borrowed view of one full layer's data: what a [`BlockPlan`]
/// indexes into. `packed` optionally carries the pre-packed kernel
/// bit-words so the functional engine packs once per layer (or once per
/// session) rather than once per block.
#[derive(Debug, Clone, Copy)]
pub struct LayerData<'a> {
    /// Kernel size (1..=7).
    pub k: usize,
    /// Zero-padded convolution.
    pub zero_pad: bool,
    /// Full input feature map.
    pub input: &'a Image,
    /// Full kernel set.
    pub kernels: &'a BinaryKernels,
    /// Pre-packed kernel bit-words, if the caller has them.
    pub packed: Option<&'a PackedKernels>,
    /// Layer-resident bitplane raster of `input` (all channels, all
    /// rows, halo pre-baked), if the caller packed one. Engines that
    /// consume rasters fall back to packing a block-local tile view
    /// into their own scratch when this is `None`.
    pub raster: Option<&'a BitplaneRaster>,
    /// Layer-resident 1-bit sign raster of `input`, if the caller
    /// packed one — the binary-activation counterpart of `raster`,
    /// consumed by the XNOR engine family. Same fallback contract:
    /// engines pack a block-local tile view into their own scratch when
    /// this is `None`.
    pub binary: Option<&'a BinaryRaster>,
    /// Full per-output-channel scale/bias.
    pub scale_bias: &'a ScaleBias,
}

/// What an engine returns for one block: the output tile, plus whatever
/// activity the engine chose to account (the functional engine only
/// fills `useful_ops`; the cycle-accurate engine fills everything).
#[derive(Debug, Clone)]
pub struct EngineOutput {
    /// Output tile (`out_len × out_h × out_w`, raw Q2.9).
    pub output: Image,
    /// Activity statistics (all-zero except `useful_ops` for engines
    /// that keep no ledger).
    pub stats: ChipStats,
}

/// A convolution engine: computes chip blocks with YodaNN's exact
/// arithmetic. Implementations may keep per-instance scratch state, so
/// the coordinator builds one engine per worker thread.
pub trait ConvEngine {
    /// Short engine name for reports.
    fn name(&self) -> &'static str;

    /// Whether this engine consumes [`LayerData::packed`] — callers skip
    /// the per-layer packing pass for engines that don't.
    fn wants_packed(&self) -> bool {
        false
    }

    /// Whether this engine consumes [`LayerData::raster`] — callers skip
    /// the per-layer activation packing pass for engines that don't.
    fn wants_raster(&self) -> bool {
        false
    }

    /// Whether this engine consumes [`LayerData::binary`] — the 1-bit
    /// sign raster of the binary-activation datapath. Mutually exclusive
    /// with [`Self::wants_raster`] in practice: an engine binarizes its
    /// activations or it doesn't.
    fn wants_binary_raster(&self) -> bool {
        false
    }

    /// Execute one materialized block job.
    fn run_block(&mut self, job: &BlockJob) -> EngineOutput;

    /// Execute one planned block against the full layer's data. The
    /// default materializes the job (tile + kernel slices) and calls
    /// [`Self::run_block`]; engines that can work zero-copy override it.
    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let job = materialize_block(layer, plan);
        self.run_block(&job)
    }
}

/// Forwarding impl so a runtime-selected boxed engine satisfies generic
/// `E: ConvEngine` bounds (e.g. the executor's worker pool). Dispatch
/// goes through the inner trait object — one virtual call, no recursion.
impl ConvEngine for Box<dyn ConvEngine> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn wants_packed(&self) -> bool {
        (**self).wants_packed()
    }

    fn wants_raster(&self) -> bool {
        (**self).wants_raster()
    }

    fn wants_binary_raster(&self) -> bool {
        (**self).wants_binary_raster()
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        (**self).run_block(job)
    }

    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        (**self).run_plan(layer, plan)
    }
}

/// Materialize a planned block into an owned [`BlockJob`]: slice the
/// image tile, the kernel bits and the scale/bias exactly as the chip
/// expects them. Intermediate (non-final) input blocks get identity
/// scale/bias — the real α/β are applied after the off-chip reduction
/// (Algorithm 1 line 37).
pub fn materialize_block(layer: &LayerData<'_>, plan: &BlockPlan) -> BlockJob {
    let k = layer.k;
    let input = layer.input;
    let mut tile = Image::zeros(plan.in_len, plan.tile_h, input.w);
    for c in 0..plan.in_len {
        for y in 0..plan.tile_h {
            tile.row_mut(c, y).copy_from_slice(input.row(plan.in_base + c, plan.clip0 + y));
        }
    }
    let mut bits = Vec::with_capacity(plan.out_len * plan.in_len * k * k);
    for o in 0..plan.out_len {
        for i in 0..plan.in_len {
            for dy in 0..k {
                for dx in 0..k {
                    bits.push(layer.kernels.bit(plan.out_base + o, plan.in_base + i, dy, dx));
                }
            }
        }
    }
    let kernels = BinaryKernels { n_out: plan.out_len, n_in: plan.in_len, k, bits };
    let scale_bias = if plan.in_blocks == 1 {
        ScaleBias {
            alpha: layer.scale_bias.alpha[plan.out_base..plan.out_base + plan.out_len].to_vec(),
            beta: layer.scale_bias.beta[plan.out_base..plan.out_base + plan.out_len].to_vec(),
        }
    } else {
        ScaleBias::identity(plan.out_len)
    };
    BlockJob { k, zero_pad: layer.zero_pad, image: tile, kernels, scale_bias }
}

/// Runtime-selectable engine kind (CLI, benches, sessions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Cycle-accurate chip simulation with the full activity ledger.
    CycleAccurate,
    /// Functional popcount datapath on the layer-resident bitplane
    /// raster, outputs only.
    Functional,
    /// The PR-1 functional baseline that repacks every window bit by
    /// bit — kept only for measured A/B against the raster path.
    FunctionalPerWindow,
    /// The functional raster path with SIMD inner loops
    /// (runtime-detected AVX2/NEON, portable-scalar fallback) — see
    /// [`simd::FunctionalSimd`].
    FunctionalSimd,
    /// [`simd::FunctionalSimd`] pinned to its portable scalar loop —
    /// kept in the matrix so the fallback is conformance-tested on
    /// SIMD-capable hosts too.
    FunctionalSimdScalar,
    /// Binary-activation XNOR+popcount datapath, scalar reference —
    /// see [`xnor::Xnor`]. Binarizes its input activations by sign, so
    /// it is **not** bit-identical to the multi-bit engines; its oracle
    /// is [`crate::workload::reference_xnor_conv`].
    Xnor,
    /// [`xnor::XnorSimd`]: the XNOR datapath with the output-channel
    /// dot vectorized (same runtime AVX2/NEON dispatch as
    /// [`simd::FunctionalSimd`]).
    XnorSimd,
    /// [`xnor::XnorSimd`] pinned to its portable scalar loop — the
    /// fallback, conformance-tested on SIMD-capable hosts too.
    XnorSimdScalar,
}

impl EngineKind {
    /// Every engine kind, in report order — one axis of the
    /// engine × shard conformance matrix (`rust/tests/conformance.rs`).
    pub const ALL: [EngineKind; 8] = [
        EngineKind::CycleAccurate,
        EngineKind::Functional,
        EngineKind::FunctionalPerWindow,
        EngineKind::FunctionalSimd,
        EngineKind::FunctionalSimdScalar,
        EngineKind::Xnor,
        EngineKind::XnorSimd,
        EngineKind::XnorSimdScalar,
    ];

    /// The multi-bit (BWN) engine kinds: bit-identical to each other and
    /// to the chip's Q2.9 datapath.
    pub const MULTI_BIT: [EngineKind; 5] = [
        EngineKind::CycleAccurate,
        EngineKind::Functional,
        EngineKind::FunctionalPerWindow,
        EngineKind::FunctionalSimd,
        EngineKind::FunctionalSimdScalar,
    ];

    /// The binary-activation (BNN) engine kinds: bit-identical to each
    /// other and to the naive sign/threshold reference.
    pub const XNOR: [EngineKind; 3] =
        [EngineKind::Xnor, EngineKind::XnorSimd, EngineKind::XnorSimdScalar];

    /// Whether engines of this kind consume [`LayerData::packed`] — the
    /// static mirror of [`ConvEngine::wants_packed`], for callers that
    /// pack shared state before any engine instance exists (sessions,
    /// the shard executor).
    pub fn wants_packed(self) -> bool {
        !matches!(self, EngineKind::CycleAccurate)
    }

    /// Whether engines of this kind consume [`LayerData::raster`] — the
    /// static mirror of [`ConvEngine::wants_raster`].
    pub fn wants_raster(self) -> bool {
        matches!(
            self,
            EngineKind::Functional | EngineKind::FunctionalSimd | EngineKind::FunctionalSimdScalar
        )
    }

    /// Whether engines of this kind consume [`LayerData::binary`] — the
    /// static mirror of [`ConvEngine::wants_binary_raster`].
    pub fn wants_binary_raster(self) -> bool {
        self.is_binary()
    }

    /// Whether this kind binarizes its input activations (the BNN
    /// datapath) — such engines follow the sign reference, not the
    /// multi-bit chip arithmetic.
    pub fn is_binary(self) -> bool {
        matches!(self, EngineKind::Xnor | EngineKind::XnorSimd | EngineKind::XnorSimdScalar)
    }

    /// The XNOR engine a mixed-precision session pairs with this kind
    /// for its `Precision::Binary` layers: the same dispatch tier (SIMD
    /// stays SIMD, forced-scalar stays forced-scalar), so one session
    /// never mixes vector and fallback paths across precisions.
    pub fn binary_companion(self) -> EngineKind {
        match self {
            EngineKind::FunctionalSimd => EngineKind::XnorSimd,
            EngineKind::FunctionalSimdScalar => EngineKind::XnorSimdScalar,
            k if k.is_binary() => k,
            _ => EngineKind::Xnor,
        }
    }

    /// Engine name as printed in reports.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::CycleAccurate => "cycle-accurate",
            EngineKind::Functional => "functional",
            EngineKind::FunctionalPerWindow => "functional-pr1",
            EngineKind::FunctionalSimd => "functional-simd",
            EngineKind::FunctionalSimdScalar => "functional-simd-scalar",
            EngineKind::Xnor => "xnor",
            EngineKind::XnorSimd => "xnor-simd",
            EngineKind::XnorSimdScalar => "xnor-simd-scalar",
        }
    }

    /// Every spelling [`EngineKind::parse`] accepts, for error messages
    /// (`yodann throughput --engine` echoes this list on a bad value).
    /// Drift-pinned against [`EngineKind::ALL`] by
    /// `accepted_and_parse_stay_in_sync_with_all`.
    pub const ACCEPTED: &'static [&'static str] = &[
        "cycle",
        "cycle-accurate",
        "sim",
        "functional",
        "fast",
        "popcount",
        "raster",
        "functional-pr1",
        "per-window",
        "pr1",
        "functional-simd",
        "simd",
        "functional-simd-scalar",
        "simd-scalar",
        "xnor",
        "bnn",
        "xnor-simd",
        "xnor-simd-scalar",
    ];

    /// Parse a CLI spelling, case-insensitively.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "cycle" | "cycle-accurate" | "sim" => Some(EngineKind::CycleAccurate),
            "functional" | "fast" | "popcount" | "raster" => Some(EngineKind::Functional),
            "functional-pr1" | "per-window" | "pr1" => Some(EngineKind::FunctionalPerWindow),
            "functional-simd" | "simd" => Some(EngineKind::FunctionalSimd),
            "functional-simd-scalar" | "simd-scalar" => Some(EngineKind::FunctionalSimdScalar),
            "xnor" | "bnn" => Some(EngineKind::Xnor),
            "xnor-simd" => Some(EngineKind::XnorSimd),
            "xnor-simd-scalar" => Some(EngineKind::XnorSimdScalar),
            _ => None,
        }
    }

    /// Build a boxed engine of this kind.
    pub fn build(self, cfg: ChipConfig) -> Box<dyn ConvEngine> {
        match self {
            EngineKind::CycleAccurate => Box::new(CycleAccurate::new(cfg)),
            EngineKind::Functional => Box::new(Functional::new()),
            EngineKind::FunctionalPerWindow => Box::new(Functional::per_window()),
            EngineKind::FunctionalSimd => Box::new(FunctionalSimd::new()),
            EngineKind::FunctionalSimdScalar => Box::new(FunctionalSimd::forced_scalar()),
            EngineKind::Xnor => Box::new(Xnor::new()),
            EngineKind::XnorSimd => Box::new(XnorSimd::new()),
            EngineKind::XnorSimdScalar => Box::new(XnorSimd::forced_scalar()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::random_image;

    #[test]
    fn engine_kind_parses_cli_spellings() {
        assert_eq!(EngineKind::parse("cycle"), Some(EngineKind::CycleAccurate));
        assert_eq!(EngineKind::parse("cycle-accurate"), Some(EngineKind::CycleAccurate));
        assert_eq!(EngineKind::parse("functional"), Some(EngineKind::Functional));
        assert_eq!(EngineKind::parse("popcount"), Some(EngineKind::Functional));
        assert_eq!(EngineKind::parse("pr1"), Some(EngineKind::FunctionalPerWindow));
        assert_eq!(
            EngineKind::parse("functional-pr1"),
            Some(EngineKind::FunctionalPerWindow)
        );
        assert_eq!(EngineKind::parse("simd"), Some(EngineKind::FunctionalSimd));
        assert_eq!(EngineKind::parse("simd-scalar"), Some(EngineKind::FunctionalSimdScalar));
        assert_eq!(EngineKind::parse("xnor"), Some(EngineKind::Xnor));
        assert_eq!(EngineKind::parse("bnn"), Some(EngineKind::Xnor));
        assert_eq!(EngineKind::parse("xnor-simd"), Some(EngineKind::XnorSimd));
        assert_eq!(EngineKind::parse("xnor-simd-scalar"), Some(EngineKind::XnorSimdScalar));
        assert_eq!(EngineKind::parse("nope"), None);
        assert_eq!(EngineKind::Functional.name(), "functional");
        assert_eq!(EngineKind::FunctionalPerWindow.name(), "functional-pr1");
        assert_eq!(EngineKind::FunctionalSimd.name(), "functional-simd");
        assert_eq!(EngineKind::FunctionalSimdScalar.name(), "functional-simd-scalar");
        assert_eq!(EngineKind::Xnor.name(), "xnor");
        assert_eq!(EngineKind::XnorSimd.name(), "xnor-simd");
        assert_eq!(EngineKind::XnorSimdScalar.name(), "xnor-simd-scalar");
    }

    #[test]
    fn precision_families_partition_all() {
        // MULTI_BIT and XNOR are the two conformance families: disjoint,
        // and together exactly ALL (in ALL's order).
        let mut union: Vec<EngineKind> = EngineKind::MULTI_BIT.to_vec();
        union.extend(EngineKind::XNOR);
        assert_eq!(union, EngineKind::ALL.to_vec());
        for kind in EngineKind::MULTI_BIT {
            assert!(!kind.is_binary(), "{} in MULTI_BIT but is_binary", kind.name());
        }
        for kind in EngineKind::XNOR {
            assert!(kind.is_binary(), "{} in XNOR but not is_binary", kind.name());
            assert_eq!(kind.binary_companion(), kind, "binary kinds are their own companion");
        }
        // Companions stay within the same dispatch tier.
        assert_eq!(EngineKind::FunctionalSimd.binary_companion(), EngineKind::XnorSimd);
        assert_eq!(
            EngineKind::FunctionalSimdScalar.binary_companion(),
            EngineKind::XnorSimdScalar
        );
        assert_eq!(EngineKind::Functional.binary_companion(), EngineKind::Xnor);
        assert_eq!(EngineKind::CycleAccurate.binary_companion(), EngineKind::Xnor);
        for kind in EngineKind::ALL {
            assert!(kind.binary_companion().is_binary());
        }
    }

    #[test]
    fn accepted_and_parse_stay_in_sync_with_all() {
        // The drift pin: adding an engine to ALL without teaching parse,
        // name and ACCEPTED about it must fail here — otherwise CLI
        // help, the UnknownEngine error text and the bench matrix
        // silently desync.
        for kind in EngineKind::ALL {
            assert_eq!(
                EngineKind::parse(kind.name()),
                Some(kind),
                "ALL member '{}' does not round-trip through parse",
                kind.name()
            );
            assert!(
                EngineKind::ACCEPTED.contains(&kind.name()),
                "ALL member '{}' missing from ACCEPTED",
                kind.name()
            );
        }
        // And every accepted spelling must land on a member of ALL.
        for &s in EngineKind::ACCEPTED {
            let kind = EngineKind::parse(s).expect("ACCEPTED spelling parses");
            assert!(EngineKind::ALL.contains(&kind), "'{s}' parses to a kind outside ALL");
        }
    }

    #[test]
    fn engine_kind_parse_is_case_insensitive_and_accepted_is_exhaustive() {
        // Shell users type what they type: every accepted spelling must
        // parse in any case, and ACCEPTED must list exactly the
        // spellings that parse.
        assert_eq!(EngineKind::parse("Cycle"), Some(EngineKind::CycleAccurate));
        assert_eq!(EngineKind::parse("FUNCTIONAL"), Some(EngineKind::Functional));
        assert_eq!(EngineKind::parse("Per-Window"), Some(EngineKind::FunctionalPerWindow));
        for &name in EngineKind::ACCEPTED {
            assert!(EngineKind::parse(name).is_some(), "ACCEPTED lists unparsable '{name}'");
            assert!(
                EngineKind::parse(&name.to_uppercase()).is_some(),
                "'{name}' fails to parse uppercased"
            );
        }
    }

    #[test]
    fn static_wants_mirror_the_built_engines() {
        // The EngineKind predicates must never drift from what the
        // engines they build actually consume.
        let cfg = ChipConfig::tiny(4);
        for kind in EngineKind::ALL {
            let e = kind.build(cfg);
            assert_eq!(kind.wants_packed(), e.wants_packed(), "{}", kind.name());
            assert_eq!(kind.wants_raster(), e.wants_raster(), "{}", kind.name());
            assert_eq!(kind.wants_binary_raster(), e.wants_binary_raster(), "{}", kind.name());
            assert!(
                !(kind.wants_raster() && kind.wants_binary_raster()),
                "{} wants both rasters",
                kind.name()
            );
        }
    }

    #[test]
    fn materialize_whole_plan_reproduces_the_layer() {
        let mut g = Gen::new(3);
        let input = random_image(&mut g, 3, 6, 5, 0.05);
        let kernels = BinaryKernels::random(&mut g, 4, 3, 3);
        let sb = ScaleBias::random(&mut g, 4);
        let layer = LayerData {
            k: 3,
            zero_pad: true,
            input: &input,
            kernels: &kernels,
            packed: None,
            raster: None,
            binary: None,
            scale_bias: &sb,
        };
        let plan = BlockPlan::whole(3, true, 4, 3, 6);
        let job = materialize_block(&layer, &plan);
        assert_eq!(job.image, input);
        assert_eq!(job.kernels.bits, kernels.bits);
        assert_eq!(job.scale_bias.alpha, sb.alpha);
    }

    #[test]
    fn materialize_partial_block_gets_identity_scale() {
        let mut g = Gen::new(4);
        let input = random_image(&mut g, 4, 6, 5, 0.05);
        let kernels = BinaryKernels::random(&mut g, 2, 4, 3);
        let sb = ScaleBias::random(&mut g, 2);
        let layer = LayerData {
            k: 3,
            zero_pad: true,
            input: &input,
            kernels: &kernels,
            packed: None,
            raster: None,
            binary: None,
            scale_bias: &sb,
        };
        let plan = BlockPlan {
            out_base: 0,
            out_len: 2,
            in_base: 2,
            in_len: 2,
            in_block: 1,
            in_blocks: 2,
            row_base: 0,
            rows_valid: 6,
            clip0: 0,
            tile_h: 6,
        };
        let job = materialize_block(&layer, &plan);
        assert_eq!(job.image.c, 2);
        assert_eq!(job.image.at(0, 1, 2), input.at(2, 1, 2));
        assert_eq!(job.scale_bias.alpha, vec![512, 512]);
        assert_eq!(job.scale_bias.beta, vec![0, 0]);
    }
}
