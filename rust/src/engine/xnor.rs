//! The XNOR engine family: binary-activation (BNN) convolution as pure
//! XNOR + popcount, the datapath of YodaNN's successors (XNORBIN,
//! ChewBaccaNN — PAPERS.md).
//!
//! With both weights and activations in {−1, +1}, a window's dot product
//! collapses: encode the k² activation signs as one window word `A`
//! ([`BinaryRaster::window`], bit set ⇔ +1) and the kernel as the plain
//! packed word `P` ([`PackedKernels::word`], same bit order). Every
//! agreeing bit contributes +1, every disagreement −1, so with
//! `d = pc((A ⊕ P) ∧ mask)` disagreements:
//!
//! ```text
//! Σ_j a_j·w_j = (k² − d) − d = k² − 2·pc(A ⊕ P)
//! ```
//!
//! — one XOR and one POPCNT per (window, output channel), no bitplanes,
//! no window sums. Carried back into the chip's arithmetic as raw Q2.9
//! (binary ±1 is raw ±512 — [`BINARY_ONE`]), the accumulation order is
//! byte-for-byte the multi-bit datapath's: per-input-channel Q7.9
//! saturating add, then the Scale-Bias resize to Q2.9. That keeps every
//! downstream consumer (host ops, reduction, range analysis) unchanged,
//! and makes the engines bit-identical to the naive sign reference
//! ([`crate::workload::reference_xnor_conv`]) by exact-integer
//! construction.
//!
//! Two engines share one scalar hot loop:
//!
//! * [`Xnor`] — the scalar reference (engine name `xnor`).
//! * [`XnorSimd`] — the same loop with the output-channel dot
//!   vectorized, dispatching through the exact [`Isa`] runtime detection
//!   the multi-bit SIMD engine uses (AVX2 4 channels / NEON 2 channels
//!   per lane op, portable scalar fallback, `YODANN_FORCE_SCALAR`
//!   honored, [`XnorSimd::forced_scalar`] pinned in the conformance
//!   matrix as engine name `xnor-simd-scalar`).
//!
//! The kernel words come from the **same** [`PackedKernels`] the
//! multi-bit engines share — the replicated form masked to its first
//! field is the plain word, so one pack per layer/session serves every
//! engine kind, mixed-precision sessions included.

use super::binary::{BinaryParts, BinaryRaster, BINARY_ONE};
use super::functional::PackedKernels;
use super::simd::Isa;
use super::{BlockPlan, ConvEngine, EngineOutput, LayerData};
use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
use crate::hw::{BlockJob, ChipStats};
use crate::workload::Image;

/// The scalar XNOR+popcount engine — the family's reference. Holds
/// reusable accumulator and binary-raster scratch so a worker thread
/// allocates nothing per block in steady state.
#[derive(Debug, Default)]
pub struct Xnor {
    accs: Vec<i64>,
    raster: BinaryRaster,
}

impl Xnor {
    /// New engine with empty scratch.
    pub fn new() -> Xnor {
        Xnor::default()
    }

    /// Binary-raster scratch packs that had to grow a buffer
    /// (steady-state serving keeps this constant).
    pub fn raster_reallocs(&self) -> u64 {
        self.raster.reallocs()
    }
}

/// The XNOR engine with the output-channel dot vectorized — same
/// runtime [`Isa`] dispatch as [`super::FunctionalSimd`], bit-identical
/// to [`Xnor`] on every path (exact integer arithmetic throughout).
#[derive(Debug)]
pub struct XnorSimd {
    accs: Vec<i64>,
    raster: BinaryRaster,
    isa: Isa,
    forced_scalar: bool,
}

impl Default for XnorSimd {
    fn default() -> XnorSimd {
        XnorSimd::new()
    }
}

impl XnorSimd {
    /// New engine with the best lane ISA the host offers (honours
    /// `YODANN_FORCE_SCALAR`).
    pub fn new() -> XnorSimd {
        XnorSimd::with(false)
    }

    /// New engine pinned to the portable scalar loop regardless of host
    /// features — conformance-tested alongside the vector variant.
    pub fn forced_scalar() -> XnorSimd {
        XnorSimd::with(true)
    }

    fn with(forced_scalar: bool) -> XnorSimd {
        XnorSimd {
            accs: Vec::new(),
            raster: BinaryRaster::new(),
            isa: Isa::detect(forced_scalar),
            forced_scalar,
        }
    }

    /// The lane ISA this engine dispatches to: `"avx2"`, `"neon"` or
    /// `"scalar"`.
    pub fn isa_name(&self) -> &'static str {
        self.isa.name()
    }
}

/// Tile output shape of a plan (mirrors `Functional::out_dims`).
fn out_dims(layer: &LayerData<'_>, plan: &BlockPlan) -> (usize, usize) {
    let (k, w, tile_h) = (layer.k, layer.input.w, plan.tile_h);
    if !layer.zero_pad {
        assert!(tile_h >= k && w >= k, "tile {tile_h}x{w} smaller than kernel {k} (valid mode)");
    }
    if layer.zero_pad {
        (tile_h, w)
    } else {
        (tile_h + 1 - k, w + 1 - k)
    }
}

/// The shared plan prologue: resolve packed kernels and the binary
/// raster (the caller's layer-resident one, or scratch packed from the
/// plan's tile view), then run `body` against raster coordinates.
fn run_with_raster<F>(
    scratch: &mut BinaryRaster,
    layer: &LayerData<'_>,
    plan: &BlockPlan,
    body: F,
) -> EngineOutput
where
    F: FnOnce(&BinaryRaster, usize, usize, &PackedKernels, &mut Image),
{
    let k = layer.k;
    let kk = k * k;
    let (out_h, out_w) = out_dims(layer, plan);
    let local;
    let packed: &PackedKernels = match layer.packed {
        Some(p) => {
            debug_assert_eq!(p.k, k);
            p
        }
        None => {
            local = PackedKernels::pack(layer.kernels);
            &local
        }
    };
    // (c_base, row0) map plan-local (channel, window row) into raster
    // coordinates, exactly like the multi-bit engines.
    let (raster, c_base, row0): (&BinaryRaster, usize, usize) = match layer.binary {
        Some(r) => {
            debug_assert_eq!(r.k(), k);
            (r, plan.in_base, plan.clip0)
        }
        None => {
            scratch.pack_view(
                layer.input,
                k,
                layer.zero_pad,
                plan.in_base,
                plan.in_len,
                plan.clip0,
                plan.tile_h,
            );
            (&*scratch, 0, 0)
        }
    };
    let mut out = Image::zeros(plan.out_len, out_h, out_w);
    body(raster, c_base, row0, packed, &mut out);
    let stats = ChipStats {
        useful_ops: 2 * kk as u64
            * (plan.in_len * plan.out_len) as u64
            * (out_h * out_w) as u64,
        ..Default::default()
    };
    EngineOutput { output: out, stats }
}

/// The portable scalar hot loop, shared by [`Xnor`] and [`XnorSimd`]'s
/// fallback so the reference and the dispatch tail are one body of code.
#[allow(clippy::too_many_arguments)] // one flat hot-loop context, mirrors the vector paths
fn conv_scalar(
    raster: &BinaryRaster,
    c_base: usize,
    row0: usize,
    layer: &LayerData<'_>,
    plan: &BlockPlan,
    packed: &PackedKernels,
    identity: bool,
    out: &mut Image,
    accs: &mut [i64],
) {
    let kk = (layer.k * layer.k) as i64;
    let mask = (1u64 << (layer.k * layer.k)) - 1;
    let (out_h, out_w) = (out.h, out.w);
    for y in 0..out_h {
        for x in 0..out_w {
            accs.iter_mut().for_each(|a| *a = 0);
            for i in 0..plan.in_len {
                let a = raster.window(c_base + i, row0 + y, x);
                let reps = packed.rep_slice(plan.in_base + i, plan.out_base, plan.out_len);
                for (o, acc) in accs.iter_mut().enumerate() {
                    // rep masked to its first field is the plain kernel
                    // word P; d disagreements ⇒ dot = k² − 2d.
                    let d = ((a ^ reps[o]) & mask).count_ones() as i64;
                    let sop = BINARY_ONE * (kk - 2 * d);
                    *acc = sat_add(Q7_9, *acc, sop);
                }
            }
            for (o, &acc) in accs.iter().enumerate() {
                let (alpha, beta) = if identity {
                    (512, 0)
                } else {
                    (
                        layer.scale_bias.alpha[plan.out_base + o],
                        layer.scale_bias.beta[plan.out_base + o],
                    )
                };
                *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
            }
        }
    }
}

/// Window extract straight from [`BinaryParts`] — the vector paths'
/// scalar prologue (the per-window extract is one plane row deep, so
/// only the output-channel dot is worth lanes).
#[inline]
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")), allow(dead_code))]
fn window_from_parts(p: &BinaryParts<'_>, c: usize, y: usize, x: usize) -> u64 {
    let k = p.k;
    let mask = (1u64 << k) - 1;
    let wi = x >> 6;
    let sh = (x & 63) as u32;
    let mut out = 0u64;
    for dy in 0..k {
        let idx = (c * p.ph + y + dy) * p.stride + wi;
        let lo = p.words[idx] >> sh;
        let bits = if sh == 0 { lo } else { lo | (p.words[idx + 1] << (64 - sh)) };
        out |= (bits & mask) << (dy * k);
    }
    out
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::super::binary::{BinaryParts, BINARY_ONE};
    use super::super::functional::PackedKernels;
    use super::super::{BlockPlan, LayerData};
    use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
    use crate::workload::Image;

    /// Per-64-bit-lane popcount (AVX2 has no `VPOPCNTQ`): the same
    /// nibble-LUT + `PSADBW` scheme as the multi-bit SIMD engine.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// The AVX2 hot loop: same iteration order and saturation points as
    /// the scalar path, with the XNOR dot evaluated 4 output channels
    /// per lane op.
    #[allow(clippy::too_many_arguments)] // one flat hot-loop context
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn conv(
        parts: BinaryParts<'_>,
        c_base: usize,
        row0: usize,
        layer: &LayerData<'_>,
        plan: &BlockPlan,
        packed: &PackedKernels,
        identity: bool,
        out: &mut Image,
        accs: &mut [i64],
    ) {
        let kk = (parts.k * parts.k) as i64;
        let mask = (1u64 << (parts.k * parts.k)) - 1;
        let maskv = _mm256_set1_epi64x(mask as i64);
        let (out_h, out_w) = (out.h, out.w);
        let n_out = plan.out_len;
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                for i in 0..plan.in_len {
                    let a = super::window_from_parts(&parts, c_base + i, row0 + y, x);
                    let av = _mm256_set1_epi64x(a as i64);
                    let reps = packed.rep_slice(plan.in_base + i, plan.out_base, n_out);
                    let mut o = 0usize;
                    while o + 4 <= n_out {
                        let repv = _mm256_loadu_si256(reps.as_ptr().add(o) as *const __m256i);
                        let d = popcnt_epi64(_mm256_and_si256(
                            _mm256_xor_si256(av, repv),
                            maskv,
                        ));
                        let mut dd = [0i64; 4];
                        _mm256_storeu_si256(dd.as_mut_ptr() as *mut __m256i, d);
                        for (l, &dl) in dd.iter().enumerate() {
                            let sop = BINARY_ONE * (kk - 2 * dl);
                            accs[o + l] = sat_add(Q7_9, accs[o + l], sop);
                        }
                        o += 4;
                    }
                    while o < n_out {
                        let d = ((a ^ reps[o]) & mask).count_ones() as i64;
                        let sop = BINARY_ONE * (kk - 2 * d);
                        accs[o] = sat_add(Q7_9, accs[o], sop);
                        o += 1;
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::super::binary::{BinaryParts, BINARY_ONE};
    use super::super::functional::PackedKernels;
    use super::super::{BlockPlan, LayerData};
    use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
    use crate::workload::Image;

    /// Per-64-bit-lane popcount: `CNT` + widening pairwise adds, the
    /// same scheme as the multi-bit SIMD engine.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn popcnt_u64x2(v: uint64x2_t) -> uint64x2_t {
        vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(v)))))
    }

    /// The NEON hot loop: same iteration order and saturation points as
    /// the scalar path, XNOR dot 2 output channels per lane op.
    #[allow(clippy::too_many_arguments)] // one flat hot-loop context
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn conv(
        parts: BinaryParts<'_>,
        c_base: usize,
        row0: usize,
        layer: &LayerData<'_>,
        plan: &BlockPlan,
        packed: &PackedKernels,
        identity: bool,
        out: &mut Image,
        accs: &mut [i64],
    ) {
        let kk = (parts.k * parts.k) as i64;
        let mask = (1u64 << (parts.k * parts.k)) - 1;
        let maskv = vdupq_n_u64(mask);
        let (out_h, out_w) = (out.h, out.w);
        let n_out = plan.out_len;
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                for i in 0..plan.in_len {
                    let a = super::window_from_parts(&parts, c_base + i, row0 + y, x);
                    let av = vdupq_n_u64(a);
                    let reps = packed.rep_slice(plan.in_base + i, plan.out_base, n_out);
                    let mut o = 0usize;
                    while o + 2 <= n_out {
                        let repv = vld1q_u64(reps.as_ptr().add(o));
                        let d = popcnt_u64x2(vandq_u64(veorq_u64(av, repv), maskv));
                        let dd = [
                            vgetq_lane_u64::<0>(d) as i64,
                            vgetq_lane_u64::<1>(d) as i64,
                        ];
                        for (l, &dl) in dd.iter().enumerate() {
                            let sop = BINARY_ONE * (kk - 2 * dl);
                            accs[o + l] = sat_add(Q7_9, accs[o + l], sop);
                        }
                        o += 2;
                    }
                    while o < n_out {
                        let d = ((a ^ reps[o]) & mask).count_ones() as i64;
                        let sop = BINARY_ONE * (kk - 2 * d);
                        accs[o] = sat_add(Q7_9, accs[o], sop);
                        o += 1;
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
    }
}

impl ConvEngine for Xnor {
    fn name(&self) -> &'static str {
        "xnor"
    }

    fn wants_packed(&self) -> bool {
        true
    }

    fn wants_binary_raster(&self) -> bool {
        true
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        let layer = LayerData {
            k: job.k,
            zero_pad: job.zero_pad,
            input: &job.image,
            kernels: &job.kernels,
            packed: None,
            raster: None,
            binary: None,
            scale_bias: &job.scale_bias,
        };
        let plan =
            BlockPlan::whole(job.k, job.zero_pad, job.kernels.n_out, job.image.c, job.image.h);
        self.run_plan(&layer, &plan)
    }

    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let identity = plan.in_blocks > 1;
        let Xnor { accs, raster: scratch } = self;
        accs.clear();
        accs.resize(plan.out_len, 0);
        run_with_raster(scratch, layer, plan, |raster, c_base, row0, packed, out| {
            conv_scalar(raster, c_base, row0, layer, plan, packed, identity, out, accs);
        })
    }
}

impl ConvEngine for XnorSimd {
    fn name(&self) -> &'static str {
        if self.forced_scalar {
            "xnor-simd-scalar"
        } else {
            "xnor-simd"
        }
    }

    fn wants_packed(&self) -> bool {
        true
    }

    fn wants_binary_raster(&self) -> bool {
        true
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        let layer = LayerData {
            k: job.k,
            zero_pad: job.zero_pad,
            input: &job.image,
            kernels: &job.kernels,
            packed: None,
            raster: None,
            binary: None,
            scale_bias: &job.scale_bias,
        };
        let plan =
            BlockPlan::whole(job.k, job.zero_pad, job.kernels.n_out, job.image.c, job.image.h);
        self.run_plan(&layer, &plan)
    }

    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let identity = plan.in_blocks > 1;
        let isa = self.isa;
        let XnorSimd { accs, raster: scratch, .. } = self;
        accs.clear();
        accs.resize(plan.out_len, 0);
        run_with_raster(scratch, layer, plan, |raster, c_base, row0, packed, out| match isa {
            Isa::Scalar => {
                conv_scalar(raster, c_base, row0, layer, plan, packed, identity, out, accs)
            }
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                // SAFETY: Isa::Avx2 is only selected after
                // is_x86_feature_detected!("avx2") returned true.
                unsafe {
                    avx2::conv(
                        raster.raw_parts(),
                        c_base,
                        row0,
                        layer,
                        plan,
                        packed,
                        identity,
                        out,
                        accs,
                    )
                }
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => {
                // SAFETY: NEON is mandatory on aarch64.
                unsafe {
                    neon::conv(
                        raster.raw_parts(),
                        c_base,
                        row0,
                        layer,
                        plan,
                        packed,
                        identity,
                        out,
                        accs,
                    )
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, reference_xnor_conv, BinaryKernels, ScaleBias};

    fn job(
        k: usize,
        n_in: usize,
        n_out: usize,
        h: usize,
        w: usize,
        zp: bool,
        amp: f64,
        seed: u64,
    ) -> BlockJob {
        let mut g = Gen::new(seed);
        BlockJob {
            k,
            zero_pad: zp,
            image: random_image(&mut g, n_in, h, w, amp),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn matches_sign_reference_every_kernel_size() {
        // n_out = 6 exercises both the vector dot (4-lane / 2-lane) and
        // its scalar tail on every ISA.
        for k in 1..=7usize {
            for zp in [true, false] {
                if !zp && k == 1 {
                    continue;
                }
                let j = job(k, 3, 6, 11, 9, zp, 0.5, 600 + k as u64);
                let want = reference_xnor_conv(&j.image, &j.kernels, &j.scale_bias, zp);
                assert_eq!(Xnor::new().run_block(&j).output, want, "k={k} zp={zp} scalar");
                assert_eq!(XnorSimd::new().run_block(&j).output, want, "k={k} zp={zp} vector");
                assert_eq!(
                    XnorSimd::forced_scalar().run_block(&j).output,
                    want,
                    "k={k} zp={zp} forced-scalar"
                );
            }
        }
    }

    #[test]
    fn word_boundary_windows_match() {
        for w in [63usize, 64, 65, 66, 127, 130] {
            let j = job(3, 2, 5, 6, w, true, 0.3, 950 + w as u64);
            let want = reference_xnor_conv(&j.image, &j.kernels, &j.scale_bias, true);
            assert_eq!(Xnor::new().run_block(&j).output, want, "w={w} scalar");
            assert_eq!(XnorSimd::new().run_block(&j).output, want, "w={w} vector");
        }
    }

    #[test]
    fn saturating_regime_matches() {
        // Many channels of all-plus kernels over an all-positive image:
        // every channel dot is +512·k², so the Q7.9 accumulator
        // saturates and the per-input-channel saturation order must
        // agree exactly with the reference.
        let mut g = Gen::new(87);
        let image = random_image(&mut g, 24, 8, 8, 0.02);
        let kernels = BinaryKernels::all_plus(9, 24, 3);
        let sb = ScaleBias::random(&mut g, 9);
        let j = BlockJob {
            k: 3,
            zero_pad: true,
            image: image.clone(),
            kernels: kernels.clone(),
            scale_bias: sb.clone(),
        };
        let want = reference_xnor_conv(&image, &kernels, &sb, true);
        assert_eq!(Xnor::new().run_block(&j).output, want);
        assert_eq!(XnorSimd::new().run_block(&j).output, want);
        assert_eq!(XnorSimd::forced_scalar().run_block(&j).output, want);
    }

    #[test]
    fn names_and_isa_report() {
        assert_eq!(Xnor::new().name(), "xnor");
        assert_eq!(XnorSimd::new().name(), "xnor-simd");
        let s = XnorSimd::forced_scalar();
        assert_eq!(s.name(), "xnor-simd-scalar");
        assert_eq!(s.isa_name(), "scalar");
    }

    #[test]
    fn scratch_is_reused_across_blocks() {
        let mut e = Xnor::new();
        let a = job(3, 2, 6, 8, 8, true, 0.3, 1);
        let b = job(5, 3, 2, 9, 9, false, 0.3, 2);
        let ra1 = e.run_block(&a).output;
        let rb = e.run_block(&b).output;
        let ra2 = e.run_block(&a).output;
        assert_eq!(ra1, ra2);
        assert_eq!(rb, reference_xnor_conv(&b.image, &b.kernels, &b.scale_bias, false));
        e.run_block(&a);
        let warm = e.raster_reallocs();
        for seed in 0..4 {
            e.run_block(&job(3, 2, 6, 8, 8, true, 0.3, 100 + seed));
        }
        assert_eq!(e.raster_reallocs(), warm, "steady-state blocks must not allocate");
    }

    #[test]
    fn useful_ops_follow_eq7() {
        let j = job(3, 2, 4, 6, 5, true, 0.3, 3);
        let s = Xnor::new().run_block(&j).stats;
        assert_eq!(s.useful_ops, 2 * 9 * (2 * 4) as u64 * (6 * 5) as u64);
        assert_eq!(s.cycles.total(), 0); // no ledger
    }
}
