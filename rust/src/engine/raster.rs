//! Layer-resident bitplane rasters: activations packed **once**, windows
//! assembled by shifts.
//!
//! The functional engine's popcount identity (see [`crate::engine`] docs)
//! consumes each k×k window as 12 offset-binary plane words. PR 1 rebuilt
//! those words from scratch for every (output pixel × input channel) —
//! `out_h·out_w·n_in·k²·12` bit inserts per block even though a pixel's
//! code never changes within a layer. [`BitplaneRaster`] removes that
//! redundancy the way the chip's image bank does: pack every input pixel
//! exactly once, keep the feature map resident in the layout the datapath
//! consumes, and slide windows over it with shifts.
//!
//! Per (channel, padded row) the raster stores:
//!
//! * **12 plane rows**, u64-packed along x (bit `pc` of plane `b` ⇔ bit
//!   `b` of the pixel's offset-binary code `u = x + 2048`). The
//!   zero-padding halo is pre-baked: halo pixels hold code 2048, i.e.
//!   plane 11 set, all others clear. Each plane row carries one guard
//!   word so two-word window extracts never branch on the row end.
//! * **prefix sums of `u`** (`usums[x]` = Σ of codes left of padded
//!   column x), so a window's Σu is `k` subtractions — one per row —
//!   instead of k² adds.
//!
//! A window's plane word for output position (y, x) then assembles as
//! `k` shift+mask row extracts per plane (window bit `dy·k+dx` ⇔ padded
//! column `x+dx` of padded row `y+dy`), amortized across **all** output
//! channels of that window. Both convolution modes use the same
//! coordinates: with the halo pre-baked, the window for output (y, x)
//! always starts at padded row y, padded column x.
//!
//! The buffers are plain `Vec`s reused across `pack` calls (`resize`
//! after `clear` keeps capacity), so a worker that serves same-geometry
//! frames allocates nothing in steady state — [`Self::reallocs`] counts
//! the packs that actually had to grow, which tests pin down.

use crate::fixedpoint::Q2_9;
use crate::workload::Image;

/// Bitplanes in the offset-binary activation code (12-bit Q2.9).
pub const PLANES: usize = 12;

/// Offset added to a raw Q2.9 sample to make it a non-negative 12-bit
/// code (`x + 2048 ∈ [0, 4096)`). Zero-padding halo pixels carry exactly
/// this code (bit 11 alone).
pub const OFFSET: i64 = 2048;

/// A packed bitplane raster of one image view (a full layer input or one
/// block's tile), with the convolution halo pre-baked. Reusable scratch:
/// `pack_view` overwrites in place and only allocates when it must grow.
#[derive(Debug, Default)]
pub struct BitplaneRaster {
    k: usize,
    channels: usize,
    /// Padded width (w + k − 1 when zero-padded, w otherwise).
    pw: usize,
    /// Padded height per channel.
    ph: usize,
    /// u64 words per plane row, including one guard word.
    stride: usize,
    /// Plane words: `[(c·ph + y)·PLANES + b] · stride`.
    words: Vec<u64>,
    /// Prefix sums of `u` per padded row: `[(c·ph + y)] · (pw + 1)`.
    usums: Vec<i64>,
    reallocs: u64,
    /// Per padded-row checksums over the row's plane words, filled by
    /// [`Self::seal`]. Empty unless the fault-detection path is armed.
    row_chk: Vec<u64>,
    /// Whether `row_chk` matches the current `words` contents.
    sealed: bool,
}

impl BitplaneRaster {
    /// Empty raster scratch (packs lazily on first use).
    pub fn new() -> BitplaneRaster {
        BitplaneRaster::default()
    }

    /// Pack a full image (all channels, all rows) — the layer-resident
    /// form shared by every block of a layer.
    pub fn pack(&mut self, img: &Image, k: usize, zero_pad: bool) {
        self.pack_view(img, k, zero_pad, 0, img.c, 0, img.h);
    }

    /// Pack a sub-view of `img`: channels `c0..c0+c_len`, rows
    /// `y0..y0+y_len`. Rows outside the view read as zero-padding halo
    /// even where the image has data — exactly the per-tile semantics of
    /// a materialized [`crate::hw::BlockJob`].
    ///
    /// This is also where activations are validated: each pixel is
    /// checked against Q2.9 **once** (debug builds), instead of k² times
    /// per pixel in the window inner loop.
    #[allow(clippy::too_many_arguments)] // raw view geometry, mirrors BlockPlan fields
    pub fn pack_view(
        &mut self,
        img: &Image,
        k: usize,
        zero_pad: bool,
        c0: usize,
        c_len: usize,
        y0: usize,
        y_len: usize,
    ) {
        assert!((1..=7).contains(&k), "kernel size {k} unsupported");
        assert!(c0 + c_len <= img.c && y0 + y_len <= img.h, "view outside image");
        let halo = if zero_pad { k - 1 } else { 0 };
        let offset = if zero_pad { (k - 1) / 2 } else { 0 };
        let pw = img.w + halo;
        let ph = y_len + halo;
        let stride = pw.div_ceil(64) + 1; // +1 guard word: branch-free extracts
        self.k = k;
        self.channels = c_len;
        self.pw = pw;
        self.ph = ph;
        self.stride = stride;
        self.sealed = false;
        let word_len = c_len * ph * PLANES * stride;
        let usum_len = c_len * ph * (pw + 1);
        if word_len > self.words.capacity() || usum_len > self.usums.capacity() {
            self.reallocs += 1;
        }
        self.words.clear();
        self.words.resize(word_len, 0);
        self.usums.clear();
        self.usums.resize(usum_len, 0);

        for c in 0..c_len {
            for py in 0..ph {
                let row = c * ph + py;
                let wbase = row * PLANES * stride;
                let ubase = row * (pw + 1);
                // Padded row py holds view row py − offset; outside the
                // view it is all halo (code 2048 = bit 11 alone).
                if py < offset || py >= offset + y_len {
                    Self::fill_halo_row(
                        &mut self.words[wbase..wbase + PLANES * stride],
                        &mut self.usums[ubase..ubase + pw + 1],
                        pw,
                        stride,
                    );
                    continue;
                }
                let src = img.row(c0 + c, y0 + py - offset);
                let words = &mut self.words[wbase..wbase + PLANES * stride];
                let usums = &mut self.usums[ubase..ubase + pw + 1];
                let mut run = 0i64;
                for pc in 0..pw {
                    let u = if (offset..offset + img.w).contains(&pc) {
                        let px = src[pc - offset];
                        debug_assert!(
                            Q2_9.contains(px),
                            "activation {px} outside Q2.9 at packed col {pc}"
                        );
                        (px + OFFSET) as u64
                    } else {
                        OFFSET as u64
                    };
                    run += u as i64;
                    usums[pc + 1] = run;
                    let mut bits = u;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        words[b * stride + (pc >> 6)] |= 1u64 << (pc & 63);
                        bits &= bits - 1;
                    }
                }
            }
        }
    }

    /// Write one all-halo padded row: plane 11 set across `pw` columns,
    /// prefix sums of the constant code 2048.
    fn fill_halo_row(words: &mut [u64], usums: &mut [i64], pw: usize, stride: usize) {
        let p11 = &mut words[11 * stride..12 * stride];
        for wi in 0..pw >> 6 {
            p11[wi] = !0u64;
        }
        if pw & 63 != 0 {
            p11[pw >> 6] = (1u64 << (pw & 63)) - 1;
        }
        for pc in 0..pw {
            usums[pc + 1] = usums[pc] + OFFSET;
        }
    }

    /// Assemble the 12 window plane words for output position (y, x) of
    /// packed channel `c`, and return the window's Σu.
    ///
    /// `y`/`x` are output coordinates, which equal the window's top-left
    /// corner in padded raster coordinates for both convolution modes.
    /// Each plane word is built from `k` shift+mask row extracts (two
    /// word reads per extract, guard word makes the pair unconditional);
    /// Σu is `k` prefix-sum subtractions.
    #[inline]
    pub fn window(&self, c: usize, y: usize, x: usize, out: &mut [u64; PLANES]) -> i64 {
        let k = self.k;
        debug_assert!(c < self.channels, "channel {c} outside raster ({})", self.channels);
        debug_assert!(y + k <= self.ph && x + k <= self.pw, "window ({y},{x}) outside raster");
        let mask = (1u64 << k) - 1;
        let mut sum_u = 0i64;
        *out = [0u64; PLANES];
        let wi = x >> 6;
        let sh = (x & 63) as u32;
        for dy in 0..k {
            let row = c * self.ph + y + dy;
            let ubase = row * (self.pw + 1);
            sum_u += self.usums[ubase + x + k] - self.usums[ubase + x];
            let wbase = row * PLANES * self.stride + wi;
            let jshift = (dy * k) as u32;
            for (b, plane) in out.iter_mut().enumerate() {
                let p = wbase + b * self.stride;
                let lo = self.words[p] >> sh;
                let bits = if sh == 0 { lo } else { lo | (self.words[p + 1] << (64 - sh)) };
                *plane |= (bits & mask) << jshift;
            }
        }
        sum_u
    }

    /// Raw geometry + buffer view for engines that re-implement the
    /// window extract with wider loads (the SIMD engine assembles 4–8
    /// plane words per lane op from the same layout). The guard word per
    /// plane row is part of the contract: `words[p + 1]` is always in
    /// bounds for any in-window extract position `p`.
    #[inline]
    pub(crate) fn raw_parts(&self) -> RasterParts<'_> {
        RasterParts {
            k: self.k,
            ph: self.ph,
            pw: self.pw,
            stride: self.stride,
            words: &self.words,
            usums: &self.usums,
        }
    }

    /// Kernel size this raster was packed for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Channels packed into this raster.
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Padded (height, width) per channel.
    pub fn padded_dims(&self) -> (usize, usize) {
        (self.ph, self.pw)
    }

    /// Number of `pack`/`pack_view` calls that had to grow a buffer.
    /// Steady-state serving of same-geometry frames keeps this constant —
    /// the scratch-reuse tests assert exactly that.
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    /// Checksum every padded row's plane words, arming [`Self::verify`].
    /// Models the parity bits a latch-based image bank would carry: the
    /// fault path seals right after `pack`, injects, then verifies.
    pub fn seal(&mut self) {
        let rows = self.channels * self.ph;
        let span = PLANES * self.stride;
        self.row_chk.clear();
        self.row_chk.resize(rows, 0);
        for r in 0..rows {
            let mut h = mix64(r as u64 ^ 0x5EA1);
            for &w in &self.words[r * span..(r + 1) * span] {
                h = mix64(h ^ w);
            }
            self.row_chk[r] = h;
        }
        self.sealed = true;
    }

    /// First padded row whose plane words no longer match the sealed
    /// checksum, or `None` if the raster is clean (or never sealed).
    pub fn verify(&self) -> Option<usize> {
        if !self.sealed {
            return None;
        }
        let span = PLANES * self.stride;
        for (r, &chk) in self.row_chk.iter().enumerate() {
            let mut h = mix64(r as u64 ^ 0x5EA1);
            for &w in &self.words[r * span..(r + 1) * span] {
                h = mix64(h ^ w);
            }
            if h != chk {
                return Some(r);
            }
        }
        None
    }

    /// Total plane words currently packed (the fault injector's address
    /// space for image-memory upsets).
    pub(crate) fn words_len(&self) -> usize {
        self.words.len()
    }

    /// Flip one bit of one plane word — a single-event upset in the
    /// image bank. Deliberately leaves `usums` untouched: a real upset
    /// corrupts the stored planes only, so [`Self::window`] returns an
    /// inconsistent (Σu, planes) pair exactly like silicon would.
    pub(crate) fn flip_word_bit(&mut self, wi: usize, bit: u32) {
        self.words[wi] ^= 1u64 << bit;
    }

    /// Word range holding padded row `py` of packed channel `c` (all 12
    /// planes) — the rows a halo exchange would retransmit.
    pub(crate) fn row_word_range(&self, c: usize, py: usize) -> std::ops::Range<usize> {
        let span = PLANES * self.stride;
        let base = (c * self.ph + py) * span;
        base..base + span
    }
}

/// The splitmix64 finalizer: a cheap, high-quality 64-bit mixer shared by
/// the raster/kernel checksums and the fault plan's per-site seeding.
pub(crate) fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Borrowed raw view of a packed raster: the geometry and buffers the
/// [`BitplaneRaster::window`] extract walks, exposed crate-internally so
/// the SIMD engine can run the identical extract with vector loads.
#[derive(Debug, Clone, Copy)]
pub(crate) struct RasterParts<'a> {
    pub k: usize,
    pub ph: usize,
    pub pw: usize,
    pub stride: usize,
    /// Plane words: `[(c·ph + y)·PLANES + b] · stride`, one guard word
    /// per plane row.
    pub words: &'a [u64],
    /// Prefix sums of `u`: `[(c·ph + y)] · (pw + 1)`.
    pub usums: &'a [i64],
}

#[cfg(test)]
mod tests {
    // The window-extraction-vs-naive-packing oracle sweep (every kernel
    // size, both modes, u64-word-boundary widths) lives in
    // `rust/tests/raster_props.rs` — the unit tests here cover only what
    // that property cannot see: view/halo semantics and scratch reuse.
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::random_image;

    #[test]
    fn view_rows_outside_tile_read_as_halo() {
        // Packing rows 2..5 of a 8-row image must behave exactly like
        // packing a standalone image holding only those rows.
        let mut g = Gen::new(9);
        let img = random_image(&mut g, 2, 8, 7, 0.3);
        let mut crop = Image::zeros(2, 3, 7);
        for c in 0..2 {
            for y in 0..3 {
                crop.row_mut(c, y).copy_from_slice(img.row(c, 2 + y));
            }
        }
        let mut via_view = BitplaneRaster::new();
        via_view.pack_view(&img, 3, true, 0, 2, 2, 3);
        let mut via_crop = BitplaneRaster::new();
        via_crop.pack(&crop, 3, true);
        let mut a = [0u64; PLANES];
        let mut b = [0u64; PLANES];
        for c in 0..2 {
            for y in 0..3 {
                for x in 0..7 {
                    let ua = via_view.window(c, y, x, &mut a);
                    let ub = via_crop.window(c, y, x, &mut b);
                    assert_eq!((a, ua), (b, ub), "c={c} y={y} x={x}");
                }
            }
        }
    }

    #[test]
    fn repacking_same_geometry_never_reallocates() {
        let mut g = Gen::new(11);
        let img = random_image(&mut g, 3, 10, 9, 0.1);
        let mut r = BitplaneRaster::new();
        r.pack(&img, 3, true);
        let after_first = r.reallocs();
        for _ in 0..5 {
            let frame = random_image(&mut g, 3, 10, 9, 0.1);
            r.pack(&frame, 3, true);
        }
        assert_eq!(r.reallocs(), after_first, "steady-state pack must not allocate");
        // A strictly larger geometry grows once, then is steady again.
        let big = random_image(&mut g, 3, 20, 9, 0.1);
        r.pack(&big, 3, true);
        assert_eq!(r.reallocs(), after_first + 1);
        r.pack(&big, 3, true);
        assert_eq!(r.reallocs(), after_first + 1);
    }

    #[test]
    fn seal_detects_a_single_flipped_bit_and_repack_clears_it() {
        let mut g = Gen::new(13);
        let img = random_image(&mut g, 2, 6, 5, 0.2);
        let mut r = BitplaneRaster::new();
        r.pack(&img, 3, true);
        r.seal();
        assert_eq!(r.verify(), None, "freshly sealed raster must be clean");
        r.flip_word_bit(0, 7);
        assert!(r.verify().is_some(), "flip must trip the row checksum");
        // Repacking rebuilds the words and disarms the stale seal...
        r.pack(&img, 3, true);
        assert_eq!(r.verify(), None);
        // ...and resealing the repacked contents is clean again.
        r.seal();
        assert_eq!(r.verify(), None);
        // Halo-row word ranges address real words.
        let range = r.row_word_range(1, 0);
        assert!(range.end <= r.words_len());
    }
}
