//! Layer-resident **1-bit** activation rasters for the binary-activation
//! (BNN / XNOR) datapath.
//!
//! YodaNN binarizes weights only; its successors (XNORBIN, ChewBaccaNN —
//! PAPERS.md) binarize activations too, so a pixel needs **one** stored
//! bit instead of the 12 offset-binary planes of
//! [`super::BitplaneRaster`]. [`BinaryRaster`] is that raster: per
//! (channel, padded row) a single u64-packed plane row — bit set ⇔ the
//! activation's sign is +1 — which is ~12× less activation traffic and
//! SCM occupancy than the multi-bit raster for the same feature map.
//!
//! The contract deliberately mirrors [`super::BitplaneRaster`] so every
//! consumer of the multi-bit raster (shard planner, row-band schedule,
//! fault injection, per-worker scratch reuse) works unchanged:
//!
//! * same padded geometry (`pw = w + k − 1` zero-padded, `ph` likewise),
//!   with the convolution halo **pre-baked**: a zero-padding pixel has
//!   value 0, and the sign convention `sign(x) = +1 ⇔ x ≥ 0` makes halo
//!   bits *set*;
//! * one **guard word** per plane row, so two-word window extracts never
//!   branch on the row end (the SIMD engine's +1-word loads stay
//!   in-bounds);
//! * reusable scratch: `pack_view` overwrites in place and only
//!   allocates on growth ([`Self::reallocs`] is pinned by tests);
//! * [`Self::seal`]/[`Self::verify`] row checksums and
//!   [`Self::flip_word_bit`]/[`Self::row_word_range`], so the fault
//!   injector treats the binary image bank exactly like the multi-bit
//!   one.
//!
//! **Sign convention.** A raw Q2.9 activation `x` binarizes to
//! `+1 ⇔ x ≥ 0` (the deterministic BinaryConnect sign, matching
//! [`crate::fixedpoint::binarize_det`]), carried downstream as raw
//! `±512` (±1.0 in Q2.9) so binary feature maps remain legal Q2.9
//! images. [`binarize_q29`] is the single source of truth; the naive
//! reference conv, this raster and both XNOR engines all go through it.

use crate::fixedpoint::Q2_9;
use crate::workload::Image;

use super::raster::mix64;

/// Raw Q2.9 value of binary +1 (1.0): what a set raster bit stands for.
pub const BINARY_ONE: i64 = 512;

/// Binarize a raw Q2.9 activation by sign: `+512 ⇔ x ≥ 0`, else `−512`.
/// Zero (and therefore the zero-padding halo) binarizes to +1, exactly
/// like the deterministic BinaryConnect sign on weights.
#[inline]
pub const fn binarize_q29(x: i64) -> i64 {
    if x >= 0 {
        BINARY_ONE
    } else {
        -BINARY_ONE
    }
}

/// A packed 1-bit sign raster of one image view (a full layer input or
/// one block's tile), with the convolution halo pre-baked. Reusable
/// scratch: `pack_view` overwrites in place and only allocates when it
/// must grow.
#[derive(Debug, Default)]
pub struct BinaryRaster {
    k: usize,
    channels: usize,
    /// Padded width (w + k − 1 when zero-padded, w otherwise).
    pw: usize,
    /// Padded height per channel.
    ph: usize,
    /// u64 words per plane row, including one guard word.
    stride: usize,
    /// Sign-plane words: `[(c·ph + y)] · stride`.
    words: Vec<u64>,
    reallocs: u64,
    /// Per padded-row checksums, filled by [`Self::seal`].
    row_chk: Vec<u64>,
    /// Whether `row_chk` matches the current `words` contents.
    sealed: bool,
}

impl BinaryRaster {
    /// Empty raster scratch (packs lazily on first use).
    pub fn new() -> BinaryRaster {
        BinaryRaster::default()
    }

    /// Pack a full image (all channels, all rows) — the layer-resident
    /// form shared by every block of a layer.
    pub fn pack(&mut self, img: &Image, k: usize, zero_pad: bool) {
        self.pack_view(img, k, zero_pad, 0, img.c, 0, img.h);
    }

    /// Pack a sub-view of `img`: channels `c0..c0+c_len`, rows
    /// `y0..y0+y_len`. Rows outside the view read as zero-padding halo
    /// (sign +1) even where the image has data — the same per-tile
    /// semantics as [`super::BitplaneRaster::pack_view`].
    #[allow(clippy::too_many_arguments)] // raw view geometry, mirrors BlockPlan fields
    pub fn pack_view(
        &mut self,
        img: &Image,
        k: usize,
        zero_pad: bool,
        c0: usize,
        c_len: usize,
        y0: usize,
        y_len: usize,
    ) {
        assert!((1..=7).contains(&k), "kernel size {k} unsupported");
        assert!(c0 + c_len <= img.c && y0 + y_len <= img.h, "view outside image");
        let halo = if zero_pad { k - 1 } else { 0 };
        let offset = if zero_pad { (k - 1) / 2 } else { 0 };
        let pw = img.w + halo;
        let ph = y_len + halo;
        let stride = pw.div_ceil(64) + 1; // +1 guard word: branch-free extracts
        self.k = k;
        self.channels = c_len;
        self.pw = pw;
        self.ph = ph;
        self.stride = stride;
        self.sealed = false;
        let word_len = c_len * ph * stride;
        if word_len > self.words.capacity() {
            self.reallocs += 1;
        }
        self.words.clear();
        self.words.resize(word_len, 0);

        for c in 0..c_len {
            for py in 0..ph {
                let row = c * ph + py;
                let words = &mut self.words[row * stride..(row + 1) * stride];
                // Padded row py holds view row py − offset; outside the
                // view it is all halo (value 0 → sign +1 → bits set).
                if py < offset || py >= offset + y_len {
                    Self::fill_halo_row(words, pw);
                    continue;
                }
                let src = img.row(c0 + c, y0 + py - offset);
                for pc in 0..pw {
                    let plus = if (offset..offset + img.w).contains(&pc) {
                        let px = src[pc - offset];
                        debug_assert!(
                            Q2_9.contains(px),
                            "activation {px} outside Q2.9 at packed col {pc}"
                        );
                        px >= 0
                    } else {
                        true // halo pixel: value 0 → sign +1
                    };
                    if plus {
                        words[pc >> 6] |= 1u64 << (pc & 63);
                    }
                }
            }
        }
    }

    /// Write one all-halo padded row: sign bits set across `pw` columns
    /// (halo value 0 binarizes to +1), guard word clear.
    fn fill_halo_row(words: &mut [u64], pw: usize) {
        for wi in 0..pw >> 6 {
            words[wi] = !0u64;
        }
        if pw & 63 != 0 {
            words[pw >> 6] = (1u64 << (pw & 63)) - 1;
        }
    }

    /// Assemble the k²-bit sign window for output position (y, x) of
    /// packed channel `c`: window bit `dy·k + dx` ⇔ padded column
    /// `x + dx` of padded row `y + dy` — the same bit order as
    /// [`super::PackedKernels::word`], so the XNOR dot is one
    /// `XOR` + `POPCNT` per (window, output channel).
    #[inline]
    pub fn window(&self, c: usize, y: usize, x: usize) -> u64 {
        let k = self.k;
        debug_assert!(c < self.channels, "channel {c} outside raster ({})", self.channels);
        debug_assert!(y + k <= self.ph && x + k <= self.pw, "window ({y},{x}) outside raster");
        let mask = (1u64 << k) - 1;
        let wi = x >> 6;
        let sh = (x & 63) as u32;
        let mut out = 0u64;
        for dy in 0..k {
            let p = (c * self.ph + y + dy) * self.stride + wi;
            let lo = self.words[p] >> sh;
            let bits = if sh == 0 { lo } else { lo | (self.words[p + 1] << (64 - sh)) };
            out |= (bits & mask) << (dy * k);
        }
        out
    }

    /// Raw geometry + buffer view for engines that re-implement the
    /// window extract with wider loads. The guard word per plane row is
    /// part of the contract: `words[p + 1]` is always in bounds for any
    /// in-window extract position `p`.
    #[inline]
    pub(crate) fn raw_parts(&self) -> BinaryParts<'_> {
        BinaryParts {
            k: self.k,
            ph: self.ph,
            pw: self.pw,
            stride: self.stride,
            words: &self.words,
        }
    }

    /// Kernel size this raster was packed for.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Channels packed into this raster.
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Padded (height, width) per channel.
    pub fn padded_dims(&self) -> (usize, usize) {
        (self.ph, self.pw)
    }

    /// Number of `pack`/`pack_view` calls that had to grow a buffer —
    /// steady-state serving of same-geometry frames keeps this constant.
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    /// Activation words this raster occupies (guard words included) —
    /// the binary image bank's footprint, ~12× below the multi-bit
    /// raster's for the same view. The XNOR power model prices I/O and
    /// SCM occupancy from this.
    pub fn words_total(&self) -> usize {
        self.words.len()
    }

    /// Checksum every padded row's sign words, arming [`Self::verify`] —
    /// the parity a latch-based binary image bank would carry.
    pub fn seal(&mut self) {
        let rows = self.channels * self.ph;
        let span = self.stride;
        self.row_chk.clear();
        self.row_chk.resize(rows, 0);
        for r in 0..rows {
            let mut h = mix64(r as u64 ^ 0xB1A5);
            for &w in &self.words[r * span..(r + 1) * span] {
                h = mix64(h ^ w);
            }
            self.row_chk[r] = h;
        }
        self.sealed = true;
    }

    /// First padded row whose sign words no longer match the sealed
    /// checksum, or `None` if the raster is clean (or never sealed).
    pub fn verify(&self) -> Option<usize> {
        if !self.sealed {
            return None;
        }
        let span = self.stride;
        for (r, &chk) in self.row_chk.iter().enumerate() {
            let mut h = mix64(r as u64 ^ 0xB1A5);
            for &w in &self.words[r * span..(r + 1) * span] {
                h = mix64(h ^ w);
            }
            if h != chk {
                return Some(r);
            }
        }
        None
    }

    /// Total sign words currently packed (the fault injector's address
    /// space for binary image-memory upsets).
    pub(crate) fn words_len(&self) -> usize {
        self.words.len()
    }

    /// Flip one bit of one sign word — a single-event upset in the
    /// binary image bank. In a 1-bit raster a single flipped bit is a
    /// full sign inversion of that pixel, which is what makes BNN
    /// datapaths so sensitive to near-threshold upsets.
    pub(crate) fn flip_word_bit(&mut self, wi: usize, bit: u32) {
        self.words[wi] ^= 1u64 << bit;
    }

    /// Word range holding padded row `py` of packed channel `c` — the
    /// row a halo exchange would retransmit.
    pub(crate) fn row_word_range(&self, c: usize, py: usize) -> std::ops::Range<usize> {
        let base = (c * self.ph + py) * self.stride;
        base..base + self.stride
    }
}

/// Borrowed raw view of a packed binary raster (geometry + sign words),
/// exposed crate-internally so the XNOR SIMD engine can run the
/// identical window extract with vector loads.
#[derive(Debug, Clone, Copy)]
pub(crate) struct BinaryParts<'a> {
    pub k: usize,
    pub ph: usize,
    pub pw: usize,
    pub stride: usize,
    /// Sign words: `[(c·ph + y)] · stride`, one guard word per row.
    pub words: &'a [u64],
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::random_image;

    /// Naive window oracle: binarize straight from the image with the
    /// same halo semantics and compare bit by bit.
    fn naive_window(
        img: &Image,
        k: usize,
        zero_pad: bool,
        c: usize,
        y: usize,
        x: usize,
    ) -> u64 {
        let offset = if zero_pad { ((k - 1) / 2) as isize } else { 0 };
        let mut out = 0u64;
        for dy in 0..k {
            for dx in 0..k {
                let iy = y as isize + dy as isize - offset;
                let ix = x as isize + dx as isize - offset;
                let px = if (0..img.h as isize).contains(&iy) && (0..img.w as isize).contains(&ix)
                {
                    img.at(c, iy as usize, ix as usize)
                } else {
                    0
                };
                if binarize_q29(px) == BINARY_ONE {
                    out |= 1u64 << (dy * k + dx);
                }
            }
        }
        out
    }

    #[test]
    fn window_matches_naive_binarization_every_kernel_size() {
        let mut g = Gen::new(17);
        for k in 1..=7usize {
            for zp in [true, false] {
                if !zp && k > 1 {
                    continue;
                }
                let img = random_image(&mut g, 2, 9, 8, 0.4);
                let mut r = BinaryRaster::new();
                r.pack(&img, k, zp);
                let (out_h, out_w) =
                    if zp { (img.h, img.w) } else { (img.h + 1 - k, img.w + 1 - k) };
                for c in 0..img.c {
                    for y in 0..out_h {
                        for x in 0..out_w {
                            assert_eq!(
                                r.window(c, y, x),
                                naive_window(&img, k, zp, c, y, x),
                                "k={k} zp={zp} c={c} y={y} x={x}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn word_boundary_windows_match_naive() {
        // Widths whose windows straddle u64 word boundaries — the
        // shift-pair extract's edge cases, guard word included.
        let mut g = Gen::new(19);
        for w in [63usize, 64, 65, 66, 127, 130] {
            let img = random_image(&mut g, 1, 4, w, 0.3);
            let mut r = BinaryRaster::new();
            r.pack(&img, 3, true);
            for y in 0..img.h {
                for x in 0..img.w {
                    assert_eq!(
                        r.window(0, y, x),
                        naive_window(&img, 3, true, 0, y, x),
                        "w={w} y={y} x={x}"
                    );
                }
            }
        }
    }

    #[test]
    fn view_rows_outside_tile_read_as_halo() {
        // Packing rows 2..5 of an 8-row image must behave exactly like
        // packing a standalone image holding only those rows — the same
        // tile semantics as BitplaneRaster.
        let mut g = Gen::new(23);
        let img = random_image(&mut g, 2, 8, 7, 0.3);
        let mut crop = Image::zeros(2, 3, 7);
        for c in 0..2 {
            for y in 0..3 {
                crop.row_mut(c, y).copy_from_slice(img.row(c, 2 + y));
            }
        }
        let mut via_view = BinaryRaster::new();
        via_view.pack_view(&img, 3, true, 0, 2, 2, 3);
        let mut via_crop = BinaryRaster::new();
        via_crop.pack(&crop, 3, true);
        for c in 0..2 {
            for y in 0..3 {
                for x in 0..7 {
                    assert_eq!(
                        via_view.window(c, y, x),
                        via_crop.window(c, y, x),
                        "c={c} y={y} x={x}"
                    );
                }
            }
        }
    }

    #[test]
    fn repacking_same_geometry_never_reallocates() {
        let mut g = Gen::new(29);
        let img = random_image(&mut g, 3, 10, 9, 0.1);
        let mut r = BinaryRaster::new();
        r.pack(&img, 3, true);
        let after_first = r.reallocs();
        for _ in 0..5 {
            let frame = random_image(&mut g, 3, 10, 9, 0.1);
            r.pack(&frame, 3, true);
        }
        assert_eq!(r.reallocs(), after_first, "steady-state pack must not allocate");
        let big = random_image(&mut g, 3, 20, 9, 0.1);
        r.pack(&big, 3, true);
        assert_eq!(r.reallocs(), after_first + 1);
        r.pack(&big, 3, true);
        assert_eq!(r.reallocs(), after_first + 1);
    }

    #[test]
    fn seal_detects_a_single_flipped_bit_and_repack_clears_it() {
        let mut g = Gen::new(31);
        let img = random_image(&mut g, 2, 6, 5, 0.2);
        let mut r = BinaryRaster::new();
        r.pack(&img, 3, true);
        r.seal();
        assert_eq!(r.verify(), None, "freshly sealed raster must be clean");
        r.flip_word_bit(0, 7);
        assert!(r.verify().is_some(), "flip must trip the row checksum");
        r.pack(&img, 3, true);
        assert_eq!(r.verify(), None);
        r.seal();
        assert_eq!(r.verify(), None);
        let range = r.row_word_range(1, 0);
        assert!(range.end <= r.words_len());
    }

    #[test]
    fn binary_raster_is_about_12x_smaller_than_bitplanes() {
        // The headline of the XNOR generation: same view, 1 plane word
        // per (channel, padded row) instead of 12.
        let mut g = Gen::new(37);
        let img = random_image(&mut g, 4, 16, 16, 0.2);
        let mut bin = BinaryRaster::new();
        bin.pack(&img, 3, true);
        let mut multi = super::super::BitplaneRaster::new();
        multi.pack(&img, 3, true);
        // Identical padded geometry, exactly PLANES× fewer plane words
        // (and the multi-bit raster additionally carries prefix sums the
        // binary path never needs).
        assert_eq!(multi.padded_dims(), bin.padded_dims());
        assert_eq!(bin.words_total() * super::super::raster::PLANES, multi.words_len());
    }

    #[test]
    fn binarize_convention_is_sign_with_zero_positive() {
        assert_eq!(binarize_q29(0), BINARY_ONE);
        assert_eq!(binarize_q29(2047), BINARY_ONE);
        assert_eq!(binarize_q29(-1), -BINARY_ONE);
        assert_eq!(binarize_q29(-2048), -BINARY_ONE);
    }
}
