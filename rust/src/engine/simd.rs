//! `FunctionalSimd`: the functional popcount datapath with the two hot
//! inner loops vectorized via `std::arch` — windows assembled 4 bitplane
//! words per lane op on AVX2 (2 on NEON), and the grouped-popcount dot
//! evaluated 4 output channels per lane op (2 on NEON).
//!
//! The engine reuses the exact [`BitplaneRaster`] layout — packing is
//! unchanged, only the window-extract + dot inner loop of
//! [`super::Functional`]'s raster path vectorizes. Every operation is
//! exact integer arithmetic (shifts, masks, popcounts, adds), so the
//! vector paths are **bit-identical** to the scalar fallback and to
//! [`super::Functional`]/[`super::CycleAccurate`] by construction; the
//! conformance fuzzer pins this across ~100 geometries per run.
//!
//! Dispatch is decided **once at engine construction**, at runtime:
//!
//! * x86-64 with AVX2 (detected via
//!   `std::arch::is_x86_feature_detected!`) → 256-bit lanes,
//! * aarch64 → NEON (mandatory on that architecture) → 128-bit lanes,
//! * anything else, or `YODANN_FORCE_SCALAR=1` in the environment, or
//!   [`FunctionalSimd::forced_scalar`] → the portable scalar loop
//!   (identical to `Functional`'s, kept so every platform runs the same
//!   numbers and CI can exercise the fallback on SIMD-capable hosts).
//!
//! There is deliberately **no compile-time dispatch**: the crate builds
//! without `target-cpu=native` (see `.cargo/config.toml`), and the only
//! thing that decides which inner loop runs is the `Isa` picked here.
//!
//! AVX2 has no 64-bit popcount instruction; the dot loop uses the
//! classic nibble-LUT scheme (two `PSHUFB` table lookups for per-byte
//! counts, `PSADBW` against zero to sum each u64 lane). NEON uses
//! `CNT` + the widening pairwise-add chain. Both produce the same exact
//! per-lane popcount as `u64::count_ones`.

use super::functional::PackedKernels;
use super::raster::{BitplaneRaster, OFFSET, PLANES};
use super::{BlockPlan, ConvEngine, EngineOutput, LayerData};
use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
use crate::hw::{BlockJob, ChipStats};
use crate::workload::Image;

/// Lane ISA for the vector inner loops, decided once per engine. Shared
/// with the XNOR engine family ([`super::xnor`]), which dispatches the
/// same way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Isa {
    /// Portable scalar loops — the forced fallback, and the default on
    /// architectures without a vector path.
    Scalar,
    /// 256-bit AVX2 lanes: 4 plane words / 4 output channels per op.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 128-bit NEON lanes: 2 plane words / 2 output channels per op.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// `YODANN_FORCE_SCALAR` set (and not "0") disables the vector paths —
/// CI runs the whole suite once this way so the fallback cannot rot.
fn env_forces_scalar() -> bool {
    std::env::var_os("YODANN_FORCE_SCALAR").is_some_and(|v| v != "0")
}

impl Isa {
    #[allow(unreachable_code)] // arch-dependent tail after cfg'd returns
    pub(crate) fn detect(force_scalar: bool) -> Isa {
        if force_scalar || env_forces_scalar() {
            return Isa::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            return Isa::Neon;
        }
        Isa::Scalar
    }

    pub(crate) fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => "neon",
        }
    }
}

/// The SIMD functional engine. Same scratch discipline as
/// [`super::Functional`]: reusable accumulators and raster, nothing
/// allocated per block in steady state.
#[derive(Debug)]
pub struct FunctionalSimd {
    accs: Vec<i64>,
    raster: BitplaneRaster,
    isa: Isa,
    forced_scalar: bool,
}

impl Default for FunctionalSimd {
    fn default() -> FunctionalSimd {
        FunctionalSimd::new()
    }
}

impl FunctionalSimd {
    /// New engine with the best lane ISA the host offers (honours
    /// `YODANN_FORCE_SCALAR`).
    pub fn new() -> FunctionalSimd {
        FunctionalSimd::with(false)
    }

    /// New engine pinned to the portable scalar loop regardless of host
    /// features — the conformance matrix runs this variant alongside the
    /// vector one so the fallback is pinned bit-identical automatically.
    pub fn forced_scalar() -> FunctionalSimd {
        FunctionalSimd::with(true)
    }

    fn with(forced_scalar: bool) -> FunctionalSimd {
        FunctionalSimd {
            accs: Vec::new(),
            raster: BitplaneRaster::new(),
            isa: Isa::detect(forced_scalar),
            forced_scalar,
        }
    }

    /// The lane ISA this engine dispatches to: `"avx2"`, `"neon"` or
    /// `"scalar"`.
    pub fn isa_name(&self) -> &'static str {
        self.isa.name()
    }

    /// Raster-scratch packs that had to grow a buffer (see
    /// [`BitplaneRaster::reallocs`]).
    pub fn raster_reallocs(&self) -> u64 {
        self.raster.reallocs()
    }

    /// Tile output shape of a plan (mirrors `Functional::out_dims`).
    fn out_dims(layer: &LayerData<'_>, plan: &BlockPlan) -> (usize, usize) {
        let (k, w, tile_h) = (layer.k, layer.input.w, plan.tile_h);
        if !layer.zero_pad {
            assert!(
                tile_h >= k && w >= k,
                "tile {tile_h}x{w} smaller than kernel {k} (valid mode)"
            );
        }
        if layer.zero_pad {
            (tile_h, w)
        } else {
            (tile_h + 1 - k, w + 1 - k)
        }
    }

    fn run_plan_impl(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let k = layer.k;
        let kk = k * k;
        let (out_h, out_w) = Self::out_dims(layer, plan);
        let n_in = plan.in_len;
        let n_out = plan.out_len;
        let local;
        let packed: &PackedKernels = match layer.packed {
            Some(p) => {
                debug_assert_eq!(p.k, k);
                p
            }
            None => {
                local = PackedKernels::pack(layer.kernels);
                &local
            }
        };
        let identity = plan.in_blocks > 1;
        let isa = self.isa;
        // Split-borrow the scratch fields so the raster can be packed
        // mutably and then read while `accs` is written.
        let FunctionalSimd { accs, raster: scratch, .. } = self;
        // (c_base, row0) map plan-local (channel, window row) into raster
        // coordinates, exactly like the Functional engine.
        let (raster, c_base, row0): (&BitplaneRaster, usize, usize) = match layer.raster {
            Some(r) => {
                debug_assert_eq!(r.k(), k);
                (r, plan.in_base, plan.clip0)
            }
            None => {
                scratch.pack_view(
                    layer.input,
                    k,
                    layer.zero_pad,
                    plan.in_base,
                    plan.in_len,
                    plan.clip0,
                    plan.tile_h,
                );
                (&*scratch, 0, 0)
            }
        };
        let m = packed.planes_per_group();
        // Per-sub-plane fold multipliers (see Functional::run_plan_raster).
        let mut fold = [0u64; PLANES];
        for (t, f) in fold[..m].iter_mut().enumerate() {
            let copies = 1usize << t;
            for cpy in 0..copies {
                *f |= 1u64 << ((copies - 1 + cpy) * kk);
            }
        }
        let mut out = Image::zeros(n_out, out_h, out_w);
        accs.clear();
        accs.resize(n_out, 0);
        match isa {
            Isa::Scalar => conv_scalar(
                raster, c_base, row0, layer, plan, packed, identity, &fold, &mut out, accs,
            ),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                // SAFETY: Isa::Avx2 is only selected after
                // is_x86_feature_detected!("avx2") returned true.
                unsafe {
                    avx2::conv(
                        raster.raw_parts(),
                        c_base,
                        row0,
                        layer,
                        plan,
                        packed,
                        identity,
                        &fold,
                        &mut out,
                        accs,
                    )
                }
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => {
                // SAFETY: NEON is mandatory on aarch64.
                unsafe {
                    neon::conv(
                        raster.raw_parts(),
                        c_base,
                        row0,
                        layer,
                        plan,
                        packed,
                        identity,
                        &fold,
                        &mut out,
                        accs,
                    )
                }
            }
        }
        let stats = ChipStats {
            useful_ops: 2 * kk as u64 * (n_in * n_out) as u64 * (out_h * out_w) as u64,
            ..Default::default()
        };
        EngineOutput { output: out, stats }
    }
}

/// The portable fallback: byte-for-byte the Functional engine's raster
/// hot loop, via [`BitplaneRaster::window`]. Kept as a free function so
/// the vector paths and this one share the identical caller.
#[allow(clippy::too_many_arguments)] // one flat hot-loop context, mirrors the vector paths
fn conv_scalar(
    raster: &BitplaneRaster,
    c_base: usize,
    row0: usize,
    layer: &LayerData<'_>,
    plan: &BlockPlan,
    packed: &PackedKernels,
    identity: bool,
    fold: &[u64; PLANES],
    out: &mut Image,
    accs: &mut [i64],
) {
    let (out_h, out_w) = (out.h, out.w);
    let n_in = plan.in_len;
    let n_out = plan.out_len;
    let m = packed.planes_per_group();
    let groups = PLANES / m;
    let mut planes = [0u64; PLANES];
    let mut gwords = [0u64; PLANES];
    for y in 0..out_h {
        for x in 0..out_w {
            accs.iter_mut().for_each(|a| *a = 0);
            for i in 0..n_in {
                let sum_u = raster.window(c_base + i, row0 + y, x, &mut planes);
                if m == 1 {
                    gwords = planes;
                } else {
                    for (g, gw) in gwords[..groups].iter_mut().enumerate() {
                        let mut acc = 0u64;
                        for (t, &u) in planes[g * m..g * m + m].iter().enumerate() {
                            acc |= u * fold[t];
                        }
                        *gw = acc;
                    }
                }
                let reps = packed.rep_slice(plan.in_base + i, plan.out_base, n_out);
                let signs = packed.sign_slice(plan.in_base + i, plan.out_base, n_out);
                for (o, acc) in accs.iter_mut().enumerate() {
                    let rep = reps[o];
                    let mut dot2: i64 = 0;
                    for (g, &gw) in gwords[..groups].iter().enumerate() {
                        dot2 += ((gw & rep).count_ones() as i64) << (m * g);
                    }
                    let sop = 2 * dot2 - sum_u - OFFSET * signs[o];
                    *acc = sat_add(Q7_9, *acc, sop);
                }
            }
            for (o, &acc) in accs.iter().enumerate() {
                let (alpha, beta) = if identity {
                    (512, 0)
                } else {
                    (
                        layer.scale_bias.alpha[plan.out_base + o],
                        layer.scale_bias.beta[plan.out_base + o],
                    )
                };
                *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::super::functional::PackedKernels;
    use super::super::raster::{RasterParts, OFFSET, PLANES};
    use super::super::{BlockPlan, LayerData};
    use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
    use crate::workload::Image;

    /// Per-64-bit-lane popcount (AVX2 has no `VPOPCNTQ`): nibble-LUT
    /// byte counts via two `PSHUFB` lookups, summed into each u64 lane
    /// by `PSADBW` against zero. Exact: equals `u64::count_ones` per
    /// lane.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
        _mm256_sad_epu8(cnt, _mm256_setzero_si256())
    }

    /// The AVX2 hot loop: same iteration order and saturation points as
    /// the scalar path, with the window extract processing 4 plane words
    /// per lane op and the dot 4 output channels per lane op.
    #[allow(clippy::too_many_arguments)] // one flat hot-loop context
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn conv(
        parts: RasterParts<'_>,
        c_base: usize,
        row0: usize,
        layer: &LayerData<'_>,
        plan: &BlockPlan,
        packed: &PackedKernels,
        identity: bool,
        fold: &[u64; PLANES],
        out: &mut Image,
        accs: &mut [i64],
    ) {
        let k = parts.k;
        let (out_h, out_w) = (out.h, out.w);
        let n_in = plan.in_len;
        let n_out = plan.out_len;
        let m = packed.planes_per_group();
        let groups = PLANES / m;
        let stride = parts.stride;
        let words = parts.words;
        let usums = parts.usums;
        let maskv = _mm256_set1_epi64x(((1u64 << k) - 1) as i64);
        let mut planes = [0u64; PLANES];
        let mut gwords = [0u64; PLANES];
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                let wi = x >> 6;
                // Variable AVX2 shifts yield 0 for counts >= 64, so the
                // (lo >> sh) | (hi << (64 - sh)) extract needs no sh == 0
                // branch — unlike the scalar path, where << 64 is UB.
                let shr = _mm_cvtsi32_si128((x & 63) as i32);
                let shl = _mm_cvtsi32_si128((64 - (x & 63)) as i32);
                for i in 0..n_in {
                    let mut pv = [_mm256_setzero_si256(); PLANES / 4];
                    let mut sum_u = 0i64;
                    for dy in 0..k {
                        let row = (c_base + i) * parts.ph + row0 + y + dy;
                        let ubase = row * (parts.pw + 1);
                        sum_u += usums[ubase + x + k] - usums[ubase + x];
                        let wbase = row * PLANES * stride + wi;
                        let jshift = _mm_cvtsi32_si128((dy * k) as i32);
                        for (q, acc) in pv.iter_mut().enumerate() {
                            let b0 = wbase + 4 * q * stride;
                            // 4 plane rows per lane op; the raster's
                            // guard word makes the +1 loads in-bounds.
                            let lo = _mm256_set_epi64x(
                                words[b0 + 3 * stride] as i64,
                                words[b0 + 2 * stride] as i64,
                                words[b0 + stride] as i64,
                                words[b0] as i64,
                            );
                            let hi = _mm256_set_epi64x(
                                words[b0 + 3 * stride + 1] as i64,
                                words[b0 + 2 * stride + 1] as i64,
                                words[b0 + stride + 1] as i64,
                                words[b0 + 1] as i64,
                            );
                            let bits = _mm256_or_si256(
                                _mm256_srl_epi64(lo, shr),
                                _mm256_sll_epi64(hi, shl),
                            );
                            let bits = _mm256_and_si256(bits, maskv);
                            *acc = _mm256_or_si256(*acc, _mm256_sll_epi64(bits, jshift));
                        }
                    }
                    for (q, &v) in pv.iter().enumerate() {
                        _mm256_storeu_si256(planes.as_mut_ptr().add(4 * q) as *mut __m256i, v);
                    }
                    // Fold stays scalar: cross-lane, and at most 12
                    // multiplies per (window, input channel).
                    if m == 1 {
                        gwords = planes;
                    } else {
                        for (g, gw) in gwords[..groups].iter_mut().enumerate() {
                            let mut acc = 0u64;
                            for (t, &u) in planes[g * m..g * m + m].iter().enumerate() {
                                acc |= u * fold[t];
                            }
                            *gw = acc;
                        }
                    }
                    let reps = packed.rep_slice(plan.in_base + i, plan.out_base, n_out);
                    let signs = packed.sign_slice(plan.in_base + i, plan.out_base, n_out);
                    let mut o = 0usize;
                    while o + 4 <= n_out {
                        let mut dot2v = _mm256_setzero_si256();
                        for (g, &gw) in gwords[..groups].iter().enumerate() {
                            let repv =
                                _mm256_loadu_si256(reps.as_ptr().add(o) as *const __m256i);
                            let pc =
                                popcnt_epi64(_mm256_and_si256(_mm256_set1_epi64x(gw as i64), repv));
                            dot2v = _mm256_add_epi64(
                                dot2v,
                                _mm256_sll_epi64(pc, _mm_cvtsi32_si128((m * g) as i32)),
                            );
                        }
                        let mut d = [0i64; 4];
                        _mm256_storeu_si256(d.as_mut_ptr() as *mut __m256i, dot2v);
                        for (l, &dot2) in d.iter().enumerate() {
                            let sop = 2 * dot2 - sum_u - OFFSET * signs[o + l];
                            accs[o + l] = sat_add(Q7_9, accs[o + l], sop);
                        }
                        o += 4;
                    }
                    while o < n_out {
                        let rep = reps[o];
                        let mut dot2: i64 = 0;
                        for (g, &gw) in gwords[..groups].iter().enumerate() {
                            dot2 += ((gw & rep).count_ones() as i64) << (m * g);
                        }
                        let sop = 2 * dot2 - sum_u - OFFSET * signs[o];
                        accs[o] = sat_add(Q7_9, accs[o], sop);
                        o += 1;
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::super::functional::PackedKernels;
    use super::super::raster::{RasterParts, OFFSET, PLANES};
    use super::super::{BlockPlan, LayerData};
    use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
    use crate::workload::Image;

    /// Per-64-bit-lane popcount: `CNT` byte counts widened pairwise up
    /// to u64. Exact: equals `u64::count_ones` per lane.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn popcnt_u64x2(v: uint64x2_t) -> uint64x2_t {
        vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(v)))))
    }

    /// The NEON hot loop: same iteration order and saturation points as
    /// the scalar path, 2 plane words / 2 output channels per lane op.
    #[allow(clippy::too_many_arguments)] // one flat hot-loop context
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn conv(
        parts: RasterParts<'_>,
        c_base: usize,
        row0: usize,
        layer: &LayerData<'_>,
        plan: &BlockPlan,
        packed: &PackedKernels,
        identity: bool,
        fold: &[u64; PLANES],
        out: &mut Image,
        accs: &mut [i64],
    ) {
        let k = parts.k;
        let (out_h, out_w) = (out.h, out.w);
        let n_in = plan.in_len;
        let n_out = plan.out_len;
        let m = packed.planes_per_group();
        let groups = PLANES / m;
        let stride = parts.stride;
        let words = parts.words;
        let usums = parts.usums;
        let maskv = vdupq_n_u64((1u64 << k) - 1);
        let mut planes = [0u64; PLANES];
        let mut gwords = [0u64; PLANES];
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                let wi = x >> 6;
                // USHL with a negative count shifts right; out-of-range
                // counts (sh = 0 -> left shift by 64) yield 0, so the
                // extract needs no sh == 0 branch.
                let shr = vdupq_n_s64(-((x & 63) as i64));
                let shl = vdupq_n_s64(64 - (x & 63) as i64);
                for i in 0..n_in {
                    let mut pv = [vdupq_n_u64(0); PLANES / 2];
                    let mut sum_u = 0i64;
                    for dy in 0..k {
                        let row = (c_base + i) * parts.ph + row0 + y + dy;
                        let ubase = row * (parts.pw + 1);
                        sum_u += usums[ubase + x + k] - usums[ubase + x];
                        let wbase = row * PLANES * stride + wi;
                        let jshift = vdupq_n_s64((dy * k) as i64);
                        for (q, acc) in pv.iter_mut().enumerate() {
                            let b0 = wbase + 2 * q * stride;
                            // 2 plane rows per lane op; the raster's
                            // guard word makes the +1 loads in-bounds.
                            let lo_pair = [words[b0], words[b0 + stride]];
                            let hi_pair = [words[b0 + 1], words[b0 + stride + 1]];
                            let lo = vld1q_u64(lo_pair.as_ptr());
                            let hi = vld1q_u64(hi_pair.as_ptr());
                            let bits =
                                vorrq_u64(vshlq_u64(lo, shr), vshlq_u64(hi, shl));
                            let bits = vandq_u64(bits, maskv);
                            *acc = vorrq_u64(*acc, vshlq_u64(bits, jshift));
                        }
                    }
                    for (q, &v) in pv.iter().enumerate() {
                        vst1q_u64(planes.as_mut_ptr().add(2 * q), v);
                    }
                    // Fold stays scalar: cross-lane, and at most 12
                    // multiplies per (window, input channel).
                    if m == 1 {
                        gwords = planes;
                    } else {
                        for (g, gw) in gwords[..groups].iter_mut().enumerate() {
                            let mut acc = 0u64;
                            for (t, &u) in planes[g * m..g * m + m].iter().enumerate() {
                                acc |= u * fold[t];
                            }
                            *gw = acc;
                        }
                    }
                    let reps = packed.rep_slice(plan.in_base + i, plan.out_base, n_out);
                    let signs = packed.sign_slice(plan.in_base + i, plan.out_base, n_out);
                    let mut o = 0usize;
                    while o + 2 <= n_out {
                        let mut dot2v = vdupq_n_u64(0);
                        for (g, &gw) in gwords[..groups].iter().enumerate() {
                            let repv = vld1q_u64(reps.as_ptr().add(o));
                            let pc = popcnt_u64x2(vandq_u64(vdupq_n_u64(gw), repv));
                            dot2v = vaddq_u64(dot2v, vshlq_u64(pc, vdupq_n_s64((m * g) as i64)));
                        }
                        let d = [
                            vgetq_lane_u64::<0>(dot2v) as i64,
                            vgetq_lane_u64::<1>(dot2v) as i64,
                        ];
                        for (l, &dot2) in d.iter().enumerate() {
                            let sop = 2 * dot2 - sum_u - OFFSET * signs[o + l];
                            accs[o + l] = sat_add(Q7_9, accs[o + l], sop);
                        }
                        o += 2;
                    }
                    while o < n_out {
                        let rep = reps[o];
                        let mut dot2: i64 = 0;
                        for (g, &gw) in gwords[..groups].iter().enumerate() {
                            dot2 += ((gw & rep).count_ones() as i64) << (m * g);
                        }
                        let sop = 2 * dot2 - sum_u - OFFSET * signs[o];
                        accs[o] = sat_add(Q7_9, accs[o], sop);
                        o += 1;
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
    }
}

impl ConvEngine for FunctionalSimd {
    fn name(&self) -> &'static str {
        if self.forced_scalar {
            "functional-simd-scalar"
        } else {
            "functional-simd"
        }
    }

    fn wants_packed(&self) -> bool {
        true
    }

    fn wants_raster(&self) -> bool {
        true
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        let layer = LayerData {
            k: job.k,
            zero_pad: job.zero_pad,
            input: &job.image,
            kernels: &job.kernels,
            packed: None,
            raster: None,
            binary: None,
            scale_bias: &job.scale_bias,
        };
        let plan =
            BlockPlan::whole(job.k, job.zero_pad, job.kernels.n_out, job.image.c, job.image.h);
        self.run_plan(&layer, &plan)
    }

    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        self.run_plan_impl(layer, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::super::Functional;
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, BinaryKernels, ScaleBias};

    fn job(
        k: usize,
        n_in: usize,
        n_out: usize,
        h: usize,
        w: usize,
        zp: bool,
        amp: f64,
        seed: u64,
    ) -> BlockJob {
        let mut g = Gen::new(seed);
        BlockJob {
            k,
            zero_pad: zp,
            image: random_image(&mut g, n_in, h, w, amp),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn matches_functional_every_kernel_size() {
        // n_out = 6 exercises both the vector dot (4-lane / 2-lane) and
        // its scalar tail on every ISA.
        for k in 1..=7usize {
            for zp in [true, false] {
                if !zp && k == 1 {
                    continue;
                }
                let j = job(k, 3, 6, 11, 9, zp, 0.05, 500 + k as u64);
                let want = Functional::new().run_block(&j).output;
                assert_eq!(
                    FunctionalSimd::new().run_block(&j).output,
                    want,
                    "k={k} zp={zp} vector"
                );
                assert_eq!(
                    FunctionalSimd::forced_scalar().run_block(&j).output,
                    want,
                    "k={k} zp={zp} forced-scalar"
                );
            }
        }
    }

    #[test]
    fn word_boundary_windows_match() {
        // Widths whose windows straddle the first and second u64 word
        // boundary — the shift-pair extract's edge cases.
        for w in [63usize, 64, 65, 66, 127, 130] {
            let j = job(3, 2, 5, 6, w, true, 0.3, 900 + w as u64);
            let want = Functional::new().run_block(&j).output;
            assert_eq!(FunctionalSimd::new().run_block(&j).output, want, "w={w} vector");
            assert_eq!(
                FunctionalSimd::forced_scalar().run_block(&j).output,
                want,
                "w={w} forced-scalar"
            );
        }
    }

    #[test]
    fn saturating_regime_matches() {
        // Full-amplitude, many channels: Q7.9 saturation fires and the
        // per-input-channel saturation order must agree exactly.
        let j = job(3, 16, 9, 10, 10, true, 1.0, 77);
        let want = Functional::new().run_block(&j).output;
        assert_eq!(FunctionalSimd::new().run_block(&j).output, want);
        assert_eq!(FunctionalSimd::forced_scalar().run_block(&j).output, want);
    }

    #[test]
    fn names_and_isa_report() {
        assert_eq!(FunctionalSimd::new().name(), "functional-simd");
        let s = FunctionalSimd::forced_scalar();
        assert_eq!(s.name(), "functional-simd-scalar");
        assert_eq!(s.isa_name(), "scalar");
    }

    #[test]
    fn useful_ops_match_functional() {
        let j = job(3, 2, 4, 6, 5, true, 0.05, 3);
        let simd = FunctionalSimd::new().run_block(&j);
        let fun = Functional::new().run_block(&j);
        assert_eq!(simd.stats.useful_ops, fun.stats.useful_ops);
        assert_eq!(simd.stats.cycles.total(), 0);
    }
}
