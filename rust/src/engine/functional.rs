//! The functional engine: YodaNN's sign-select-and-add datapath as a
//! bit-packed popcount kernel, with no per-cycle ledger.
//!
//! Per (output, input) channel pair the k×k weight bits live in one
//! `u64` ([`PackedKernels`]); per output pixel and input channel the
//! window's activations are packed into 12 offset-binary bitplanes, and
//! every output channel's window dot is then 12 `AND`+`POPCNT` steps
//! (see the identity in the module docs of [`crate::engine`]). The
//! accumulation order — exact window dot, Q7.9 saturating add per input
//! channel, Scale-Bias to Q2.9 — is byte-for-byte the chip's, so the
//! outputs are bit-identical to [`super::CycleAccurate`].

use super::{BlockPlan, ConvEngine, EngineOutput, LayerData};
use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
use crate::hw::{BlockJob, ChipStats};
use crate::workload::{BinaryKernels, Image};

/// Offset added to a raw Q2.9 sample to make it a non-negative 12-bit
/// code (`x + 2048 ∈ [0, 4096)`).
const OFFSET: i64 = 2048;
/// Bitplanes in the offset-binary activation code.
const PLANES: usize = 12;

/// Kernel weight bits packed one `u64` word per (output, input) channel
/// pair: bit `dy·k + dx` is 1 ⇔ w = +1 (the paper's Eq. 5 encoding).
/// Pack once per layer (or once per session) and share by reference.
#[derive(Debug, Clone)]
pub struct PackedKernels {
    /// Kernel size.
    pub k: usize,
    /// Input channels.
    pub n_in: usize,
    /// Output channels.
    pub n_out: usize,
    words: Vec<u64>,
    sign_sums: Vec<i64>,
}

impl PackedKernels {
    /// Pack a kernel set (`k² ≤ 64` required; the chip supports k ≤ 7).
    pub fn pack(kernels: &BinaryKernels) -> PackedKernels {
        let k = kernels.k;
        let kk = k * k;
        assert!(kk >= 1 && kk <= 64, "kernel {k}x{k} does not fit a u64 word");
        let mut words = Vec::with_capacity(kernels.n_out * kernels.n_in);
        let mut sign_sums = Vec::with_capacity(kernels.n_out * kernels.n_in);
        for o in 0..kernels.n_out {
            for i in 0..kernels.n_in {
                let mut w = 0u64;
                for dy in 0..k {
                    for dx in 0..k {
                        if kernels.bit(o, i, dy, dx) {
                            w |= 1u64 << (dy * k + dx);
                        }
                    }
                }
                words.push(w);
                sign_sums.push(2 * w.count_ones() as i64 - kk as i64);
            }
        }
        PackedKernels { k, n_in: kernels.n_in, n_out: kernels.n_out, words, sign_sums }
    }

    /// Packed weight word of kernel (out, in).
    #[inline]
    pub fn word(&self, o: usize, i: usize) -> u64 {
        self.words[o * self.n_in + i]
    }

    /// `Σ_j w_j` over the window of kernel (out, in): `2·pc(P) − k²`.
    #[inline]
    pub fn sign_sum(&self, o: usize, i: usize) -> i64 {
        self.sign_sums[o * self.n_in + i]
    }
}

/// The functional popcount engine. Holds reusable accumulator scratch so
/// a worker thread allocates nothing per block.
#[derive(Debug, Default)]
pub struct Functional {
    accs: Vec<i64>,
}

impl Functional {
    /// New engine with empty scratch.
    pub fn new() -> Functional {
        Functional::default()
    }
}

impl ConvEngine for Functional {
    fn name(&self) -> &'static str {
        "functional"
    }

    fn wants_packed(&self) -> bool {
        true
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        let layer = LayerData {
            k: job.k,
            zero_pad: job.zero_pad,
            input: &job.image,
            kernels: &job.kernels,
            packed: None,
            scale_bias: &job.scale_bias,
        };
        let plan =
            BlockPlan::whole(job.k, job.zero_pad, job.kernels.n_out, job.image.c, job.image.h);
        self.run_plan(&layer, &plan)
    }

    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let k = layer.k;
        let kk = k * k;
        let w = layer.input.w;
        let tile_h = plan.tile_h;
        if !layer.zero_pad {
            assert!(tile_h >= k && w >= k, "tile {tile_h}x{w} smaller than kernel {k} (valid mode)");
        }
        let offset = if layer.zero_pad { ((k - 1) / 2) as isize } else { 0 };
        let (out_h, out_w) =
            if layer.zero_pad { (tile_h, w) } else { (tile_h + 1 - k, w + 1 - k) };
        let n_in = plan.in_len;
        let n_out = plan.out_len;
        // Borrow the caller's packed kernels, or pack this block's slice
        // view on the fly (cheap: one pass over the weight bits).
        let local;
        let packed: &PackedKernels = match layer.packed {
            Some(p) => {
                debug_assert_eq!(p.k, k);
                p
            }
            None => {
                local = PackedKernels::pack(layer.kernels);
                &local
            }
        };
        // Partial (non-final) input blocks stream identity-scaled Q2.9,
        // exactly like the silicon (coordinator/blocks.rs docs).
        let identity = plan.in_blocks > 1;
        let input = layer.input;
        let kk_offset = kk as i64 * OFFSET;
        let mut out = Image::zeros(n_out, out_h, out_w);
        self.accs.clear();
        self.accs.resize(n_out, 0);
        let accs = &mut self.accs;
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                for i in 0..n_in {
                    // Pack this channel's k×k window into offset-binary
                    // bitplanes; positions outside the *tile* read the
                    // zero-padding halo (code 2048), like the chip's
                    // padding muxes.
                    let mut planes = [0u64; PLANES];
                    let mut total: i64 = 0; // Σ_j x_j (true window sum)
                    let mut j = 0u32;
                    for dy in 0..k {
                        let ty = y as isize + dy as isize - offset;
                        let row_ok = ty >= 0 && ty < tile_h as isize;
                        for dx in 0..k {
                            let tx = x as isize + dx as isize - offset;
                            let px = if row_ok && tx >= 0 && tx < w as isize {
                                input.at(plan.in_base + i, plan.clip0 + ty as usize, tx as usize)
                            } else {
                                0
                            };
                            debug_assert!(
                                crate::fixedpoint::Q2_9.contains(px),
                                "activation {px} outside Q2.9"
                            );
                            total += px;
                            let mut u = (px + OFFSET) as u64;
                            while u != 0 {
                                planes[u.trailing_zeros() as usize] |= 1u64 << j;
                                u &= u - 1;
                            }
                            j += 1;
                        }
                    }
                    let sum_u = total + kk_offset;
                    for (o, acc) in accs.iter_mut().enumerate() {
                        let word = packed.word(plan.out_base + o, plan.in_base + i);
                        let mut dot2: i64 = 0;
                        for (b, &plane) in planes.iter().enumerate() {
                            dot2 += ((plane & word).count_ones() as i64) << b;
                        }
                        // Σ w·x = 2·Σ_b 2^b·pc(U_b ∧ P) − Σ u − 2048·Σ w
                        let sop = 2 * dot2
                            - sum_u
                            - OFFSET * packed.sign_sum(plan.out_base + o, plan.in_base + i);
                        *acc = sat_add(Q7_9, *acc, sop);
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
        let stats = ChipStats {
            useful_ops: 2 * kk as u64 * (n_in * n_out) as u64 * (out_h * out_w) as u64,
            ..Default::default()
        };
        EngineOutput { output: out, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, reference_conv, synthetic_scene, ScaleBias};

    fn job(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, zp: bool, seed: u64) -> BlockJob {
        let mut g = Gen::new(seed);
        BlockJob {
            k,
            zero_pad: zp,
            image: random_image(&mut g, n_in, h, w, 0.05),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn packed_words_match_bits() {
        let mut g = Gen::new(1);
        let ks = BinaryKernels::random(&mut g, 3, 2, 5);
        let p = PackedKernels::pack(&ks);
        for o in 0..3 {
            for i in 0..2 {
                let mut plus = 0i64;
                for dy in 0..5 {
                    for dx in 0..5 {
                        let bit = ks.bit(o, i, dy, dx);
                        assert_eq!((p.word(o, i) >> (dy * 5 + dx)) & 1 == 1, bit);
                        plus += if bit { 1 } else { -1 };
                    }
                }
                assert_eq!(p.sign_sum(o, i), plus);
            }
        }
    }

    #[test]
    fn matches_reference_all_kernel_sizes() {
        for k in 1..=7usize {
            let j = job(k, 3, 4, 10, 9, true, 40 + k as u64);
            let want = reference_conv(&j.image, &j.kernels, &j.scale_bias, true);
            assert_eq!(Functional::new().run_block(&j).output, want, "k={k} padded");
            if k > 1 {
                let j = job(k, 2, 3, 11, 10, false, 80 + k as u64);
                let want = reference_conv(&j.image, &j.kernels, &j.scale_bias, false);
                assert_eq!(Functional::new().run_block(&j).output, want, "k={k} valid");
            }
        }
    }

    #[test]
    fn matches_reference_in_saturating_regime() {
        // Full-amplitude scene with many channels: Q7.9 saturation fires
        // and the per-channel saturation order must still agree.
        let mut g = Gen::new(9);
        let image = synthetic_scene(&mut g, 16, 10, 10);
        let kernels = BinaryKernels::random(&mut g, 8, 16, 3);
        let sb = ScaleBias::random(&mut g, 8);
        let j = BlockJob {
            k: 3,
            zero_pad: true,
            image: image.clone(),
            kernels: kernels.clone(),
            scale_bias: sb.clone(),
        };
        let want = reference_conv(&image, &kernels, &sb, true);
        assert_eq!(Functional::new().run_block(&j).output, want);
    }

    #[test]
    fn scratch_is_reused_across_blocks() {
        let mut e = Functional::new();
        let a = job(3, 2, 6, 8, 8, true, 1);
        let b = job(5, 3, 2, 9, 9, false, 2);
        let ra1 = e.run_block(&a).output;
        let rb = e.run_block(&b).output;
        let ra2 = e.run_block(&a).output;
        assert_eq!(ra1, ra2);
        assert_eq!(rb, reference_conv(&b.image, &b.kernels, &b.scale_bias, false));
    }

    #[test]
    fn useful_ops_follow_eq7() {
        let j = job(3, 2, 4, 6, 5, true, 3);
        let s = Functional::new().run_block(&j).stats;
        assert_eq!(s.useful_ops, 2 * 9 * (2 * 4) as u64 * (6 * 5) as u64);
        assert_eq!(s.cycles.total(), 0); // no ledger
    }
}
