//! The functional engine: YodaNN's sign-select-and-add datapath as a
//! bit-packed popcount kernel, with no per-cycle ledger.
//!
//! Per (output, input) channel pair the k×k weight bits live in one
//! `u64` ([`PackedKernels`]); per output pixel and input channel the
//! window's activations arrive as 12 offset-binary plane words. Since
//! the raster refactor those words come from a layer-resident
//! [`BitplaneRaster`] — packed once per layer (or per block tile) and
//! sliced per window with shifts — and the window dot folds multiple
//! planes into each `AND`+`POPCNT` via replicated kernel fields (4
//! popcounts instead of 12 at k ≤ 3; see the grouped-popcount notes in
//! [`crate::engine`]'s module docs). The accumulation order — exact
//! window dot, Q7.9 saturating add per input channel, Scale-Bias to
//! Q2.9 — is byte-for-byte the chip's, so the outputs are bit-identical
//! to [`super::CycleAccurate`].
//!
//! The PR-1 per-window packing loop survives behind
//! [`Functional::per_window`] (engine name `functional-pr1`) purely as
//! the A/B baseline for `benches/engines.rs` and the `yodann throughput`
//! subcommand.

use super::raster::{mix64, BitplaneRaster, OFFSET, PLANES};
use super::{BlockPlan, ConvEngine, EngineOutput, LayerData};
use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
use crate::hw::{BlockJob, ChipStats};
use crate::workload::{BinaryKernels, Image};

/// Planes folded into one popcount for a k×k kernel: the largest `m`
/// dividing 12 with `(2^m − 1)·k² ≤ 64`, so that plane `t` of a group
/// can appear `2^t` times in one word and a single `POPCNT` returns the
/// weighted partial sum `Σ_t 2^t·pc_t`.
fn planes_per_group(kk: usize) -> usize {
    for m in [6usize, 4, 3, 2, 1] {
        if ((1usize << m) - 1) * kk <= 64 {
            return m;
        }
    }
    unreachable!("k² ≤ 49 always admits m = 1")
}

/// Kernel weight bits packed one `u64` word per (output, input) channel
/// pair: bit `dy·k + dx` is 1 ⇔ w = +1 (the paper's Eq. 5 encoding).
/// Pack once per layer (or once per session) and share by reference.
///
/// Besides the plain words the pack also precomputes the **replicated**
/// form for the grouped-popcount dot — the k² weight bits copied into
/// every `2^m − 1` field of the word — stored input-channel-major so the
/// raster hot loop walks it contiguously. Both forms are kept
/// deliberately (16 bytes per channel pair): one pack per layer/session
/// serves every functional variant, which is what lets the A/B benches
/// and `--engine all` share a single packed set. Packing is
/// `O(n_out·n_in·k²)` — noise next to the convolution it feeds.
#[derive(Debug, Clone)]
pub struct PackedKernels {
    /// Kernel size.
    pub k: usize,
    /// Input channels.
    pub n_in: usize,
    /// Output channels.
    pub n_out: usize,
    words: Vec<u64>,
    sign_sums: Vec<i64>,
    /// Replicated weight words, transposed: `[i·n_out + o]`.
    rep: Vec<u64>,
    /// Sign sums, transposed: `[i·n_out + o]`.
    sign_t: Vec<i64>,
    /// Planes per popcount group (function of k alone).
    m: usize,
    /// Checksum over the plain weight words, computed at pack time — the
    /// parity a latch-based filter bank would carry. [`Self::verify`]
    /// recomputes it; a bit flipped after packing leaves it stale.
    checksum: u64,
}

impl PackedKernels {
    /// Pack a kernel set (`k² ≤ 64` required; the chip supports k ≤ 7).
    pub fn pack(kernels: &BinaryKernels) -> PackedKernels {
        let k = kernels.k;
        let kk = k * k;
        assert!((1..=64).contains(&kk), "kernel {k}x{k} does not fit a u64 word");
        let m = planes_per_group(kk);
        let fields = (1usize << m) - 1;
        let (n_out, n_in) = (kernels.n_out, kernels.n_in);
        let mut words = Vec::with_capacity(n_out * n_in);
        let mut sign_sums = Vec::with_capacity(n_out * n_in);
        let mut rep = vec![0u64; n_out * n_in];
        let mut sign_t = vec![0i64; n_out * n_in];
        for o in 0..n_out {
            for i in 0..n_in {
                let mut w = 0u64;
                for dy in 0..k {
                    for dx in 0..k {
                        if kernels.bit(o, i, dy, dx) {
                            w |= 1u64 << (dy * k + dx);
                        }
                    }
                }
                let sign = 2 * w.count_ones() as i64 - kk as i64;
                words.push(w);
                sign_sums.push(sign);
                let mut r = 0u64;
                for f in 0..fields {
                    r |= w << (f * kk);
                }
                rep[i * n_out + o] = r;
                sign_t[i * n_out + o] = sign;
            }
        }
        let checksum = Self::checksum_of(&words, n_out, n_in);
        PackedKernels { k, n_in, n_out, words, sign_sums, rep, sign_t, m, checksum }
    }

    fn checksum_of(words: &[u64], n_out: usize, n_in: usize) -> u64 {
        let mut h = mix64(0x9E37_79B9_7F4A_7C15 ^ (n_out * n_in) as u64);
        for &w in words {
            h = mix64(h ^ w);
        }
        h
    }

    /// Packed weight word of kernel (out, in).
    #[inline]
    pub fn word(&self, o: usize, i: usize) -> u64 {
        self.words[o * self.n_in + i]
    }

    /// `Σ_j w_j` over the window of kernel (out, in): `2·pc(P) − k²`.
    #[inline]
    pub fn sign_sum(&self, o: usize, i: usize) -> i64 {
        self.sign_sums[o * self.n_in + i]
    }

    /// Planes folded into one popcount group for this kernel size.
    #[inline]
    pub fn planes_per_group(&self) -> usize {
        self.m
    }

    /// Replicated weight words of input channel `i` for output channels
    /// `out_base..out_base+out_len` — contiguous for the hot loop.
    #[inline]
    pub fn rep_slice(&self, i: usize, out_base: usize, out_len: usize) -> &[u64] {
        &self.rep[i * self.n_out + out_base..][..out_len]
    }

    /// Sign sums of input channel `i` for a contiguous output range.
    #[inline]
    pub fn sign_slice(&self, i: usize, out_base: usize, out_len: usize) -> &[i64] {
        &self.sign_t[i * self.n_out + out_base..][..out_len]
    }

    /// Whether the weight words still match the pack-time checksum. A
    /// [`Self::flip_weight_bit`] after packing makes this return false —
    /// the filter bank's fault-detection hook.
    pub fn verify(&self) -> bool {
        Self::checksum_of(&self.words, self.n_out, self.n_in) == self.checksum
    }

    /// Flip one weight bit of kernel (out, in) — a single-event upset in
    /// the filter bank's latch array. All derived forms (sign sums,
    /// replicated words, transposed tables) are updated consistently, so
    /// every engine variant computes with the *same corrupted weight*;
    /// only the pack-time checksum is deliberately left stale, which is
    /// exactly what [`Self::verify`] detects.
    pub(crate) fn flip_weight_bit(&mut self, o: usize, i: usize, bit: u32) {
        let kk = self.k * self.k;
        debug_assert!((bit as usize) < kk, "bit {bit} outside k²={kk}");
        let idx = o * self.n_in + i;
        let w = self.words[idx] ^ (1u64 << bit);
        let sign = 2 * w.count_ones() as i64 - kk as i64;
        self.words[idx] = w;
        self.sign_sums[idx] = sign;
        let fields = (1usize << self.m) - 1;
        let mut r = 0u64;
        for f in 0..fields {
            r |= w << (f * kk);
        }
        self.rep[i * self.n_out + o] = r;
        self.sign_t[i * self.n_out + o] = sign;
    }
}

/// The functional popcount engine. Holds reusable accumulator and raster
/// scratch so a worker thread allocates nothing per block in steady
/// state.
#[derive(Debug, Default)]
pub struct Functional {
    accs: Vec<i64>,
    raster: BitplaneRaster,
    per_window: bool,
}

impl Functional {
    /// New engine on the raster fast path, with empty scratch.
    pub fn new() -> Functional {
        Functional::default()
    }

    /// The PR-1 per-window packing path — kept only as the measured A/B
    /// baseline for the raster refactor (benches, `yodann throughput`).
    pub fn per_window() -> Functional {
        Functional { per_window: true, ..Functional::default() }
    }

    /// Raster-scratch packs that had to grow a buffer (steady-state
    /// serving keeps this constant; see the scratch-reuse tests).
    pub fn raster_reallocs(&self) -> u64 {
        self.raster.reallocs()
    }

    /// Common block geometry checks: tile output shape of a plan.
    fn out_dims(layer: &LayerData<'_>, plan: &BlockPlan) -> (usize, usize) {
        let (k, w, tile_h) = (layer.k, layer.input.w, plan.tile_h);
        if !layer.zero_pad {
            assert!(
                tile_h >= k && w >= k,
                "tile {tile_h}x{w} smaller than kernel {k} (valid mode)"
            );
        }
        if layer.zero_pad {
            (tile_h, w)
        } else {
            (tile_h + 1 - k, w + 1 - k)
        }
    }

    /// The raster hot path: windows assembled from a bitplane raster —
    /// the caller's layer-resident one if present, else this engine's
    /// scratch packed from the plan's tile view.
    fn run_plan_raster(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let k = layer.k;
        let kk = k * k;
        let (out_h, out_w) = Self::out_dims(layer, plan);
        let n_in = plan.in_len;
        let n_out = plan.out_len;
        let local;
        let packed: &PackedKernels = match layer.packed {
            Some(p) => {
                debug_assert_eq!(p.k, k);
                p
            }
            None => {
                local = PackedKernels::pack(layer.kernels);
                &local
            }
        };
        let identity = plan.in_blocks > 1;
        // Split-borrow the scratch fields so the raster can be packed
        // mutably and then read while `accs` is written.
        let Functional { accs, raster: scratch, .. } = self;
        // (c_base, row0) map plan-local (channel, window row) into raster
        // coordinates: the layer-resident raster holds every channel and
        // row of the layer; the block-local scratch holds only this
        // plan's view.
        let (raster, c_base, row0): (&BitplaneRaster, usize, usize) = match layer.raster {
            Some(r) => {
                debug_assert_eq!(r.k(), k);
                (r, plan.in_base, plan.clip0)
            }
            None => {
                scratch.pack_view(
                    layer.input,
                    k,
                    layer.zero_pad,
                    plan.in_base,
                    plan.in_len,
                    plan.clip0,
                    plan.tile_h,
                );
                (&*scratch, 0, 0)
            }
        };
        let m = packed.planes_per_group();
        let groups = PLANES / m;
        // Per-sub-plane fold multipliers: plane t of a group appears 2^t
        // times at fields 2^t−1 .. 2^(t+1)−2, so multiplying the plane
        // word by F_t = Σ 2^(field·k²) replicates it in one op — exact,
        // because the fields are disjoint (no carries) and the top bit
        // index fields·k² − 1 ≤ 63.
        let mut fold = [0u64; PLANES];
        for (t, f) in fold[..m].iter_mut().enumerate() {
            let copies = 1usize << t;
            for cpy in 0..copies {
                *f |= 1u64 << ((copies - 1 + cpy) * kk);
            }
        }
        let mut out = Image::zeros(n_out, out_h, out_w);
        accs.clear();
        accs.resize(n_out, 0);
        let mut planes = [0u64; PLANES];
        let mut gwords = [0u64; PLANES];
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                for i in 0..n_in {
                    let sum_u = raster.window(c_base + i, row0 + y, x, &mut planes);
                    // Fold m consecutive planes per popcount group: plane
                    // t of a group appears 2^t times, so one POPCNT later
                    // yields Σ_t 2^t·pc_t directly.
                    if m == 1 {
                        gwords = planes;
                    } else {
                        for (g, gw) in gwords[..groups].iter_mut().enumerate() {
                            let mut acc = 0u64;
                            for (t, &u) in planes[g * m..g * m + m].iter().enumerate() {
                                acc |= u * fold[t];
                            }
                            *gw = acc;
                        }
                    }
                    let reps = packed.rep_slice(plan.in_base + i, plan.out_base, n_out);
                    let signs = packed.sign_slice(plan.in_base + i, plan.out_base, n_out);
                    for (o, acc) in accs.iter_mut().enumerate() {
                        let rep = reps[o];
                        let mut dot2: i64 = 0;
                        for (g, &gw) in gwords[..groups].iter().enumerate() {
                            dot2 += ((gw & rep).count_ones() as i64) << (m * g);
                        }
                        // Σ w·x = 2·Σ_b 2^b·pc(U_b ∧ P) − Σ u − 2048·Σ w
                        let sop = 2 * dot2 - sum_u - OFFSET * signs[o];
                        *acc = sat_add(Q7_9, *acc, sop);
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
        let stats = ChipStats {
            useful_ops: 2 * kk as u64 * (n_in * n_out) as u64 * (out_h * out_w) as u64,
            ..Default::default()
        };
        EngineOutput { output: out, stats }
    }

    /// The PR-1 baseline: repack every (output pixel × input channel)
    /// window from the image, bit by bit. Kept for measured comparison
    /// only — the raster path is the default.
    fn run_plan_per_window(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        let k = layer.k;
        let kk = k * k;
        let w = layer.input.w;
        let tile_h = plan.tile_h;
        let (out_h, out_w) = Self::out_dims(layer, plan);
        let offset = if layer.zero_pad { ((k - 1) / 2) as isize } else { 0 };
        let n_in = plan.in_len;
        let n_out = plan.out_len;
        let local;
        let packed: &PackedKernels = match layer.packed {
            Some(p) => {
                debug_assert_eq!(p.k, k);
                p
            }
            None => {
                local = PackedKernels::pack(layer.kernels);
                &local
            }
        };
        let identity = plan.in_blocks > 1;
        let input = layer.input;
        let kk_offset = kk as i64 * OFFSET;
        let mut out = Image::zeros(n_out, out_h, out_w);
        self.accs.clear();
        self.accs.resize(n_out, 0);
        let accs = &mut self.accs;
        for y in 0..out_h {
            for x in 0..out_w {
                accs.iter_mut().for_each(|a| *a = 0);
                for i in 0..n_in {
                    // Pack this channel's k×k window into offset-binary
                    // bitplanes; positions outside the *tile* read the
                    // zero-padding halo (code 2048), like the chip's
                    // padding muxes. (Activation range validation happens
                    // once per pixel at raster-pack time on the default
                    // path, not here.)
                    let mut planes = [0u64; PLANES];
                    let mut total: i64 = 0; // Σ_j x_j (true window sum)
                    let mut j = 0u32;
                    for dy in 0..k {
                        let ty = y as isize + dy as isize - offset;
                        let row_ok = (0..tile_h as isize).contains(&ty);
                        for dx in 0..k {
                            let tx = x as isize + dx as isize - offset;
                            let px = if row_ok && (0..w as isize).contains(&tx) {
                                input.at(plan.in_base + i, plan.clip0 + ty as usize, tx as usize)
                            } else {
                                0
                            };
                            total += px;
                            let mut u = (px + OFFSET) as u64;
                            while u != 0 {
                                planes[u.trailing_zeros() as usize] |= 1u64 << j;
                                u &= u - 1;
                            }
                            j += 1;
                        }
                    }
                    let sum_u = total + kk_offset;
                    for (o, acc) in accs.iter_mut().enumerate() {
                        let word = packed.word(plan.out_base + o, plan.in_base + i);
                        let mut dot2: i64 = 0;
                        for (b, &plane) in planes.iter().enumerate() {
                            dot2 += ((plane & word).count_ones() as i64) << b;
                        }
                        // Σ w·x = 2·Σ_b 2^b·pc(U_b ∧ P) − Σ u − 2048·Σ w
                        let sop = 2 * dot2
                            - sum_u
                            - OFFSET * packed.sign_sum(plan.out_base + o, plan.in_base + i);
                        *acc = sat_add(Q7_9, *acc, sop);
                    }
                }
                for (o, &acc) in accs.iter().enumerate() {
                    let (alpha, beta) = if identity {
                        (512, 0)
                    } else {
                        (
                            layer.scale_bias.alpha[plan.out_base + o],
                            layer.scale_bias.beta[plan.out_base + o],
                        )
                    };
                    *out.at_mut(o, y, x) = scale_bias(acc, alpha, beta);
                }
            }
        }
        let stats = ChipStats {
            useful_ops: 2 * kk as u64 * (n_in * n_out) as u64 * (out_h * out_w) as u64,
            ..Default::default()
        };
        EngineOutput { output: out, stats }
    }
}

impl ConvEngine for Functional {
    fn name(&self) -> &'static str {
        if self.per_window {
            "functional-pr1"
        } else {
            "functional"
        }
    }

    fn wants_packed(&self) -> bool {
        true
    }

    fn wants_raster(&self) -> bool {
        !self.per_window
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        let layer = LayerData {
            k: job.k,
            zero_pad: job.zero_pad,
            input: &job.image,
            kernels: &job.kernels,
            packed: None,
            raster: None,
            binary: None,
            scale_bias: &job.scale_bias,
        };
        let plan =
            BlockPlan::whole(job.k, job.zero_pad, job.kernels.n_out, job.image.c, job.image.h);
        self.run_plan(&layer, &plan)
    }

    fn run_plan(&mut self, layer: &LayerData<'_>, plan: &BlockPlan) -> EngineOutput {
        if self.per_window {
            self.run_plan_per_window(layer, plan)
        } else {
            self.run_plan_raster(layer, plan)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, reference_conv, synthetic_scene, ScaleBias};

    fn job(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, zp: bool, seed: u64) -> BlockJob {
        let mut g = Gen::new(seed);
        BlockJob {
            k,
            zero_pad: zp,
            image: random_image(&mut g, n_in, h, w, 0.05),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn packed_words_match_bits() {
        let mut g = Gen::new(1);
        let ks = BinaryKernels::random(&mut g, 3, 2, 5);
        let p = PackedKernels::pack(&ks);
        for o in 0..3 {
            for i in 0..2 {
                let mut plus = 0i64;
                for dy in 0..5 {
                    for dx in 0..5 {
                        let bit = ks.bit(o, i, dy, dx);
                        assert_eq!((p.word(o, i) >> (dy * 5 + dx)) & 1 == 1, bit);
                        plus += if bit { 1 } else { -1 };
                    }
                }
                assert_eq!(p.sign_sum(o, i), plus);
                assert_eq!(p.sign_slice(i, o, 1)[0], plus);
            }
        }
    }

    #[test]
    fn plane_grouping_obeys_word_capacity() {
        // (2^m − 1)·k² ≤ 64 and m divides 12, maximal.
        for (k, want_m) in [(1usize, 6usize), (2, 4), (3, 3), (4, 2), (5, 1), (6, 1), (7, 1)] {
            assert_eq!(planes_per_group(k * k), want_m, "k={k}");
            assert!(((1usize << want_m) - 1) * k * k <= 64);
            assert_eq!(PLANES % want_m, 0);
        }
    }

    #[test]
    fn replicated_words_repeat_the_plain_word() {
        let mut g = Gen::new(2);
        let ks = BinaryKernels::random(&mut g, 2, 2, 3);
        let p = PackedKernels::pack(&ks);
        let kk = 9;
        let fields = (1usize << p.planes_per_group()) - 1; // 7 for k=3
        for o in 0..2 {
            for i in 0..2 {
                let rep = p.rep_slice(i, o, 1)[0];
                for f in 0..fields {
                    assert_eq!((rep >> (f * kk)) & ((1u64 << kk) - 1), p.word(o, i), "field {f}");
                }
                assert_eq!(rep >> (fields * kk), 0, "no stray bits past the last field");
            }
        }
    }

    #[test]
    fn matches_reference_all_kernel_sizes() {
        for k in 1..=7usize {
            let j = job(k, 3, 4, 10, 9, true, 40 + k as u64);
            let want = reference_conv(&j.image, &j.kernels, &j.scale_bias, true);
            assert_eq!(Functional::new().run_block(&j).output, want, "k={k} padded");
            assert_eq!(Functional::per_window().run_block(&j).output, want, "k={k} padded pr1");
            if k > 1 {
                let j = job(k, 2, 3, 11, 10, false, 80 + k as u64);
                let want = reference_conv(&j.image, &j.kernels, &j.scale_bias, false);
                assert_eq!(Functional::new().run_block(&j).output, want, "k={k} valid");
                assert_eq!(
                    Functional::per_window().run_block(&j).output,
                    want,
                    "k={k} valid pr1"
                );
            }
        }
    }

    #[test]
    fn matches_reference_in_saturating_regime() {
        // Full-amplitude scene with many channels: Q7.9 saturation fires
        // and the per-channel saturation order must still agree.
        let mut g = Gen::new(9);
        let image = synthetic_scene(&mut g, 16, 10, 10);
        let kernels = BinaryKernels::random(&mut g, 8, 16, 3);
        let sb = ScaleBias::random(&mut g, 8);
        let j = BlockJob {
            k: 3,
            zero_pad: true,
            image: image.clone(),
            kernels: kernels.clone(),
            scale_bias: sb.clone(),
        };
        let want = reference_conv(&image, &kernels, &sb, true);
        assert_eq!(Functional::new().run_block(&j).output, want);
        assert_eq!(Functional::per_window().run_block(&j).output, want);
    }

    #[test]
    fn scratch_is_reused_across_blocks() {
        let mut e = Functional::new();
        let a = job(3, 2, 6, 8, 8, true, 1);
        let b = job(5, 3, 2, 9, 9, false, 2);
        let ra1 = e.run_block(&a).output;
        let rb = e.run_block(&b).output;
        let ra2 = e.run_block(&a).output;
        assert_eq!(ra1, ra2);
        assert_eq!(rb, reference_conv(&b.image, &b.kernels, &b.scale_bias, false));
    }

    #[test]
    fn raster_scratch_stops_allocating_in_steady_state() {
        // A session worker replays same-geometry blocks frame after
        // frame; after the first block the raster scratch must never
        // grow again.
        let mut e = Functional::new();
        let a = job(3, 4, 6, 12, 10, true, 21);
        e.run_block(&a);
        let warm = e.raster_reallocs();
        for seed in 0..4 {
            let frame = job(3, 4, 6, 12, 10, true, 100 + seed);
            e.run_block(&frame);
        }
        assert_eq!(e.raster_reallocs(), warm, "steady-state blocks must not allocate");
    }

    #[test]
    fn useful_ops_follow_eq7() {
        let j = job(3, 2, 4, 6, 5, true, 3);
        let s = Functional::new().run_block(&j).stats;
        assert_eq!(s.useful_ops, 2 * 9 * (2 * 4) as u64 * (6 * 5) as u64);
        assert_eq!(s.cycles.total(), 0); // no ledger
    }
}
