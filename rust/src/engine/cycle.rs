//! The cycle-accurate engine: a thin [`ConvEngine`] adapter over
//! [`crate::hw::Chip`]. Bit-true outputs *and* the full activity ledger
//! (cycle breakdown, SCM bank events, SoP operator counts) — identical
//! semantics to calling `Chip::run_block` directly.

use super::{ConvEngine, EngineOutput};
use crate::hw::{BlockJob, Chip, ChipConfig};

/// Engine wrapping one simulated chip instance. The chip is reused
/// across blocks (unit state resets per block, counters are gathered per
/// block), exactly like the pre-engine executor did.
pub struct CycleAccurate {
    chip: Chip,
}

impl CycleAccurate {
    /// Build an engine around a fresh chip of configuration `cfg`.
    pub fn new(cfg: ChipConfig) -> CycleAccurate {
        CycleAccurate { chip: Chip::new(cfg) }
    }

    /// The chip configuration this engine simulates.
    pub fn cfg(&self) -> &ChipConfig {
        &self.chip.cfg
    }
}

impl ConvEngine for CycleAccurate {
    fn name(&self) -> &'static str {
        "cycle-accurate"
    }

    fn run_block(&mut self, job: &BlockJob) -> EngineOutput {
        let r = self.chip.run_block(job);
        EngineOutput { output: r.output, stats: r.stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, reference_conv, BinaryKernels, ScaleBias};

    #[test]
    fn engine_matches_direct_chip_run() {
        let mut g = Gen::new(11);
        let image = random_image(&mut g, 3, 8, 8, 0.03);
        let kernels = BinaryKernels::random(&mut g, 4, 3, 3);
        let sb = ScaleBias::random(&mut g, 4);
        let job = BlockJob {
            k: 3,
            zero_pad: true,
            image: image.clone(),
            kernels: kernels.clone(),
            scale_bias: sb.clone(),
        };
        let cfg = ChipConfig::tiny(4);
        let mut engine = CycleAccurate::new(cfg);
        let out = engine.run_block(&job);
        let direct = Chip::new(cfg).run_block(&job);
        assert_eq!(out.output, direct.output);
        assert_eq!(out.stats.cycles.total(), direct.stats.cycles.total());
        assert_eq!(out.output, reference_conv(&image, &kernels, &sb, true));
    }
}
