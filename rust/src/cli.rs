//! Tiny declarative CLI argument parser (clap stand-in; see Cargo.toml for
//! why clap is unavailable). Supports subcommands, `--flag`, `--key value`
//! and positional arguments, with generated `--help` text.

use std::collections::HashMap;

/// Parsed arguments of one (sub)command invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse a raw argument list (no program name, no subcommand).
    /// `value_keys` lists options that consume a value; everything else
    /// starting with `--` is a flag.
    pub fn parse(raw: &[String], value_keys: &[&str]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = stripped.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                if value_keys.contains(&stripped) {
                    match it.next() {
                        Some(v) => {
                            a.options.insert(stripped.to_string(), v.clone());
                        }
                        None => return Err(format!("option --{stripped} needs a value")),
                    }
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    /// String option with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Required string option.
    pub fn require(&self, key: &str) -> Result<&str, String> {
        self.options
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| format!("missing required option --{key}"))
    }

    /// f64 option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not a number")),
        }
    }

    /// usize option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not an integer")),
        }
    }

    /// u64 option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not an integer")),
        }
    }

    /// Whether a bare flag was passed.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(&v(&["pos1", "--net", "vgg13", "--verbose", "--v=0.6"]), &["net", "v"])
            .unwrap();
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get("net", ""), "vgg13");
        assert_eq!(a.get("v", ""), "0.6");
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&v(&["--net"]), &["net"]).is_err());
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&v(&["--v", "0.8", "--n", "42"]), &["v", "n"]).unwrap();
        assert_eq!(a.get_f64("v", 1.2).unwrap(), 0.8);
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("absent", 7.5).unwrap(), 7.5);
        assert!(a.get_f64("n", 0.0).is_ok());
        let b = Args::parse(&v(&["--v", "abc"]), &["v"]).unwrap();
        assert!(b.get_f64("v", 0.0).is_err());
    }

    #[test]
    fn require_reports_key() {
        let a = Args::parse(&v(&[]), &[]).unwrap();
        let e = a.require("net").unwrap_err();
        assert!(e.contains("--net"));
    }
}
