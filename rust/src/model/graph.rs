//! Graph-based network IR: describe a CNN as a typed DAG, compile it
//! into an executable plan.
//!
//! The chain-only `SessionLayerSpec` list cannot express the topologies
//! the paper evaluates: AlexNet's first layer is decomposed into
//! parallel 2×(6×6) + 2×(5×5) kernel groups whose partial sums
//! recombine off-chip (§IV-D), and ResNet-18/34 need residual adds and
//! stride-2 subsampling. This module is the model-side fix:
//!
//! * [`NetworkBuilder`] — grow a [`NetworkGraph`] front-to-back: conv
//!   nodes carry caller-supplied or seeded [`Weights`] (not
//!   random-only), host nodes cover everything YodaNN leaves to the
//!   host — quantized ReLU, 2×2 max-pool, stride-2 subsample, residual
//!   [`GraphOp::Add`] and branch [`GraphOp::Concat`];
//! * [`NetworkGraph::compile`] — validate the whole graph (channel
//!   typing, join arity, reachability) into typed
//!   [`YodannError`]s, then lower it to a [`CompiledGraph`]: conv
//!   segments plus host-op interludes over a slot-addressed value
//!   store, with per-step free lists so intermediates die as early as
//!   possible;
//! * [`CompiledGraph::walk_shapes`] — walk one frame's (c, h, w)
//!   through every step, reporting valid-mode underflow and
//!   branch-shape conflicts as typed errors **before** the frame enters
//!   a session queue.
//!
//! Execution reuses the session machinery unchanged: the coordinator's
//! `NetworkSession` interprets [`PlanStep`]s, running conv steps
//! through the same per-layer raster packing, block planning, sharding
//! and telemetry paths a chain network uses (a chain is just the
//! degenerate graph with one step per layer). Faithful graph encodings
//! of the paper's non-chain networks live in
//! [`networks`](super::networks) (`alexnet_graph`, `resnet18_graph`,
//! `resnet34_graph`).

use std::sync::Arc;

use crate::api::YodannError;
use crate::fixedpoint::Q2_9;
use crate::testkit::Gen;
use crate::workload::{BinaryKernels, ScaleBias};

/// One conv node's parameters: the kernel set plus its per-output
/// scale/bias, `Arc`-shared so a graph, its compiled plan and every
/// session worker reference one copy.
#[derive(Debug, Clone)]
pub struct Weights {
    /// Binary kernel set (`n_out × n_in` kernels of `k × k` bits).
    pub kernels: Arc<BinaryKernels>,
    /// Per-output-channel α/β (batch-norm folding), arity-checked
    /// against `kernels.n_out` at [`NetworkGraph::compile`].
    pub scale_bias: Arc<ScaleBias>,
}

impl Weights {
    /// Caller-supplied weights (e.g. trained BinaryConnect kernels).
    pub fn new(kernels: Arc<BinaryKernels>, scale_bias: Arc<ScaleBias>) -> Weights {
        Weights { kernels, scale_bias }
    }

    /// Seeded synthetic weights: random binary kernels and the same
    /// small range-preserving α/β the chain path's `synthetic_network`
    /// uses, so deep graphs keep activations inside Q2.9.
    pub fn seeded(g: &mut Gen, n_out: usize, n_in: usize, k: usize) -> Weights {
        Weights::seeded_scaled(g, n_out, n_in, k, 0.05, 0.01)
    }

    /// Seeded weights with explicit uniform α/β — e.g. bias-free
    /// partial convolutions whose outputs recombine off-chip through a
    /// residual [`GraphOp::Add`].
    pub fn seeded_scaled(
        g: &mut Gen,
        n_out: usize,
        n_in: usize,
        k: usize,
        alpha: f64,
        beta: f64,
    ) -> Weights {
        Weights {
            kernels: Arc::new(BinaryKernels::random(g, n_out, n_in, k)),
            scale_bias: Arc::new(ScaleBias {
                alpha: vec![Q2_9.from_f64(alpha); n_out],
                beta: vec![Q2_9.from_f64(beta); n_out],
            }),
        }
    }
}

/// Per-layer activation precision — the BinarEye-style energy–accuracy
/// knob. [`Precision::MultiBit`] is classic YodaNN: 12-bit Q2.9
/// activations through the bitplane raster and the multi-bit engine
/// family. [`Precision::Binary`] is XNOR mode (XNORBIN / ChewBaccaNN):
/// the layer's *input* activations are binarized to ±1.0 (sign
/// convention `x ≥ 0 ⇒ +1`) and the conv runs on an
/// [`crate::engine::EngineKind`] from the XNOR family against the
/// 1-bit [`crate::engine::BinaryRaster`] — ~12× fewer activation words
/// moved per (channel, row). One graph can mix both, e.g. a multi-bit
/// stem in front of a binary trunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// 12-bit Q2.9 activations (YodaNN BWN mode) — the default.
    #[default]
    MultiBit,
    /// 1-bit ±1 activations (XNOR/BNN mode).
    Binary,
}

impl Precision {
    /// Canonical spelling ([`std::fmt::Display`] echoes it).
    pub fn name(self) -> &'static str {
        match self {
            Precision::MultiBit => "multi-bit",
            Precision::Binary => "binary",
        }
    }

    /// Parse a CLI/config spelling. Accepted: `multi-bit`/`multibit`/
    /// `bwn` and `binary`/`bnn`/`xnor`.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "multi-bit" | "multibit" | "bwn" => Some(Precision::MultiBit),
            "binary" | "bnn" | "xnor" => Some(Precision::Binary),
            _ => None,
        }
    }

    /// Every spelling [`Precision::parse`] accepts (drift-pinned by the
    /// round-trip proptest).
    pub const ACCEPTED: [&'static str; 6] =
        ["multi-bit", "multibit", "bwn", "binary", "bnn", "xnor"];

    /// Every precision, in listing order (`yodann networks` builds its
    /// modes column from this, so a new precision shows up there by
    /// construction).
    pub const ALL: [Precision; 2] = [Precision::MultiBit, Precision::Binary];

    /// Short column tag for listings (`B` = multi-bit/BWN, `X` = binary/
    /// XNOR).
    pub fn tag(self) -> char {
        match self {
            Precision::MultiBit => 'B',
            Precision::Binary => 'X',
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.name())
    }
}

/// Handle to a node of the graph being built (opaque; only valid for
/// the [`NetworkBuilder`] that issued it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeId(usize);

/// The operation a graph node performs.
#[derive(Debug, Clone)]
pub enum GraphOp {
    /// The graph's input feature map (always node 0, created by
    /// [`NetworkBuilder::new`]).
    Input {
        /// Input channels.
        c: usize,
    },
    /// A convolution on the accelerator (`k` is `weights.kernels.k`).
    Conv {
        /// Zero-padded (H×W-preserving) convolution.
        zero_pad: bool,
        /// Kernels and scale/bias.
        weights: Weights,
        /// Activation precision of this layer's *input* (the per-layer
        /// BWN/BNN knob).
        precision: Precision,
    },
    /// Quantized ReLU (`max(0, ·)` on raw Q2.9), on the host.
    Relu,
    /// 2×2 stride-2 max-pool (odd trailing rows/columns dropped), on
    /// the host.
    MaxPool2,
    /// Stride-2 subsample (keep every other pixel, starting at 0) — how
    /// strided convolutions run on a stride-less accelerator: compute
    /// at stride 1, subsample off-chip (the paper's op accounting does
    /// the same).
    Subsample2,
    /// Element-wise residual add of ≥ 2 branches: wide integer sum,
    /// saturated once to Q2.9 (host arithmetic).
    Add,
    /// Channel-wise concatenation of ≥ 2 branches.
    Concat,
    /// Batch-norm + sign lowered to a per-channel threshold (the
    /// standard BNN trick): `out = +1.0 if x ≥ threshold[c] else −1.0`
    /// in raw Q2.9 (±512), on the host. The natural producer of a
    /// [`Precision::Binary`] conv's input — its output is already a
    /// legal binarized Q2.9 image, so the next layer's 1-bit raster
    /// pack is lossless.
    BatchNormThreshold {
        /// Per-channel raw Q2.9 thresholds, arity-checked against the
        /// source's channel count at [`NetworkGraph::compile`].
        thresholds: Arc<Vec<i64>>,
    },
}

/// One node: its operation, label (used in error messages) and inputs.
#[derive(Debug, Clone)]
pub struct GraphNode {
    /// Label for diagnostics ([`YodannError::AtNode`] tags).
    pub label: String,
    /// The operation.
    pub op: GraphOp,
    /// Input nodes (always earlier in the build order, so the graph is
    /// a DAG by construction).
    pub inputs: Vec<NodeId>,
}

/// Builder for a [`NetworkGraph`]: nodes are appended front-to-back,
/// every method returns the new node's [`NodeId`] for wiring.
///
/// The builder itself never fails — structural and typing problems
/// (channel mismatches, bad join arity, disconnected nodes) are
/// reported as typed [`YodannError`]s by [`NetworkGraph::compile`],
/// which is also where [`crate::api::SessionBuilder::graph`] sends
/// them. [`NodeId`]s are only meaningful to the builder that issued
/// them: a foreign id panics when it is out of range, and an in-range
/// one silently names this builder's node of the same index — don't
/// mix builders.
#[derive(Debug, Clone)]
pub struct NetworkBuilder {
    name: String,
    nodes: Vec<GraphNode>,
}

impl NetworkBuilder {
    /// Start a graph taking `input_channels`-channel frames.
    pub fn new(name: impl Into<String>, input_channels: usize) -> NetworkBuilder {
        NetworkBuilder {
            name: name.into(),
            nodes: vec![GraphNode {
                label: "input".into(),
                op: GraphOp::Input { c: input_channels },
                inputs: Vec::new(),
            }],
        }
    }

    /// The graph's input node.
    pub fn input(&self) -> NodeId {
        NodeId(0)
    }

    fn push(&mut self, label: String, op: GraphOp, inputs: Vec<NodeId>) -> NodeId {
        for id in &inputs {
            assert!(id.0 < self.nodes.len(), "NodeId from a different NetworkBuilder");
        }
        self.nodes.push(GraphNode { label, op, inputs });
        NodeId(self.nodes.len() - 1)
    }

    /// Add a convolution node (`k` comes from `weights.kernels.k`),
    /// multi-bit activations (the default precision).
    pub fn conv(&mut self, label: &str, src: NodeId, zero_pad: bool, weights: Weights) -> NodeId {
        self.conv_with_precision(label, src, zero_pad, weights, Precision::MultiBit)
    }

    /// Add a convolution node with an explicit activation
    /// [`Precision`] — [`Precision::Binary`] makes this an XNOR layer
    /// (its input is binarized to ±1 before the dot product).
    pub fn conv_with_precision(
        &mut self,
        label: &str,
        src: NodeId,
        zero_pad: bool,
        weights: Weights,
        precision: Precision,
    ) -> NodeId {
        self.push(label.to_string(), GraphOp::Conv { zero_pad, weights, precision }, vec![src])
    }

    /// Add a batch-norm-threshold node: per-channel `sign(x − t[c])`
    /// emitting ±1.0 (raw ±512). Threshold arity is checked against
    /// the source's channels at [`NetworkGraph::compile`].
    pub fn batch_norm_threshold(
        &mut self,
        label: &str,
        src: NodeId,
        thresholds: Arc<Vec<i64>>,
    ) -> NodeId {
        self.push(label.to_string(), GraphOp::BatchNormThreshold { thresholds }, vec![src])
    }

    /// Add a quantized-ReLU node.
    pub fn relu(&mut self, src: NodeId) -> NodeId {
        let label = format!("relu#{}", self.nodes.len());
        self.push(label, GraphOp::Relu, vec![src])
    }

    /// Add a 2×2 stride-2 max-pool node.
    pub fn maxpool2(&mut self, src: NodeId) -> NodeId {
        let label = format!("maxpool#{}", self.nodes.len());
        self.push(label, GraphOp::MaxPool2, vec![src])
    }

    /// Add a stride-2 subsample node.
    pub fn subsample2(&mut self, src: NodeId) -> NodeId {
        let label = format!("subsample#{}", self.nodes.len());
        self.push(label, GraphOp::Subsample2, vec![src])
    }

    /// Add a residual-add node joining `srcs` (≥ 2 branches of
    /// identical shape).
    pub fn add(&mut self, label: &str, srcs: &[NodeId]) -> NodeId {
        self.push(label.to_string(), GraphOp::Add, srcs.to_vec())
    }

    /// Add a channel-concat node joining `srcs` (≥ 2 branches of
    /// identical H×W).
    pub fn concat(&mut self, label: &str, srcs: &[NodeId]) -> NodeId {
        self.push(label.to_string(), GraphOp::Concat, srcs.to_vec())
    }

    /// Finish the graph, designating `output` as the network's output.
    pub fn build(self, output: NodeId) -> NetworkGraph {
        assert!(output.0 < self.nodes.len(), "NodeId from a different NetworkBuilder");
        NetworkGraph { name: self.name, nodes: self.nodes, output }
    }
}

/// A CNN as a typed DAG of conv nodes and host ops. Built by
/// [`NetworkBuilder`], validated and lowered by
/// [`NetworkGraph::compile`], run by
/// [`crate::api::SessionBuilder::graph`].
///
/// ```
/// use yodann::model::graph::{NetworkBuilder, Weights};
/// use yodann::testkit::Gen;
///
/// // A toy residual block: conv → relu → conv, added to a 1×1
/// // projection of the input, then ReLU.
/// let mut g = Gen::new(7);
/// let mut b = NetworkBuilder::new("toy-residual", 3);
/// let x = b.input();
/// let main = b.conv("conv1", x, true, Weights::seeded(&mut g, 8, 3, 3));
/// let main = b.relu(main);
/// let main = b.conv("conv2", main, true, Weights::seeded(&mut g, 8, 8, 3));
/// let proj = b.conv("proj", x, true, Weights::seeded(&mut g, 8, 3, 1));
/// let sum = b.add("residual", &[main, proj]);
/// let out = b.relu(sum);
/// let graph = b.build(out);
///
/// let plan = graph.compile().expect("a well-typed graph");
/// assert_eq!(plan.convs.len(), 3);
/// assert_eq!(plan.walk_shapes(3, 16, 16).unwrap(), (8, 16, 16));
/// ```
#[derive(Debug, Clone)]
pub struct NetworkGraph {
    /// Network name (used by [`YodannError::NoConvLayers`] and reports).
    pub name: String,
    nodes: Vec<GraphNode>,
    output: NodeId,
}

impl NetworkGraph {
    /// All nodes, in build order (node 0 is the input).
    pub fn nodes(&self) -> &[GraphNode] {
        &self.nodes
    }

    /// The designated output node.
    pub fn output(&self) -> NodeId {
        self.output
    }

    /// Validate the graph end-to-end and lower it into an executable
    /// [`CompiledGraph`].
    ///
    /// Checks, all reported as typed [`YodannError`]s (conv-node
    /// failures tagged [`YodannError::AtNode`]):
    ///
    /// * conv kernel size in 1..=7 and scale/bias arity matching the
    ///   kernel set;
    /// * channel typing along every edge (conv input channels, add
    ///   branches agreeing, concat summing);
    /// * join arity (add/concat need ≥ 2 inputs);
    /// * every node on a path to the output
    ///   ([`YodannError::GraphDisconnected`] otherwise);
    /// * at least one conv node ([`YodannError::NoConvLayers`]).
    ///
    /// Frame-dependent geometry (valid-mode h < k, branch H×W
    /// conflicts) is checked per frame by
    /// [`CompiledGraph::walk_shapes`].
    pub fn compile(&self) -> Result<CompiledGraph, YodannError> {
        // Pass 1: structural checks + channel inference, in build order
        // (inputs always precede their consumers, so the graph is a DAG
        // and build order is a topological order).
        let mut out_c: Vec<usize> = Vec::with_capacity(self.nodes.len());
        for (i, n) in self.nodes.iter().enumerate() {
            for id in &n.inputs {
                if id.0 >= i {
                    return Err(YodannError::InvalidConfig {
                        what: format!(
                            "graph node '{}' references node #{} at or after itself (#{i})",
                            n.label, id.0
                        ),
                    });
                }
            }
            let c = match &n.op {
                GraphOp::Input { c } => {
                    if i != 0 {
                        return Err(YodannError::InvalidConfig {
                            what: format!("graph has a second input node '{}'", n.label),
                        });
                    }
                    *c
                }
                GraphOp::Conv { weights, .. } => {
                    let k = weights.kernels.k;
                    if !(1..=7).contains(&k) {
                        return Err(YodannError::UnsupportedKernel { k }.at_node(&n.label));
                    }
                    if weights.scale_bias.alpha.len() != weights.kernels.n_out {
                        return Err(YodannError::ScaleBiasArity {
                            alphas: weights.scale_bias.alpha.len(),
                            n_out: weights.kernels.n_out,
                        }
                        .at_node(&n.label));
                    }
                    let src_c = out_c[n.inputs[0].0];
                    if src_c != weights.kernels.n_in {
                        return Err(YodannError::ChannelChainMismatch {
                            prev_out: src_c,
                            n_in: weights.kernels.n_in,
                        }
                        .at_node(&n.label));
                    }
                    weights.kernels.n_out
                }
                GraphOp::Relu | GraphOp::MaxPool2 | GraphOp::Subsample2 => out_c[n.inputs[0].0],
                GraphOp::BatchNormThreshold { thresholds } => {
                    let src_c = out_c[n.inputs[0].0];
                    if thresholds.len() != src_c {
                        return Err(YodannError::ThresholdArity {
                            thresholds: thresholds.len(),
                            channels: src_c,
                        }
                        .at_node(&n.label));
                    }
                    src_c
                }
                GraphOp::Add => {
                    if n.inputs.len() < 2 {
                        return Err(YodannError::GraphArity {
                            node: n.label.clone(),
                            op: "add",
                            inputs: n.inputs.len(),
                        });
                    }
                    let c0 = out_c[n.inputs[0].0];
                    for id in &n.inputs[1..] {
                        if out_c[id.0] != c0 {
                            return Err(YodannError::GraphChannelMismatch {
                                node: n.label.clone(),
                                a: c0,
                                b: out_c[id.0],
                            });
                        }
                    }
                    c0
                }
                GraphOp::Concat => {
                    if n.inputs.len() < 2 {
                        return Err(YodannError::GraphArity {
                            node: n.label.clone(),
                            op: "concat",
                            inputs: n.inputs.len(),
                        });
                    }
                    n.inputs.iter().map(|id| out_c[id.0]).sum()
                }
            };
            out_c.push(c);
        }

        // Pass 2: every node must sit on a path to the output.
        let mut reach = vec![false; self.nodes.len()];
        let mut stack = vec![self.output.0];
        while let Some(p) = stack.pop() {
            if !reach[p] {
                reach[p] = true;
                stack.extend(self.nodes[p].inputs.iter().map(|id| id.0));
            }
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if !reach[i] {
                return Err(YodannError::GraphDisconnected { node: n.label.clone() });
            }
        }

        // Pass 3: lower. One value slot per node (node index = slot),
        // conv nodes extracted into the conv table the session packs
        // kernels for.
        let mut convs: Vec<PlanConv> = Vec::new();
        let mut steps: Vec<PlanStep> = Vec::new();
        let mut step_labels: Vec<String> = Vec::new();
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            let srcs: Vec<usize> = n.inputs.iter().map(|id| id.0).collect();
            let step = match &n.op {
                GraphOp::Input { .. } => unreachable!("checked in pass 1"),
                GraphOp::Conv { zero_pad, weights, precision } => {
                    convs.push(PlanConv {
                        k: weights.kernels.k,
                        zero_pad: *zero_pad,
                        kernels: Arc::clone(&weights.kernels),
                        scale_bias: Arc::clone(&weights.scale_bias),
                        precision: *precision,
                        label: n.label.clone(),
                    });
                    PlanStep::Conv { conv: convs.len() - 1, src: srcs[0], dst: i }
                }
                GraphOp::Relu => PlanStep::Relu { src: srcs[0], dst: i },
                GraphOp::MaxPool2 => PlanStep::MaxPool2 { src: srcs[0], dst: i },
                GraphOp::Subsample2 => PlanStep::Subsample2 { src: srcs[0], dst: i },
                GraphOp::Add => PlanStep::Add { srcs, dst: i },
                GraphOp::Concat => PlanStep::Concat { srcs, dst: i },
                GraphOp::BatchNormThreshold { thresholds } => PlanStep::BatchNormThreshold {
                    thresholds: Arc::clone(thresholds),
                    src: srcs[0],
                    dst: i,
                },
            };
            steps.push(step);
            step_labels.push(n.label.clone());
        }
        if convs.is_empty() {
            return Err(YodannError::NoConvLayers { net: self.name.clone() });
        }
        let n_slots = self.nodes.len();
        let output_slot = self.output.0;
        let free_after = compute_free_after(&steps, n_slots, output_slot);
        Ok(CompiledGraph {
            name: self.name.clone(),
            n_in: out_c[0],
            convs,
            steps,
            step_labels,
            n_slots,
            input_slot: 0,
            output_slot,
            free_after,
        })
    }
}

/// One lowered convolution layer: what a session packs kernels for and
/// fans out across engines/shards.
#[derive(Debug, Clone)]
pub struct PlanConv {
    /// Kernel size (1..=7, validated at compile).
    pub k: usize,
    /// Zero-padded convolution.
    pub zero_pad: bool,
    /// Kernel set, shared across workers and frames.
    pub kernels: Arc<BinaryKernels>,
    /// Per-output-channel scale/bias, shared.
    pub scale_bias: Arc<ScaleBias>,
    /// Activation precision of this layer's input (BWN vs XNOR mode).
    pub precision: Precision,
    /// Originating graph-node label (diagnostics).
    pub label: String,
}

/// One step of a compiled network: a conv segment or a host-op
/// interlude, reading and writing value slots.
#[derive(Debug, Clone)]
pub enum PlanStep {
    /// Run conv layer `conv` (index into [`CompiledGraph::convs`]) on
    /// slot `src`, writing slot `dst`.
    Conv {
        /// Index into [`CompiledGraph::convs`].
        conv: usize,
        /// Input slot.
        src: usize,
        /// Output slot.
        dst: usize,
    },
    /// Quantized ReLU interlude.
    Relu {
        /// Input slot.
        src: usize,
        /// Output slot.
        dst: usize,
    },
    /// 2×2 stride-2 max-pool interlude (identity when h or w < 2).
    MaxPool2 {
        /// Input slot.
        src: usize,
        /// Output slot.
        dst: usize,
    },
    /// Stride-2 subsample interlude.
    Subsample2 {
        /// Input slot.
        src: usize,
        /// Output slot.
        dst: usize,
    },
    /// Residual add of `srcs` (wide sum, one Q2.9 saturation).
    Add {
        /// Input slots.
        srcs: Vec<usize>,
        /// Output slot.
        dst: usize,
    },
    /// Channel-wise concat of `srcs`.
    Concat {
        /// Input slots.
        srcs: Vec<usize>,
        /// Output slot.
        dst: usize,
    },
    /// Batch-norm + sign threshold interlude: per-channel
    /// `x ≥ t[c] ? +512 : −512` (host arithmetic, shape-preserving).
    BatchNormThreshold {
        /// Per-channel raw Q2.9 thresholds (arity == src channels,
        /// validated at compile).
        thresholds: Arc<Vec<i64>>,
        /// Input slot.
        src: usize,
        /// Output slot.
        dst: usize,
    },
}

impl PlanStep {
    /// The slot this step writes.
    pub fn dst(&self) -> usize {
        match self {
            PlanStep::Conv { dst, .. }
            | PlanStep::Relu { dst, .. }
            | PlanStep::MaxPool2 { dst, .. }
            | PlanStep::Subsample2 { dst, .. }
            | PlanStep::Add { dst, .. }
            | PlanStep::Concat { dst, .. }
            | PlanStep::BatchNormThreshold { dst, .. } => *dst,
        }
    }

    /// The slots this step reads (with multiplicity).
    pub fn srcs(&self) -> Vec<usize> {
        match self {
            PlanStep::Conv { src, .. }
            | PlanStep::Relu { src, .. }
            | PlanStep::MaxPool2 { src, .. }
            | PlanStep::Subsample2 { src, .. }
            | PlanStep::BatchNormThreshold { src, .. } => vec![*src],
            PlanStep::Add { srcs, .. } | PlanStep::Concat { srcs, .. } => srcs.clone(),
        }
    }
}

/// For each step, the slots whose last read is that step (and which are
/// not the output) — what an interpreter frees to keep at most the live
/// frontier of the DAG in memory.
pub(crate) fn compute_free_after(
    steps: &[PlanStep],
    n_slots: usize,
    output_slot: usize,
) -> Vec<Vec<usize>> {
    let mut last_use = vec![usize::MAX; n_slots];
    for (i, s) in steps.iter().enumerate() {
        for src in s.srcs() {
            last_use[src] = i;
        }
    }
    let mut free: Vec<Vec<usize>> = vec![Vec::new(); steps.len()];
    for (slot, &lu) in last_use.iter().enumerate() {
        if lu != usize::MAX && slot != output_slot {
            free[lu].push(slot);
        }
    }
    free
}

/// A validated, lowered network: conv segments + host-op interludes
/// over a slot-addressed value store. Produced by
/// [`NetworkGraph::compile`] (and, internally, by the session's chain
/// lowering so flat [`SessionLayerSpec`] networks run through the same
/// interpreter).
///
/// [`SessionLayerSpec`]: crate::coordinator::SessionLayerSpec
#[derive(Debug, Clone)]
pub struct CompiledGraph {
    /// Network name.
    pub name: String,
    /// Channels the input frame must carry.
    pub n_in: usize,
    /// The conv layers, in step order.
    pub convs: Vec<PlanConv>,
    /// The step program, in topological order.
    pub steps: Vec<PlanStep>,
    /// Graph-node label per step (diagnostics).
    pub step_labels: Vec<String>,
    /// Value slots an interpreter allocates.
    pub n_slots: usize,
    /// Slot holding the input frame.
    pub input_slot: usize,
    /// Slot holding the network output after the last step.
    pub output_slot: usize,
    /// Per-step free lists (see [`compute_free_after`]).
    pub free_after: Vec<Vec<usize>>,
}

impl CompiledGraph {
    /// Walk a frame's (c, h, w) through every step without running it:
    /// the typed pre-flight the serving facade performs at `submit`.
    /// Conv geometry failures come back tagged with the conv's layer
    /// index ([`YodannError::AtLayer`], matching the chain path);
    /// branch-shape conflicts name the join node
    /// ([`YodannError::GraphShapeMismatch`]). Returns the output shape.
    pub fn walk_shapes(
        &self,
        c: usize,
        h: usize,
        w: usize,
    ) -> Result<(usize, usize, usize), YodannError> {
        if c != self.n_in {
            return Err(YodannError::FrameChannelMismatch { got: c, expected: self.n_in });
        }
        let mut shapes: Vec<Option<(usize, usize, usize)>> = vec![None; self.n_slots];
        shapes[self.input_slot] = Some((c, h, w));
        let get = |shapes: &[Option<(usize, usize, usize)>], s: usize| {
            shapes[s].expect("steps are topologically ordered")
        };
        for (si, step) in self.steps.iter().enumerate() {
            let out = match step {
                PlanStep::Conv { conv, src, .. } => {
                    let (_, sh, sw) = get(&shapes, *src);
                    let pc = &self.convs[*conv];
                    if !pc.zero_pad {
                        if sh < pc.k {
                            return Err(YodannError::NoOutputRows {
                                k: pc.k,
                                axis: "height",
                                size: sh,
                            }
                            .at_layer(*conv));
                        }
                        if sw < pc.k {
                            return Err(YodannError::NoOutputRows {
                                k: pc.k,
                                axis: "width",
                                size: sw,
                            }
                            .at_layer(*conv));
                        }
                    }
                    let (oh, ow) =
                        if pc.zero_pad { (sh, sw) } else { (sh - pc.k + 1, sw - pc.k + 1) };
                    (pc.kernels.n_out, oh, ow)
                }
                PlanStep::Relu { src, .. } | PlanStep::BatchNormThreshold { src, .. } => {
                    get(&shapes, *src)
                }
                PlanStep::MaxPool2 { src, .. } => {
                    let (sc, sh, sw) = get(&shapes, *src);
                    if sh >= 2 && sw >= 2 {
                        (sc, sh / 2, sw / 2)
                    } else {
                        (sc, sh, sw)
                    }
                }
                PlanStep::Subsample2 { src, .. } => {
                    let (sc, sh, sw) = get(&shapes, *src);
                    (sc, sh.div_ceil(2), sw.div_ceil(2))
                }
                PlanStep::Add { srcs, .. } => {
                    let s0 = get(&shapes, srcs[0]);
                    for &s in &srcs[1..] {
                        let si_shape = get(&shapes, s);
                        if si_shape != s0 {
                            return Err(YodannError::GraphShapeMismatch {
                                node: self.step_labels[si].clone(),
                                a: s0,
                                b: si_shape,
                            });
                        }
                    }
                    s0
                }
                PlanStep::Concat { srcs, .. } => {
                    let (c0, h0, w0) = get(&shapes, srcs[0]);
                    let mut csum = 0;
                    for &s in srcs {
                        let (sc, sh, sw) = get(&shapes, s);
                        if (sh, sw) != (h0, w0) {
                            return Err(YodannError::GraphShapeMismatch {
                                node: self.step_labels[si].clone(),
                                a: (c0, h0, w0),
                                b: (sc, sh, sw),
                            });
                        }
                        csum += sc;
                    }
                    (csum, h0, w0)
                }
            };
            shapes[step.dst()] = Some(out);
        }
        Ok(shapes[self.output_slot].expect("the output slot is written by the last use of it"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_graph() -> NetworkGraph {
        let mut g = Gen::new(7);
        let mut b = NetworkBuilder::new("toy", 3);
        let x = b.input();
        let main = b.conv("conv1", x, true, Weights::seeded(&mut g, 8, 3, 3));
        let main = b.relu(main);
        let main = b.conv("conv2", main, true, Weights::seeded(&mut g, 8, 8, 3));
        let proj = b.conv("proj", x, true, Weights::seeded(&mut g, 8, 3, 1));
        let sum = b.add("residual", &[main, proj]);
        let out = b.relu(sum);
        b.build(out)
    }

    #[test]
    fn residual_graph_compiles_and_walks() {
        let plan = toy_graph().compile().unwrap();
        assert_eq!(plan.convs.len(), 3);
        assert_eq!(plan.n_in, 3);
        assert_eq!(plan.steps.len(), 6);
        assert_eq!(plan.walk_shapes(3, 16, 12).unwrap(), (8, 16, 12));
        // Channel mismatch at the door.
        let e = plan.walk_shapes(4, 16, 12).unwrap_err();
        assert_eq!(e, YodannError::FrameChannelMismatch { got: 4, expected: 3 });
    }

    #[test]
    fn free_lists_release_everything_but_the_output() {
        let plan = toy_graph().compile().unwrap();
        let freed: usize = plan.free_after.iter().map(|f| f.len()).sum();
        // Every slot except the output is freed exactly once.
        assert_eq!(freed, plan.n_slots - 1);
        assert!(plan.free_after.iter().flatten().all(|&s| s != plan.output_slot));
    }

    #[test]
    fn channel_typing_is_validated_at_the_offending_node() {
        let mut g = Gen::new(1);
        let mut b = NetworkBuilder::new("bad", 3);
        let x = b.input();
        // conv expects 4 input channels, gets 3.
        let c = b.conv("conv1", x, true, Weights::seeded(&mut g, 8, 4, 3));
        let e = b.build(c).compile().unwrap_err();
        assert!(
            matches!(&e, YodannError::AtNode { node, inner }
                if node == "conv1"
                    && matches!(**inner, YodannError::ChannelChainMismatch { prev_out: 3, n_in: 4 })),
            "{e}"
        );
    }

    #[test]
    fn join_arity_and_channel_conflicts_are_typed() {
        let mut g = Gen::new(2);
        let mut b = NetworkBuilder::new("joins", 3);
        let x = b.input();
        let a = b.conv("a", x, true, Weights::seeded(&mut g, 4, 3, 3));
        let sum = b.add("lonely", &[a]);
        let e = b.build(sum).compile().unwrap_err();
        assert_eq!(e, YodannError::GraphArity { node: "lonely".into(), op: "add", inputs: 1 });
        // Add of 4- and 6-channel branches.
        let mut b = NetworkBuilder::new("joins2", 3);
        let x = b.input();
        let a = b.conv("a", x, true, Weights::seeded(&mut g, 4, 3, 3));
        let b6 = b.conv("b", x, true, Weights::seeded(&mut g, 6, 3, 3));
        let bad = b.add("join", &[a, b6]);
        let e = b.build(bad).compile().unwrap_err();
        assert_eq!(e, YodannError::GraphChannelMismatch { node: "join".into(), a: 4, b: 6 });
    }

    #[test]
    fn disconnected_nodes_and_convless_graphs_are_rejected() {
        let mut g = Gen::new(3);
        let mut b = NetworkBuilder::new("dead", 3);
        let x = b.input();
        let used = b.conv("used", x, true, Weights::seeded(&mut g, 4, 3, 3));
        b.conv("dead-branch", x, true, Weights::seeded(&mut g, 4, 3, 3));
        let e = b.build(used).compile().unwrap_err();
        assert_eq!(e, YodannError::GraphDisconnected { node: "dead-branch".into() });

        let mut b = NetworkBuilder::new("no-convs", 3);
        let x = b.input();
        let r = b.relu(x);
        let e = b.build(r).compile().unwrap_err();
        assert_eq!(e, YodannError::NoConvLayers { net: "no-convs".into() });
    }

    #[test]
    fn bad_kernel_and_scale_arity_are_tagged_with_the_node() {
        let mut g = Gen::new(4);
        let mut b = NetworkBuilder::new("badk", 3);
        let x = b.input();
        let c = b.conv("conv9", x, true, Weights::seeded(&mut g, 4, 3, 9));
        let e = b.build(c).compile().unwrap_err();
        assert!(matches!(&e, YodannError::AtNode { node, inner }
            if node == "conv9" && matches!(**inner, YodannError::UnsupportedKernel { k: 9 })));

        let mut g = Gen::new(5);
        let mut b = NetworkBuilder::new("badsb", 3);
        let x = b.input();
        let w = Weights::new(
            Arc::new(BinaryKernels::random(&mut g, 4, 3, 3)),
            Arc::new(ScaleBias::identity(2)), // 2 != 4
        );
        let c = b.conv("convsb", x, true, w);
        let e = b.build(c).compile().unwrap_err();
        assert!(matches!(&e, YodannError::AtNode { node, inner }
            if node == "convsb"
                && matches!(**inner, YodannError::ScaleBiasArity { alphas: 2, n_out: 4 })));
    }

    #[test]
    fn walk_reports_valid_mode_underflow_and_branch_conflicts() {
        let mut g = Gen::new(6);
        let mut b = NetworkBuilder::new("shapes", 2);
        let x = b.input();
        // Valid-mode k=5 shrinks by 4; identity branch does not.
        let shrunk = b.conv("valid5", x, false, Weights::seeded(&mut g, 2, 2, 5));
        let ident = b.conv("ident", x, true, Weights::seeded(&mut g, 2, 2, 1));
        let sum = b.add("join", &[shrunk, ident]);
        let plan = b.build(sum).compile().unwrap();
        // Frame too small for the valid conv: typed NoOutputRows at layer 0.
        let e = plan.walk_shapes(2, 3, 9).unwrap_err();
        assert!(matches!(&e, YodannError::AtLayer { layer: 0, inner }
            if matches!(**inner, YodannError::NoOutputRows { k: 5, axis: "height", size: 3 })));
        // Large enough frame: the join's branches disagree on H×W.
        let e = plan.walk_shapes(2, 9, 9).unwrap_err();
        assert!(
            matches!(&e, YodannError::GraphShapeMismatch { node, a: (2, 5, 5), b: (2, 9, 9) }
                if node == "join"),
            "{e}"
        );
    }

    #[test]
    fn precision_knob_and_threshold_lower_into_the_plan() {
        let mut g = Gen::new(9);
        let mut b = NetworkBuilder::new("bnn", 3);
        let x = b.input();
        // BWN stem → batch-norm threshold → XNOR trunk.
        let stem = b.conv("stem", x, true, Weights::seeded(&mut g, 8, 3, 3));
        let bin = b.batch_norm_threshold("bnt", stem, Arc::new(vec![0; 8]));
        let trunk = b.conv_with_precision(
            "trunk",
            bin,
            true,
            Weights::seeded(&mut g, 8, 8, 3),
            Precision::Binary,
        );
        let plan = b.build(trunk).compile().unwrap();
        assert_eq!(plan.convs[0].precision, Precision::MultiBit);
        assert_eq!(plan.convs[1].precision, Precision::Binary);
        // The threshold step is shape-preserving and slot-typed.
        assert_eq!(plan.walk_shapes(3, 12, 10).unwrap(), (8, 12, 10));
        let bnt = &plan.steps[1];
        assert!(matches!(bnt, PlanStep::BatchNormThreshold { .. }));
        assert_eq!(bnt.srcs(), vec![bnt.dst() - 1]);
    }

    #[test]
    fn threshold_arity_is_validated_at_the_node() {
        let mut g = Gen::new(10);
        let mut b = NetworkBuilder::new("badt", 3);
        let x = b.input();
        let c = b.conv("c", x, true, Weights::seeded(&mut g, 8, 3, 3));
        let t = b.batch_norm_threshold("bnt", c, Arc::new(vec![0; 5])); // 5 != 8
        let e = b.build(t).compile().unwrap_err();
        assert!(
            matches!(&e, YodannError::AtNode { node, inner }
                if node == "bnt"
                    && matches!(**inner, YodannError::ThresholdArity { thresholds: 5, channels: 8 })),
            "{e}"
        );
    }

    #[test]
    fn precision_parse_round_trips_and_covers_accepted() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(p.to_string(), p.name());
        }
        for s in Precision::ACCEPTED {
            let p = Precision::parse(s).unwrap_or_else(|| panic!("ACCEPTED spelling {s:?}"));
            assert!(Precision::ALL.contains(&p), "{s:?} parses outside ALL");
        }
        assert_eq!(Precision::parse("xnor"), Some(Precision::Binary));
        assert_eq!(Precision::parse("bwn"), Some(Precision::MultiBit));
        assert_eq!(Precision::parse("ternary"), None);
        assert_eq!(Precision::default(), Precision::MultiBit);
    }

    #[test]
    fn subsample_and_pool_shapes_walk_like_the_host_ops() {
        let mut g = Gen::new(8);
        let mut b = NetworkBuilder::new("downs", 3);
        let x = b.input();
        let c = b.conv("c", x, true, Weights::seeded(&mut g, 4, 3, 3));
        let s = b.subsample2(c);
        let p = b.maxpool2(s);
        let plan = b.build(p).compile().unwrap();
        // 11 → ceil(11/2) = 6 → pool 3.
        assert_eq!(plan.walk_shapes(3, 11, 11).unwrap(), (4, 3, 3));
        // Pool is the identity below 2×2.
        assert_eq!(plan.walk_shapes(3, 2, 2).unwrap(), (4, 1, 1));
    }
}
