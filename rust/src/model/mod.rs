//! CNN model descriptors and the paper's performance analytics.
//!
//! * [`layer`] — convolution / fully-connected layer descriptors and the
//!   operation-count formula (paper Eq. 7).
//! * [`networks`] — every network evaluated in the paper's Table III
//!   (BinaryConnect Cifar-10 / SVHN, AlexNet with the 11×11 kernel split,
//!   ResNet-18/34, VGG-13/19), encoded from the table — plus runnable
//!   **graph encodings** of the non-chain networks (AlexNet's parallel
//!   split, ResNet's residual shortcuts).
//! * [`graph`] — the graph-based network IR: [`graph::NetworkBuilder`] /
//!   [`graph::NetworkGraph`] (typed DAG of conv nodes and host ops) and
//!   [`graph::NetworkGraph::compile`], the validating lowering pass that
//!   produces the executable [`graph::CompiledGraph`] sessions run.
//! * [`efficiency`] — the throughput-efficiency model of §IV-A
//!   (Eqs. 8–11: tiling, channel idling, border effects) and the
//!   per-layer/per-network evaluation engine behind Tables III–V.

pub mod efficiency;
pub mod graph;
pub mod layer;
pub mod networks;

pub use efficiency::{evaluate_layer, evaluate_network, Corner, LayerEval, NetworkEval};
pub use graph::{CompiledGraph, NetworkBuilder, NetworkGraph, Precision, Weights};
pub use layer::{ops_per_layer, ConvLayer, KernelMode, Layer};
pub use networks::{all_networks, network, Network};
