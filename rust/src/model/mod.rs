//! CNN model descriptors and the paper's performance analytics.
//!
//! * [`layer`] — convolution / fully-connected layer descriptors and the
//!   operation-count formula (paper Eq. 7).
//! * [`networks`] — every network evaluated in the paper's Table III
//!   (BinaryConnect Cifar-10 / SVHN, AlexNet with the 11×11 kernel split,
//!   ResNet-18/34, VGG-13/19), encoded from the table.
//! * [`efficiency`] — the throughput-efficiency model of §IV-A
//!   (Eqs. 8–11: tiling, channel idling, border effects) and the
//!   per-layer/per-network evaluation engine behind Tables III–V.

pub mod efficiency;
pub mod layer;
pub mod networks;

pub use efficiency::{evaluate_layer, evaluate_network, Corner, LayerEval, NetworkEval};
pub use layer::{ops_per_layer, ConvLayer, KernelMode, Layer};
pub use networks::{all_networks, network, Network};
