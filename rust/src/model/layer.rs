//! Layer descriptors and operation counting (paper Eq. 7).

use crate::api::YodannError;

/// How a kernel size maps onto the SoP hardware (§III-E, Fig. 9).
///
/// Each SoP unit has 50 binary operators; it natively computes either one
/// 7×7 filter (one output channel) or **two** 5×5 / 3×3 filters (two output
/// channels, doubling output parallelism to `2·n_ch`). All other sizes are
/// zero-padded into the next-larger native slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Native 7×7 slot, one filter per SoP (used for k ∈ {6, 7}).
    Slot7,
    /// Dual 5×5 slot, two filters per SoP (used for k ∈ {4, 5}).
    Slot5,
    /// Dual 3×3 slot, two filters per SoP (used for k ∈ {1, 2, 3}).
    Slot3,
}

impl KernelMode {
    /// Native slot size for a filter of size `k` (1..=7).
    pub fn for_kernel(k: usize) -> KernelMode {
        match k {
            1..=3 => KernelMode::Slot3,
            4 | 5 => KernelMode::Slot5,
            6 | 7 => KernelMode::Slot7,
            _ => panic!("unsupported kernel size {k} (YodaNN supports 1..=7)"),
        }
    }

    /// Slot edge length (3, 5 or 7).
    pub fn slot_k(self) -> usize {
        match self {
            KernelMode::Slot3 => 3,
            KernelMode::Slot5 => 5,
            KernelMode::Slot7 => 7,
        }
    }

    /// Output channels computed in parallel per SoP unit (1 or 2).
    pub fn filters_per_sop(self) -> usize {
        match self {
            KernelMode::Slot7 => 1,
            KernelMode::Slot5 | KernelMode::Slot3 => 2,
        }
    }
}

/// A convolution layer as evaluated by the paper (Table III row).
#[derive(Debug, Clone)]
pub struct ConvLayer {
    /// Row label, e.g. "2-5" for grouped rows.
    pub label: &'static str,
    /// Square kernel size `h_k = b_k` (1..=7 after any decomposition).
    pub k: usize,
    /// Input image width in pixels.
    pub w: usize,
    /// Input image height in pixels.
    pub h: usize,
    /// Input channels.
    pub n_in: usize,
    /// Output channels.
    pub n_out: usize,
    /// How many instances of this layer the network contains
    /// (the table's "×" column).
    pub repeat: usize,
    /// Whether the layer zero-pads the image border (keeps H×W constant).
    pub zero_pad: bool,
}

impl ConvLayer {
    /// Output (height, width) as a typed result: a valid-mode
    /// (non-padded) layer smaller than its kernel has no output pixels
    /// and reports [`YodannError::NoOutputRows`] instead of wrapping
    /// `w − k + 1` around `usize` in release builds (debug builds used
    /// to panic on the bare subtraction, with no geometry in the
    /// message).
    pub fn try_out_hw(&self) -> Result<(usize, usize), YodannError> {
        if !self.zero_pad {
            if self.h < self.k {
                return Err(YodannError::NoOutputRows { k: self.k, axis: "height", size: self.h });
            }
            if self.w < self.k {
                return Err(YodannError::NoOutputRows { k: self.k, axis: "width", size: self.w });
            }
        }
        if self.zero_pad {
            Ok((self.h, self.w))
        } else {
            Ok((self.h - self.k + 1, self.w - self.k + 1))
        }
    }

    /// Output width (Eq. 7's `w_in − h_k + 1` without zero-padding).
    /// Panics with the typed geometry error on impossible layers — use
    /// [`ConvLayer::try_out_hw`] to handle them as data.
    pub fn out_w(&self) -> usize {
        match self.try_out_hw() {
            Ok((_, w)) => w,
            Err(e) => panic!("{e}"),
        }
    }

    /// Output height. Panics with the typed geometry error on
    /// impossible layers — use [`ConvLayer::try_out_hw`] to handle them
    /// as data.
    pub fn out_h(&self) -> usize {
        match self.try_out_hw() {
            Ok((h, _)) => h,
            Err(e) => panic!("{e}"),
        }
    }

    /// Hardware slot this kernel maps to.
    pub fn mode(&self) -> KernelMode {
        KernelMode::for_kernel(self.k)
    }

    /// Operations (multiply + add counted separately) for **one** instance,
    /// per the paper's Eq. 7:
    /// `#Op = 2·n_out·n_in·h_k·w_k·(h_out)·(w_out)`.
    ///
    /// The paper counts zero-padded layers over the full H×W output (its
    /// AlexNet/VGG #MOp values only match under that reading), and does not
    /// count memory accesses or the off-chip partial-sum additions.
    pub fn ops(&self) -> u64 {
        2 * self.n_out as u64
            * self.n_in as u64
            * (self.k * self.k) as u64
            * self.out_h() as u64
            * self.out_w() as u64
    }

    /// Total operations over all `repeat` instances.
    pub fn total_ops(&self) -> u64 {
        self.ops() * self.repeat as u64
    }
}

/// A non-convolution layer, listed for op-count completeness only — YodaNN
/// accelerates convolutions; FC/SVM layers run on the host (paper §III).
#[derive(Debug, Clone)]
pub struct DenseLayer {
    /// Row label.
    pub label: &'static str,
    /// Input features (n_in · w · h for flattening layers).
    pub n_in: usize,
    /// Output features.
    pub n_out: usize,
    /// Instance count.
    pub repeat: usize,
}

impl DenseLayer {
    /// 2 ops (mul + add) per weight.
    pub fn ops(&self) -> u64 {
        2 * self.n_in as u64 * self.n_out as u64
    }
}

/// Any layer of a network description.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Convolution, runs on the accelerator.
    Conv(ConvLayer),
    /// Fully-connected (or SVM) layer, runs on the host.
    Dense(DenseLayer),
}

impl Layer {
    /// Convolution view, if applicable.
    pub fn as_conv(&self) -> Option<&ConvLayer> {
        match self {
            Layer::Conv(c) => Some(c),
            Layer::Dense(_) => None,
        }
    }
}

/// Convenience: Eq. 7 for explicit parameters.
pub fn ops_per_layer(n_out: usize, n_in: usize, k: usize, out_h: usize, out_w: usize) -> u64 {
    2 * (n_out as u64) * (n_in as u64) * ((k * k) as u64) * (out_h as u64) * (out_w as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(k: usize, w: usize, h: usize, n_in: usize, n_out: usize, pad: bool) -> ConvLayer {
        ConvLayer { label: "t", k, w, h, n_in, n_out, repeat: 1, zero_pad: pad }
    }

    #[test]
    fn mode_mapping_matches_paper() {
        assert_eq!(KernelMode::for_kernel(7), KernelMode::Slot7);
        assert_eq!(KernelMode::for_kernel(6), KernelMode::Slot7);
        assert_eq!(KernelMode::for_kernel(5), KernelMode::Slot5);
        assert_eq!(KernelMode::for_kernel(4), KernelMode::Slot5);
        assert_eq!(KernelMode::for_kernel(3), KernelMode::Slot3);
        assert_eq!(KernelMode::for_kernel(2), KernelMode::Slot3);
        assert_eq!(KernelMode::for_kernel(1), KernelMode::Slot3);
        assert_eq!(KernelMode::Slot5.filters_per_sop(), 2);
        assert_eq!(KernelMode::Slot7.filters_per_sop(), 1);
    }

    #[test]
    #[should_panic]
    fn kernel_larger_than_7_rejected() {
        KernelMode::for_kernel(9);
    }

    #[test]
    fn op_counts_match_table3() {
        // BC-Cifar-10 L1: 3→128, k3, 32×32, zero-padded → 7 MOp.
        let l = conv(3, 32, 32, 3, 128, true);
        assert_eq!(l.ops(), 7_077_888); // ≈ 7 MOp
        // BC-Cifar-10 L2: 128→128 → 302 MOp.
        let l = conv(3, 32, 32, 128, 128, true);
        assert_eq!(l.ops() / 1_000_000, 301);
        // VGG L1: 3→64, k3, 224×224 → 173 MOp.
        let l = conv(3, 224, 224, 3, 64, true);
        assert_eq!(l.ops() / 1_000_000, 173);
        // ResNet L1: 3→64, k7, 224×224 → 944 MOp.
        let l = conv(7, 224, 224, 3, 64, true);
        assert_eq!(l.ops() / 1_000_000, 944);
        // AlexNet 1ab (6×6 split of 11×11): 3→48 → 520 MOp.
        let l = conv(6, 224, 224, 3, 48, true);
        assert_eq!(l.ops() / 1_000_000, 520);
        // AlexNet 1cd (5×5 split): 3→48 → 361 MOp.
        let l = conv(5, 224, 224, 3, 48, true);
        assert_eq!(l.ops() / 1_000_000, 361);
        // AlexNet L2: 48→128, k5, 55×55 → 929 MOp.
        let l = conv(5, 55, 55, 48, 128, true);
        assert_eq!(l.ops() / 1_000_000, 929);
        // ResNet stage rows: 64→64, k3, 112×112 → 925 MOp.
        let l = conv(3, 112, 112, 64, 64, true);
        assert_eq!((l.ops() as f64 / 1e6).round() as u64, 925);
    }

    #[test]
    fn non_padded_output_shrinks() {
        let l = conv(7, 32, 32, 8, 8, false);
        assert_eq!(l.out_w(), 26);
        assert_eq!(l.out_h(), 26);
        assert_eq!(l.ops(), 2 * 8 * 8 * 49 * 26 * 26);
    }

    #[test]
    fn thin_valid_layers_report_typed_geometry_instead_of_wrapping() {
        // Regression: w < k (or h < k) on an unpadded layer used to
        // compute `w − k + 1` directly — a debug panic with no context,
        // a near-2⁶⁴ wrap in release. Now: typed data via try_out_hw.
        let l = conv(5, 3, 12, 2, 2, false); // w = 3 < k = 5
        assert_eq!(
            l.try_out_hw().unwrap_err(),
            YodannError::NoOutputRows { k: 5, axis: "width", size: 3 }
        );
        let l = conv(7, 12, 4, 2, 2, false); // h = 4 < k = 7
        assert_eq!(
            l.try_out_hw().unwrap_err(),
            YodannError::NoOutputRows { k: 7, axis: "height", size: 4 }
        );
        // Zero-padded thin layers are fine (the halo supplies the rows).
        let l = conv(7, 3, 3, 2, 2, true);
        assert_eq!(l.try_out_hw().unwrap(), (3, 3));
        assert_eq!(l.out_w(), 3);
    }

    #[test]
    #[should_panic(expected = "no output rows")]
    fn out_w_panics_with_the_typed_geometry_error() {
        conv(5, 3, 12, 2, 2, false).out_w();
    }

    #[test]
    #[should_panic(expected = "no output rows")]
    fn out_h_panics_with_the_typed_geometry_error() {
        conv(7, 12, 4, 2, 2, false).out_h();
    }

    #[test]
    fn repeat_scales_total_ops() {
        let mut l = conv(3, 14, 14, 512, 512, true);
        l.repeat = 3;
        assert_eq!(l.total_ops(), 3 * l.ops());
    }
}
