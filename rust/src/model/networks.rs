//! The networks evaluated in the paper's Table III, encoded row-by-row.
//!
//! Notes on fidelity:
//!
//! * **AlexNet 11×11 split (§IV-D):** the first layer's 11×11 kernels are
//!   decomposed into 2×(6×6) + 2×(5×5) kernels with one overlapping centre
//!   pixel, avoiding extra 1×1 convolutions by choosing the overlap weight;
//!   the identity sums are subtracted off-chip. The table therefore lists
//!   rows "1ab" (6×6, ×4) and "1cd" (5×5, ×4). The printed `h_k = 4` for
//!   row 1cd is a typo — the split produces 5×5 kernels and only k = 5
//!   reproduces the row's 361 MOp.
//! * **ResNet-18/34 and VGG-13/19** share rows; the "×" column holds the
//!   per-variant instance counts (e.g. "5/6" → 5 for ResNet-18, 6 for
//!   ResNet-34). Stride-2 stages and 1×1 projection shortcuts are absorbed
//!   into the table's geometry exactly as the paper prints them.
//! * The accelerator has no stride support; strided layers are computed at
//!   stride 1 and subsampled off-chip, which is also how the paper counts
//!   operations (its AlexNet #MOp values only match at stride 1).

use super::layer::{ConvLayer, DenseLayer, Layer};

/// A network under evaluation.
#[derive(Debug, Clone)]
pub struct Network {
    /// Short identifier, e.g. `bc-cifar10`.
    pub id: &'static str,
    /// Human-readable name as used in the paper's tables.
    pub name: &'static str,
    /// Input image size (h × w), the tables' "img size" column.
    pub img: (usize, usize),
    /// All layers, convolutional and dense.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Convolution layers only (what runs on the accelerator).
    pub fn conv_layers(&self) -> impl Iterator<Item = &ConvLayer> {
        self.layers.iter().filter_map(|l| l.as_conv())
    }

    /// Total conv operations per frame (Eq. 7, over all instances).
    pub fn conv_ops(&self) -> u64 {
        self.conv_layers().map(|c| c.total_ops()).sum()
    }
}

fn conv(
    label: &'static str,
    k: usize,
    w: usize,
    h: usize,
    n_in: usize,
    n_out: usize,
    repeat: usize,
) -> Layer {
    Layer::Conv(ConvLayer { label, k, w, h, n_in, n_out, repeat, zero_pad: true })
}

fn dense(label: &'static str, n_in: usize, n_out: usize) -> Layer {
    Layer::Dense(DenseLayer { label, n_in, n_out, repeat: 1 })
}

/// BinaryConnect Cifar-10 network [22] (Table III, first block).
pub fn bc_cifar10() -> Network {
    Network {
        id: "bc-cifar10",
        name: "BC-Cifar-10",
        img: (32, 32),
        layers: vec![
            conv("1", 3, 32, 32, 3, 128, 1),
            conv("2", 3, 32, 32, 128, 128, 1),
            conv("3", 3, 16, 16, 128, 256, 1),
            conv("4", 3, 16, 16, 256, 256, 1),
            conv("5", 3, 8, 8, 256, 512, 1),
            conv("6", 3, 8, 8, 512, 512, 1),
            dense("7", 512 * 4 * 4, 1024),
            dense("8", 1024, 1024),
            dense("9", 1024, 10),
        ],
    }
}

/// BinaryConnect SVHN network [22].
pub fn bc_svhn() -> Network {
    Network {
        id: "bc-svhn",
        name: "BC-SVHN",
        img: (32, 32),
        layers: vec![
            conv("1", 3, 32, 32, 3, 128, 1),
            conv("2", 3, 16, 16, 128, 256, 1),
            conv("3", 3, 8, 8, 256, 512, 1),
            dense("4", 512 * 4 * 4, 1024),
        ],
    }
}

/// AlexNet [2] with binary weights [23]; the 11×11 first layer is split per
/// §IV-D into 2×(6×6) + 2×(5×5) kernel groups (rows 1ab / 1cd, ×4 each:
/// two filter groups × two split kernels).
pub fn alexnet() -> Network {
    Network {
        id: "alexnet",
        name: "AlexNet",
        img: (224, 224),
        layers: vec![
            conv("1ab", 6, 224, 224, 3, 48, 4),
            conv("1cd", 5, 224, 224, 3, 48, 4),
            conv("2", 5, 55, 55, 48, 128, 2),
            conv("3", 3, 27, 27, 128, 192, 2),
            conv("4", 3, 13, 13, 192, 192, 2),
            conv("5", 3, 13, 13, 192, 128, 2),
            dense("7", 256 * 13 * 13, 4096),
            dense("8", 4096, 4096),
            dense("9", 4096, 1000),
        ],
    }
}

/// ResNet-18 or ResNet-34 [4] with binary weights; `is34` selects the
/// per-row instance counts from the table's "×" column (e.g. "3/7").
fn resnet(is34: bool) -> Network {
    let q = |n18: usize, n34: usize| if is34 { n34 } else { n18 };
    Network {
        id: if is34 { "resnet34" } else { "resnet18" },
        name: if is34 { "ResNet-34" } else { "ResNet-18" },
        img: (224, 224),
        layers: vec![
            conv("1", 7, 224, 224, 3, 64, 1),
            conv("2-5", 3, 112, 112, 64, 64, q(5, 6)),
            conv("6", 3, 56, 56, 64, 128, 1),
            conv("7-9", 3, 56, 56, 128, 128, q(3, 7)),
            conv("10", 3, 28, 28, 128, 256, 1),
            conv("11-13", 3, 28, 28, 256, 256, q(3, 11)),
            conv("14", 3, 14, 14, 256, 512, 1),
            conv("15-17", 3, 14, 14, 512, 512, 3),
            dense("18", 512, 1000),
        ],
    }
}

/// ResNet-18.
pub fn resnet18() -> Network {
    resnet(false)
}

/// ResNet-34.
pub fn resnet34() -> Network {
    resnet(true)
}

/// VGG-13 or VGG-19 [54] with binary weights; `is19` selects instance
/// counts ("1/3", "2/4").
fn vgg(is19: bool) -> Network {
    let q = |n13: usize, n19: usize| if is19 { n19 } else { n13 };
    Network {
        id: if is19 { "vgg19" } else { "vgg13" },
        name: if is19 { "VGG-19" } else { "VGG-13" },
        img: (224, 224),
        layers: vec![
            conv("1", 3, 224, 224, 3, 64, 1),
            conv("2", 3, 224, 224, 64, 64, 1),
            conv("3", 3, 112, 112, 64, 128, 1),
            conv("4", 3, 112, 112, 128, 128, 1),
            conv("5", 3, 56, 56, 128, 256, 1),
            conv("6", 3, 56, 56, 256, 256, q(1, 3)),
            conv("7", 3, 28, 28, 256, 512, 1),
            conv("8", 3, 28, 28, 512, 512, q(1, 3)),
            conv("9-10", 3, 14, 14, 512, 512, q(2, 4)),
            dense("11", 512 * 7 * 7, 4096),
            dense("12", 4096, 4096),
            dense("13", 4096, 1000),
        ],
    }
}

/// VGG-13.
pub fn vgg13() -> Network {
    vgg(false)
}

/// VGG-19.
pub fn vgg19() -> Network {
    vgg(true)
}

/// The scene-labeling network of Cavigelli et al. [13]/[50] (Origami) on
/// 320×240 frames — the workload the paper's power simulations ran
/// (Stanford backgrounds, 8 classes) and the subject of Fig. 2.
pub fn scene_labeling() -> Network {
    Network {
        id: "scene-labeling",
        name: "SceneLabeling",
        img: (240, 320),
        layers: vec![
            conv("1", 7, 320, 240, 3, 16, 1),
            conv("2", 7, 160, 120, 16, 64, 1),
            conv("3", 7, 80, 60, 64, 256, 1),
            dense("4", 256, 8),
        ],
    }
}

/// All networks of Tables III–V, in table order.
pub fn all_networks() -> Vec<Network> {
    vec![bc_cifar10(), bc_svhn(), alexnet(), resnet18(), resnet34(), vgg13(), vgg19()]
}

/// Look a network up by id (as used by the CLI).
pub fn network(id: &str) -> Option<Network> {
    all_networks()
        .into_iter()
        .chain(std::iter::once(scene_labeling()))
        .find(|n| n.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_mop_columns() {
        // Spot-check per-instance MOp against Table III's #MOp column.
        let net = bc_cifar10();
        let mops: Vec<u64> =
            net.conv_layers().map(|c| (c.ops() as f64 / 1e6).round() as u64).collect();
        assert_eq!(mops, vec![7, 302, 151, 302, 151, 302]);

        let net = resnet18();
        let mops: Vec<u64> =
            net.conv_layers().map(|c| (c.ops() as f64 / 1e6).round() as u64).collect();
        assert_eq!(mops, vec![944, 925, 462, 925, 462, 925, 462, 925]);

        let net = vgg13();
        let mops: Vec<u64> =
            net.conv_layers().map(|c| (c.ops() as f64 / 1e6).round() as u64).collect();
        assert_eq!(mops, vec![173, 3699, 1850, 3699, 1850, 3699, 1850, 3699, 925]);
    }

    #[test]
    fn network_total_conv_ops_plausible() {
        // Totals implied by Table IV (E × EnEff): ResNet-18 ≈ 15 GOp,
        // ResNet-34 ≈ 28.8 GOp, VGG-13 ≈ 21.6 GOp, AlexNet ≈ 5–6.4 GOp.
        let gops = |n: Network| n.conv_ops() as f64 / 1e9;
        assert!((gops(resnet18()) - 15.3).abs() < 1.0, "{}", gops(resnet18()));
        assert!((gops(resnet34()) - 27.3).abs() < 2.0);
        assert!((gops(vgg13()) - 22.4).abs() < 1.5);
        assert!((gops(vgg19()) - 39.0).abs() < 3.0);
        assert!((gops(alexnet()) - 6.4).abs() < 0.8);
        assert!((gops(bc_cifar10()) - 1.215).abs() < 0.05);
        assert!((gops(bc_svhn()) - 0.309).abs() < 0.02);
    }

    #[test]
    fn lookup_by_id() {
        assert!(network("bc-cifar10").is_some());
        assert!(network("resnet34").is_some());
        assert!(network("scene-labeling").is_some());
        assert!(network("nope").is_none());
    }

    #[test]
    fn resnet_variants_differ_only_in_repeats() {
        let (a, b) = (resnet18(), resnet34());
        assert_eq!(a.layers.len(), b.layers.len());
        assert!(b.conv_ops() > a.conv_ops());
    }
}
