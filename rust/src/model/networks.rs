//! The networks evaluated in the paper's Table III, encoded row-by-row.
//!
//! Notes on fidelity:
//!
//! * **AlexNet 11×11 split (§IV-D):** the first layer's 11×11 kernels are
//!   decomposed into 2×(6×6) + 2×(5×5) kernels with one overlapping centre
//!   pixel, avoiding extra 1×1 convolutions by choosing the overlap weight;
//!   the identity sums are subtracted off-chip. The table therefore lists
//!   rows "1ab" (6×6, ×4) and "1cd" (5×5, ×4). The printed `h_k = 4` for
//!   row 1cd is a typo — the split produces 5×5 kernels and only k = 5
//!   reproduces the row's 361 MOp.
//! * **ResNet-18/34 and VGG-13/19** share rows; the "×" column holds the
//!   per-variant instance counts (e.g. "5/6" → 5 for ResNet-18, 6 for
//!   ResNet-34). Stride-2 stages and 1×1 projection shortcuts are absorbed
//!   into the table's geometry exactly as the paper prints them.
//! * The accelerator has no stride support; strided layers are computed at
//!   stride 1 and subsampled off-chip, which is also how the paper counts
//!   operations (its AlexNet #MOp values only match at stride 1).

use super::graph::{NetworkBuilder, NetworkGraph, NodeId, Weights};
use super::layer::{ConvLayer, DenseLayer, Layer};
use crate::testkit::Gen;

/// A network under evaluation.
#[derive(Debug, Clone)]
pub struct Network {
    /// Short identifier, e.g. `bc-cifar10`.
    pub id: &'static str,
    /// Human-readable name as used in the paper's tables.
    pub name: &'static str,
    /// Input image size (h × w), the tables' "img size" column.
    pub img: (usize, usize),
    /// All layers, convolutional and dense.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Convolution layers only (what runs on the accelerator).
    pub fn conv_layers(&self) -> impl Iterator<Item = &ConvLayer> {
        self.layers.iter().filter_map(|l| l.as_conv())
    }

    /// Total conv operations per frame (Eq. 7, over all instances).
    pub fn conv_ops(&self) -> u64 {
        self.conv_layers().map(|c| c.total_ops()).sum()
    }
}

fn conv(
    label: &'static str,
    k: usize,
    w: usize,
    h: usize,
    n_in: usize,
    n_out: usize,
    repeat: usize,
) -> Layer {
    Layer::Conv(ConvLayer { label, k, w, h, n_in, n_out, repeat, zero_pad: true })
}

fn dense(label: &'static str, n_in: usize, n_out: usize) -> Layer {
    Layer::Dense(DenseLayer { label, n_in, n_out, repeat: 1 })
}

/// BinaryConnect Cifar-10 network [22] (Table III, first block).
pub fn bc_cifar10() -> Network {
    Network {
        id: "bc-cifar10",
        name: "BC-Cifar-10",
        img: (32, 32),
        layers: vec![
            conv("1", 3, 32, 32, 3, 128, 1),
            conv("2", 3, 32, 32, 128, 128, 1),
            conv("3", 3, 16, 16, 128, 256, 1),
            conv("4", 3, 16, 16, 256, 256, 1),
            conv("5", 3, 8, 8, 256, 512, 1),
            conv("6", 3, 8, 8, 512, 512, 1),
            dense("7", 512 * 4 * 4, 1024),
            dense("8", 1024, 1024),
            dense("9", 1024, 10),
        ],
    }
}

/// BinaryConnect SVHN network [22].
pub fn bc_svhn() -> Network {
    Network {
        id: "bc-svhn",
        name: "BC-SVHN",
        img: (32, 32),
        layers: vec![
            conv("1", 3, 32, 32, 3, 128, 1),
            conv("2", 3, 16, 16, 128, 256, 1),
            conv("3", 3, 8, 8, 256, 512, 1),
            dense("4", 512 * 4 * 4, 1024),
        ],
    }
}

/// AlexNet [2] with binary weights [23]; the 11×11 first layer is split per
/// §IV-D into 2×(6×6) + 2×(5×5) kernel groups (rows 1ab / 1cd, ×4 each:
/// two filter groups × two split kernels).
pub fn alexnet() -> Network {
    Network {
        id: "alexnet",
        name: "AlexNet",
        img: (224, 224),
        layers: vec![
            conv("1ab", 6, 224, 224, 3, 48, 4),
            conv("1cd", 5, 224, 224, 3, 48, 4),
            conv("2", 5, 55, 55, 48, 128, 2),
            conv("3", 3, 27, 27, 128, 192, 2),
            conv("4", 3, 13, 13, 192, 192, 2),
            conv("5", 3, 13, 13, 192, 128, 2),
            dense("7", 256 * 13 * 13, 4096),
            dense("8", 4096, 4096),
            dense("9", 4096, 1000),
        ],
    }
}

/// ResNet-18 or ResNet-34 [4] with binary weights; `is34` selects the
/// per-row instance counts from the table's "×" column (e.g. "3/7").
fn resnet(is34: bool) -> Network {
    let q = |n18: usize, n34: usize| if is34 { n34 } else { n18 };
    Network {
        id: if is34 { "resnet34" } else { "resnet18" },
        name: if is34 { "ResNet-34" } else { "ResNet-18" },
        img: (224, 224),
        layers: vec![
            conv("1", 7, 224, 224, 3, 64, 1),
            conv("2-5", 3, 112, 112, 64, 64, q(5, 6)),
            conv("6", 3, 56, 56, 64, 128, 1),
            conv("7-9", 3, 56, 56, 128, 128, q(3, 7)),
            conv("10", 3, 28, 28, 128, 256, 1),
            conv("11-13", 3, 28, 28, 256, 256, q(3, 11)),
            conv("14", 3, 14, 14, 256, 512, 1),
            conv("15-17", 3, 14, 14, 512, 512, 3),
            dense("18", 512, 1000),
        ],
    }
}

/// ResNet-18.
pub fn resnet18() -> Network {
    resnet(false)
}

/// ResNet-34.
pub fn resnet34() -> Network {
    resnet(true)
}

/// VGG-13 or VGG-19 [54] with binary weights; `is19` selects instance
/// counts ("1/3", "2/4").
fn vgg(is19: bool) -> Network {
    let q = |n13: usize, n19: usize| if is19 { n19 } else { n13 };
    Network {
        id: if is19 { "vgg19" } else { "vgg13" },
        name: if is19 { "VGG-19" } else { "VGG-13" },
        img: (224, 224),
        layers: vec![
            conv("1", 3, 224, 224, 3, 64, 1),
            conv("2", 3, 224, 224, 64, 64, 1),
            conv("3", 3, 112, 112, 64, 128, 1),
            conv("4", 3, 112, 112, 128, 128, 1),
            conv("5", 3, 56, 56, 128, 256, 1),
            conv("6", 3, 56, 56, 256, 256, q(1, 3)),
            conv("7", 3, 28, 28, 256, 512, 1),
            conv("8", 3, 28, 28, 512, 512, q(1, 3)),
            conv("9-10", 3, 14, 14, 512, 512, q(2, 4)),
            dense("11", 512 * 7 * 7, 4096),
            dense("12", 4096, 4096),
            dense("13", 4096, 1000),
        ],
    }
}

/// VGG-13.
pub fn vgg13() -> Network {
    vgg(false)
}

/// VGG-19.
pub fn vgg19() -> Network {
    vgg(true)
}

/// The scene-labeling network of Cavigelli et al. [13]/[50] (Origami) on
/// 320×240 frames — the workload the paper's power simulations ran
/// (Stanford backgrounds, 8 classes) and the subject of Fig. 2.
pub fn scene_labeling() -> Network {
    Network {
        id: "scene-labeling",
        name: "SceneLabeling",
        img: (240, 320),
        layers: vec![
            conv("1", 7, 320, 240, 3, 16, 1),
            conv("2", 7, 160, 120, 16, 64, 1),
            conv("3", 7, 80, 60, 64, 256, 1),
            dense("4", 256, 8),
        ],
    }
}

/// All networks of Tables III–V, in table order.
pub fn all_networks() -> Vec<Network> {
    vec![bc_cifar10(), bc_svhn(), alexnet(), resnet18(), resnet34(), vgg13(), vgg19()]
}

/// Every network id [`network`] accepts, in table order — echoed by the
/// CLI on an unknown `--net` (the network analog of
/// [`crate::engine::EngineKind::ACCEPTED`]).
pub const ACCEPTED: &[&str] = &[
    "bc-cifar10",
    "bc-svhn",
    "alexnet",
    "resnet18",
    "resnet34",
    "vgg13",
    "vgg19",
    "scene-labeling",
];

/// Look a network up by id (as used by the CLI).
pub fn network(id: &str) -> Option<Network> {
    all_networks()
        .into_iter()
        .chain(std::iter::once(scene_labeling()))
        .find(|n| n.id == id)
}

// ---------------------------------------------------------------------
// Graph encodings — the runnable form of the non-chain networks.
//
// The Table-III rows above are *op-count descriptors*; the functions
// below encode the same topologies as executable `NetworkGraph`s:
// AlexNet's §IV-D 11×11 split (4 parallel partial convolutions per
// filter group, summed off-chip, groups concatenated) and
// ResNet-18/34's residual blocks with 1×1 projection shortcuts.
// Topology-faithful, with the deployment's quantization semantics made
// explicit: each partial conv is its own chip pass, so the off-chip
// recombination adds the chip's streamed Q2.9 outputs (per-pass
// rounding/saturation included), and AlexNet's conv3–5 stay
// group-local exactly as Table III tabulates them (the original
// network's conv3 crosses groups; the table's op counts do not).
// Strided layers run at stride 1 and subsample off-chip — exactly how
// the paper counts their operations on a stride-less accelerator — and
// the 3×3/2 max-pools are approximated by the host's 2×2/2 pool.
// `width_div` scales every channel width down (floor 1) so the
// cycle-accurate engine can execute the full topology in tests.
// ---------------------------------------------------------------------

/// One ResNet basic block: conv3×3 → ReLU → conv3×3, plus the identity
/// (or, when the block changes width or stride, a 1×1 projection)
/// shortcut, joined by a residual add and a final ReLU.
fn residual_block(
    b: &mut NetworkBuilder,
    g: &mut Gen,
    x: NodeId,
    n_in: usize,
    n_out: usize,
    downsample: bool,
    label: &str,
) -> NodeId {
    let mut y = b.conv(&format!("{label}.conv1"), x, true, Weights::seeded(g, n_out, n_in, 3));
    if downsample {
        y = b.subsample2(y); // the stride-2 conv, subsampled off-chip
    }
    y = b.relu(y);
    y = b.conv(&format!("{label}.conv2"), y, true, Weights::seeded(g, n_out, n_out, 3));
    let shortcut = if n_in != n_out || downsample {
        let mut s = b.conv(&format!("{label}.proj"), x, true, Weights::seeded(g, n_out, n_in, 1));
        if downsample {
            s = b.subsample2(s);
        }
        s
    } else {
        x
    };
    let sum = b.add(&format!("{label}.add"), &[y, shortcut]);
    b.relu(sum)
}

fn resnet_graph(is34: bool, seed: u64, width_div: usize) -> NetworkGraph {
    let div = width_div.max(1);
    let d = |n: usize| (n / div).max(1);
    let mut g = Gen::new(seed);
    let mut b = NetworkBuilder::new(if is34 { "resnet34" } else { "resnet18" }, 3);
    // conv1: 7×7 stride 2 (stride off-chip) + ReLU + 3×3/2 max-pool.
    let mut x = b.conv("conv1", b.input(), true, Weights::seeded(&mut g, d(64), 3, 7));
    x = b.subsample2(x);
    x = b.relu(x);
    x = b.maxpool2(x);
    let stages: [(usize, usize); 4] = if is34 {
        [(64, 3), (128, 4), (256, 6), (512, 3)]
    } else {
        [(64, 2), (128, 2), (256, 2), (512, 2)]
    };
    let mut c_in = d(64);
    for (si, &(width, blocks)) in stages.iter().enumerate() {
        let w = d(width);
        for bi in 0..blocks {
            let down = si > 0 && bi == 0;
            x = residual_block(&mut b, &mut g, x, c_in, w, down, &format!("s{}b{}", si + 1, bi + 1));
            c_in = w;
        }
    }
    b.build(x)
}

/// ResNet-18 as a runnable graph (residual adds, projection shortcuts,
/// stride-2 subsampling), seeded synthetic weights.
pub fn resnet18_graph(seed: u64) -> NetworkGraph {
    resnet_graph(false, seed, 1)
}

/// ResNet-34 as a runnable graph.
pub fn resnet34_graph(seed: u64) -> NetworkGraph {
    resnet_graph(true, seed, 1)
}

/// ResNet-18 with every channel width divided by `width_div` (floor 1):
/// the full topology at a size the cycle-accurate engine can execute in
/// tests.
pub fn resnet18_graph_scaled(seed: u64, width_div: usize) -> NetworkGraph {
    resnet_graph(false, seed, width_div)
}

fn alexnet_graph_with(seed: u64, width_div: usize) -> NetworkGraph {
    let div = width_div.max(1);
    let d = |n: usize| (n / div).max(1);
    let mut g = Gen::new(seed);
    let mut b = NetworkBuilder::new("alexnet", 3);
    let input = b.input();
    let mut groups: Vec<NodeId> = Vec::new();
    for gi in 0..2 {
        // §IV-D: the 11×11 kernels decompose into 2×(6×6) + 2×(5×5)
        // partial convolutions (rows 1ab / 1cd of Table III, ×4 per
        // group) that recombine off-chip through the residual Add.
        // Each partial is a separate chip pass, so what recombines is
        // the chip's *streamed Q2.9 output* (per-partial rounding and
        // saturation are inherent to the deployment, not an encoding
        // shortcut); the shared α rides on every partial, the bias on
        // the first only, so the recombined sum carries β once.
        let n48 = d(48);
        let parts: Vec<NodeId> = [("1a", 6usize), ("1b", 6), ("1c", 5), ("1d", 5)]
            .iter()
            .enumerate()
            .map(|(pi, &(lbl, k))| {
                let beta = if pi == 0 { 0.01 } else { 0.0 };
                let w = Weights::seeded_scaled(&mut g, n48, 3, k, 0.05, beta);
                b.conv(&format!("g{gi}.{lbl}"), input, true, w)
            })
            .collect();
        let mut x = b.add(&format!("g{gi}.split-sum"), &parts);
        // Layer 1's stride 4 = two off-chip stride-2 subsamples.
        x = b.subsample2(x);
        x = b.subsample2(x);
        x = b.relu(x);
        x = b.maxpool2(x);
        x = b.conv(&format!("g{gi}.conv2"), x, true, Weights::seeded(&mut g, d(128), n48, 5));
        x = b.relu(x);
        x = b.maxpool2(x);
        x = b.conv(&format!("g{gi}.conv3"), x, true, Weights::seeded(&mut g, d(192), d(128), 3));
        x = b.relu(x);
        x = b.conv(&format!("g{gi}.conv4"), x, true, Weights::seeded(&mut g, d(192), d(192), 3));
        x = b.relu(x);
        x = b.conv(&format!("g{gi}.conv5"), x, true, Weights::seeded(&mut g, d(128), d(192), 3));
        x = b.relu(x);
        x = b.maxpool2(x);
        groups.push(x);
    }
    let out = b.concat("groups", &groups);
    b.build(out)
}

/// AlexNet as a runnable graph: the 11×11 split of §IV-D (4 parallel
/// partial convolutions per filter group, summed off-chip), two filter
/// groups concatenated at the end, seeded synthetic weights.
pub fn alexnet_graph(seed: u64) -> NetworkGraph {
    alexnet_graph_with(seed, 1)
}

/// AlexNet with every channel width divided by `width_div` (floor 1).
pub fn alexnet_graph_scaled(seed: u64, width_div: usize) -> NetworkGraph {
    alexnet_graph_with(seed, width_div)
}

/// Whether a network id has a graph encoding — the weight-free mirror
/// of [`graph_network`], for callers (the CLI's `networks` listing)
/// that only need the flag, not the multi-megabit seeded kernels.
pub fn has_graph(id: &str) -> bool {
    matches!(id, "alexnet" | "resnet18" | "resnet34")
}

/// Whether a descriptor's conv rows form a simple chain — the
/// weight-free mirror of `SessionLayerSpec::synthetic_network`'s
/// channel-chaining check (which also materializes seeded kernels for
/// every layer; this flag costs nothing).
pub fn is_simple_chain(net: &Network) -> bool {
    let mut prev: Option<usize> = None;
    let mut any = false;
    for c in net.conv_layers() {
        any = true;
        for rep in 0..c.repeat.max(1) {
            let n_in = if rep == 0 { c.n_in } else { c.n_out };
            if let Some(p) = prev {
                if p != n_in {
                    return false;
                }
            }
            prev = Some(c.n_out);
        }
    }
    any
}

/// The runnable graph encoding of a network id, if one exists. Chain
/// networks run through [`SessionLayerSpec::synthetic_network`] instead
/// and return `None` here; the CLI consults both to flag which networks
/// are runnable.
///
/// [`SessionLayerSpec::synthetic_network`]: crate::coordinator::SessionLayerSpec::synthetic_network
pub fn graph_network(id: &str, seed: u64) -> Option<NetworkGraph> {
    if !has_graph(id) {
        return None;
    }
    match id {
        "alexnet" => Some(alexnet_graph(seed)),
        "resnet18" => Some(resnet18_graph(seed)),
        "resnet34" => Some(resnet34_graph(seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_mop_columns() {
        // Spot-check per-instance MOp against Table III's #MOp column.
        let net = bc_cifar10();
        let mops: Vec<u64> =
            net.conv_layers().map(|c| (c.ops() as f64 / 1e6).round() as u64).collect();
        assert_eq!(mops, vec![7, 302, 151, 302, 151, 302]);

        let net = resnet18();
        let mops: Vec<u64> =
            net.conv_layers().map(|c| (c.ops() as f64 / 1e6).round() as u64).collect();
        assert_eq!(mops, vec![944, 925, 462, 925, 462, 925, 462, 925]);

        let net = vgg13();
        let mops: Vec<u64> =
            net.conv_layers().map(|c| (c.ops() as f64 / 1e6).round() as u64).collect();
        assert_eq!(mops, vec![173, 3699, 1850, 3699, 1850, 3699, 1850, 3699, 925]);
    }

    #[test]
    fn network_total_conv_ops_plausible() {
        // Totals implied by Table IV (E × EnEff): ResNet-18 ≈ 15 GOp,
        // ResNet-34 ≈ 28.8 GOp, VGG-13 ≈ 21.6 GOp, AlexNet ≈ 5–6.4 GOp.
        let gops = |n: Network| n.conv_ops() as f64 / 1e9;
        assert!((gops(resnet18()) - 15.3).abs() < 1.0, "{}", gops(resnet18()));
        assert!((gops(resnet34()) - 27.3).abs() < 2.0);
        assert!((gops(vgg13()) - 22.4).abs() < 1.5);
        assert!((gops(vgg19()) - 39.0).abs() < 3.0);
        assert!((gops(alexnet()) - 6.4).abs() < 0.8);
        assert!((gops(bc_cifar10()) - 1.215).abs() < 0.05);
        assert!((gops(bc_svhn()) - 0.309).abs() < 0.02);
    }

    #[test]
    fn lookup_by_id() {
        assert!(network("bc-cifar10").is_some());
        assert!(network("resnet34").is_some());
        assert!(network("scene-labeling").is_some());
        assert!(network("nope").is_none());
    }

    #[test]
    fn accepted_ids_round_trip_through_lookup() {
        for &id in ACCEPTED {
            assert!(network(id).is_some(), "ACCEPTED lists unknown id '{id}'");
        }
        assert_eq!(ACCEPTED.len(), all_networks().len() + 1); // + scene-labeling
    }

    #[test]
    fn graph_encodings_compile_with_the_expected_conv_counts() {
        // ResNet-18: conv1 + 8 blocks × 2 convs + 3 projections = 20.
        let plan = resnet18_graph(1).compile().unwrap();
        assert_eq!(plan.convs.len(), 20);
        assert_eq!(plan.n_in, 3);
        // ResNet-34: conv1 + 16 blocks × 2 + 3 projections = 36.
        let plan = resnet34_graph(1).compile().unwrap();
        assert_eq!(plan.convs.len(), 36);
        // AlexNet: 2 groups × (4 split partials + conv2..5) = 16.
        let plan = alexnet_graph(1).compile().unwrap();
        assert_eq!(plan.convs.len(), 16);
    }

    #[test]
    fn graph_encodings_walk_scaled_frames_end_to_end() {
        // 3×32×32 through ResNet-18: subsample + pool + 3 strided
        // stages leave a 1×1 map of 512 channels.
        let plan = resnet18_graph(2).compile().unwrap();
        assert_eq!(plan.walk_shapes(3, 32, 32).unwrap(), (512, 1, 1));
        // AlexNet: two 128-channel groups concatenated.
        let plan = alexnet_graph(2).compile().unwrap();
        assert_eq!(plan.walk_shapes(3, 32, 32).unwrap(), (256, 1, 1));
        // Width scaling divides channels, floor 1.
        let plan = resnet18_graph_scaled(2, 8).compile().unwrap();
        assert_eq!(plan.walk_shapes(3, 32, 32).unwrap(), (64, 1, 1));
        assert_eq!(plan.convs[0].kernels.n_out, 8);
    }

    #[test]
    fn graph_network_covers_exactly_the_non_chain_ids() {
        assert!(graph_network("alexnet", 1).is_some());
        assert!(graph_network("resnet18", 1).is_some());
        assert!(graph_network("resnet34", 1).is_some());
        assert!(graph_network("bc-cifar10", 1).is_none());
        assert!(graph_network("nope", 1).is_none());
        // The weight-free flag must never drift from the constructor.
        for &id in ACCEPTED {
            assert_eq!(has_graph(id), graph_network(id, 1).is_some(), "{id}");
        }
    }

    #[test]
    fn is_simple_chain_mirrors_the_session_chain_lowering() {
        use crate::coordinator::SessionLayerSpec;
        let mut nets = all_networks();
        nets.push(scene_labeling());
        for n in &nets {
            assert_eq!(
                is_simple_chain(n),
                SessionLayerSpec::synthetic_network(n, 1).is_ok(),
                "weight-free chain flag drifted from synthetic_network on {}",
                n.id
            );
        }
        // A conv-less descriptor is not runnable as a chain.
        let dense = Network { id: "d", name: "D", img: (8, 8), layers: vec![] };
        assert!(!is_simple_chain(&dense));
    }

    #[test]
    fn resnet_variants_differ_only_in_repeats() {
        let (a, b) = (resnet18(), resnet34());
        assert_eq!(a.layers.len(), b.layers.len());
        assert!(b.conv_ops() > a.conv_ops());
    }
}
