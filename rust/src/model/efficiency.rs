//! The paper's throughput-efficiency model (§IV-A, Eqs. 8–11) and the
//! per-layer / per-network evaluation engine behind Tables III, IV and V.
//!
//! `Θ_real = Θ_peak · η_tile · η_chIdle · η_border` (Eq. 8), with
//!
//! * `η_tile` (Eq. 9) — vertical image tiling: the image-window memory
//!   holds `h_max = 1024 / n_ch` rows per channel; taller images split
//!   into tiles that re-load `h_k − 1` overlap rows.
//! * `η_chIdle` (Eq. 10) — input-channel idling when a block has fewer
//!   than `n_ch` input channels (affects throughput, *not* energy: the
//!   silenced SoPs stop toggling, captured by `P̃_real`).
//! * `η_border` (Eq. 11) — the output shrink of non-zero-padded layers.
//!
//! Cross-validation: `rust/tests/efficiency_vs_sim.rs` checks η_tile and
//! the per-block cycle counts of this analytic model against the
//! cycle-accurate simulator on small workloads.

use super::layer::{ConvLayer, KernelMode};
use super::networks::Network;
use crate::power::{ArchId, CorePowerModel, IoPowerModel};

/// Eq. 9 — tiling efficiency for image height `h_im`, window capacity
/// `h_max` rows and kernel size `k`.
pub fn eta_tile(h_im: usize, h_max: usize, k: usize) -> f64 {
    let tiles = h_im.div_ceil(h_max);
    h_im as f64 / (h_im + (tiles - 1) * (k - 1)) as f64
}

/// Eq. 10 — channel-idling efficiency. The chip always walks all `n_ch`
/// input-channel slots per pixel; a layer with `n_in` input channels over
/// `⌈n_in/n_ch⌉` blocks keeps the SoPs busy for only this fraction of
/// cycles.
pub fn eta_ch_idle(n_in: usize, n_ch: usize) -> f64 {
    let blocks = n_in.div_ceil(n_ch);
    n_in as f64 / (n_ch * blocks) as f64
}

/// Eq. 11 — border efficiency. Zero-padded layers lose nothing (the halo
/// pixels are synthesized on-chip); non-padded layers compute a smaller
/// output, and the paper additionally charges the preload of the first
/// `h_k − 1` columns.
pub fn eta_border(zero_pad: bool, k: usize, w_im: usize, h_im: usize) -> f64 {
    if zero_pad {
        1.0
    } else {
        (1.0 - (k - 1) as f64 / w_im as f64) * (1.0 - (k - 1) as f64 / h_im as f64)
    }
}

/// An operating corner: architecture + core supply voltage.
#[derive(Debug, Clone, Copy)]
pub struct Corner {
    /// Architecture variant.
    pub arch: ArchId,
    /// Core supply voltage (V).
    pub v: f64,
}

impl Corner {
    /// The paper's energy-optimal corner (0.6 V, Table IV).
    pub fn energy_optimal() -> Corner {
        Corner { arch: ArchId::Bin32Multi, v: 0.6 }
    }

    /// The paper's throughput-optimal corner (1.2 V, Table V).
    pub fn throughput_optimal() -> Corner {
        Corner { arch: ArchId::Bin32Multi, v: 1.2 }
    }
}

/// One evaluated Table-III row (a conv layer at a corner). Energies/times
/// are **per instance**; multiply by `repeat` for network totals.
#[derive(Debug, Clone)]
pub struct LayerEval {
    /// Row label.
    pub label: &'static str,
    /// Kernel size.
    pub k: usize,
    /// Hardware slot mode.
    pub mode: KernelMode,
    /// Instances of this layer.
    pub repeat: usize,
    /// Peak useful throughput at the corner (Op/s).
    pub theta_peak: f64,
    /// Eq. 9.
    pub eta_tile: f64,
    /// Eq. 10.
    pub eta_idle: f64,
    /// Eq. 11.
    pub eta_border: f64,
    /// Normalized power vs. fully-active convolving (Table III's P̃_real).
    pub p_real: f64,
    /// Eq. 8 actual throughput (Op/s).
    pub theta_real: f64,
    /// Core power while running this layer (W).
    pub p_core: f64,
    /// Core energy efficiency (Op/s/W = Op/J).
    pub en_eff: f64,
    /// Operations per instance (Eq. 7).
    pub ops: u64,
    /// Execution time per instance (s).
    pub t: f64,
    /// Core energy per instance (J).
    pub energy: f64,
}

/// Network-level aggregation (a Table IV / V row).
#[derive(Debug, Clone)]
pub struct NetworkEval {
    /// Network id.
    pub id: &'static str,
    /// Network display name.
    pub name: &'static str,
    /// Input image size (h, w).
    pub img: (usize, usize),
    /// Corner evaluated.
    pub corner: Corner,
    /// Per-layer rows (conv layers only).
    pub rows: Vec<LayerEval>,
    /// Total conv operations per frame.
    pub total_ops: u64,
    /// Frame time (s), conv layers only (the paper excludes FC layers).
    pub frame_time: f64,
    /// Core energy per frame (J).
    pub frame_energy: f64,
    /// Average throughput Θ̄ = ΣOp / Σt (Op/s).
    pub avg_theta: f64,
    /// Average core energy efficiency ΣOp / ΣE (Op/J).
    pub avg_en_eff: f64,
    /// Frames per second.
    pub fps: f64,
    /// Average device power (core + pads) over the frame (W).
    pub avg_device_power: f64,
}

/// Evaluate one conv layer at a corner (one Table III row).
pub fn evaluate_layer(layer: &ConvLayer, corner: Corner) -> LayerEval {
    let core = CorePowerModel::new(corner.arch);
    let n_ch = corner.arch.n_ch();
    let h_max = crate::power::calib::IMAGE_MEM_ROWS / n_ch;

    let theta_peak = core.theta_peak(corner.v, layer.k);
    let e_tile = eta_tile(layer.h, h_max, layer.k);
    let e_idle = eta_ch_idle(layer.n_in, n_ch);
    let e_border = eta_border(layer.zero_pad, layer.k, layer.w, layer.h);
    let theta_real = theta_peak * e_tile * e_idle * e_border;

    let p_real = CorePowerModel::p_real(e_idle);
    let p_core = core.p_core(corner.v, layer.k);
    let en_eff = theta_real / (p_real * p_core);

    let ops = layer.ops();
    let t = ops as f64 / theta_real;
    let energy = ops as f64 / en_eff;

    LayerEval {
        label: layer.label,
        k: layer.k,
        mode: layer.mode(),
        repeat: layer.repeat,
        theta_peak,
        eta_tile: e_tile,
        eta_idle: e_idle,
        eta_border: e_border,
        p_real,
        theta_real,
        p_core,
        en_eff,
        ops,
        t,
        energy,
    }
}

/// Evaluate a full network at a corner (a Table IV / V row).
pub fn evaluate_network(net: &Network, corner: Corner) -> NetworkEval {
    let rows: Vec<LayerEval> = net.conv_layers().map(|l| evaluate_layer(l, corner)).collect();
    let total_ops: u64 = rows.iter().map(|r| r.ops * r.repeat as u64).sum();
    let frame_time: f64 = rows.iter().map(|r| r.t * r.repeat as f64).sum();
    let frame_energy: f64 = rows.iter().map(|r| r.energy * r.repeat as f64).sum();

    // Device power: pads run whenever the chip streams; average over layer
    // times with the per-mode stream configuration.
    let core = CorePowerModel::new(corner.arch);
    let io =
        if corner.arch.binary_weights() { IoPowerModel::binary() } else { IoPowerModel::q29() };
    let f = core.freq(corner.v);
    let io_energy: f64 = rows
        .iter()
        .map(|r| {
            io.power_for_kernel(f, r.k, corner.arch.multi_kernel()) * r.t * r.repeat as f64
        })
        .sum();

    NetworkEval {
        id: net.id,
        name: net.name,
        img: net.img,
        corner,
        total_ops,
        avg_theta: total_ops as f64 / frame_time,
        avg_en_eff: total_ops as f64 / frame_energy,
        fps: 1.0 / frame_time,
        avg_device_power: (frame_energy + io_energy) / frame_time,
        frame_time,
        frame_energy,
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::networks;

    fn close(a: f64, b: f64, rel: f64) -> bool {
        (a - b).abs() / b.abs() < rel
    }

    #[test]
    fn eta_tile_matches_table3_values() {
        // h_max = 32 for the 32×32 chip.
        assert!(close(eta_tile(224, 32, 7), 0.86, 0.01)); // ResNet L1
        assert!(close(eta_tile(224, 32, 3), 0.95, 0.01)); // VGG rows
        assert!(close(eta_tile(112, 32, 3), 0.95, 0.01));
        assert!(close(eta_tile(56, 32, 3), 0.97, 0.01));
        assert!((eta_tile(32, 32, 3) - 1.0).abs() < 1e-12); // BC rows
        assert!((eta_tile(28, 32, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eta_idle_matches_table3_values() {
        assert!(close(eta_ch_idle(3, 32), 0.09, 0.05)); // first layers
        assert!(close(eta_ch_idle(48, 32), 0.75, 1e-9)); // AlexNet L2
        assert!((eta_ch_idle(128, 32) - 1.0).abs() < 1e-12);
        assert!((eta_ch_idle(64, 32) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eta_border_zero_padded_is_one() {
        assert_eq!(eta_border(true, 7, 224, 224), 1.0);
        let e = eta_border(false, 7, 32, 32);
        assert!(close(e, (26.0 / 32.0) * (26.0 / 32.0), 1e-12));
    }

    #[test]
    fn bc_cifar10_layer2_row() {
        // Table III: Θ_real 20.1 GOp/s, EnEff 59.2 TOp/s/W, t 15 ms,
        // E 5.1 µJ (the paper's "mJ" column header is a unit typo — the
        // rows are only self-consistent as µJ, see DESIGN.md §5).
        let net = networks::bc_cifar10();
        let l2 = net.conv_layers().nth(1).unwrap();
        let r = evaluate_layer(l2, Corner::energy_optimal());
        assert!(close(r.theta_real / 1e9, 20.1, 0.01), "{}", r.theta_real / 1e9);
        assert!(close(r.en_eff / 1e12, 59.2, 0.01), "{}", r.en_eff / 1e12);
        assert!(close(r.t * 1e3, 15.0, 0.01));
        assert!(close(r.energy * 1e6, 5.1, 0.02));
    }

    #[test]
    fn bc_cifar10_first_layer_row() {
        // Table III row 1: Θ_real 1.9 GOp/s, EnEff 16.0 TOp/s/W, P̃ 0.35.
        let net = networks::bc_cifar10();
        let l1 = net.conv_layers().next().unwrap();
        let r = evaluate_layer(l1, Corner::energy_optimal());
        assert!(close(r.theta_real / 1e9, 1.9, 0.02), "{}", r.theta_real / 1e9);
        assert!(close(r.p_real, 0.35, 0.01));
        assert!(close(r.en_eff / 1e12, 16.0, 0.02), "{}", r.en_eff / 1e12);
    }

    #[test]
    fn table4_bc_cifar10() {
        // Table IV: EnEff 56.7 TOp/s/W, Θ 19.1 GOp/s, 15.8 FPS, E 20.8 µJ.
        let e = evaluate_network(&networks::bc_cifar10(), Corner::energy_optimal());
        assert!(close(e.frame_energy * 1e6, 20.8, 0.02), "{}", e.frame_energy * 1e6);
        assert!(close(e.fps, 15.8, 0.02), "{}", e.fps);
        assert!(close(e.avg_theta / 1e9, 19.1, 0.02), "{}", e.avg_theta / 1e9);
        assert!(close(e.avg_en_eff / 1e12, 56.7, 0.05), "{}", e.avg_en_eff / 1e12);
    }

    #[test]
    fn table5_bc_cifar10() {
        // Table V (1.2 V): Θ 525.4 GOp/s, 434.8 FPS.
        let e = evaluate_network(&networks::bc_cifar10(), Corner::throughput_optimal());
        assert!(close(e.avg_theta / 1e9, 525.4, 0.02), "{}", e.avg_theta / 1e9);
        assert!(close(e.fps, 434.8, 0.02), "{}", e.fps);
        // EnEff 8.6 TOp/s/W — interpolated Ceff at 1.2 V, allow 15%.
        assert!(close(e.avg_en_eff / 1e12, 8.6, 0.15), "{}", e.avg_en_eff / 1e12);
    }

    #[test]
    fn table4_resnet18() {
        // ResNet-18 @0.6 V: EnEff 48.1 TOp/s/W, Θ 16.2 GOp/s, 1.1 FPS,
        // E 311 µJ.
        let e = evaluate_network(&networks::resnet18(), Corner::energy_optimal());
        assert!(close(e.avg_en_eff / 1e12, 48.1, 0.05), "{}", e.avg_en_eff / 1e12);
        assert!(close(e.avg_theta / 1e9, 16.2, 0.05), "{}", e.avg_theta / 1e9);
        assert!(close(e.fps, 1.1, 0.05), "{}", e.fps);
        assert!(close(e.frame_energy * 1e6, 311.0, 0.05), "{}", e.frame_energy * 1e6);
    }

    #[test]
    fn table4_vgg19() {
        // VGG-19 @0.6 V: EnEff 55.9, Θ 18.9, 0.5 FPS, E 683.7 µJ.
        let e = evaluate_network(&networks::vgg19(), Corner::energy_optimal());
        assert!(close(e.avg_en_eff / 1e12, 55.9, 0.03), "{}", e.avg_en_eff / 1e12);
        assert!(close(e.avg_theta / 1e9, 18.9, 0.03));
        assert!(close(e.frame_energy * 1e6, 683.7, 0.04), "{}", e.frame_energy * 1e6);
    }

    #[test]
    fn device_power_at_throughput_corner_near_153mw() {
        // §IV-D: "a chip power of just 153 mW" in the throughput corner.
        // Our device average (core + pads over the frame) lands in the same
        // regime for the mostly-3×3 networks; check order of magnitude and
        // that the core share is small vs pads at 1.2 V.
        let e = evaluate_network(&networks::vgg19(), Corner::throughput_optimal());
        assert!(
            e.avg_device_power > 0.1 && e.avg_device_power < 0.7,
            "{}",
            e.avg_device_power
        );
    }

    #[test]
    fn energy_corner_beats_throughput_corner_in_efficiency() {
        for net in networks::all_networks() {
            let lo = evaluate_network(&net, Corner::energy_optimal());
            let hi = evaluate_network(&net, Corner::throughput_optimal());
            assert!(lo.avg_en_eff > 5.0 * hi.avg_en_eff, "{}", net.id);
            assert!(hi.avg_theta > 20.0 * lo.avg_theta, "{}", net.id);
        }
    }
}
