//! Deterministic synthetic workload generation.
//!
//! The paper's power simulations ran the scene-labeling CNN of [50] on the
//! Stanford backgrounds data set (715 outdoor images, 320×240 RGB). That
//! data set is not redistributable here, so [`synthetic_scene`] generates
//! frames with comparable statistics — smooth large-scale gradients (sky /
//! ground), piecewise regions (buildings) and high-frequency texture
//! (foliage) — which is what drives switching activity in the datapath.
//! All generation is seeded (SplitMix64) and bit-reproducible.

use crate::fixedpoint::{Q2_9, QFormat};
use crate::testkit::Gen;

/// A multi-channel image holding **raw Q2.9** samples, channel-major
/// (`data[c][y][x]` flattened as `(c * h + y) * w + x`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Image {
    /// Width in pixels.
    pub w: usize,
    /// Height in pixels.
    pub h: usize,
    /// Channels.
    pub c: usize,
    /// Raw Q2.9 samples.
    pub data: Vec<i64>,
}

impl Image {
    /// All-zero image.
    pub fn zeros(c: usize, h: usize, w: usize) -> Image {
        Image { w, h, c, data: vec![0; c * h * w] }
    }

    /// Sample accessor (no bounds slack: panics out of range).
    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> i64 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Mutable sample accessor.
    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut i64 {
        &mut self.data[(c * self.h + y) * self.w + x]
    }

    /// One full pixel row of a channel as a slice — the unit the block
    /// materializer and the bitplane raster consume (whole-row copies
    /// and packs instead of per-pixel `at` calls).
    #[inline]
    pub fn row(&self, c: usize, y: usize) -> &[i64] {
        let base = (c * self.h + y) * self.w;
        &self.data[base..base + self.w]
    }

    /// Mutable full pixel row of a channel.
    #[inline]
    pub fn row_mut(&mut self, c: usize, y: usize) -> &mut [i64] {
        let base = (c * self.h + y) * self.w;
        &mut self.data[base..base + self.w]
    }

    /// Zero-padded accessor: coordinates outside the image read 0, the
    /// halo the accelerator synthesizes for zero-padded layers.
    #[inline]
    pub fn at_padded(&self, c: usize, y: isize, x: isize) -> i64 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.at(c, y as usize, x as usize)
        }
    }
}

/// Uniform random image over the full Q2.9 range. `amplitude` scales the
/// range (1.0 = full ±4); keep it ≲0.05 for golden comparisons that must
/// avoid ChannelSummer saturation on deep channel sums.
pub fn random_image(gen: &mut Gen, c: usize, h: usize, w: usize, amplitude: f64) -> Image {
    let hi = ((Q2_9.max_raw() as f64) * amplitude) as i64;
    let lo = -hi;
    let mut img = Image::zeros(c, h, w);
    for v in img.data.iter_mut() {
        *v = gen.range_i64(lo.min(-1), hi.max(1));
    }
    img
}

/// Synthetic outdoor scene: per-channel mixture of a vertical gradient
/// (sky→ground), a few rectangular "structures" and low-amplitude texture.
/// Values span roughly ±1.5 in Q2.9.
pub fn synthetic_scene(gen: &mut Gen, c: usize, h: usize, w: usize) -> Image {
    let mut img = Image::zeros(c, h, w);
    for ch in 0..c {
        // Sky/ground gradient with per-channel tint.
        let top = gen.f64_in(-1.0, 1.0);
        let bottom = gen.f64_in(-1.0, 1.0);
        for y in 0..h {
            let t = y as f64 / (h.max(2) - 1) as f64;
            let base = top + (bottom - top) * t;
            for x in 0..w {
                *img.at_mut(ch, y, x) = Q2_9.from_f64(base);
            }
        }
        // Rectangular structures (buildings / foreground objects).
        for _ in 0..gen.range(2, 5) {
            let x0 = gen.range(0, w - 1);
            let y0 = gen.range(0, h - 1);
            let rw = gen.range(1, (w / 3).max(1));
            let rh = gen.range(1, (h / 3).max(1));
            let level = gen.f64_in(-1.2, 1.2);
            for y in y0..(y0 + rh).min(h) {
                for x in x0..(x0 + rw).min(w) {
                    *img.at_mut(ch, y, x) = Q2_9.from_f64(level);
                }
            }
        }
        // Texture noise.
        for y in 0..h {
            for x in 0..w {
                let v = img.at(ch, y, x) + gen.range_i64(-24, 24);
                *img.at_mut(ch, y, x) = Q2_9.saturate(v);
            }
        }
    }
    img
}

/// A set of binary filters: `n_out × n_in` kernels of `k × k` bits
/// (Eq. 5 encoding: bit 1 ⇔ w = +1). `bits[(o·n_in + i)·k² + dy·k + dx]`.
#[derive(Debug, Clone)]
pub struct BinaryKernels {
    /// Output channels.
    pub n_out: usize,
    /// Input channels.
    pub n_in: usize,
    /// Kernel size.
    pub k: usize,
    /// Weight bits.
    pub bits: Vec<bool>,
}

impl BinaryKernels {
    /// Random kernel set.
    pub fn random(gen: &mut Gen, n_out: usize, n_in: usize, k: usize) -> BinaryKernels {
        let bits = (0..n_out * n_in * k * k).map(|_| gen.bool()).collect();
        BinaryKernels { n_out, n_in, k, bits }
    }

    /// All-(+1) kernels (useful in tests: convolution degenerates to a
    /// window sum).
    pub fn all_plus(n_out: usize, n_in: usize, k: usize) -> BinaryKernels {
        BinaryKernels { n_out, n_in, k, bits: vec![true; n_out * n_in * k * k] }
    }

    /// Weight bit of kernel (out, in) at (dy, dx).
    #[inline]
    pub fn bit(&self, o: usize, i: usize, dy: usize, dx: usize) -> bool {
        self.bits[((o * self.n_in + i) * self.k + dy) * self.k + dx]
    }

    /// Weight value (−1 / +1).
    #[inline]
    pub fn weight(&self, o: usize, i: usize, dy: usize, dx: usize) -> i64 {
        if self.bit(o, i, dy, dx) {
            1
        } else {
            -1
        }
    }

    /// Storage size in bits — the paper's 12× I/O reduction argument.
    pub fn storage_bits(&self) -> usize {
        self.bits.len()
    }
}

/// Per-output-channel scale/bias pairs in raw Q2.9 (batch-norm folding).
#[derive(Debug, Clone)]
pub struct ScaleBias {
    /// Raw Q2.9 scales α_k.
    pub alpha: Vec<i64>,
    /// Raw Q2.9 biases β_k.
    pub beta: Vec<i64>,
}

impl ScaleBias {
    /// Identity scaling (α = 1.0, β = 0).
    pub fn identity(n_out: usize) -> ScaleBias {
        ScaleBias { alpha: vec![512; n_out], beta: vec![0; n_out] }
    }

    /// Random scales in (−1, 1) and small biases.
    pub fn random(gen: &mut Gen, n_out: usize) -> ScaleBias {
        let fmt: QFormat = Q2_9;
        ScaleBias {
            alpha: (0..n_out).map(|_| fmt.from_f64(gen.f64_in(-1.0, 1.0))).collect(),
            beta: (0..n_out).map(|_| fmt.from_f64(gen.f64_in(-0.5, 0.5))).collect(),
        }
    }
}

/// Reference software convolution with YodaNN semantics, used as the
/// module-level oracle for the cycle simulator (the cross-chip oracle is
/// the JAX/Pallas golden model loaded via PJRT).
///
/// For each output channel: ChannelSummer accumulation is **saturating at
/// Q7.9 after each input-channel contribution** (hardware register width),
/// then scale/bias/truncate to Q2.9.
pub fn reference_conv(
    img: &Image,
    kernels: &BinaryKernels,
    sb: &ScaleBias,
    zero_pad: bool,
) -> Image {
    use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
    assert_eq!(img.c, kernels.n_in);
    let k = kernels.k;
    let (out_h, out_w) =
        if zero_pad { (img.h, img.w) } else { (img.h - k + 1, img.w - k + 1) };
    let half = (k - 1) / 2;
    let mut out = Image::zeros(kernels.n_out, out_h, out_w);
    for o in 0..kernels.n_out {
        for y in 0..out_h {
            for x in 0..out_w {
                let mut acc: i64 = 0;
                for i in 0..img.c {
                    // One SoP result: the full k×k window of channel i.
                    let mut sop: i64 = 0;
                    for dy in 0..k {
                        for dx in 0..k {
                            let (yy, xx) = if zero_pad {
                                (y as isize + dy as isize - half as isize,
                                 x as isize + dx as isize - half as isize)
                            } else {
                                ((y + dy) as isize, (x + dx) as isize)
                            };
                            let px = img.at_padded(i, yy, xx);
                            sop += if kernels.bit(o, i, dy, dx) { px } else { -px };
                        }
                    }
                    acc = sat_add(Q7_9, acc, sop);
                }
                *out.at_mut(o, y, x) = scale_bias(acc, sb.alpha[o], sb.beta[o]);
            }
        }
    }
    out
}

/// Reference XNOR (binary-activation) convolution: every window sample is
/// binarized to ±1.0 (raw ±512, sign convention `x ≥ 0 ⇒ +1`, so the
/// zero-pad halo binarizes to **+1**) before the binary-weight dot, then
/// accumulated with the same per-input-channel Q7.9 saturation and
/// scale/bias epilogue as [`reference_conv`]. This is the oracle the XNOR
/// engine family (`engine::xnor`) must match bit-for-bit; the ±512
/// convention itself is pinned against `engine::binary::binarize_q29` by a
/// test there (workload deliberately does not depend on `engine`).
pub fn reference_xnor_conv(
    img: &Image,
    kernels: &BinaryKernels,
    sb: &ScaleBias,
    zero_pad: bool,
) -> Image {
    use crate::fixedpoint::{sat_add, scale_bias, Q7_9};
    assert_eq!(img.c, kernels.n_in);
    let k = kernels.k;
    let (out_h, out_w) =
        if zero_pad { (img.h, img.w) } else { (img.h - k + 1, img.w - k + 1) };
    let half = (k - 1) / 2;
    let mut out = Image::zeros(kernels.n_out, out_h, out_w);
    for o in 0..kernels.n_out {
        for y in 0..out_h {
            for x in 0..out_w {
                let mut acc: i64 = 0;
                for i in 0..img.c {
                    let mut sop: i64 = 0;
                    for dy in 0..k {
                        for dx in 0..k {
                            let (yy, xx) = if zero_pad {
                                (y as isize + dy as isize - half as isize,
                                 x as isize + dx as isize - half as isize)
                            } else {
                                ((y + dy) as isize, (x + dx) as isize)
                            };
                            let px = img.at_padded(i, yy, xx);
                            let a = if px >= 0 { 512 } else { -512 };
                            sop += if kernels.bit(o, i, dy, dx) { a } else { -a };
                        }
                    }
                    acc = sat_add(Q7_9, acc, sop);
                }
                *out.at_mut(o, y, x) = scale_bias(acc, sb.alpha[o], sb.beta[o]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_indexing_roundtrip() {
        let mut img = Image::zeros(2, 3, 4);
        *img.at_mut(1, 2, 3) = 77;
        assert_eq!(img.at(1, 2, 3), 77);
        assert_eq!(img.at_padded(1, 2, 3), 77);
        assert_eq!(img.at_padded(1, -1, 0), 0);
        assert_eq!(img.at_padded(1, 0, 4), 0);
    }

    #[test]
    fn row_slices_alias_at_indexing() {
        let mut img = Image::zeros(2, 3, 4);
        *img.at_mut(1, 2, 0) = 5;
        *img.at_mut(1, 2, 3) = 9;
        assert_eq!(img.row(1, 2), &[5, 0, 0, 9]);
        img.row_mut(0, 1).copy_from_slice(&[1, 2, 3, 4]);
        assert_eq!(img.at(0, 1, 2), 3);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = synthetic_scene(&mut Gen::new(9), 3, 16, 16);
        let b = synthetic_scene(&mut Gen::new(9), 3, 16, 16);
        assert_eq!(a, b);
        let ka = BinaryKernels::random(&mut Gen::new(5), 4, 3, 3);
        let kb = BinaryKernels::random(&mut Gen::new(5), 4, 3, 3);
        assert_eq!(ka.bits, kb.bits);
    }

    #[test]
    fn scene_values_in_q29_range() {
        let img = synthetic_scene(&mut Gen::new(1), 3, 24, 24);
        for &v in &img.data {
            assert!(crate::fixedpoint::Q2_9.contains(v));
        }
    }

    #[test]
    fn kernel_storage_is_one_bit_per_weight() {
        let k = BinaryKernels::random(&mut Gen::new(2), 32, 32, 7);
        // The paper's filter bank: 32²·7²·1 bit = 50176 bit (§III-B).
        assert_eq!(k.storage_bits(), 50176);
    }

    #[test]
    fn reference_conv_all_plus_is_window_sum() {
        // 1 input channel, all-ones 3×3 kernel, identity scale: each output
        // equals the padded window sum.
        let mut img = Image::zeros(1, 3, 3);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = i as i64 + 1; // 1..9
        }
        let kernels = BinaryKernels::all_plus(1, 1, 3);
        let out = reference_conv(&img, &kernels, &ScaleBias::identity(1), true);
        // Centre pixel: sum(1..9) = 45.
        assert_eq!(out.at(0, 1, 1), 45);
        // Corner (0,0): window covers pixels {1,2,4,5} = 12.
        assert_eq!(out.at(0, 0, 0), 12);
    }

    #[test]
    fn reference_conv_non_padded_shape() {
        let img = random_image(&mut Gen::new(3), 2, 8, 9, 0.02);
        let kernels = BinaryKernels::random(&mut Gen::new(4), 3, 2, 5);
        let out = reference_conv(&img, &kernels, &ScaleBias::identity(3), false);
        assert_eq!((out.c, out.h, out.w), (3, 4, 5));
    }

    #[test]
    fn reference_conv_scale_bias_applied() {
        let mut img = Image::zeros(1, 1, 1);
        *img.at_mut(0, 0, 0) = 512; // 1.0
        let kernels = BinaryKernels::all_plus(1, 1, 1);
        // α = 0.5, β = 0.25 → 1.0·0.5 + 0.25 = 0.75 → raw 384.
        let sb = ScaleBias { alpha: vec![256], beta: vec![128] };
        let out = reference_conv(&img, &kernels, &sb, true);
        assert_eq!(out.at(0, 0, 0), 384);
    }

    #[test]
    fn channel_summer_saturates_at_q79() {
        // 64 input channels of max pixels with all-plus 1×1 kernels drive
        // the accumulator into Q7.9 saturation (65535), then α=1 truncates
        // to Q2.9 max.
        let c = 64;
        let mut img = Image::zeros(c, 1, 1);
        for ch in 0..c {
            *img.at_mut(ch, 0, 0) = 2047;
        }
        let kernels = BinaryKernels::all_plus(1, c, 1);
        let out = reference_conv(&img, &kernels, &ScaleBias::identity(1), true);
        assert_eq!(out.at(0, 0, 0), 2047); // saturated to Q2.9 max
    }

    #[test]
    fn reference_xnor_conv_ignores_magnitudes() {
        // XNOR conv only sees signs: two images with equal sign patterns but
        // different magnitudes produce identical outputs.
        let mut gen = Gen::new(11);
        let a = random_image(&mut gen, 2, 7, 9, 0.8);
        let mut b = a.clone();
        for v in b.data.iter_mut() {
            *v = if *v >= 0 { 3 } else { -1500 };
        }
        let kernels = BinaryKernels::random(&mut gen, 3, 2, 3);
        let sb = ScaleBias::random(&mut gen, 3);
        for zp in [false, true] {
            assert_eq!(
                reference_xnor_conv(&a, &kernels, &sb, zp),
                reference_xnor_conv(&b, &kernels, &sb, zp)
            );
        }
    }

    #[test]
    fn reference_xnor_conv_all_plus_counts_agreements() {
        // 1 channel, 3×3 all-plus kernel, zero-pad: every sample (including
        // the halo, which binarizes to +1) contributes +1.0, so each output
        // is k² = 9.0 → Q2.9 saturates at 2047 after identity scale? No:
        // 9.0 = raw 4608 exceeds Q2.9 max 2047 → truncate/saturate to 2047.
        let img = Image::zeros(1, 3, 3); // zeros binarize to +1
        let kernels = BinaryKernels::all_plus(1, 1, 3);
        let out = reference_xnor_conv(&img, &kernels, &ScaleBias::identity(1), true);
        assert_eq!(out.at(0, 1, 1), 2047);
        // With α = 1/8 (raw 64): 9.0·0.125 = 1.125 → raw 576.
        let sb = ScaleBias { alpha: vec![64], beta: vec![0] };
        let out = reference_xnor_conv(&img, &kernels, &sb, true);
        assert_eq!(out.at(0, 1, 1), 576);
    }
}
