//! Calibration anchors — every constant the analytic models are fitted to,
//! annotated with the paper table/figure it comes from.
//!
//! Derivations (see DESIGN.md §5):
//!
//! * Frequencies follow from peak throughput via Eq. 6, `Θ = 2·k²·n_ch·f`,
//!   e.g. Table I binary 8×8 @1.2 V: 377 GOp/s = 2·49·8·481 MHz.
//! * Table II is reported at 400 MHz with a fixed 328 mW I/O contribution
//!   (§IV-C): "we estimate a fixed contribution of 328 mW for the I/O power
//!   at 400 MHz". Back-solving its columns yields the 32×32 core powers.
//! * The 0.6 V mode powers follow from Table III's per-layer efficiency
//!   rows: a fully-utilized 3×3 layer runs at 20.1 GOp/s and 59.2 TOp/s/W
//!   ⇒ 0.3405 mW; peak 7×7 is 55 GOp/s at 61.23 TOp/s/W ⇒ 0.898 mW; the
//!   5×5 AlexNet L2 row (39.1 GOp/s, 45.2 TOp/s/W, activity 0.821)
//!   ⇒ 1.054 mW.

/// Nominal supply voltage (V).
pub const V_NOM: f64 = 1.2;
/// SCM / standard-cell minimum supply (V), §III-C.
pub const V_MIN_SCM: f64 = 0.6;
/// SRAM minimum supply (V): "UMC 65nm technology SRAMs fail below 0.8 V".
pub const V_MIN_SRAM: f64 = 0.8;

/// V→f corners. Frequencies in Hz.
pub mod freq {
    /// Fixed-point Q2.9 8×8 baseline: Table I peak throughputs
    /// 348 GOp/s @1.2 V, 131 GOp/s @0.8 V over 2·49·8 ops/cycle.
    pub const Q29_8: [(f64, f64); 2] = [(0.8, 167.1e6), (1.2, 443.9e6)];
    /// Binary 8×8: Table I — 377 / 149 / 15 GOp/s at 1.2 / 0.8 / 0.6 V.
    pub const BIN_8: [(f64, f64); 3] = [(0.6, 19.1e6), (0.8, 190.0e6), (1.2, 480.9e6)];
    /// Final 32×32 multi-kernel chip: §IV-B "480 MHz @ 1.2 V"; 0.6 V point
    /// from the 55 GOp/s peak (§IV-E) ⇒ 17.5 MHz (the multi-kernel adder
    /// tree lengthens the low-voltage critical path vs. the plain 8×8).
    pub const BIN_32: [(f64, f64); 2] = [(0.6, 17.54e6), (1.2, 480.0e6)];
}

/// Core power anchors `(V, W)` at the architecture's f(V), 7×7 kernels,
/// full utilization.
pub mod core_power {
    /// Table I, "Avg. Power Core": Q2.9 baseline.
    pub const Q29_8: [(f64, f64); 2] = [(0.8, 31.0e-3), (1.2, 185.0e-3)];
    /// Table I: binary 8×8 (fixed 7×7 kernel variant).
    pub const BIN_8: [(f64, f64); 3] = [(0.6, 0.26e-3), (0.8, 5.1e-3), (1.2, 39.0e-3)];
    /// 16×16: Table II @400 MHz back-solved (1611 GOp/s/W device with
    /// 328 mW I/O ⇒ 61.3 mW core), rescaled to f(1.2 V) = 480 MHz; the
    /// 0.6 V anchor scales by the 32×32 C_eff(0.6)/C_eff(1.2) ratio.
    pub const BIN_16: [(f64, f64); 2] = [(0.6, 0.433e-3), (1.2, 73.6e-3)];
    /// 32×32 fixed-7×7 (Table II "32² (fixed)" column: 3001 GOp/s/W
    /// ⇒ 92.1 mW @400 MHz; equals multi-kernel minus the paper's "+38%
    /// core power" for multi-kernel support).
    pub const BIN_32_FIXED: [(f64, f64); 2] = [(0.6, 0.649e-3), (1.2, 110.5e-3)];
    /// Final 32×32 multi-kernel chip: 0.6 V from the 895 µW / 61.23 TOp/s/W
    /// headline; 1.2 V from Table II (2756 GOp/s/W ⇒ 127.1 mW @400 MHz,
    /// ×480/400). Matches the paper's "core power ×3.32 from 8×8 to 32×32"
    /// and "+38% for multi-kernel" cross-checks to <2%.
    pub const BIN_32_MULTI: [(f64, f64); 2] = [(0.6, 0.8963e-3), (1.2, 152.5e-3)];
}

/// Per-kernel-mode core power ratios relative to the native 7×7 slot, at
/// full utilization (from Table III's per-layer EnEff rows, see module
/// docs). The 5×5 dual mode burns slightly *more* than 7×7 (50 active
/// binary ops vs 49, both output streams busy); the 3×3 dual mode gates
/// most of the adder tree.
pub const MODE_RATIO_SLOT7: f64 = 1.0;
/// 2×(5×5) dual-filter mode (1.054 mW / 0.896 mW at 0.6 V).
pub const MODE_RATIO_SLOT5: f64 = 1.1756;
/// 2×(3×3) dual-filter mode (0.3405 mW / 0.896 mW at 0.6 V).
pub const MODE_RATIO_SLOT3: f64 = 0.3799;

/// Idle-cycle power fraction: when input channels idle (η_chIdle < 1) the
/// silenced SoPs stop toggling but the image memory, controller and clock
/// tree keep running. P̃_real = a + IDLE_FRACTION·(1−a) reproduces
/// Table III's P̃ = 0.35 at activity 0.09.
pub const IDLE_FRACTION: f64 = 0.283;

/// I/O pad model (§IV-C): "a fixed contribution of 328 mW for the I/O
/// power at 400 MHz", 1.8 V pads, scaled linearly with frequency.
pub const IO_POWER_AT_400MHZ: f64 = 328.0e-3;
/// Reference frequency for the pad anchor.
pub const IO_REF_FREQ: f64 = 400.0e6;
/// Second 12-bit output stream (dual-filter modes): back-solved from
/// Table II's 5×5 column (2107 GOp/s/W @32×32 ⇒ 458 mW I/O ⇒ +130 mW).
pub const IO_SECOND_STREAM_AT_400MHZ: f64 = 130.0e-3;
/// Weight-stream overhead of the 12-bit baseline relative to binary
/// weights (12× the bits; Table I's 580 mW Q2.9 device power at 1.2 V
/// back-solves to ≈31 mW of extra pad power at 444 MHz).
pub const IO_WEIGHTS_Q29_AT_400MHZ: f64 = 28.0e-3;
/// Binary-weight stream pad power (12× less than `IO_WEIGHTS_Q29…`).
pub const IO_WEIGHTS_BIN_AT_400MHZ: f64 = 28.0e-3 / 12.0;

/// Power-breakdown fractions per unit (Fig. 12-style), at 1.2 V, expressed
/// as watts at 400 MHz. Derived from the paper's ratios: binary vs Q2.9
/// unit power ÷3.5 (SCM vs SRAM), ÷4.8 (SoP), ÷31 (filter bank); the
/// Scale-Bias unit adds 0.4 mW; total anchors as in [`core_power`].
pub mod breakdown_400mhz {
    //! Solved such that (a) each architecture's split sums to its measured
    //! core power when rescaled to its own f(1.2 V), and (b) the paper's
    //! §IV-C unit reductions hold between the as-measured 8×8 designs:
    //! SCM = SRAM/3.5, SoP/4.8, filter bank/31.

    /// (image memory, SoP units, filter bank, scale-bias, other) in W.
    /// Sums to 166.7 mW ⇒ 185 mW at f(1.2 V) = 444 MHz (Table I).
    pub const Q29_8: [f64; 5] = [44.8e-3, 90.8e-3, 27.9e-3, 0.0, 3.2e-3];
    /// Binary 8×8: each unit divided by the paper's reduction factors.
    /// Sums to 32.46 mW ⇒ 39 mW at 481 MHz (Table I).
    pub const BIN_8: [f64; 5] = [11.8e-3, 17.5e-3, 0.83e-3, 0.0, 2.33e-3];
    /// Binary 16×16: SCM constant, filter bank ∝ n_ch², SoP grows with
    /// n_ch; residual solved against the 61.3 mW Table II anchor.
    pub const BIN_16: [f64; 5] = [11.8e-3, 43.0e-3, 3.3e-3, 0.2e-3, 3.0e-3];
    /// Binary 32×32, fixed 7×7 (92.1 mW total @400 MHz).
    pub const BIN_32_FIXED: [f64; 5] = [11.8e-3, 64.0e-3, 13.3e-3, 0.0, 3.0e-3];
    /// Binary 32×32 multi-kernel (127.1 mW; the paper's "+38% core power
    /// for multi-kernel support" lands in the SoP muxes and adder trees).
    pub const BIN_32_MULTI: [f64; 5] = [11.8e-3, 98.6e-3, 13.3e-3, 0.4e-3, 3.0e-3];
}

/// Area anchors in kGE (Fig. 6 + §IV-B floorplan).
pub mod area_kge {
    /// Final chip floorplan: SCM 480, filter bank 333, SoP 215, image bank
    /// 123, scale-bias 2.5, other 107.5 ⇒ 1261 kGE total.
    pub const BIN_32_MULTI: [f64; 6] = [480.0, 333.0, 215.0, 123.0, 2.5, 107.5];
    /// 32×32 fixed-7×7: multi-kernel support adds 11.2% core area (§IV-C),
    /// attributed to the SoP mux/adder-tree extensions.
    pub const BIN_32_FIXED: [f64; 6] = [480.0, 333.0, 88.0, 123.0, 0.0, 110.0];
    /// Binary 16×16: filter bank ∝ n_ch², SoP & image bank ∝ n_ch.
    pub const BIN_16: [f64; 6] = [480.0, 83.0, 107.5, 61.5, 0.0, 20.0];
    /// Binary 8×8 (0.60 MGE total, Table I): SoP = Q2.9's 288 kGE ÷ 5.3,
    /// filter bank ÷ 14.9 (§III-B).
    pub const BIN_8: [f64; 6] = [480.0, 19.3, 54.3, 30.8, 0.0, 15.6];
    /// Q2.9 8×8 with SRAM (0.72 MGE total; "40% filter bank, 40%
    /// multipliers and adder trees", §III-B; SRAM macro ≈ 80 kGE).
    pub const Q29_8: [f64; 6] = [80.0, 288.0, 288.0, 30.8, 0.0, 33.2];
}

/// Headline core-area figure used for GOp/s/MGE metrics: the abstract's
/// "1.33 MGE (0.19 mm²)" (the floorplan's 1261 kGE excludes clock tree /
/// fill). 1510 GOp/s / 1.33 MGE = 1135 GOp/s/MGE, the paper's number.
pub const CHIP_AREA_MGE: f64 = 1.33;
/// Image-memory capacity: 1024 rows of 7 × 12-bit words (§III).
pub const IMAGE_MEM_ROWS: usize = 1024;
/// SCM banking: 6 × 8 banks of 128 rows × 12 bit (§III-C, Fig. 7).
pub const SCM_BANKS: (usize, usize) = (6, 8);
/// SCM bank rows.
pub const SCM_BANK_ROWS: usize = 128;
/// SRAM→SCM memory power reduction at 1.2 V (§III-C): 3.25×.
pub const SCM_VS_SRAM_POWER: f64 = 3.25;
