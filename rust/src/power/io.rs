//! I/O pad power model (§III-D / §IV-C).
//!
//! The paper does not measure pad power directly; it "approximated [it] by
//! power measurements on chips of the same technology [15] and scaled to
//! the actual operating frequency", fixing **328 mW at 400 MHz** for the
//! 12-bit input stream + one 12-bit output stream at 1.8 V pad supply. We
//! adopt the identical model and add two fitted terms:
//!
//! * the **second output stream** active in dual-filter (3×3/5×5) modes
//!   (+130 mW @400 MHz, back-solved from Table II's 5×5 column);
//! * the **weight stream**: 12-bit weights in the Q2.9 baseline vs 1-bit
//!   binary weights (12× fewer bits — the paper's key I/O saving).

use super::calib;
use crate::model::KernelMode;

/// Pad power model. All powers in watts.
#[derive(Debug, Clone, Copy)]
pub struct IoPowerModel {
    /// Base stream power at the 400 MHz reference (input + one output).
    pub base_at_ref: f64,
    /// Second-output-stream incremental power at the reference frequency.
    pub second_stream_at_ref: f64,
    /// Weight-stream power at the reference frequency.
    pub weights_at_ref: f64,
}

impl IoPowerModel {
    /// Model for a binary-weight architecture.
    pub fn binary() -> IoPowerModel {
        IoPowerModel {
            base_at_ref: calib::IO_POWER_AT_400MHZ,
            second_stream_at_ref: calib::IO_SECOND_STREAM_AT_400MHZ,
            weights_at_ref: calib::IO_WEIGHTS_BIN_AT_400MHZ,
        }
    }

    /// Model for the 12-bit fixed-point baseline (12× weight bits).
    pub fn q29() -> IoPowerModel {
        IoPowerModel {
            base_at_ref: calib::IO_POWER_AT_400MHZ,
            second_stream_at_ref: calib::IO_SECOND_STREAM_AT_400MHZ,
            weights_at_ref: calib::IO_WEIGHTS_Q29_AT_400MHZ,
        }
    }

    /// Pad power at clock `f` (Hz) for kernel mode `mode` (dual-filter
    /// modes stream two output channels per cycle).
    pub fn power(&self, f: f64, mode: KernelMode) -> f64 {
        let scale = f / calib::IO_REF_FREQ;
        let dual = if mode.filters_per_sop() == 2 { self.second_stream_at_ref } else { 0.0 };
        (self.base_at_ref + dual + self.weights_at_ref) * scale
    }

    /// Pad power for a kernel size `k` on a multi-kernel architecture
    /// (`multi = false` forces the single-stream 7×7 mapping).
    pub fn power_for_kernel(&self, f: f64, k: usize, multi: bool) -> f64 {
        let mode = if multi { KernelMode::for_kernel(k) } else { KernelMode::Slot7 };
        self.power(f, mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_anchor() {
        let io = IoPowerModel::binary();
        let p = io.power(400.0e6, KernelMode::Slot7);
        // 328 mW + ~2.3 mW binary weight stream.
        assert!((p - 0.3303).abs() < 1e-3, "{p}");
    }

    #[test]
    fn scales_linearly_with_frequency() {
        let io = IoPowerModel::binary();
        let p1 = io.power(100.0e6, KernelMode::Slot7);
        let p4 = io.power(400.0e6, KernelMode::Slot7);
        assert!((p4 / p1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn dual_stream_costs_more() {
        let io = IoPowerModel::binary();
        assert!(io.power(400.0e6, KernelMode::Slot5) > io.power(400.0e6, KernelMode::Slot7));
        let delta = io.power(400.0e6, KernelMode::Slot3) - io.power(400.0e6, KernelMode::Slot7);
        assert!((delta - 0.130).abs() < 1e-6);
    }

    #[test]
    fn q29_weight_stream_is_12x_binary() {
        let b = IoPowerModel::binary();
        let q = IoPowerModel::q29();
        assert!((q.weights_at_ref / b.weights_at_ref - 12.0).abs() < 1e-9);
    }

    #[test]
    fn table1_device_power_shape() {
        // Binary 8×8 @0.6 V: core 0.26 mW + pads at 19.1 MHz ≈ 15.9 mW,
        // paper reports 15.54 mW (≲3% — the paper's own scaling rounds).
        let io = IoPowerModel::binary();
        let dev = 0.26e-3 + io.power(19.1e6, KernelMode::Slot7);
        assert!((dev - 15.54e-3).abs() / 15.54e-3 < 0.05, "{dev}");
    }
}
