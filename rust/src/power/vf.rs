//! Voltage → maximum-frequency model.
//!
//! Standard-cell delay over a wide voltage range follows the alpha-power
//! law: `f(V) = k · (V − V_t)^α / V`. We fit `(V_t, α, k)` to the paper's
//! measured corners: with three corners (binary 8×8: Table I gives
//! 19.1 MHz @ 0.6 V, 190 MHz @ 0.8 V, 481 MHz @ 1.2 V via Θ = 2·k²·n_ch·f)
//! all three parameters are identified; with two corners `α` is carried
//! over from the three-point fit and `(V_t, k)` are solved exactly.

/// Fitted alpha-power-law frequency curve, valid on `[vmin, vmax]`.
#[derive(Debug, Clone, Copy)]
pub struct VfCurve {
    /// Threshold-like fitting voltage (V).
    pub vt: f64,
    /// Velocity-saturation exponent.
    pub alpha: f64,
    /// Scale constant (Hz·V).
    pub k: f64,
    /// Lowest valid supply voltage (0.6 V for SCM designs, 0.8 V for the
    /// SRAM baseline, per §III-C).
    pub vmin: f64,
    /// Highest valid supply voltage (1.2 V nominal in UMC 65 nm).
    pub vmax: f64,
}

fn alpha_from_pair(vt: f64, p0: (f64, f64), p1: (f64, f64)) -> f64 {
    // f·V = k (V−vt)^α  ⇒  α = ln(f0·V0 / f1·V1) / ln((V0−vt)/(V1−vt))
    ((p0.1 * p0.0) / (p1.1 * p1.0)).ln() / ((p0.0 - vt) / (p1.0 - vt)).ln()
}

impl VfCurve {
    /// Fit all three parameters to exactly three (V, f) corners
    /// (ascending V). Bisects on `vt` until both corner pairs agree on `α`.
    pub fn fit3(points: [(f64, f64); 3], vmin: f64, vmax: f64) -> VfCurve {
        let [p0, p1, p2] = points;
        assert!(p0.0 < p1.0 && p1.0 < p2.0, "corners must be ascending in V");
        let g = |vt: f64| alpha_from_pair(vt, p1, p0) - alpha_from_pair(vt, p2, p1);
        let (mut lo, mut hi) = (1e-3, p0.0 - 1e-3);
        assert!(
            g(lo).signum() != g(hi).signum(),
            "alpha-power law cannot fit these corners: {points:?}"
        );
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if g(mid).signum() == g(lo).signum() {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let vt = 0.5 * (lo + hi);
        let alpha = alpha_from_pair(vt, p1, p0);
        let k = p2.1 * p2.0 / (p2.0 - vt).powf(alpha);
        VfCurve { vt, alpha, k, vmin, vmax }
    }

    /// Fit `(vt, k)` to two corners with a given `α` (carried over from the
    /// three-corner binary-architecture fit).
    pub fn fit2(points: [(f64, f64); 2], alpha: f64, vmin: f64, vmax: f64) -> VfCurve {
        let [p0, p1] = points;
        assert!(p0.0 < p1.0);
        // Solve ((V1−vt)/(V0−vt))^α = f1·V1/(f0·V0) for vt by bisection.
        let target = (p1.1 * p1.0) / (p0.1 * p0.0);
        let g = |vt: f64| ((p1.0 - vt) / (p0.0 - vt)).powf(alpha) - target;
        let (mut lo, mut hi) = (1e-6, p0.0 - 1e-6);
        assert!(g(lo) < 0.0 && g(hi) > 0.0, "cannot fit 2-point curve: {points:?}");
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if g(mid) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let vt = 0.5 * (lo + hi);
        let k = p1.1 * p1.0 / (p1.0 - vt).powf(alpha);
        VfCurve { vt, alpha, k, vmin, vmax }
    }

    /// Maximum clock frequency (Hz) at supply `v` (V). Panics outside the
    /// curve's valid voltage range — the hardware does not operate there
    /// (SRAM fails below 0.8 V, standard cells below 0.6 V, §III-C).
    pub fn freq(&self, v: f64) -> f64 {
        assert!(
            (self.vmin - 1e-9..=self.vmax + 1e-9).contains(&v),
            "supply {v} V outside operating range [{}, {}] V",
            self.vmin,
            self.vmax
        );
        self.k * (v - self.vt).powf(self.alpha) / v
    }

    /// Typed sibling of [`VfCurve::freq`]: the maximum clock frequency
    /// (Hz) at supply `v` (V), or
    /// [`YodannError::SupplyOutOfRange`] instead of a panic when `v`
    /// falls off the curve. Serving paths (the DVFS governor, runtime
    /// corner swaps) route through this so a bad step — or float
    /// accumulation at the boundary — surfaces as a typed error rather
    /// than crashing the daemon; the analytic models keep the panicking
    /// [`VfCurve::freq`], whose boundary assert stays pinned by test.
    pub fn try_freq(&self, v: f64) -> Result<f64, crate::api::YodannError> {
        if !(self.vmin - 1e-9..=self.vmax + 1e-9).contains(&v) {
            return Err(crate::api::YodannError::SupplyOutOfRange {
                v,
                vmin: self.vmin,
                vmax: self.vmax,
            });
        }
        Ok(self.k * (v - self.vt).powf(self.alpha) / v)
    }

    /// Safe corner stepping: `v + dv` clamped to the curve's valid
    /// `[vmin, vmax]` range. A governor that only moves its supply
    /// through `step_supply` can never leave the operating region, so
    /// every voltage it quotes is valid for [`VfCurve::try_freq`] and
    /// the power models.
    pub fn step_supply(&self, v: f64, dv: f64) -> f64 {
        (v + dv).clamp(self.vmin, self.vmax)
    }

    /// Memory/interconnect bit-error rate at supply `v` (V).
    ///
    /// The standard-cell latch arrays that replace SRAM (§III-C) keep
    /// working near threshold, but their noise margin shrinks as the
    /// supply approaches `V_t`; upset rates grow roughly exponentially in
    /// the lost margin. We model that with the curve's own fitted `vt`:
    /// nominal supply (`vmax`) sits at a baseline 1e-9 upsets/bit-access,
    /// and the rate rises by `exp(GAMMA)` as the margin collapses,
    /// capped at 1e-2. Unlike [`VfCurve::freq`] this never panics —
    /// fault sweeps deliberately price corners outside the operating
    /// range, where the clamp saturates the rate instead.
    pub fn bit_error_rate(&self, v: f64) -> f64 {
        const BER_NOM: f64 = 1e-9;
        const GAMMA: f64 = 14.0;
        let margin = ((v - self.vt) / (self.vmax - self.vt)).clamp(0.0, 1.0);
        (BER_NOM * (GAMMA * (1.0 - margin)).exp()).min(1e-2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BIN8: [(f64, f64); 3] = [(0.6, 19.1e6), (0.8, 190.0e6), (1.2, 481.0e6)];

    #[test]
    fn fit3_reproduces_anchor_corners() {
        let c = VfCurve::fit3(BIN8, 0.6, 1.2);
        for (v, f) in BIN8 {
            let rel = (c.freq(v) - f).abs() / f;
            assert!(rel < 1e-6, "corner {v} V: {} vs {f}", c.freq(v));
        }
        // Physically plausible parameters.
        assert!(c.vt > 0.3 && c.vt < 0.6, "vt = {}", c.vt);
        assert!(c.alpha > 1.0 && c.alpha < 2.0, "alpha = {}", c.alpha);
    }

    #[test]
    fn fit2_reproduces_anchor_corners() {
        let alpha = VfCurve::fit3(BIN8, 0.6, 1.2).alpha;
        let pts = [(0.6, 17.5e6), (1.2, 480.0e6)];
        let c = VfCurve::fit2(pts, alpha, 0.6, 1.2);
        for (v, f) in pts {
            assert!((c.freq(v) - f).abs() / f < 1e-6);
        }
        // Interpolated 0.8 V point should be near the sibling binary
        // architecture's measured 190 MHz.
        let f08 = c.freq(0.8);
        assert!((150.0e6..230.0e6).contains(&f08), "f(0.8 V) = {f08}");
    }

    #[test]
    fn freq_is_monotonic() {
        let c = VfCurve::fit3(BIN8, 0.6, 1.2);
        let mut prev = 0.0;
        let mut v = 0.6;
        while v <= 1.2 {
            let f = c.freq(v);
            assert!(f > prev);
            prev = f;
            v += 0.01;
        }
    }

    #[test]
    #[should_panic]
    fn freq_rejects_out_of_range_voltage() {
        let c = VfCurve::fit3(BIN8, 0.6, 1.2);
        c.freq(0.5);
    }

    #[test]
    fn try_freq_is_typed_where_freq_panics() {
        use crate::api::YodannError;
        let c = VfCurve::fit3(BIN8, 0.6, 1.2);
        // In range: agrees exactly with the panicking path.
        for v in [0.6, 0.8, 1.0, 1.2] {
            assert_eq!(c.try_freq(v).unwrap(), c.freq(v));
        }
        // Out of range: a typed error carrying the bounds, not a panic.
        let e = c.try_freq(0.5).unwrap_err();
        assert_eq!(e, YodannError::SupplyOutOfRange { v: 0.5, vmin: 0.6, vmax: 1.2 });
        assert!(c.try_freq(1.3).is_err());
        // The boundary tolerance matches freq's (float accumulation at
        // the rail must not error).
        assert!(c.try_freq(0.6 - 1e-10).is_ok());
        assert!(c.try_freq(1.2 + 1e-10).is_ok());
    }

    #[test]
    fn step_supply_clamps_to_the_operating_range() {
        let c = VfCurve::fit3(BIN8, 0.6, 1.2);
        assert_eq!(c.step_supply(0.6, -0.025), 0.6);
        assert_eq!(c.step_supply(1.2, 0.025), 1.2);
        let v = c.step_supply(0.8, 0.025);
        assert!((v - 0.825).abs() < 1e-12);
        // A stepped voltage is always valid for try_freq.
        let mut v = 0.6;
        for _ in 0..100 {
            v = c.step_supply(v, 0.05);
            assert!(c.try_freq(v).is_ok());
        }
    }

    #[test]
    fn bit_error_rate_grows_toward_threshold() {
        let c = VfCurve::fit3(BIN8, 0.6, 1.2);
        // Nominal supply sits at the baseline rate.
        let nominal = c.bit_error_rate(1.2);
        assert!((nominal - 1e-9).abs() / 1e-9 < 1e-9, "nominal BER = {nominal}");
        // Near threshold the rate is orders of magnitude worse but bounded.
        let near = c.bit_error_rate(0.6);
        assert!(near > 1e-6 && near < 1e-3, "0.6 V BER = {near}");
        // Monotone non-increasing in supply; never panics below vmin.
        let mut prev = c.bit_error_rate(0.3);
        let mut v = 0.31;
        while v <= 1.3 {
            let b = c.bit_error_rate(v);
            assert!(b <= prev + 1e-18, "BER rose at {v} V");
            prev = b;
            v += 0.01;
        }
    }
}
