//! Voltage/frequency/power/area models of the accelerator, calibrated to
//! the paper's reported silicon corners.
//!
//! The paper characterizes UMC 65 nm standard cells over 0.6–1.2 V and
//! reports throughput/power at discrete corners (Table I, Table II at
//! 400 MHz, §IV-C text). We cannot re-run Synopsys PrimePower without the
//! PDK, so this module substitutes (see DESIGN.md §1):
//!
//! * [`vf`] — an alpha-power-law delay model `f(V) = k·(V−V_t)^α / V`
//!   fitted to the paper's measured (V, f) corners per architecture.
//! * [`core`] — core power `P(V) = C_eff(V)·V²·f(V)` with `C_eff`
//!   interpolated between the paper's measured power anchors, per-kernel
//!   mode scaling and the silenced-unit idle model.
//! * [`io`] — the pad power model the paper itself uses (328 mW @ 400 MHz,
//!   scaled with frequency; extra term for the second output stream and for
//!   12× weight I/O in the fixed-point baseline).
//! * [`multichip`] — aggregate power envelope and halo border-exchange
//!   accounting for sharded multi-chip grids
//!   ([`crate::coordinator::shard`]).
//! * [`xnor`] — the derived XNOR-mode (binary-activation) operating
//!   point: SCM occupancy / activation traffic at 1 bitplane instead of
//!   12, SoP at XNOR+popcount cost, per-op energy per V/f corner — the
//!   accelerator-generation comparison against XNORBIN/ChewBaccaNN-class
//!   successors.
//! * [`area`] — per-unit gate-equivalent areas (Fig. 6, floorplan §IV-B).
//! * [`calib`] — every constant, each annotated with the table/figure it
//!   anchors to.

pub mod area;
pub mod calib;
pub mod core;
pub mod io;
pub mod multichip;
pub mod vf;
pub mod xnor;

pub use self::core::{ArchId, CorePowerModel, PowerBreakdown};
pub use area::{area_breakdown, metric_area_mge, AreaBreakdown};
pub use io::IoPowerModel;
pub use multichip::{halo_exchange_words, MultiChipPower};
pub use vf::VfCurve;
pub use xnor::{GenerationPoint, XnorPowerModel};
