//! Multi-chip power envelope and border-exchange accounting for sharded
//! execution (the Hyperdrive scaling axis, arXiv:1804.00623).
//!
//! A [`crate::coordinator::ShardGrid`] runs one frame on several chip
//! instances at once. Two costs the single-chip models do not see:
//!
//! * the **aggregate power envelope** — every chip burns its own core
//!   and pad power concurrently, so the device budget multiplies with
//!   the grid even when per-chip efficiency is unchanged;
//! * the **border exchange** — vertically adjacent stripes both need the
//!   `k − 1` halo rows at their boundary (the Eq. 9 tiling overlap, now
//!   crossing chips), so those activation words are transferred twice.
//!
//! Wall-clock/energy aggregation of the *simulated* activity lives in
//! [`crate::coordinator::metrics::sharded_metrics`]; this module prices
//! the analytic envelope the same way the paper's Table I prices one
//! chip.

use super::{ArchId, CorePowerModel, IoPowerModel};

/// Aggregate power envelope of a grid of identical chips at one
/// operating corner, all running kernel size `k` at full utilization.
#[derive(Debug, Clone, Copy)]
pub struct MultiChipPower {
    /// Chip instances in the grid.
    pub chips: usize,
    /// Core power of one chip (W).
    pub core_w_each: f64,
    /// Pad power of one chip (W).
    pub io_w_each: f64,
}

impl MultiChipPower {
    /// Price a `chips`-instance grid of `arch` at supply `v`, kernel
    /// size `k` (the architecture's own kernel-mode capability applies,
    /// exactly as for one chip).
    pub fn at(arch: ArchId, v: f64, chips: usize, k: usize) -> MultiChipPower {
        assert!(chips >= 1, "a grid needs at least one chip");
        let core = CorePowerModel::new(arch);
        let io =
            if arch.binary_weights() { IoPowerModel::binary() } else { IoPowerModel::q29() };
        MultiChipPower {
            chips,
            core_w_each: core.p_core(v, k),
            io_w_each: io.power_for_kernel(core.freq(v), k, arch.multi_kernel()),
        }
    }

    /// Total device power of the grid (W): every chip's core + pads.
    pub fn total_w(&self) -> f64 {
        self.chips as f64 * (self.core_w_each + self.io_w_each)
    }
}

/// Activation words crossed between vertically adjacent stripes per
/// layer: each of the `stripes − 1` interior borders re-transfers the
/// `k − 1` shared halo rows (`w` pixels × `n_in` channels each) — zero
/// for an unsharded layer, growing linearly with the stripe count. This
/// is the I/O price of intra-frame scaling that Eq. 9 charges intra-chip
/// tiling.
pub fn halo_exchange_words(stripes: usize, k: usize, w: usize, n_in: usize) -> u64 {
    if stripes <= 1 || k <= 1 {
        return 0;
    }
    ((stripes - 1) * (k - 1) * w * n_in) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_power_is_linear_in_chips() {
        let one = MultiChipPower::at(ArchId::Bin32Multi, 0.6, 1, 7);
        let four = MultiChipPower::at(ArchId::Bin32Multi, 0.6, 4, 7);
        assert_eq!(four.chips, 4);
        assert!((four.total_w() / one.total_w() - 4.0).abs() < 1e-9);
        assert!(one.core_w_each > 0.0 && one.io_w_each > 0.0);
    }

    #[test]
    fn single_chip_envelope_matches_the_single_chip_models() {
        let p = MultiChipPower::at(ArchId::Bin32Multi, 1.2, 1, 7);
        let core = CorePowerModel::new(ArchId::Bin32Multi);
        assert!((p.core_w_each - core.p_core(1.2, 7)).abs() < 1e-12);
        let io = IoPowerModel::binary();
        assert!(
            (p.io_w_each - io.power_for_kernel(core.freq(1.2), 7, true)).abs() < 1e-12
        );
    }

    #[test]
    fn halo_exchange_follows_the_stripe_count() {
        assert_eq!(halo_exchange_words(1, 7, 320, 3), 0);
        assert_eq!(halo_exchange_words(2, 7, 320, 3), 6 * 320 * 3);
        assert_eq!(halo_exchange_words(4, 7, 320, 3), 3 * 6 * 320 * 3);
        assert_eq!(halo_exchange_words(4, 1, 320, 3), 0); // 1x1 needs no halo
        assert_eq!(halo_exchange_words(3, 3, 16, 8), 2 * 2 * 16 * 8);
    }
}
