//! XNOR-mode (binary-activation) power/traffic model — the
//! "next-generation" point the paper's conclusion gestures at and that
//! XNORBIN and ChewBaccaNN later taped out: keep YodaNN's binary
//! weights, binarize the **activations** too, and the 12-bit Q2.9
//! datapath collapses to XNOR + popcount over single-bit planes.
//!
//! Nothing here adds a new [`ArchId`] — the silicon anchors of
//! Tables I/II stay exactly the five taped-out/synthesized variants.
//! Instead this module *derives* an XNOR operating point from the
//! calibrated [`CorePowerModel`] / [`IoPowerModel`] of a binary-weight
//! architecture by applying the three structural reductions the mode
//! buys, each tied to a quantity the simulator actually models:
//!
//! * **SCM occupancy / activation traffic** — activations live as 1
//!   raster plane word per (channel, padded row) instead of 12
//!   ([`crate::engine::BinaryRaster`] vs
//!   [`crate::engine::BitplaneRaster`]): a hard 12× reduction in image
//!   memory words and in the activation I/O stream
//!   ([`ACTIVATION_PLANES_BWN`] → [`ACTIVATION_PLANES_XNOR`]).
//! * **SoP datapath** — the complement-mux 12-bit adder tree becomes
//!   XNOR + popcount. We price it with the same ratio the paper
//!   measured for the analogous 12-bit→1-bit *weight* collapse of the
//!   filter bank path (§IV-C reports SoP ÷4.8 going Q2.9→binary
//!   weights; binarizing the other operand removes the remaining
//!   multi-bit adds — [`XNOR_SOP_RATIO`]).
//! * **Scale-bias** — unchanged: the batch-norm threshold that replaces
//!   it in a real BNN runs off-chip in this codebase
//!   (`PlanStep::BatchNormThreshold`), so the on-chip α/β unit stays in
//!   the envelope, keeping the comparison conservative.
//!
//! The derived numbers are first-order estimates for the generation
//! *comparison table* (`report::tables::xnor_generation_table`), not
//! silicon reproductions — the doc of every method says which side of
//! the estimate is conservative.

use super::core::{ArchId, CorePowerModel, PowerBreakdown};
use super::io::IoPowerModel;
use crate::model::Corner;

/// Activation bitplanes a BWN (Q2.9-activation) layer keeps resident
/// per (channel, padded row) — the [`crate::engine::raster::PLANES`]
/// layout constant.
pub const ACTIVATION_PLANES_BWN: usize = crate::engine::raster::PLANES;

/// Activation bitplanes in XNOR mode: one sign plane.
pub const ACTIVATION_PLANES_XNOR: usize = 1;

/// SoP-unit power reduction for XNOR+popcount vs the 12-bit
/// complement-mux adder tree. The paper's own Q2.9→binary-weight
/// transition measured ÷4.8 on the SoP units (§IV-C) while still
/// adding 12-bit operands; binarizing the activations removes the
/// remaining multi-bit adds, which XNORBIN-class datapaths report as a
/// further ~2× — we use 4.8 × 2 and call it an estimate.
pub const XNOR_SOP_RATIO: f64 = 9.6;

/// u64 words one channel's padded activation rows occupy per bitplane:
/// the rasters' shared row layout (`stride = ceil(pw / 64) + 1` guard
/// word, `ph` padded rows) — see `BitplaneRaster::pack_view`.
fn plane_words_per_channel(h: usize, w: usize, k: usize, zero_pad: bool) -> usize {
    let halo = if zero_pad { k - 1 } else { 0 };
    let pw = w + halo;
    let ph = h + halo;
    (pw.div_ceil(64) + 1) * ph
}

/// Activation words a `c`×`h`×`w` layer input occupies in SCM (equals
/// the words its raster pack writes, i.e. `words_total()` of the
/// matching raster): `planes` = [`ACTIVATION_PLANES_BWN`] or
/// [`ACTIVATION_PLANES_XNOR`].
pub fn activation_words(c: usize, h: usize, w: usize, k: usize, zero_pad: bool, planes: usize) -> usize {
    c * plane_words_per_channel(h, w, k, zero_pad) * planes
}

/// One row of the accelerator-generation comparison: a named operating
/// mode's core power, throughput and efficiency at a corner.
#[derive(Debug, Clone)]
pub struct GenerationPoint {
    /// Mode label ("YodaNN BWN", "XNOR").
    pub mode: &'static str,
    /// Core power (W) at the corner, native 7×7 mode.
    pub core_w: f64,
    /// Peak throughput (Op/s) at the corner.
    pub theta_op_s: f64,
    /// Core energy efficiency (Op/s/W).
    pub eff_op_s_w: f64,
    /// Activation bitplanes resident per (channel, padded row).
    pub activation_planes: usize,
    /// Pad power (W) at the corner's f, 7×7 single-stream.
    pub io_w: f64,
}

/// The derived XNOR power model: the calibrated binary-weight model
/// plus the structural reductions above.
#[derive(Debug, Clone)]
pub struct XnorPowerModel {
    core: CorePowerModel,
    io: IoPowerModel,
}

impl XnorPowerModel {
    /// Derive from a binary-weight architecture's calibration. Panics
    /// on the Q2.9 baseline — XNOR mode presupposes binary weights.
    pub fn new(arch: ArchId) -> XnorPowerModel {
        assert!(arch.binary_weights(), "XNOR mode derives from a binary-weight architecture");
        XnorPowerModel { core: CorePowerModel::new(arch), io: IoPowerModel::binary() }
    }

    /// The underlying BWN core model.
    pub fn bwn(&self) -> &CorePowerModel {
        &self.core
    }

    /// XNOR-mode per-unit breakdown at supply `v`: image memory ÷12
    /// (1-bit residency), SoP ÷[`XNOR_SOP_RATIO`], filter bank /
    /// scale-bias / other unchanged (conservative).
    pub fn breakdown(&self, v: f64) -> PowerBreakdown {
        let b = self.core.breakdown(v);
        PowerBreakdown {
            memory: b.memory * ACTIVATION_PLANES_XNOR as f64 / ACTIVATION_PLANES_BWN as f64,
            sop: b.sop / XNOR_SOP_RATIO,
            filter_bank: b.filter_bank,
            scale_bias: b.scale_bias,
            other: b.other,
        }
    }

    /// XNOR core power (W) at `v`, native 7×7 mode, full utilization.
    pub fn p_core_slot7(&self, v: f64) -> f64 {
        self.breakdown(v).total()
    }

    /// XNOR pad power at clock `f`: the 12-bit activation streams drop
    /// to 1 bit (in and out), the 1-bit weight stream is unchanged.
    pub fn p_io(&self, f: f64) -> f64 {
        let scale = f / super::calib::IO_REF_FREQ;
        (self.io.base_at_ref / ACTIVATION_PLANES_BWN as f64 + self.io.weights_at_ref) * scale
    }

    /// Core energy per operation (J/Op) at `v`, 7×7 — the number the
    /// generation table compares across modes. Throughput is held at
    /// the BWN peak (same SoP array geometry; a real XNOR datapath
    /// would clock *higher*, so this is conservative for XNOR).
    pub fn energy_per_op(&self, v: f64) -> f64 {
        self.p_core_slot7(v) / self.core.theta_peak(v, 7)
    }

    /// The two [`GenerationPoint`] rows (BWN, XNOR) at a corner.
    pub fn generation_points(&self, corner: Corner) -> [GenerationPoint; 2] {
        let v = corner.v;
        let f = self.core.freq(v);
        let theta = self.core.theta_peak(v, 7);
        let bwn_w = self.core.p_core_slot7(v);
        let xnor_w = self.p_core_slot7(v);
        [
            GenerationPoint {
                mode: "YodaNN BWN",
                core_w: bwn_w,
                theta_op_s: theta,
                eff_op_s_w: theta / bwn_w,
                activation_planes: ACTIVATION_PLANES_BWN,
                io_w: self.io.power_for_kernel(f, 7, false),
            },
            GenerationPoint {
                mode: "XNOR",
                core_w: xnor_w,
                theta_op_s: theta,
                eff_op_s_w: theta / xnor_w,
                activation_planes: ACTIVATION_PLANES_XNOR,
                io_w: self.p_io(f),
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{BinaryRaster, BitplaneRaster};
    use crate::testkit::Gen;
    use crate::workload::random_image;

    #[test]
    fn activation_words_match_the_real_rasters() {
        // The analytic word count is the sizing hook for the report
        // table; it must agree with what the rasters actually allocate.
        let mut g = Gen::new(7);
        for (c, h, w, k, zp) in
            [(3usize, 12usize, 17usize, 3usize, true), (5, 9, 64, 5, false), (2, 8, 63, 7, true)]
        {
            let img = random_image(&mut g, c, h, w, 0.2);
            let mut bin = BinaryRaster::new();
            bin.pack(&img, k, zp);
            assert_eq!(
                activation_words(c, h, w, k, zp, ACTIVATION_PLANES_XNOR),
                bin.words_total(),
                "binary raster {c}x{h}x{w} k{k} zp={zp}"
            );
            let mut full = BitplaneRaster::new();
            full.pack(&img, k, zp);
            let (ph, pw) = full.padded_dims();
            assert_eq!(
                activation_words(c, h, w, k, zp, ACTIVATION_PLANES_BWN),
                c * ph * (pw.div_ceil(64) + 1) * ACTIVATION_PLANES_BWN,
                "bitplane raster geometry"
            );
        }
    }

    #[test]
    fn xnor_words_are_exactly_12x_fewer() {
        let bwn = activation_words(32, 32, 32, 3, true, ACTIVATION_PLANES_BWN);
        let xnor = activation_words(32, 32, 32, 3, true, ACTIVATION_PLANES_XNOR);
        assert_eq!(bwn, 12 * xnor);
    }

    #[test]
    fn xnor_point_dominates_bwn_at_every_corner() {
        let m = XnorPowerModel::new(ArchId::Bin32Multi);
        for v in [0.6, 0.8, 1.0, 1.2] {
            let [bwn, xnor] = m.generation_points(Corner { arch: ArchId::Bin32Multi, v });
            assert!(xnor.core_w < bwn.core_w, "core at {v} V");
            assert!(xnor.eff_op_s_w > bwn.eff_op_s_w, "efficiency at {v} V");
            assert!(xnor.io_w < bwn.io_w, "pads at {v} V");
            assert_eq!(bwn.theta_op_s, xnor.theta_op_s, "throughput held equal");
            assert_eq!(bwn.activation_planes, 12);
            assert_eq!(xnor.activation_planes, 1);
        }
        // Headline sanity: at the 0.6 V corner the derived XNOR point
        // clears 100 TOp/s/W while BWN sits at the paper's 61.2.
        let [bwn, xnor] =
            m.generation_points(Corner { arch: ArchId::Bin32Multi, v: 0.6 });
        assert!((bwn.eff_op_s_w / 1e12 - 61.2).abs() < 1.0, "{}", bwn.eff_op_s_w / 1e12);
        assert!(xnor.eff_op_s_w / 1e12 > 100.0, "{}", xnor.eff_op_s_w / 1e12);
    }

    #[test]
    fn q29_baseline_is_rejected() {
        let r = std::panic::catch_unwind(|| XnorPowerModel::new(ArchId::Q29Fixed8));
        assert!(r.is_err(), "XNOR mode must refuse the fixed-point baseline");
    }

    #[test]
    fn breakdown_reductions_touch_only_memory_and_sop() {
        let m = XnorPowerModel::new(ArchId::Bin32Multi);
        let b = m.bwn().breakdown(0.6);
        let x = m.breakdown(0.6);
        assert!((x.memory - b.memory / 12.0).abs() < 1e-15);
        assert!((x.sop - b.sop / XNOR_SOP_RATIO).abs() < 1e-15);
        assert_eq!(x.filter_bank, b.filter_bank);
        assert_eq!(x.scale_bias, b.scale_bias);
        assert_eq!(x.other, b.other);
    }
}
