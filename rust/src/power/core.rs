//! Core power model: `P(V) = C_eff(V) · V² · f(V)`, per architecture,
//! kernel mode and utilization.

use super::calib;
use super::vf::VfCurve;
use crate::model::KernelMode;

/// The architecture variants evaluated across the paper's tables/figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArchId {
    /// Fixed-point Q2.9 12-bit-MAC baseline, 8×8 channels, SRAM (Table I).
    Q29Fixed8,
    /// Binary weights, 8×8 channels, SCM, fixed 7×7 kernels (Table I).
    Bin8,
    /// Binary 16×16 channels, multi-kernel (Table II).
    Bin16,
    /// Binary 32×32 channels, fixed 7×7 kernels (Table II "32² (fixed)").
    Bin32Fixed,
    /// The final YodaNN: binary, 32×32 channels, multi-kernel support.
    Bin32Multi,
}

impl ArchId {
    /// Channels processed in parallel (n_ch × n_ch).
    pub fn n_ch(self) -> usize {
        match self {
            ArchId::Q29Fixed8 | ArchId::Bin8 => 8,
            ArchId::Bin16 => 16,
            ArchId::Bin32Fixed | ArchId::Bin32Multi => 32,
        }
    }

    /// Whether the architecture supports the dual 5×5/3×3 kernel modes.
    pub fn multi_kernel(self) -> bool {
        matches!(self, ArchId::Bin16 | ArchId::Bin32Multi)
    }

    /// Whether weights are binary (vs 12-bit Q2.9).
    pub fn binary_weights(self) -> bool {
        !matches!(self, ArchId::Q29Fixed8)
    }

    /// Minimum operating voltage — 0.8 V for the SRAM baseline, 0.6 V for
    /// latch-based SCM designs (§III-C).
    pub fn v_min(self) -> f64 {
        match self {
            ArchId::Q29Fixed8 => calib::V_MIN_SRAM,
            _ => calib::V_MIN_SCM,
        }
    }

    /// Display name used in tables.
    pub fn name(self) -> &'static str {
        match self {
            ArchId::Q29Fixed8 => "Q2.9 8x8",
            ArchId::Bin8 => "Bin 8x8",
            ArchId::Bin16 => "Bin 16x16",
            ArchId::Bin32Fixed => "Bin 32x32 (fixed 7x7)",
            ArchId::Bin32Multi => "YodaNN 32x32",
        }
    }

    /// All variants, in Table-II column order.
    pub fn all() -> [ArchId; 5] {
        [ArchId::Q29Fixed8, ArchId::Bin8, ArchId::Bin16, ArchId::Bin32Fixed, ArchId::Bin32Multi]
    }
}

/// Per-unit power split (Fig. 12): image memory, SoP array, filter bank,
/// scale-bias, other (controller, clock tree, image bank). Watts.
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    /// Image memory (SRAM or SCM banks).
    pub memory: f64,
    /// SoP units (adders, complement-mux / MAC units).
    pub sop: f64,
    /// Filter bank shift registers.
    pub filter_bank: f64,
    /// Scale-Bias unit.
    pub scale_bias: f64,
    /// Controller, image bank, clock tree.
    pub other: f64,
}

impl PowerBreakdown {
    /// Total core power.
    pub fn total(&self) -> f64 {
        self.memory + self.sop + self.filter_bank + self.scale_bias + self.other
    }
}

/// The calibrated core power model for one architecture.
#[derive(Debug, Clone)]
pub struct CorePowerModel {
    /// Architecture this model describes.
    pub arch: ArchId,
    /// Fitted V→f curve.
    pub vf: VfCurve,
    /// Power anchors (V, W) at f(V), full 7×7 utilization.
    anchors: Vec<(f64, f64)>,
}

impl CorePowerModel {
    /// Build the calibrated model for `arch` (anchors from [`calib`]).
    pub fn new(arch: ArchId) -> CorePowerModel {
        use calib::{core_power as cp, freq};
        // The 3-corner binary fit provides the shared alpha exponent.
        let bin8 = VfCurve::fit3(freq::BIN_8, calib::V_MIN_SCM, calib::V_NOM);
        let (vf, anchors): (VfCurve, Vec<(f64, f64)>) = match arch {
            ArchId::Q29Fixed8 => (
                VfCurve::fit2(freq::Q29_8, bin8.alpha, calib::V_MIN_SRAM, calib::V_NOM),
                cp::Q29_8.to_vec(),
            ),
            ArchId::Bin8 => (bin8, cp::BIN_8.to_vec()),
            ArchId::Bin16 => (
                VfCurve::fit2(freq::BIN_32, bin8.alpha, calib::V_MIN_SCM, calib::V_NOM),
                cp::BIN_16.to_vec(),
            ),
            ArchId::Bin32Fixed => (
                VfCurve::fit2(freq::BIN_32, bin8.alpha, calib::V_MIN_SCM, calib::V_NOM),
                cp::BIN_32_FIXED.to_vec(),
            ),
            ArchId::Bin32Multi => (
                VfCurve::fit2(freq::BIN_32, bin8.alpha, calib::V_MIN_SCM, calib::V_NOM),
                cp::BIN_32_MULTI.to_vec(),
            ),
        };
        CorePowerModel { arch, vf, anchors }
    }

    /// Maximum clock frequency at supply `v`.
    pub fn freq(&self, v: f64) -> f64 {
        self.vf.freq(v)
    }

    /// Effective switched capacitance at `v`, linearly interpolated between
    /// the measured anchors (clamped at the ends). Voltage dependence
    /// captures the growing leakage/short-circuit share at high V that the
    /// measured corners exhibit.
    pub fn ceff(&self, v: f64) -> f64 {
        let c = |&(av, ap): &(f64, f64)| ap / (av * av * self.vf.freq(av));
        let first = self.anchors.first().unwrap();
        let last = self.anchors.last().unwrap();
        if v <= first.0 {
            return c(first);
        }
        if v >= last.0 {
            return c(last);
        }
        for w in self.anchors.windows(2) {
            let (a, b) = (w[0], w[1]);
            if (a.0..=b.0).contains(&v) {
                let t = (v - a.0) / (b.0 - a.0);
                return c(&a) + t * (c(&b) - c(&a));
            }
        }
        unreachable!()
    }

    /// Core power (W) at supply `v`, native 7×7 mode, full utilization,
    /// running at f(v).
    pub fn p_core_slot7(&self, v: f64) -> f64 {
        self.ceff(v) * v * v * self.vf.freq(v)
    }

    /// Core power for a kernel of size `k` at full utilization, with an
    /// explicit multi-kernel capability. Dual-filter modes apply the
    /// calibrated mode ratios; zero-padded kernels inside a larger slot
    /// switch proportionally fewer operand bits (k²/slot_k²).
    pub fn p_core_mode(&self, v: f64, k: usize, multi: bool) -> f64 {
        let base = self.p_core_slot7(v);
        if !multi {
            // Fixed-kernel architectures zero-pad everything into 7×7.
            return base * (k * k) as f64 / 49.0;
        }
        let mode = KernelMode::for_kernel(k);
        let slot = mode.slot_k();
        let ratio = match mode {
            KernelMode::Slot7 => calib::MODE_RATIO_SLOT7,
            KernelMode::Slot5 => calib::MODE_RATIO_SLOT5,
            KernelMode::Slot3 => calib::MODE_RATIO_SLOT3,
        };
        base * ratio * (k * k) as f64 / (slot * slot) as f64
    }

    /// [`Self::p_core_mode`] with the architecture's own capability.
    pub fn p_core(&self, v: f64, k: usize) -> f64 {
        self.p_core_mode(v, k, self.arch.multi_kernel())
    }

    /// Workload power factor P̃_real for a given active-cycle fraction
    /// (Table III's P̃ column): silenced SoPs burn only the idle fraction.
    pub fn p_real(activity: f64) -> f64 {
        activity + calib::IDLE_FRACTION * (1.0 - activity)
    }

    /// Peak throughput (Op/s) at `v` for kernel size `k` — Eq. 6 with the
    /// dual-filter output parallelism and counting only the k² useful ops
    /// for zero-padded kernels. `multi` selects dual-filter capability.
    pub fn theta_peak_mode(&self, v: f64, k: usize, multi: bool) -> f64 {
        let filters = if multi { KernelMode::for_kernel(k).filters_per_sop() } else { 1 };
        2.0 * (k * k) as f64 * (self.arch.n_ch() * filters) as f64 * self.vf.freq(v)
    }

    /// [`Self::theta_peak_mode`] with the architecture's own capability.
    pub fn theta_peak(&self, v: f64, k: usize) -> f64 {
        self.theta_peak_mode(v, k, self.arch.multi_kernel())
    }

    /// Fig. 12-style per-unit breakdown at `v` (scaled from the 400 MHz /
    /// 1.2 V calibration split by total power).
    pub fn breakdown(&self, v: f64) -> PowerBreakdown {
        use calib::breakdown_400mhz as bd;
        let split = match self.arch {
            ArchId::Q29Fixed8 => bd::Q29_8,
            ArchId::Bin8 => bd::BIN_8,
            ArchId::Bin16 => bd::BIN_16,
            ArchId::Bin32Fixed => bd::BIN_32_FIXED,
            ArchId::Bin32Multi => bd::BIN_32_MULTI,
        };
        let split_total: f64 = split.iter().sum();
        // The split defines per-unit *fractions*; the absolute level at any
        // voltage comes from the calibrated total core power.
        let s = self.p_core_slot7(v) / split_total;
        PowerBreakdown {
            memory: split[0] * s,
            sop: split[1] * s,
            filter_bank: split[2] * s,
            scale_bias: split[3] * s,
            other: split[4] * s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, rel: f64) -> bool {
        (a - b).abs() / b.abs() < rel
    }

    #[test]
    fn table1_core_anchor_reproduction() {
        // Table I "Avg. Power Core" rows must reproduce exactly (anchors).
        let q29 = CorePowerModel::new(ArchId::Q29Fixed8);
        assert!(close(q29.p_core_slot7(1.2), 185.0e-3, 1e-6));
        assert!(close(q29.p_core_slot7(0.8), 31.0e-3, 1e-6));
        let bin = CorePowerModel::new(ArchId::Bin8);
        assert!(close(bin.p_core_slot7(1.2), 39.0e-3, 1e-6));
        assert!(close(bin.p_core_slot7(0.8), 5.1e-3, 1e-6));
        assert!(close(bin.p_core_slot7(0.6), 0.26e-3, 1e-6));
    }

    #[test]
    fn table1_peak_throughput() {
        let q29 = CorePowerModel::new(ArchId::Q29Fixed8);
        assert!(close(q29.theta_peak(1.2, 7) / 1e9, 348.0, 0.01));
        assert!(close(q29.theta_peak(0.8, 7) / 1e9, 131.0, 0.01));
        let bin = CorePowerModel::new(ArchId::Bin8);
        assert!(close(bin.theta_peak(1.2, 7) / 1e9, 377.0, 0.01));
        assert!(close(bin.theta_peak(0.8, 7) / 1e9, 149.0, 0.01));
        assert!(close(bin.theta_peak(0.6, 7) / 1e9, 15.0, 0.01));
    }

    #[test]
    fn headline_numbers() {
        // 1510 GOp/s @ 1.2 V and 61.2 TOp/s/W / 895 µW @ 0.6 V.
        let chip = CorePowerModel::new(ArchId::Bin32Multi);
        assert!(close(chip.theta_peak(1.2, 7) / 1e9, 1505.0, 0.01));
        assert!(close(chip.theta_peak(0.6, 7) / 1e9, 55.0, 0.01));
        assert!(close(chip.p_core_slot7(0.6), 0.8963e-3, 1e-6));
        let en_eff = chip.theta_peak(0.6, 7) / chip.p_core_slot7(0.6) / 1e12;
        assert!(close(en_eff, 61.2, 0.01), "peak energy efficiency {en_eff}");
    }

    #[test]
    fn table1_binary_08v_efficiency_interpolates() {
        // 29.05 TOp/s/W @ 0.8 V is an anchored corner.
        let bin = CorePowerModel::new(ArchId::Bin8);
        let e = bin.theta_peak(0.8, 7) / bin.p_core_slot7(0.8) / 1e12;
        assert!(close(e, 29.05, 0.02), "{e}");
    }

    #[test]
    fn chip_08v_is_physically_between_corners() {
        let chip = CorePowerModel::new(ArchId::Bin32Multi);
        let p08 = chip.p_core_slot7(0.8);
        assert!(p08 > chip.p_core_slot7(0.6) && p08 < chip.p_core_slot7(1.2));
        // Energy efficiency at 0.8 V should sit between the corners too
        // (≈29 TOp/s/W, mirroring the 8×8 binary variant).
        let e = chip.theta_peak(0.8, 7) / p08 / 1e12;
        assert!((20.0..40.0).contains(&e), "{e}");
    }

    #[test]
    fn mode_powers_match_table3_rows() {
        let chip = CorePowerModel::new(ArchId::Bin32Multi);
        // Fully-utilized 3×3 layers: 20.1 GOp/s at 59.2 TOp/s/W (0.6 V).
        let p3 = chip.p_core(0.6, 3);
        assert!(close(p3, 0.3405e-3, 0.01), "{p3}");
        let e3 = chip.theta_peak(0.6, 3) / p3 / 1e12;
        assert!(close(e3, 59.2, 0.02), "{e3}");
        // 5×5 mode: 1.054 mW.
        assert!(close(chip.p_core(0.6, 5), 1.054e-3, 0.01));
        // Zero-padded 6×6 burns less than native 7×7.
        assert!(chip.p_core(0.6, 6) < chip.p_core(0.6, 7));
    }

    #[test]
    fn p_real_matches_table3() {
        // Activity 3/32 → P̃ ≈ 0.35 (first-layer rows).
        let p = CorePowerModel::p_real(3.0 / 32.0);
        assert!(close(p, 0.35, 0.01), "{p}");
        assert!(close(CorePowerModel::p_real(1.0), 1.0, 1e-12));
    }

    #[test]
    fn breakdown_sums_to_core_power() {
        for arch in ArchId::all() {
            let m = CorePowerModel::new(arch);
            let b = m.breakdown(1.2);
            assert!(close(b.total(), m.p_core_slot7(1.2), 1e-9), "{arch:?}");
        }
    }

    #[test]
    fn binary_unit_reduction_ratios() {
        // §IV-C: moving 8×8 Q2.9 → binary reduces SCM ÷3.5, SoP ÷4.8,
        // filter bank ÷31 (our calibration split encodes these).
        // The paper compares the designs as-measured, each at its own
        // f(1.2 V) — so the ratios apply to the absolute unit powers.
        let q = CorePowerModel::new(ArchId::Q29Fixed8).breakdown(1.2);
        let b = CorePowerModel::new(ArchId::Bin8).breakdown(1.2);
        assert!(close(q.memory / b.memory, 3.5, 0.05));
        assert!(close(q.sop / b.sop, 4.8, 0.05));
        assert!(close(q.filter_bank / b.filter_bank, 31.0, 0.05));
    }
}
