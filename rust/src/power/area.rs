//! Area model in gate equivalents (Fig. 6 area breakdown + §IV-B
//! floorplan: SCM 480 kGE, filter bank 333 kGE, SoP 215 kGE, image bank
//! 123 kGE, 1261 kGE core total; Table I: 0.72 MGE Q2.9 vs 0.60 MGE
//! binary at 8×8).

use super::calib::{self, area_kge};
use super::core::ArchId;

/// Per-unit area in kGE.
#[derive(Debug, Clone, Copy)]
pub struct AreaBreakdown {
    /// Image memory (SRAM macro or latch-based SCM banks).
    pub memory: f64,
    /// Filter bank (12-bit or binary weight storage).
    pub filter_bank: f64,
    /// SoP units (MAC or complement-mux + adder trees).
    pub sop: f64,
    /// Image bank window cache.
    pub image_bank: f64,
    /// Scale-Bias unit.
    pub scale_bias: f64,
    /// Controller, I/O, interconnect.
    pub other: f64,
}

impl AreaBreakdown {
    /// Total core area in kGE.
    pub fn total_kge(&self) -> f64 {
        self.memory + self.filter_bank + self.sop + self.image_bank + self.scale_bias + self.other
    }

    /// Total core area in MGE.
    pub fn total_mge(&self) -> f64 {
        self.total_kge() / 1000.0
    }
}

fn from_calib(a: [f64; 6]) -> AreaBreakdown {
    AreaBreakdown {
        memory: a[0],
        filter_bank: a[1],
        sop: a[2],
        image_bank: a[3],
        scale_bias: a[4],
        other: a[5],
    }
}

/// Area breakdown of an architecture variant.
pub fn area_breakdown(arch: ArchId) -> AreaBreakdown {
    from_calib(match arch {
        ArchId::Q29Fixed8 => area_kge::Q29_8,
        ArchId::Bin8 => area_kge::BIN_8,
        ArchId::Bin16 => area_kge::BIN_16,
        ArchId::Bin32Fixed => area_kge::BIN_32_FIXED,
        ArchId::Bin32Multi => area_kge::BIN_32_MULTI,
    })
}

/// Area (MGE) used for the paper's GOp/s/MGE metrics. For the final chip
/// the paper's headline divides by the abstract's 1.33 MGE (which includes
/// clock tree and fill the floorplan excludes); other variants use their
/// Table-I core areas.
pub fn metric_area_mge(arch: ArchId) -> f64 {
    match arch {
        ArchId::Bin32Multi => calib::CHIP_AREA_MGE,
        _ => area_breakdown(arch).total_mge(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floorplan_totals() {
        // §IV-B: 1261 kGE core.
        let a = area_breakdown(ArchId::Bin32Multi);
        assert!((a.total_kge() - 1261.0).abs() < 1.0, "{}", a.total_kge());
        assert!((a.memory - 480.0).abs() < 1e-9);
        assert!((a.filter_bank - 333.0).abs() < 1e-9);
        assert!((a.sop - 215.0).abs() < 1e-9);
        assert!((a.image_bank - 123.0).abs() < 1e-9);
    }

    #[test]
    fn table1_areas() {
        assert!((area_breakdown(ArchId::Q29Fixed8).total_mge() - 0.72).abs() < 0.01);
        assert!((area_breakdown(ArchId::Bin8).total_mge() - 0.60).abs() < 0.01);
    }

    #[test]
    fn binary_shrinks_sop_and_filter_bank() {
        // §III-B: SoP ÷5.3, filter bank ÷14.9 moving Q2.9 → binary (8×8).
        let q = area_breakdown(ArchId::Q29Fixed8);
        let b = area_breakdown(ArchId::Bin8);
        assert!((q.sop / b.sop - 5.3).abs() < 0.1, "{}", q.sop / b.sop);
        assert!((q.filter_bank / b.filter_bank - 14.9).abs() < 1.0);
        // ...but the SCM image memory is larger than the SRAM (Fig. 6).
        assert!(b.memory > q.memory);
    }

    #[test]
    fn multi_kernel_area_overhead() {
        // §IV-C: +11.2% core area for multi-kernel support.
        let fixed = area_breakdown(ArchId::Bin32Fixed).total_kge();
        let multi = area_breakdown(ArchId::Bin32Multi).total_kge();
        assert!((multi / fixed - 1.112).abs() < 0.01, "{}", multi / fixed);
    }

    #[test]
    fn headline_area_efficiency() {
        // 1510 GOp/s / 1.33 MGE ⇒ 1135 GOp/s/MGE.
        let eff = 1510.0 / metric_area_mge(ArchId::Bin32Multi);
        assert!((eff - 1135.0).abs() < 5.0, "{eff}");
    }
}
