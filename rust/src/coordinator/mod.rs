//! Layer 3 — the off-chip coordinator.
//!
//! YodaNN accelerates one ≤ `n_ch × n_ch(×2)` channel block over one
//! image tile at a time; everything else is the host's job (paper
//! Algorithm 1 lines 1–3 and 37):
//!
//! * [`blocks`] — decompose a convolution layer into index-only
//!   [`crate::engine::BlockPlan`]s (zero-copy) or materialized
//!   [`crate::hw::BlockJob`]s: output-channel blocks, input-channel
//!   blocks, and vertical image tiles with `k − 1` rows of overlap (the
//!   η_tile cost of Eq. 9);
//! * [`executor`] — run the planned blocks on a pool of convolution
//!   engines ([`crate::engine::ConvEngine`]: cycle-accurate or
//!   functional popcount), accumulate input-channel partial sums
//!   off-chip, apply the final scale/bias, and merge activity
//!   statistics;
//! * [`session`] — batched multi-frame inference: a persistent worker
//!   pool with `Arc`-shared kernels/scale-bias and reusable accumulator
//!   buffers runs a whole network over frame batches with one setup,
//!   scheduled per frame, per shard, or hybrid ([`ShardPolicy`]). This
//!   is the engine behind the serving facade ([`crate::api::Yodann`]);
//!   its own `run_frame`/`run_batch` surface is deprecated in favor of
//!   the facade's validated, ticketed, telemetry-carrying one;
//! * [`shard`] — multi-chip sharded execution: a layer's output striped
//!   across a [`ShardGrid`] of chip instances, each resolving its input
//!   halo against the shared layer raster, with per-shard activity for
//!   the power/throughput roll-ups;
//! * [`golden`] (feature `golden`) — check block outputs bit-for-bit
//!   against the AOT-compiled JAX/Pallas golden model via
//!   `crate::runtime`;
//! * [`metrics`] — roll simulated cycles/energy into the paper's metrics
//!   (Θ, TOp/s/W, FPS, J/frame) for cross-validation against the
//!   analytic model of [`crate::model::efficiency`].

// The serving path runs through this layer on every frame: like fault/,
// api/ and serve/, it must not panic on a recoverable condition.
// Invariant violations that *should* stop the world use explicit
// panic!/unreachable! with a message, never unwrap/expect.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod blocks;
pub mod executor;
#[cfg(feature = "golden")]
pub mod golden;
pub mod metrics;
pub mod session;
pub mod shard;

pub use blocks::{decompose, plan_layer, LayerWorkload};
pub use executor::{run_layer, run_layer_engine, run_layer_with, ExecOptions, LayerRun};
#[cfg(feature = "golden")]
pub use golden::{check_block, GoldenReport};
pub use metrics::SimMetrics;
pub use session::{NetworkSession, SessionLayerSpec};
pub use shard::{
    plan_layer_shards, run_layer_sharded, LayerShard, ShardActivity, ShardGrid, ShardPolicy,
    ShardedLayerRun,
};
