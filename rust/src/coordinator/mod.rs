//! Layer 3 — the off-chip coordinator.
//!
//! YodaNN accelerates one ≤ `n_ch × n_ch(×2)` channel block over one
//! image tile at a time; everything else is the host's job (paper
//! Algorithm 1 lines 1–3 and 37):
//!
//! * [`blocks`] — decompose a convolution layer into [`hw::BlockJob`]s:
//!   output-channel blocks, input-channel blocks, and vertical image
//!   tiles with `k − 1` rows of overlap (the η_tile cost of Eq. 9);
//! * [`executor`] — run the jobs on one or more simulated chips using a
//!   `std::thread` worker pool (tokio is unavailable offline; blocks are
//!   independent up to the partial-sum reduction), accumulate
//!   input-channel partial sums off-chip, apply the final scale/bias,
//!   and merge activity statistics;
//! * [`golden`] — check simulator block outputs bit-for-bit against the
//!   AOT-compiled JAX/Pallas golden model via [`crate::runtime`];
//! * [`metrics`] — roll simulated cycles/energy into the paper's metrics
//!   (Θ, TOp/s/W, FPS, J/frame) for cross-validation against the
//!   analytic model of [`crate::model::efficiency`].

pub mod blocks;
pub mod executor;
pub mod golden;
pub mod metrics;

pub use blocks::{decompose, LayerWorkload};
pub use executor::{run_layer, ExecOptions, LayerRun};
pub use golden::{check_block, GoldenReport};
pub use metrics::SimMetrics;
