//! Roll simulated activity into the paper's metrics, for direct
//! comparison with the analytic model (Tables III–V) — the simulator and
//! the analytic formulas are independent derivations of the same chip,
//! so agreement here validates both.

use crate::hw::ChipStats;
use crate::hw::EnergyModel;
use crate::power::{ArchId, CorePowerModel, IoPowerModel};

/// Metrics of a simulated run at an operating corner.
#[derive(Debug, Clone, Copy)]
pub struct SimMetrics {
    /// Supply voltage.
    pub v: f64,
    /// Clock frequency (Hz).
    pub f: f64,
    /// Total cycles.
    pub cycles: u64,
    /// Wall-clock chip time (s).
    pub time: f64,
    /// Useful operations (Eq. 7 accounting).
    pub ops: u64,
    /// Actual throughput Θ_real (Op/s).
    pub theta: f64,
    /// Core energy (J).
    pub core_energy: f64,
    /// Core energy efficiency (Op/J).
    pub en_eff: f64,
    /// Device power including pads (W), averaged over the run.
    pub device_power: f64,
}

/// Compute corner metrics from merged simulator statistics.
pub fn sim_metrics(stats: &ChipStats, arch: ArchId, v: f64, dual_stream: bool) -> SimMetrics {
    let core = CorePowerModel::new(arch);
    let f = core.freq(v);
    let em = EnergyModel::new(arch, v);
    let cycles = stats.cycles.total();
    let time = cycles as f64 / f;
    let core_energy = em.energy(stats);
    let io = if arch.binary_weights() { IoPowerModel::binary() } else { IoPowerModel::q29() };
    let mode =
        if dual_stream { crate::model::KernelMode::Slot3 } else { crate::model::KernelMode::Slot7 };
    let io_power = io.power(f, mode);
    SimMetrics {
        v,
        f,
        cycles,
        time,
        ops: stats.useful_ops,
        theta: stats.useful_ops as f64 / time,
        core_energy,
        en_eff: stats.useful_ops as f64 / core_energy,
        device_power: core_energy / time + io_power,
    }
}

/// Roll a sharded layer's per-chip activity into one multi-chip metric:
/// every shard is priced at the corner like a chip of its own
/// ([`sim_metrics`]), then reduced with [`SimMetrics::merge_parallel`] —
/// wall-clock is the critical-path chip, energy and ops add. The halo
/// rows striping re-loads (Eq. 9, now crossing chips) are *in* the
/// per-shard cycle ledgers, so the scaling curve this reports is the
/// honest one, not linear-by-construction.
pub fn sharded_metrics(
    per_shard: &[ChipStats],
    arch: ArchId,
    v: f64,
    dual_stream: bool,
) -> SimMetrics {
    let mut it = per_shard.iter().map(|s| sim_metrics(s, arch, v, dual_stream));
    let first = match it.next() {
        Some(m) => m,
        None => panic!("sharded_metrics needs at least one shard"),
    };
    it.fold(first, |a, b| a.merge_parallel(&b))
}

impl SimMetrics {
    /// Merge metrics of runs executing **in parallel** on separate chips
    /// at the same corner (a shard grid): wall time and cycles follow
    /// the critical path (max), ops and energy add, and device power is
    /// the sum of per-chip averages — the grid's aggregate envelope
    /// while all chips are busy.
    pub fn merge_parallel(&self, other: &SimMetrics) -> SimMetrics {
        assert!((self.v - other.v).abs() < 1e-12, "corner mismatch");
        let cycles = self.cycles.max(other.cycles);
        let time = self.time.max(other.time);
        let ops = self.ops + other.ops;
        let core_energy = self.core_energy + other.core_energy;
        SimMetrics {
            v: self.v,
            f: self.f,
            cycles,
            time,
            ops,
            theta: ops as f64 / time,
            core_energy,
            en_eff: ops as f64 / core_energy,
            device_power: self.device_power + other.device_power,
        }
    }

    /// Merge metrics of consecutive runs (same corner).
    pub fn merge(&self, other: &SimMetrics) -> SimMetrics {
        assert!((self.v - other.v).abs() < 1e-12, "corner mismatch");
        let cycles = self.cycles + other.cycles;
        let time = self.time + other.time;
        let ops = self.ops + other.ops;
        let core_energy = self.core_energy + other.core_energy;
        SimMetrics {
            v: self.v,
            f: self.f,
            cycles,
            time,
            ops,
            theta: ops as f64 / time,
            core_energy,
            en_eff: ops as f64 / core_energy,
            device_power: (self.device_power * self.time + other.device_power * other.time)
                / time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::CycleBreakdown;

    fn full_stats(cycles: u64, n_ch: u64) -> ChipStats {
        ChipStats {
            cycles: CycleBreakdown { compute: cycles, ..Default::default() },
            sop_active_ops: cycles * n_ch * 49,
            scm_reads: cycles * 6,
            scm_writes: cycles,
            sb_ops: cycles,
            useful_ops: cycles * 2 * 49 * n_ch,
            ..Default::default()
        }
    }

    #[test]
    fn fully_utilized_sim_matches_headline_efficiency() {
        // A fully-active 7×7 run at 0.6 V must land on the paper's
        // 61.2 TOp/s/W within the energy model's calibration error.
        let s = full_stats(1_000_000, 32);
        let m = sim_metrics(&s, ArchId::Bin32Multi, 0.6, false);
        assert!((m.theta / 1e9 - 55.0).abs() < 1.0, "{}", m.theta / 1e9);
        assert!(
            (m.en_eff / 1e12 - 61.2).abs() / 61.2 < 0.05,
            "{} TOp/s/W",
            m.en_eff / 1e12
        );
    }

    #[test]
    fn parallel_merge_takes_the_critical_path() {
        // Two unequal shards: wall time is the slower chip's, ops and
        // energy add, so throughput sits between 1x and 2x of one chip.
        let a = sim_metrics(&full_stats(4000, 32), ArchId::Bin32Multi, 0.6, false);
        let b = sim_metrics(&full_stats(1000, 32), ArchId::Bin32Multi, 0.6, false);
        let m = a.merge_parallel(&b);
        assert_eq!(m.cycles, 4000);
        assert!((m.time - a.time).abs() < 1e-15);
        assert_eq!(m.ops, a.ops + b.ops);
        assert!((m.core_energy - (a.core_energy + b.core_energy)).abs() < 1e-15);
        assert!(m.theta > a.theta && m.theta < 2.0 * a.theta);
        assert!((m.device_power - (a.device_power + b.device_power)).abs() < 1e-12);
    }

    #[test]
    fn sharded_metrics_of_balanced_shards_scales_throughput() {
        // Four equal shards: same wall-clock as one, 4x the ops — the
        // ideal-scaling corner of the model.
        let stats: Vec<ChipStats> = (0..4).map(|_| full_stats(1000, 32)).collect();
        let one = sim_metrics(&stats[0], ArchId::Bin32Multi, 0.6, false);
        let grid = sharded_metrics(&stats, ArchId::Bin32Multi, 0.6, false);
        assert_eq!(grid.cycles, one.cycles);
        assert_eq!(grid.ops, 4 * one.ops);
        assert!((grid.theta / one.theta - 4.0).abs() < 1e-9);
        // Energy per op is unchanged: parallelism is not an efficiency
        // model, only a wall-clock one.
        assert!((grid.en_eff - one.en_eff).abs() / one.en_eff < 1e-12);
    }

    #[test]
    fn merge_preserves_totals() {
        let a = sim_metrics(&full_stats(1000, 32), ArchId::Bin32Multi, 0.6, false);
        let b = sim_metrics(&full_stats(3000, 32), ArchId::Bin32Multi, 0.6, false);
        let m = a.merge(&b);
        assert_eq!(m.cycles, 4000);
        assert_eq!(m.ops, a.ops + b.ops);
        assert!((m.theta - a.theta).abs() / a.theta < 1e-9);
    }
}
