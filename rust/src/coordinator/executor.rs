//! The layer executor: runs decomposed block jobs on simulated chips,
//! reduces input-channel partial sums off-chip, applies the final
//! scale/bias, and aggregates the activity ledger.
//!
//! Concurrency model: blocks are independent up to the per-output-block
//! reduction, so a `std::thread` worker pool simulates them in parallel
//! (the offline registry has no tokio). Parallelism accelerates the
//! *simulation*; the chip-time ledger still sums every block's cycles,
//! because the real device executes blocks sequentially.
//!
//! Numerical semantics of the off-chip reduction (Algorithm 1 line 37):
//! each input-channel block leaves the chip as Q2.9 (identity scale —
//! saturating/truncating, exactly what the silicon streams); the host
//! accumulates the partials in wide precision, clamps to the Q7.9
//! accumulator range and applies the layer's α/β through the same
//! Scale-Bias datapath. A monolithic (unblocked) convolution can differ
//! by LSBs when partials saturate — an inherent property of the paper's
//! scheme, quantified in `rust/tests/integration_network.rs`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use super::blocks::{decompose, tile_row_skip, LayerWorkload, PlacedJob};
use crate::fixedpoint::{scale_bias, Q7_9};
use crate::hw::{Chip, ChipConfig, ChipStats};
use crate::workload::Image;

/// Execution options.
#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    /// Simulation worker threads (≥1).
    pub workers: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4) }
    }
}

/// Result of one simulated layer.
#[derive(Debug, Clone)]
pub struct LayerRun {
    /// Output feature map (`n_out × out_h × out_w`, raw Q2.9).
    pub output: Image,
    /// Merged activity statistics over all blocks.
    pub stats: ChipStats,
    /// Number of chip blocks executed.
    pub blocks: usize,
    /// Off-chip partial-sum additions performed (the
    /// `⌈n_in/n_ch⌉ − 1` ops/pixel the paper mentions in §III).
    pub offchip_adds: u64,
}

/// Run one convolution layer on the simulated chip.
pub fn run_layer(wl: &LayerWorkload, cfg: &ChipConfig, opts: ExecOptions) -> LayerRun {
    let jobs = decompose(wl, cfg);
    let n_jobs = jobs.len();
    let n_out = wl.kernels.n_out;
    let out_h = if wl.zero_pad { wl.input.h } else { wl.input.h - wl.k + 1 };
    let out_w = if wl.zero_pad { wl.input.w } else { wl.input.w - wl.k + 1 };

    // Run the blocks (worker pool over a shared queue).
    let results: Vec<(PlacedJob, crate::hw::BlockResult)> = run_jobs(jobs, cfg, opts);

    // Reduce: wide-precision accumulation of per-input-block partials.
    let mut acc = vec![0i64; n_out * out_h * out_w];
    let mut stats = ChipStats::default();
    let mut offchip_adds = 0u64;
    for (placed, result) in &results {
        stats.merge(&result.stats);
        let skip = tile_row_skip(wl.zero_pad, wl.k, placed.row_base);
        for o in 0..result.output.c {
            let oo = placed.out_base + o;
            for r in 0..placed.rows_valid {
                let ty = skip + r; // row inside the tile's output
                let ly = placed.row_base + r; // row in the layer output
                for x in 0..out_w {
                    let idx = (oo * out_h + ly) * out_w + x;
                    acc[idx] += result.output.at(o, ty, x);
                    if placed.in_block > 0 {
                        offchip_adds += 1;
                    }
                }
            }
        }
    }

    // Final scale/bias. Single-input-block layers already applied the
    // real α/β on-chip (straight from the Q7.9 accumulators); the host
    // only rescales when partials from several input blocks were reduced.
    let single_in_block = results.iter().all(|(p, _)| p.in_blocks == 1);
    let mut output = Image::zeros(n_out, out_h, out_w);
    for o in 0..n_out {
        for y in 0..out_h {
            for x in 0..out_w {
                let raw = acc[(o * out_h + y) * out_w + x];
                *output.at_mut(o, y, x) = if single_in_block {
                    raw
                } else {
                    scale_bias(Q7_9.saturate(raw), wl.scale_bias.alpha[o], wl.scale_bias.beta[o])
                };
            }
        }
    }

    LayerRun { output, stats, blocks: n_jobs, offchip_adds }
}

/// Execute jobs on a pool of simulated chips.
fn run_jobs(
    jobs: Vec<PlacedJob>,
    cfg: &ChipConfig,
    opts: ExecOptions,
) -> Vec<(PlacedJob, crate::hw::BlockResult)> {
    let workers = opts.workers.max(1).min(jobs.len().max(1));
    if workers <= 1 {
        let mut chip = Chip::new(*cfg);
        return jobs
            .into_iter()
            .map(|p| {
                let r = chip.run_block(&p.job);
                (p, r)
            })
            .collect();
    }
    let queue = Arc::new(Mutex::new(jobs.into_iter().enumerate().collect::<Vec<_>>()));
    let (tx, rx) = mpsc::channel();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let cfg = *cfg;
            s.spawn(move || {
                let mut chip = Chip::new(cfg);
                loop {
                    let item = queue.lock().unwrap().pop();
                    match item {
                        Some((idx, placed)) => {
                            let result = chip.run_block(&placed.job);
                            tx.send((idx, placed, result)).unwrap();
                        }
                        None => break,
                    }
                }
            });
        }
        drop(tx);
    });
    let mut collected: Vec<(usize, PlacedJob, crate::hw::BlockResult)> = rx.into_iter().collect();
    collected.sort_by_key(|(i, _, _)| *i);
    collected.into_iter().map(|(_, p, r)| (p, r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{random_image, reference_conv, BinaryKernels, ScaleBias};

    fn wl(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, seed: u64) -> LayerWorkload {
        let mut g = Gen::new(seed);
        LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g, n_in, h, w, 0.02),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn single_block_layer_matches_reference() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 4, 8, 10, 9, 11);
        let run = run_layer(&w, &cfg, ExecOptions { workers: 1 });
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        assert_eq!(run.output, want);
        assert_eq!(run.blocks, 1);
        assert_eq!(run.offchip_adds, 0);
    }

    #[test]
    fn channel_blocked_layer_matches_blocked_reference() {
        // n_in = 8 on a 4-channel chip: two input blocks, host reduction.
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 8, 4, 8, 8, 22);
        let run = run_layer(&w, &cfg, ExecOptions { workers: 2 });
        // Blocked semantics: partials are Q2.9-saturated per block. With
        // tiny amplitudes nothing saturates, so the monolithic reference
        // matches exactly.
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        assert_eq!(run.output, want);
        assert!(run.offchip_adds > 0);
    }

    #[test]
    fn vertically_tiled_layer_matches_reference() {
        // h = 40 on a chip with h_max = 16: three tiles.
        let cfg = ChipConfig::tiny(4); // image_mem_rows = 256 → h_max 64
        let mut cfg = cfg;
        cfg.image_mem_rows = 16 * 4; // h_max = 16
        let w = wl(5, 3, 4, 40, 8, 33);
        let run = run_layer(&w, &cfg, ExecOptions { workers: 3 });
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        assert_eq!(run.output, want);
        assert!(run.blocks >= 3, "{}", run.blocks);
    }

    #[test]
    fn non_padded_tiled_layer_matches_reference() {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 16 * 4;
        let mut w = wl(3, 2, 3, 30, 9, 44);
        w.zero_pad = false;
        let run = run_layer(&w, &cfg, ExecOptions::default());
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, false);
        assert_eq!(run.output, want);
    }

    #[test]
    fn parallel_and_serial_agree() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 8, 8, 12, 12, 55);
        let a = run_layer(&w, &cfg, ExecOptions { workers: 1 });
        let b = run_layer(&w, &cfg, ExecOptions { workers: 4 });
        assert_eq!(a.output, b.output);
        assert_eq!(a.stats.cycles.total(), b.stats.cycles.total());
    }
}
