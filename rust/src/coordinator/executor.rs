//! The layer executor: runs planned block jobs on a pool of convolution
//! engines, reduces input-channel partial sums off-chip, applies the
//! final scale/bias, and aggregates whatever activity ledger the engine
//! kept.
//!
//! Since the engine refactor the executor is generic over
//! [`ConvEngine`]: [`run_layer`] keeps the historical cycle-accurate
//! behavior (bit-true outputs + full stats), [`run_layer_engine`]
//! selects an engine at runtime, and [`run_layer_with`] takes any
//! engine factory (one engine is built per worker thread).
//!
//! Concurrency model: blocks are independent up to the per-output-block
//! reduction, so a `std::thread` worker pool computes them in parallel
//! (the offline registry has no tokio). Parallelism accelerates the
//! *host computation*; the chip-time ledger still sums every block's
//! cycles, because the real device executes blocks sequentially.
//!
//! Numerical semantics of the off-chip reduction (Algorithm 1 line 37):
//! each input-channel block leaves the engine as Q2.9 (identity scale —
//! saturating/truncating, exactly what the silicon streams); the host
//! accumulates the partials in wide precision, clamps to the Q7.9
//! accumulator range and applies the layer's α/β through the same
//! Scale-Bias datapath. A monolithic (unblocked) convolution can differ
//! by LSBs when partials saturate — an inherent property of the paper's
//! scheme, quantified in `rust/tests/integration_network.rs`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use super::blocks::{check_width_geometry, plan_layer, tile_row_skip, LayerWorkload};
use crate::engine::{
    BinaryRaster, BitplaneRaster, BlockPlan, ConvEngine, CycleAccurate, EngineKind, EngineOutput,
    Functional, FunctionalSimd, LayerData, PackedKernels, Xnor, XnorSimd,
};
use crate::fixedpoint::{scale_bias, Q7_9};
use crate::hw::{ChipConfig, ChipStats};
use crate::workload::{Image, ScaleBias};

/// Execution options.
#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    /// Simulation worker threads (≥1).
    pub workers: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4) }
    }
}

/// Result of one simulated layer.
#[derive(Debug, Clone)]
pub struct LayerRun {
    /// Output feature map (`n_out × out_h × out_w`, raw Q2.9).
    pub output: Image,
    /// Merged activity statistics over all blocks (all-zero except
    /// `useful_ops` when the engine keeps no ledger).
    pub stats: ChipStats,
    /// Number of chip blocks executed.
    pub blocks: usize,
    /// Off-chip partial-sum additions performed (the
    /// `⌈n_in/n_ch⌉ − 1` ops/pixel the paper mentions in §III).
    pub offchip_adds: u64,
}

/// Run one convolution layer on the cycle-accurate simulator (the
/// historical default: bit-true outputs + full activity ledger).
pub fn run_layer(wl: &LayerWorkload, cfg: &ChipConfig, opts: ExecOptions) -> LayerRun {
    let cfg_copy = *cfg;
    run_layer_with(wl, cfg, opts, move || CycleAccurate::new(cfg_copy))
}

/// Run one convolution layer on a runtime-selected engine kind.
pub fn run_layer_engine(
    wl: &LayerWorkload,
    cfg: &ChipConfig,
    opts: ExecOptions,
    kind: EngineKind,
) -> LayerRun {
    match kind {
        EngineKind::CycleAccurate => run_layer(wl, cfg, opts),
        EngineKind::Functional => run_layer_with(wl, cfg, opts, Functional::new),
        EngineKind::FunctionalPerWindow => run_layer_with(wl, cfg, opts, Functional::per_window),
        EngineKind::FunctionalSimd => run_layer_with(wl, cfg, opts, FunctionalSimd::new),
        EngineKind::FunctionalSimdScalar => {
            run_layer_with(wl, cfg, opts, FunctionalSimd::forced_scalar)
        }
        EngineKind::Xnor => run_layer_with(wl, cfg, opts, Xnor::new),
        EngineKind::XnorSimd => run_layer_with(wl, cfg, opts, XnorSimd::new),
        EngineKind::XnorSimdScalar => run_layer_with(wl, cfg, opts, XnorSimd::forced_scalar),
    }
}

/// Run one convolution layer with engines built by `make` (one engine
/// per worker thread). Blocking, tiling, reduction and final scale/bias
/// are engine-independent; outputs are bit-identical across engines.
pub fn run_layer_with<E, F>(
    wl: &LayerWorkload,
    cfg: &ChipConfig,
    opts: ExecOptions,
    make: F,
) -> LayerRun
where
    E: ConvEngine,
    F: Fn() -> E + Sync,
{
    let n_out = wl.kernels.n_out;
    // Plan first: plan_layer's geometry guard fires before the output
    // shape math can underflow on impossible layers (valid-mode h < k);
    // the width guard covers the out_w mirror of the same wrap.
    let plans = plan_layer(cfg, wl.k, wl.zero_pad, wl.input.c, n_out, wl.input.h);
    check_width_geometry(wl.zero_pad, wl.k, wl.input.w);
    let out_h = if wl.zero_pad { wl.input.h } else { wl.input.h - wl.k + 1 };
    let out_w = if wl.zero_pad { wl.input.w } else { wl.input.w - wl.k + 1 };
    let n_jobs = plans.len();

    // Pack the kernels — and the activations' bitplane raster — once per
    // layer, but only when the engine actually consumes the packed forms
    // (the cycle-accurate engine consumes neither). The raster is shared
    // read-only by every worker, so each block's windows assemble by
    // shifts instead of repacking pixels.
    let mut engine0 = make();
    let packed =
        if engine0.wants_packed() { Some(PackedKernels::pack(&wl.kernels)) } else { None };
    let raster = engine0.wants_raster().then(|| {
        let mut r = BitplaneRaster::new();
        r.pack(&wl.input, wl.k, wl.zero_pad);
        r
    });
    let binary = engine0.wants_binary_raster().then(|| {
        let mut r = BinaryRaster::new();
        r.pack(&wl.input, wl.k, wl.zero_pad);
        r
    });
    let mut data = wl.as_layer_data(packed.as_ref());
    data.raster = raster.as_ref();
    data.binary = binary.as_ref();

    let results = run_plans(&data, plans, opts, &make, &mut engine0);

    // Reduce: wide-precision accumulation of per-input-block partials.
    let mut acc = vec![0i64; n_out * out_h * out_w];
    let mut stats = ChipStats::default();
    let mut offchip_adds = 0u64;
    let mut single_in_block = true;
    for (plan, result) in &results {
        stats.merge(&result.stats);
        if plan.in_blocks > 1 {
            single_in_block = false;
        }
        offchip_adds +=
            reduce_block(&mut acc, wl.zero_pad, wl.k, out_h, out_w, plan, &result.output);
    }

    let output = finalize_output(&acc, single_in_block, &wl.scale_bias, n_out, out_h, out_w);
    LayerRun { output, stats, blocks: n_jobs, offchip_adds }
}

/// Accumulate one block's output tile into the layer-wide wide-precision
/// accumulator. Returns the off-chip additions performed (partials from
/// input blocks after the first).
pub(crate) fn reduce_block(
    acc: &mut [i64],
    zero_pad: bool,
    k: usize,
    out_h: usize,
    out_w: usize,
    plan: &BlockPlan,
    output: &Image,
) -> u64 {
    let skip = tile_row_skip(zero_pad, k, plan.row_base);
    let mut adds = 0u64;
    for o in 0..output.c {
        let oo = plan.out_base + o;
        for r in 0..plan.rows_valid {
            let ty = skip + r; // row inside the tile's output
            let ly = plan.row_base + r; // row in the layer output
            for x in 0..out_w {
                acc[(oo * out_h + ly) * out_w + x] += output.at(o, ty, x);
                if plan.in_block > 0 {
                    adds += 1;
                }
            }
        }
    }
    adds
}

/// Final scale/bias over the reduced accumulator. Single-input-block
/// layers already applied the real α/β on-chip (straight from the Q7.9
/// accumulators); the host only rescales when partials from several
/// input blocks were reduced.
pub(crate) fn finalize_output(
    acc: &[i64],
    single_in_block: bool,
    sb: &ScaleBias,
    n_out: usize,
    out_h: usize,
    out_w: usize,
) -> Image {
    let mut output = Image::zeros(n_out, out_h, out_w);
    for o in 0..n_out {
        for y in 0..out_h {
            for x in 0..out_w {
                let raw = acc[(o * out_h + y) * out_w + x];
                *output.at_mut(o, y, x) = if single_in_block {
                    raw
                } else {
                    scale_bias(Q7_9.saturate(raw), sb.alpha[o], sb.beta[o])
                };
            }
        }
    }
    output
}

/// Execute plans on a pool of engines. `engine0` is reused on the
/// single-worker path; the parallel path builds one engine per thread
/// (engines need not be `Send`). Results come back in `plans` order
/// regardless of completion order — the shard executor relies on that
/// to re-associate results with their shards.
pub(crate) fn run_plans<E, F>(
    data: &LayerData<'_>,
    plans: Vec<BlockPlan>,
    opts: ExecOptions,
    make: &F,
    engine0: &mut E,
) -> Vec<(BlockPlan, EngineOutput)>
where
    E: ConvEngine,
    F: Fn() -> E + Sync,
{
    let workers = opts.workers.max(1).min(plans.len().max(1));
    if workers <= 1 {
        return plans
            .into_iter()
            .map(|p| {
                let r = engine0.run_plan(data, &p);
                (p, r)
            })
            .collect();
    }
    let queue = Arc::new(Mutex::new(plans.into_iter().enumerate().collect::<Vec<_>>()));
    let (tx, rx) = mpsc::channel();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            s.spawn(move || {
                let mut engine = make();
                drain_queue(&mut engine, data, &queue, &tx);
            });
        }
        drop(tx);
    });
    let mut collected: Vec<(usize, BlockPlan, EngineOutput)> = rx.into_iter().collect();
    collected.sort_by_key(|(i, _, _)| *i);
    collected.into_iter().map(|(_, p, r)| (p, r)).collect()
}

/// One worker's pool loop: pop block plans until the queue drains.
///
/// Failure tolerance mirrors the session's worker pool: a poisoned
/// queue mutex (a sibling panicked mid-`pop` under `catch_unwind`
/// supervision) is recovered with `into_inner` — the plan list is a
/// plain `Vec`, valid regardless of where the panic landed — and a
/// disconnected result channel (the collector is gone) stops the worker
/// instead of panicking the whole layer.
fn drain_queue<E: ConvEngine>(
    engine: &mut E,
    data: &LayerData<'_>,
    queue: &Mutex<Vec<(usize, BlockPlan)>>,
    tx: &mpsc::Sender<(usize, BlockPlan, EngineOutput)>,
) {
    loop {
        let item = queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner).pop();
        match item {
            Some((idx, plan)) => {
                let result = engine.run_plan(data, &plan);
                if tx.send((idx, plan, result)).is_err() {
                    break;
                }
            }
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::{
        random_image, reference_conv, reference_xnor_conv, BinaryKernels, ScaleBias,
    };

    fn wl(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, seed: u64) -> LayerWorkload {
        let mut g = Gen::new(seed);
        LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g, n_in, h, w, 0.02),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn single_block_layer_matches_reference() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 4, 8, 10, 9, 11);
        let run = run_layer(&w, &cfg, ExecOptions { workers: 1 });
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        assert_eq!(run.output, want);
        assert_eq!(run.blocks, 1);
        assert_eq!(run.offchip_adds, 0);
    }

    #[test]
    fn channel_blocked_layer_matches_blocked_reference() {
        // n_in = 8 on a 4-channel chip: two input blocks, host reduction.
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 8, 4, 8, 8, 22);
        let run = run_layer(&w, &cfg, ExecOptions { workers: 2 });
        // Blocked semantics: partials are Q2.9-saturated per block. With
        // tiny amplitudes nothing saturates, so the monolithic reference
        // matches exactly.
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        assert_eq!(run.output, want);
        assert!(run.offchip_adds > 0);
    }

    #[test]
    fn vertically_tiled_layer_matches_reference() {
        // h = 40 on a chip with h_max = 16: three tiles.
        let cfg = ChipConfig::tiny(4); // image_mem_rows = 256 → h_max 64
        let mut cfg = cfg;
        cfg.image_mem_rows = 16 * 4; // h_max = 16
        let w = wl(5, 3, 4, 40, 8, 33);
        let run = run_layer(&w, &cfg, ExecOptions { workers: 3 });
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        assert_eq!(run.output, want);
        assert!(run.blocks >= 3, "{}", run.blocks);
    }

    #[test]
    fn non_padded_tiled_layer_matches_reference() {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 16 * 4;
        let mut w = wl(3, 2, 3, 30, 9, 44);
        w.zero_pad = false;
        let run = run_layer(&w, &cfg, ExecOptions::default());
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, false);
        assert_eq!(run.output, want);
    }

    #[test]
    fn thin_tiles_near_the_top_stay_correct() {
        // h_max = 7 with k = 7 forces 1-row tiles, so interior tiles
        // near the image top are still clipped (0 < row_base < offset).
        // tile_row_skip used to return `offset` there, slicing a
        // vertically shifted window out of the tile — wrong on every
        // engine. Found by the raster refactor's mirror verification.
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 7 * 4; // h_max = 7
        let w = wl(7, 2, 3, 20, 8, 77);
        let want = reference_conv(&w.input, &w.kernels, &w.scale_bias, true);
        for kind in
            [EngineKind::CycleAccurate, EngineKind::Functional, EngineKind::FunctionalPerWindow]
        {
            let run = run_layer_engine(&w, &cfg, ExecOptions { workers: 2 }, kind);
            assert_eq!(run.output, want, "engine {}", kind.name());
        }
    }

    #[test]
    #[should_panic(expected = "no output rows")]
    fn valid_mode_thin_width_fails_loudly_instead_of_wrapping() {
        // The width mirror of the h < k guard: a valid-mode layer
        // narrower than its kernel used to wrap `w − k + 1` in release
        // (debug panicked on the subtraction, with no geometry in the
        // message). The serving facade reports the same condition as a
        // typed error before frames reach here.
        let cfg = ChipConfig::tiny(4);
        let mut w = wl(5, 2, 3, 12, 3, 88); // w = 3 < k = 5
        w.zero_pad = false;
        run_layer(&w, &cfg, ExecOptions { workers: 1 });
    }

    #[test]
    fn xnor_engines_match_the_sign_reference_through_the_executor() {
        // Single input block (n_in = n_ch = 4), so the on-chip Q7.9 α/β
        // path applies and the monolithic sign reference holds exactly —
        // across the whole XNOR family, tiled and parallel.
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 16 * 4; // h_max = 16: forces row tiles at h = 20
        for k in [1usize, 3, 5, 7] {
            let w = wl(k, 4, 6, 20, 9, 12 + k as u64);
            let want = reference_xnor_conv(&w.input, &w.kernels, &w.scale_bias, true);
            for kind in EngineKind::XNOR {
                let run = run_layer_engine(&w, &cfg, ExecOptions { workers: 3 }, kind);
                assert_eq!(run.output, want, "engine {} k {k}", kind.name());
            }
        }
    }

    #[test]
    fn parallel_and_serial_agree() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 8, 8, 12, 12, 55);
        let a = run_layer(&w, &cfg, ExecOptions { workers: 1 });
        let b = run_layer(&w, &cfg, ExecOptions { workers: 4 });
        assert_eq!(a.output, b.output);
        assert_eq!(a.stats.cycles.total(), b.stats.cycles.total());
    }

    #[test]
    fn drain_queue_recovers_from_poison_and_disconnect() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(3, 4, 4, 8, 8, 99);
        let data = w.as_layer_data(None);
        let plan = BlockPlan::whole(w.k, w.zero_pad, 4, 4, w.input.h);

        // Poison the queue mutex the way a panicking sibling under
        // catch_unwind supervision would.
        let queue = Arc::new(Mutex::from(vec![(0usize, plan), (1usize, plan)]));
        {
            let q = Arc::clone(&queue);
            let _ = std::thread::spawn(move || {
                let _guard = q.lock();
                panic!("poison the plan queue");
            })
            .join();
        }
        assert!(queue.is_poisoned());
        let (tx, rx) = mpsc::channel();
        let mut engine = CycleAccurate::new(cfg);
        drain_queue(&mut engine, &data, &queue, &tx);
        drop(tx);
        // Both plans drained through the poisoned lock, results intact.
        assert_eq!(rx.into_iter().count(), 2);

        // A disconnected collector must stop the worker, not panic it.
        let queue = Mutex::from(vec![(0usize, plan)]);
        let (tx, rx) = mpsc::channel();
        drop(rx);
        drain_queue(&mut engine, &data, &queue, &tx);
        assert!(
            queue.lock().unwrap_or_else(std::sync::PoisonError::into_inner).is_empty(),
            "the worker must consume the queue even with the collector gone"
        );
    }

    #[test]
    fn engine_selection_is_bit_identical() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(5, 7, 6, 14, 10, 66);
        let cyc = run_layer_engine(&w, &cfg, ExecOptions { workers: 2 }, EngineKind::CycleAccurate);
        let fun = run_layer_engine(&w, &cfg, ExecOptions { workers: 2 }, EngineKind::Functional);
        let pr1 =
            run_layer_engine(&w, &cfg, ExecOptions { workers: 2 }, EngineKind::FunctionalPerWindow);
        assert_eq!(cyc.output, fun.output);
        assert_eq!(cyc.output, pr1.output);
        assert_eq!(cyc.blocks, fun.blocks);
        assert_eq!(cyc.offchip_adds, fun.offchip_adds);
        // The functional engines keep no cycle ledger.
        assert_eq!(fun.stats.cycles.total(), 0);
        assert_eq!(pr1.stats.cycles.total(), 0);
        assert!(cyc.stats.cycles.total() > 0);
        assert_eq!(fun.stats.useful_ops, cyc.stats.useful_ops);
    }
}
