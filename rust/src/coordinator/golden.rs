//! Golden checking: the cycle simulator's streamed outputs vs the
//! AOT-compiled JAX/Pallas golden model executed through PJRT — the
//! reproduction of the paper's testbench-vs-Torch-golden-model check
//! (§IV-B), with the golden model produced by a completely independent
//! implementation (Pallas kernel, XLA compilation, different language and
//! arithmetic stack).

use crate::hw::{BlockJob, Chip, ChipConfig};
use crate::runtime::Runtime;
use crate::workload::{BinaryKernels, Image, ScaleBias};
use crate::Result;

/// Outcome of one golden comparison.
#[derive(Debug, Clone)]
pub struct GoldenReport {
    /// Artifact checked.
    pub artifact: String,
    /// Total output samples compared.
    pub samples: usize,
    /// Mismatching samples (must be 0).
    pub mismatches: usize,
    /// First mismatch, if any: (channel, y, x, simulated, golden).
    pub first_mismatch: Option<(usize, usize, usize, i64, i64)>,
}

impl GoldenReport {
    /// True when simulator and golden model agree bit-for-bit.
    pub fn ok(&self) -> bool {
        self.mismatches == 0
    }
}

fn compare(artifact: &str, sim: &Image, golden: &Image) -> GoldenReport {
    assert_eq!((sim.c, sim.h, sim.w), (golden.c, golden.h, golden.w));
    let mut mismatches = 0;
    let mut first = None;
    for c in 0..sim.c {
        for y in 0..sim.h {
            for x in 0..sim.w {
                let (a, b) = (sim.at(c, y, x), golden.at(c, y, x));
                if a != b {
                    mismatches += 1;
                    if first.is_none() {
                        first = Some((c, y, x, a, b));
                    }
                }
            }
        }
    }
    GoldenReport {
        artifact: artifact.to_string(),
        samples: sim.data.len(),
        mismatches,
        first_mismatch: first,
    }
}

/// Run one block on the simulator and on the golden model, and compare.
/// The block geometry must match one of the AOT artifacts
/// (`runtime.find(...)`).
pub fn check_block(
    runtime: &mut Runtime,
    cfg: &ChipConfig,
    image: &Image,
    kernels: &BinaryKernels,
    sb: &ScaleBias,
    zero_pad: bool,
) -> Result<GoldenReport> {
    let meta = runtime
        .find(kernels.k, image.c, kernels.n_out, image.h, image.w, zero_pad)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact for k={} {}x{} {}x{} pad={} — extend python/compile/aot.py BLOCKS",
                kernels.k,
                image.c,
                kernels.n_out,
                image.h,
                image.w,
                zero_pad
            )
        })?
        .name
        .clone();

    let job = BlockJob {
        k: kernels.k,
        zero_pad,
        image: image.clone(),
        kernels: kernels.clone(),
        scale_bias: sb.clone(),
    };
    let mut chip = Chip::new(*cfg);
    let sim = chip.run_block(&job);

    let golden = runtime.golden(&meta)?.run(image, kernels, sb)?;
    Ok(compare(&meta, &sim.output, &golden))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_reports_first_mismatch() {
        let mut a = Image::zeros(1, 2, 2);
        let b = a.clone();
        let r = compare("x", &a, &b);
        assert!(r.ok());
        *a.at_mut(0, 1, 0) = 5;
        let r = compare("x", &a, &b);
        assert_eq!(r.mismatches, 1);
        assert_eq!(r.first_mismatch, Some((0, 1, 0, 5, 0)));
    }
}
