//! Multi-chip sharded execution: one layer's output feature map split
//! across a grid of simulated chip instances.
//!
//! YodaNN scales throughput by tiling feature maps across chip blocks
//! (Algorithm 1); its successor *Hyperdrive* (arXiv:1804.00623) runs the
//! same binary-weight datapath on a systolic grid of chips with border
//! exchange, and *XNORBIN* (arXiv:1803.05849) leans on feature-map
//! partitioning to stay inside on-chip memory. This module adds that
//! intra-frame axis of parallelism on top of the existing per-frame one:
//!
//! * [`ShardGrid`] — a `stripes × out_groups` partition of a layer's
//!   output: horizontal stripes of output rows × groups of output
//!   channels, each shard one independent chip instance.
//! * [`plan_layer_shards`] — balanced shard geometry for one layer.
//! * [`shard_block_plans`] — exactly [`super::blocks::plan_layer`]'s
//!   block/tile geometry, restricted to one shard. Plans carry
//!   **layer-global** coordinates, so every engine consumes them against
//!   the one shared layer raster ([`crate::engine::BitplaneRaster`]) with
//!   the k-dependent input halo rows resolved by indices — no activation
//!   is ever copied per shard — and the existing off-chip reduction
//!   stitches stripes with no coordinate translation.
//! * [`run_layer_sharded`] — the multi-chip executor: shards fan out
//!   across a worker pool, partial sums reduce into one wide
//!   accumulator, and per-shard activity is kept so the power and
//!   throughput models can price the grid
//!   ([`super::metrics::sharded_metrics`],
//!   [`crate::power::MultiChipPower`]).
//! * [`ShardPolicy`] — how a [`super::NetworkSession`] schedules a batch:
//!   frames across workers, shards across workers, or an automatic
//!   hybrid.
//!
//! **Bit-identity.** Shard boundaries never change outputs: each output
//! pixel's per-input-block partial is produced by the same window over
//! the same rows with the same in-block channel order regardless of
//! which stripe computes it, and the i64 wide reduction is
//! order-invariant. `rust/tests/conformance.rs` fuzzes this across the
//! whole engine × shard matrix.

use super::blocks::{check_plan_geometry, check_width_geometry, plan_block_range, LayerWorkload};
use super::executor::{finalize_output, reduce_block, run_plans, ExecOptions, LayerRun};
use crate::engine::{
    BinaryRaster, BitplaneRaster, BlockPlan, ConvEngine, EngineKind, PackedKernels,
};
use crate::hw::{ChipConfig, ChipStats};

/// A `stripes × out_groups` shard grid: output rows are split into
/// `stripes` horizontal stripes and output channels into `out_groups`
/// groups; every cell is computed by one independent chip instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardGrid {
    /// Horizontal stripes of output rows.
    pub stripes: usize,
    /// Output-channel groups.
    pub out_groups: usize,
}

impl ShardGrid {
    /// A validated grid (both axes ≥ 1).
    pub fn new(stripes: usize, out_groups: usize) -> ShardGrid {
        assert!(stripes >= 1 && out_groups >= 1, "shard grid must be at least 1x1");
        ShardGrid { stripes, out_groups }
    }

    /// Pure row-striping (`n × 1`), the common case.
    pub fn striped(stripes: usize) -> ShardGrid {
        ShardGrid::new(stripes, 1)
    }

    /// Chip instances in the grid.
    pub fn chips(&self) -> usize {
        self.stripes * self.out_groups
    }

    /// Parse the CLI spelling: `"N"` (stripes only) or `"NxM"`
    /// (stripes × output-channel groups).
    pub fn parse(s: &str) -> Option<ShardGrid> {
        let (a, b) = match s.split_once(['x', 'X']) {
            Some((a, b)) => (a, b),
            None => (s, "1"),
        };
        let stripes: usize = a.trim().parse().ok()?;
        let out_groups: usize = b.trim().parse().ok()?;
        if stripes == 0 || out_groups == 0 {
            return None;
        }
        Some(ShardGrid { stripes, out_groups })
    }
}

impl std::fmt::Display for ShardGrid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Through f.pad so table printers can width-align grids.
        f.pad(&format!("{}x{}", self.stripes, self.out_groups))
    }
}

/// How a [`super::NetworkSession`] schedules a batch of frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Frame-level parallelism only (the historical schedule): each
    /// worker carries one frame through every layer.
    PerFrame,
    /// Intra-frame parallelism: frames run in order, and each layer's
    /// shards fan out across the worker pool.
    PerShard(ShardGrid),
    /// Hybrid: batches with at least one frame per worker run
    /// [`ShardPolicy::PerFrame`]; smaller batches shard each frame
    /// across the idle workers (`workers × 1` stripes).
    Auto,
    /// Within-frame row-band parallelism, unconditionally: every frame's
    /// conv layers split their output rows into `n` horizontal bands
    /// (`n × 1` stripes) fanned across the worker pool against the one
    /// shared layer raster. `RowBands(0)` sizes the bands to the pool.
    /// This is the latency schedule for batch=1 traffic — the same
    /// stripe mechanics as [`ShardPolicy::PerShard`], without the
    /// channel-group axis and without waiting for `Auto`'s batch-size
    /// heuristic.
    RowBands(usize),
}

impl ShardPolicy {
    /// Parse the CLI spelling, case-insensitively: `per-frame`, `auto`,
    /// `row-bands[:N]`, `per-shard:NxM` (or a bare grid `NxM`).
    pub fn parse(s: &str) -> Option<ShardPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "per-frame" | "frame" => Some(ShardPolicy::PerFrame),
            "auto" => Some(ShardPolicy::Auto),
            "row-bands" | "bands" | "rows" => Some(ShardPolicy::RowBands(0)),
            other => {
                if let Some(n) = other.strip_prefix("row-bands:") {
                    let bands: usize = n.trim().parse().ok()?;
                    if bands == 0 {
                        return None;
                    }
                    return Some(ShardPolicy::RowBands(bands));
                }
                let g = other.strip_prefix("per-shard:").unwrap_or(other);
                ShardGrid::parse(g).map(ShardPolicy::PerShard)
            }
        }
    }

    /// Representative spellings [`ShardPolicy::parse`] accepts — every
    /// fixed token plus one exemplar of each parameterized form. The
    /// Display/parse round-trip proptest pins that all of these (and
    /// every Display form) stay parseable.
    pub const ACCEPTED: [&'static str; 10] = [
        "per-frame",
        "frame",
        "auto",
        "row-bands",
        "bands",
        "rows",
        "row-bands:3",
        "per-shard:2x2",
        "4x2",
        "4",
    ];
}

impl std::fmt::Display for ShardPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Through f.pad so table printers can width-align policies.
        let s = match self {
            ShardPolicy::PerFrame => "per-frame".to_string(),
            ShardPolicy::PerShard(g) => format!("per-shard:{g}"),
            ShardPolicy::Auto => "auto".to_string(),
            ShardPolicy::RowBands(0) => "row-bands".to_string(),
            ShardPolicy::RowBands(n) => format!("row-bands:{n}"),
        };
        f.pad(&s)
    }
}

/// One shard of a layer: the output-row stripe `row0 .. row0 + rows`
/// times the output-channel group `out0 .. out0 + out_len`, computed by
/// one chip instance. Coordinates are layer-global.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerShard {
    /// Shard index in the flattened grid (group-major).
    pub index: usize,
    /// First output row of the stripe.
    pub row0: usize,
    /// Output rows in the stripe.
    pub rows: usize,
    /// First output channel of the group.
    pub out0: usize,
    /// Output channels in the group.
    pub out_len: usize,
}

/// Partition a layer's `out_h × n_out` output space on `grid`, balanced
/// to within one row/channel. Axes larger than the space collapse (a
/// 8-stripe grid over 3 output rows yields 3 stripes), so every returned
/// shard is non-empty and the union covers the output exactly once.
pub fn plan_layer_shards(grid: ShardGrid, out_h: usize, n_out: usize) -> Vec<LayerShard> {
    let stripes = grid.stripes.min(out_h.max(1));
    let out_groups = grid.out_groups.min(n_out.max(1));
    let mut shards = Vec::with_capacity(stripes * out_groups);
    let mut out0 = 0;
    for g in 0..out_groups {
        let out_len = n_out / out_groups + usize::from(g < n_out % out_groups);
        let mut row0 = 0;
        for s in 0..stripes {
            let rows = out_h / stripes + usize::from(s < out_h % stripes);
            if rows > 0 && out_len > 0 {
                shards.push(LayerShard { index: shards.len(), row0, rows, out0, out_len });
            }
            row0 += rows;
        }
        out0 += out_len;
    }
    shards
}

/// Plan one shard's chip blocks: [`super::blocks::plan_layer`]'s exact
/// output-channel blocking and vertical tiling, restricted to the
/// shard's stripe and channel group. The stripe's first tile re-loads
/// the `k − 1` halo rows above `row0` (clipped at the image border) —
/// the same Eq. 9 overlap the intra-chip tiles pay, now crossing chips.
pub fn shard_block_plans(
    cfg: &ChipConfig,
    k: usize,
    zero_pad: bool,
    n_in: usize,
    h: usize,
    shard: &LayerShard,
) -> Vec<BlockPlan> {
    plan_block_range(
        cfg, k, zero_pad, n_in, h, shard.row0, shard.rows, shard.out0, shard.out_len,
    )
}

/// Activity of one shard (one chip instance) in a sharded layer run.
#[derive(Debug, Clone)]
pub struct ShardActivity {
    /// The shard's geometry.
    pub shard: LayerShard,
    /// Merged activity of the shard's blocks (this chip's ledger).
    pub stats: ChipStats,
    /// Blocks the shard executed.
    pub blocks: usize,
}

/// Result of a multi-chip sharded layer run: the stitched layer output
/// plus the per-chip activity the power/throughput models aggregate.
#[derive(Debug, Clone)]
pub struct ShardedLayerRun {
    /// The stitched layer result (stats merged over every shard — the
    /// total activity of the grid; wall-clock parallelism is priced by
    /// [`super::metrics::sharded_metrics`] over [`Self::per_shard`]).
    pub run: LayerRun,
    /// Per-shard activity, indexed like [`plan_layer_shards`]'s output.
    pub per_shard: Vec<ShardActivity>,
    /// The grid that was executed.
    pub grid: ShardGrid,
}

/// Run one convolution layer sharded on `grid`: every shard's blocks fan
/// out across `opts.workers` threads, all consuming the one shared
/// kernel pack + layer raster; the host stitches stripes through the
/// same wide-precision reduction the unsharded executor uses. Outputs
/// are **bit-identical** to [`super::executor::run_layer_engine`] for
/// every engine kind and every grid.
pub fn run_layer_sharded(
    wl: &LayerWorkload,
    cfg: &ChipConfig,
    opts: ExecOptions,
    kind: EngineKind,
    grid: ShardGrid,
) -> ShardedLayerRun {
    let n_out = wl.kernels.n_out;
    // Guard first: the output shape math below underflows on impossible
    // layers (valid-mode h < k, and its w < k mirror) before any
    // per-shard planning would.
    check_plan_geometry(cfg, wl.k, wl.zero_pad, wl.input.h);
    check_width_geometry(wl.zero_pad, wl.k, wl.input.w);
    let out_h = if wl.zero_pad { wl.input.h } else { wl.input.h - wl.k + 1 };
    let out_w = if wl.zero_pad { wl.input.w } else { wl.input.w - wl.k + 1 };
    let shards = plan_layer_shards(grid, out_h, n_out);
    let mut shard_of: Vec<usize> = Vec::new();
    let mut plans: Vec<BlockPlan> = Vec::new();
    for s in &shards {
        for p in shard_block_plans(cfg, wl.k, wl.zero_pad, wl.input.c, wl.input.h, s) {
            shard_of.push(s.index);
            plans.push(p);
        }
    }
    let n_jobs = plans.len();

    // Shared read-only forms, packed once per layer exactly like
    // `run_layer_with`: kernel words and the layer-resident raster.
    let packed = kind.wants_packed().then(|| PackedKernels::pack(&wl.kernels));
    let raster = kind.wants_raster().then(|| {
        let mut r = BitplaneRaster::new();
        r.pack(&wl.input, wl.k, wl.zero_pad);
        r
    });
    let binary = kind.wants_binary_raster().then(|| {
        let mut r = BinaryRaster::new();
        r.pack(&wl.input, wl.k, wl.zero_pad);
        r
    });
    let mut data = wl.as_layer_data(packed.as_ref());
    data.raster = raster.as_ref();
    data.binary = binary.as_ref();

    // The executor's worker pool returns results in `plans` order, so
    // `shard_of[i]` re-associates `results[i]` with its chip.
    let make = || kind.build(*cfg);
    let mut engine0: Box<dyn ConvEngine> = make();
    let results = run_plans(&data, plans, opts, &make, &mut engine0);

    let mut acc = vec![0i64; n_out * out_h * out_w];
    let mut per_shard: Vec<ShardActivity> = shards
        .iter()
        .map(|s| ShardActivity { shard: *s, stats: ChipStats::default(), blocks: 0 })
        .collect();
    let mut stats = ChipStats::default();
    let mut offchip_adds = 0u64;
    let mut single_in_block = true;
    for (sidx, (plan, result)) in shard_of.iter().zip(results.iter()) {
        stats.merge(&result.stats);
        per_shard[*sidx].stats.merge(&result.stats);
        per_shard[*sidx].blocks += 1;
        if plan.in_blocks > 1 {
            single_in_block = false;
        }
        offchip_adds +=
            reduce_block(&mut acc, wl.zero_pad, wl.k, out_h, out_w, plan, &result.output);
    }
    let output = finalize_output(&acc, single_in_block, &wl.scale_bias, n_out, out_h, out_w);
    ShardedLayerRun {
        run: LayerRun { output, stats, blocks: n_jobs, offchip_adds },
        per_shard,
        grid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_layer_engine;
    use crate::testkit::Gen;
    use crate::workload::{random_image, BinaryKernels, ScaleBias};

    fn wl(k: usize, n_in: usize, n_out: usize, h: usize, w: usize, seed: u64) -> LayerWorkload {
        let mut g = Gen::new(seed);
        LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g, n_in, h, w, 0.05),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::random(&mut g, n_out),
        }
    }

    #[test]
    fn grid_parses_cli_spellings() {
        assert_eq!(ShardGrid::parse("4"), Some(ShardGrid::striped(4)));
        assert_eq!(ShardGrid::parse("2x3"), Some(ShardGrid::new(2, 3)));
        assert_eq!(ShardGrid::parse("2X3"), Some(ShardGrid::new(2, 3)));
        assert_eq!(ShardGrid::parse("0x2"), None);
        assert_eq!(ShardGrid::parse("2x"), None);
        assert_eq!(ShardGrid::parse("nope"), None);
        assert_eq!(ShardGrid::new(2, 3).chips(), 6);
        assert_eq!(ShardGrid::new(2, 3).to_string(), "2x3");
    }

    #[test]
    fn policy_parses_cli_spellings() {
        assert_eq!(ShardPolicy::parse("per-frame"), Some(ShardPolicy::PerFrame));
        assert_eq!(ShardPolicy::parse("auto"), Some(ShardPolicy::Auto));
        assert_eq!(ShardPolicy::parse("Auto"), Some(ShardPolicy::Auto));
        assert_eq!(
            ShardPolicy::parse("Per-Shard:2x2"),
            Some(ShardPolicy::PerShard(ShardGrid::new(2, 2)))
        );
        assert_eq!(
            ShardPolicy::parse("per-shard:2x2"),
            Some(ShardPolicy::PerShard(ShardGrid::new(2, 2)))
        );
        assert_eq!(
            ShardPolicy::parse("4"),
            Some(ShardPolicy::PerShard(ShardGrid::striped(4)))
        );
        assert_eq!(ShardPolicy::parse("row-bands"), Some(ShardPolicy::RowBands(0)));
        assert_eq!(ShardPolicy::parse("Row-Bands"), Some(ShardPolicy::RowBands(0)));
        assert_eq!(ShardPolicy::parse("bands"), Some(ShardPolicy::RowBands(0)));
        assert_eq!(ShardPolicy::parse("row-bands:3"), Some(ShardPolicy::RowBands(3)));
        assert_eq!(ShardPolicy::parse("row-bands:0"), None);
        assert_eq!(ShardPolicy::RowBands(0).to_string(), "row-bands");
        assert_eq!(ShardPolicy::RowBands(3).to_string(), "row-bands:3");
        assert_eq!(ShardPolicy::parse("bogus"), None);
    }

    #[test]
    fn shards_tile_the_output_space_exactly_once() {
        for (grid, out_h, n_out) in [
            (ShardGrid::new(3, 2), 17, 7),
            (ShardGrid::new(1, 1), 5, 3),
            (ShardGrid::new(8, 3), 3, 2), // grid larger than the space
            (ShardGrid::new(2, 5), 10, 4),
        ] {
            let shards = plan_layer_shards(grid, out_h, n_out);
            let mut cover = vec![0u32; out_h * n_out];
            for s in &shards {
                assert!(s.rows > 0 && s.out_len > 0, "empty shard emitted");
                for o in s.out0..s.out0 + s.out_len {
                    for y in s.row0..s.row0 + s.rows {
                        cover[o * out_h + y] += 1;
                    }
                }
            }
            assert!(cover.iter().all(|&c| c == 1), "grid {grid} over {out_h}x{n_out}");
            assert!(shards.len() <= grid.chips());
            let max = shards.iter().map(|s| s.rows).max().unwrap();
            let min = shards.iter().map(|s| s.rows).min().unwrap();
            assert!(max - min <= 1, "stripes unbalanced: {min}..{max}");
        }
    }

    #[test]
    fn shard_plans_match_unsharded_plans_on_the_trivial_grid() {
        let cfg = ChipConfig::tiny(4);
        let (k, n_in, n_out, h) = (5, 9, 10, 30);
        let whole = LayerShard { index: 0, row0: 0, rows: h, out0: 0, out_len: n_out };
        let sharded = shard_block_plans(&cfg, k, true, n_in, h, &whole);
        let unsharded = crate::coordinator::blocks::plan_layer(&cfg, k, true, n_in, n_out, h);
        assert_eq!(sharded, unsharded);
    }

    #[test]
    fn sharded_run_is_bit_identical_to_unsharded_every_engine() {
        let mut cfg = ChipConfig::tiny(4);
        cfg.image_mem_rows = 4 * 12; // h_max = 12 → intra-shard tiling too
        let w = wl(3, 6, 9, 21, 8, 0xA1);
        for kind in EngineKind::ALL {
            let want = run_layer_engine(&w, &cfg, ExecOptions { workers: 2 }, kind);
            for grid in [ShardGrid::striped(2), ShardGrid::new(3, 2), ShardGrid::new(5, 3)] {
                let got = run_layer_sharded(&w, &cfg, ExecOptions { workers: 3 }, kind, grid);
                assert_eq!(
                    got.run.output,
                    want.output,
                    "engine {} grid {grid}",
                    kind.name()
                );
                assert_eq!(got.run.offchip_adds, want.offchip_adds);
            }
        }
    }

    #[test]
    fn per_shard_activity_sums_to_the_merged_ledger() {
        let cfg = ChipConfig::tiny(4);
        let w = wl(5, 4, 6, 18, 9, 0xB2);
        let grid = ShardGrid::new(3, 2);
        let run = run_layer_sharded(&w, &cfg, ExecOptions { workers: 2 },
            EngineKind::CycleAccurate, grid);
        assert_eq!(run.per_shard.len(), 6);
        let block_sum: usize = run.per_shard.iter().map(|s| s.blocks).sum();
        assert_eq!(block_sum, run.run.blocks);
        let cycle_sum: u64 = run.per_shard.iter().map(|s| s.stats.cycles.total()).sum();
        assert_eq!(cycle_sum, run.run.stats.cycles.total());
        let ops_sum: u64 = run.per_shard.iter().map(|s| s.stats.useful_ops).sum();
        assert_eq!(ops_sum, run.run.stats.useful_ops);
        assert!(run.per_shard.iter().all(|s| s.stats.cycles.total() > 0));
    }

    #[test]
    fn striping_pays_the_halo_reload_penalty() {
        // More stripes ⇒ more k−1-row reloads ⇒ more total chip cycles —
        // the Eq. 9 cost the metrics aggregation must price, not hide.
        let cfg = ChipConfig::tiny(4);
        let w = wl(7, 2, 3, 24, 8, 0xC3);
        let solo = run_layer_sharded(&w, &cfg, ExecOptions { workers: 1 },
            EngineKind::CycleAccurate, ShardGrid::striped(1));
        let quad = run_layer_sharded(&w, &cfg, ExecOptions { workers: 4 },
            EngineKind::CycleAccurate, ShardGrid::striped(4));
        assert_eq!(solo.run.output, quad.run.output);
        assert!(
            quad.run.stats.cycles.total() > solo.run.stats.cycles.total(),
            "4-stripe grid must re-load halo rows: {} vs {}",
            quad.run.stats.cycles.total(),
            solo.run.stats.cycles.total()
        );
    }
}
