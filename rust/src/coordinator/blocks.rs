//! Block decomposition: a convolution layer → the chip-block jobs of
//! Algorithm 1 lines 1–3.
//!
//! Decomposition is split in two stages since the engine refactor:
//!
//! * [`plan_layer`] — pure geometry: output-channel blocks, input-channel
//!   blocks and vertical tiles as index-only [`BlockPlan`]s, no data
//!   copied. Engines consume plans directly against the full layer's
//!   `Arc`-shareable data (`ConvEngine::run_plan`).
//! * [`crate::engine::materialize_block`] — slices one plan into an owned
//!   [`BlockJob`] for consumers that want the historical materialized
//!   form (the cycle-accurate chip front door, tests, examples).
//!
//! [`decompose`] composes the two and is unchanged in behavior.

use crate::api::YodannError;
use crate::engine::{materialize_block, BlockPlan, LayerData, PackedKernels};
use crate::hw::{BlockJob, ChipConfig};
use crate::workload::{BinaryKernels, Image, ScaleBias};

/// A full layer's worth of work: the input feature map plus the complete
/// weight/scale/bias set.
#[derive(Debug, Clone)]
pub struct LayerWorkload {
    /// Kernel size.
    pub k: usize,
    /// Zero-padded convolution.
    pub zero_pad: bool,
    /// Full input feature map (`n_in × h × w`).
    pub input: Image,
    /// Full kernel set (`n_out × n_in`).
    pub kernels: BinaryKernels,
    /// Per-output-channel scale/bias (applied once, after the off-chip
    /// partial-sum accumulation).
    pub scale_bias: ScaleBias,
}

impl LayerWorkload {
    /// Borrow this workload as the engine-facing layer view. The caller
    /// fills [`LayerData::raster`] when it packed a layer-resident
    /// bitplane raster (see `run_layer_with`).
    pub fn as_layer_data<'a>(&'a self, packed: Option<&'a PackedKernels>) -> LayerData<'a> {
        LayerData {
            k: self.k,
            zero_pad: self.zero_pad,
            input: &self.input,
            kernels: &self.kernels,
            packed,
            raster: None,
            binary: None,
            scale_bias: &self.scale_bias,
        }
    }
}

/// One decomposed job plus its position in the layer.
#[derive(Debug, Clone)]
pub struct PlacedJob {
    /// The chip block to execute.
    pub job: BlockJob,
    /// First output channel this block computes.
    pub out_base: usize,
    /// Input-channel block index (for partial-sum reduction).
    pub in_block: usize,
    /// Total input-channel blocks for this output block.
    pub in_blocks: usize,
    /// First output row of this tile in the layer's output.
    pub row_base: usize,
    /// Rows of valid (non-overlap) output this tile contributes.
    pub rows_valid: usize,
}

/// Split `n` into chunks of at most `cap`.
pub(crate) fn chunks(n: usize, cap: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut base = 0;
    while base < n {
        let len = cap.min(n - base);
        out.push((base, len));
        base += len;
    }
    out
}

/// Plan a layer's decomposition on `cfg` — geometry only, no data:
///
/// * output channels → blocks of `n_ch × streams` (dual modes compute 64);
/// * input channels → blocks of `n_ch`, partial sums reduced off-chip;
/// * image height → tiles of `h_max` output rows; each tile's *input*
///   includes the vertical halo it needs, so consecutive tiles re-load
///   `k − 1` rows (exactly Eq. 9's tiling penalty).
///
/// Intermediate (non-final) input blocks run with identity scale/bias —
/// the real α/β are applied once after the off-chip accumulation, which
/// is where the paper's "summed together for every block of input
/// channels" (line 37) happens.
pub fn plan_layer(
    cfg: &ChipConfig,
    k: usize,
    zero_pad: bool,
    n_in: usize,
    n_out: usize,
    h: usize,
) -> Vec<BlockPlan> {
    check_plan_geometry(cfg, k, zero_pad, h);
    let out_h_total = if zero_pad { h } else { h - k + 1 };
    plan_block_range(cfg, k, zero_pad, n_in, h, 0, out_h_total, 0, n_out)
}

/// Plan the chip blocks covering output rows `row0 .. row0 + rows` of
/// output channels `out0 .. out0 + out_len` — the **single source** of
/// the Eq. 9 tiling/blocking geometry, shared by [`plan_layer`] (the
/// whole layer) and [`super::shard::shard_block_plans`] (one shard's
/// stripe × channel group). Keeping one copy is what lets the sharded
/// and unsharded paths stay bit-identical by construction.
#[allow(clippy::too_many_arguments)] // raw range geometry, mirrors BlockPlan fields
pub(crate) fn plan_block_range(
    cfg: &ChipConfig,
    k: usize,
    zero_pad: bool,
    n_in: usize,
    h: usize,
    row0: usize,
    rows_total: usize,
    out0: usize,
    out_len_total: usize,
) -> Vec<BlockPlan> {
    check_plan_geometry(cfg, k, zero_pad, h);
    let streams = if cfg.multi_kernel {
        crate::model::KernelMode::for_kernel(k).filters_per_sop()
    } else {
        1
    };
    let out_cap = cfg.n_ch * streams;
    let in_cap = cfg.n_ch;
    let h_max = cfg.h_max();
    let offset = if zero_pad { (k - 1) / 2 } else { 0 };

    let in_chunks = chunks(n_in, in_cap);
    let mut plans = Vec::new();
    for (ob, out_len) in chunks(out_len_total, out_cap) {
        let out_base = out0 + ob;
        // Output-row tiles: each covers up to (h_max − overhang) output
        // rows; its input tile needs rows [row0−offset, row0+rows+k−1−offset).
        let mut row_base = row0;
        let row_end = row0 + rows_total;
        while row_base < row_end {
            let in_row0 = row_base as isize - offset as isize;
            // Max output rows such that input tile height ≤ h_max.
            let max_rows = h_max.saturating_sub(k - 1).max(1);
            let rows = max_rows.min(row_end - row_base);
            let in_row_end = in_row0 + (rows + k - 1) as isize;
            let (clip0, clip1) = (in_row0.max(0) as usize, in_row_end.min(h as isize) as usize);
            for (ib, &(in_base, in_len)) in in_chunks.iter().enumerate() {
                plans.push(BlockPlan {
                    out_base,
                    out_len,
                    in_base,
                    in_len,
                    in_block: ib,
                    in_blocks: in_chunks.len(),
                    row_base,
                    rows_valid: rows,
                    clip0,
                    tile_h: clip1 - clip0,
                });
            }
            row_base += rows;
        }
    }
    plans
}

/// Geometry preconditions shared by [`plan_layer`] and the shard planner
/// ([`super::shard::shard_block_plans`]), as typed data — the single
/// source of the checks [`check_plan_geometry`] panics on and the serving
/// facade ([`crate::api::Yodann`]) reports as [`YodannError`]s. Found by
/// the k = 5/7 thin-tile audit:
///
/// * `h_max < k` — the image memory cannot hold even one window, yet the
///   tiler would still emit "tiles" of up to `k > h_max` input rows
///   (`max_rows` is clamped to 1 to guarantee progress), silently
///   exceeding chip capacity on every engine.
/// * valid-mode `h < k` — the layer has no output rows and
///   `h − k + 1` *wraps* in release builds (debug builds panic on the
///   subtraction), turning the row loop into a near-2⁶⁴ iteration hang.
pub(crate) fn plan_geometry_check(
    cfg: &ChipConfig,
    k: usize,
    zero_pad: bool,
    h: usize,
) -> Result<(), YodannError> {
    if !(1..=7).contains(&k) {
        return Err(YodannError::UnsupportedKernel { k });
    }
    if cfg.h_max() < k {
        return Err(YodannError::ChipCapacity {
            k,
            h_max: cfg.h_max(),
            image_mem_rows: cfg.image_mem_rows,
            n_ch: cfg.n_ch,
        });
    }
    if !zero_pad && h < k {
        return Err(YodannError::NoOutputRows { k, axis: "height", size: h });
    }
    Ok(())
}

/// The panicking form of [`plan_geometry_check`], for the executor paths
/// whose callers pre-validated (or accept the historical panic). Both are
/// impossible-to-satisfy requests, so they fail loudly with the geometry
/// spelled out. Pinned by `rust/tests/raster_props.rs`, whose expected
/// panic substrings are the [`YodannError`] display texts.
pub(crate) fn check_plan_geometry(cfg: &ChipConfig, k: usize, zero_pad: bool, h: usize) {
    if let Err(e) = plan_geometry_check(cfg, k, zero_pad, h) {
        panic!("{e}");
    }
}

/// The width mirror of [`plan_geometry_check`]'s valid-mode height
/// check. The planner only tiles rows so it never sees `w`, but every
/// executor computes `out_w = w − k + 1` — which wraps in release
/// builds on a valid-mode layer narrower than its kernel (found by the
/// serving facade's `validate_frame` audit). Callers that compute an
/// output width call this first; the facade reports the same condition
/// as a typed [`YodannError::NoOutputRows`] before frames enter the
/// queue.
pub(crate) fn check_width_geometry(zero_pad: bool, k: usize, w: usize) {
    if !zero_pad && w < k {
        panic!("{}", YodannError::NoOutputRows { k, axis: "width", size: w });
    }
}

/// Decompose a layer into materialized chip-block jobs on `cfg` (the
/// historical interface: [`plan_layer`] + `materialize_block` per plan).
pub fn decompose(wl: &LayerWorkload, cfg: &ChipConfig) -> Vec<PlacedJob> {
    let data = wl.as_layer_data(None);
    plan_layer(cfg, wl.k, wl.zero_pad, wl.input.c, wl.kernels.n_out, wl.input.h)
        .into_iter()
        .map(|p| PlacedJob {
            job: materialize_block(&data, &p),
            out_base: p.out_base,
            in_block: p.in_block,
            in_blocks: p.in_blocks,
            row_base: p.row_base,
            rows_valid: p.rows_valid,
        })
        .collect()
}

/// Offset (within a tile's output) of the first valid row, given the tile
/// position. The tile's input starts at `clip0 = max(0, row_base − offset)`,
/// so layer output row `row_base` sits at tile output row
/// `row_base − clip0 = min(offset, row_base)`. For interior tiles that is
/// `offset`, for the first tile 0 — and for interior tiles that are still
/// clipped by the image top (`0 < row_base < offset`, possible only when
/// `h_max − k + 1 < offset`) it is `row_base`: returning `offset` there
/// would slice a vertically shifted window. Caught by the raster
/// refactor's mirror verification; `thin_tiles_near_the_top_stay_correct`
/// pins it.
pub fn tile_row_skip(zero_pad: bool, k: usize, row_base: usize) -> usize {
    let offset = if zero_pad { (k - 1) / 2 } else { 0 };
    offset.min(row_base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;
    use crate::workload::random_image;

    fn workload(k: usize, n_in: usize, n_out: usize, h: usize, w: usize) -> LayerWorkload {
        let mut g = Gen::new(5);
        LayerWorkload {
            k,
            zero_pad: true,
            input: random_image(&mut g, n_in, h, w, 0.02),
            kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
            scale_bias: ScaleBias::identity(n_out),
        }
    }

    #[test]
    fn small_layer_is_one_job() {
        let cfg = ChipConfig::yodann();
        let jobs = decompose(&workload(7, 32, 32, 16, 16), &cfg);
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].in_blocks, 1);
    }

    #[test]
    fn channel_blocking_counts() {
        let cfg = ChipConfig::yodann();
        // 128 in × 128 out, 3×3 (dual mode: 64 out per block) on a 16-row
        // image: 4 input blocks × 2 output blocks.
        let jobs = decompose(&workload(3, 128, 128, 16, 16), &cfg);
        assert_eq!(jobs.len(), 8);
        let out_bases: std::collections::HashSet<_> =
            jobs.iter().map(|j| j.out_base).collect();
        assert_eq!(out_bases.len(), 2);
        assert!(jobs.iter().all(|j| j.in_blocks == 4));
        assert!(jobs.iter().all(|j| j.job.image.c == 32));
    }

    #[test]
    fn vertical_tiling_respects_h_max() {
        let cfg = ChipConfig::yodann(); // h_max = 32
        let jobs = decompose(&workload(3, 32, 32, 64, 8), &cfg);
        // max_rows = 32 − 2 = 30 ⇒ tiles of 30/30/4 output rows.
        let tiles: Vec<usize> = jobs.iter().map(|j| j.rows_valid).collect();
        assert_eq!(tiles.iter().sum::<usize>(), 64);
        assert!(jobs.iter().all(|j| j.job.image.h <= cfg.h_max()));
        assert_eq!(tiles, vec![30, 30, 4]);
    }

    #[test]
    fn tiles_overlap_k_minus_1_rows() {
        let cfg = ChipConfig::yodann();
        let jobs = decompose(&workload(7, 8, 8, 80, 8), &cfg);
        // Total input rows loaded across tiles exceeds h by (tiles−1)(k−1)
        // minus border clipping — the Eq. 9 penalty.
        let total_rows: usize = jobs.iter().map(|j| j.job.image.h).sum();
        assert!(total_rows > 80, "tiles must overlap: {total_rows}");
    }

    #[test]
    fn non_padded_layers_decompose() {
        let cfg = ChipConfig::yodann();
        let mut wl = workload(5, 8, 8, 40, 12);
        wl.zero_pad = false;
        let jobs = decompose(&wl, &cfg);
        let rows: usize = jobs.iter().map(|j| j.rows_valid).sum();
        assert_eq!(rows, 40 - 4);
    }

    #[test]
    fn plans_carry_no_data_and_match_materialization() {
        let cfg = ChipConfig::yodann();
        let wl = workload(3, 48, 40, 40, 8);
        let plans = plan_layer(&cfg, wl.k, wl.zero_pad, wl.input.c, wl.kernels.n_out, wl.input.h);
        let jobs = decompose(&wl, &cfg);
        assert_eq!(plans.len(), jobs.len());
        for (p, j) in plans.iter().zip(jobs.iter()) {
            assert_eq!(p.out_base, j.out_base);
            assert_eq!(p.in_block, j.in_block);
            assert_eq!(p.in_blocks, j.in_blocks);
            assert_eq!(p.row_base, j.row_base);
            assert_eq!(p.rows_valid, j.rows_valid);
            assert_eq!(p.tile_h, j.job.image.h);
            assert_eq!(p.in_len, j.job.image.c);
            assert_eq!(p.out_len, j.job.kernels.n_out);
        }
    }
}
