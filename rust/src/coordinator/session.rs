//! Batched network sessions: run a whole CNN over many frames with one
//! setup.
//!
//! [`NetworkSession`] holds a **persistent worker pool** (threads live
//! for the session's lifetime, fed over a channel) and `Arc`-shared
//! per-layer state — kernels, scale/bias and the pre-packed popcount
//! words ([`crate::engine::PackedKernels`]) are packed **once** at
//! session build and shared by every worker, eliminating the per-job
//! `Image`/`BinaryKernels` clones of the materializing path. Each worker
//! owns one [`ConvEngine`] instance plus a reusable wide-precision
//! accumulator and a reusable [`BitplaneRaster`] scratch, so
//! steady-state frame processing allocates only the output images.
//!
//! Scheduling is governed by [`ShardPolicy`]:
//!
//! * **[`ShardPolicy::PerFrame`]** (the historical default) — a batch
//!   fans frames out across the pool, each worker carrying its frame
//!   through every layer (conv → optional quantized ReLU → optional 2×2
//!   max-pool). Within a frame the blocks of a layer run sequentially on
//!   the worker's engine — for batch traffic this keeps every core busy
//!   with no cross-thread reduction.
//! * **[`ShardPolicy::PerShard`]** — intra-frame parallelism for
//!   latency-bound traffic (single frames, small batches): frames run in
//!   order and each layer's output is striped across a
//!   [`ShardGrid`](super::shard::ShardGrid) of chip instances; shard
//!   tasks fan out across the same persistent pool, every shard
//!   resolving its input halo against one shared per-layer
//!   [`BitplaneRaster`] (packed once into caller-side reusable scratch,
//!   shared via `Arc` — no activation copies), and the caller stitches
//!   stripes through the executor's wide-precision reduction.
//! * **[`ShardPolicy::RowBands`]** — within-frame row-band parallelism,
//!   unconditionally: every conv's output rows split into `n` horizontal
//!   bands (`n × 1` stripes, `RowBands(0)` = one band per worker) fanned
//!   across the pool against the one shared layer raster — the explicit
//!   latency schedule for batch=1 traffic, with no batch-size heuristic
//!   in the way.
//! * **[`ShardPolicy::Auto`]** — batches with at least one frame per
//!   worker run per-frame; smaller batches shard each frame across the
//!   whole pool (`workers × 1` stripes — i.e. `RowBands(0)`).
//!
//! Since the graph-IR redesign the session no longer walks a flat layer
//! chain: it **interprets a compiled step program**
//! ([`CompiledGraph`]) of conv segments and host-op interludes
//! (quantized ReLU, 2×2 max-pool, stride-2 subsample, residual add,
//! channel concat) over a slot-addressed value store — which is what
//! lets AlexNet's parallel 11×11 split and ResNet's shortcut graphs run
//! through the same worker pool, raster packing and sharding machinery
//! as a chain. Flat [`SessionLayerSpec`] chains lower into the same
//! program (one conv step per layer plus its ReLU/pool interludes), so
//! the historical surface is a shim with byte-identical outputs.
//!
//! The per-layer numerical pipeline is exactly the executor's:
//! plan → engine blocks → off-chip wide accumulation → final α/β
//! (Algorithm 1 line 37), and the i64 stitch reduction is
//! order-invariant, so session outputs are **bit-identical** to
//! [`super::executor::run_layer_engine`] layer by layer, for every
//! engine kind and every policy (`rust/tests/conformance.rs` fuzzes the
//! whole matrix).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::blocks::{check_plan_geometry, check_width_geometry, plan_layer};
use super::executor::{finalize_output, reduce_block};
use super::shard::{plan_layer_shards, shard_block_plans, ShardGrid, ShardPolicy};
use crate::api::YodannError;
use crate::fault::{FaultPlan, FaultReport, FaultSite};
use crate::engine::{
    BinaryRaster, BitplaneRaster, BlockPlan, ConvEngine, EngineKind, EngineOutput, LayerData,
    PackedKernels, BINARY_ONE,
};
use crate::fixedpoint::Q2_9;
use crate::hw::{ChipConfig, ChipStats};
use crate::model::graph::{compute_free_after, CompiledGraph, PlanConv, PlanStep, Precision};
use crate::model::Network;
use crate::testkit::Gen;
use crate::workload::{BinaryKernels, Image, ScaleBias};

/// One layer of a session: conv parameters plus the inter-layer plumbing
/// the host applies after it (quantized ReLU, 2×2 max-pool).
#[derive(Debug, Clone)]
pub struct SessionLayerSpec {
    /// Kernel size (1..=7).
    pub k: usize,
    /// Zero-padded convolution.
    pub zero_pad: bool,
    /// Kernel set, shared across workers and frames.
    pub kernels: Arc<BinaryKernels>,
    /// Per-output-channel scale/bias, shared.
    pub scale_bias: Arc<ScaleBias>,
    /// Apply a quantized ReLU (`max(0, ·)`) after the conv.
    pub relu: bool,
    /// Apply a 2×2 max-pool after the conv (and ReLU, if any).
    pub maxpool2: bool,
}

impl SessionLayerSpec {
    /// Build a runnable layer chain from a Table-III network descriptor:
    /// conv rows are expanded by their repeat counts, random binary
    /// kernels and small range-preserving scales are generated from
    /// `seed`, ReLU runs between layers, and a 2×2 max-pool is inserted
    /// wherever the table's geometry halves. Returns a typed
    /// [`YodannError`] for specs that cannot run: networks without conv
    /// layers ([`YodannError::NoConvLayers`] — e.g. a dense-only
    /// descriptor) and networks that are not a simple chain
    /// ([`YodannError::NotASimpleChain`] — e.g. AlexNet's parallel 11×11
    /// split rows).
    pub fn synthetic_network(
        net: &Network,
        seed: u64,
    ) -> Result<Vec<SessionLayerSpec>, YodannError> {
        let convs: Vec<_> = net.conv_layers().collect();
        if convs.is_empty() {
            return Err(YodannError::NoConvLayers { net: net.id.to_string() });
        }
        let mut g = Gen::new(seed);
        let mut specs: Vec<SessionLayerSpec> = Vec::new();
        let mut prev_out: Option<usize> = None;
        for (idx, c) in convs.iter().enumerate() {
            for rep in 0..c.repeat.max(1) {
                let n_in = if rep == 0 { c.n_in } else { c.n_out };
                if let Some(p) = prev_out {
                    if p != n_in {
                        return Err(YodannError::NotASimpleChain {
                            net: net.id.to_string(),
                            layer: c.label.to_string(),
                            prev_out: p,
                            n_in,
                        });
                    }
                }
                specs.push(SessionLayerSpec {
                    k: c.k,
                    zero_pad: c.zero_pad,
                    kernels: Arc::new(BinaryKernels::random(&mut g, c.n_out, n_in, c.k)),
                    scale_bias: Arc::new(ScaleBias {
                        alpha: vec![Q2_9.from_f64(0.05); c.n_out],
                        beta: vec![Q2_9.from_f64(0.01); c.n_out],
                    }),
                    relu: true,
                    maxpool2: false,
                });
                prev_out = Some(c.n_out);
            }
            // Pool after this row when the next row's tabulated height
            // is half of this row's.
            if let Some(next) = convs.get(idx + 1) {
                if next.h * 2 == c.h {
                    if let Some(last) = specs.last_mut() {
                        last.maxpool2 = true;
                    }
                }
            }
        }
        if let Some(last) = specs.last_mut() {
            last.relu = false;
        }
        Ok(specs)
    }
}

/// Internal per-conv-layer state: the lowered conv plus the
/// session-wide packed kernel words (packed only for engines that
/// consume them).
struct SessionLayer {
    conv: PlanConv,
    packed: Option<Arc<PackedKernels>>,
}

/// The executable form of a network inside a session: the
/// [`CompiledGraph`] step program with every conv layer's kernels
/// packed once for the session's engine kind. Shared (`Arc`) by every
/// worker.
struct SessionPlan {
    convs: Vec<SessionLayer>,
    steps: Vec<PlanStep>,
    n_slots: usize,
    input_slot: usize,
    output_slot: usize,
    free_after: Vec<Vec<usize>>,
    /// The armed fault-injection plan, if any (shared by every worker —
    /// the plan's own seeding makes injection independent of which
    /// worker runs a frame).
    fault: Option<FaultPlan>,
    /// Weight-memory faults injected at pack time. Weights are written
    /// once and stay resident, so these are session-lifetime: every
    /// frame that computes with them inherits this report.
    weight_faults: FaultReport,
}

impl SessionPlan {
    /// Pack every conv layer's kernels for the engine kind, running the
    /// weight-memory leg of the fault plan as the bits are written: a
    /// detected corruption repacks once at the guard-banded retry rate;
    /// corruption that persists refuses the whole session
    /// ([`YodannError::FaultDetected`] with no frame — no frame exists
    /// yet).
    fn from_compiled(
        kind: EngineKind,
        cg: CompiledGraph,
        fault: Option<FaultPlan>,
    ) -> Result<SessionPlan, YodannError> {
        let mut weight_faults = FaultReport::default();
        let mut convs = Vec::with_capacity(cg.convs.len());
        for (li, conv) in cg.convs.into_iter().enumerate() {
            // Binary layers always consume packed kernels, whatever the
            // session's main engine wants: the XNOR companion engine a
            // mixed-precision session routes them to has no materializing
            // fallback path.
            let packed = if kind.wants_packed() || conv.precision == Precision::Binary {
                let mut pk = PackedKernels::pack(&conv.kernels);
                if let Some(f) = fault.as_ref().filter(|f| f.injects_weights()) {
                    let mut flips = f.corrupt_weights(&mut pk, li as u64, 0);
                    if f.detects() && !pk.verify() {
                        weight_faults.detected += 1;
                        weight_faults.retries += 1;
                        pk = PackedKernels::pack(&conv.kernels);
                        flips = f.corrupt_weights(&mut pk, li as u64, 1);
                        if !pk.verify() {
                            return Err(YodannError::FaultDetected {
                                frame: None,
                                layer: li,
                                site: FaultSite::WeightMemory,
                            });
                        }
                    }
                    weight_faults.weight_flips += flips;
                }
                Some(Arc::new(pk))
            } else {
                None
            };
            convs.push(SessionLayer { conv, packed });
        }
        Ok(SessionPlan {
            convs,
            steps: cg.steps,
            n_slots: cg.n_slots,
            input_slot: cg.input_slot,
            output_slot: cg.output_slot,
            free_after: cg.free_after,
            fault,
            weight_faults,
        })
    }
}

/// Lower a flat chain of [`SessionLayerSpec`]s into the step program
/// the session interprets: per layer one conv step plus its optional
/// ReLU / max-pool interludes, outputs in fresh slots. This is the shim
/// that keeps the historical chain surface byte-identical — the
/// interludes run in exactly the order the pre-graph session applied
/// them.
pub(crate) fn chain_compiled(specs: &[SessionLayerSpec]) -> CompiledGraph {
    let mut convs = Vec::with_capacity(specs.len());
    let mut steps: Vec<PlanStep> = Vec::new();
    let mut step_labels: Vec<String> = Vec::new();
    let mut slot = 0usize;
    let mut next = 1usize;
    for (i, s) in specs.iter().enumerate() {
        convs.push(PlanConv {
            k: s.k,
            zero_pad: s.zero_pad,
            kernels: Arc::clone(&s.kernels),
            scale_bias: Arc::clone(&s.scale_bias),
            label: format!("conv{i}"),
            precision: Precision::MultiBit,
        });
        steps.push(PlanStep::Conv { conv: i, src: slot, dst: next });
        step_labels.push(format!("conv{i}"));
        slot = next;
        next += 1;
        if s.relu {
            steps.push(PlanStep::Relu { src: slot, dst: next });
            step_labels.push(format!("relu{i}"));
            slot = next;
            next += 1;
        }
        if s.maxpool2 {
            steps.push(PlanStep::MaxPool2 { src: slot, dst: next });
            step_labels.push(format!("maxpool{i}"));
            slot = next;
            next += 1;
        }
    }
    let free_after = compute_free_after(&steps, next, slot);
    CompiledGraph {
        name: "chain".into(),
        n_in: specs[0].kernels.n_in,
        convs,
        steps,
        step_labels,
        n_slots: next,
        input_slot: 0,
        output_slot: slot,
        free_after,
    }
}

/// The engine kind that actually runs a layer: a [`Precision::Binary`]
/// layer routes to the session kind's XNOR companion
/// ([`EngineKind::binary_companion`] — SIMD dispatch preserved, e.g.
/// `FunctionalSimd` → `XnorSimd`); multi-bit layers run the session
/// kind as-is. A session whose main kind is already binary runs
/// *every* layer binary (a binary kind is its own companion).
fn effective_kind(kind: EngineKind, precision: Precision) -> EngineKind {
    match precision {
        Precision::Binary => kind.binary_companion(),
        Precision::MultiBit => kind,
    }
}

/// A worker's engine set for mixed-precision programs: the session's
/// main engine plus the lazily built XNOR companion the first binary
/// layer brings up. All-one-precision sessions never build the second
/// engine.
struct WorkerEngines {
    cfg: ChipConfig,
    kind: EngineKind,
    main: Box<dyn ConvEngine>,
    companion: Option<Box<dyn ConvEngine>>,
}

impl WorkerEngines {
    fn new(cfg: ChipConfig, kind: EngineKind) -> WorkerEngines {
        WorkerEngines { cfg, kind, main: kind.build(cfg), companion: None }
    }

    /// The engine a layer of `precision` runs on.
    fn for_precision(&mut self, precision: Precision) -> &mut dyn ConvEngine {
        let eff = effective_kind(self.kind, precision);
        if eff == self.kind {
            &mut *self.main
        } else {
            &mut **self.companion.get_or_insert_with(|| eff.build(self.cfg))
        }
    }

    /// Rebuild everything after a panic left mid-frame garbage behind.
    fn rebuild(&mut self) {
        self.main = self.kind.build(self.cfg);
        self.companion = None;
    }
}

/// Owned, `Arc`-shared view of the layer currently being sharded across
/// the pool: what a worker rebuilds a [`LayerData`] from. Activations
/// (`input`, `raster`, `binary`) are shared, never copied per shard.
struct ShardLayer {
    k: usize,
    zero_pad: bool,
    precision: Precision,
    input: Arc<Image>,
    kernels: Arc<BinaryKernels>,
    packed: Option<Arc<PackedKernels>>,
    raster: Option<Arc<BitplaneRaster>>,
    binary: Option<Arc<BinaryRaster>>,
    scale_bias: Arc<ScaleBias>,
}

impl ShardLayer {
    fn as_layer_data(&self) -> LayerData<'_> {
        LayerData {
            k: self.k,
            zero_pad: self.zero_pad,
            input: &self.input,
            kernels: &self.kernels,
            packed: self.packed.as_deref(),
            raster: self.raster.as_deref(),
            binary: self.binary.as_deref(),
            scale_bias: &self.scale_bias,
        }
    }
}

/// A unit of pool work: one whole frame (per-frame schedule) or one
/// shard of one layer (per-shard schedule). Shard tasks carry a
/// monotonically increasing `job` tag so the coordinator can discard
/// stale replies from a layer that was abandoned mid-drain (a frame
/// that failed after some of its shards were already in flight).
enum Task {
    Frame(usize, Image),
    Shard { job: usize, shard: usize, plans: Vec<BlockPlan>, layer: Arc<ShardLayer> },
}

/// One fully processed frame: the output image plus the merged activity
/// of every block the frame executed, across all layers (all-zero except
/// `useful_ops` for engines that keep no ledger). This is what the
/// serving facade ([`crate::api::Yodann`]) rolls into per-frame
/// [`SimMetrics`](super::metrics::SimMetrics) — the session keeps the
/// ledger instead of discarding it.
#[derive(Debug, Clone)]
pub(crate) struct TracedFrame {
    /// The network's output for this frame.
    pub(crate) output: Image,
    /// Merged per-frame activity ledger.
    pub(crate) stats: ChipStats,
    /// What fault injection did to this frame (session-lifetime
    /// weight-memory faults folded in).
    pub(crate) fault: FaultReport,
}

/// A worker's reply to one [`Task`]. Shard replies echo their task's
/// `job` tag (first field) so stale replies are droppable.
enum Reply {
    Frame(usize, Result<TracedFrame, YodannError>),
    Shard(usize, usize, Result<Vec<(BlockPlan, EngineOutput)>, String>),
}

/// How often a blocked batch drain sweeps for dead workers. Workers die
/// only through an injected loss (panics are caught), so the sweep is a
/// liveness backstop: it lets the supervisor respawn mid-batch instead
/// of stranding queued frames behind a lost thread.
const WORKER_SWEEP: Duration = Duration::from_millis(25);

/// A persistent multi-frame inference session over one network.
pub struct NetworkSession {
    cfg: ChipConfig,
    tx: Option<Sender<Task>>,
    rx_out: Receiver<Reply>,
    /// Shared task-queue end and reply-channel sender, kept so the
    /// supervisor can respawn a lost worker with the same wiring.
    rx_in: Arc<Mutex<Receiver<Task>>>,
    tx_out: Sender<Reply>,
    handles: Vec<JoinHandle<()>>,
    plan: Arc<SessionPlan>,
    workers: usize,
    engine: EngineKind,
    policy: ShardPolicy,
    n_in: usize,
    /// Monotonic shard-job tag (see [`Task::Shard`]).
    shard_job: usize,
    /// Workers the supervisor has replaced after a loss.
    respawns: u64,
    /// Caller-side scratch for the sharded schedule: the per-layer
    /// raster every shard reads (swapped out while a layer is in
    /// flight, reclaimed through `Arc::try_unwrap` afterwards), its
    /// single-plane twin for binary (XNOR) layers, and the wide stitch
    /// accumulator.
    shard_raster: Option<BitplaneRaster>,
    shard_binary: Option<BinaryRaster>,
    shard_acc: Vec<i64>,
}

impl NetworkSession {
    /// Build a session on the historical per-frame schedule.
    #[deprecated(note = "configure and build through `yodann::api::SessionBuilder` instead")]
    pub fn new(
        cfg: ChipConfig,
        kind: EngineKind,
        workers: usize,
        specs: Vec<SessionLayerSpec>,
    ) -> NetworkSession {
        NetworkSession::spawn(cfg, kind, workers, ShardPolicy::PerFrame, specs)
    }

    /// Build a session with an explicit batch schedule.
    #[deprecated(note = "configure and build through `yodann::api::SessionBuilder` instead")]
    pub fn with_policy(
        cfg: ChipConfig,
        kind: EngineKind,
        workers: usize,
        policy: ShardPolicy,
        specs: Vec<SessionLayerSpec>,
    ) -> NetworkSession {
        NetworkSession::spawn(cfg, kind, workers, policy, specs)
    }

    /// Build a session from a layer chain: validates it (panicking on
    /// bad specs — the [`crate::api::SessionBuilder`] validates the
    /// same conditions eagerly into typed errors first), lowers it into
    /// the step program, and spawns the pool. `policy` picks the batch
    /// schedule; outputs are bit-identical under every policy.
    pub(crate) fn spawn(
        cfg: ChipConfig,
        kind: EngineKind,
        workers: usize,
        policy: ShardPolicy,
        specs: Vec<SessionLayerSpec>,
    ) -> NetworkSession {
        assert!(!specs.is_empty(), "session needs at least one layer");
        for (i, s) in specs.iter().enumerate() {
            assert!((1..=7).contains(&s.k), "layer {i}: kernel size {} unsupported", s.k);
            assert_eq!(
                s.scale_bias.alpha.len(),
                s.kernels.n_out,
                "layer {i}: scale/bias arity mismatch"
            );
            if i > 0 {
                assert_eq!(
                    specs[i - 1].kernels.n_out,
                    s.kernels.n_in,
                    "layer {i}: channel chain mismatch"
                );
            }
        }
        match NetworkSession::spawn_plan(cfg, kind, workers, policy, chain_compiled(&specs), None)
        {
            Ok(s) => s,
            Err(e) => panic!("{e}"),
        }
    }

    /// Build a session from a compiled network plan (a lowered
    /// [`NetworkGraph`](crate::model::graph::NetworkGraph) or a chain
    /// shim): packs every conv layer's kernels once for the engine
    /// kind (running the fault plan's weight-memory leg as the bits are
    /// written), and spins up `workers` threads each owning one engine
    /// of `kind`, all interpreting the same `Arc`-shared step program.
    pub(crate) fn spawn_plan(
        cfg: ChipConfig,
        kind: EngineKind,
        workers: usize,
        policy: ShardPolicy,
        compiled: CompiledGraph,
        fault: Option<FaultPlan>,
    ) -> Result<NetworkSession, YodannError> {
        assert!(!compiled.convs.is_empty(), "session needs at least one conv layer");
        let n_in = compiled.n_in;
        // Pack once per session, only when the engine consumes the packed
        // form (the cycle-accurate engine materializes jobs instead).
        let plan = Arc::new(SessionPlan::from_compiled(kind, compiled, fault)?);
        let workers = workers.max(1);
        let (tx, rx_in) = channel::<Task>();
        let rx_in = Arc::new(Mutex::new(rx_in));
        let (tx_out, rx_out) = channel::<Reply>();
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(spawn_worker(cfg, kind, &rx_in, &tx_out, &plan));
        }
        Ok(NetworkSession {
            cfg,
            tx: Some(tx),
            rx_out,
            rx_in,
            tx_out,
            handles,
            plan,
            workers,
            engine: kind,
            policy,
            n_in,
            shard_job: 0,
            respawns: 0,
            shard_raster: Some(BitplaneRaster::new()),
            shard_binary: Some(BinaryRaster::new()),
            shard_acc: Vec::new(),
        })
    }

    /// Supervisor sweep: join workers whose threads have exited (only an
    /// injected worker loss does — panics are caught in the loop) and
    /// respawn replacements so the pool keeps its configured width.
    fn ensure_workers(&mut self) {
        if self.tx.is_none() {
            return;
        }
        let handles = std::mem::take(&mut self.handles);
        let mut alive = Vec::with_capacity(handles.len());
        for h in handles {
            if h.is_finished() {
                let _ = h.join();
                self.respawns += 1;
                alive.push(spawn_worker(
                    self.cfg,
                    self.engine,
                    &self.rx_in,
                    &self.tx_out,
                    &self.plan,
                ));
            } else {
                alive.push(h);
            }
        }
        self.handles = alive;
    }

    /// Workers the supervisor has replaced after a loss (0 in healthy
    /// sessions).
    pub fn worker_respawns(&self) -> u64 {
        self.respawns
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Engine kind the pool runs.
    pub fn engine(&self) -> EngineKind {
        self.engine
    }

    /// The batch schedule in force.
    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    /// Change the batch schedule (takes effect from the next batch;
    /// outputs are bit-identical under every policy).
    #[deprecated(note = "pick the schedule once via `SessionBuilder::shard_policy` instead")]
    pub fn set_policy(&mut self, policy: ShardPolicy) {
        self.policy = policy;
    }

    /// Conv layers in the network plan.
    pub fn n_layers(&self) -> usize {
        self.plan.convs.len()
    }

    /// Sharded-schedule raster packs that had to grow the caller-side
    /// scratch. Steady-state serving of same-geometry traffic keeps this
    /// constant — the scratch-reuse tests pin it (a lost scratch, e.g. a
    /// shard still holding the `Arc` at reclaim time, shows up here as
    /// renewed growth).
    pub fn shard_raster_reallocs(&self) -> u64 {
        self.shard_raster.as_ref().map_or(u64::MAX, |r| r.reallocs())
    }

    /// The binary-raster twin of [`Self::shard_raster_reallocs`], for
    /// sessions whose sharded layers run in XNOR mode.
    pub fn shard_binary_reallocs(&self) -> u64 {
        self.shard_binary.as_ref().map_or(u64::MAX, |r| r.reallocs())
    }

    /// Run one frame through the whole network.
    #[deprecated(note = "submit through `yodann::api::Yodann` for tickets and telemetry")]
    pub fn run_frame(&mut self, frame: Image) -> Image {
        #[allow(deprecated)]
        match self.run_batch(vec![frame]).pop() {
            Some(out) => out,
            None => unreachable!("run_batch returns one output per frame"),
        }
    }

    /// Run a batch of frames, discarding the per-frame activity ledgers.
    /// Panics on the first failed frame with the historical panic text
    /// (the [`YodannError`] Display form reproduces it verbatim); the
    /// serving facade returns the typed error per frame instead.
    #[deprecated(note = "submit through `yodann::api::Yodann` for tickets and telemetry")]
    pub fn run_batch(&mut self, frames: Vec<Image>) -> Vec<Image> {
        self.run_batch_traced(frames)
            .into_iter()
            .map(|t| match t {
                Ok(t) => t.output,
                Err(e) => panic!("{e}"),
            })
            .collect()
    }

    /// Run a batch of frames under the session's [`ShardPolicy`].
    /// Results come back in input order regardless of the schedule or
    /// completion order, each slot carrying its merged activity ledger
    /// — or the typed error that failed *that frame alone* (a worker
    /// panic, an injected loss, an uncorrectable detected fault). The
    /// session survives every per-frame error and keeps serving.
    ///
    /// Panics on frames whose channel count does not match the first
    /// layer (validated up front — a worker dying mid-batch would
    /// otherwise leave the batch waiting forever). The serving facade
    /// validates frames into typed errors before they get here.
    pub(crate) fn run_batch_traced(
        &mut self,
        frames: Vec<Image>,
    ) -> Vec<Result<TracedFrame, YodannError>> {
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(
                f.c, self.n_in,
                "frame {i} has {} channels, the network takes {}",
                f.c, self.n_in
            );
        }
        self.ensure_workers();
        match self.policy {
            ShardPolicy::PerFrame => self.run_batch_per_frame(frames),
            ShardPolicy::PerShard(grid) => self.run_batch_sharded(frames, grid),
            // Row-band parallelism is stripe-only sharding: each conv's
            // output rows split into n bands against the one shared
            // layer raster (RowBands(0) sizes the bands to the pool).
            // Auto's small-batch arm below is exactly RowBands(0) — the
            // explicit policy skips the batch-size heuristic, which is
            // what latency-bound batch=1 traffic wants.
            ShardPolicy::RowBands(bands) => {
                let n = if bands == 0 { self.workers } else { bands };
                self.run_batch_sharded(frames, ShardGrid::striped(n))
            }
            ShardPolicy::Auto => {
                if frames.len() >= self.workers {
                    self.run_batch_per_frame(frames)
                } else {
                    self.run_batch_sharded(frames, ShardGrid::striped(self.workers))
                }
            }
        }
    }

    /// The per-frame schedule: frames fan out across the pool; each
    /// slot resolves to its frame's result or to the typed error that
    /// failed it. A drain that stalls (a worker lost mid-batch) sweeps
    /// the supervisor so queued frames land on a respawned worker.
    fn run_batch_per_frame(&mut self, frames: Vec<Image>) -> Vec<Result<TracedFrame, YodannError>> {
        let n = frames.len();
        let mut out: Vec<Option<Result<TracedFrame, YodannError>>> = (0..n).map(|_| None).collect();
        let mut sent = 0usize;
        if let Some(tx) = self.tx.as_ref() {
            for (i, f) in frames.into_iter().enumerate() {
                if tx.send(Task::Frame(i, f)).is_err() {
                    break;
                }
                sent += 1;
            }
        }
        let mut got = 0usize;
        while got < sent {
            match self.rx_out.recv_timeout(WORKER_SWEEP) {
                Ok(Reply::Frame(i, res)) => {
                    got += 1;
                    out[i] = Some(res);
                }
                // A stale shard reply from a layer abandoned mid-drain.
                Ok(Reply::Shard(..)) => {}
                Err(RecvTimeoutError::Timeout) => self.ensure_workers(),
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        out.into_iter()
            .map(|o| match o {
                Some(res) => res,
                None => Err(YodannError::SessionClosed),
            })
            .collect()
    }

    /// The per-shard schedule: frames run in order, each layer striped
    /// across the pool on `grid`. A coordinator-side panic (the Q2.9
    /// pack assert, a stitch bug) fails only its frame.
    fn run_batch_sharded(
        &mut self,
        frames: Vec<Image>,
        grid: ShardGrid,
    ) -> Vec<Result<TracedFrame, YodannError>> {
        frames
            .into_iter()
            .enumerate()
            .map(|(i, f)| {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.run_frame_sharded(i, f, grid)
                }))
                .unwrap_or_else(|p| {
                    Err(YodannError::WorkerPanicked {
                        frame: i as u64,
                        layer: None,
                        message: panic_message(p),
                    })
                })
            })
            .collect()
    }

    /// Carry one frame through the step program, fanning each conv
    /// step's shards out across the pool (raster pack into shared,
    /// caller-side scratch → shard plans → pool fan-out → wide stitch
    /// reduction → final α/β) and computing the host-op interludes
    /// (ReLU / pools / subsample / add / concat) inline. Identical
    /// numerics to the per-frame path.
    fn run_frame_sharded(
        &mut self,
        fidx: usize,
        frame: Image,
        grid: ShardGrid,
    ) -> Result<TracedFrame, YodannError> {
        let plan = Arc::clone(&self.plan);
        if let Some(f) = plan.fault.as_ref() {
            f.maybe_panic(fidx as u64);
        }
        let mut fault_report = plan.weight_faults;
        let mut frame_stats = ChipStats::default();
        let mut slots: Vec<Option<Arc<Image>>> = (0..plan.n_slots).map(|_| None).collect();
        slots[plan.input_slot] = Some(Arc::new(frame));
        for (si, step) in plan.steps.iter().enumerate() {
            let out: Arc<Image> = match step {
                PlanStep::Conv { conv, src, .. } => {
                    let x = Arc::clone(slot_ref(&slots, *src));
                    let y = self.run_conv_sharded(
                        fidx,
                        *conv,
                        &plan.convs[*conv],
                        x,
                        grid,
                        &mut frame_stats,
                        plan.fault.as_ref(),
                        &mut fault_report,
                    )?;
                    Arc::new(y)
                }
                PlanStep::Relu { src, .. } => {
                    // Steal the Arc on the source's last use so the
                    // unwrap mutates in place (zero-copy, like the
                    // pre-graph epilogue); clone only on fan-out.
                    let arc = if plan.free_after[si].contains(src) {
                        slot_take(&mut slots, *src)
                    } else {
                        Arc::clone(slot_ref(&slots, *src))
                    };
                    let mut y = Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone());
                    relu_inplace(&mut y);
                    Arc::new(y)
                }
                PlanStep::BatchNormThreshold { thresholds, src, .. } => {
                    // Same steal-on-last-use discipline as ReLU: the
                    // binarization mutates in place when this step owns
                    // the map.
                    let arc = if plan.free_after[si].contains(src) {
                        slot_take(&mut slots, *src)
                    } else {
                        Arc::clone(slot_ref(&slots, *src))
                    };
                    let mut y = Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone());
                    threshold_inplace(&mut y, thresholds);
                    Arc::new(y)
                }
                PlanStep::MaxPool2 { src, .. } => {
                    Arc::new(maybe_maxpool2(slot_ref(&slots, *src)))
                }
                PlanStep::Subsample2 { src, .. } => {
                    Arc::new(subsample2(slot_ref(&slots, *src)))
                }
                PlanStep::Add { srcs, .. } => {
                    let imgs: Vec<&Image> = srcs
                        .iter()
                        .map(|&s| &**slot_ref(&slots, s))
                        .collect();
                    Arc::new(add_wide_saturating(&imgs))
                }
                PlanStep::Concat { srcs, .. } => {
                    let imgs: Vec<&Image> = srcs
                        .iter()
                        .map(|&s| &**slot_ref(&slots, s))
                        .collect();
                    Arc::new(concat_channels(&imgs))
                }
            };
            slots[step.dst()] = Some(out);
            for &f in &plan.free_after[si] {
                slots[f] = None;
            }
        }
        let out = take_output(&mut slots, plan.output_slot);
        Ok(TracedFrame {
            output: Arc::try_unwrap(out).unwrap_or_else(|a| (*a).clone()),
            stats: frame_stats,
            fault: fault_report,
        })
    }

    /// One sharded conv step: the layer's output striped across `grid`,
    /// every shard resolving its halo against one shared caller-side
    /// raster, stitched back through the executor's wide reduction.
    /// With an armed fault plan the shared raster is sealed, corrupted
    /// (image words plus the halo rows crossing shard boundaries) and
    /// verified before the fan-out.
    #[allow(clippy::too_many_arguments)] // the frame's whole fault + stats context
    fn run_conv_sharded(
        &mut self,
        fidx: usize,
        li: usize,
        layer: &SessionLayer,
        x: Arc<Image>,
        grid: ShardGrid,
        frame_stats: &mut ChipStats,
        fault: Option<&FaultPlan>,
        report: &mut FaultReport,
    ) -> Result<Image, YodannError> {
        let spec = &layer.conv;
        assert_eq!(
            x.c, spec.kernels.n_in,
            "layer {li}: frame has {} channels, kernels expect {}",
            x.c, spec.kernels.n_in
        );
        let n_out = spec.kernels.n_out;
        check_plan_geometry(&self.cfg, spec.k, spec.zero_pad, x.h);
        check_width_geometry(spec.zero_pad, spec.k, x.w);
        let (out_h, out_w) =
            if spec.zero_pad { (x.h, x.w) } else { (x.h - spec.k + 1, x.w - spec.k + 1) };
        // Pack this layer's activations once into the caller-side
        // reusable scratch; every shard reads it through the Arc.
        // Packing happens *in place* so a panic mid-pack (e.g. the
        // Q2.9 range debug_assert) leaves the scratch owned by the
        // session instead of dropped with the unwind. Binary layers
        // route to the session kind's XNOR companion, which reads the
        // single-plane binary raster instead of the 12-plane one.
        let eff = effective_kind(self.engine, spec.precision);
        let raster = if eff.wants_raster() {
            let r = self.shard_raster.get_or_insert_with(BitplaneRaster::new);
            r.pack(&x, spec.k, spec.zero_pad);
            if let Some(f) = fault.filter(|f| f.injects_raster_faults()) {
                let halo_rows =
                    halo_exchange_rows(grid, out_h, n_out, spec.k, r.padded_dims().0);
                inject_raster_faults(
                    f,
                    r,
                    |r| r.pack(&x, spec.k, spec.zero_pad),
                    fidx,
                    li,
                    &halo_rows,
                    report,
                )?;
            }
            Some(Arc::new(std::mem::take(r)))
        } else {
            None
        };
        let binary = if eff.wants_binary_raster() {
            let r = self.shard_binary.get_or_insert_with(BinaryRaster::new);
            r.pack(&x, spec.k, spec.zero_pad);
            if let Some(f) = fault.filter(|f| f.injects_raster_faults()) {
                let halo_rows =
                    halo_exchange_rows(grid, out_h, n_out, spec.k, r.padded_dims().0);
                inject_binary_faults(
                    f,
                    r,
                    |r| r.pack(&x, spec.k, spec.zero_pad),
                    fidx,
                    li,
                    &halo_rows,
                    report,
                )?;
            }
            Some(Arc::new(std::mem::take(r)))
        } else {
            None
        };
        let shards = plan_layer_shards(grid, out_h, n_out);
        let sl = Arc::new(ShardLayer {
            k: spec.k,
            zero_pad: spec.zero_pad,
            precision: spec.precision,
            input: Arc::clone(&x),
            kernels: Arc::clone(&spec.kernels),
            packed: layer.packed.clone(),
            raster: raster.clone(),
            binary: binary.clone(),
            scale_bias: Arc::clone(&spec.scale_bias),
        });
        self.shard_job += 1;
        let job = self.shard_job;
        let mut sent = 0usize;
        if let Some(tx) = self.tx.as_ref() {
            for s in &shards {
                let plans = shard_block_plans(&self.cfg, spec.k, spec.zero_pad, x.c, x.h, s);
                if tx
                    .send(Task::Shard { job, shard: s.index, plans, layer: Arc::clone(&sl) })
                    .is_err()
                {
                    break;
                }
                sent += 1;
            }
        }
        let mut acc = std::mem::take(&mut self.shard_acc);
        acc.clear();
        acc.resize(n_out * out_h * out_w, 0);
        let mut single_in_block = true;
        let mut first_err: Option<String> = None;
        let mut got = 0usize;
        let mut pool_gone = false;
        while got < sent {
            let reply = match self.rx_out.recv_timeout(WORKER_SWEEP) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    self.ensure_workers();
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    pool_gone = true;
                    break;
                }
            };
            let (j, s, res) = match reply {
                Reply::Shard(j, s, res) => (j, s, res),
                // A stale frame reply (from a per-frame batch that gave
                // up on a lost worker) — not ours.
                Reply::Frame(..) => continue,
            };
            if j != job {
                // A stale shard reply from a layer abandoned mid-drain.
                continue;
            }
            got += 1;
            match res {
                Ok(results) => {
                    for (plan, r) in &results {
                        frame_stats.merge(&r.stats);
                        if plan.in_blocks > 1 {
                            single_in_block = false;
                        }
                        reduce_block(
                            &mut acc, spec.zero_pad, spec.k, out_h, out_w, plan, &r.output,
                        );
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(format!("shard {s}: {e}"));
                    }
                }
            }
        }
        // Reclaim the raster scratch: workers drop their ShardLayer
        // Arc before replying, so after the last reply the caller's
        // `sl` is the only owner and the unwraps below are
        // deterministic.
        drop(sl);
        if let Some(arc) = raster {
            if let Ok(r) = Arc::try_unwrap(arc) {
                self.shard_raster = Some(r);
            }
        }
        if let Some(arc) = binary {
            if let Ok(r) = Arc::try_unwrap(arc) {
                self.shard_binary = Some(r);
            }
        }
        if let Some(e) = first_err {
            self.shard_acc = acc;
            return Err(YodannError::WorkerPanicked {
                frame: fidx as u64,
                layer: Some(li),
                message: e,
            });
        }
        if pool_gone || sent < shards.len() {
            self.shard_acc = acc;
            return Err(YodannError::SessionClosed);
        }
        let y = finalize_output(&acc, single_in_block, &spec.scale_bias, n_out, out_h, out_w);
        self.shard_acc = acc;
        Ok(y)
    }
}

impl Drop for NetworkSession {
    fn drop(&mut self) {
        // Closing the task channel makes every worker's recv() fail;
        // join them before the result receiver is torn down.
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn one pool worker wired to the shared task queue and reply
/// channel — used both at session build and by the supervisor's
/// mid-flight respawn.
fn spawn_worker(
    cfg: ChipConfig,
    kind: EngineKind,
    rx_in: &Arc<Mutex<Receiver<Task>>>,
    tx_out: &Sender<Reply>,
    plan: &Arc<SessionPlan>,
) -> JoinHandle<()> {
    let rx = Arc::clone(rx_in);
    let tx_out = tx_out.clone();
    let plan = Arc::clone(plan);
    std::thread::spawn(move || {
        worker_loop(cfg, kind, &rx, &tx_out, &plan);
    })
}

/// One pool worker: owns an engine plus per-frame scratch, serves both
/// frame and shard tasks until the session closes the task channel.
fn worker_loop(
    cfg: ChipConfig,
    kind: EngineKind,
    rx: &Mutex<Receiver<Task>>,
    tx_out: &Sender<Reply>,
    plan: &SessionPlan,
) {
    let mut engines = WorkerEngines::new(cfg, kind);
    let mut acc: Vec<i64> = Vec::new();
    // Per-worker raster scratch for the per-frame schedule, repacked
    // once per (frame, layer) and reused across frames — steady-state
    // serving of same-geometry traffic allocates nothing here. (The
    // sharded schedule shares one caller-side raster instead.) Binary
    // (XNOR) layers pack into the single-plane twin.
    let mut raster = BitplaneRaster::new();
    let mut binary = BinaryRaster::new();
    loop {
        // Take the next task; holding the lock while idle is fine —
        // exactly one waiter is handed each task. A sibling that
        // panicked while holding the lock leaves it poisoned; the queue
        // itself is still consistent (the lock only guards recv), so
        // recover the inner receiver instead of wedging the whole pool.
        let task = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(poisoned) => poisoned.into_inner().recv(),
        };
        let task = match task {
            Ok(t) => t,
            Err(_) => break, // session dropped
        };
        // A panic (bad frame geometry, engine bug) must reach the batch
        // as an error — a silently dead worker would leave run_batch
        // waiting forever on the task's reply.
        match task {
            Task::Frame(idx, frame) => {
                // An injected worker loss: fail the frame it took down
                // with it, then exit the thread so the supervisor's
                // respawn path is exercised end to end.
                let killed = match plan.fault.as_ref() {
                    Some(f) => f.take_kill(idx as u64),
                    None => false,
                };
                if killed {
                    let _ = tx_out.send(Reply::Frame(
                        idx,
                        Err(YodannError::WorkerPanicked {
                            frame: idx as u64,
                            layer: None,
                            message: "injected worker loss".into(),
                        }),
                    ));
                    return;
                }
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_frame_inner(
                        &cfg,
                        &mut engines,
                        plan,
                        idx,
                        frame,
                        &mut acc,
                        &mut raster,
                        &mut binary,
                    )
                }))
                .unwrap_or_else(|p| {
                    Err(YodannError::WorkerPanicked {
                        frame: idx as u64,
                        layer: None,
                        message: panic_message(p),
                    })
                });
                if out.is_err() {
                    // Engine/scratch state may be mid-frame garbage.
                    engines.rebuild();
                    acc = Vec::new();
                    raster = BitplaneRaster::new();
                    binary = BinaryRaster::new();
                }
                if tx_out.send(Reply::Frame(idx, out)).is_err() {
                    break;
                }
            }
            Task::Shard { job, shard, plans, layer } => {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let data = layer.as_layer_data();
                    let engine = engines.for_precision(layer.precision);
                    plans.iter().map(|p| (*p, engine.run_plan(&data, p))).collect::<Vec<_>>()
                }))
                .map_err(panic_message);
                // Drop the shared-layer Arc *before* replying: the
                // coordinator reclaims the raster scratch via
                // Arc::try_unwrap once the last reply arrives.
                drop(layer);
                if out.is_err() {
                    engines.rebuild();
                }
                if tx_out.send(Reply::Shard(job, shard, out)).is_err() {
                    break;
                }
            }
        }
    }
}

/// Padded raster rows that cross a shard boundary under `grid`: for
/// every row stripe that does not start at the image top, the k−1 rows
/// its windows read from the stripe above — the words a chip-to-chip
/// halo link carries (`power::halo_exchange_words` prices the same
/// traffic). Channel groups share row stripes, so only `out0 == 0`
/// shards contribute; indices are padded-raster rows, deduped, clamped.
fn halo_exchange_rows(
    grid: ShardGrid,
    out_h: usize,
    n_out: usize,
    k: usize,
    ph: usize,
) -> Vec<usize> {
    let mut rows: Vec<usize> = Vec::new();
    for s in plan_layer_shards(grid, out_h, n_out) {
        if s.out0 == 0 && s.row0 > 0 {
            for dy in 0..k.saturating_sub(1) {
                let py = s.row0 + dy;
                if py < ph && !rows.contains(&py) {
                    rows.push(py);
                }
            }
        }
    }
    rows
}

/// The image-memory / halo-exchange leg of the fault plan, run on a
/// freshly packed raster: seal (when detecting) → inject both sites →
/// verify → on detection repack once and re-inject at the guard-banded
/// retry rate → a second detection refuses the frame. Surviving flips
/// (all of them, when detection is off) land on `report`.
fn inject_raster_faults(
    f: &FaultPlan,
    raster: &mut BitplaneRaster,
    mut repack: impl FnMut(&mut BitplaneRaster),
    fidx: usize,
    li: usize,
    halo_rows: &[usize],
    report: &mut FaultReport,
) -> Result<(), YodannError> {
    let (frame, layer) = (fidx as u64, li as u64);
    if f.detects() {
        raster.seal();
    }
    let mut image_flips = f.corrupt_raster(raster, frame, layer, 0);
    let mut halo_flips = f.corrupt_halo(raster, halo_rows, frame, layer, 0);
    if f.detects() && raster.verify().is_some() {
        report.detected += 1;
        report.retries += 1;
        repack(raster);
        raster.seal();
        image_flips = f.corrupt_raster(raster, frame, layer, 1);
        halo_flips = f.corrupt_halo(raster, halo_rows, frame, layer, 1);
        if raster.verify().is_some() {
            let site = if halo_flips > 0 {
                FaultSite::HaloExchange
            } else {
                FaultSite::ImageMemory
            };
            return Err(YodannError::FaultDetected { frame: Some(frame), layer: li, site });
        }
    }
    report.image_flips += image_flips;
    report.halo_flips += halo_flips;
    Ok(())
}

/// The binary-raster twin of [`inject_raster_faults`], run on a freshly
/// packed XNOR-mode raster: same seal → inject → verify → repack-once
/// policy, same detect-twice refusal, same report accounting.
fn inject_binary_faults(
    f: &FaultPlan,
    raster: &mut BinaryRaster,
    mut repack: impl FnMut(&mut BinaryRaster),
    fidx: usize,
    li: usize,
    halo_rows: &[usize],
    report: &mut FaultReport,
) -> Result<(), YodannError> {
    let (frame, layer) = (fidx as u64, li as u64);
    if f.detects() {
        raster.seal();
    }
    let mut image_flips = f.corrupt_binary(raster, frame, layer, 0);
    let mut halo_flips = f.corrupt_binary_halo(raster, halo_rows, frame, layer, 0);
    if f.detects() && raster.verify().is_some() {
        report.detected += 1;
        report.retries += 1;
        repack(raster);
        raster.seal();
        image_flips = f.corrupt_binary(raster, frame, layer, 1);
        halo_flips = f.corrupt_binary_halo(raster, halo_rows, frame, layer, 1);
        if raster.verify().is_some() {
            let site = if halo_flips > 0 {
                FaultSite::HaloExchange
            } else {
                FaultSite::ImageMemory
            };
            return Err(YodannError::FaultDetected { frame: Some(frame), layer: li, site });
        }
    }
    report.image_flips += image_flips;
    report.halo_flips += halo_flips;
    Ok(())
}

/// Carry one frame through the step program on one engine: conv steps
/// run raster pack (engines that want one) → plan → blocks → wide
/// reduction (reusing `acc`) → final α/β; host-op interludes compute in
/// place over the slot store. Identical numerics to `run_layer_engine`
/// plus the host composition; the frame's activity ledger is merged
/// across every block of every conv step.
#[allow(clippy::too_many_arguments)] // the worker's whole scratch set, threaded explicitly
fn run_frame_inner(
    cfg: &ChipConfig,
    engines: &mut WorkerEngines,
    plan: &SessionPlan,
    fidx: usize,
    frame: Image,
    acc: &mut Vec<i64>,
    raster: &mut BitplaneRaster,
    binary: &mut BinaryRaster,
) -> Result<TracedFrame, YodannError> {
    if let Some(f) = plan.fault.as_ref() {
        f.maybe_panic(fidx as u64);
    }
    let mut fault_report = plan.weight_faults;
    let mut stats = ChipStats::default();
    let mut slots: Vec<Option<Image>> = (0..plan.n_slots).map(|_| None).collect();
    slots[plan.input_slot] = Some(frame);
    for (si, step) in plan.steps.iter().enumerate() {
        let out = match step {
            PlanStep::Conv { conv, src, .. } => {
                let x = slot_ref(&slots, *src);
                run_conv_layer(
                    cfg,
                    engines,
                    *conv,
                    &plan.convs[*conv],
                    x,
                    acc,
                    raster,
                    binary,
                    &mut stats,
                    plan.fault.as_ref(),
                    fidx,
                    &mut fault_report,
                )?
            }
            PlanStep::Relu { src, .. } => {
                // When this step is the source's last use (always, for
                // the chain shim) steal the map and ReLU in place —
                // the historical zero-copy behavior; cloning is only
                // needed for graphs that fan the value out further.
                let mut y = if plan.free_after[si].contains(src) {
                    slot_take(&mut slots, *src)
                } else {
                    slot_ref(&slots, *src).clone()
                };
                relu_inplace(&mut y);
                y
            }
            PlanStep::BatchNormThreshold { thresholds, src, .. } => {
                let mut y = if plan.free_after[si].contains(src) {
                    slot_take(&mut slots, *src)
                } else {
                    slot_ref(&slots, *src).clone()
                };
                threshold_inplace(&mut y, thresholds);
                y
            }
            PlanStep::MaxPool2 { src, .. } => {
                maybe_maxpool2(slot_ref(&slots, *src))
            }
            PlanStep::Subsample2 { src, .. } => {
                subsample2(slot_ref(&slots, *src))
            }
            PlanStep::Add { srcs, .. } => {
                let imgs: Vec<&Image> =
                    srcs.iter().map(|&s| slot_ref(&slots, s)).collect();
                add_wide_saturating(&imgs)
            }
            PlanStep::Concat { srcs, .. } => {
                let imgs: Vec<&Image> =
                    srcs.iter().map(|&s| slot_ref(&slots, s)).collect();
                concat_channels(&imgs)
            }
        };
        slots[step.dst()] = Some(out);
        for &f in &plan.free_after[si] {
            slots[f] = None;
        }
    }
    Ok(TracedFrame {
        output: take_output(&mut slots, plan.output_slot),
        stats,
        fault: fault_report,
    })
}

/// One conv step on one engine: plan → blocks → wide reduction → final
/// α/β, reusing the worker's accumulator and raster scratch.
#[allow(clippy::too_many_arguments)] // the worker's whole scratch set, threaded explicitly
fn run_conv_layer(
    cfg: &ChipConfig,
    engines: &mut WorkerEngines,
    li: usize,
    layer: &SessionLayer,
    x: &Image,
    acc: &mut Vec<i64>,
    raster: &mut BitplaneRaster,
    binary: &mut BinaryRaster,
    stats: &mut ChipStats,
    fault: Option<&FaultPlan>,
    fidx: usize,
    report: &mut FaultReport,
) -> Result<Image, YodannError> {
    let spec = &layer.conv;
    let engine = engines.for_precision(spec.precision);
    assert_eq!(
        x.c, spec.kernels.n_in,
        "layer {li}: frame has {} channels, kernels expect {}",
        x.c, spec.kernels.n_in
    );
    let n_out = spec.kernels.n_out;
    // Plan first: plan_layer's geometry guard fires before the
    // output shape math can underflow (valid-mode h < k); the width
    // guard covers the out_w mirror.
    let plans = plan_layer(cfg, spec.k, spec.zero_pad, x.c, n_out, x.h);
    check_width_geometry(spec.zero_pad, spec.k, x.w);
    let (out_h, out_w) =
        if spec.zero_pad { (x.h, x.w) } else { (x.h - spec.k + 1, x.w - spec.k + 1) };
    // Pack this layer's activations once into the worker's reusable
    // raster scratch; every block of the layer then slices windows
    // out of it by shifts.
    let wants_raster = engine.wants_raster();
    if wants_raster {
        raster.pack(x, spec.k, spec.zero_pad);
        // Per-frame schedule: the raster never crosses a shard
        // boundary, so only the image-memory site applies here.
        if let Some(f) = fault.filter(|f| f.injects_raster_faults()) {
            inject_raster_faults(
                f,
                raster,
                |r| r.pack(x, spec.k, spec.zero_pad),
                fidx,
                li,
                &[],
                report,
            )?;
        }
    }
    // Binary (XNOR) layers pack the single-plane raster instead —
    // mutually exclusive with the 12-plane pack above per layer.
    let wants_binary = engine.wants_binary_raster();
    if wants_binary {
        binary.pack(x, spec.k, spec.zero_pad);
        if let Some(f) = fault.filter(|f| f.injects_raster_faults()) {
            inject_binary_faults(
                f,
                binary,
                |r| r.pack(x, spec.k, spec.zero_pad),
                fidx,
                li,
                &[],
                report,
            )?;
        }
    }
    let data = LayerData {
        k: spec.k,
        zero_pad: spec.zero_pad,
        input: x,
        kernels: &spec.kernels,
        packed: layer.packed.as_deref(),
        raster: wants_raster.then_some(&*raster),
        binary: wants_binary.then_some(&*binary),
        scale_bias: &spec.scale_bias,
    };
    acc.clear();
    acc.resize(n_out * out_h * out_w, 0);
    let mut single_in_block = true;
    for plan in &plans {
        let r = engine.run_plan(&data, plan);
        stats.merge(&r.stats);
        if plan.in_blocks > 1 {
            single_in_block = false;
        }
        reduce_block(acc, spec.zero_pad, spec.k, out_h, out_w, plan, &r.output);
    }
    Ok(finalize_output(acc, single_in_block, &spec.scale_bias, n_out, out_h, out_w))
}

/// Read a live slot of the step interpreters' slot store. The
/// compiler's topological order guarantees every source is written
/// before its first read and freed only after its last
/// (`compute_free_after`); `analysis::liveness` proves the same
/// discipline statically per graph. A `None` is therefore a plan bug —
/// the historical panic text is kept.
fn slot_ref<T>(slots: &[Option<T>], s: usize) -> &T {
    match slots[s].as_ref() {
        Some(v) => v,
        None => panic!("topological order"),
    }
}

/// Steal a slot's value on its last use (zero-copy epilogue mutation).
fn slot_take<T>(slots: &mut [Option<T>], s: usize) -> T {
    match slots[s].take() {
        Some(v) => v,
        None => panic!("topological order"),
    }
}

/// Take the finished output slot once the program ends.
fn take_output<T>(slots: &mut [Option<T>], s: usize) -> T {
    match slots[s].take() {
        Some(v) => v,
        None => panic!("plan writes its output"),
    }
}

/// Quantized ReLU (`max(0, ·)` on raw Q2.9), the host interlude between
/// accelerator layers.
fn relu_inplace(img: &mut Image) {
    img.data.iter_mut().for_each(|v| *v = (*v).max(0));
}

/// Batch-norm threshold binarization, the host interlude that feeds a
/// binary (XNOR) trunk: per channel `c`, every sample becomes
/// `±BINARY_ONE` by comparison against `thresholds[c]` (raw Q2.9) — the
/// standard folding of batch-norm + sign into one comparison. The `>=`
/// matches the XNOR engines' `binarize_q29` convention, so a following
/// binary conv sees exactly the signs this step wrote.
fn threshold_inplace(img: &mut Image, thresholds: &[i64]) {
    assert_eq!(img.c, thresholds.len(), "threshold arity must match channels");
    for c in 0..img.c {
        let t = thresholds[c];
        for y in 0..img.h {
            for v in img.row_mut(c, y) {
                *v = if *v >= t { BINARY_ONE } else { -BINARY_ONE };
            }
        }
    }
}

/// The 2×2 max-pool interlude: identity when the map is smaller than
/// 2×2 (matching the chain shim's historical behavior and the shape
/// walk in [`CompiledGraph::walk_shapes`]).
fn maybe_maxpool2(img: &Image) -> Image {
    if img.h >= 2 && img.w >= 2 {
        maxpool2(img)
    } else {
        img.clone()
    }
}

/// Stride-2 subsample: keep the pixels at even coordinates — how a
/// stride-2 convolution runs on the stride-less accelerator (computed
/// at stride 1, subsampled off-chip).
fn subsample2(img: &Image) -> Image {
    let mut out = Image::zeros(img.c, img.h.div_ceil(2), img.w.div_ceil(2));
    for c in 0..img.c {
        for y in 0..out.h {
            for x in 0..out.w {
                *out.at_mut(c, y, x) = img.at(c, 2 * y, 2 * x);
            }
        }
    }
    out
}

/// Residual add: wide integer sum of every branch, saturated once to
/// Q2.9 — host accumulators are not the chip's 12-bit datapath, so the
/// only quantization is the final writeback.
fn add_wide_saturating(imgs: &[&Image]) -> Image {
    let first = imgs[0];
    let mut out = first.clone();
    for img in &imgs[1..] {
        assert_eq!(
            (img.c, img.h, img.w),
            (first.c, first.h, first.w),
            "residual-add branches must agree in shape"
        );
        for (o, v) in out.data.iter_mut().zip(img.data.iter()) {
            *o += *v;
        }
    }
    out.data.iter_mut().for_each(|v| *v = Q2_9.saturate(*v));
    out
}

/// Channel-wise concatenation of branches with identical H×W.
fn concat_channels(imgs: &[&Image]) -> Image {
    let (h, w) = (imgs[0].h, imgs[0].w);
    let c_total = imgs.iter().map(|i| i.c).sum();
    let mut out = Image::zeros(c_total, h, w);
    let mut base = 0;
    for img in imgs {
        assert_eq!((img.h, img.w), (h, w), "concat branches must agree on HxW");
        for c in 0..img.c {
            for y in 0..h {
                out.row_mut(base + c, y).copy_from_slice(img.row(c, y));
            }
        }
        base += img.c;
    }
    out
}

/// Best-effort panic payload → message (shared with the serving
/// dispatcher, which converts coordinator panics to typed errors).
pub(crate) fn panic_message(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "<non-string panic>".into()
    }
}

/// 2×2 max-pool with stride 2 (odd trailing rows/columns dropped).
fn maxpool2(img: &Image) -> Image {
    let mut out = Image::zeros(img.c, img.h / 2, img.w / 2);
    for c in 0..img.c {
        for y in 0..out.h {
            for x in 0..out.w {
                *out.at_mut(c, y, x) = img
                    .at(c, 2 * y, 2 * x)
                    .max(img.at(c, 2 * y, 2 * x + 1))
                    .max(img.at(c, 2 * y + 1, 2 * x))
                    .max(img.at(c, 2 * y + 1, 2 * x + 1));
            }
        }
    }
    out
}

#[cfg(test)]
#[allow(deprecated)] // the old NetworkSession surface stays pinned for one release
mod tests {
    use super::*;
    use crate::coordinator::{run_layer_engine, ExecOptions, LayerWorkload};
    use crate::model::networks;
    use crate::workload::synthetic_scene;

    fn two_layer_specs(seed: u64) -> Vec<SessionLayerSpec> {
        let mut g = Gen::new(seed);
        vec![
            SessionLayerSpec {
                k: 3,
                zero_pad: true,
                kernels: Arc::new(BinaryKernels::random(&mut g, 6, 3, 3)),
                scale_bias: Arc::new(ScaleBias {
                    alpha: vec![Q2_9.from_f64(0.1); 6],
                    beta: vec![0; 6],
                }),
                relu: true,
                maxpool2: true,
            },
            SessionLayerSpec {
                k: 5,
                zero_pad: true,
                kernels: Arc::new(BinaryKernels::random(&mut g, 4, 6, 5)),
                scale_bias: Arc::new(ScaleBias {
                    alpha: vec![Q2_9.from_f64(0.1); 4],
                    beta: vec![0; 4],
                }),
                relu: false,
                maxpool2: false,
            },
        ]
    }

    fn manual_reference_on(
        specs: &[SessionLayerSpec],
        cfg: &ChipConfig,
        frame: &Image,
        kind: EngineKind,
    ) -> Image {
        let mut x = frame.clone();
        for spec in specs {
            let wl = LayerWorkload {
                k: spec.k,
                zero_pad: spec.zero_pad,
                input: x.clone(),
                kernels: (*spec.kernels).clone(),
                scale_bias: (*spec.scale_bias).clone(),
            };
            let run = run_layer_engine(&wl, cfg, ExecOptions { workers: 1 }, kind);
            x = run.output;
            if spec.relu {
                x.data.iter_mut().for_each(|v| *v = (*v).max(0));
            }
            if spec.maxpool2 && x.h >= 2 && x.w >= 2 {
                x = maxpool2(&x);
            }
        }
        x
    }

    fn manual_reference(specs: &[SessionLayerSpec], cfg: &ChipConfig, frame: &Image) -> Image {
        manual_reference_on(specs, cfg, frame, EngineKind::CycleAccurate)
    }

    #[test]
    fn session_matches_layerwise_executor_both_engines() {
        // Multi-bit kinds only: the XNOR family computes a different
        // (binarized) function and gets its own reference below.
        let cfg = ChipConfig::tiny(4);
        let specs = two_layer_specs(77);
        let mut g = Gen::new(5);
        let frame = synthetic_scene(&mut g, 3, 12, 12);
        let want = manual_reference(&specs, &cfg, &frame);
        for kind in EngineKind::MULTI_BIT {
            let mut sess = NetworkSession::new(cfg, kind, 2, specs.clone());
            let got = sess.run_frame(frame.clone());
            assert_eq!(got, want, "engine {}", kind.name());
        }
    }

    #[test]
    fn xnor_session_matches_the_layerwise_xnor_executor() {
        // A session on a binary kind runs every layer through the XNOR
        // family and must be bit-identical to the layerwise executor on
        // EngineKind::Xnor (the whole family agrees by construction).
        let cfg = ChipConfig::tiny(4);
        let specs = two_layer_specs(85);
        let mut g = Gen::new(6);
        let frame = synthetic_scene(&mut g, 3, 12, 12);
        let want = manual_reference_on(&specs, &cfg, &frame, EngineKind::Xnor);
        for kind in EngineKind::XNOR {
            let mut sess = NetworkSession::new(cfg, kind, 2, specs.clone());
            let got = sess.run_frame(frame.clone());
            assert_eq!(got, want, "engine {}", kind.name());
        }
        // And a different function than the multi-bit reference: the
        // binarization must actually bite on this workload.
        assert_ne!(want, manual_reference(&specs, &cfg, &frame));
    }

    fn mixed_precision_compiled(seed: u64) -> CompiledGraph {
        use crate::model::graph::{NetworkBuilder, Weights};
        let mut g = Gen::new(seed);
        let mut b = NetworkBuilder::new("mixed", 3);
        let x = b.input();
        // BWN stem → batch-norm threshold → XNOR trunk.
        let stem = b.conv("stem", x, true, Weights::seeded(&mut g, 4, 3, 3));
        let bin = b.batch_norm_threshold("bnt", stem, Arc::new(vec![0; 4]));
        let trunk = b.conv_with_precision(
            "trunk",
            bin,
            true,
            Weights::seeded(&mut g, 4, 4, 3),
            Precision::Binary,
        );
        match b.build(trunk).compile() {
            Ok(cg) => cg,
            Err(e) => panic!("{e}"),
        }
    }

    #[test]
    fn mixed_precision_graph_routes_layers_by_precision() {
        // A BWN stem + XNOR trunk session must compute: stem on the
        // session's multi-bit engine, host thresholding to ±1, trunk on
        // the XNOR companion — bit-identical across every multi-bit
        // main kind and every policy.
        let cfg = ChipConfig::tiny(4);
        let mut g = Gen::new(41);
        let frame = synthetic_scene(&mut g, 3, 10, 10);
        // Manual reference: layerwise executor + host threshold.
        let cg = mixed_precision_compiled(55);
        let stem_wl = LayerWorkload {
            k: 3,
            zero_pad: true,
            input: frame.clone(),
            kernels: (*cg.convs[0].kernels).clone(),
            scale_bias: (*cg.convs[0].scale_bias).clone(),
        };
        let mut mid =
            run_layer_engine(&stem_wl, &cfg, ExecOptions { workers: 1 }, EngineKind::CycleAccurate)
                .output;
        threshold_inplace(&mut mid, &[0; 4]);
        let trunk_wl = LayerWorkload {
            k: 3,
            zero_pad: true,
            input: mid,
            kernels: (*cg.convs[1].kernels).clone(),
            scale_bias: (*cg.convs[1].scale_bias).clone(),
        };
        let want =
            run_layer_engine(&trunk_wl, &cfg, ExecOptions { workers: 1 }, EngineKind::Xnor).output;
        for kind in EngineKind::MULTI_BIT {
            for policy in [ShardPolicy::PerFrame, ShardPolicy::RowBands(2)] {
                let mut sess = match NetworkSession::spawn_plan(
                    cfg,
                    kind,
                    2,
                    policy,
                    mixed_precision_compiled(55),
                    None,
                ) {
                    Ok(s) => s,
                    Err(e) => panic!("{e}"),
                };
                let out = sess.run_batch_traced(vec![frame.clone()]);
                let got = match &out[0] {
                    Ok(t) => &t.output,
                    Err(e) => panic!("engine {} policy {policy}: {e}", kind.name()),
                };
                assert_eq!(*got, want, "engine {} policy {policy}", kind.name());
            }
        }
    }

    #[test]
    fn every_policy_matches_the_per_frame_schedule() {
        // The hybrid-schedule obligation: per-shard and auto batches are
        // bit-identical to per-frame, for every engine kind.
        let cfg = ChipConfig::tiny(4);
        let specs = two_layer_specs(81);
        let mut g = Gen::new(17);
        let frames: Vec<Image> = (0..3).map(|_| synthetic_scene(&mut g, 3, 11, 13)).collect();
        for kind in EngineKind::ALL {
            let mut base = NetworkSession::new(cfg, kind, 3, specs.clone());
            let want = base.run_batch(frames.clone());
            for policy in [
                ShardPolicy::PerShard(ShardGrid::striped(3)),
                ShardPolicy::PerShard(ShardGrid::new(2, 2)),
                ShardPolicy::Auto,
                ShardPolicy::RowBands(0),
                ShardPolicy::RowBands(2),
            ] {
                let mut sess =
                    NetworkSession::with_policy(cfg, kind, 3, policy, specs.clone());
                let got = sess.run_batch(frames.clone());
                assert_eq!(got, want, "engine {} policy {policy}", kind.name());
            }
        }
    }

    #[test]
    fn worker_count_never_changes_batch_results_any_policy() {
        // 1, 2 and 8 workers over the same batch must be bit-identical
        // under every schedule.
        let cfg = ChipConfig::tiny(4);
        let specs = two_layer_specs(82);
        let mut g = Gen::new(23);
        let frames: Vec<Image> = (0..4).map(|_| synthetic_scene(&mut g, 3, 10, 12)).collect();
        let policies = [
            ShardPolicy::PerFrame,
            ShardPolicy::PerShard(ShardGrid::striped(4)),
            ShardPolicy::Auto,
            ShardPolicy::RowBands(3),
        ];
        for policy in policies {
            let mut base =
                NetworkSession::with_policy(cfg, EngineKind::Functional, 1, policy, specs.clone());
            let want = base.run_batch(frames.clone());
            for workers in [2, 8] {
                let mut sess = NetworkSession::with_policy(
                    cfg,
                    EngineKind::Functional,
                    workers,
                    policy,
                    specs.clone(),
                );
                let got = sess.run_batch(frames.clone());
                assert_eq!(got, want, "workers={workers} policy {policy}");
            }
        }
    }

    #[test]
    fn results_are_independent_of_frame_submission_order() {
        // Submitting the same frames permuted returns the same images,
        // permuted the same way — no cross-frame state, any policy.
        let cfg = ChipConfig::tiny(4);
        let specs = two_layer_specs(83);
        let mut g = Gen::new(29);
        let frames: Vec<Image> = (0..5).map(|_| synthetic_scene(&mut g, 3, 9, 9)).collect();
        let perm = [3usize, 0, 4, 2, 1];
        for policy in [ShardPolicy::PerFrame, ShardPolicy::PerShard(ShardGrid::striped(2))] {
            let mut sess =
                NetworkSession::with_policy(cfg, EngineKind::Functional, 3, policy, specs.clone());
            let fwd = sess.run_batch(frames.clone());
            let permuted: Vec<Image> = perm.iter().map(|&i| frames[i].clone()).collect();
            let back = sess.run_batch(permuted);
            for (slot, &src) in perm.iter().enumerate() {
                assert_eq!(back[slot], fwd[src], "slot {slot} policy {policy}");
            }
        }
    }

    #[test]
    fn batch_results_are_ordered_and_deterministic() {
        let cfg = ChipConfig::tiny(4);
        let specs = two_layer_specs(78);
        let mut g = Gen::new(9);
        let frames: Vec<Image> = (0..6).map(|_| synthetic_scene(&mut g, 3, 10, 10)).collect();
        let mut sess = NetworkSession::new(cfg, EngineKind::Functional, 3, specs.clone());
        let batch = sess.run_batch(frames.clone());
        assert_eq!(batch.len(), frames.len());
        // Order: each batch slot must equal its frame run alone.
        let mut solo = NetworkSession::new(cfg, EngineKind::Functional, 1, specs);
        for (i, f) in frames.into_iter().enumerate() {
            assert_eq!(batch[i], solo.run_frame(f), "frame {i}");
        }
    }

    #[test]
    fn session_survives_multiple_batches() {
        let cfg = ChipConfig::tiny(4);
        let mut sess = NetworkSession::new(cfg, EngineKind::Functional, 2, two_layer_specs(79));
        let mut g = Gen::new(1);
        for _ in 0..3 {
            let frames: Vec<Image> =
                (0..4).map(|_| synthetic_scene(&mut g, 3, 8, 8)).collect();
            let out = sess.run_batch(frames);
            assert_eq!(out.len(), 4);
            assert_eq!((out[0].c, out[0].h, out[0].w), (4, 4, 4));
        }
    }

    #[test]
    fn sharded_schedule_reuses_the_caller_side_raster_scratch() {
        // The per-shard analog of the worker scratch-reuse guarantee:
        // after the first frame warms the caller-side raster to the
        // largest layer, steady-state frames must not grow it — which
        // also proves the Arc round-trip reclaims the scratch every
        // layer instead of silently dropping it.
        let cfg = ChipConfig::tiny(4);
        let mut sess = NetworkSession::with_policy(
            cfg,
            EngineKind::Functional,
            3,
            ShardPolicy::PerShard(ShardGrid::striped(3)),
            two_layer_specs(84),
        );
        let mut g = Gen::new(31);
        sess.run_frame(synthetic_scene(&mut g, 3, 12, 12));
        let warm = sess.shard_raster_reallocs();
        assert!(warm < u64::MAX, "raster scratch lost after warm-up");
        for _ in 0..3 {
            let frames: Vec<Image> =
                (0..2).map(|_| synthetic_scene(&mut g, 3, 12, 12)).collect();
            sess.run_batch(frames);
        }
        assert_eq!(
            sess.shard_raster_reallocs(),
            warm,
            "steady-state sharded frames must not grow the raster scratch"
        );
    }

    #[test]
    fn synthetic_network_chains_and_pools() {
        let specs = SessionLayerSpec::synthetic_network(&networks::scene_labeling(), 3).unwrap();
        assert_eq!(specs.len(), 3);
        assert!(specs[0].maxpool2 && specs[1].maxpool2);
        assert!(!specs[2].relu);
        assert_eq!(specs[0].kernels.n_in, 3);
        assert_eq!(specs[2].kernels.n_out, 256);
        // bc-cifar10 pools after rows 2 and 4.
        let bc = SessionLayerSpec::synthetic_network(&networks::bc_cifar10(), 3).unwrap();
        assert_eq!(bc.len(), 6);
        assert!(bc[1].maxpool2 && bc[3].maxpool2);
        assert!(!bc[0].maxpool2);
        // AlexNet's parallel split rows are rejected with a typed error.
        let err = SessionLayerSpec::synthetic_network(&networks::alexnet(), 3).unwrap_err();
        assert!(
            matches!(&err, YodannError::NotASimpleChain { net, .. } if net == "alexnet"),
            "{err}"
        );
        assert!(err.to_string().contains("not a simple chain"), "{err}");
    }

    #[test]
    fn seeded_specs_are_reproducible() {
        let a = SessionLayerSpec::synthetic_network(&networks::bc_svhn(), 42).unwrap();
        let b = SessionLayerSpec::synthetic_network(&networks::bc_svhn(), 42).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.kernels.bits, y.kernels.bits);
        }
    }

    #[test]
    fn injected_worker_loss_fails_one_frame_and_respawns() {
        // The hardest supervisor case: a ONE-worker pool loses its only
        // thread on frame 0 with frame 1 still queued behind it. The
        // drain's sweep must respawn mid-batch, frame 0 must come back
        // as a typed error, frame 1 and every later batch must succeed.
        let cfg = ChipConfig::tiny(4);
        let fault = crate::fault::FaultPlan::seeded(1).kill_worker_on_frame(0);
        let mut sess = NetworkSession::spawn_plan(
            cfg,
            EngineKind::Functional,
            1,
            ShardPolicy::PerFrame,
            chain_compiled(&two_layer_specs(90)),
            Some(fault),
        )
        .unwrap();
        let mut g = Gen::new(9);
        let frames: Vec<Image> = (0..2).map(|_| synthetic_scene(&mut g, 3, 8, 8)).collect();
        let out = sess.run_batch_traced(frames.clone());
        assert!(
            matches!(out[0], Err(YodannError::WorkerPanicked { frame: 0, .. })),
            "{:?}",
            out[0].as_ref().map(|_| ())
        );
        assert!(out[1].is_ok(), "{}", out[1].as_ref().err().unwrap());
        // The kill token is spent; the respawned worker serves on.
        let again = sess.run_batch_traced(frames);
        assert!(again.iter().all(|r| r.is_ok()));
        assert_eq!(sess.worker_respawns(), 1);
    }

    #[test]
    fn injected_panic_poisons_nothing_and_pool_survives() {
        // A panicking frame is caught in the worker loop: only its slot
        // errors (with the historical panic text preserved in Display),
        // siblings and later batches are unaffected, and the poisoned
        // task-queue lock is recovered rather than wedging the pool.
        let cfg = ChipConfig::tiny(4);
        let fault = crate::fault::FaultPlan::seeded(2).panic_on_frame(1);
        let mut sess = NetworkSession::spawn_plan(
            cfg,
            EngineKind::Functional,
            2,
            ShardPolicy::PerFrame,
            chain_compiled(&two_layer_specs(91)),
            Some(fault),
        )
        .unwrap();
        let mut g = Gen::new(11);
        let frames: Vec<Image> = (0..4).map(|_| synthetic_scene(&mut g, 3, 8, 8)).collect();
        let out = sess.run_batch_traced(frames);
        for (i, r) in out.iter().enumerate() {
            if i == 1 {
                let e = r.as_ref().err().expect("frame 1 must fail");
                let text = e.to_string();
                assert!(text.contains("failed in a session worker"), "{text}");
                assert!(text.contains("deliberately injected"), "{text}");
            } else {
                assert!(r.is_ok(), "frame {i}: {}", r.as_ref().err().unwrap());
            }
        }
        // panic_on_frame keys on the batch index, so a 1-frame batch
        // (index 0) avoids re-triggering it.
        let mut g2 = Gen::new(12);
        let again = sess.run_batch_traced(vec![synthetic_scene(&mut g2, 3, 8, 8)]);
        assert!(again[0].is_ok());
    }
}
