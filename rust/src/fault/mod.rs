//! Seeded fault injection + detection for the near-threshold corners.
//!
//! YodaNN's 895 µW headline rests on standard-cell latch memories that
//! keep working at 0.6 V — exactly the regime where single-event upsets
//! in memories and interconnect stop being negligible (§III-C; BinarEye
//! and Hyperdrive trade the same margin explicitly). The simulator
//! prices those corners but, before this module, never modeled what
//! going there does to the *data*.
//!
//! [`FaultPlan`] is a seeded, reproducible injector for the three places
//! the paper cares about:
//!
//! * **image memory** — raster plane words, flipped right after pack;
//! * **weight memory** — packed filter-bank bits, flipped at session
//!   build (weights are written once and then resident);
//! * **halo exchange** — the k−1 raster rows that cross a shard
//!   boundary, flipped again to model a lossy chip-to-chip link.
//!
//! Per-word flip probabilities derive from a voltage-dependent
//! bit-error-rate model ([`bit_error_rate`], backed by
//! `VfCurve::bit_error_rate`), so a plan can be armed directly
//! [`FaultPlan::at_corner`]. Detection is checksum-based
//! (`BitplaneRaster::seal`/`verify`, `PackedKernels::verify`) with a
//! detect → retry-once-at-guard-banded-rate → typed-error policy; what
//! happened to each frame is reported through
//! [`FaultReport`] on the frame's telemetry.
//!
//! Everything is deterministic: the same seed over the same traffic
//! produces the same flips, the same detections, and the same report —
//! per (site, frame, layer, attempt), independent of worker scheduling.

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::raster::mix64;
use crate::engine::{BinaryRaster, BitplaneRaster, PackedKernels};
use crate::model::Corner;
use crate::power::CorePowerModel;
use crate::testkit::Gen;

/// Where an injected (or detected) fault lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Raster plane words in the image bank.
    ImageMemory,
    /// Packed filter-bank weight bits.
    WeightMemory,
    /// Raster rows crossing a shard boundary.
    HaloExchange,
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FaultSite::ImageMemory => "image-memory",
            FaultSite::WeightMemory => "weight-memory",
            FaultSite::HaloExchange => "halo-exchange",
        })
    }
}

/// What fault injection did to one frame (plus the session-lifetime
/// weight-memory faults, folded into every frame that computed with
/// those weights). Surfaced through `FrameTelemetry::fault`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Bits flipped in image-memory raster words (survivors only: flips
    /// that a detect+retry repack cleaned up are not counted here).
    pub image_flips: u32,
    /// Bits flipped in packed filter-bank weights at session build.
    pub weight_flips: u32,
    /// Bits flipped in halo-exchange rows.
    pub halo_flips: u32,
    /// Checksum detections (each one triggered a repack retry).
    pub detected: u32,
    /// Repack retries performed after a detection.
    pub retries: u32,
}

impl FaultReport {
    /// Total surviving bit flips across all sites.
    pub fn total_flips(&self) -> u32 {
        self.image_flips + self.weight_flips + self.halo_flips
    }

    /// Fold another report into this one.
    pub fn merge(&mut self, other: &FaultReport) {
        self.image_flips += other.image_flips;
        self.weight_flips += other.weight_flips;
        self.halo_flips += other.halo_flips;
        self.detected += other.detected;
        self.retries += other.retries;
    }
}

/// A seeded, reproducible fault-injection plan.
///
/// Built with [`FaultPlan::seeded`] (inert until a rate is set via
/// [`FaultPlan::ber`] or [`FaultPlan::at_corner`]) or
/// [`FaultPlan::disabled`] (explicit no-injection override, e.g. to beat
/// a `YODANN_FAULT_SEED` environment arm). Cloning is cheap and clones
/// share the one-shot worker-kill fuse, so a plan distributed across
/// worker threads still kills at most one worker.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    ber: f64,
    live: Option<LiveBer>,
    detect: bool,
    image: bool,
    weights: bool,
    halo: bool,
    panic_frame: Option<u64>,
    kill_frame: Option<u64>,
    kill_fuse: Arc<AtomicBool>,
}

/// A runtime-adjustable bit-error-rate dial shared with a [`FaultPlan`]
/// via [`FaultPlan::live_ber`] — the serve governor's fault hook. As the
/// DVFS governor steps the simulated corner, it moves this dial (e.g. to
/// [`bit_error_rate`] at the new corner) and the injection rate follows
/// **without rebuilding the session**: the plan's seed, sites and
/// detection policy stay fixed, only the per-bit upset probability
/// floats. Injection stays deterministic as long as the dial moves at
/// deterministic points in the traffic (the serve loop moves it only at
/// tick boundaries, between fully-drained batches).
#[derive(Debug, Clone)]
pub struct LiveBer(Arc<AtomicU64>);

impl LiveBer {
    /// A dial starting at `ber` upsets per bit-access.
    pub fn new(ber: f64) -> LiveBer {
        let dial = LiveBer(Arc::new(AtomicU64::new(0)));
        dial.set(ber);
        dial
    }

    /// Move the dial. Panics outside `[0, 1]`, like [`FaultPlan::ber`].
    pub fn set(&self, ber: f64) {
        assert!((0.0..=1.0).contains(&ber), "bit-error rate {ber} outside [0, 1]");
        self.0.store(ber.to_bits(), Ordering::SeqCst);
    }

    /// The dial's current rate.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::SeqCst))
    }
}

/// Injection rate used by the `YODANN_FAULT_SEED` CI smoke arm: low
/// enough that a double fault (one surviving the retry) is vanishingly
/// unlikely across the whole suite, high enough that the detect/retry
/// path actually runs a handful of times.
const SMOKE_BER: f64 = 1e-9;

const TAG_IMAGE: u64 = 0x1A6E;
const TAG_WEIGHTS: u64 = 0x2B7F;
const TAG_HALO: u64 = 0x3C90;

impl FaultPlan {
    /// A plan with every site enabled and detection on, but a zero
    /// bit-error rate — inert until [`Self::ber`] or [`Self::at_corner`]
    /// arms it (or a panic/kill frame is set).
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            ber: 0.0,
            live: None,
            detect: true,
            image: true,
            weights: true,
            halo: true,
            panic_frame: None,
            kill_frame: None,
            kill_fuse: Arc::new(AtomicBool::new(false)),
        }
    }

    /// A plan that injects nothing and detects nothing — the explicit
    /// override for sessions that must stay byte-identical to the
    /// uninstrumented path even when `YODANN_FAULT_SEED` is set.
    pub fn disabled() -> FaultPlan {
        FaultPlan {
            detect: false,
            image: false,
            weights: false,
            halo: false,
            ..FaultPlan::seeded(0)
        }
    }

    /// The plan `YODANN_FAULT_SEED=<seed>` arms on every session that
    /// did not set an explicit plan: all sites at [`SMOKE_BER`],
    /// detection on.
    pub fn from_env() -> Option<FaultPlan> {
        let raw = std::env::var("YODANN_FAULT_SEED").ok()?;
        let seed = raw.trim().parse::<u64>().ok()?;
        Some(FaultPlan::seeded(seed).ber(SMOKE_BER))
    }

    /// Set the per-bit-access upset probability directly.
    pub fn ber(mut self, ber: f64) -> FaultPlan {
        assert!((0.0..=1.0).contains(&ber), "bit-error rate {ber} outside [0, 1]");
        self.ber = ber;
        self
    }

    /// Derive the upset probability from an operating corner via the
    /// fitted voltage curve (see [`bit_error_rate`]).
    pub fn at_corner(self, corner: Corner) -> FaultPlan {
        let ber = bit_error_rate(corner);
        self.ber(ber)
    }

    /// Attach a runtime [`LiveBer`] dial: while attached, the dial's
    /// current rate **overrides** the plan's static [`FaultPlan::ber`]
    /// for every subsequent injection (weight faults already injected at
    /// session build keep whatever rate was in force then).
    pub fn live_ber(mut self, dial: &LiveBer) -> FaultPlan {
        self.live = Some(dial.clone());
        self
    }

    /// Enable/disable checksum detection (off = silent corruption).
    pub fn detect(mut self, on: bool) -> FaultPlan {
        self.detect = on;
        self
    }

    /// Enable/disable image-memory injection.
    pub fn image(mut self, on: bool) -> FaultPlan {
        self.image = on;
        self
    }

    /// Enable/disable weight-memory injection.
    pub fn weights(mut self, on: bool) -> FaultPlan {
        self.weights = on;
        self
    }

    /// Enable/disable halo-exchange injection.
    pub fn halo(mut self, on: bool) -> FaultPlan {
        self.halo = on;
        self
    }

    /// Panic inside the worker while computing frame `frame` — exercises
    /// the catch_unwind / poison-recovery containment path.
    pub fn panic_on_frame(mut self, frame: u64) -> FaultPlan {
        self.panic_frame = Some(frame);
        self
    }

    /// Kill (cleanly exit) the worker thread that picks up frame
    /// `frame`, once — exercises the supervisor's respawn path.
    pub fn kill_worker_on_frame(mut self, frame: u64) -> FaultPlan {
        self.kill_frame = Some(frame);
        self
    }

    /// The plan's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The armed per-bit-access upset probability (the [`LiveBer`]
    /// dial's current rate when one is attached).
    pub fn ber_value(&self) -> f64 {
        self.current_ber()
    }

    /// The rate in force right now: the live dial when attached,
    /// otherwise the static rate.
    fn current_ber(&self) -> f64 {
        self.live.as_ref().map_or(self.ber, LiveBer::get)
    }

    pub(crate) fn detects(&self) -> bool {
        self.detect
    }

    pub(crate) fn injects_weights(&self) -> bool {
        self.weights && self.current_ber() > 0.0
    }

    pub(crate) fn injects_raster_faults(&self) -> bool {
        (self.image || self.halo) && self.current_ber() > 0.0
    }

    /// Panic if this frame is the planned panic frame.
    pub(crate) fn maybe_panic(&self, frame: u64) {
        if self.panic_frame == Some(frame) {
            panic!("deliberately injected worker panic (frame {frame})");
        }
    }

    /// True exactly once, for the planned kill frame — the shared fuse
    /// keeps a respawned worker from dying again on a retry.
    pub(crate) fn take_kill(&self, frame: u64) -> bool {
        self.kill_frame == Some(frame) && !self.kill_fuse.swap(true, Ordering::SeqCst)
    }

    /// Retry attempts inject at a guard-banded rate: the retried pack is
    /// assumed to run with refreshed margin (slower, checked access), so
    /// a detected fault usually clears on the second try.
    fn attempt_ber(&self, attempt: u32) -> f64 {
        let ber = self.current_ber();
        if attempt == 0 {
            ber
        } else {
            ber / 16.0
        }
    }

    /// Deterministic per-(site, frame, layer, attempt) generator:
    /// independent of worker scheduling, reproducible across runs.
    fn site_gen(&self, tag: u64, frame: u64, layer: u64, attempt: u32) -> Gen {
        Gen::new(mix64(mix64(mix64(self.seed ^ tag) ^ frame) ^ layer) ^ attempt as u64)
    }

    /// Flip image-memory bits across the raster's plane words. Returns
    /// the number of flips.
    pub(crate) fn corrupt_raster(
        &self,
        raster: &mut BitplaneRaster,
        frame: u64,
        layer: u64,
        attempt: u32,
    ) -> u32 {
        if !self.image {
            return 0;
        }
        let p = (64.0 * self.attempt_ber(attempt)).min(1.0);
        if p <= 0.0 {
            return 0;
        }
        let mut g = self.site_gen(TAG_IMAGE, frame, layer, attempt);
        let mut flips = 0u32;
        for wi in 0..raster.words_len() {
            if g.unit_f64() < p {
                raster.flip_word_bit(wi, g.below(64) as u32);
                flips += 1;
            }
        }
        flips
    }

    /// Flip bits in the halo-exchange rows (padded row indices in
    /// `rows`, every packed channel) — the words a shard-boundary link
    /// would retransmit. Returns the number of flips.
    pub(crate) fn corrupt_halo(
        &self,
        raster: &mut BitplaneRaster,
        rows: &[usize],
        frame: u64,
        layer: u64,
        attempt: u32,
    ) -> u32 {
        if !self.halo || rows.is_empty() {
            return 0;
        }
        let p = (64.0 * self.attempt_ber(attempt)).min(1.0);
        if p <= 0.0 {
            return 0;
        }
        let mut g = self.site_gen(TAG_HALO, frame, layer, attempt);
        let mut flips = 0u32;
        for c in 0..raster.channels() {
            for &py in rows {
                for wi in raster.row_word_range(c, py) {
                    if g.unit_f64() < p {
                        raster.flip_word_bit(wi, g.below(64) as u32);
                        flips += 1;
                    }
                }
            }
        }
        flips
    }

    /// Flip image-memory bits across a binary (XNOR-mode) raster's plane
    /// words — same per-word Bernoulli model as [`Self::corrupt_raster`],
    /// and the same deterministic site stream, so a binary layer at the
    /// same (frame, layer, attempt) draws the same pattern a multi-bit
    /// layer would (a layer is one or the other, never both). Returns
    /// the number of flips.
    pub(crate) fn corrupt_binary(
        &self,
        raster: &mut BinaryRaster,
        frame: u64,
        layer: u64,
        attempt: u32,
    ) -> u32 {
        if !self.image {
            return 0;
        }
        let p = (64.0 * self.attempt_ber(attempt)).min(1.0);
        if p <= 0.0 {
            return 0;
        }
        let mut g = self.site_gen(TAG_IMAGE, frame, layer, attempt);
        let mut flips = 0u32;
        for wi in 0..raster.words_len() {
            if g.unit_f64() < p {
                raster.flip_word_bit(wi, g.below(64) as u32);
                flips += 1;
            }
        }
        flips
    }

    /// Flip bits in a binary raster's halo-exchange rows (padded row
    /// indices in `rows`, every channel) — the binary-mode twin of
    /// [`Self::corrupt_halo`]. Returns the number of flips.
    pub(crate) fn corrupt_binary_halo(
        &self,
        raster: &mut BinaryRaster,
        rows: &[usize],
        frame: u64,
        layer: u64,
        attempt: u32,
    ) -> u32 {
        if !self.halo || rows.is_empty() {
            return 0;
        }
        let p = (64.0 * self.attempt_ber(attempt)).min(1.0);
        if p <= 0.0 {
            return 0;
        }
        let mut g = self.site_gen(TAG_HALO, frame, layer, attempt);
        let mut flips = 0u32;
        for c in 0..raster.channels() {
            for &py in rows {
                for wi in raster.row_word_range(c, py) {
                    if g.unit_f64() < p {
                        raster.flip_word_bit(wi, g.below(64) as u32);
                        flips += 1;
                    }
                }
            }
        }
        flips
    }

    /// Flip weight bits across the packed filter bank (one Bernoulli per
    /// (out, in) pair over its k² bits). Returns the number of flips.
    pub(crate) fn corrupt_weights(&self, pk: &mut PackedKernels, layer: u64, attempt: u32) -> u32 {
        if !self.weights {
            return 0;
        }
        let kk = (pk.k * pk.k) as u64;
        let p = (kk as f64 * self.attempt_ber(attempt)).min(1.0);
        if p <= 0.0 {
            return 0;
        }
        let mut g = self.site_gen(TAG_WEIGHTS, 0, layer, attempt);
        let mut flips = 0u32;
        for o in 0..pk.n_out {
            for i in 0..pk.n_in {
                if g.unit_f64() < p {
                    pk.flip_weight_bit(o, i, g.below(kk) as u32);
                    flips += 1;
                }
            }
        }
        flips
    }
}

/// Bit-error rate of a corner's memories: the architecture's fitted
/// voltage curve evaluated at the corner's supply (never panics — out of
/// range corners saturate, see `VfCurve::bit_error_rate`).
pub fn bit_error_rate(corner: Corner) -> f64 {
    CorePowerModel::new(corner.arch).vf.bit_error_rate(corner.v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::random_image;

    fn packed(seed: u64) -> PackedKernels {
        let mut g = Gen::new(seed);
        PackedKernels::pack(&crate::workload::BinaryKernels::random(&mut g, 4, 3, 3))
    }

    #[test]
    fn same_seed_reproduces_identical_flips() {
        let mut g = Gen::new(21);
        let img = random_image(&mut g, 2, 8, 8, 0.2);
        let plan = FaultPlan::seeded(5).ber(0.02);
        let mut a = BitplaneRaster::new();
        let mut b = BitplaneRaster::new();
        a.pack(&img, 3, true);
        b.pack(&img, 3, true);
        let fa = plan.corrupt_raster(&mut a, 7, 1, 0);
        let fb = plan.clone().corrupt_raster(&mut b, 7, 1, 0);
        assert_eq!(fa, fb);
        assert!(fa > 0, "2% word BER over a whole raster should flip something");
        let mut wa = [0u64; crate::engine::raster::PLANES];
        let mut wb = [0u64; crate::engine::raster::PLANES];
        for y in 0..6 {
            for x in 0..6 {
                let ua = a.window(0, y, x, &mut wa);
                let ub = b.window(0, y, x, &mut wb);
                assert_eq!((wa, ua), (wb, ub), "same seed must corrupt identically");
            }
        }
        // A different frame id draws a different pattern.
        let mut c = BitplaneRaster::new();
        c.pack(&img, 3, true);
        plan.corrupt_raster(&mut c, 8, 1, 0);
        let differs = (0..6).any(|y| {
            (0..6).any(|x| {
                let ua = a.window(0, y, x, &mut wa);
                let uc = c.window(0, y, x, &mut wb);
                (wa, ua) != (wb, uc)
            })
        });
        assert!(differs, "different frames should see different upsets");
    }

    #[test]
    fn live_ber_dial_overrides_the_static_rate() {
        let dial = LiveBer::new(0.0);
        let plan = FaultPlan::seeded(9).ber(0.02).live_ber(&dial);
        // Dial at zero: the static 2% rate is overridden — nothing flips.
        assert_eq!(plan.ber_value(), 0.0);
        assert!(!plan.injects_raster_faults());
        let mut g = Gen::new(24);
        let img = random_image(&mut g, 2, 8, 8, 0.2);
        let mut r = BitplaneRaster::new();
        r.pack(&img, 3, true);
        assert_eq!(plan.corrupt_raster(&mut r, 0, 0, 0), 0);
        // Dial raised: clones of the plan (already distributed to
        // workers) see the new rate through the shared handle, and the
        // flips stay seed-deterministic at the dialed rate.
        let worker_clone = plan.clone();
        dial.set(0.5);
        assert_eq!(worker_clone.ber_value(), 0.5);
        assert!(worker_clone.injects_raster_faults());
        let flips = worker_clone.corrupt_raster(&mut r, 0, 0, 0);
        assert!(flips > 0, "a 50% word BER must flip something");
        let mut r2 = BitplaneRaster::new();
        r2.pack(&img, 3, true);
        assert_eq!(plan.corrupt_raster(&mut r2, 0, 0, 0), flips);
    }

    #[test]
    fn disabled_plan_injects_nothing() {
        let mut g = Gen::new(22);
        let img = random_image(&mut g, 2, 8, 8, 0.2);
        let plan = FaultPlan::disabled();
        let mut r = BitplaneRaster::new();
        r.pack(&img, 3, true);
        r.seal();
        assert_eq!(plan.corrupt_raster(&mut r, 0, 0, 0), 0);
        assert_eq!(plan.corrupt_halo(&mut r, &[0, 1], 0, 0, 0), 0);
        let mut pk = packed(3);
        assert_eq!(plan.corrupt_weights(&mut pk, 0, 0), 0);
        assert_eq!(r.verify(), None);
        assert!(pk.verify());
        assert!(!plan.injects_raster_faults() && !plan.injects_weights());
    }

    #[test]
    fn saturated_ber_hits_every_word_and_checksums_notice() {
        let mut g = Gen::new(23);
        let img = random_image(&mut g, 1, 6, 6, 0.2);
        let plan = FaultPlan::seeded(9).ber(1.0);
        let mut r = BitplaneRaster::new();
        r.pack(&img, 3, true);
        r.seal();
        let flips = plan.corrupt_raster(&mut r, 0, 0, 0);
        assert_eq!(flips as usize, r.words_len(), "p=1 must flip every word once");
        assert!(r.verify().is_some());
        let mut pk = packed(4);
        let wflips = plan.corrupt_weights(&mut pk, 0, 0);
        assert_eq!(wflips as usize, pk.n_out * pk.n_in);
        assert!(!pk.verify());
    }

    #[test]
    fn binary_raster_corruption_is_seeded_and_detected() {
        let mut g = Gen::new(31);
        let img = random_image(&mut g, 3, 8, 8, 0.2);
        let plan = FaultPlan::seeded(5).ber(0.02);
        let mut a = BinaryRaster::new();
        let mut b = BinaryRaster::new();
        a.pack(&img, 3, true);
        b.pack(&img, 3, true);
        a.seal();
        b.seal();
        let fa = plan.corrupt_binary(&mut a, 7, 1, 0);
        let fb = plan.clone().corrupt_binary(&mut b, 7, 1, 0);
        assert_eq!(fa, fb, "same seed must flip the same binary words");
        assert!(fa > 0, "2% word BER over a packed binary raster should flip something");
        for y in 0..6 {
            for x in 0..6 {
                assert_eq!(a.window(0, y, x), b.window(0, y, x));
            }
        }
        assert!(a.verify().is_some(), "seal/verify must notice the flips");
        // Saturated rate hits every word, halo corruption stays row-scoped.
        let mut c = BinaryRaster::new();
        c.pack(&img, 3, true);
        let every = FaultPlan::seeded(9).ber(1.0).corrupt_binary(&mut c, 0, 0, 0);
        assert_eq!(every as usize, c.words_len(), "p=1 must flip every word once");
        let mut h = BinaryRaster::new();
        h.pack(&img, 3, true);
        h.seal();
        let hf = FaultPlan::seeded(9).ber(1.0).corrupt_binary_halo(&mut h, &[2, 3], 0, 0, 0);
        assert!(hf > 0 && (hf as usize) < h.words_len());
        assert!(h.verify().is_some());
        // Disabled plan leaves a sealed binary raster verifiable.
        let mut d = BinaryRaster::new();
        d.pack(&img, 3, true);
        d.seal();
        assert_eq!(FaultPlan::disabled().corrupt_binary(&mut d, 0, 0, 0), 0);
        assert_eq!(FaultPlan::disabled().corrupt_binary_halo(&mut d, &[1], 0, 0, 0), 0);
        assert_eq!(d.verify(), None);
    }

    #[test]
    fn flipped_weights_stay_consistent_across_forms() {
        let mut pk = packed(5);
        let before = pk.word(2, 1);
        pk.flip_weight_bit(2, 1, 4);
        let after = pk.word(2, 1);
        assert_eq!(before ^ after, 1 << 4);
        assert_eq!(pk.sign_sum(2, 1), 2 * after.count_ones() as i64 - 9);
        // The replicated/transposed forms see the same corrupted word.
        assert_eq!(pk.rep_slice(1, 2, 1)[0] & ((1 << 9) - 1), after);
        assert_eq!(pk.sign_slice(1, 2, 1)[0], pk.sign_sum(2, 1));
        assert!(!pk.verify());
    }

    #[test]
    fn report_merge_and_kill_fuse() {
        let mut a = FaultReport { image_flips: 1, detected: 1, retries: 1, ..Default::default() };
        let b = FaultReport { weight_flips: 2, halo_flips: 3, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total_flips(), 6);
        assert_eq!((a.detected, a.retries), (1, 1));

        let plan = FaultPlan::seeded(1).kill_worker_on_frame(3);
        let clone = plan.clone();
        assert!(!plan.take_kill(2));
        assert!(plan.take_kill(3), "first claim fires");
        assert!(!clone.take_kill(3), "clones share the one-shot fuse");
    }

    #[test]
    fn corner_ber_tracks_supply() {
        let low = bit_error_rate(Corner { arch: crate::power::ArchId::Bin32Multi, v: 0.6 });
        let high = bit_error_rate(Corner { arch: crate::power::ArchId::Bin32Multi, v: 1.2 });
        assert!(low > high, "near-threshold corner must be worse: {low} vs {high}");
        assert!(high >= 1e-10 && low <= 1e-2);
    }
}
