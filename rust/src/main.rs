//! `yodann` — the command-line front end.
//!
//! ```text
//! yodann info                         chip/calibration summary + headlines
//! yodann table <1|2|4|5>              regenerate a paper table (vs paper)
//! yodann table 3 --net <id>           per-layer Table III for one network
//! yodann table xnor                   accelerator-generation table (BWN vs XNOR mode)
//! yodann run --net <id> [--v 0.6]     evaluate a network at a corner
//! yodann simulate [--k 3 ...]         run one block on the cycle simulator
//! yodann golden [--seed N]            simulator vs PJRT golden model
//! yodann figure <2|6|11|12|13>        regenerate a paper figure's series
//! yodann sweep [--points 13]          voltage sweep (Fig. 11 data)
//! yodann throughput [--net id ...]    batch frames through a NetworkSession (frames/s)
//! yodann analyze [--net id]           static plan verifier (range/liveness/contracts/locks)
//! yodann faults [--net id --corner v] fault-injection sweep (detection/corruption vs corner)
//! yodann serve --scenario burst --budget-mw 1.0   power-aware serving daemon (DVFS governor)
//! yodann networks                     list known networks
//! ```

use std::sync::Arc;
use std::time::Instant;

use yodann::analysis::{AnalysisOptions, Interval, SatVerdict, Severity};
use yodann::api::{SessionBuilder, Yodann, YodannError};
use yodann::bench::{merge_json, validate_records, JsonRecord};
use yodann::cli::Args;
#[cfg(feature = "golden")]
use yodann::coordinator::check_block;
use yodann::coordinator::{metrics::sim_metrics, SessionLayerSpec, ShardGrid, ShardPolicy};
use yodann::engine::EngineKind;
use yodann::fault::{bit_error_rate, FaultPlan, LiveBer};
use yodann::hw::{BlockJob, Chip, ChipConfig, EnergyModel};
use yodann::model::{evaluate_network, networks, Corner, Network, NetworkGraph, Precision};
use yodann::power::{ArchId, CorePowerModel};
use yodann::report::{
    figures, paper,
    table::{fmt, Table},
    tables,
};
use yodann::serve::{self, GovernorConfig, GovernorMode, Scenario, ServeConfig, TickTrace};
use yodann::testkit::Gen;
use yodann::workload::{random_image, synthetic_scene, BinaryKernels, Image, ScaleBias};

const VALUE_KEYS: &[&str] = &[
    "net", "v", "k", "n-in", "n-out", "h", "w", "seed", "points", "workers", "arch", "frames",
    "engine", "scale", "shards", "bands", "corner", "scenario", "budget-mw", "slo-ms", "tick-ms",
    "v-start", "depth", "precision",
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        print_help();
        return;
    }
    let cmd = raw[0].clone();
    let args = match Args::parse(&raw[1..], VALUE_KEYS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "info" => cmd_info(),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "run" => cmd_run(&args),
        "simulate" => cmd_simulate(&args),
        "golden" => cmd_golden(&args),
        "sweep" => cmd_sweep(&args),
        "throughput" => cmd_throughput(&args),
        "analyze" => cmd_analyze(&args),
        "faults" => cmd_faults(&args),
        "serve" => cmd_serve(&args),
        "networks" => cmd_networks(),
        other => Err(format!("unknown command '{other}' (try --help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "yodann — reproduction of 'YodaNN: Ultra-Low Power Binary-Weight CNN Acceleration'\n\n\
         USAGE: yodann <command> [options]\n\n\
         COMMANDS:\n\
         \x20 info                        chip configuration + headline metrics vs paper\n\
         \x20 table <1|2|4|5>             regenerate a paper table with paper deltas\n\
         \x20 table 3 --net <id>          per-layer Table III rows for one network\n\
         \x20 table xnor                  accelerator-generation comparison: YodaNN BWN\n\
         \x20                             vs the derived XNOR (binary-activation) mode\n\
         \x20 run --net <id> [--v 0.6]    evaluate a network at an operating corner\n\
         \x20 simulate [--k 3 --n-in 32 --n-out 64 --h 16 --w 16 --v 0.6] [--valid]\n\
         \x20                             run one block on the cycle-accurate simulator\n\
         \x20 golden [--seed N]           check simulator vs the PJRT golden model\n\
         \x20 figure <2|6|11|12|13>       regenerate a paper figure's data series\n\
         \x20 sweep [--points 13] [--arch yodann|q29|bin8]  voltage sweep\n\
         \x20 throughput [--net scene-labeling] [--frames 8]\n\
         \x20            [--engine both|all|xnor-all|functional|functional-pr1|simd|\n\
         \x20             simd-scalar|cycle|xnor|xnor-simd|xnor-simd-scalar]\n\
         \x20            [--precision multi-bit|binary|p1,p2,...]\n\
         \x20            [--workers N] [--scale 0.25] [--seed 42] [--shards NxM] [--bands N]\n\
         \x20                             batch synthetic frames through a NetworkSession\n\
         \x20                             and report frames/s per engine (A/B + equality;\n\
         \x20                             'all' adds the PR-1 per-window baseline, the\n\
         \x20                             SIMD engine in vector + forced-scalar form and\n\
         \x20                             the XNOR binary-activation family; 'xnor-all'\n\
         \x20                             runs just the XNOR family; bit-identity is\n\
         \x20                             checked within each precision family).\n\
         \x20                             --precision overrides the per-layer precision\n\
         \x20                             knob: one spelling broadcasts, a comma list\n\
         \x20                             assigns layer by layer (binary layers run on\n\
         \x20                             the engine's XNOR companion).\n\
         \x20                             --bands N runs every engine again under the\n\
         \x20                             within-frame row-band schedule (N bands, 0 = one\n\
         \x20                             per worker), checks bit-identity against the\n\
         \x20                             per-frame run, and merges the scaling records\n\
         \x20                             into BENCH_engines.json.\n\
         \x20                             --shards N (row stripes) or NxM (x output-channel\n\
         \x20                             groups) also runs every engine on the multi-chip\n\
         \x20                             per-shard schedule, checks bit-identity against\n\
         \x20                             the per-frame run, prints the grid's power\n\
         \x20                             envelope + halo exchange, and merges\n\
         \x20                             shard-scaling records into BENCH_engines.json.\n\
         \x20                             Cycle-accurate runs also merge per-frame\n\
         \x20                             telemetry records (id, cycles, energy, policy;\n\
         \x20                             first 8 frames) into BENCH_engines.json.\n\
         \x20                             Non-chain networks (alexnet, resnet18,\n\
         \x20                             resnet34) run through their graph encodings\n\
         \x20                             (§IV-D 11x11 split, residual shortcuts).\n\
         \x20 analyze [--net id] [--shards NxM | --bands N] [--workers 4]\n\
         \x20         [--h H --w W] [--scale 1.0] [--seed 42]\n\
         \x20                             static plan verifier: prove range/saturation,\n\
         \x20                             slot liveness, block/shard geometry contracts and\n\
         \x20                             the lock-order registry over each network's\n\
         \x20                             compiled plan without running a frame. Without\n\
         \x20                             --net, analyzes every accepted network (graphs\n\
         \x20                             included) at its nominal frame size. Prints a\n\
         \x20                             findings table plus the SCM-occupancy report\n\
         \x20                             section (peak live slot-store vs the chip's\n\
         \x20                             image-memory sizing), merges analysis records\n\
         \x20                             into BENCH_engines.json, and exits non-zero\n\
         \x20                             when any error-severity finding survives.\n\
         \x20 faults [--net bc-cifar10] [--corner 0.6] [--frames 4] [--scale 0.25]\n\
         \x20        [--workers 2] [--seed 42]\n\
         \x20                             seeded fault-injection sweep: per corner, derive\n\
         \x20                             the memory bit-error rate from the voltage curve,\n\
         \x20                             inject into image memory / packed weights / halo\n\
         \x20                             rows, and report silent-corruption vs\n\
         \x20                             detect-and-contain outcomes per frame; records\n\
         \x20                             (model-ber, corrupted/contained/detected\n\
         \x20                             fractions) merge into BENCH_engines.json.\n\
         \x20                             Without --corner, sweeps 0.6/0.8/1.0/1.2 V.\n\
         \x20 serve --scenario burst|sustained|thermal (--budget-mw P | --slo-ms L)\n\
         \x20       [--frames 64] [--seed 7] [--tick-ms 0.5] [--v-start V]\n\
         \x20       [--net id] [--h 24] [--w 24] [--workers 2] [--depth 8]\n\
         \x20                             power-aware serving daemon: a DVFS governor\n\
         \x20                             steps the simulated corner each control tick\n\
         \x20                             against a core-power budget (--budget-mw) or a\n\
         \x20                             drain-latency SLO (--slo-ms), with priority\n\
         \x20                             admission over the bounded queue and, on the\n\
         \x20                             thermal scenario, the live fault dial coupled\n\
         \x20                             to the corner. Prints a per-tick readout,\n\
         \x20                             merges serve records into BENCH_engines.json,\n\
         \x20                             and exits non-zero when the steady-state power\n\
         \x20                             budget was violated. Same seed => identical\n\
         \x20                             corner trace and output digest (no wall clock\n\
         \x20                             in the control law).\n\
         \x20 networks                    list the networks of Tables III–V, their\n\
         \x20                             precision modes (runnable models take the\n\
         \x20                             per-layer multi-bit/binary knob) and whether\n\
         \x20                             they are runnable (chain/graph) vs\n\
         \x20                             descriptor-only"
    );
}

fn corner_of(args: &Args) -> Result<Corner, String> {
    let v = args.get_f64("v", 0.6)?;
    Ok(Corner { arch: ArchId::Bin32Multi, v })
}

/// Network lookup whose failure echoes every accepted id (the network
/// analog of the engine parser's `EngineKind::ACCEPTED` echo).
fn lookup_network(id: &str) -> Result<Network, String> {
    networks::network(id)
        .ok_or_else(|| YodannError::UnknownNetwork { given: id.to_string() }.to_string())
}

fn cmd_info() -> Result<(), String> {
    let chip = CorePowerModel::new(ArchId::Bin32Multi);
    println!("YodaNN (binary-weight CNN accelerator, UMC 65 nm) — simulated reproduction\n");
    println!("architecture : 32x32 channels, kernels 1x1..7x7 (dual 3x3/5x5 modes)");
    println!("image memory : 7x8 latch-based SCM banks x 128 rows x 12 bit (h_max = 32)");
    println!("formats      : Q2.9 activations, binary weights, Q7.9 accumulate, Q10.18 scale\n");
    let rows = [
        (
            "peak throughput @1.2V",
            chip.theta_peak(1.2, 7) / 1e9,
            paper::headline::PEAK_GOPS_1V2,
            "GOp/s",
        ),
        (
            "peak throughput @0.6V",
            chip.theta_peak(0.6, 7) / 1e9,
            paper::headline::PEAK_GOPS_0V6,
            "GOp/s",
        ),
        ("core power @0.6V", chip.p_core_slot7(0.6) * 1e6, paper::headline::CORE_UW_0V6, "uW"),
        (
            "energy efficiency @0.6V",
            chip.theta_peak(0.6, 7) / chip.p_core_slot7(0.6) / 1e12,
            paper::headline::PEAK_TOPS_W_0V6,
            "TOp/s/W",
        ),
        (
            "area efficiency @1.2V",
            chip.theta_peak(1.2, 7) / 1e9 / yodann::power::metric_area_mge(ArchId::Bin32Multi),
            paper::headline::AREA_EFF_1V2,
            "GOp/s/MGE",
        ),
        ("f_max @1.2V", chip.freq(1.2) / 1e6, paper::headline::FMAX_1V2_MHZ, "MHz"),
    ];
    for (name, measured, paperv, unit) in rows {
        println!(
            "{name:<26} {:>9} {unit:<10} (paper {paperv}, {})",
            fmt(measured, 1),
            yodann::report::table::delta_pct(measured, paperv)
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<(), String> {
    let which = args.positional.first().ok_or("table number required (1..5, or xnor)")?;
    let t = match which.as_str() {
        "1" => tables::table1(),
        "2" => tables::table2(),
        "3" => {
            let net = args.get("net", "bc-cifar10").to_string();
            lookup_network(&net)?;
            tables::table3(&net, corner_of(args)?)
        }
        "4" => tables::table45(Corner::energy_optimal()),
        "5" => tables::table45(Corner::throughput_optimal()),
        "xnor" => tables::xnor_generation_table(),
        other => return Err(format!("unknown table {other} (1..5 or xnor)")),
    };
    println!("{}", t.render());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<(), String> {
    let which = args.positional.first().ok_or("figure number required (2,6,11,12,13)")?;
    match which.as_str() {
        "2" => {
            let f = figures::fig2();
            println!("Fig. 2 — conv vs other layers, scene-labeling CNN [13]:");
            println!("  conv ops            : {:.2} GOp/frame", f.conv_ops as f64 / 1e9);
            println!("  non-conv ops        : {:.2} MOp/frame", f.other_ops as f64 / 1e6);
            println!("  conv share of ops   : {:.4}", f.conv_op_share);
            println!(
                "  conv share of time  : CPU {:.0}%  GPU {:.0}% (measured, [13])",
                f.cpu_conv_time_share * 100.0,
                f.gpu_conv_time_share * 100.0
            );
            println!(
                "  implied non-conv per-op slowdown: CPU {:.0}x  GPU {:.0}x",
                f.cpu_other_slowdown, f.gpu_other_slowdown
            );
        }
        "6" => {
            println!("Fig. 6 — area breakdown (kGE):");
            println!(
                "{:<24} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
                "arch", "memory", "filter", "SoP", "imgbank", "sc-bias", "total"
            );
            for (arch, a) in figures::fig6() {
                println!(
                    "{:<24} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1}",
                    arch.name(),
                    a.memory,
                    a.filter_bank,
                    a.sop,
                    a.image_bank,
                    a.scale_bias,
                    a.total_kge()
                );
            }
        }
        "11" => {
            println!("Fig. 11 — throughput & core efficiency vs supply:");
            for arch in [ArchId::Q29Fixed8, ArchId::Bin32Multi] {
                println!("  {}:", arch.name());
                println!("    {:>5} {:>9} {:>12} {:>12}", "V", "f (MHz)", "GOp/s", "TOp/s/W");
                for p in figures::fig11_sweep(arch, 7) {
                    println!(
                        "    {:>5.2} {:>9.1} {:>12.1} {:>12.2}",
                        p.v, p.f_mhz, p.theta_gops, p.en_eff_tops_w
                    );
                }
            }
        }
        "12" => {
            println!("Fig. 12 — core power breakdown @1.2 V, 400 MHz (mW):");
            println!(
                "{:<24} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
                "arch", "memory", "SoP", "filter", "sc-bias", "other", "total"
            );
            for (arch, b) in figures::fig12_at_400mhz() {
                println!(
                    "{:<24} {:>8.1} {:>8.1} {:>8.1} {:>8.2} {:>8.1} {:>8.1}",
                    arch.name(),
                    b.memory * 1e3,
                    b.sop * 1e3,
                    b.filter_bank * 1e3,
                    b.scale_bias * 1e3,
                    b.other * 1e3,
                    b.total() * 1e3
                );
            }
        }
        "13" => {
            println!("Fig. 13 — area efficiency vs energy efficiency (pareto):");
            println!("{:<18} {:>12} {:>16}", "point", "TOp/s/W", "GOp/s/MGE");
            for p in figures::fig13(7) {
                println!(
                    "{:<18} {:>12.2} {:>16.1}{}",
                    p.name,
                    p.en_eff,
                    p.area_eff,
                    if p.ours { "  <- YodaNN" } else { "" }
                );
            }
        }
        other => return Err(format!("unknown figure {other}")),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let id = args.require("net")?;
    let net = lookup_network(id)?;
    let corner = corner_of(args)?;
    let e = evaluate_network(&net, corner);
    println!("{} @{:.2} V ({}):", net.name, corner.v, corner.arch.name());
    println!("  conv ops        : {:.2} GOp/frame", e.total_ops as f64 / 1e9);
    println!("  avg throughput  : {:.1} GOp/s", e.avg_theta / 1e9);
    println!("  avg energy eff  : {:.1} TOp/s/W (core)", e.avg_en_eff / 1e12);
    println!("  frame rate      : {:.2} FPS", e.fps);
    println!("  energy/frame    : {:.1} uJ (core)", e.frame_energy * 1e6);
    println!("  avg device power: {:.1} mW (core + pads)", e.avg_device_power * 1e3);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let k = args.get_usize("k", 3)?;
    let n_in = args.get_usize("n-in", 32)?;
    let n_out = args.get_usize("n-out", 64)?;
    let h = args.get_usize("h", 16)?;
    let w = args.get_usize("w", 16)?;
    let v = args.get_f64("v", 0.6)?;
    let seed = args.get_u64("seed", 42)?;
    let mut g = Gen::new(seed);
    let job = BlockJob {
        k,
        zero_pad: !args.has_flag("valid"),
        image: random_image(&mut g, n_in, h, w, 0.02),
        kernels: BinaryKernels::random(&mut g, n_out, n_in, k),
        scale_bias: ScaleBias::random(&mut g, n_out),
    };
    let cfg = ChipConfig::yodann();
    job.validate(&cfg).map_err(|e| format!("invalid job: {e}"))?;
    let mut chip = Chip::new(cfg);
    let res = chip.run_block(&job);
    let s = &res.stats;
    println!("block k={k} {n_in}->{n_out} {h}x{w} @{v} V:");
    println!(
        "  cycles: {} (filter {} | preload {} | compute {} | idle {} | flush {})",
        s.cycles.total(),
        s.cycles.filter_load,
        s.cycles.preload,
        s.cycles.compute,
        s.cycles.idle,
        s.cycles.flush
    );
    println!(
        "  SCM   : {} reads, {} writes, max {} banks/cycle",
        s.scm_reads, s.scm_writes, s.scm_max_banks_per_cycle
    );
    println!(
        "  SoP   : {} active ops, {} silenced; {} summer saturations",
        s.sop_active_ops, s.sop_silenced_ops, s.summer_saturations
    );
    println!("  I/O   : {} words in, {} words out", s.input_words, s.output_words);
    let dual = k < 6 && n_out > 32;
    let m = sim_metrics(s, ArchId::Bin32Multi, v, dual);
    let em = EnergyModel::new(ArchId::Bin32Multi, v);
    println!(
        "  chip time {:.3} ms  |  {:.2} GOp/s  |  {:.1} TOp/s/W  |  {:.2} uJ",
        m.time * 1e3,
        m.theta / 1e9,
        m.en_eff / 1e12,
        em.energy(s) * 1e6
    );
    Ok(())
}

#[cfg(not(feature = "golden"))]
fn cmd_golden(_args: &Args) -> Result<(), String> {
    Err("this binary was built without the `golden` feature (PJRT/XLA golden-model \
         runtime); rebuild with `cargo build --features golden` and the xla/anyhow \
         dependencies enabled (see rust/Cargo.toml)"
        .into())
}

#[cfg(feature = "golden")]
fn cmd_golden(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 7)?;
    let mut rt = yodann::runtime::Runtime::open_default().map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());
    let cases: Vec<(usize, usize, usize, usize, usize, bool)> = rt
        .manifest()
        .iter()
        .map(|m| (m.k, m.n_in, m.n_out, m.h, m.w, m.zero_pad))
        .collect();
    for (k, n_in, n_out, h, w, zp) in cases {
        let mut g = Gen::new(seed ^ ((k as u64) << 8));
        let image = random_image(&mut g, n_in, h, w, 0.03);
        let kernels = BinaryKernels::random(&mut g, n_out, n_in, k);
        let sb = ScaleBias::random(&mut g, n_out);
        let report = check_block(&mut rt, &ChipConfig::yodann(), &image, &kernels, &sb, zp)
            .map_err(|e| e.to_string())?;
        println!(
            "  {:<34} {} samples: {}",
            report.artifact,
            report.samples,
            if report.ok() { "OK (bit-exact)" } else { "MISMATCH" }
        );
        if !report.ok() {
            return Err(format!("golden mismatch: {:?}", report.first_mismatch));
        }
    }
    println!("all artifacts bit-exact: simulator == JAX/Pallas golden model");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let points = args.get_usize("points", 13)?;
    let arch = match args.get("arch", "yodann") {
        "yodann" => ArchId::Bin32Multi,
        "q29" => ArchId::Q29Fixed8,
        "bin8" => ArchId::Bin8,
        other => return Err(format!("unknown arch {other}")),
    };
    println!("{:>5} {:>9} {:>12} {:>12}", "V", "f (MHz)", "GOp/s", "TOp/s/W");
    for p in figures::fig11_sweep(arch, points) {
        println!("{:>5.2} {:>9.1} {:>12.1} {:>12.2}", p.v, p.f_mhz, p.theta_gops, p.en_eff_tops_w);
    }
    Ok(())
}

/// The network model a throughput run executes: a flat chain (the
/// historical path) or a compiled graph encoding for the non-chain
/// networks (alexnet, resnet18, resnet34).
enum NetModel {
    Chain(Vec<SessionLayerSpec>),
    Graph(NetworkGraph),
}

/// Batch synthetic frames through the serving facade (`yodann::api::Yodann`)
/// on one or both engines: the end-to-end throughput A/B. With more than one
/// engine selected (`--engine both`; `--engine all` which adds the
/// PR-1 per-window functional baseline, the SIMD engine in vector +
/// forced-scalar form and the XNOR binary-activation family; or
/// `--engine xnor-all` for just the XNOR family) every engine's outputs
/// are checked for bit-identity against the first *of its precision
/// family* — XNOR engines follow the sign reference, not the Q2.9
/// datapath. `--precision` overrides the per-layer precision knob
/// (broadcast or comma list). With `--shards NxM`
/// every engine additionally runs the multi-chip per-shard schedule on
/// that grid, and with `--bands N` the within-frame row-band schedule;
/// in both cases bit-identity against the per-frame run is enforced and
/// the measured scaling records are merged into
/// `BENCH_engines.json`. The cycle-accurate engine's run also lands its
/// per-frame telemetry (frame id, cycles, energy, policy) there.
fn cmd_throughput(args: &Args) -> Result<(), String> {
    let id = args.get("net", "scene-labeling");
    let net = lookup_network(id)?;
    let n_frames = args.get_usize("frames", 8)?.max(1);
    let workers = args.get_usize(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )?;
    let scale = args.get_f64("scale", 0.25)?;
    if scale.is_nan() || scale <= 0.0 {
        return Err("--scale must be positive".into());
    }
    let seed = args.get_u64("seed", 42)?;
    let shards: Option<ShardGrid> = match args.options.get("shards") {
        None => None,
        Some(s) => Some(
            ShardGrid::parse(s)
                .ok_or_else(|| format!("--shards '{s}' is not N or NxM (stripes x groups)"))?,
        ),
    };
    let bands: Option<usize> = match args.options.get("bands") {
        None => None,
        Some(s) => Some(
            s.trim()
                .parse::<usize>()
                .map_err(|_| format!("--bands '{s}' is not a band count (0 = one per worker)"))?,
        ),
    };
    let kinds: Vec<EngineKind> = match args.get("engine", "both").to_ascii_lowercase().as_str() {
        "both" => vec![EngineKind::Functional, EngineKind::CycleAccurate],
        // The full A/B field: the raster functional engine, the PR-1
        // per-window packing baseline, the SIMD engine (runtime-detected
        // vector path and forced-scalar control), the cycle simulator
        // for reference, plus the binary-activation XNOR family.
        // Bit-identity is only checked within a precision family —
        // XNOR engines binarize their inputs, so their outputs follow
        // the sign reference, not the Q2.9 datapath.
        "all" => {
            let mut v = vec![
                EngineKind::Functional,
                EngineKind::FunctionalPerWindow,
                EngineKind::FunctionalSimd,
                EngineKind::FunctionalSimdScalar,
                EngineKind::CycleAccurate,
            ];
            v.extend(EngineKind::XNOR);
            v
        }
        "xnor-all" => EngineKind::XNOR.to_vec(),
        other => vec![EngineKind::parse(other).ok_or_else(|| {
            format!(
                "{} (or the multi-engine spellings: both, all, xnor-all)",
                YodannError::UnknownEngine { given: other.to_string() }
            )
        })?],
    };
    // Per-layer precision override: one spelling broadcasts to every
    // conv layer, a comma list assigns layer by layer (arity checked
    // against the compiled plan at build).
    let precision: Option<Vec<Precision>> = match args.options.get("precision") {
        None => None,
        Some(s) => Some(
            s.split(',')
                .map(|t| {
                    Precision::parse(t).ok_or_else(|| {
                        format!("--precision '{t}' (accepted: {})", Precision::ACCEPTED.join(", "))
                    })
                })
                .collect::<Result<Vec<_>, _>>()?,
        ),
    };

    // Chain networks run the historical spec path (byte-identical);
    // non-chain networks fall through to their graph encoding, which is
    // what turns alexnet/resnet18/resnet34 from descriptor rows into
    // runnable workloads.
    let model = match SessionLayerSpec::synthetic_network(&net, seed) {
        Ok(specs) => NetModel::Chain(specs),
        Err(e) => match networks::graph_network(id, seed) {
            Some(g) => NetModel::Graph(g),
            None => return Err(e.into()),
        },
    };
    // First-conv metadata drives the frame generator, the shard-grid
    // clamp and the printed envelope, whichever path lowered the model.
    let (n_convs, c0, first_k, first_pad, first_n_out, model_note) = match &model {
        NetModel::Chain(specs) => {
            let f = &specs[0];
            (specs.len(), f.kernels.n_in, f.k, f.zero_pad, f.kernels.n_out, "chain")
        }
        NetModel::Graph(g) => {
            let cg = g.compile().map_err(|e| e.to_string())?;
            let f = &cg.convs[0];
            (cg.convs.len(), cg.n_in, f.k, f.zero_pad, f.kernels.n_out, "graph encoding")
        }
    };
    let h = ((net.img.0 as f64 * scale).round() as usize).max(16);
    let w = ((net.img.1 as f64 * scale).round() as usize).max(16);
    let mut g = Gen::new(seed ^ 0xF00D);
    let frames: Vec<Image> = (0..n_frames).map(|_| synthetic_scene(&mut g, c0, h, w)).collect();
    // One --precision spelling broadcasts across the chain; a comma
    // list must match the conv count (checked again at build).
    let precision = precision.map(|ps| if ps.len() == 1 { vec![ps[0]; n_convs] } else { ps });

    println!(
        "{} ({} conv layers, {model_note}, seeded binary weights), {} frames of {}x{}x{}, {} \
         workers:",
        net.name, n_convs, n_frames, c0, h, w, workers
    );
    let any_binary = kinds.iter().any(|k| k.is_binary())
        || precision.as_ref().is_some_and(|ps| ps.contains(&Precision::Binary));
    if any_binary {
        use yodann::power::xnor::{activation_words, ACTIVATION_PLANES_BWN, ACTIVATION_PLANES_XNOR};
        let bwn = activation_words(c0, h, w, first_k, first_pad, ACTIVATION_PLANES_BWN);
        let xn = activation_words(c0, h, w, first_k, first_pad, ACTIVATION_PLANES_XNOR);
        println!(
            "  binary activations in play: layer-1 residency {xn} words (XNOR) vs {bwn} (BWN), \
             {}x less traffic",
            bwn / xn
        );
    }
    let cfg = ChipConfig::yodann();
    // Clamp the requested grid to layer 1's output space: axes beyond
    // it can never materialize as chips, and the printed envelope plus
    // the merged shard-scaling records must describe the grid that
    // actually runs.
    let out_h0 = if first_pad { h } else { h + 1 - first_k };
    let shards = shards.map(|g| {
        let eff = ShardGrid::new(g.stripes.min(out_h0), g.out_groups.min(first_n_out));
        if eff != g {
            println!(
                "  note: --shards {g} clamped to {eff} (layer 1 outputs {out_h0} rows x \
                 {first_n_out} channels)"
            );
        }
        eff
    });
    if let Some(grid) = shards {
        // Analytic grid envelope: every chip burns core + pads
        // concurrently, and stripe neighbours re-exchange the k−1 halo
        // rows of the first layer's input every frame.
        let envelope =
            yodann::power::MultiChipPower::at(ArchId::Bin32Multi, 0.6, grid.chips(), first_k);
        let halo = yodann::power::halo_exchange_words(grid.stripes, first_k, w, c0);
        println!(
            "  shard grid {grid}: {} chips, {:.1} mW device envelope @0.6 V, \
             {halo} halo words/frame (layer 1)",
            envelope.chips,
            envelope.total_w() * 1e3
        );
    }
    let mut runs: Vec<(EngineKind, Vec<Image>, f64)> = Vec::new();
    let mut merged_records: Vec<JsonRecord> = Vec::new();
    // One builder per (engine, policy) leg, whichever path lowered the
    // model: chains go through the historical `layers`, graphs through
    // `graph` — the facade behind both is identical.
    let make_session = |kind: EngineKind, policy: ShardPolicy| -> Result<Yodann, String> {
        let b = SessionBuilder::new()
            .chip(cfg)
            .engine(kind)
            .workers(workers)
            .shard_policy(policy)
            .max_in_flight(n_frames);
        let b = match &precision {
            Some(ps) => b.precision(ps.clone()),
            None => b,
        };
        let b = match &model {
            NetModel::Chain(specs) => b.layers(specs.clone()),
            NetModel::Graph(g) => b.graph(g),
        };
        Ok(b.build()?)
    };
    for kind in kinds {
        let mut sess = make_session(kind, ShardPolicy::PerFrame)?;
        let t0 = Instant::now();
        let results = sess.run_batch(frames.clone())?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {:<16} {:>8.3} s  ->  {:>8.2} frames/s",
            kind.name(),
            dt,
            n_frames as f64 / dt
        );
        // The cycle-accurate run carries a full per-frame ledger: land
        // it as frame-telemetry records (id, cycles, energy, policy).
        // Capped at the first TELEMETRY_FRAMES so re-runs with different
        // --frames values replace a stable record set instead of leaving
        // stale high-index records behind.
        const TELEMETRY_FRAMES: usize = 8;
        if kind == EngineKind::CycleAccurate {
            let mut sum_cycles = 0u64;
            let mut sum_uj = 0.0f64;
            let mut priced = 0usize;
            for r in results.iter().take(TELEMETRY_FRAMES) {
                let t = &r.telemetry;
                let base =
                    format!("frame-telemetry/cli/{id}/{}/frame{}", t.policy, t.frame_id);
                merged_records.push(JsonRecord::ratio(&format!("{base}/cycles"), t.cycles as f64));
                if let Some(e) = t.energy_j() {
                    merged_records.push(JsonRecord::ratio(&format!("{base}/energy-uj"), e * 1e6));
                }
                sum_cycles += t.cycles;
                sum_uj += t.energy_j().unwrap_or(0.0) * 1e6;
                priced += 1;
            }
            if priced > 0 {
                println!(
                    "  {:<16} telemetry: avg {} cycles, {:.2} uJ/frame @{:.1} V \
                     (first {priced}/{n_frames} frames -> BENCH_engines.json)",
                    "",
                    sum_cycles / priced as u64,
                    sum_uj / priced as f64,
                    sess.corner().v
                );
            }
        }
        let out: Vec<Image> = results.into_iter().map(|r| r.output).collect();
        if let Some(grid) = shards {
            let mut sh = make_session(kind, ShardPolicy::PerShard(grid))?;
            let t0 = Instant::now();
            let results_sh = sh.run_batch(frames.clone())?;
            let dt_sh = t0.elapsed().as_secs_f64();
            let out_sh: Vec<Image> = results_sh.into_iter().map(|r| r.output).collect();
            if out_sh != out {
                return Err(format!(
                    "sharded outputs diverge from per-frame on {} — this is a bug",
                    kind.name()
                ));
            }
            println!(
                "  {:<16} {:>8.3} s  ->  {:>8.2} frames/s  (per-shard:{grid}, \
                 bit-identical, {:.2}x vs per-frame)",
                kind.name(),
                dt_sh,
                n_frames as f64 / dt_sh,
                dt / dt_sh
            );
            merged_records.push(JsonRecord {
                name: format!("shard-scaling/cli/{}/per-frame/batch{n_frames}", kind.name()),
                ns_per_iter: dt * 1e9,
                frames_per_s: Some(n_frames as f64 / dt),
            });
            merged_records.push(JsonRecord {
                name: format!("shard-scaling/cli/{}/{grid}/batch{n_frames}", kind.name()),
                ns_per_iter: dt_sh * 1e9,
                frames_per_s: Some(n_frames as f64 / dt_sh),
            });
            merged_records.push(JsonRecord::ratio(
                &format!("shard-scaling/cli/{}/speedup-{grid}", kind.name()),
                dt / dt_sh,
            ));
        }
        if let Some(n) = bands {
            // The within-frame row-band schedule: the same batch with
            // every frame's output rows fanned across the pool.
            let policy = ShardPolicy::RowBands(n);
            let mut rb = make_session(kind, policy)?;
            let t0 = Instant::now();
            let results_rb = rb.run_batch(frames.clone())?;
            let dt_rb = t0.elapsed().as_secs_f64();
            let out_rb: Vec<Image> = results_rb.into_iter().map(|r| r.output).collect();
            if out_rb != out {
                return Err(format!(
                    "row-band outputs diverge from per-frame on {} — this is a bug",
                    kind.name()
                ));
            }
            println!(
                "  {:<16} {:>8.3} s  ->  {:>8.2} frames/s  ({policy}, bit-identical, \
                 {:.2}x vs per-frame)",
                kind.name(),
                dt_rb,
                n_frames as f64 / dt_rb,
                dt / dt_rb
            );
            merged_records.push(JsonRecord {
                name: format!("row-band/cli/{}/{policy}/batch{n_frames}", kind.name()),
                ns_per_iter: dt_rb * 1e9,
                frames_per_s: Some(n_frames as f64 / dt_rb),
            });
            merged_records.push(JsonRecord::ratio(
                &format!("row-band/cli/{}/speedup-{policy}", kind.name()),
                dt / dt_rb,
            ));
        }
        runs.push((kind, out, dt));
    }
    // Equality is a per-family contract: multi-bit engines follow the
    // chip's Q2.9 arithmetic, XNOR engines the binarized sign
    // reference — identical within a family, intentionally different
    // across.
    for binary in [false, true] {
        let fam: Vec<&(EngineKind, Vec<Image>, f64)> =
            runs.iter().filter(|(k, _, _)| k.is_binary() == binary).collect();
        if fam.len() < 2 {
            continue;
        }
        let (ka, oa, ta) = fam[0];
        for (kb, ob, tb) in &fam[1..] {
            if oa != ob {
                return Err(format!(
                    "engine outputs diverge: {} vs {} — this is a bug",
                    ka.name(),
                    kb.name()
                ));
            }
            println!("  {} speedup over {}: {:.1}x", ka.name(), kb.name(), tb / ta);
        }
        println!(
            "  outputs bit-identical across {} engines",
            if binary { "xnor" } else { "multi-bit" }
        );
    }
    if !merged_records.is_empty() {
        // The schema gate first: a bogus record set (zero cycles, NaN
        // ratios) must fail loudly, not land in the evidence file.
        validate_records(&merged_records)
            .map_err(|e| format!("telemetry/shard records failed validation: {e}"))?;
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engines.json");
        let total = merge_json(path, "engines", &merged_records)
            .map_err(|e| format!("merging records into {path}: {e}"))?;
        println!("  merged {} records into {path} ({total} total)", merged_records.len());
    }
    Ok(())
}

/// Static plan verifier: run all four analyzer passes (range/saturation
/// intervals, slot liveness, block/shard geometry contracts, lock-order
/// registry) over each network's compiled plan — graphs included —
/// without executing a frame. Prints per-network summaries, a findings
/// table, and the SCM-occupancy report section; merges analysis records
/// into `BENCH_engines.json`; exits non-zero when any error-severity
/// finding survives.
fn cmd_analyze(args: &Args) -> Result<(), String> {
    let workers = args.get_usize("workers", 4)?.max(1);
    let seed = args.get_u64("seed", 42)?;
    let scale = args.get_f64("scale", 1.0)?;
    if scale.is_nan() || scale <= 0.0 {
        return Err("--scale must be positive".into());
    }
    let shards: Option<ShardGrid> = match args.options.get("shards") {
        None => None,
        Some(s) => Some(
            ShardGrid::parse(s)
                .ok_or_else(|| format!("--shards '{s}' is not N or NxM (stripes x groups)"))?,
        ),
    };
    let bands: Option<usize> = match args.options.get("bands") {
        None => None,
        Some(s) => Some(
            s.trim()
                .parse::<usize>()
                .map_err(|_| format!("--bands '{s}' is not a band count (0 = one per worker)"))?,
        ),
    };
    if shards.is_some() && bands.is_some() {
        return Err("--shards and --bands are mutually exclusive".into());
    }
    let policy = match (shards, bands) {
        (Some(grid), _) => ShardPolicy::PerShard(grid),
        (_, Some(n)) => ShardPolicy::RowBands(n),
        // The serving default: Auto stripes small batches across the
        // pool, so the contracts pass proves that grid's plans too.
        _ => ShardPolicy::Auto,
    };
    let ids: Vec<String> = match args.options.get("net") {
        Some(id) => vec![id.clone()],
        None => networks::ACCEPTED.iter().map(|s| s.to_string()).collect(),
    };
    let cfg = ChipConfig::yodann();
    println!(
        "static plan verifier: {} network(s), {policy}, {workers} workers, chip {}x{}",
        ids.len(),
        cfg.n_ch,
        cfg.n_ch
    );
    let mut findings_table = Table::new(
        "Analyzer findings",
        &["net", "severity", "pass/code", "step", "node", "detail"],
    );
    let mut scm_rows: Vec<tables::ScmOccupancyRow> = Vec::new();
    let mut records: Vec<JsonRecord> = Vec::new();
    let mut n_errors = 0usize;
    for id in &ids {
        let net = lookup_network(id)?;
        // Same model lowering as `throughput`: chains through the
        // historical spec path, non-chain networks (alexnet, resnets)
        // through their graph encodings.
        let model = match SessionLayerSpec::synthetic_network(&net, seed) {
            Ok(specs) => NetModel::Chain(specs),
            Err(e) => match networks::graph_network(id, seed) {
                Some(g) => NetModel::Graph(g),
                None => return Err(e.into()),
            },
        };
        let h = args.get_usize("h", ((net.img.0 as f64 * scale).round() as usize).max(16))?;
        let w = args.get_usize("w", ((net.img.1 as f64 * scale).round() as usize).max(16))?;
        let b = SessionBuilder::new().chip(cfg).workers(workers).shard_policy(policy);
        let b = match &model {
            NetModel::Chain(specs) => b.layers(specs.clone()),
            NetModel::Graph(g) => b.graph(g),
        };
        let report = b
            .analyze(&AnalysisOptions { input: Interval::full_q29(), shape: Some((h, w)) })
            .map_err(|e| format!("{id}: {e}"))?;
        let verdicts = |v: SatVerdict| {
            report.ranges.iter().filter(|r| r.verdict == Some(v)).count()
        };
        println!(
            "  {id} ({h}x{w}): {} steps, {} convs — saturation unreachable {} / possible {} \
             / certain {}; contracts: {} blocks, {} shards; findings: {} error, {} warning",
            report.ranges.len(),
            report.contracts.convs_checked,
            verdicts(SatVerdict::Unreachable),
            verdicts(SatVerdict::Possible),
            verdicts(SatVerdict::Certain),
            report.contracts.blocks_checked,
            report.contracts.shards_checked,
            report.count_at(Severity::Error),
            report.count_at(Severity::Warning),
        );
        for f in &report.findings {
            let mut detail = f.detail.clone();
            if detail.len() > 72 {
                detail.truncate(69);
                detail.push_str("...");
            }
            findings_table.row(vec![
                id.to_string(),
                f.severity.to_string(),
                format!("{}/{}", f.pass, f.code),
                f.step.map(|s| s.to_string()).unwrap_or_default(),
                f.node.clone(),
                detail,
            ]);
        }
        n_errors += report.count_at(Severity::Error);
        if let Some(words) = report.liveness.peak_words {
            scm_rows.push(tables::ScmOccupancyRow {
                net: id.to_string(),
                img: (h, w),
                peak_slots: report.liveness.peak_slots,
                peak_words: words,
            });
            push_nonzero(
                &mut records,
                format!("analysis/{id}/peak-slot-kib"),
                words as f64 * 12.0 / 8.0 / 1024.0,
            );
            push_nonzero(
                &mut records,
                format!("analysis/{id}/scm-occupancy"),
                words as f64 / paper::headline::SCM_WORDS as f64,
            );
        }
        push_nonzero(
            &mut records,
            format!("analysis/{id}/findings-warning"),
            report.count_at(Severity::Warning) as f64,
        );
    }
    if findings_table.is_empty() {
        println!("\nno findings — all proofs passed.");
    } else {
        println!("\n{}", findings_table.render());
    }
    if !scm_rows.is_empty() {
        println!("{}", tables::scm_occupancy_table(&cfg, &scm_rows).render());
    }
    if !records.is_empty() {
        validate_records(&records)
            .map_err(|e| format!("analysis records failed validation: {e}"))?;
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engines.json");
        let total = merge_json(path, "engines", &records)
            .map_err(|e| format!("merging records into {path}: {e}"))?;
        println!("merged {} records into {path} ({total} total)", records.len());
    }
    if n_errors > 0 {
        return Err(format!("{n_errors} error-severity finding(s) — see the table above"));
    }
    Ok(())
}

/// Push a sweep fraction/ratio record, skipping non-positive values:
/// the BENCH schema requires ratio records to carry a positive finite
/// value, and a zero fraction (nothing corrupted at a healthy corner)
/// is a legitimate sweep outcome, not evidence worth merging.
fn push_nonzero(records: &mut Vec<JsonRecord>, name: String, value: f64) {
    if value > 0.0 && value.is_finite() {
        records.push(JsonRecord { name, ns_per_iter: 0.0, frames_per_s: Some(value) });
    } else {
        println!("    note: {name} is zero here — record skipped (schema wants positive ratios)");
    }
}

/// Seeded fault-injection sweep: per operating corner, derive the
/// memory bit-error rate from the architecture's voltage curve, then
/// measure (a) silent corruption with detection off, (b) the
/// detect-and-contain path with checksums on — every frame either
/// matches the clean baseline bit-for-bit or comes back as a typed
/// [`YodannError::FaultDetected`] — and (c) whether pack-time
/// weight-memory corruption refuses the session at build. Fractions
/// merge into `BENCH_engines.json` after schema validation.
fn cmd_faults(args: &Args) -> Result<(), String> {
    let id = args.get("net", "bc-cifar10");
    let net = lookup_network(id)?;
    let n_frames = args.get_usize("frames", 4)?.max(1);
    let workers = args.get_usize("workers", 2)?.max(1);
    let scale = args.get_f64("scale", 0.25)?;
    if scale.is_nan() || scale <= 0.0 {
        return Err("--scale must be positive".into());
    }
    let seed = args.get_u64("seed", 42)?;
    let corners: Vec<f64> = match args.options.get("corner") {
        Some(s) => vec![s
            .trim()
            .parse::<f64>()
            .map_err(|_| format!("--corner '{s}' is not a supply voltage"))?],
        None => vec![0.6, 0.8, 1.0, 1.2],
    };
    let model = match SessionLayerSpec::synthetic_network(&net, seed) {
        Ok(specs) => NetModel::Chain(specs),
        Err(e) => match networks::graph_network(id, seed) {
            Some(g) => NetModel::Graph(g),
            None => return Err(e.into()),
        },
    };
    let c0 = match &model {
        NetModel::Chain(specs) => specs[0].kernels.n_in,
        NetModel::Graph(g) => g.compile().map_err(|e| e.to_string())?.n_in,
    };
    let h = ((net.img.0 as f64 * scale).round() as usize).max(16);
    let w = ((net.img.1 as f64 * scale).round() as usize).max(16);
    let mut g = Gen::new(seed ^ 0xF00D);
    let frames: Vec<Image> = (0..n_frames).map(|_| synthetic_scene(&mut g, c0, h, w)).collect();

    // The row-band schedule exercises every injection site (image
    // memory, halo rows crossing band boundaries, packed weights).
    // Frames run one per session with a per-frame plan *seed*: that is
    // what varies the upset draws frame to frame deterministically,
    // independent of how the dispatcher batches submissions.
    let make_session = |plan: FaultPlan| -> Result<Yodann, YodannError> {
        let b = SessionBuilder::new()
            .engine(EngineKind::Functional)
            .workers(workers)
            .shard_policy(ShardPolicy::RowBands(2))
            .max_in_flight(1)
            .fault_plan(plan);
        let b = match &model {
            NetModel::Chain(specs) => b.layers(specs.clone()),
            NetModel::Graph(gr) => b.graph(gr),
        };
        b.build()
    };
    let frame_seed =
        |i: usize| seed.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));

    println!(
        "{} fault sweep: {n_frames} frames of {c0}x{h}x{w}, row-band schedule, seed {seed}",
        net.name
    );
    // Clean baseline, explicitly disabled — immune to YODANN_FAULT_SEED.
    let baseline: Vec<Image> = {
        let mut sess = make_session(FaultPlan::disabled()).map_err(|e| e.to_string())?;
        let mut out = Vec::with_capacity(n_frames);
        for f in &frames {
            let r = sess.submit(f.clone()).and_then(|t| t.wait()).map_err(|e| e.to_string())?;
            out.push(r.output);
        }
        out
    };
    let mut records: Vec<JsonRecord> = Vec::new();
    for &v in &corners {
        let corner = Corner { arch: ArchId::Bin32Multi, v };
        let ber = bit_error_rate(corner);
        println!("  corner {v:.1} V: model memory BER {ber:.3e}");
        let base = format!("faults/cli/{id}/v{v}");
        push_nonzero(&mut records, format!("{base}/model-ber"), ber);

        // (a) Silent corruption: inject at the corner's BER, no checksums.
        let mut corrupted = 0usize;
        let mut flips_sum = 0u64;
        for (i, f) in frames.iter().enumerate() {
            let plan = FaultPlan::seeded(frame_seed(i)).ber(ber).detect(false);
            let mut sess = make_session(plan).map_err(|e| e.to_string())?;
            let r = sess.submit(f.clone()).and_then(|t| t.wait()).map_err(|e| e.to_string())?;
            if r.output != baseline[i] {
                corrupted += 1;
            }
            flips_sum += u64::from(r.telemetry.fault.total_flips());
        }
        println!(
            "    detect off: {corrupted}/{n_frames} frames silently corrupted \
             ({flips_sum} bit flips landed)"
        );
        push_nonzero(
            &mut records,
            format!("{base}/corrupted-frames"),
            corrupted as f64 / n_frames as f64,
        );
        push_nonzero(&mut records, format!("{base}/mean-flips"), flips_sum as f64 / n_frames as f64);

        // (b) Detect and contain: frame-path checksums on (weights
        // probed separately — pack-time faults reject at build).
        let mut detected = 0usize;
        let mut clean = 0usize;
        for (i, f) in frames.iter().enumerate() {
            let plan = FaultPlan::seeded(frame_seed(i)).ber(ber).weights(false);
            let mut sess = make_session(plan).map_err(|e| e.to_string())?;
            match sess.submit(f.clone()).and_then(|t| t.wait()) {
                Ok(r) => {
                    if r.output != baseline[i] {
                        return Err(format!(
                            "frame {i} passed checksums but diverged from the clean \
                             baseline — this is a bug"
                        ));
                    }
                    clean += 1;
                }
                Err(YodannError::FaultDetected { .. }) => detected += 1,
                Err(e) => return Err(e.to_string()),
            }
        }
        let contained = clean + detected;
        println!(
            "    detect on : {clean} clean, {detected} refused with typed FaultDetected \
             -> {contained}/{n_frames} contained"
        );
        push_nonzero(
            &mut records,
            format!("{base}/fault-detected"),
            detected as f64 / n_frames as f64,
        );
        push_nonzero(
            &mut records,
            format!("{base}/contained-frames"),
            contained as f64 / n_frames as f64,
        );

        // (c) Weight memory: weights pack once at session build, so a
        // persistent detected corruption refuses the whole session.
        match make_session(FaultPlan::seeded(seed).ber(ber).image(false).halo(false)) {
            Err(YodannError::FaultDetected { frame: None, .. }) => {
                println!("    weights   : uncorrectable pack-time corruption -> session refused");
                push_nonzero(&mut records, format!("{base}/weights-rejected"), 1.0);
            }
            Err(e) => return Err(e.to_string()),
            Ok(_) => {
                println!("    weights   : packed weights verified clean (or corrected on retry)");
            }
        }
    }
    if !records.is_empty() {
        validate_records(&records).map_err(|e| format!("fault records failed validation: {e}"))?;
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engines.json");
        let total = merge_json(path, "engines", &records)
            .map_err(|e| format!("merging records into {path}: {e}"))?;
        println!("  merged {} records into {path} ({total} total)", records.len());
    }
    Ok(())
}

/// The power-aware serving daemon: a `serve::run` loop over a live
/// session, with the DVFS governor steering the simulated corner
/// against `--budget-mw` (core power, the paper's 895 µW axis) or
/// `--slo-ms` (queue-drain latency). Prints a per-tick readout, merges
/// `serve/cli/<scenario>/...` records into `BENCH_engines.json`, and
/// exits non-zero when the steady-state budget was violated — the CI
/// contract. The default workload is a heterogeneous k7→k3 chain on
/// one chip (`ShardPolicy::PerFrame`), so the session envelope prices
/// the native 7×7 mode and a 1 mW budget is holdable at 0.6 V.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let scenario_raw = args.get("scenario", "burst");
    let scenario = Scenario::parse(scenario_raw).ok_or_else(|| {
        format!("unknown scenario '{scenario_raw}' (accepted: burst, sustained, thermal)")
    })?;
    let budget_mw = match args.options.get("budget-mw") {
        Some(s) => Some(
            s.trim()
                .parse::<f64>()
                .map_err(|_| format!("--budget-mw '{s}' is not a number"))?,
        ),
        None => None,
    };
    let slo_ms = match args.options.get("slo-ms") {
        Some(s) => Some(
            s.trim().parse::<f64>().map_err(|_| format!("--slo-ms '{s}' is not a number"))?,
        ),
        None => None,
    };
    let mode = match (budget_mw, slo_ms) {
        (Some(b), None) if b > 0.0 => GovernorMode::PowerBudget { watts: b * 1e-3 },
        (None, Some(s)) if s > 0.0 => GovernorMode::LatencySlo { seconds: s * 1e-3 },
        (Some(_), None) => return Err("--budget-mw must be positive".into()),
        (None, Some(_)) => return Err("--slo-ms must be positive".into()),
        _ => {
            return Err(
                "pass exactly one of --budget-mw (core power, mW) or --slo-ms (drain \
                 latency, ms)"
                    .into(),
            )
        }
    };
    let frames = args.get_usize("frames", 64)?.max(1);
    let seed = args.get_u64("seed", 7)?;
    let workers = args.get_usize("workers", 2)?.max(1);
    let depth = args.get_usize("depth", 8)?.max(1);
    let tick_ms = args.get_f64("tick-ms", 0.5)?;
    if !(tick_ms > 0.0 && tick_ms.is_finite()) {
        return Err("--tick-ms must be positive".into());
    }
    let v_start = args.get_f64("v-start", scenario.default_v_start())?;
    let h = args.get_usize("h", 24)?.max(8);
    let w = args.get_usize("w", 24)?.max(8);

    // The workload: a --net chain/graph, or the built-in heterogeneous
    // k7 -> k3 demo chain (whose envelope prices the 7x7 mode).
    let model: NetModel = match args.options.get("net") {
        Some(id) => match SessionLayerSpec::synthetic_network(&lookup_network(id)?, seed) {
            Ok(specs) => NetModel::Chain(specs),
            Err(e) => match networks::graph_network(id, seed) {
                Some(gr) => NetModel::Graph(gr),
                None => return Err(e.into()),
            },
        },
        None => {
            let mut g = Gen::new(seed ^ 0x5E4E);
            NetModel::Chain(vec![
                SessionLayerSpec {
                    k: 7,
                    zero_pad: true,
                    kernels: Arc::new(BinaryKernels::random(&mut g, 4, 2, 7)),
                    scale_bias: Arc::new(ScaleBias::identity(4)),
                    relu: false,
                    maxpool2: false,
                },
                SessionLayerSpec {
                    k: 3,
                    zero_pad: true,
                    kernels: Arc::new(BinaryKernels::random(&mut g, 2, 4, 3)),
                    scale_bias: Arc::new(ScaleBias::identity(2)),
                    relu: false,
                    maxpool2: false,
                },
            ])
        }
    };
    let c0 = match &model {
        NetModel::Chain(specs) => specs[0].kernels.n_in,
        NetModel::Graph(gr) => gr.compile().map_err(|e| e.to_string())?.n_in,
    };

    // Fault coupling is per scenario: only thermal throttling arms the
    // live dial (starting at 0, so weight packing at build is clean);
    // the other scenarios explicitly disable injection so their traces
    // isolate the budget/SLO control laws.
    let dial = scenario.couples_faults().then(|| LiveBer::new(0.0));
    let plan = match &dial {
        Some(d) => FaultPlan::seeded(seed).live_ber(d),
        None => FaultPlan::disabled(),
    };
    let b = SessionBuilder::new()
        .engine(EngineKind::Functional)
        .workers(workers)
        .shard_policy(ShardPolicy::PerFrame)
        .max_in_flight(depth)
        .fault_plan(plan);
    let b = match &model {
        NetModel::Chain(specs) => b.layers(specs.clone()),
        NetModel::Graph(gr) => b.graph(gr),
    };
    let mut session = b.build().map_err(|e| e.to_string())?;

    let cfg = ServeConfig {
        scenario,
        mode,
        governor: GovernorConfig { v_start, ..GovernorConfig::default() },
        total_frames: frames,
        seed,
        tick_s: tick_ms * 1e-3,
        warmup_ticks: 8,
        max_ticks: 100_000,
    };
    println!(
        "serve: {} scenario | {} | {frames} frames of {c0}x{h}x{w} | tick {tick_ms} ms | \
         v_start {v_start} V | workers {workers}, depth {depth}, seed {seed}",
        scenario.name(),
        match mode {
            GovernorMode::PowerBudget { watts } =>
                format!("core-power budget {:.3} mW", watts * 1e3),
            GovernorMode::LatencySlo { seconds } =>
                format!("drain-latency SLO {:.3} ms", seconds * 1e3),
        }
    );
    let mut make = |fseed: u64| {
        let mut g = Gen::new(fseed);
        synthetic_scene(&mut g, c0, h, w)
    };
    let budget_txt =
        |b: f64| if b.is_finite() { format!("{:.3}", b * 1e3) } else { "-".to_string() };
    let mut on_tick = |t: &TickTrace| {
        println!(
            "  tick {:>4} [{}] v={:.3}V f={:>6.1}MHz P={:>7.3}mW budget={}mW util={:>5.1}% \
             q={:>7.3}ms adm={}/{} shed={}L/{}H faults={} miss={}",
            t.tick,
            t.action.glyph(),
            t.v,
            t.freq_hz / 1e6,
            t.power_w * 1e3,
            budget_txt(t.budget_w),
            t.util * 100.0,
            t.queue_s * 1e3,
            t.admitted,
            t.offered,
            t.shed_low,
            t.shed_high,
            t.faults,
            t.deadline_misses,
        );
    };
    let t0 = Instant::now();
    let report = serve::run(&mut session, dial.as_ref(), &cfg, &mut make, &mut on_tick)
        .map_err(|e| e.to_string())?;
    let wall = t0.elapsed().as_secs_f64();

    println!("summary:");
    println!(
        "  {} ticks; served {}/{} frames ({} low + {} high shed, {} fault-refused, {} \
         deadline misses)",
        report.trace.len(),
        report.frames_served,
        frames,
        report.shed_low,
        report.shed_high,
        report.faults_detected,
        report.deadline_misses
    );
    println!(
        "  corner: start {v_start:.3} V, final {:.3} V, visited [{:.3}, {:.3}] V",
        report.final_v, report.min_v, report.max_v
    );
    println!(
        "  power : steady-state mean {:.3} mW core, energy {:.3} uJ (simulated)",
        report.mean_power_w * 1e3,
        report.energy_j * 1e6
    );
    println!("  output digest {:#018x} (same seed => same digest + corner trace)", report.output_digest);

    let base = format!("serve/cli/{}", scenario.name());
    let served = report.frames_served.max(1) as f64;
    let mut records = vec![JsonRecord {
        name: format!("{base}/run"),
        ns_per_iter: wall * 1e9 / served,
        frames_per_s: Some(served / wall.max(1e-9)),
    }];
    push_nonzero(&mut records, format!("{base}/mean-power-mw"), report.mean_power_w * 1e3);
    push_nonzero(&mut records, format!("{base}/final-corner-v"), report.final_v);
    push_nonzero(&mut records, format!("{base}/energy-uj"), report.energy_j * 1e6);
    validate_records(&records).map_err(|e| format!("serve records failed validation: {e}"))?;
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engines.json");
    let total = merge_json(path, "engines", &records)
        .map_err(|e| format!("merging records into {path}: {e}"))?;
    println!("  merged {} records into {path} ({total} total)", records.len());

    if report.budget_violated {
        return Err(format!(
            "steady-state power budget violated: post-warmup core power exceeded the \
             effective budget (mean {:.3} mW)",
            report.mean_power_w * 1e3
        ));
    }
    Ok(())
}

/// How a network executes: as a session chain, as a compiled graph
/// (AlexNet's split, ResNet's shortcuts), or not at all (a Table-III
/// op-count descriptor only). Descriptor-level checks only — no
/// weights are materialized for a listing.
fn exec_kind(n: &Network) -> &'static str {
    if networks::is_simple_chain(n) {
        "runnable (chain)"
    } else if networks::has_graph(n.id) {
        "runnable (graph)"
    } else {
        "descriptor-only"
    }
}

/// Precision modes a listed network can run under. Runnable models
/// (chain or graph) take the per-layer [`Precision`] knob, so they list
/// every mode in [`Precision::ALL`] — a new precision variant lands in
/// this column by construction. Descriptor-only rows evaluate through
/// the analytic BWN model only.
fn precision_modes(n: &Network) -> String {
    if networks::is_simple_chain(n) || networks::has_graph(n.id) {
        Precision::ALL.map(|p| p.name()).join("+")
    } else {
        Precision::MultiBit.name().to_string()
    }
}

fn cmd_networks() -> Result<(), String> {
    println!(
        "{:<14} {:<14} {:>10} {:>8}  {:<18} {:<16}",
        "id", "name", "img", "GOp", "precision", "exec"
    );
    let mut nets = networks::all_networks();
    nets.push(networks::scene_labeling());
    for n in &nets {
        println!(
            "{:<14} {:<14} {:>10} {:>8.2}  {:<18} {:<16}",
            n.id,
            n.name,
            format!("{}x{}", n.img.0, n.img.1),
            n.conv_ops() as f64 / 1e9,
            precision_modes(n),
            exec_kind(n)
        );
    }
    Ok(())
}
