//! Pass 1 — interval range analysis over the step program.
//!
//! Every slot carries an interval of raw Q2.9 values. The conv transfer
//! function is exact where it matters: a binary weight contributes
//! `+pixel` or `−pixel`, so for an output channel with `p` plus-bits and
//! `m = k² − p` minus-bits against one input channel whose pixels lie in
//! `[a, b]`, the per-channel window sum lies in `[p·a − m·b, p·b − m·a]`
//! — the popcount of the actual kernel row, not a worst case over all
//! kernels.
//!
//! **Why the accumulator test is schedule-independent.** The reference
//! conv saturates at Q7.9 once per input channel; the blocked executor
//! accumulates raw partials off-chip and clamps once at the end. Those
//! two schedules clip *differently* when a partial overshoots, so no
//! single schedule's interval is sound for the other. The analyzer
//! instead checks `Σᵢ max(|lᵢ|, |uᵢ|) ≤ Q7.9 max`: every partial sum any
//! schedule can form is a subset sum of the per-channel terms, so under
//! that bound **no clamp can engage anywhere** and the exact interval
//! `[Σ lᵢ, Σ uᵢ]` is sound for every engine and block decomposition.
//! Otherwise the accumulator widens to the full Q7.9 range (sound: every
//! schedule's final accumulator is clamped into it) and the step is
//! flagged `acc-saturation-possible`.
//!
//! The scale/bias fold reuses the bit-exact [`crate::fixedpoint`]
//! arithmetic and is monotone in the accumulator (for either sign of
//! α), so mapping the interval endpoints is exact. Saturation verdicts
//! are classified on the *pre-clamp* aligned value — the quantity the
//! final Q2.9 saturation inspects.

use crate::engine::BINARY_ONE;
use crate::fixedpoint::{self, Q10_18, Q2_9, Q7_9};
use crate::model::graph::{CompiledGraph, PlanConv, PlanStep, Precision};

use super::{AnalysisFinding, Pass, Severity};

/// A closed interval of raw fixed-point values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    /// Inclusive lower bound.
    pub lo: i64,
    /// Inclusive upper bound.
    pub hi: i64,
}

impl Interval {
    /// `[lo, hi]`; panics if empty.
    pub fn new(lo: i64, hi: i64) -> Interval {
        assert!(lo <= hi, "empty interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    /// The full representable Q2.9 range.
    pub fn full_q29() -> Interval {
        Interval { lo: Q2_9.min_raw(), hi: Q2_9.max_raw() }
    }

    /// Smallest interval containing both.
    pub fn hull(self, other: Interval) -> Interval {
        Interval { lo: self.lo.min(other.lo), hi: self.hi.max(other.hi) }
    }

    /// Whether `v` lies inside.
    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }
}

impl std::fmt::Display for Interval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}, {}]", self.lo, self.hi)
    }
}

/// Can the final Q2.9 saturation at a step engage?
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SatVerdict {
    /// Proved: no input in the assumed range can clip.
    Unreachable,
    /// Some inputs may clip.
    Possible,
    /// Every input clips (the pre-clamp interval lies entirely outside
    /// Q2.9 on one side).
    Certain,
}

impl std::fmt::Display for SatVerdict {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SatVerdict::Unreachable => "unreachable",
            SatVerdict::Possible => "possible",
            SatVerdict::Certain => "certain",
        })
    }
}

/// Range-pass result for one step.
#[derive(Debug, Clone)]
pub struct NodeRange {
    /// Step index into [`CompiledGraph::steps`].
    pub step: usize,
    /// The step's label.
    pub label: String,
    /// Output-slot interval after the step.
    pub out: Interval,
    /// Saturation verdict, for steps that end in a Q2.9 clamp (conv and
    /// residual add); `None` for clamp-free host ops.
    pub verdict: Option<SatVerdict>,
    /// Conv only: whether the Q7.9 accumulator could clip under some
    /// block schedule (forces the widened accumulator interval).
    pub acc_saturation: bool,
}

/// The pre-clamp scale/bias value: [`fixedpoint::scale_bias`] minus its
/// final Q2.9 saturation, bit-exact otherwise (Q7.9 × Q2.9 product,
/// Q10.18 wide-sum saturation, truncating re-alignment). Monotone in
/// `acc` for either sign of `alpha`.
fn scale_bias_preclamp(acc_q79: i64, alpha_q29: i64, beta_q29: i64) -> i64 {
    let (_, prod) = fixedpoint::mul(Q7_9, acc_q79, Q2_9, alpha_q29);
    Q10_18.saturate(prod + (beta_q29 << 9)) >> 9
}

/// Classify a pre-clamp interval against the Q2.9 range.
fn classify(pre: Interval) -> SatVerdict {
    if pre.lo >= Q2_9.min_raw() && pre.hi <= Q2_9.max_raw() {
        SatVerdict::Unreachable
    } else if pre.hi < Q2_9.min_raw() || pre.lo > Q2_9.max_raw() {
        SatVerdict::Certain
    } else {
        SatVerdict::Possible
    }
}

/// Conv transfer: returns the output interval, the worst per-channel
/// saturation verdict, and whether any channel's accumulator had to be
/// widened.
fn conv_transfer(cv: &PlanConv, input: Interval) -> (Interval, SatVerdict, bool) {
    // Zero padding injects literal zeros into border windows.
    let (a, b) = if cv.zero_pad {
        (input.lo.min(0), input.hi.max(0))
    } else {
        (input.lo, input.hi)
    };
    let k2 = (cv.k * cv.k) as i64;
    let kn = &cv.kernels;
    let sb = &cv.scale_bias;
    let mut out: Option<Interval> = None;
    let mut worst = SatVerdict::Unreachable;
    let mut widened = false;
    for o in 0..kn.n_out {
        let (mut sum_lo, mut sum_hi, mut abs_sum) = (0i64, 0i64, 0i64);
        for i in 0..kn.n_in {
            let mut p = 0i64;
            for dy in 0..kn.k {
                for dx in 0..kn.k {
                    if kn.bit(o, i, dy, dx) {
                        p += 1;
                    }
                }
            }
            let m = k2 - p;
            let term_lo = p * a - m * b;
            let term_hi = p * b - m * a;
            sum_lo += term_lo;
            sum_hi += term_hi;
            abs_sum += term_lo.abs().max(term_hi.abs());
        }
        let acc = if abs_sum <= Q7_9.max_raw() {
            Interval { lo: sum_lo, hi: sum_hi }
        } else {
            widened = true;
            Interval { lo: Q7_9.min_raw(), hi: Q7_9.max_raw() }
        };
        let e0 = scale_bias_preclamp(acc.lo, sb.alpha[o], sb.beta[o]);
        let e1 = scale_bias_preclamp(acc.hi, sb.alpha[o], sb.beta[o]);
        let pre = Interval { lo: e0.min(e1), hi: e0.max(e1) };
        worst = worst.max(classify(pre));
        let clamped = Interval { lo: Q2_9.saturate(pre.lo), hi: Q2_9.saturate(pre.hi) };
        out = Some(match out {
            Some(acc) => acc.hull(clamped),
            None => clamped,
        });
    }
    (out.unwrap_or_else(Interval::full_q29), worst, widened)
}

/// Run the range pass: one [`NodeRange`] per step, findings for every
/// step where saturation is not proved unreachable.
pub(crate) fn analyze(
    graph: &CompiledGraph,
    input: Interval,
    findings: &mut Vec<AnalysisFinding>,
) -> Vec<NodeRange> {
    let mut slots: Vec<Option<Interval>> = vec![None; graph.n_slots];
    slots[graph.input_slot] = Some(input);
    let mut ranges = Vec::with_capacity(graph.steps.len());
    for (si, step) in graph.steps.iter().enumerate() {
        let label = graph.step_labels.get(si).cloned().unwrap_or_default();
        // A missing source interval means the graph is malformed (the
        // liveness pass reports it); the sound fallback is full range.
        let src_iv =
            |s: usize| slots.get(s).copied().flatten().unwrap_or_else(Interval::full_q29);
        let (out, verdict, acc_sat) = match step {
            PlanStep::Conv { conv, src, .. } => {
                let cv = &graph.convs[*conv];
                // A binary (XNOR) conv binarizes every input sample to
                // ±1 (raw ±BINARY_ONE) before the sum-of-products, so
                // the incoming interval collapses to the binary rails
                // whatever the source step produced — and zero padding
                // injects +1, already inside those rails.
                let iv = if cv.precision == Precision::Binary {
                    Interval::new(-BINARY_ONE, BINARY_ONE)
                } else {
                    src_iv(*src)
                };
                let (out, v, widened) = conv_transfer(cv, iv);
                (out, Some(v), widened)
            }
            PlanStep::BatchNormThreshold { src, .. } => {
                // Exact transfer: every output sample is ±BINARY_ONE
                // whichever side of its threshold the input lands on.
                let _ = src_iv(*src);
                (Interval::new(-BINARY_ONE, BINARY_ONE), None, false)
            }
            PlanStep::Relu { src, .. } => {
                let iv = src_iv(*src);
                (Interval { lo: iv.lo.max(0), hi: iv.hi.max(0) }, None, false)
            }
            PlanStep::MaxPool2 { src, .. } | PlanStep::Subsample2 { src, .. } => {
                (src_iv(*src), None, false)
            }
            PlanStep::Add { srcs, .. } => {
                // Wide sum, one Q2.9 saturation (`add_wide_saturating`):
                // a single monotone clamp, so endpoint mapping is exact.
                let (lo, hi) = srcs
                    .iter()
                    .map(|&s| src_iv(s))
                    .fold((0i64, 0i64), |(lo, hi), iv| (lo + iv.lo, hi + iv.hi));
                let pre = Interval { lo, hi };
                (
                    Interval { lo: Q2_9.saturate(lo), hi: Q2_9.saturate(hi) },
                    Some(classify(pre)),
                    false,
                )
            }
            PlanStep::Concat { srcs, .. } => {
                let out = srcs
                    .iter()
                    .map(|&s| src_iv(s))
                    .reduce(Interval::hull)
                    .unwrap_or_else(Interval::full_q29);
                (out, None, false)
            }
        };
        if acc_sat {
            findings.push(AnalysisFinding {
                pass: Pass::Range,
                severity: Severity::Warning,
                code: "acc-saturation-possible",
                step: Some(si),
                node: label.clone(),
                detail: format!(
                    "Q7.9 accumulator may clip under some block schedule; \
                     widened to [{}, {}]",
                    Q7_9.min_raw(),
                    Q7_9.max_raw()
                ),
            });
        }
        match verdict {
            Some(SatVerdict::Possible) => findings.push(AnalysisFinding {
                pass: Pass::Range,
                severity: Severity::Warning,
                code: "saturation-possible",
                step: Some(si),
                node: label.clone(),
                detail: format!("Q2.9 output clamp may engage; output interval {out}"),
            }),
            Some(SatVerdict::Certain) => findings.push(AnalysisFinding {
                pass: Pass::Range,
                severity: Severity::Error,
                code: "saturation-certain",
                step: Some(si),
                node: label.clone(),
                detail: format!(
                    "every output value clips at the Q2.9 boundary; \
                     output interval {out} — the layer computes a constant rail"
                ),
            }),
            Some(SatVerdict::Unreachable) | None => {}
        }
        slots[step.dst()] = Some(out);
        ranges.push(NodeRange { step: si, label, out, verdict, acc_saturation: acc_sat });
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::{NetworkBuilder, Weights};
    use crate::testkit::Gen;

    fn single_conv(
        k: usize,
        zero_pad: bool,
        n_in: usize,
        n_out: usize,
        seed: u64,
    ) -> CompiledGraph {
        let mut g = Gen::new(seed);
        let mut b = NetworkBuilder::new("range-ut", n_in);
        let x = b.input();
        let c = b.conv("conv", x, zero_pad, Weights::seeded(&mut g, n_out, n_in, k));
        b.build(c).compile().expect("single conv compiles")
    }

    #[test]
    fn small_inputs_prove_saturation_unreachable() {
        let g = single_conv(3, false, 2, 4, 7);
        let mut findings = Vec::new();
        // ±0.05 in Q2.9: 3×3×2 windows at α = 0.05 cannot reach ±2.
        let ranges = analyze(&g, Interval::new(-25, 25), &mut findings);
        assert_eq!(ranges.len(), 1);
        assert_eq!(ranges[0].verdict, Some(SatVerdict::Unreachable));
        assert!(!ranges[0].acc_saturation);
        assert!(findings.is_empty(), "no findings expected: {findings:?}");
    }

    #[test]
    fn wide_accumulation_widens_and_warns() {
        // 64 input channels of full-range pixels overflow Q7.9 on any
        // schedule's worst case: the accumulator interval must widen.
        let g = single_conv(3, true, 64, 2, 11);
        let mut findings = Vec::new();
        let ranges = analyze(&g, Interval::full_q29(), &mut findings);
        assert!(ranges[0].acc_saturation);
        assert!(findings.iter().any(|f| f.code == "acc-saturation-possible"));
    }

    #[test]
    fn certain_saturation_is_an_error() {
        // 1×1 all-plus kernel at α = 1.0 (raw 512) with β at the Q2.9
        // ceiling: inputs in [1000, 2000] give pre-clamp values in
        // roughly [3047, 4047] — entirely past the 2047 rail.
        use crate::workload::{BinaryKernels, ScaleBias};
        use std::sync::Arc;
        let kernels = Arc::new(BinaryKernels::all_plus(1, 1, 1));
        let sb = Arc::new(ScaleBias { alpha: vec![512], beta: vec![Q2_9.max_raw()] });
        let mut b = NetworkBuilder::new("rail", 1);
        let x = b.input();
        let c = b.conv("rail-conv", x, false, Weights::new(kernels, sb));
        let g = b.build(c).compile().expect("compiles");
        let mut findings = Vec::new();
        let ranges = analyze(&g, Interval::new(1000, 2000), &mut findings);
        assert_eq!(ranges[0].verdict, Some(SatVerdict::Certain));
        assert_eq!(ranges[0].out, Interval::new(Q2_9.max_raw(), Q2_9.max_raw()));
        assert!(
            findings.iter().any(|f| f.code == "saturation-certain"
                && f.severity == Severity::Error),
            "certain saturation must be an error finding: {findings:?}"
        );
    }

    #[test]
    fn threshold_and_binary_conv_collapse_to_the_rails() {
        use std::sync::Arc;
        let mut gen = Gen::new(13);
        let mut b = NetworkBuilder::new("bnn-range", 2);
        let x = b.input();
        let stem = b.conv("stem", x, true, Weights::seeded(&mut gen, 3, 2, 3));
        let bnt = b.batch_norm_threshold("bnt", stem, Arc::new(vec![0; 3]));
        let trunk = b.conv_with_precision(
            "trunk",
            bnt,
            true,
            Weights::seeded(&mut gen, 2, 3, 3),
            Precision::Binary,
        );
        let g = b.build(trunk).compile().expect("compiles");
        let mut findings = Vec::new();
        let ranges = analyze(&g, Interval::new(-25, 25), &mut findings);
        // The threshold step lands exactly on the binary rails, with no
        // clamp of its own.
        assert_eq!(ranges[1].out, Interval::new(-BINARY_ONE, BINARY_ONE));
        assert_eq!(ranges[1].verdict, None);
        // The binary conv's transfer saw the rails (not the stem's
        // small interval): its output is bounded by k²·n_in·512 per
        // channel folded through α/β — just assert it's a valid Q2.9
        // interval and that the analysis ran without widening panic.
        assert!(ranges[2].out.lo >= Q2_9.min_raw() && ranges[2].out.hi <= Q2_9.max_raw());
    }

    #[test]
    fn relu_clamps_lower_bound_and_concat_hulls() {
        let mut gen = Gen::new(3);
        let mut b = NetworkBuilder::new("hostops", 1);
        let x = b.input();
        let c = b.conv("c", x, true, Weights::seeded(&mut gen, 2, 1, 3));
        let r = b.relu(c);
        let j = b.concat("j", &[r, r]);
        let g = b.build(j).compile().expect("compiles");
        let mut findings = Vec::new();
        let ranges = analyze(&g, Interval::new(-100, 100), &mut findings);
        let relu = &ranges[1];
        assert!(relu.out.lo >= 0, "relu floor: {:?}", relu.out);
        let cat = &ranges[2];
        assert_eq!(cat.out, relu.out, "concat of identical branches is the same interval");
    }
}
