//! Static plan verifier: abstract interpretation of the [`CompiledGraph`]
//! step program, **without executing a frame**.
//!
//! The coordinator's correctness invariants — Q2.9/Q7.9 saturation
//! behavior, slot-store lifetime discipline, block/shard geometry — are
//! otherwise only checked at runtime-panic or fuzz time. This module
//! proves them per compiled network before a session ever runs:
//!
//! 1. **Range analysis** ([`range`]) — propagates raw-Q2.9 value
//!    intervals through every step. Conv bounds come from per-kernel
//!    popcounts (a binary weight contributes `+pixel` or `−pixel`, so
//!    `p` plus-bits and `k²−p` minus-bits bound the window sum exactly),
//!    folded through the bit-exact [`crate::fixedpoint`] scale/bias
//!    arithmetic. Each conv/add step is classified
//!    saturation-unreachable / -possible / -certain.
//! 2. **Slot liveness** ([`liveness`]) — symbolic execution of the
//!    [`PlanStep`] program over the slot store: proves no
//!    use-before-def, no use-after-free, no double-free, no leaked
//!    slot, and reports peak live-slot memory.
//! 3. **Plan/shard contracts** ([`contracts`]) — lifts the executor's
//!    runtime geometry panics (`check_plan_geometry`,
//!    `check_width_geometry`, valid-mode `h < k` underflow) plus halo
//!    coverage for `ShardGrid` / row-band partitions into static proofs
//!    over the actual [`crate::engine::BlockPlan`]s the planner emits.
//! 4. **Concurrency lint** ([`locks`]) — a registry of the crate's
//!    long-lived mutexes and their allowed nesting order, with a cycle
//!    check (also pinned as a unit test).
//!
//! Entry points: [`analyze_graph`] here, `SessionBuilder::analyze` /
//! the [`Preflight`] build knob on the serving facade, and the
//! `yodann analyze` CLI.

use crate::coordinator::{ShardGrid, ShardPolicy};
use crate::hw::ChipConfig;
use crate::model::graph::{CompiledGraph, PlanStep};

pub mod contracts;
pub mod liveness;
pub mod locks;
pub mod range;

pub use contracts::ContractsSummary;
pub use liveness::LivenessSummary;
pub use range::{Interval, NodeRange, SatVerdict};

/// How bad a finding is. [`Severity::Error`] means the session would
/// panic, return a typed error, or compute wrong values at runtime;
/// `yodann analyze` exits non-zero when any error-severity finding
/// survives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Informational: a property worth surfacing, not a defect.
    Info,
    /// A value-quality hazard (e.g. possible saturation) that cannot
    /// crash the session.
    Warning,
    /// A proof failure: the runtime would panic, refuse, or clip.
    Error,
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Which analysis pass produced a finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    /// Interval / saturation analysis.
    Range,
    /// Slot-store lifetime analysis.
    Liveness,
    /// Block/shard geometry proofs.
    Contracts,
    /// Lock-order registry check.
    Locks,
}

impl std::fmt::Display for Pass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Pass::Range => "range",
            Pass::Liveness => "liveness",
            Pass::Contracts => "contracts",
            Pass::Locks => "locks",
        })
    }
}

/// One typed, machine-readable analyzer finding.
#[derive(Debug, Clone)]
pub struct AnalysisFinding {
    /// The pass that produced it.
    pub pass: Pass,
    /// How bad it is.
    pub severity: Severity,
    /// Stable machine-readable code (kebab-case), e.g.
    /// `"saturation-possible"`, `"use-after-free"`, `"halo-underread"`.
    pub code: &'static str,
    /// Step index into [`CompiledGraph::steps`], when the finding is
    /// attached to one step.
    pub step: Option<usize>,
    /// The step's label (empty when not step-attached).
    pub node: String,
    /// Human-readable detail.
    pub detail: String,
}

impl std::fmt::Display for AnalysisFinding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}/{}", self.severity, self.pass, self.code)?;
        if let Some(step) = self.step {
            write!(f, " at step {step}")?;
        }
        if !self.node.is_empty() {
            write!(f, " ({})", self.node)?;
        }
        write!(f, ": {}", self.detail)
    }
}

/// Knobs for one analyzer run.
#[derive(Debug, Clone)]
pub struct AnalysisOptions {
    /// Raw-Q2.9 interval assumed for every input activation. Defaults
    /// to the full representable range — what the serving facade
    /// admits.
    pub input: Interval,
    /// Frame geometry `(h, w)`. `None` (the preflight default, where
    /// frame sizes are not yet known) skips the shape-dependent checks:
    /// the contracts pass and peak-memory accounting.
    pub shape: Option<(usize, usize)>,
}

impl Default for AnalysisOptions {
    fn default() -> AnalysisOptions {
        AnalysisOptions { input: Interval::full_q29(), shape: None }
    }
}

/// `SessionBuilder::build` preflight policy: what to do with analyzer
/// findings before spawning the session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Preflight {
    /// Do not run the analyzer at build time (default).
    #[default]
    Off,
    /// Run it and print every finding to stderr; always build.
    Warn,
    /// Run it and refuse the build with a typed error if any
    /// [`Severity::Error`] finding survives.
    Refuse,
}

/// Everything one analyzer run produced.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    /// The analyzed graph's name.
    pub net: String,
    /// All findings, in pass order.
    pub findings: Vec<AnalysisFinding>,
    /// Per-step interval/saturation verdicts (range pass).
    pub ranges: Vec<NodeRange>,
    /// Slot-store lifetime summary (liveness pass).
    pub liveness: LivenessSummary,
    /// Geometry-proof summary (contracts pass).
    pub contracts: ContractsSummary,
}

impl AnalysisReport {
    /// Number of findings at exactly `severity`.
    pub fn count_at(&self, severity: Severity) -> usize {
        self.findings.iter().filter(|f| f.severity == severity).count()
    }

    /// Whether any [`Severity::Error`] finding survived.
    pub fn has_errors(&self) -> bool {
        self.count_at(Severity::Error) > 0
    }

    /// The worst severity present, if any finding exists.
    pub fn worst(&self) -> Option<Severity> {
        self.findings.iter().map(|f| f.severity).max()
    }
}

/// Per-step slot shapes `(c, h, w)`: what each step reads and writes
/// for a given input geometry. `None` marks shapes unknown because an
/// upstream step already failed its shape check (the runtime would
/// never reach this step).
#[derive(Debug, Clone)]
pub(crate) struct StepGeom {
    /// Shape of each source slot, in [`PlanStep`] source order.
    pub srcs: Vec<Option<(usize, usize, usize)>>,
    /// Shape written to the destination slot.
    pub dst: Option<(usize, usize, usize)>,
}

/// Walk the step program's shapes for one input geometry, mirroring
/// [`CompiledGraph::walk_shapes`] but per-step and finding-typed: shape
/// mismatches become [`AnalysisFinding`]s instead of one early error.
/// Geometry failures *inside a conv* (valid-mode `h < k` etc.) are left
/// to the contracts pass, which re-derives them from the real planner
/// checks — here they only mark downstream shapes unknown.
pub(crate) fn step_geometry(
    graph: &CompiledGraph,
    shape: (usize, usize),
) -> (Vec<StepGeom>, Vec<AnalysisFinding>) {
    let (h, w) = shape;
    let mut slots: Vec<Option<(usize, usize, usize)>> = vec![None; graph.n_slots];
    slots[graph.input_slot] = Some((graph.n_in, h, w));
    let mut geoms = Vec::with_capacity(graph.steps.len());
    let mut findings = Vec::new();
    let fail = |step: usize, node: &str, detail: String, findings: &mut Vec<AnalysisFinding>| {
        findings.push(AnalysisFinding {
            pass: Pass::Contracts,
            severity: Severity::Error,
            code: "shape-mismatch",
            step: Some(step),
            node: node.to_string(),
            detail,
        });
    };
    for (si, step) in graph.steps.iter().enumerate() {
        let label = graph.step_labels.get(si).cloned().unwrap_or_default();
        let srcs: Vec<Option<(usize, usize, usize)>> =
            step.srcs().iter().map(|&s| slots[s]).collect();
        let dst = match step {
            PlanStep::Conv { conv, .. } => {
                let cv = &graph.convs[*conv];
                match srcs[0] {
                    Some((c, sh, sw)) if c != cv.kernels.n_in => {
                        fail(
                            si,
                            &label,
                            format!(
                                "conv expects {} input channels, slot carries {c} \
                                 ({sh}x{sw} map)",
                                cv.kernels.n_in
                            ),
                            &mut findings,
                        );
                        None
                    }
                    Some((_, sh, sw)) => {
                        let (oh, ow) = if cv.zero_pad {
                            (Some(sh), Some(sw))
                        } else {
                            (sh.checked_sub(cv.k - 1), sw.checked_sub(cv.k - 1))
                        };
                        match (oh, ow) {
                            // Valid-mode h < k or w < k: no output rows.
                            // The contracts pass reports it via the real
                            // planner checks; here just stop the walk.
                            (Some(oh), Some(ow)) if oh > 0 && ow > 0 => {
                                Some((cv.kernels.n_out, oh, ow))
                            }
                            _ => None,
                        }
                    }
                    None => None,
                }
            }
            PlanStep::Relu { .. } | PlanStep::BatchNormThreshold { .. } => srcs[0],
            PlanStep::MaxPool2 { .. } => srcs[0].map(|(c, sh, sw)| {
                if sh >= 2 && sw >= 2 {
                    (c, sh / 2, sw / 2)
                } else {
                    (c, sh, sw)
                }
            }),
            PlanStep::Subsample2 { .. } => {
                srcs[0].map(|(c, sh, sw)| (c, sh.div_ceil(2), sw.div_ceil(2)))
            }
            PlanStep::Add { .. } => match srcs.iter().copied().collect::<Option<Vec<_>>>() {
                Some(shapes) if !shapes.is_empty() => {
                    if shapes.iter().any(|&s| s != shapes[0]) {
                        fail(
                            si,
                            &label,
                            format!("residual-add branches disagree in shape: {shapes:?}"),
                            &mut findings,
                        );
                        None
                    } else {
                        Some(shapes[0])
                    }
                }
                _ => None,
            },
            PlanStep::Concat { .. } => match srcs.iter().copied().collect::<Option<Vec<_>>>() {
                Some(shapes) if !shapes.is_empty() => {
                    let (_, h0, w0) = shapes[0];
                    if shapes.iter().any(|&(_, sh, sw)| (sh, sw) != (h0, w0)) {
                        fail(
                            si,
                            &label,
                            format!("concat branches disagree in map size: {shapes:?}"),
                            &mut findings,
                        );
                        None
                    } else {
                        Some((shapes.iter().map(|&(c, _, _)| c).sum(), h0, w0))
                    }
                }
                _ => None,
            },
        };
        slots[step.dst()] = dst;
        geoms.push(StepGeom { srcs, dst });
    }
    (geoms, findings)
}

/// Run all four passes over one compiled graph. `sharding` optionally
/// carries the session's `(ShardPolicy, workers)` so the contracts pass
/// proves the *sharded* plans too (`Auto` is analyzed at its batch-1
/// lowering, a `workers`-stripe grid, like `RowBands(0)`).
pub fn analyze_graph(
    graph: &CompiledGraph,
    cfg: &ChipConfig,
    sharding: Option<(&ShardPolicy, usize)>,
    opts: &AnalysisOptions,
) -> AnalysisReport {
    let mut findings = Vec::new();

    // Shape walk first: contracts and peak-memory accounting hang off it.
    let geoms = opts.shape.map(|shape| {
        let (geoms, shape_findings) = step_geometry(graph, shape);
        findings.extend(shape_findings);
        geoms
    });

    let ranges = range::analyze(graph, opts.input, &mut findings);
    let liveness = liveness::analyze(graph, geoms.as_deref(), &mut findings);
    let contracts = match (&geoms, opts.shape) {
        (Some(geoms), Some(_)) => {
            let grid = sharding.and_then(|(policy, workers)| resolve_grid(policy, workers));
            contracts::analyze(graph, cfg, geoms, grid.as_ref(), &mut findings)
        }
        _ => ContractsSummary::skipped(),
    };

    if let Err(cycle) = locks::check_lock_order() {
        findings.push(AnalysisFinding {
            pass: Pass::Locks,
            severity: Severity::Error,
            code: "lock-order-cycle",
            step: None,
            node: String::new(),
            detail: cycle,
        });
    }

    AnalysisReport { net: graph.name.clone(), findings, ranges, liveness, contracts }
}

/// Lower a [`ShardPolicy`] to the concrete grid the contracts pass
/// proves, mirroring the session's batch dispatch: `RowBands(0)` and
/// `Auto` stripe across the worker pool, `PerFrame` needs no shard
/// proofs (the unsharded plans cover it).
fn resolve_grid(policy: &ShardPolicy, workers: usize) -> Option<ShardGrid> {
    match policy {
        ShardPolicy::PerFrame => None,
        ShardPolicy::PerShard(grid) => Some(*grid),
        ShardPolicy::Auto => Some(ShardGrid::striped(workers.max(1))),
        ShardPolicy::RowBands(bands) => {
            let n = if *bands == 0 { workers.max(1) } else { *bands };
            Some(ShardGrid::striped(n))
        }
    }
}
