//! Pass 3 — block/shard geometry proofs.
//!
//! The planner (`coordinator::blocks`, `coordinator::shard`) guards its
//! geometry with runtime panics (`check_plan_geometry`,
//! `check_width_geometry`) and a valid-mode `h < k` underflow that only
//! debug builds catch. This pass lifts those guards into static proofs
//! per conv step of a compiled graph, at a concrete frame geometry:
//!
//! * the typed planner preconditions ([`plan_geometry_check`], plus the
//!   width-axis check) become [`AnalysisFinding`]s instead of panics;
//! * the **actual** [`BlockPlan`]s the planner emits are then verified
//!   against the chip contract: tile height within image-memory
//!   capacity, channel blocks within `n_ch`/stream capacity, every tile
//!   reading the full input halo its output rows need, and the output
//!   space covered **exactly once** by valid output rectangles;
//! * with a shard grid, the same proofs run per [`LayerShard`], plus an
//!   exact-cover proof of the shard partition itself — the halo-row
//!   contract multi-chip tiling depends on.
//!
//! Everything here re-derives from the planner's own code paths, so a
//! future planner change that violates the contract fails the analyzer
//! (and its property tests) rather than a frame at 2 a.m.

use crate::coordinator::blocks::{plan_block_range, plan_geometry_check};
use crate::coordinator::shard::shard_block_plans;
use crate::coordinator::{plan_layer_shards, ShardGrid};
use crate::engine::BlockPlan;
use crate::hw::ChipConfig;
use crate::model::graph::{CompiledGraph, PlanStep};
use crate::model::KernelMode;

use super::{AnalysisFinding, Pass, Severity, StepGeom};

/// Contracts-pass summary.
#[derive(Debug, Clone, Default)]
pub struct ContractsSummary {
    /// Conv steps whose geometry was proved (or refuted).
    pub convs_checked: usize,
    /// Block plans verified against the chip contract.
    pub blocks_checked: usize,
    /// Layer shards verified (0 when analyzing unsharded plans only).
    pub shards_checked: usize,
    /// True when the pass did not run (no frame geometry supplied).
    pub skipped: bool,
}

impl ContractsSummary {
    /// The no-geometry placeholder.
    pub fn skipped() -> ContractsSummary {
        ContractsSummary { skipped: true, ..ContractsSummary::default() }
    }
}

struct Ctx<'a> {
    step: usize,
    label: &'a str,
    findings: &'a mut Vec<AnalysisFinding>,
}

impl Ctx<'_> {
    fn error(&mut self, code: &'static str, detail: String) {
        self.findings.push(AnalysisFinding {
            pass: Pass::Contracts,
            severity: Severity::Error,
            code,
            step: Some(self.step),
            node: self.label.to_string(),
            detail,
        });
    }
}

/// Run the contracts pass over every conv step with a known input
/// shape. `grid` adds the sharded-plan proofs.
pub(crate) fn analyze(
    graph: &CompiledGraph,
    cfg: &ChipConfig,
    geoms: &[StepGeom],
    grid: Option<&ShardGrid>,
    findings: &mut Vec<AnalysisFinding>,
) -> ContractsSummary {
    let mut summary = ContractsSummary::default();
    for (si, step) in graph.steps.iter().enumerate() {
        let PlanStep::Conv { conv, .. } = step else { continue };
        let Some((_, h, w)) = geoms.get(si).and_then(|g| g.srcs.first().copied().flatten())
        else {
            // Upstream geometry already failed; the runtime never
            // reaches this conv.
            continue;
        };
        let cv = &graph.convs[*conv];
        let label = graph.step_labels.get(si).map(String::as_str).unwrap_or("");
        let mut ctx = Ctx { step: si, label, findings };
        summary.convs_checked += 1;

        // The typed planner preconditions, statically. These are the
        // exact checks `check_plan_geometry` panics on at runtime.
        if let Err(e) = plan_geometry_check(cfg, cv.k, cv.zero_pad, h) {
            ctx.error("geometry", format!("{e}"));
            continue;
        }
        if !cv.zero_pad && w < cv.k {
            // `check_width_geometry`'s panic, statically: a valid conv
            // with no output columns.
            ctx.error(
                "geometry",
                format!(
                    "no output columns: valid-mode k={} against width {w} \
                     (width-axis underflow)",
                    cv.k
                ),
            );
            continue;
        }

        let n_in = cv.kernels.n_in;
        let n_out = cv.kernels.n_out;
        let out_h = if cv.zero_pad { h } else { h - cv.k + 1 };

        // Unsharded plans: the whole layer in one partition.
        let plans = plan_block_range(cfg, cv.k, cv.zero_pad, n_in, h, 0, out_h, 0, n_out);
        summary.blocks_checked += plans.len();
        check_plans(&mut ctx, cfg, cv.k, cv.zero_pad, n_in, h, &plans, (0, out_h, 0, n_out));

        // Sharded plans: partition proof, then per-shard block proofs.
        if let Some(grid) = grid {
            let shards = plan_layer_shards(*grid, out_h, n_out);
            check_partition(
                &mut ctx,
                out_h,
                n_out,
                &shards.iter().map(|s| (s.row0, s.rows, s.out0, s.out_len)).collect::<Vec<_>>(),
                "shard",
            );
            for shard in &shards {
                let splans = shard_block_plans(cfg, cv.k, cv.zero_pad, n_in, h, shard);
                summary.blocks_checked += splans.len();
                check_plans(
                    &mut ctx,
                    cfg,
                    cv.k,
                    cv.zero_pad,
                    n_in,
                    h,
                    &splans,
                    (shard.row0, shard.rows, shard.out0, shard.out_len),
                );
            }
            summary.shards_checked += shards.len();
        }
    }
    summary
}

/// Verify one partition's block plans against the chip contract.
/// `region` is the `(row0, rows, out0, out_len)` output rectangle the
/// plans must cover exactly once.
fn check_plans(
    ctx: &mut Ctx<'_>,
    cfg: &ChipConfig,
    k: usize,
    zero_pad: bool,
    n_in: usize,
    h: usize,
    plans: &[BlockPlan],
    region: (usize, usize, usize, usize),
) {
    let streams = if cfg.multi_kernel { KernelMode::for_kernel(k).filters_per_sop() } else { 1 };
    let out_cap = cfg.n_ch * streams;
    let in_blocks_expected = n_in.div_ceil(cfg.n_ch);
    let offset = if zero_pad { (k - 1) / 2 } else { 0 };

    for p in plans {
        // Chip capacity: the image memory must hold the whole tile.
        if p.tile_h > cfg.h_max() {
            ctx.error(
                "chip-capacity-exceeded",
                format!("tile of {} input rows exceeds h_max {}", p.tile_h, cfg.h_max()),
            );
        }
        if p.rows_valid == 0 {
            ctx.error("empty-tile", format!("plan contributes no output rows: {p:?}"));
        }
        if p.in_len > cfg.n_ch || p.out_len > out_cap {
            ctx.error(
                "channel-capacity-exceeded",
                format!(
                    "block of {}x{} channels exceeds the {}x{out_cap} chip block",
                    p.in_len, p.out_len, cfg.n_ch
                ),
            );
        }
        if p.clip0 + p.tile_h > h {
            ctx.error(
                "tile-out-of-image",
                format!("input tile [{}, {}) leaves the {h}-row image", p.clip0, p.clip0 + p.tile_h),
            );
        }
        if p.in_blocks != in_blocks_expected {
            ctx.error(
                "in-block-mismatch",
                format!(
                    "plan declares {} input blocks, {} channels need {in_blocks_expected}",
                    p.in_blocks, n_in
                ),
            );
        }
        // Halo coverage: the input tile must contain every row the
        // plan's output rows convolve over (clamped to the image — the
        // zero-padding injects the rest).
        let need_lo = (p.row_base as isize - offset as isize).max(0) as usize;
        let need_hi = (p.row_base + p.rows_valid - 1 - offset + k).min(h);
        if p.clip0 > need_lo || p.clip0 + p.tile_h < need_hi {
            ctx.error(
                "halo-underread",
                format!(
                    "output rows [{}, {}) need input rows [{need_lo}, {need_hi}) but the \
                     tile reads [{}, {})",
                    p.row_base,
                    p.row_base + p.rows_valid,
                    p.clip0,
                    p.clip0 + p.tile_h
                ),
            );
        }
    }

    // Exact cover of the output rectangle by the in_block == 0 plans
    // (the other input blocks retrace the same rectangles for the
    // off-chip reduction — verified by the in_block census below).
    let rects: Vec<(usize, usize, usize, usize)> = plans
        .iter()
        .filter(|p| p.in_block == 0)
        .map(|p| (p.row_base, p.rows_valid, p.out_base, p.out_len))
        .collect();
    check_partition_region(ctx, region, &rects, "block");

    // Every (output rectangle) must carry the full run of input blocks.
    use std::collections::HashMap;
    let mut census: HashMap<(usize, usize), Vec<usize>> = HashMap::new();
    for p in plans {
        census.entry((p.row_base, p.out_base)).or_default().push(p.in_block);
    }
    for ((row_base, out_base), mut blocks) in census {
        blocks.sort_unstable();
        let expect: Vec<usize> = (0..in_blocks_expected).collect();
        if blocks != expect {
            ctx.error(
                "in-block-mismatch",
                format!(
                    "tile at row {row_base}, channel {out_base} carries input \
                     blocks {blocks:?}, expected {expect:?}"
                ),
            );
        }
    }
}

/// Exact-cover proof of `(out_h, n_out)` by `(row0, rows, out0, out_len)`
/// rectangles, anchored at the origin.
fn check_partition(
    ctx: &mut Ctx<'_>,
    out_h: usize,
    n_out: usize,
    rects: &[(usize, usize, usize, usize)],
    what: &str,
) {
    check_partition_region(ctx, (0, out_h, 0, n_out), rects, what);
}

/// Exact-cover proof of an arbitrary output rectangle.
fn check_partition_region(
    ctx: &mut Ctx<'_>,
    region: (usize, usize, usize, usize),
    rects: &[(usize, usize, usize, usize)],
    what: &str,
) {
    let (row0, rows, out0, out_len) = region;
    if rows == 0 || out_len == 0 {
        return;
    }
    let mut cover = vec![0u8; rows * out_len];
    for &(r0, rl, o0, ol) in rects {
        for r in r0..r0 + rl {
            for o in o0..o0 + ol {
                if r < row0 || r >= row0 + rows || o < out0 || o >= out0 + out_len {
                    ctx.error(
                        "coverage-overrun",
                        format!(
                            "{what} rectangle rows [{r0}, {}) x channels [{o0}, {}) \
                             leaves the output region",
                            r0 + rl,
                            o0 + ol
                        ),
                    );
                    return;
                }
                cover[(r - row0) * out_len + (o - out0)] += 1;
            }
        }
    }
    if let Some(idx) = cover.iter().position(|&c| c == 0) {
        ctx.error(
            "coverage-gap",
            format!(
                "output row {}, channel {} is computed by no {what}",
                row0 + idx / out_len,
                out0 + idx % out_len
            ),
        );
    }
    if let Some(idx) = cover.iter().position(|&c| c > 1) {
        ctx.error(
            "coverage-overlap",
            format!(
                "output row {}, channel {} is computed by {} {what}s",
                row0 + idx / out_len,
                out0 + idx % out_len,
                cover[idx]
            ),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::step_geometry;
    use crate::model::graph::{NetworkBuilder, Weights};
    use crate::testkit::Gen;

    fn conv_graph(k: usize, zero_pad: bool, n_in: usize, n_out: usize) -> CompiledGraph {
        let mut g = Gen::new(13);
        let mut b = NetworkBuilder::new("contracts-ut", n_in);
        let x = b.input();
        let c = b.conv("conv", x, zero_pad, Weights::seeded(&mut g, n_out, n_in, k));
        b.build(c).compile().expect("compiles")
    }

    fn run(
        graph: &CompiledGraph,
        cfg: &ChipConfig,
        shape: (usize, usize),
        grid: Option<ShardGrid>,
    ) -> (ContractsSummary, Vec<AnalysisFinding>) {
        let (geoms, mut findings) = step_geometry(graph, shape);
        let sum = analyze(graph, cfg, &geoms, grid.as_ref(), &mut findings);
        (sum, findings)
    }

    #[test]
    fn valid_geometries_prove_clean_including_shards() {
        let cfg = ChipConfig::yodann();
        // 80 rows forces row tiling (h_max = 32); 70 channels forces
        // channel blocking; the 3-stripe x 2-group grid adds shards.
        let g = conv_graph(3, true, 70, 70);
        let (sum, findings) = run(&g, &cfg, (80, 40), Some(ShardGrid::new(3, 2)));
        assert!(findings.is_empty(), "clean geometry must prove: {findings:?}");
        assert_eq!(sum.convs_checked, 1);
        assert_eq!(sum.shards_checked, 6);
        assert!(sum.blocks_checked > 6, "tiling must emit plans: {}", sum.blocks_checked);
    }

    #[test]
    fn valid_mode_h_under_k_is_refuted_not_panicked() {
        let cfg = ChipConfig::yodann();
        let g = conv_graph(5, false, 2, 2);
        let (sum, findings) = run(&g, &cfg, (3, 16), None);
        assert_eq!(sum.convs_checked, 1);
        assert!(
            findings.iter().any(|f| f.code == "geometry" && f.severity == Severity::Error),
            "h < k must be a typed finding: {findings:?}"
        );
    }

    #[test]
    fn width_underflow_is_refuted() {
        let cfg = ChipConfig::yodann();
        let g = conv_graph(5, false, 2, 2);
        let (_, findings) = run(&g, &cfg, (16, 3), None);
        assert!(
            findings.iter().any(|f| f.code == "geometry" && f.detail.contains("width")),
            "w < k must be a typed finding: {findings:?}"
        );
    }

    #[test]
    fn chip_capacity_h_max_under_k_is_refuted() {
        // tiny(1): h_max = 64 / 1 = 64... use a config whose image
        // memory cannot hold one 7-row window.
        let cfg = ChipConfig { image_mem_rows: 4, ..ChipConfig::yodann() };
        assert!(cfg.h_max() < 7);
        let g = conv_graph(7, true, 2, 2);
        let (_, findings) = run(&g, &cfg, (16, 16), None);
        assert!(
            findings.iter().any(|f| f.code == "geometry"),
            "h_max < k must be refuted: {findings:?}"
        );
    }

    #[test]
    fn partition_checker_catches_gaps_and_overlaps() {
        let mut findings = Vec::new();
        let mut ctx = Ctx { step: 0, label: "ut", findings: &mut findings };
        // Gap: second row stripe missing.
        check_partition(&mut ctx, 4, 2, &[(0, 2, 0, 2)], "shard");
        assert!(ctx.findings.iter().any(|f| f.code == "coverage-gap"));
        let mut findings = Vec::new();
        let mut ctx = Ctx { step: 0, label: "ut", findings: &mut findings };
        // Overlap: stripes share row 1.
        check_partition(&mut ctx, 3, 1, &[(0, 2, 0, 1), (1, 2, 0, 1)], "shard");
        assert!(ctx.findings.iter().any(|f| f.code == "coverage-overlap"));
    }
}
