//! Pass 2 — slot-store lifetime analysis.
//!
//! The session interpreters execute the step program over a slot store,
//! dropping each slot at the step [`compute_free_after`] marks as its
//! last use. This pass symbolically executes the same program over
//! abstract slot states (unwritten / live / freed) and proves the
//! discipline the interpreters rely on:
//!
//! * every read hits a live slot (no use-before-def, no use-after-free);
//! * every free hits a live, non-output slot exactly once
//!   (no double-free, no freeing the output);
//! * each slot is written exactly once (single-assignment store);
//! * at the end, the output is live and everything else was freed
//!   (no leaked slot — a leak is a dead node the compiler should have
//!   rejected, and memory the interpreter would hold for the whole
//!   frame).
//!
//! With a frame geometry available it also reports **peak live-slot
//! memory**: the maximum, over step boundaries, of the summed live
//! feature-map sizes — the number the report module compares against
//! the paper's SCM sizing.
//!
//! [`compute_free_after`]: crate::model::graph::CompiledGraph

use crate::model::graph::CompiledGraph;

use super::{AnalysisFinding, Pass, Severity, StepGeom};

/// Abstract state of one slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Slot {
    Unwritten,
    Live,
    Freed,
}

/// Liveness-pass summary.
#[derive(Debug, Clone, Default)]
pub struct LivenessSummary {
    /// Maximum number of simultaneously live slots.
    pub peak_slots: usize,
    /// Maximum live feature-map footprint in Q2.9 words (`c·h·w`,
    /// summed over live slots); `None` without a frame geometry.
    pub peak_words: Option<usize>,
    /// Steps executed.
    pub steps: usize,
    /// Slots in the store.
    pub n_slots: usize,
}

/// Run the liveness pass. `geoms` (when a frame geometry was supplied)
/// carries per-step slot shapes for the footprint accounting.
pub(crate) fn analyze(
    graph: &CompiledGraph,
    geoms: Option<&[StepGeom]>,
    findings: &mut Vec<AnalysisFinding>,
) -> LivenessSummary {
    let mut slots = vec![Slot::Unwritten; graph.n_slots];
    let mut words = vec![0usize; graph.n_slots];
    slots[graph.input_slot] = Slot::Live;
    if let Some(geoms) = geoms {
        // The input slot's footprint, before any step runs.
        if let Some(first) = geoms.first() {
            if let Some((c, h, w)) = first.srcs.first().copied().flatten() {
                words[graph.input_slot] = c * h * w;
            }
        }
    }
    let mut finding = |severity, code, step: usize, node: &str, detail: String| {
        findings.push(AnalysisFinding {
            pass: Pass::Liveness,
            severity,
            code,
            step: Some(step),
            node: node.to_string(),
            detail,
        });
    };

    let mut peak_slots = slots.iter().filter(|&&s| s == Slot::Live).count();
    let mut peak_words = words.iter().sum::<usize>();
    let mut shapes_complete = geoms.is_some();

    for (si, step) in graph.steps.iter().enumerate() {
        let label = graph.step_labels.get(si).cloned().unwrap_or_default();
        for src in step.srcs() {
            match slots[src] {
                Slot::Live => {}
                Slot::Unwritten => finding(
                    Severity::Error,
                    "use-before-def",
                    si,
                    &label,
                    format!("step reads slot {src} before anything wrote it"),
                ),
                Slot::Freed => finding(
                    Severity::Error,
                    "use-after-free",
                    si,
                    &label,
                    format!("step reads slot {src} after its last-use free"),
                ),
            }
        }
        let dst = step.dst();
        match slots[dst] {
            Slot::Unwritten => {}
            Slot::Live => finding(
                Severity::Error,
                "double-write",
                si,
                &label,
                format!("slot {dst} is written twice — the store is single-assignment"),
            ),
            Slot::Freed => finding(
                Severity::Error,
                "write-after-free",
                si,
                &label,
                format!("slot {dst} is rewritten after being freed"),
            ),
        }
        slots[dst] = Slot::Live;
        match geoms.and_then(|g| g.get(si)).and_then(|g| g.dst) {
            Some((c, h, w)) => words[dst] = c * h * w,
            None => shapes_complete = false,
        }

        // Peak is sampled here: destination written, sources still held
        // (the interpreter drops them only after the step completes).
        peak_slots = peak_slots.max(slots.iter().filter(|&&s| s == Slot::Live).count());
        peak_words = peak_words.max(
            slots
                .iter()
                .zip(words.iter())
                .filter(|(&s, _)| s == Slot::Live)
                .map(|(_, &w)| w)
                .sum(),
        );

        for &f in &graph.free_after[si] {
            match slots[f] {
                Slot::Live if f == graph.output_slot => finding(
                    Severity::Error,
                    "free-output",
                    si,
                    &label,
                    format!("the output slot {f} must never be freed"),
                ),
                Slot::Live => slots[f] = Slot::Freed,
                Slot::Freed => finding(
                    Severity::Error,
                    "double-free",
                    si,
                    &label,
                    format!("slot {f} is freed twice"),
                ),
                Slot::Unwritten => finding(
                    Severity::Error,
                    "free-unwritten",
                    si,
                    &label,
                    format!("slot {f} is freed before anything wrote it"),
                ),
            }
        }
    }

    let last = graph.steps.len().saturating_sub(1);
    if slots[graph.output_slot] != Slot::Live {
        finding(
            Severity::Error,
            "output-missing",
            last,
            "",
            format!("output slot {} is not live when the program ends", graph.output_slot),
        );
    }
    for (s, &state) in slots.iter().enumerate() {
        if state == Slot::Live && s != graph.output_slot {
            finding(
                Severity::Error,
                "slot-leak",
                last,
                "",
                format!(
                    "slot {s} is still live when the program ends — a dead \
                     node the interpreter would hold for the whole frame"
                ),
            );
        }
    }

    LivenessSummary {
        peak_slots,
        peak_words: shapes_complete.then_some(peak_words),
        steps: graph.steps.len(),
        n_slots: graph.n_slots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::{NetworkBuilder, PlanStep, Weights};
    use crate::testkit::Gen;

    fn residual_graph(seed: u64) -> CompiledGraph {
        let mut g = Gen::new(seed);
        let mut b = NetworkBuilder::new("live-ut", 2);
        let x = b.input();
        let c1 = b.conv("c1", x, true, Weights::seeded(&mut g, 4, 2, 3));
        let r1 = b.relu(c1);
        let c2 = b.conv("c2", r1, true, Weights::seeded(&mut g, 4, 4, 3));
        let a = b.add("res", &[r1, c2]);
        b.build(a).compile().expect("residual graph compiles")
    }

    #[test]
    fn compiled_graphs_are_clean_and_peak_counts_the_residual() {
        let g = residual_graph(5);
        let mut findings = Vec::new();
        let sum = analyze(&g, None, &mut findings);
        assert!(findings.is_empty(), "compiled graph must be lifetime-clean: {findings:?}");
        // The residual holds r1 across c2: at least 2 simultaneous maps
        // plus the destination being written.
        assert!(sum.peak_slots >= 3, "residual peak: {}", sum.peak_slots);
        assert_eq!(sum.steps, g.steps.len());
    }

    #[test]
    fn peak_words_follow_the_shape_walk() {
        let g = residual_graph(9);
        let (geoms, shape_findings) = crate::analysis::step_geometry(&g, (8, 8));
        assert!(shape_findings.is_empty());
        let mut findings = Vec::new();
        let sum = analyze(&g, Some(&geoms), &mut findings);
        // Input 2×8×8 = 128; r1 and c2 are 4×8×8 = 256 each. Peak is at
        // the add: r1 + c2 live + the add's 256-word destination.
        assert_eq!(sum.peak_words, Some(3 * 256));
    }

    #[test]
    fn a_corrupted_free_list_is_caught() {
        let mut g = residual_graph(7);
        // Free the residual branch right after its first read: the add
        // step later reads it again — use-after-free.
        let r1_slot = match g.steps[2] {
            PlanStep::Conv { src, .. } => src,
            ref s => panic!("expected c2 conv step, got {s:?}"),
        };
        g.free_after[2].push(r1_slot);
        let mut findings = Vec::new();
        analyze(&g, None, &mut findings);
        assert!(
            findings.iter().any(|f| f.code == "use-after-free"),
            "corrupted free list must surface: {findings:?}"
        );
    }

    #[test]
    fn a_leaked_slot_is_caught() {
        let mut g = residual_graph(7);
        // Drop every free: everything but the output leaks.
        for f in g.free_after.iter_mut() {
            f.clear();
        }
        let mut findings = Vec::new();
        analyze(&g, None, &mut findings);
        assert!(findings.iter().any(|f| f.code == "slot-leak"), "leaks must surface");
    }
}
